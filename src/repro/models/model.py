"""Model assembly: decoder-only LMs (dense / MoE / MLA), xLSTM, Zamba2-style
hybrids, and encoder-decoder — all scan-over-layers, cache-aware, and
declared via P-descriptors for abstract (dry-run) initialization.

Public API (built by `build_model(cfg)`):
  model.desc()                         -> param descriptor tree
  model.forward(params, batch, cache)  -> (logits, new_cache)
  model.loss(params, batch)            -> (loss, metrics)
  model.cache_desc(batch, max_len)     -> cache ShapeDtypeStruct tree
  model.init_cache(batch, max_len)     -> zero-initialized cache
  model.decode_step(params, tok, cache)-> (logits, new_cache)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import blocks, nn, ssm, xlstm
from .config import ModelConfig
from .nn import P, dense, rms_norm, shard


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _zeros_cache(desc_tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), desc_tree)


def _stack_descs(desc: dict, n: int) -> dict:
    return nn.stack_layers([desc] * n)


def scan_layers(body, init, xs, *, unroll: bool):
    """lax.scan over stacked layers, or an unrolled python loop when
    `unroll` (dry-run cost probes: XLA costs a scan body only ONCE, so the
    1/2-unit extrapolation modules must be unrolled to be countable)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


class BaseLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # --- embedding / head -------------------------------------------------
    def _embed_desc(self) -> dict:
        cfg = self.cfg
        out = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
            "final_norm": P((cfg.d_model,), ("norm",), "ones"),
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        if cfg.frontend == "vision":
            out["patch_proj"] = P((cfg.d_model, cfg.d_model), ("embed", "embed"))
        if cfg.frontend == "audio":
            out["frame_proj"] = P((cfg.d_model, cfg.d_model), ("embed", "embed"))
        return out

    def _embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tok = batch["tokens"]
        x = params["embed"][tok].astype(_dt(cfg))
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = dense(batch["patch_embeds"].astype(_dt(cfg)), params["patch_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        x = shard(x, "batch", None, None)
        return x

    def _logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        xn = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bld,dv->blv", xn, head.astype(xn.dtype))
        return shard(logits.astype(jnp.float32), "batch", None, "vocab")

    # --- losses ------------------------------------------------------------
    def loss(self, params, batch):
        logits, _ = self.forward(params, batch, cache=None)
        labels = batch["labels"]
        if self.cfg.frontend == "vision" and "patch_embeds" in batch:
            # logits cover [patches, tokens]; labels only the token part
            logits = logits[:, -labels.shape[1] :]
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    # --- cache -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        return _zeros_cache(self.cache_desc(batch, max_len))

    def decode_step(self, params, tokens, cache):
        return self.forward(params, {"tokens": tokens}, cache=cache)


# ---------------------------------------------------------------------------
# decoder-only transformer (dense / moe / mla / vlm)
# ---------------------------------------------------------------------------


class TransformerLM(BaseLM):
    """Dense or MoE decoder-only LM; attention is GQA or MLA per config."""

    def _attn_desc(self):
        return blocks.desc_mla(self.cfg) if self.cfg.mla else blocks.desc_attn(self.cfg)

    def _mlp_desc(self):
        return blocks.desc_moe(self.cfg) if self.cfg.moe else blocks.desc_mlp(self.cfg)

    def _n_dense(self) -> int:
        return self.cfg.moe.n_dense_layers if self.cfg.moe else 0

    def desc(self):
        cfg = self.cfg
        nd = self._n_dense()
        layer = {"attn": self._attn_desc(), "mlp": self._mlp_desc()}
        out = self._embed_desc()
        if nd:
            dense_layer = {"attn": self._attn_desc(), "mlp": blocks.desc_mlp(cfg)}
            out["dense_blocks"] = _stack_descs(dense_layer, nd)
        out["blocks"] = _stack_descs(layer, cfg.n_layers - nd)
        return out

    def _block(self, p, x, positions, cache, window=None):
        cfg = self.cfg
        if cfg.mla:
            a, new_c = blocks.apply_mla(p["attn"], x, positions, cfg, cache=cache)
        else:
            a, new_c = blocks.apply_attn(
                p["attn"], x, positions, cfg, cache=cache, window=window
            )
        x = x + a
        if cfg.moe and "router" in p["mlp"]:
            x = x + blocks.apply_moe(p["mlp"], x, cfg)
        else:
            x = x + blocks.apply_mlp(p["mlp"], x, cfg)
        return x, new_c

    def forward(self, params, batch, cache=None):
        cfg = self.cfg
        x = self._embed(params, batch)
        b, l, _ = x.shape
        pos0 = cache["pos"] if cache is not None else 0
        # paged serving cache (DESIGN.md §9): per-slot clocks (B,) + page
        # table, threaded into every layer's cache view
        paged = cache is not None and "page_table" in cache
        if paged:
            positions = pos0[:, None] + jnp.arange(l)[None, :]
        else:
            positions = pos0 + jnp.arange(l)[None, :]
        nd = self._n_dense()
        new_cache = {"pos": pos0 + l} if cache is not None else None
        if paged:
            new_cache["page_table"] = cache["page_table"]

        def layer_cache(cl):
            cl = dict(cl, len=pos0)
            if paged:
                cl["ptab"] = cache["page_table"]
            return cl

        def strip(nc):
            nc.pop("len", None)
            nc.pop("ptab", None)
            return nc

        if nd:
            for i in range(nd):
                pl_ = jax.tree_util.tree_map(lambda a: a[i], params["dense_blocks"])
                cl = jax.tree_util.tree_map(lambda a: a[i], cache["dense_blocks"]) if cache else None
                if cl is not None:
                    cl = layer_cache(cl)
                x, nc = self._block(pl_, x, positions, cl)
                if cache is not None:
                    strip(nc)
                    if i == 0:
                        new_cache["dense_blocks"] = jax.tree_util.tree_map(
                            lambda a: jnp.broadcast_to(a[None], (nd,) + a.shape).copy(), nc
                        )
                    else:
                        new_cache["dense_blocks"] = jax.tree_util.tree_map(
                            lambda acc, a: acc.at[i].set(a), new_cache["dense_blocks"], nc
                        )

        def scan_fn(carry, xs):
            xcur = carry
            if cache is not None:
                pl_, cl = xs
                cl = layer_cache(cl)
            else:
                pl_, cl = xs, None
            xcur, nc = self._block(pl_, xcur, positions, cl, window=cfg.attn_window)
            if nc is not None:
                strip(nc)
            return xcur, nc

        xs = (params["blocks"], cache["blocks"]) if cache is not None else params["blocks"]
        body = jax.checkpoint(scan_fn) if (cache is None and cfg.remat) else scan_fn
        x, ncache = scan_layers(body, x, xs, unroll=cfg.unroll_layers)
        if cache is not None:
            new_cache["blocks"] = ncache
        return self._logits(params, x), new_cache

    def cache_desc(self, batch: int, max_len: int):
        cfg = self.cfg
        nd = self._n_dense()
        one = (
            blocks.mla_cache_desc(cfg, batch, max_len)
            if cfg.mla
            else blocks.attn_cache_desc(cfg, batch, max_len)
        )
        one = {k: v for k, v in one.items() if k != "len"}
        def stack(s, n):
            return jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
        out = {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "blocks": jax.tree_util.tree_map(partial(stack, n=cfg.n_layers - nd), one),
        }
        if nd:
            out["dense_blocks"] = jax.tree_util.tree_map(partial(stack, n=nd), one)
        return out

    # --- paged serving cache (DESIGN.md §9) --------------------------------
    def paged_cache_desc(self, slots: int, pages: int, page_tokens: int,
                         max_pages: int):
        """Cache descriptors for the paged serving tier: per-slot position
        clocks + a (slots, max_pages) page table over a shared page arena of
        `pages` allocatable pages per layer (page 0 is reserved scratch, so
        arenas are sized pages+1)."""
        cfg = self.cfg
        if cfg.mla:
            raise NotImplementedError("paged KV cache does not support MLA")
        nd = self._n_dense()
        one = blocks.paged_attn_cache_desc(cfg, pages, page_tokens)
        def stack(s, n):
            return jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
        out = {
            "pos": jax.ShapeDtypeStruct((slots,), jnp.int32),
            "page_table": jax.ShapeDtypeStruct((slots, max_pages), jnp.int32),
            "blocks": jax.tree_util.tree_map(partial(stack, n=cfg.n_layers - nd), one),
        }
        if nd:
            out["dense_blocks"] = jax.tree_util.tree_map(partial(stack, n=nd), one)
        return out

    def init_paged_cache(self, slots: int, pages: int, page_tokens: int,
                         max_pages: int):
        return _zeros_cache(self.paged_cache_desc(slots, pages, page_tokens, max_pages))


# ---------------------------------------------------------------------------
# xLSTM (groups of m mLSTM + s sLSTM)
# ---------------------------------------------------------------------------


class XLSTMLM(BaseLM):
    def _gcount(self):
        xc = self.cfg.xlstm
        per = xc.m_per_group + xc.s_per_group
        assert self.cfg.n_layers % per == 0, (self.cfg.n_layers, per)
        return self.cfg.n_layers // per

    def desc(self):
        cfg = self.cfg
        xc = cfg.xlstm
        g = self._gcount()
        group = {
            "m": _stack_descs(xlstm.desc_mlstm(cfg), xc.m_per_group),
            "s": _stack_descs(xlstm.desc_slstm(cfg), xc.s_per_group),
        }
        out = self._embed_desc()
        out["groups"] = _stack_descs(group, g)
        return out

    def forward(self, params, batch, cache=None):
        cfg = self.cfg
        xc = cfg.xlstm
        x = self._embed(params, batch)
        pos0 = cache["pos"] if cache is not None else 0
        new_cache = {"pos": pos0 + x.shape[1]} if cache is not None else None

        def one_group(xcur, gp, gc):
            ncs = {"m": [], "s": []}
            for i in range(xc.m_per_group):
                pl_ = jax.tree_util.tree_map(lambda a: a[i], gp["m"])
                cl = jax.tree_util.tree_map(lambda a: a[i], gc["m"]) if gc else None
                y, nc = xlstm.apply_mlstm(pl_, xcur, cfg, cache=cl)
                xcur = xcur + y
                ncs["m"].append(nc)
            for i in range(xc.s_per_group):
                pl_ = jax.tree_util.tree_map(lambda a: a[i], gp["s"])
                cl = jax.tree_util.tree_map(lambda a: a[i], gc["s"]) if gc else None
                y, nc = xlstm.apply_slstm(pl_, xcur, cfg, cache=cl)
                xcur = xcur + y
                ncs["s"].append(nc)
            if gc is not None:
                ncs = {
                    k: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *v)
                    for k, v in ncs.items()
                }
            return xcur, (ncs if gc is not None else None)

        def scan_fn(xcur, xs):
            if cache is not None:
                gp, gc = xs
            else:
                gp, gc = xs, None
            return one_group(xcur, gp, gc)

        xs = (params["groups"], cache["groups"]) if cache is not None else params["groups"]
        body = jax.checkpoint(scan_fn) if (cache is None and cfg.remat) else scan_fn
        x, ncache = scan_layers(body, x, xs, unroll=cfg.unroll_layers)
        if cache is not None:
            new_cache["groups"] = ncache
        return self._logits(params, x), new_cache

    def init_cache(self, batch: int, max_len: int):
        cache = _zeros_cache(self.cache_desc(batch, max_len))
        # mLSTM stabilizer state starts at -inf (matches the parallel path)
        cache["groups"]["m"]["m"] = jnp.full_like(cache["groups"]["m"]["m"], -1e30)
        return cache

    def cache_desc(self, batch: int, max_len: int):
        cfg = self.cfg
        xc = cfg.xlstm
        g = self._gcount()
        def stackn(s, n):
            return jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
        group = {
            "m": jax.tree_util.tree_map(
                partial(stackn, n=xc.m_per_group), xlstm.mlstm_cache_desc(cfg, batch)
            ),
            "s": jax.tree_util.tree_map(
                partial(stackn, n=xc.s_per_group), xlstm.slstm_cache_desc(cfg, batch)
            ),
        }
        return {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "groups": jax.tree_util.tree_map(partial(stackn, n=g), group),
        }


# ---------------------------------------------------------------------------
# Zamba2-style hybrid: Mamba2 backbone + shared attention block
# ---------------------------------------------------------------------------


class HybridLM(BaseLM):
    """`every` Mamba2 layers followed by one *shared* GQA attention block
    (weights reused at every application), input fused with the original
    embedding (concat + projection), zamba-style."""

    def _layout(self):
        cfg = self.cfg
        k = cfg.hybrid.every
        n_groups = cfg.n_layers // k
        tail = cfg.n_layers - n_groups * k
        return n_groups, k, tail

    def desc(self):
        cfg = self.cfg
        n_groups, k, tail = self._layout()
        out = self._embed_desc()
        out["mamba_groups"] = _stack_descs(_stack_descs(ssm.desc_mamba(cfg), k), n_groups)
        if tail:
            out["mamba_tail"] = _stack_descs(ssm.desc_mamba(cfg), tail)
        out["shared_attn"] = blocks.desc_attn(cfg)
        out["shared_mlp"] = blocks.desc_mlp(cfg)
        out["fuse"] = P((2 * cfg.d_model, cfg.d_model), ("embed", "embed"))
        return out

    def forward(self, params, batch, cache=None):
        cfg = self.cfg
        n_groups, k, tail = self._layout()
        x = self._embed(params, batch)
        emb0 = x
        pos0 = cache["pos"] if cache is not None else 0
        positions = pos0 + jnp.arange(x.shape[1])[None, :]
        new_cache = {"pos": pos0 + x.shape[1]} if cache is not None else None

        def mamba_stack(xcur, stacked_p, stacked_c):
            def scan_fn(xc_, xs):
                if stacked_c is not None:
                    pl_, cl = xs
                else:
                    pl_, cl = xs, None
                y, nc = ssm.apply_mamba(pl_, xc_, cfg, cache=cl)
                return xc_ + y, nc

            xs = (stacked_p, stacked_c) if stacked_c is not None else stacked_p
            body = jax.checkpoint(scan_fn) if (stacked_c is None and cfg.remat) else scan_fn
            return scan_layers(body, xcur, xs, unroll=cfg.unroll_layers)

        attn_caches = []
        mamba_group_caches = []
        for gi in range(n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[gi], params["mamba_groups"])
            gc = (
                jax.tree_util.tree_map(lambda a: a[gi], cache["mamba_groups"])
                if cache is not None
                else None
            )
            x, nc = mamba_stack(x, gp, gc)
            if cache is not None:
                mamba_group_caches.append(nc)
            # shared attention block on [x ; emb0]
            fused = dense(jnp.concatenate([x, emb0], axis=-1), params["fuse"])
            ac = None
            if cache is not None:
                ac = dict(
                    jax.tree_util.tree_map(lambda a: a[gi], cache["attn"]), len=pos0
                )
            a, nac = blocks.apply_attn(
                params["shared_attn"], fused, positions, cfg,
                cache=ac, window=cfg.attn_window,
            )
            x = x + a
            x = x + blocks.apply_mlp(params["shared_mlp"], x, cfg)
            if cache is not None:
                nac.pop("len")
                attn_caches.append(nac)
        if tail:
            tc = cache["mamba_tail"] if cache is not None else None
            x, ntc = mamba_stack(x, params["mamba_tail"], tc)
            if cache is not None:
                new_cache["mamba_tail"] = ntc
        if cache is not None:
            new_cache["mamba_groups"] = jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *mamba_group_caches
            )
            new_cache["attn"] = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *attn_caches)
        return self._logits(params, x), new_cache

    def cache_desc(self, batch: int, max_len: int):
        cfg = self.cfg
        n_groups, k, tail = self._layout()
        def stackn(s, n):
            return jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
        mc = ssm.mamba_cache_desc(cfg, batch)
        ac = {k_: v for k_, v in blocks.attn_cache_desc(cfg, batch, max_len).items() if k_ != "len"}
        out = {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "mamba_groups": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n_groups, k) + s.shape, s.dtype), mc
            ),
            "attn": jax.tree_util.tree_map(partial(stackn, n=n_groups), ac),
        }
        if tail:
            out["mamba_tail"] = jax.tree_util.tree_map(partial(stackn, n=tail), mc)
        return out


# ---------------------------------------------------------------------------
# encoder-decoder (seamless-style; audio frontend stubbed as frame embeddings)
# ---------------------------------------------------------------------------


class EncDecLM(BaseLM):
    def desc(self):
        cfg = self.cfg
        enc_layer = {"attn": blocks.desc_attn(cfg), "mlp": blocks.desc_mlp(cfg)}
        dec_layer = {
            "attn": blocks.desc_attn(cfg),
            "cross": blocks.desc_attn(cfg),
            "mlp": blocks.desc_mlp(cfg),
        }
        out = self._embed_desc()
        out["enc_blocks"] = _stack_descs(enc_layer, cfg.n_enc_layers)
        out["enc_norm"] = P((cfg.d_model,), ("norm",), "ones")
        out["dec_blocks"] = _stack_descs(dec_layer, cfg.n_layers)
        return out

    def encode(self, params, frames):
        cfg = self.cfg
        x = dense(frames.astype(_dt(cfg)), params["frame_proj"])
        x = shard(x, "batch", None, None)
        positions = jnp.arange(x.shape[1])[None, :]

        def scan_fn(xc_, pl_):
            a, _ = blocks.apply_attn(pl_["attn"], xc_, positions, cfg, causal=False)
            xc_ = xc_ + a
            return xc_ + blocks.apply_mlp(pl_["mlp"], xc_, cfg), None

        body = jax.checkpoint(scan_fn) if cfg.remat else scan_fn
        x, _ = scan_layers(body, x, params["enc_blocks"], unroll=cfg.unroll_layers)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def forward(self, params, batch, cache=None):
        cfg = self.cfg
        if "frames" in batch:  # (re)encode; else reuse the cached memory
            memory = self.encode(params, batch["frames"])
        else:
            memory = cache["memory"]
        tok = batch["tokens"]
        x = params["embed"][tok].astype(_dt(cfg))
        x = shard(x, "batch", None, None)
        pos0 = cache["pos"] if cache is not None else 0
        positions = pos0 + jnp.arange(x.shape[1])[None, :]
        new_cache = (
            {"pos": pos0 + x.shape[1], "memory": memory} if cache is not None else None
        )

        def scan_fn(xc_, xs):
            if cache is not None:
                pl_, cl = xs
                cl = dict(cl, len=pos0)
            else:
                pl_, cl = xs, None
            a, nc = blocks.apply_attn(pl_["attn"], xc_, positions, cfg, cache=cl)
            xc_ = xc_ + a
            c, _ = blocks.apply_attn(pl_["cross"], xc_, positions, cfg, memory=memory)
            xc_ = xc_ + c
            xc_ = xc_ + blocks.apply_mlp(pl_["mlp"], xc_, cfg)
            if nc is not None:
                nc.pop("len")
            return xc_, nc

        xs = (params["dec_blocks"], cache["blocks"]) if cache is not None else params["dec_blocks"]
        body = jax.checkpoint(scan_fn) if (cache is None and cfg.remat) else scan_fn
        x, ncache = scan_layers(body, x, xs, unroll=cfg.unroll_layers)
        if cache is not None:
            new_cache["blocks"] = ncache
        return self._logits(params, x), new_cache

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch, cache=None)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    def cache_desc(self, batch: int, max_len: int, enc_len: int | None = None):
        cfg = self.cfg
        enc_len = enc_len or cfg.frontend_len
        one = {k: v for k, v in blocks.attn_cache_desc(cfg, batch, max_len).items() if k != "len"}
        def stackn(s):
            return jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype)
        return {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "memory": jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model), _dt(cfg)),
            "blocks": jax.tree_util.tree_map(stackn, one),
        }


def build_model(cfg: ModelConfig) -> BaseLM:
    if cfg.encdec:
        return EncDecLM(cfg)
    if cfg.xlstm is not None:
        return XLSTMLM(cfg)
    if cfg.hybrid is not None:
        return HybridLM(cfg)
    return TransformerLM(cfg)
