"""Mamba2 (SSD — state-space duality) blocks, chunked-parallel form.

Training/prefill uses the chunked algorithm (intra-chunk attention-like term
+ inter-chunk state recurrence over L/chunk steps), so the HLO contains a
short scan over chunks instead of a length-L loop — both TPU-friendly and
honest for cost analysis. Decode is the O(1) recurrent update.

State convention per head: h in R^{N x P} (state x head_dim),
  h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t (x) x_t,   y_t = C_t h_t + D x_t
with A < 0 scalar per head, B/C shared across heads per group (G=1 here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMCfg
from .nn import P, dense, rms_norm, shard


def desc_mamba(cfg: ModelConfig) -> dict:
    s: SSMCfg = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    g = s.n_groups
    conv_dim = d_in + 2 * g * s.state
    return {
        "norm": P((d,), ("norm",), "ones"),
        "in_proj": P((d, 2 * d_in + 2 * g * s.state + nh), ("embed", "mlp")),
        "conv_w": P((s.conv, conv_dim), (None, "mlp")),
        "conv_b": P((conv_dim,), ("mlp",), "zeros"),
        "A_log": P((nh,), (None,), "zeros"),   # A = -exp(A_log) ~ -1
        "D": P((nh,), (None,), "ones"),
        "dt_bias": P((nh,), (None,), "zeros"),
        "out_norm": P((d_in,), ("norm",), "ones"),
        "out_proj": P((d_in, d), ("mlp", "embed")),
    }


def _segsum(log_a: jax.Array) -> jax.Array:
    """log_a: (..., Q) -> (..., Q, Q) with [t, s] = sum_{s < r <= t} log_a_r,
    -inf above the diagonal (the 1-SS decay matrix of the SSD paper)."""
    q = log_a.shape[-1]
    cum = jnp.cumsum(log_a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H) positive
    A: jax.Array,       # (H,) negative
    Bm: jax.Array,      # (B, L, N)  (G=1, shared across heads)
    Cm: jax.Array,      # (B, L, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,H,P), h_final (B,H,N,P))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)
    log_a = dtc * A  # (b, nc, q, h), <= 0
    log_a_h = jnp.moveaxis(log_a, -1, 2)  # (b, nc, h, q)
    cum = jnp.cumsum(log_a_h, axis=-1)  # (b, nc, h, q)
    # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t . B_s) x_s
    Lmat = jnp.exp(_segsum(log_a_h))  # (b, nc, h, q, q)
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)  # (b, nc, q, q)
    W = scores[:, :, None] * Lmat * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchts,bcshp->bcthp", W.astype(x.dtype), xc)
    # chunk states: S_c = sum_s exp(cum_end - cum_s) dt_s B_s (x) x_s
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (b, nc, h, q)
    wS = (decay_to_end * jnp.moveaxis(dtc, -1, 2)).astype(x.dtype)  # (b,nc,h,q)
    S = jnp.einsum("bchs,bcsn,bcshp->bchnp", wS, Bc, xc)  # (b, nc, h, n, p)
    # inter-chunk recurrence (scan over nc chunks)
    chunk_decay = jnp.exp(cum[..., -1])  # (b, nc, h)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), x.dtype)

    def step(hprev, inp):
        S_c, dec_c = inp  # (b,h,n,p), (b,h)
        hnew = hprev * dec_c[..., None, None].astype(x.dtype) + S_c
        return hnew, hprev

    xs = (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    h_final, h_prevs = jax.lax.scan(step, h0, xs)  # h_prevs: (nc, b, h, n, p)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (b, nc, h, n, p)
    # inter contribution: y[t] += exp(cum_t) C_t . h_prev_chunk
    in_decay = jnp.exp(cum)  # (b, nc, h, q)
    y_inter = jnp.einsum(
        "bctn,bchnp,bcht->bcthp", Cc, h_prevs, in_decay.astype(x.dtype)
    )
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, h_final


def apply_mamba(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Mamba2 block. cache = {'h': (B,H,N,P), 'conv': (B,conv-1,conv_dim)}."""
    s: SSMCfg = cfg.ssm
    b, l, d = x.shape
    d_in = s.expand * d
    nh = d_in // s.head_dim
    g, n = s.n_groups, s.state
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = dense(xn, p["in_proj"])
    z, xi, BC, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xi, BC], axis=-1)  # (b, l, conv_dim)
    # depthwise causal conv, kernel K
    K = s.conv
    if cache is not None:
        prev = cache["conv"].astype(conv_in.dtype)  # (b, K-1, conv_dim)
        ext = jnp.concatenate([prev, conv_in], axis=1)
        new_conv = ext[:, -(K - 1) :, :]
    else:
        ext = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv = ext[:, -(K - 1) :, :]
    wins = jnp.stack([ext[:, i : i + l, :] for i in range(K)], axis=2)  # (b,l,K,c)
    conv_out = jnp.einsum("blkc,kc->blc", wins, p["conv_w"].astype(x.dtype)) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xi, Bm, Cm = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
    xi = xi.reshape(b, l, nh, s.head_dim)
    xi = shard(xi, "batch", None, "heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = cache["h"].astype(x.dtype) if cache is not None else None
    if l == 1 and cache is not None:
        # recurrent decode: h = exp(dt A) h + dt B (x) x ; y = C h + D x
        a = jnp.exp(dt[:, 0] * A)  # (b, nh)
        bx = jnp.einsum("bn,bhp->bhnp", Bm[:, 0], xi[:, 0] * dt[:, 0, :, None].astype(x.dtype))
        hn = h0 * a[..., None, None].astype(x.dtype) + bx.astype(x.dtype)
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], hn)[:, None]
        y = y.reshape(b, 1, nh, s.head_dim)
        h_final = hn
    else:
        pad = (-l) % s.chunk
        if pad:
            xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, h_final = ssd_chunked(xi, dt, A, Bm, Cm, s.chunk, h0)
        y = y[:, :l]
        xi = xi[:, :l]
    y = y + xi * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, l, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)  # gated
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_final.astype(cache["h"].dtype), "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache


def mamba_cache_desc(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s: SSMCfg = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, s.state, s.head_dim), dtype),
        "conv": jax.ShapeDtypeStruct((batch, s.conv - 1, conv_dim), dtype),
    }
