"""Model zoo: declarative param trees + pure-jnp apply functions."""

from .config import ModelConfig, reduced_for_smoke
from .model import build_model
