"""Transformer building blocks: GQA attention, MLA, MLPs, MoE.

Every block exposes `desc_*` (P-descriptor tree) and `apply_*` (pure jnp).
Decode caches are plain dicts of arrays; `*_cache_desc` gives their
ShapeDtypeStructs for the dry-run.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import nn
from .config import MLACfg, ModelConfig
from .nn import P, attention, dense, rms_norm, rope, shard


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def desc_attn(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    out = {
        "norm": P((d,), ("norm",), "ones"),
        "wq": P((d, h * dh), ("embed", "heads")),
        "wk": P((d, hkv * dh), ("embed", "heads")),
        "wv": P((d, hkv * dh), ("embed", "heads")),
        "wo": P((h * dh, d), ("heads", "embed")),
    }
    return out


def apply_attn(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int | None = None,
    cache: dict | None = None,
    memory: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Self- or cross-attention with optional decode cache.

    cache: {'k': (B, M, Hkv, Dh), 'v': ..., 'len': ()} — updated in place
    (functionally) at position `len`; attention masked to len+L.
    memory: encoder output for cross-attention (keys/values from memory).

    Paged cache (serving tier, DESIGN.md §9): {'k': (P, T, Hkv, Dh) page
    arena, 'v': ..., 'len': (B,) per-slot clocks, 'ptab': (B, max_pages)
    arena page ids}. Decode-only (L == 1): the new token scatters into
    page ``ptab[b, len[b] // T]`` row ``len[b] % T`` and attention reads
    the slot's whole context gathered through its page table — per-slot
    clocks, so requests at different depths decode in one batch.
    """
    b, l, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = dense(xn, p["wq"]).reshape(b, l, h, dh)
    src = memory if memory is not None else xn  # encoder memory is pre-normed
    k = dense(src, p["wk"]).reshape(b, src.shape[1], hkv, dh)
    v = dense(src, p["wv"]).reshape(b, src.shape[1], hkv, dh)
    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if cache is None else positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    new_cache = None
    if cache is not None and memory is None and "ptab" in cache:
        # --- paged KV pool (serving tier, DESIGN.md §9) ---
        if l != 1:
            raise ValueError(
                "paged KV cache is decode-only (L == 1); prefill runs "
                "against a contiguous sub-cache and is spliced into the "
                "arena by the batcher (runtime/batcher.py)"
            )
        lens = cache["len"]          # (B,) per-slot clocks
        ptab = cache["ptab"]         # (B, max_pages) arena page ids
        pt = cache["k"].shape[1]
        pid = jnp.take_along_axis(ptab, (lens // pt)[:, None], axis=1)[:, 0]
        off = jnp.mod(lens, pt)
        ck = cache["k"].at[pid, off].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[pid, off].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        k_all = ck[ptab].reshape(b, -1, hkv, dh).astype(q.dtype)
        v_all = cv[ptab].reshape(b, -1, hkv, dh).astype(q.dtype)
        out = attention(
            q, k_all, v_all,
            causal=causal, q_offset=lens, window=window, kv_len=lens + l,
        )
    elif cache is not None and memory is None:
        pos = cache["len"]
        m_cap = cache["k"].shape[1]
        upd = jnp.mod(pos, m_cap)  # ring buffer: windowed long-context decode
        if "k_scale" in cache:
            # int8 KV cache: per-(token, head) linear quantization — the
            # paper's Stage-II vector quantization applied to KV residency
            ks = jnp.max(jnp.abs(k), axis=-1).astype(jnp.float32) / 127.0 + 1e-12
            vs = jnp.max(jnp.abs(v), axis=-1).astype(jnp.float32) / 127.0 + 1e-12
            kq = jnp.round(k.astype(jnp.float32) / ks[..., None]).astype(jnp.int8)
            vq = jnp.round(v.astype(jnp.float32) / vs[..., None]).astype(jnp.int8)
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, upd, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, upd, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, upd, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, upd, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs, "len": pos + l}
            k_all = (ck.astype(q.dtype) * cks[..., None].astype(q.dtype))
            v_all = (cv.astype(q.dtype) * cvs[..., None].astype(q.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, upd, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, upd, 0, 0))
            new_cache = {"k": ck, "v": cv, "len": pos + l}
            k_all, v_all = ck.astype(q.dtype), cv.astype(q.dtype)
        out = attention(
            q, k_all, v_all,
            causal=causal, q_offset=jnp.minimum(pos, m_cap - l),
            window=window, kv_len=jnp.minimum(pos + l, m_cap),
        )
    else:
        out = attention(q, k, v, causal=causal and memory is None, window=window)
    out = out.reshape(b, l, h * dh)
    return dense(out, p["wo"]), new_cache


def attn_cache_desc(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    hkv, dh = cfg.n_kv_heads, cfg.dh
    if cfg.kv_quant:
        return {
            "k": jax.ShapeDtypeStruct((batch, max_len, hkv, dh), jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, max_len, hkv, dh), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, max_len, hkv), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((batch, max_len, hkv), jnp.float32),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, hkv, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, hkv, dh), dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def paged_attn_cache_desc(
    cfg: ModelConfig, pages: int, page_tokens: int, dtype=jnp.bfloat16
) -> dict:
    """Per-layer page-arena descriptors (serving tier, DESIGN.md §9):
    `pages` usable pages of `page_tokens` tokens, plus the reserved
    scratch page 0 that dead slots write into (the allocator hands out
    ids 1..pages). The per-slot clock/table state lives at the cache's
    top level (`model.paged_cache_desc`), not per layer."""
    if cfg.kv_quant:
        raise NotImplementedError(
            "paged KV pool does not support the int8 quantized cache yet"
        )
    hkv, dh = cfg.n_kv_heads, cfg.dh
    return {
        "k": jax.ShapeDtypeStruct((pages + 1, page_tokens, hkv, dh), dtype),
        "v": jax.ShapeDtypeStruct((pages + 1, page_tokens, hkv, dh), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def desc_mla(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    m: MLACfg = cfg.mla
    return {
        "norm": P((d,), ("norm",), "ones"),
        "wq_a": P((d, m.q_lora), ("embed", None)),
        "q_norm": P((m.q_lora,), ("norm",), "ones"),
        "wq_b": P((m.q_lora, h * (m.qk_nope + m.qk_rope)), (None, "heads")),
        "wkv_a": P((d, m.kv_lora + m.qk_rope), ("embed", None)),
        "kv_norm": P((m.kv_lora,), ("norm",), "ones"),
        "wkv_b": P((m.kv_lora, h * (m.qk_nope + m.v_head)), (None, "heads")),
        "wo": P((h * m.v_head, d), ("heads", "embed")),
    }


def apply_mla(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """MLA attention. Cache stores only the compressed latent (c_kv, k_rope)."""
    b, l, d = x.shape
    h = cfg.n_heads
    m: MLACfg = cfg.mla
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = dense(rms_norm(dense(xn, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, l, h, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., : m.qk_nope], q[..., m.qk_nope :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv_a = dense(xn, p["wkv_a"])
    c_kv, k_rope = kv_a[..., : m.kv_lora], kv_a[..., m.kv_lora :]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,L,1,r)
    new_cache = None
    if cache is not None:
        # --- absorbed MLA decode (EXPERIMENTS.md §Perf, deepseek decode) ---
        # Never materialize K/V for the context: score and contract directly
        # in the kv_lora latent space by absorbing W_uk into q and deferring
        # W_uv to after the attention contraction. Same math (reassociation
        # of q^T (c W_uk^T) = (q W_uk) c^T); turns the per-step cost from
        # O(M * h * (nope+v) * kv_lora) re-expansion into O(M * kv_lora).
        pos = cache["len"]
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0)
        )
        cr = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope[:, :, 0, :].astype(cache["krope"].dtype), (0, pos, 0)
        )
        new_cache = {"ckv": cc, "krope": cr, "len": pos + l}
        c_all = rms_norm(cc.astype(x.dtype), p["kv_norm"], cfg.norm_eps)  # (b,M,r)
        kr_all = cr.astype(x.dtype)  # (b, M, rope)
        kv_len = pos + l
        wkv = p["wkv_b"].reshape(m.kv_lora, h, m.qk_nope + m.v_head).astype(x.dtype)
        w_uk, w_uv = wkv[..., : m.qk_nope], wkv[..., m.qk_nope :]
        q_lat = jnp.einsum("blhn,rhn->blhr", q_nope, w_uk)  # absorb W_uk
        q_lat = shard(q_lat, "batch", None, "heads", None)
        scale = 1.0 / math.sqrt(m.qk_nope + m.qk_rope)
        logits = (
            jnp.einsum("blhr,bmr->bhlm", q_lat, c_all)
            + jnp.einsum("blhr,bmr->bhlm", q_rope, kr_all)
        ).astype(jnp.float32) * scale
        mcap = cc.shape[1]
        qpos = jnp.arange(l)[:, None] + pos
        kpos = jnp.arange(mcap)[None, :]
        mask = (kpos <= qpos) & (kpos < kv_len)
        logits = jnp.where(mask[None, None], logits, -1e30)
        wts = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhlm,bmr->blhr", wts, c_all)
        out = jnp.einsum("blhr,rhv->blhv", ctx, w_uv)  # deferred W_uv
        return dense(out.reshape(b, l, h * m.v_head), p["wo"]), new_cache
    # --- parallel path (train / no cache): materialized K/V ---
    kv = dense(rms_norm(c_kv, p["kv_norm"], cfg.norm_eps), p["wkv_b"])
    kv = kv.reshape(b, l, h, m.qk_nope + m.v_head)
    k_nope, v = kv[..., : m.qk_nope], kv[..., m.qk_nope :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope,))], -1
    )
    qq = jnp.concatenate([q_nope, q_rope], -1)
    qq = shard(qq, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    out = attention(qq, k, v, causal=True)
    return dense(out.reshape(b, l, h * m.v_head), p["wo"]), new_cache


def mla_cache_desc(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    m: MLACfg = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora), dtype),
        "krope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope), dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------


def desc_mlp(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out = {"norm": P((cfg.d_model,), ("norm",), "ones")}
    if cfg.mlp_type == "swiglu":
        out |= {
            "w_gate": P((d, f), ("embed", "mlp")),
            "w_up": P((d, f), ("embed", "mlp")),
            "w_down": P((f, d), ("mlp", "embed")),
        }
    else:
        out |= {
            "w_up": P((d, f), ("embed", "mlp")),
            "w_down": P((f, d), ("mlp", "embed")),
        }
    return out


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if cfg.mlp_type == "swiglu":
        return nn.swiglu(xn, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.mlp_type == "relu2":
        return nn.relu2_mlp(xn, p["w_up"], p["w_down"])
    return nn.gelu_mlp(xn, p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, sort-based capacity dispatch)
# ---------------------------------------------------------------------------


def desc_moe(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    mo = cfg.moe
    e, f = mo.n_experts, mo.d_ff_expert
    out = {
        "norm": P((d,), ("norm",), "ones"),
        "router": P((d, e), ("embed", None), scale=0.02),
        "w_gate": P((e, d, f), ("experts", "embed", "mlp")),
        "w_up": P((e, d, f), ("experts", "embed", "mlp")),
        "w_down": P((e, f, d), ("experts", "mlp", "embed")),
    }
    if mo.n_shared:
        fs = mo.d_ff_shared or mo.d_ff_expert * mo.n_shared
        out["shared"] = {
            "w_gate": P((d, fs), ("embed", "mlp")),
            "w_up": P((d, fs), ("embed", "mlp")),
            "w_down": P((fs, d), ("mlp", "embed")),
        }
    return out


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k token-choice routing with capacity; sort-based dispatch.

    Buffers are logically (experts, capacity, d): experts shard over 'model'
    (EP) and capacity over 'batch'-bearing axes so dispatch stays shard-local
    per data shard (DESIGN.md §6).
    """
    b, l, d = x.shape
    mo = cfg.moe
    e, k = mo.n_experts, mo.top_k
    n = b * l
    g_ = mo.dispatch_groups if n % max(mo.dispatch_groups, 1) == 0 else 1
    ng = n // g_  # tokens per dispatch group (group dim aligns with DP shards)
    xn = rms_norm(x, p["norm"], cfg.norm_eps).reshape(g_, ng, d)
    xn = shard(xn, "batch", None, None)
    logits = dense(xn, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, k)  # (g, ng, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    cap = max(int(mo.capacity_factor * ng * k / e), 8)
    cap = min(cap, ng)
    flat_e = sel.reshape(g_, ng * k)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(ng), k)[None], (g_, ng * k))
    flat_w = w.reshape(g_, ng * k).astype(x.dtype)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # per-group: stays local
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    rank = jnp.arange(ng * k)[None] - jnp.take_along_axis(starts, se, axis=-1)
    keep = rank < cap
    rankc = jnp.clip(rank, 0, cap - 1)
    gi = jnp.arange(g_)[:, None]
    buf = jnp.zeros((g_, e, cap, d), x.dtype)
    buf = buf.at[gi, se, rankc].add(
        xn[gi, st] * keep[..., None].astype(x.dtype)
    )
    buf = shard(buf, "batch", "experts", None, None)
    # expert FFN (batched over groups x experts)
    g = jnp.einsum("xecd,edf->xecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("xecd,edf->xecf", buf, p["w_up"].astype(x.dtype))
    hmid = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    hmid = shard(hmid, "batch", "experts", None, "mlp")
    eout = jnp.einsum("xecf,efd->xecd", hmid, p["w_down"].astype(x.dtype))
    eout = shard(eout, "batch", "experts", None, None)
    # combine
    y = jnp.zeros((g_, ng, d), x.dtype)
    y = y.at[gi, st].add(eout[gi, se, rankc] * (sw * keep.astype(x.dtype))[..., None])
    y = shard(y, "batch", None, None)
    y = y.reshape(b, l, d)
    if mo.n_shared:
        y = y + nn.swiglu(xn.reshape(b, l, d), p["shared"]["w_gate"], p["shared"]["w_up"], p["shared"]["w_down"])
    return y
