"""Minimal declarative NN substrate (no flax): param descriptors + layers.

Parameters are declared as trees of `P` descriptors (shape + logical axes +
init). The same declaration drives:
  * real initialization (smoke tests / training),
  * abstract initialization via eval_shape (multi-pod dry-run — no
    allocation),
  * PartitionSpec derivation through the logical-axis rules in
    repro.runtime.sharding.

Apply functions are plain jnp code over param dicts, annotated with
`shard(x, logical_axes)` activation constraints.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter descriptor: shape, logical axes (len == ndim), init."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def init_param(p: P, key: jax.Array) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    scale = p.scale
    if scale is None:
        scale = 0.02 if p.init == "embed" else 1.0 / math.sqrt(max(_fan_in(p.shape), 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(p.dtype)


def is_desc(x) -> bool:
    return isinstance(x, P)


def init_tree(tree: Any, key: jax.Array) -> Any:
    """Materialize a descriptor tree into real parameters."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_desc)
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(p, k) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_tree(tree: Any) -> Any:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree, is_leaf=is_desc
    )


def axes_tree(tree: Any) -> Any:
    """Logical-axis tree matching the params (for sharding rules)."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_desc)


def stack_layers(descs: list[Any]) -> Any:
    """Stack homogeneous per-layer descriptor trees along a leading 'layers'
    axis (scan-over-layers layout)."""
    first = descs[0]
    n = len(descs)

    def _stack(p: P) -> P:
        return P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale, p.dtype)

    return jax.tree_util.tree_map(_stack, first, is_leaf=is_desc)


# ---------------------------------------------------------------------------
# sharding annotation hook (bound by repro.runtime.sharding at trace time)
# ---------------------------------------------------------------------------

_SHARD_FN = None


def set_shard_fn(fn) -> None:
    global _SHARD_FN
    _SHARD_FN = fn


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation `x` to logical axes (no-op outside a mesh)."""
    if _SHARD_FN is None:
        return x
    return _SHARD_FN(x, axes)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., d_in) @ w: (d_in, d_out) in the compute dtype of x."""
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: (B, L, H, Dh) with even Dh; positions: (B, L)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, L, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


#: query-chunk size for the memory-bounded attention path
ATTN_Q_CHUNK = 1024


def _attn_direct(qr, k, v, causal, q_offset, window, kv_len, dh):
    b, lq = qr.shape[:2]
    lk = k.shape[1]
    logits = jnp.einsum("blhrd,bmhd->bhrlm", qr, k).astype(jnp.float32)
    logits = logits / math.sqrt(dh)
    per_slot = jnp.ndim(q_offset) > 0 or (kv_len is not None and jnp.ndim(kv_len) > 0)
    if per_slot:
        # per-slot clocks (paged serving, DESIGN.md §9): q_offset / kv_len
        # are (B,) vectors, so the mask gains a batch axis
        qpos = jnp.arange(lq)[None, :, None] + jnp.reshape(q_offset, (-1, 1, 1))
        kpos = jnp.arange(lk)[None, None, :]
        mask = jnp.ones((1, lq, lk), dtype=bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        if kv_len is not None:
            mask = mask & (kpos < jnp.reshape(kv_len, (-1, 1, 1)))
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    else:
        qpos = jnp.arange(lq)[:, None] + q_offset
        kpos = jnp.arange(lk)[None, :]
        mask = jnp.ones((lq, lk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_len is not None:
            mask &= kpos < kv_len
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(qr.dtype)
    return jnp.einsum("bhrlm,bmhd->blhrd", w, v)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    window: int | None = None,
    kv_len: jax.Array | None = None,
    q_chunk: int | None = None,
) -> jax.Array:
    """GQA attention. q: (B, Lq, Hq, Dh); k/v: (B, Lk, Hkv, Dh|Dv).

    `q_offset`: absolute position of q[0] (decode). `window`: sliding-window
    size. `kv_len`: valid KV prefix length (decode with preallocated cache).
    `q_offset` and `kv_len` may also be per-slot (B,) vectors — the paged
    serving tier's per-slot clocks (DESIGN.md §9) — which batches the mask.

    Long queries run the memory-bounded path: an UNROLLED loop over query
    chunks (buffers are reused across chunks by XLA liveness; unrolled so
    cost_analysis counts every chunk — a lax.scan body is costed only once).
    With a static q_offset the causal structure also statically truncates
    each chunk's KV prefix (the flash-attention triangle saving).
    """
    b, lq, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    qr = q.reshape(b, lq, hkv, rep, dh)
    qc = q_chunk or ATTN_Q_CHUNK
    if lq <= qc:
        out = _attn_direct(qr, k, v, causal, q_offset, window, kv_len, dh)
        return out.reshape(b, lq, hq, v.shape[-1])
    static_off = isinstance(q_offset, int)
    nq = -(-lq // qc)
    outs = []
    for ci in range(nq):
        s = ci * qc
        e = min(lq, s + qc)
        qs = qr[:, s:e]
        if static_off and causal and kv_len is None:
            # static causal truncation of the KV prefix (triangle saving)
            hi = min(k.shape[1], q_offset + e)
            lo = max(0, q_offset + s - window + 1) if window is not None else 0
            lo = (lo // 128) * 128  # keep slices lane-aligned
            ks, vs = k[:, lo:hi], v[:, lo:hi]
            out = _attn_direct(
                qs, ks, vs, causal, q_offset + s - lo, window, None, dh
            )
        else:
            out = _attn_direct(qs, k, v, causal, q_offset + s, window, kv_len, dh)
        outs.append(out)
    return jnp.concatenate(outs, axis=1).reshape(b, lq, hq, v.shape[-1])


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", None, "mlp")
    return dense(h, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(dense(x, w_up).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "mlp")
    return dense(h, w_down)


def relu2_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jnp.square(jax.nn.relu(dense(x, w_up).astype(jnp.float32))).astype(x.dtype)
    h = shard(h, "batch", None, "mlp")
    return dense(h, w_down)
