"""Model configuration dataclasses for the architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    n_dense_layers: int = 0  # leading dense layers (deepseek-v2 style)
    # dispatch groups: >1 sorts/ranks tokens within per-group chunks that
    # align with the DP sharding, keeping the MoE dispatch shard-local
    # (GSPMD replicates a global argsort) — §Perf knob
    dispatch_groups: int = 1


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    m_per_group: int = 7   # mLSTM layers per group
    s_per_group: int = 1   # sLSTM layers per group
    proj_factor: float = 2.0
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """zamba2-style: shared attention block applied every `every` SSM layers."""

    every: int = 6
    concat_embed: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    mlp_type: str = "swiglu"  # swiglu | gelu | relu2
    head_dim: Optional[int] = None
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    hybrid: Optional[HybridCfg] = None
    encdec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None  # vision | audio (stub embeddings)
    frontend_len: int = 256
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # supports long_500k decode
    attn_window: Optional[int] = None  # sliding window (hybrid long mode)
    remat: bool = True  # activation-checkpoint each scanned layer (train)
    unroll_layers: bool = False  # python-loop layers (dry-run cost probes)
    kv_quant: bool = False  # int8 KV cache (paper Stage-II quantization)

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink any config to CPU-smoke size, preserving the family topology."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.n_shared else 0,
        )
    if cfg.mla:
        kw["mla"] = MLACfg(q_lora=64, kv_lora=32, qk_nope=16, qk_rope=16, v_head=32)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state=16, head_dim=16, chunk=16)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, m_per_group=1, s_per_group=1, chunk=16)
        kw["n_layers"] = 4  # 2 groups x (1 mLSTM + 1 sLSTM)
    if cfg.hybrid:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, every=2)
        kw["n_layers"] = 5
    if cfg.encdec:
        kw["n_enc_layers"] = 2
    if cfg.frontend:
        kw["frontend_len"] = 16
    return cfg.scaled(**kw)
