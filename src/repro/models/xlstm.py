"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, recurrent scan). Follows the xLSTM paper's stabilized exponential
gating; mLSTM uses a chunkwise form (like SSD) so prefill is parallel and
decode/long-context is O(1)-state recurrent.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig, XLSTMCfg
from .nn import P, dense, rms_norm, shard


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def desc_mlstm(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    xc: XLSTMCfg = cfg.xlstm
    d_in = int(xc.proj_factor * d)
    nh = cfg.n_heads
    return {
        "norm": P((d,), ("norm",), "ones"),
        "w_up": P((d, d_in), ("embed", "mlp")),
        "w_gate": P((d, d_in), ("embed", "mlp")),
        "conv_w": P((4, d_in), (None, "mlp")),
        "conv_b": P((d_in,), ("mlp",), "zeros"),
        # NOTE §Perf iteration 2 (refuted): a Megatron col-parallel layout
        # ((None, "heads") + replicated conv output) was tried and measured
        # WORSE (t_collective 10.8 -> 18.1 s): the all-gather of the 2x-wide
        # conv activations costs more than the partial-sum all-reduces it
        # removes. Kept sharded-contraction layout. See EXPERIMENTS.md §Perf.
        "wq": P((d_in, d_in), ("mlp", "heads")),
        "wk": P((d_in, d_in), ("mlp", "heads")),
        "wv": P((d_in, d_in), ("mlp", "heads")),
        "w_if": P((d_in, 2 * nh), ("mlp", None), scale=0.01),
        "if_bias": P((2 * nh,), (None,), "zeros"),
        "out_norm": P((d_in,), ("norm",), "ones"),
        "w_down": P((d_in, d), ("mlp", "embed")),
    }


def _mlstm_chunked(q, k, v, ig, lf, chunk, state=None):
    """Stabilized chunkwise mLSTM.

    q/k/v: (B, L, H, D); ig (input gate logit), lf (log forget gate): (B, L, H).
    state: (C (B,H,D,D), n (B,H,D), m (B,H)) or None.
    Returns y (B,L,H,D), new state.
    """
    b, l, h, dk = q.shape
    nc = l // chunk
    qc = q.reshape(b, nc, chunk, h, dk)
    kc = k.reshape(b, nc, chunk, h, dk)
    vc = v.reshape(b, nc, chunk, h, dk)
    igc = jnp.moveaxis(ig.reshape(b, nc, chunk, h), -1, 2)  # (b,nc,h,q)
    lfc = jnp.moveaxis(lf.reshape(b, nc, chunk, h), -1, 2)
    cum = jnp.cumsum(lfc, axis=-1)  # (b,nc,h,q)
    if state is None:
        C0 = jnp.zeros((b, h, dk, dk), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    # intra-chunk log weights D[t,s] = cum_t - cum_s + ig_s  (s <= t)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dlog = cum[..., :, None] - cum[..., None, :] + igc[..., None, :]
    Dlog = jnp.where(tri, Dlog, -jnp.inf)  # (b,nc,h,q,q)
    m_intra = jnp.max(Dlog, axis=-1)  # (b,nc,h,q)

    # chunk-local state contributions (vectorized over chunks — OUTSIDE the
    # scan, so FLOPs are costed correctly and the scan body is tiny)
    cum_end = cum[..., -1]  # (b,nc,h)
    w_end = cum_end[..., None] - cum + igc  # (b,nc,h,q)
    m_loc = jnp.max(w_end, axis=-1)  # (b,nc,h)
    wgt = jnp.exp(w_end - m_loc[..., None]).astype(jnp.float32)
    KV_loc = jnp.einsum("bchs,bcshd,bcshe->bchde", wgt, kc.astype(jnp.float32), vc.astype(jnp.float32))
    n_loc = jnp.einsum("bchs,bcshd->bchd", wgt, kc.astype(jnp.float32))

    def chunk_step(carry, inp):
        C, n, m = carry
        KVc, nc_, mloc, dec = inp  # (b,h,dk,dv), (b,h,dk), (b,h), (b,h)
        m_new = jnp.maximum(m + dec, mloc)
        sc_old = jnp.exp(m + dec - m_new)
        sc_loc = jnp.exp(mloc - m_new)
        Cn = C * sc_old[..., None, None] + KVc * sc_loc[..., None, None]
        nn_ = n * sc_old[..., None] + nc_ * sc_loc[..., None]
        return (Cn, nn_, m_new), (C, n, m)

    xs = (
        jnp.moveaxis(KV_loc, 1, 0),
        jnp.moveaxis(n_loc, 1, 0),
        jnp.moveaxis(m_loc, 1, 0),
        jnp.moveaxis(cum_end, 1, 0),
    )
    (Cf, nf, mf), (C_prev, n_prev, m_prev) = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    C_prev = jnp.moveaxis(C_prev, 0, 1)  # (b,nc,h,dk,dv)
    n_prev = jnp.moveaxis(n_prev, 0, 1)  # (b,nc,h,dk)
    m_prev = jnp.moveaxis(m_prev, 0, 1)  # (b,nc,h)

    # per-step stabilizer and outputs (vectorized over chunks)
    m_t = jnp.maximum(m_prev[..., None] + cum, m_intra)  # (b,nc,h,q)
    inter_w = jnp.exp(cum + m_prev[..., None] - m_t)  # (b,nc,h,q)
    intra_w = jnp.exp(Dlog - m_t[..., None])  # (b,nc,h,q,q)
    qk = jnp.einsum("bcthd,bcshd->bchts", qc, kc) / math.sqrt(dk)
    Wts = (intra_w * qk.astype(jnp.float32)).astype(jnp.float32)
    num = jnp.einsum("bchts,bcshd->bcthd", Wts, vc.astype(jnp.float32))
    num = num + jnp.einsum(
        "bcthd,bchde,bcht->bcthe", qc.astype(jnp.float32), C_prev, inter_w
    ) / math.sqrt(dk)
    qn = jnp.einsum("bcthd,bchd->bcht", qc.astype(jnp.float32), n_prev) / math.sqrt(dk)
    den = jnp.sum(Wts, axis=-1) + qn * inter_w  # (b,nc,h,q)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    # num: (b,nc,t,h,d); den: (b,nc,h,t) -> (b,nc,t,h)
    y = num / den.transpose(0, 1, 3, 2)[..., None]
    y = y.astype(q.dtype).reshape(b, l, h, dk)
    return y, (Cf, nf, mf)


def mlstm_decode_step(q, k, v, ig, lf, state):
    """One-token recurrent mLSTM update. q/k/v: (B,H,D); ig/lf: (B,H)."""
    C, n, m = state
    dk = q.shape[-1]
    m_new = jnp.maximum(lf + m, ig)
    fw = jnp.exp(lf + m - m_new)[..., None]
    iw = jnp.exp(ig - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    Cn = C * fw[..., None] + iw[..., None] * kf[..., :, None] * vf[..., None, :]
    nn_ = n * fw + iw * kf
    qf = q.astype(jnp.float32) / math.sqrt(dk)
    num = jnp.einsum("bhd,bhde->bhe", qf, Cn)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nn_)), jnp.exp(-m_new))
    y = (num / den[..., None]).astype(q.dtype)
    return y, (Cn, nn_, m_new)


def apply_mlstm(p, x, cfg: ModelConfig, *, cache=None):
    xc: XLSTMCfg = cfg.xlstm
    b, l, d = x.shape
    d_in = int(xc.proj_factor * d)
    nh = cfg.n_heads
    dk = d_in // nh
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    u = dense(xn, p["w_up"])
    gate = dense(xn, p["w_gate"])
    # causal depthwise conv on u
    K = 4
    if cache is not None:
        ext = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        new_conv = ext[:, -(K - 1) :, :]
    else:
        ext = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv = ext[:, -(K - 1) :, :]
    wins = jnp.stack([ext[:, i : i + l, :] for i in range(K)], axis=2)
    cu = jnp.einsum("blkc,kc->blc", wins, p["conv_w"].astype(u.dtype)) + p["conv_b"].astype(u.dtype)
    cu = jax.nn.silu(cu.astype(jnp.float32)).astype(u.dtype)
    q = dense(cu, p["wq"]).reshape(b, l, nh, dk)
    k = dense(cu, p["wk"]).reshape(b, l, nh, dk)
    v = dense(u, p["wv"]).reshape(b, l, nh, dk)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    gates = dense(cu, p["w_if"]).astype(jnp.float32) + p["if_bias"].astype(jnp.float32)
    ig, fg = gates[..., :nh], gates[..., nh:]
    lf = jax.nn.log_sigmoid(fg)
    state = None
    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    if l == 1 and cache is not None:
        y, new_state = mlstm_decode_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], lf[:, 0], state)
        y = y[:, None]
    else:
        pad = (-l) % xc.chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
            lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        y, new_state = _mlstm_chunked(q, k, v, ig, lf, xc.chunk, state)
        y = y[:, :l]
    y = y.reshape(b, l, d_in)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(y.dtype)
    out = dense(y, p["w_down"])
    new_cache = None
    if cache is not None:
        C, n, m = new_state
        new_cache = {"C": C, "n": n, "m": m, "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache


def mlstm_cache_desc(cfg: ModelConfig, batch: int) -> dict:
    xc: XLSTMCfg = cfg.xlstm
    d_in = int(xc.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    dk = d_in // nh
    return {
        "C": jax.ShapeDtypeStruct((batch, nh, dk, dk), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dk), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, d_in), jnp.bfloat16),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def desc_slstm(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    return {
        "norm": P((d,), ("norm",), "ones"),
        "w_in": P((d, 4 * d), ("embed", "mlp")),
        "r": P((nh, hd, 4 * hd), (None, None, None), scale=1.0 / math.sqrt(hd)),
        "bias": P((4 * d,), (None,), "zeros"),
        "out_norm": P((d,), ("norm",), "ones"),
        "w_out": P((d, d), ("mlp", "embed")),
    }


def apply_slstm(p, x, cfg: ModelConfig, *, cache=None):
    """sLSTM with exponential gating and per-head recurrent mixing.

    cache = {'c','n','m','h': (B, NH, HD)}; scan over time for l > 1.
    """
    b, l, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = (dense(xn, p["w_in"]) + p["bias"].astype(x.dtype)).reshape(b, l, nh, 4 * hd)

    if cache is not None:
        c0, n0, m0, h0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "m", "h"))
    else:
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh, hd), jnp.float32)  # matches the zeros cache init
        h0 = jnp.zeros((b, nh, hd), jnp.float32)

    rmat = p["r"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, m, h = carry
        z = wx_t.astype(jnp.float32) + jnp.einsum("bhd,hdf->bhf", h, rmat)
        zi, ii, ff, oo = jnp.split(z, 4, axis=-1)
        m_new = jnp.maximum(ff + m, ii)
        i_p = jnp.exp(ii - m_new)
        f_p = jnp.exp(ff + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(zi)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(oo) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (cf, nf, mf, hf), hs = jax.lax.scan(step, (c0, n0, m0, h0), jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, l, d).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = dense(y, p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"c": cf, "n": nf, "m": mf, "h": hf}
    return out, new_cache


def slstm_cache_desc(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    sd = jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32)
    return {"c": sd, "n": sd, "m": sd, "h": sd}
