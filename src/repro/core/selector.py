"""Algorithm 1 — automatic online selection between SZ and ZFP (paper §5.3).

Per field:
  1. sample blocks (rate r_sp);
  2. estimate ZFP's (BR, PSNR) at the user's error bound;
  3. invert Eq. (10) to get the SZ bin size delta matching ZFP's PSNR
     (iso-PSNR comparison -> rate-distortion-optimal choice);
  4. estimate SZ's BR at that delta;
  5. pick the compressor with the smaller estimated bit-rate.

Note (DESIGN.md §1): Algorithm 1 line 11 prints "error bound 2*delta"; the
derivation requires eb_sz = delta/2 (clamped to eb_abs so the user's bound
always holds). We implement the consistent reading.

The quality-target modes (fixed_psnr and the §7.4 metric targets) reuse
the same min-rate rule but anchor it at the caller's contract instead of
at matched eb: the controller solves each codec's bound onto the target
first, then the cheapest candidate *inside the target's tolerance band*
wins (`core/controller.py`). `select_many` therefore only accepts
fixed_accuracy policies and points target modes at `solve_many`.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache as _lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import codecs as _codecs
from . import estimator as est
from .policy import Policy

#: a codec *name*; byte encode/decode dispatches through the registry
#: (`core/codecs.py`, DESIGN.md §2.1), so the set is open, not a Literal
Codec = str


def _pick_codec(br_sz: float, br_zfp: float, allowed: tuple[str, ...]) -> Codec:
    """Step 5 of Fig. 2 under a codec allowlist: min estimated rate among
    the allowed lossy candidates, `raw` when the best still exceeds 32
    bits/value (or nothing lossy is allowed). With the full allowlist this
    is exactly the historical `"sz" if br_sz < br_zfp else "zfp"` rule —
    ties keep going to ZFP — so default-policy decisions are unchanged."""
    sz_ok, zfp_ok = "sz" in allowed, "zfp" in allowed
    if sz_ok and zfp_ok:
        codec, best = ("sz", br_sz) if br_sz < br_zfp else ("zfp", br_zfp)
    elif sz_ok:
        codec, best = "sz", br_sz
    elif zfp_ok:
        codec, best = "zfp", br_zfp
    else:
        return "raw"
    return "raw" if best >= 32.0 else codec


@dataclass
class Selection:
    codec: Codec
    eb_abs: float            # user bound (guaranteed pointwise)
    eb_sz: float             # SZ bound after the iso-PSNR match
    br_sz: float
    br_zfp: float
    psnr_target: float       # ZFP's estimated PSNR (the match point)
    vr: float
    r_sp: float


def select(
    x: jax.Array | np.ndarray,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float = est.DEFAULT_SAMPLING_RATE,
    transform: str = "zfp",
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> Selection:
    """Run Steps 1-3 of Fig. 2 and return the decision + estimates."""
    x = _fold_ndim(jnp.asarray(x))
    vr = float(jnp.max(x) - jnp.min(x)) if x.size else 0.0
    sel0 = _degenerate_selection(x, vr, eb_abs, eb_rel, r_sp)
    if sel0 is not None:
        return sel0
    if eb_abs is None:
        assert eb_rel is not None, "need eb_abs or eb_rel"
        eb_abs = eb_rel * vr
    starts = est.block_starts(x.shape, r_sp)
    br_sz, br_zfp, psnr_zfp, eb_sz = _estimates_jitted(
        x.shape, starts.shape, transform
    )(x, jnp.asarray(starts), jnp.float32(eb_abs), jnp.float32(vr))
    br_sz, br_zfp = float(br_sz), float(br_zfp)
    eb_sz = float(eb_sz)
    codec = _pick_codec(br_sz, br_zfp, codecs)
    return Selection(codec, float(eb_abs), eb_sz, br_sz, br_zfp, float(psnr_zfp), vr, r_sp)


# ---------------------------------------------------------------------------
# Batched multi-field selection (the engine behind compress_pytree and the
# checkpoint writer; DESIGN.md §1, §4–§5)
# ---------------------------------------------------------------------------


def _fold_ndim(x):
    """Fields are 1-3D; fold leading axes of higher-rank tensors, and merge
    leading axes shorter than the 4-wide block (e.g. a (2, 128, 128)
    stacked-layer tensor becomes (256, 128) instead of falling back to raw).
    Shared by `select`, `select_many`, and `encode_with_selection` so the
    decision and the encoded view always agree."""
    if x.ndim > 3:
        x = x.reshape((-1,) + x.shape[-2:])
    while x.ndim > 1 and x.shape[0] < 4 and x.size:
        x = x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
    return x


def _degenerate_selection(x, vr: float, eb_abs, eb_rel, r_sp: float) -> Selection | None:
    """The raw-fallback policy, shared by `select` and `select_many` so the
    two paths cannot drift: too-small fields, constant fields, and
    NaN/inf-poisoned fields (vr non-finite) all store verbatim. `vr` is
    computed by the caller (device-side for `select`, host-side for
    `select_many`); pass 0.0 for empty fields."""
    if x.ndim == 0 or (x.size and min(x.shape) < 4) or x.size < 64:
        eb = eb_abs if eb_abs is not None else (eb_rel or 1e-3) * max(vr, 1e-30)
        return Selection("raw", float(eb), float(eb), 32.0, 32.0, 0.0, vr, r_sp)
    if vr <= 0 or not np.isfinite(vr):
        eb = eb_abs if eb_abs is not None else 1e-30
        return Selection("raw", float(eb), float(eb), 32.0, 32.0, 0.0, vr, r_sp)
    return None


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


@_lru_cache(maxsize=64)
def _batched_estimates_jitted(nd: int, n_blocks: int, n_fields: int, transform: str):
    """Jitted Steps 1-3 of Fig. 2 over a packed multi-field block batch.

    Cached per (ndim, padded block count, padded field count) — both counts
    are padded to power-of-two buckets by `select_many`, so a checkpoint
    with hundreds of distinctly-shaped tensors compiles O(log) programs,
    not O(fields).
    """

    def f(halo, seg, bounds, eb_f, vr_f, size_f):
        # the no-halo blocks are the halo blocks minus the leading
        # original-neighbor row on each axis (the boundary mask only ever
        # zeroes those -1 offsets), so one gather serves both estimators
        nohalo = halo[(slice(None),) + (slice(1, None),) * nd]
        e_zfp = est.estimate_zfp_many(nohalo, seg, bounds, eb_f, vr_f, transform)
        delta = est.sz_delta_for_psnr(e_zfp.psnr, vr_f)
        eb_sz = jnp.clip(delta / 2.0, eb_f * 1e-6, eb_f)
        e_sz = est.estimate_sz_many(halo, seg, bounds, 2.0 * eb_sz, vr_f, size_f)
        return e_sz.bitrate, e_zfp.bitrate, e_zfp.psnr, eb_sz

    return jax.jit(f)


#: per-launch field cap. Two constraints, the second binding: (a) the
#: batched SZ estimator's int32 sort key seg * (n_pdf + 1) + bin must stay
#: below 2^31 after pow2 field padding (would allow ~32k); (b) the per-run
#: |p log2 p| entropy terms ride an f32 prefix sum whose running total
#: grows ~17 bits/field, so the cap keeps the late-field window error
#: around 1e-3 bits/value — far below any real decision margin (f64
#: accumulation is unavailable without jax x64 mode).
MAX_BATCH_FIELDS = 1024


def _max_batch_blocks(nd: int) -> int:
    """Per-launch block cap: bounds batch memory AND keeps the int32
    coder-bit prefix sums in `field_sums` exact — the coder's worst case
    is ~31 planes x (2 significance/refinement bits per coefficient + the
    k field) + header, < 4^nd * 128 bits per block, so
    cap * 4^nd * 128 < 2^31. Larger pytrees simply run a few launches; a
    single field bigger than the cap falls back to the per-field `select`
    path."""
    return min(1 << 20, (1 << 31) // (4**nd * 128))


def select_many(
    fields,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float | None = None,
    transform: str = "zfp",
    codecs: tuple[str, ...] | None = None,
    *,
    policy: Policy | None = None,
    cache=None,
    names=None,
) -> list[Selection]:
    """Algorithm 1 on MANY fields with one estimator launch (per ndim group).

    Sampled blocks of every field are gathered on host (r_sp of the bytes),
    packed into one padded (total_blocks, 4, ..) batch per dimensionality,
    and Steps 1-3 run as a single jitted call with per-field segment
    reductions — one compile + one device round-trip per pytree instead of
    one per leaf. Returns one `Selection` per input field, matching the
    per-field `select` decision.

    `policy` (a fixed_accuracy `Policy`) is the object form of the
    eb/r_sp/codecs arguments — the bound-centric quality contract of
    DESIGN.md §2 — and is what `compress_pytree` passes per policy group;
    the explicit kwargs remain the primitive, non-deprecated spelling for
    direct Algorithm-1 use. `codecs` restricts which registered codecs
    (DESIGN.md §2.1) may compete; the full default reproduces the paper's
    SZ-vs-ZFP rule exactly.

    Fields are evaluated in float32 (the codecs' working dtype); the f32
    view of each field is transient — only its sampled blocks are retained,
    so peak memory is one field plus ~r_sp of the pytree.

    `cache` (a `DecisionCache`, DESIGN.md §8) with `names` (one stable
    field path per field) enables the warm path: each batchable member's
    sampled blocks are fingerprinted (`core/predictor.py`), validated
    entries replay the previous save's `Selection` verbatim — bit-identical
    to what the cold path would recompute, since the fingerprint digests
    the decision's complete preimage — and only misses run the estimator
    launch. Degenerate fields (tiny/constant/NaN-poisoned) never consult
    the cache; their raw fallback is re-derived every call.
    """
    if policy is not None:
        if policy.mode != "fixed_accuracy":
            raise ValueError(
                f"select_many takes a fixed_accuracy policy, got {policy.mode!r} "
                "(use controller.solve_many for the target modes: fixed_psnr, "
                "fixed_ratio, fixed_ssim, fixed_correlation, fixed_ks)"
            )
        if any(v is not None for v in (eb_abs, eb_rel, r_sp, codecs)):
            raise ValueError(
                "pass either policy= or eb_abs/eb_rel/r_sp/codecs, not both"
            )
        eb_abs, eb_rel = policy.eb_abs, policy.eb_rel
        r_sp, codecs = policy.r_sp, policy.codecs
    r_sp = est.DEFAULT_SAMPLING_RATE if r_sp is None else r_sp
    codecs = _codecs.DEFAULT_CODECS if codecs is None else codecs
    fields = list(fields)
    results: list[Selection | None] = [None] * len(fields)
    groups = _build_select_members(
        fields, range(len(fields)), results, eb_abs, eb_rel, r_sp, transform,
        codecs,
    )
    if cache is None:
        _run_select_batches(groups, results, r_sp, transform, codecs)
        return results  # type: ignore[return-value]
    if policy is None:
        policy = Policy.fixed_accuracy(
            eb_rel=eb_rel, eb_abs=eb_abs, r_sp=r_sp, codecs=codecs
        )
    _select_many_cached(
        fields, names, results, groups, cache, policy, r_sp, transform, codecs
    )
    return results  # type: ignore[return-value]


def _select_many_cached(
    fields,
    names,
    results: list[Selection | None],
    groups,
    cache,
    policy: Policy,
    r_sp: float,
    transform: str,
    codecs: tuple[str, ...],
) -> None:
    """Warm half of `select_many` (DESIGN.md §8): fingerprint each
    batchable member, replay validated cache entries, batch only the
    misses through the ordinary estimator launch, store fresh decisions.

    Note the batch-composition caveat: a re-decided miss subset is batched
    with the OTHER misses of the same call, not with the hit fields — so a
    miss's decision is bit-identical to a cold `select_many` over the same
    miss subset (the f32 prefix-sum window differs at ulp level across
    batch compositions; see `estimator.field_sums`). Hits, by contrast,
    replay the stored decision exactly as originally batched."""
    from . import predictor as _pred

    if names is None:
        raise ValueError("select_many(cache=...) requires names=")
    names = list(names)
    if len(names) != len(fields):
        raise ValueError(
            f"names/fields length mismatch: {len(names)} vs {len(fields)}"
        )
    miss_groups: dict[int, list] = {}
    to_store: list[tuple[int, str, tuple, str, dict]] = []
    for nd, members in groups.items():
        stats = _pred.stats_for_members(nd, members, r_sp)
        for m, (_stats, fp) in zip(members, stats):
            i = m[0]
            x = fields[i]
            shape = tuple(np.shape(x))
            dtype = str(getattr(x, "dtype", np.asarray(x).dtype))
            entry = cache.lookup(names[i], shape, dtype, policy, transform, fp)
            if entry is not None:
                results[i] = entry.to_selection()
            else:
                miss_groups.setdefault(nd, []).append(m)
                to_store.append((i, names[i], shape, dtype, fp))
    if miss_groups:
        _run_select_batches(miss_groups, results, r_sp, transform, codecs)
    for i, name, shape, dtype, fp in to_store:
        cache.store(name, shape, dtype, policy, transform, fp, results[i])


def _build_select_members(
    fields,
    indices,
    results: list[Selection | None],
    eb_abs: float | None,
    eb_rel: float | None,
    r_sp: float,
    transform: str,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> dict[int, list[tuple[int, np.ndarray, float, float, int]]]:
    """Gather-side half of `select_many`: fold + value range + degenerate
    short-circuit + monster-field per-field fallback (written straight into
    `results` at the given indices), returning the batchable members as
    nd -> [(result index, halo blocks, eb, vr, size)] — the no-halo blocks
    are recovered in-graph by slicing off the leading halo row per axis.

    Split out so the shard-local engine (DESIGN.md §6) can merge its
    device-gathered members with host-gathered ones INTO THE SAME BATCHES:
    batch composition then matches the unsharded call exactly, which is
    what makes mixed eligible/fallback pytrees decide bit-identically."""
    groups: dict[int, list[tuple[int, np.ndarray, float, float, int]]] = {}
    for i, x in zip(indices, fields):
        arr = np.asarray(x, dtype=np.float32)
        view = _fold_ndim(arr)
        vr = float(np.max(view) - np.min(view)) if view.size else 0.0
        sel0 = _degenerate_selection(view, vr, eb_abs, eb_rel, r_sp)
        if sel0 is not None:
            results[i] = sel0
            continue
        if eb_abs is None:
            assert eb_rel is not None, "need eb_abs or eb_rel"
            eb = eb_rel * vr
        else:
            eb = eb_abs
        starts = est.block_starts(view.shape, r_sp)
        if len(starts) > _max_batch_blocks(view.ndim):
            # monster field: bigger alone than a whole batch — the
            # per-field path has no int32 accumulation to protect
            results[i] = select(
                view, eb_abs=float(eb), r_sp=r_sp, transform=transform,
                codecs=codecs,
            )
            continue
        groups.setdefault(view.ndim, []).append((
            i,
            est.gather_blocks_np(view, starts, halo=True),
            float(eb), vr, view.size,
        ))
    return groups


def _run_select_batches(
    groups: dict[int, list[tuple[int, np.ndarray, float, float, int]]],
    results: list[Selection | None],
    r_sp: float,
    transform: str,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> None:
    """Drive `_select_batch` over pre-gathered members, honoring the per-ndim
    block cap and field cap. Members are (input index, halo blocks, eb, vr,
    size) tuples; shared by `select_many` (host-gathered samples) and the
    shard-local engine (device-gathered samples, DESIGN.md §6) so the two
    paths run the identical decision program on identical inputs."""
    for nd, members in groups.items():
        cap = _max_batch_blocks(nd)
        lo = 0
        while lo < len(members):
            hi, blocks = lo, 0
            while hi < len(members) and (
                hi == lo
                or (blocks + len(members[hi][1]) <= cap and hi - lo < MAX_BATCH_FIELDS)
            ):
                blocks += len(members[hi][1])
                hi += 1
            _select_batch(nd, members[lo:hi], results, r_sp, transform, codecs)
            lo = hi


def _select_batch(
    nd: int,
    members: list[tuple[int, np.ndarray, float, float, int]],
    results: list[Selection | None],
    r_sp: float,
    transform: str,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> None:
    halo = np.concatenate([m[1] for m in members], axis=0)
    seg = np.concatenate(
        [np.full(len(m[1]), f, dtype=np.int32) for f, m in enumerate(members)]
    )
    eb_l = [m[2] for m in members]
    vr_l = [m[3] for m in members]
    size_l = [m[4] for m in members]
    n_real_blocks, n_real_fields = len(seg), len(members)
    # pad to power-of-two buckets; padding blocks point at a dummy field slot
    n_blocks = _next_pow2(n_real_blocks)
    n_fields = _next_pow2(n_real_fields + 1)
    pad = n_blocks - n_real_blocks
    if pad:
        halo = np.concatenate([halo, np.zeros((pad,) + halo.shape[1:], np.float32)])
        seg = np.concatenate([seg, np.full(pad, n_fields - 1, np.int32)])
    # field boundary array: blocks of field f live at [bounds[f], bounds[f+1]);
    # empty padded slots collapse, the last slot absorbs the padding blocks
    bounds = np.zeros(n_fields + 1, np.int32)
    bounds[1 : n_real_fields + 1] = np.cumsum([len(m[1]) for m in members])
    bounds[n_real_fields + 1 :] = n_real_blocks
    bounds[n_fields] = n_blocks
    def padf(v, fill):
        return np.asarray(v + [fill] * (n_fields - n_real_fields), np.float32)

    fn = _batched_estimates_jitted(nd, n_blocks, n_fields, transform)
    br_sz, br_zfp, psnr, eb_sz = fn(
        jnp.asarray(halo), jnp.asarray(seg),
        jnp.asarray(bounds), jnp.asarray(padf(eb_l, 1.0)),
        jnp.asarray(padf(vr_l, 1.0)), jnp.asarray(padf(size_l, 1.0)),
    )
    br_sz, br_zfp = np.asarray(br_sz), np.asarray(br_zfp)
    psnr, eb_sz = np.asarray(psnr), np.asarray(eb_sz)
    for f, (i, _, eb, vr, _) in enumerate(members):
        bs, bz = float(br_sz[f]), float(br_zfp[f])
        codec = _pick_codec(bs, bz, codecs)
        results[i] = Selection(
            codec, float(eb), float(eb_sz[f]), bs, bz, float(psnr[f]), vr, r_sp
        )


@_lru_cache(maxsize=256)
def _estimates_jitted(x_shape, starts_shape, transform: str):
    """Jitted Steps 1-3 of Fig. 2, cached per (field shape, sample grid).

    Compiles once per field shape — the in-situ setting compresses the same
    fields every timestep, so the paper's <7% overhead target is met after
    the first field (see bench_overhead).
    """

    def f(x, starts, eb_abs, vr):
        e_zfp = est.estimate_zfp(x, eb_abs, starts, vr, transform)
        delta = est.sz_delta_for_psnr(e_zfp.psnr, vr)
        # clamp: degenerate (near-lossless) ZFP PSNR estimates would drive
        # the SZ bin size to 0 -> inf codes; floor keeps Algorithm 1 sane
        eb_sz = jnp.clip(delta / 2.0, eb_abs * 1e-6, eb_abs)
        e_sz = est.estimate_sz(x, 2.0 * eb_sz, starts, vr)
        return e_sz.bitrate, e_zfp.bitrate, e_zfp.psnr, eb_sz

    return jax.jit(f)


# ---------------------------------------------------------------------------
# Step 4 — construct the selected compressor and run it
# ---------------------------------------------------------------------------


@dataclass
class CompressedField:
    codec: Codec             # the selection bit s_i
    data: bytes
    shape: tuple[int, ...]
    dtype: str
    selection: Selection | None = None

    @property
    def nbytes(self) -> int:
        return len(self.data)


def encode_with_selection(
    x: np.ndarray, sel: Selection, *, device_encode: bool = False
) -> CompressedField:
    """Step 4: run the already-selected compressor on `x`.

    Split from `select_and_compress` so batched callers (compress_pytree,
    the checkpoint writer) can make ALL decisions in one device call via
    `select_many` and then encode fields on a thread pool while the device
    is free for the next batch. The byte codec is resolved through the
    registry (DESIGN.md §2.1), so registered codecs beyond sz/zfp encode
    through the same path.

    `device_encode=True` tries the codec's in-graph Stage III first
    (capability `device_encode`, DESIGN.md §3.7): the packed stream comes
    back in one `device_get` and decodes through the same registry
    decoder. Encoders return None under the §3.7 fallback rules, and the
    host coder then runs — same container either way, never a truncated
    stream.
    """
    x = np.asarray(x)
    orig_shape, orig_dtype = x.shape, x.dtype
    view = _fold_ndim(x.astype(np.float32))
    if view.ndim == 0:
        view = view.reshape(1)
    codec = _codecs.get(sel.codec)
    data = None
    if device_encode and getattr(codec, "device_encode", False):
        data = codec.encode_device(view, sel)
    if data is None:
        data = codec.encode(view, sel)
    # safety net: never ship a stream larger than raw
    if len(data) >= view.nbytes and sel.codec != "raw":
        sel = Selection("raw", sel.eb_abs, sel.eb_sz, 32.0, 32.0, sel.psnr_target, sel.vr, sel.r_sp)
        data = view.tobytes()
    return CompressedField(sel.codec, data, orig_shape, str(orig_dtype), sel)


def select_and_compress(
    x: np.ndarray,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float = est.DEFAULT_SAMPLING_RATE,
) -> CompressedField:
    x = np.asarray(x)
    sel = select(x.astype(np.float32), eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp)
    return encode_with_selection(x, sel)


def decompress(cf: CompressedField) -> np.ndarray:
    """Invert any `CompressedField`, lossy or raw, to a writeable array.

    Two raw conventions coexist and `selection` disambiguates: fields that
    went through a `Selection` (including lossy-decided/safety-net raw)
    hold f32 working-dtype bytes; selection-less raw fields — `Policy.raw`
    leaves, non-float leaves — hold exact ORIGINAL-dtype bytes, restored
    bit-identically (f64 precision, int payloads, and all)."""
    if cf.codec == "raw" and cf.selection is None:
        return _codecs.writeable_frombuffer(cf.data, cf.dtype).reshape(cf.shape)
    out = _codecs.get(cf.codec).decode(cf.data)
    return out.reshape(cf.shape).astype(cf.dtype)


def compression_ratio(cf: CompressedField) -> float:
    n = int(np.prod(cf.shape)) if cf.shape else 1
    return (n * 4) / max(len(cf.data), 1)
