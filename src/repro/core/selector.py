"""Algorithm 1 — automatic online selection between SZ and ZFP (paper §5.3).

Per field:
  1. sample blocks (rate r_sp);
  2. estimate ZFP's (BR, PSNR) at the user's error bound;
  3. invert Eq. (10) to get the SZ bin size delta matching ZFP's PSNR
     (iso-PSNR comparison -> rate-distortion-optimal choice);
  4. estimate SZ's BR at that delta;
  5. pick the compressor with the smaller estimated bit-rate.

Note (DESIGN.md §1): Algorithm 1 line 11 prints "error bound 2*delta"; the
derivation requires eb_sz = delta/2 (clamped to eb_abs so the user's bound
always holds). We implement the consistent reading.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache as _lru_cache
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import estimator as est
from . import sz as _sz
from . import zfp as _zfp

Codec = Literal["sz", "zfp", "raw"]


@dataclass
class Selection:
    codec: Codec
    eb_abs: float            # user bound (guaranteed pointwise)
    eb_sz: float             # SZ bound after the iso-PSNR match
    br_sz: float
    br_zfp: float
    psnr_target: float       # ZFP's estimated PSNR (the match point)
    vr: float
    r_sp: float


def select(
    x: jax.Array | np.ndarray,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float = est.DEFAULT_SAMPLING_RATE,
    transform: str = "zfp",
) -> Selection:
    """Run Steps 1-3 of Fig. 2 and return the decision + estimates."""
    x = jnp.asarray(x)
    if x.ndim > 3:  # fields are 1-3D; fold leading axes (checkpoint tensors)
        x = x.reshape((-1,) + x.shape[-2:])
    if x.ndim == 0 or min(x.shape) < 4 or x.size < 64:
        vr0 = float(jnp.max(x) - jnp.min(x)) if x.size else 0.0
        eb = eb_abs if eb_abs is not None else (eb_rel or 1e-3) * max(vr0, 1e-30)
        return Selection("raw", float(eb), float(eb), 32.0, 32.0, 0.0, vr0, r_sp)
    vr = float(jnp.max(x) - jnp.min(x))
    if vr <= 0:
        eb = eb_abs if eb_abs is not None else 1e-30
        return Selection("raw", float(eb), float(eb), 32.0, 32.0, 0.0, vr, r_sp)
    if eb_abs is None:
        assert eb_rel is not None, "need eb_abs or eb_rel"
        eb_abs = eb_rel * vr
    starts = est.block_starts(x.shape, r_sp)
    br_sz, br_zfp, psnr_zfp, eb_sz = _estimates_jitted(
        x.shape, starts.shape, transform
    )(x, jnp.asarray(starts), jnp.float32(eb_abs), jnp.float32(vr))
    br_sz, br_zfp = float(br_sz), float(br_zfp)
    eb_sz = float(eb_sz)
    codec: Codec = "sz" if br_sz < br_zfp else "zfp"
    if min(br_sz, br_zfp) >= 32.0:
        codec = "raw"  # incompressible at this bound — store verbatim
    return Selection(codec, float(eb_abs), eb_sz, br_sz, br_zfp, float(psnr_zfp), vr, r_sp)


@_lru_cache(maxsize=256)
def _estimates_jitted(x_shape, starts_shape, transform: str):
    """Jitted Steps 1-3 of Fig. 2, cached per (field shape, sample grid).

    Compiles once per field shape — the in-situ setting compresses the same
    fields every timestep, so the paper's <7% overhead target is met after
    the first field (see bench_overhead).
    """

    def f(x, starts, eb_abs, vr):
        e_zfp = est.estimate_zfp(x, eb_abs, starts, vr, transform)
        delta = est.sz_delta_for_psnr(e_zfp.psnr, vr)
        # clamp: degenerate (near-lossless) ZFP PSNR estimates would drive
        # the SZ bin size to 0 -> inf codes; floor keeps Algorithm 1 sane
        eb_sz = jnp.clip(delta / 2.0, eb_abs * 1e-6, eb_abs)
        e_sz = est.estimate_sz(x, 2.0 * eb_sz, starts, vr)
        return e_sz.bitrate, e_zfp.bitrate, e_zfp.psnr, eb_sz

    return jax.jit(f)


# ---------------------------------------------------------------------------
# Step 4 — construct the selected compressor and run it
# ---------------------------------------------------------------------------


@dataclass
class CompressedField:
    codec: Codec             # the selection bit s_i
    data: bytes
    shape: tuple[int, ...]
    dtype: str
    selection: Selection | None = None


def select_and_compress(
    x: np.ndarray,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float = est.DEFAULT_SAMPLING_RATE,
) -> CompressedField:
    x = np.asarray(x)
    orig_shape, orig_dtype = x.shape, x.dtype
    xf = x.astype(np.float32)
    sel = select(xf, eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp)
    view = xf
    if view.ndim > 3:
        view = view.reshape((-1,) + view.shape[-2:])
    if view.ndim == 0:
        view = view.reshape(1)
    if sel.codec == "sz":
        data = _sz.sz_compress(view, sel.eb_sz)
    elif sel.codec == "zfp":
        data = _zfp.zfp_compress(view, sel.eb_abs)
    else:
        data = view.tobytes()
    # safety net: never ship a stream larger than raw
    if len(data) >= view.nbytes and sel.codec != "raw":
        sel = Selection("raw", sel.eb_abs, sel.eb_sz, 32.0, 32.0, sel.psnr_target, sel.vr, r_sp)
        data = view.tobytes()
    return CompressedField(sel.codec, data, orig_shape, str(orig_dtype), sel)


def decompress(cf: CompressedField) -> np.ndarray:
    if cf.codec == "sz":
        out = _sz.sz_decompress(cf.data)
    elif cf.codec == "zfp":
        out = _zfp.zfp_decompress(cf.data)
    else:
        out = np.frombuffer(cf.data, dtype=np.float32)
    return out.reshape(cf.shape).astype(cf.dtype)


def compression_ratio(cf: CompressedField) -> float:
    n = int(np.prod(cf.shape)) if cf.shape else 1
    return (n * 4) / max(len(cf.data), 1)
