"""Quality-metrics estimation: SSIM / correlation / KS as Policy targets
(DESIGN.md §7.4).

The paper's controller (§7) inverts the rate/PSNR estimators; real consumers
of scientific data hold domain quality contracts instead — structural
similarity, Pearson correlation, distribution shape (arXiv 2310.14133 names
exactly this metric set; arXiv 1805.07384 is the fixed-PSNR precedent the §7
machinery already follows). This module maps a candidate error bound to a
predicted metric value for both codecs, using only the §4–§5 residual models
plus the Stage-I halo-block sample — zero trial compressions:

* Both codecs' decompression error is additive, roughly independent of the
  data, and of known variance: SZ's integer-Lorenzo residual rounding error
  is uniform in [-delta/2, delta/2] (the quantized-residual model, §4), and
  ZFP's truncation error variance comes from the sampled-point PSNR (§5.2.2).
  So every metric here is a function of the error variance ``mse``, read off
  the same PSNR curves the controller already sweeps.
* SSIM (single-window, zero-mean error): under INDEPENDENT error the
  contrast/structure product collapses to
  ``(2 var + C2) / (2 var + mse + C2)`` with ``C2 = (K2 * VR)^2`` — closed
  form in ``mse`` given the sampled field variance. But quantization error
  is signal-correlated at coarse bins (values pull toward bin centers), so
  the solver reads SSIM off the same measured quantization curve as KS
  (`ssim_from_mse_sampled` — exact for SZ, conservative for ZFP); the
  closed form remains the fine-bound limit and the demo/seed layer.
* Pearson correlation: ``rho = 1 / sqrt(1 + mse / var)`` — closed form.
* KS statistic: no closed form for arbitrary data, and no smooth-noise
  shortcut either — the prequantized integer-Lorenzo SZ (DESIGN.md §3.1)
  reconstructs exactly ``delta * round(x / delta)``, whose value-CDF shift
  is FIRST order in delta (the quantized-residual staircase concentrates
  mass at bin centers), where additive smoothing of the same variance is
  only second order. So KS is sample-measured: a per-field ``mse <-> KS``
  curve from quantizing the sorted sample over a log grid of bin sizes
  (`FieldQualityStats.ks_curve`) — exact for SZ, and a matched-mse
  surrogate for ZFP's truncation error that is conservative (value
  quantization concentrates the CDF shift harder than the
  transform-domain error it stands in for).

Inversion (`equivalent_psnr`) turns a metric target into a per-field PSNR
target — closed-form for SSIM/correlation, interpolation on the measured
monotone-forced KS curve for fixed_ks — which the controller solves with
its existing closed-form-seed + clamped-secant loop (`_solve_fixed_psnr`
generalized to per-field target arrays). All statistics come from the same
sampled blocks on every path (host, sharded, warm), so decisions and
manifests stay bit-identical across them.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

#: Policy mode -> metric key.
MODE_METRIC = {
    "fixed_ssim": "ssim",
    "fixed_correlation": "correlation",
    "fixed_ks": "ks",
}
METRIC_MODES = tuple(MODE_METRIC)

#: Documented achievement tolerances (|achieved - target|) per metric; the
#: bench gate (`quality_target_accuracy`) and `TargetSolution.on_target`
#: both read these.
TOLERANCE = {"ssim": 0.02, "correlation": 0.005, "ks": 0.02}

#: Metric value of a lossless (raw) encode.
LOSSLESS_VALUE = {"ssim": 1.0, "correlation": 1.0, "ks": 0.0}

#: SSIM stabilizer constant K2 of Wang et al., scaled by the field's value
#: range; C1 (luminance) drops out because the error is zero-mean.
SSIM_K2 = 0.03
_SSIM_K1 = 0.01

#: Cap on the per-field sorted sample the KS estimator keeps (deterministic
#: spatial stride over the Stage-I block values, so every path sees the
#: same sample). ECDF resolution ~1/sqrt(n) = 0.008 at the cap — well under
#: the 0.02 KS tolerance.
KS_MAX_SAMPLES = 16384

#: Equivalent-PSNR clamp for metric inversion: below, the rate estimator's
#: own floor takes over; above, the solve lands on raw anyway.
PSNR_EQ_RANGE = (5.0, 180.0)

#: log2(delta / VR) grid the per-field mse<->KS curve is measured on: from
#: far below any solvable bound up to "one bin swallows the range".
KS_GRID_RANGE = (-40.0, 2.0)
KS_GRID_POINTS = 64

#: fixed_ks inversion safety margin: the block sample of a spatially
#: correlated field reads the value ECDF with an effective sample size well
#: below the point count, so the measured KS curve can sit a few thousandths
#: under the full-field one. The contract is a one-sided ceiling — solving
#: for (target - margin) trades a little rate for staying under it.
KS_TARGET_MARGIN = 0.005

_TINY = 1e-30


def _ecdf_sup(x_sorted: np.ndarray, y_sorted: np.ndarray) -> float:
    """Two-sample KS statistic of two pre-sorted samples."""
    if x_sorted.size == 0 or y_sorted.size == 0:
        return 0.0
    t = np.concatenate([x_sorted, y_sorted])
    fx = np.searchsorted(x_sorted, t, side="right") / x_sorted.size
    fy = np.searchsorted(y_sorted, t, side="right") / y_sorted.size
    return float(np.max(np.abs(fx - fy)))


# ---------------------------------------------------------------------------
# Sufficient statistics
# ---------------------------------------------------------------------------


@dataclass
class FieldQualityStats:
    """Per-field metric sufficient statistics, computed once from the same
    Stage-I halo-block sample the rate/PSNR estimators use (so the warm
    path's psum-reconciled moments fingerprint also guards these — see
    `core/sharded.py`)."""

    var: float  # sample variance sigma_x^2 (float64)
    vr: float  # value range
    values: np.ndarray  # sorted sample values, float64, <= KS_MAX_SAMPLES
    _curves: tuple[np.ndarray, np.ndarray, np.ndarray] | None = field(
        default=None, init=False, repr=False
    )

    def _quant_curves(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(mse_grid, ks_grid, ssim_grid): measured error variance, KS
        statistic and global SSIM of `delta * round(values / delta)` over a
        log grid of bin sizes, each forced monotone (mse and KS
        non-decreasing, SSIM non-increasing) so target inversion by
        interpolation is well-posed. Computed lazily, once per field. SSIM
        is measured here rather than closed-form because quantization error
        is signal-CORRELATED at coarse bins (values pull toward bin
        centers: var(q) ~ var - mse, not var + mse), which depresses the
        contrast/structure term below the independent-error model; at fine
        bins the measured curve converges to the closed form."""
        if self._curves is None:
            v = self.values
            vr = max(self.vr, _TINY)
            c1 = (_SSIM_K1 * vr) ** 2
            c2 = (SSIM_K2 * vr) ** 2
            mx = float(v.mean()) if v.size else 0.0
            vx = float(v.var()) if v.size else 0.0
            deltas = vr * np.exp2(
                np.linspace(KS_GRID_RANGE[0], KS_GRID_RANGE[1], KS_GRID_POINTS)
            )
            mse = np.empty(KS_GRID_POINTS)
            ks = np.empty(KS_GRID_POINTS)
            ssim = np.empty(KS_GRID_POINTS)
            for i, d in enumerate(deltas):
                q = d * np.round(v / d)  # still sorted: round is monotone
                mse[i] = float(np.mean((v - q) ** 2)) if v.size else 0.0
                ks[i] = _ecdf_sup(v, q)
                if v.size:
                    my, vy = float(q.mean()), float(q.var())
                    cov = float(np.mean((v - mx) * (q - my)))
                    lum = (2.0 * mx * my + c1) / (mx * mx + my * my + c1)
                    ssim[i] = lum * (2.0 * cov + c2) / (vx + vy + c2)
                else:
                    ssim[i] = 1.0
            self._curves = (
                np.maximum.accumulate(mse),
                np.maximum.accumulate(ks),
                np.minimum.accumulate(ssim),
            )
        return self._curves

    def ks_curve(self) -> tuple[np.ndarray, np.ndarray]:
        """(mse_grid, ks_grid) of the measured quantization curve."""
        mse, ks, _ = self._quant_curves()
        return mse, ks

    def ssim_curve(self) -> tuple[np.ndarray, np.ndarray]:
        """(mse_grid, ssim_grid) of the measured quantization curve."""
        mse, _, ssim = self._quant_curves()
        return mse, ssim


def stats_from_blocks(blocks: np.ndarray, nd: int, vr: float) -> FieldQualityStats:
    """Statistics from a (n_blocks, 5, ..) halo-block batch (the halo row is
    zero-filled outside the domain, so only the 4^nd interior is sampled)."""
    b = np.asarray(blocks)
    if b.shape[1] == 5:  # strip the original-neighbor halo
        b = b[(slice(None),) + (slice(1, None),) * nd]
    v = b.astype(np.float64, copy=False).reshape(-1)
    var = float(np.var(v)) if v.size else 0.0
    if v.size > KS_MAX_SAMPLES:
        v = v[:: -(-v.size // KS_MAX_SAMPLES)]
    return FieldQualityStats(var=var, vr=float(vr), values=np.sort(v))


def stats_from_field(x, r_sp: float = 0.05) -> FieldQualityStats:
    """Statistics straight from a field (demo / curve helper path); the
    solver path uses `stats_from_blocks` on already-gathered batches."""
    from . import estimator as est
    from .selector import _fold_ndim

    view = _fold_ndim(np.asarray(x, np.float32))
    starts = est.block_starts(view.shape, r_sp)
    blocks = est.gather_blocks_np(view, starts, halo=True)
    vr = float(view.max() - view.min()) if view.size else 0.0
    return stats_from_blocks(blocks, view.ndim, vr)


# ---------------------------------------------------------------------------
# mse <-> PSNR <-> metric transforms (closed-form layer)
# ---------------------------------------------------------------------------


def mse_from_psnr(psnr_db, vr: float):
    """Error variance implied by a value-range-relative PSNR."""
    vr2 = max(float(vr), _TINY) ** 2
    return vr2 * 10.0 ** (-np.asarray(psnr_db, np.float64) / 10.0)


def psnr_from_mse(mse, vr: float):
    """Inverse of `mse_from_psnr` (clamped away from log(0))."""
    vr2 = max(float(vr), _TINY) ** 2
    return -10.0 * np.log10(np.maximum(np.asarray(mse, np.float64), _TINY * vr2) / vr2)


def ssim_from_mse(mse, var: float, vr: float):
    """Single-window SSIM under zero-mean INDEPENDENT additive error of
    variance `mse`: luminance = 1, contrast*structure =
    (2 var + C2) / (2 var + mse + C2). The closed-form/demo layer — the
    solver uses `ssim_from_mse_sampled`, which this curve upper-bounds."""
    c2 = (SSIM_K2 * max(float(vr), _TINY)) ** 2
    return (2.0 * var + c2) / (2.0 * var + np.asarray(mse, np.float64) + c2)


def mse_for_ssim(target: float, var: float, vr: float) -> float:
    """Invert `ssim_from_mse`: the error variance at which SSIM == target."""
    c2 = (SSIM_K2 * max(float(vr), _TINY)) ** 2
    return (2.0 * var + c2) * (1.0 - target) / max(target, _TINY)


def correlation_from_mse(mse, var: float):
    """Pearson correlation between a field and itself plus independent
    zero-mean error: rho = 1 / sqrt(1 + mse / var)."""
    return 1.0 / np.sqrt(1.0 + np.asarray(mse, np.float64) / max(var, _TINY))


def mse_for_correlation(target: float, var: float) -> float:
    """Invert `correlation_from_mse`."""
    t = min(max(target, _TINY), 1.0 - 1e-12)
    return var * (1.0 / (t * t) - 1.0)


# ---------------------------------------------------------------------------
# KS statistic (sample-measured layer)
# ---------------------------------------------------------------------------


def ks_from_mse(stats: FieldQualityStats, mse: float) -> float:
    """Predicted KS statistic at decompression-error variance `mse`, read
    off the measured per-field mse<->KS quantization curve (exact for the
    prequantized SZ codec; a conservative matched-mse surrogate for ZFP —
    module docstring)."""
    mse_g, ks_g = stats.ks_curve()
    return float(np.interp(mse, mse_g, ks_g))


def mse_for_ks(stats: FieldQualityStats, target: float) -> float:
    """Invert `ks_from_mse`: the error variance whose predicted KS hits
    `target` (interpolation on the monotone-forced measured curve)."""
    mse_g, ks_g = stats.ks_curve()
    if target <= ks_g[0]:
        return float(mse_g[0])
    return float(np.interp(target, ks_g, mse_g))


def ssim_from_mse_sampled(stats: FieldQualityStats, mse: float) -> float:
    """Predicted SSIM at error variance `mse`, read off the measured
    quantization curve. Exact for SZ (whose error IS the quantization
    error), conservative for ZFP: signal-correlated quantization depresses
    SSIM harder than ZFP's closer-to-independent truncation error, so the
    solve lands at or above target either way. Converges to
    `ssim_from_mse`'s closed form at fine bounds."""
    mse_g, _, ssim_g = stats._quant_curves()
    return float(np.interp(mse, mse_g, ssim_g))


def mse_for_ssim_sampled(stats: FieldQualityStats, target: float) -> float:
    """Invert `ssim_from_mse_sampled` on the monotone-forced curve."""
    mse_g, _, ssim_g = stats._quant_curves()
    if target >= ssim_g[0]:
        return float(mse_g[0])
    # ssim_g decreases with mse: reverse both for np.interp's ascending-x
    return float(np.interp(target, ssim_g[::-1], mse_g[::-1]))


# ---------------------------------------------------------------------------
# Metric <-> equivalent PSNR (the controller-facing layer)
# ---------------------------------------------------------------------------


def equivalent_psnr(metric: str, target: float, stats: FieldQualityStats) -> float:
    """The per-field PSNR target whose error variance achieves `target` on
    `metric` — the closed-form seed the §7 controller inversion runs on."""
    if metric == "ssim":
        mse = mse_for_ssim_sampled(stats, target)
    elif metric == "correlation":
        mse = mse_for_correlation(target, stats.var)
    elif metric == "ks":
        mse = mse_for_ks(stats, max(target - KS_TARGET_MARGIN, target * 0.5))
    else:  # pragma: no cover - guarded by Policy validation
        raise ValueError(f"unknown quality metric {metric!r}; one of {sorted(TOLERANCE)}")
    lo, hi = PSNR_EQ_RANGE
    return float(np.clip(psnr_from_mse(mse, stats.vr), lo, hi))


def metric_from_psnr(metric: str, psnr_db: float, stats: FieldQualityStats) -> float:
    """Predicted metric value at an achieved (estimated) PSNR."""
    if not np.isfinite(psnr_db):
        return LOSSLESS_VALUE[metric]
    mse = float(mse_from_psnr(psnr_db, stats.vr))
    if metric == "ssim":
        return ssim_from_mse_sampled(stats, mse)
    if metric == "correlation":
        return float(correlation_from_mse(mse, stats.var))
    if metric == "ks":
        return ks_from_mse(stats, mse)
    raise ValueError(f"unknown quality metric {metric!r}; one of {sorted(TOLERANCE)}")


def metric_gap(metric: str, achieved: float, target: float) -> float:
    """Signed violation of the contract: positive = target missed. SSIM and
    correlation are floors (overshoot is free quality), KS is a ceiling."""
    if metric == "ks":
        return achieved - target
    return target - achieved


def lossless_metric(mode: str) -> float | None:
    """`TargetSolution.est_metric` for a raw (lossless) selection; None for
    the non-metric modes."""
    m = MODE_METRIC.get(mode)
    return None if m is None else LOSSLESS_VALUE[m]


# ---------------------------------------------------------------------------
# Measured metrics (verification layer: benches, property tests, examples)
# ---------------------------------------------------------------------------


def measured_ssim(a, b) -> float:
    """Global (single-window) SSIM between original `a` and reconstruction
    `b`, with C1/C2 scaled by `a`'s value range."""
    x = np.asarray(a, np.float64).reshape(-1)
    y = np.asarray(b, np.float64).reshape(-1)
    vr = max(float(x.max() - x.min()), _TINY) if x.size else _TINY
    c1 = (_SSIM_K1 * vr) ** 2
    c2 = (SSIM_K2 * vr) ** 2
    mx, my = x.mean(), y.mean()
    vx, vy = x.var(), y.var()
    cov = float(np.mean((x - mx) * (y - my)))
    lum = (2.0 * mx * my + c1) / (mx * mx + my * my + c1)
    cs = (2.0 * cov + c2) / (vx + vy + c2)
    return float(lum * cs)


def measured_correlation(a, b) -> float:
    """Pearson correlation coefficient (1.0 for a bit-exact or constant pair)."""
    x = np.asarray(a, np.float64).reshape(-1)
    y = np.asarray(b, np.float64).reshape(-1)
    if np.array_equal(x, y):
        return 1.0
    sx, sy = x.std(), y.std()
    if sx <= 0.0 or sy <= 0.0:
        return 0.0
    return float(np.mean((x - x.mean()) * (y - y.mean())) / (sx * sy))


def measured_ks(a, b) -> float:
    """Two-sample KS statistic between the value distributions of `a` and `b`."""
    x = np.sort(np.asarray(a, np.float64).reshape(-1))
    y = np.sort(np.asarray(b, np.float64).reshape(-1))
    return _ecdf_sup(x, y)


_MEASURED = {
    "ssim": measured_ssim,
    "correlation": measured_correlation,
    "ks": measured_ks,
}


def measured_metric(metric: str, a, b) -> float:
    """Dispatch to the measured implementation of `metric`."""
    return _MEASURED[metric](a, b)


# ---------------------------------------------------------------------------
# Metric curves (demo / property-test surface)
# ---------------------------------------------------------------------------


def metric_curves(x, bounds, r_sp: float = 0.05, transform: str = "zfp") -> dict:
    """Predicted metric-vs-error-bound curves for both codecs over an
    ascending `bounds` grid, built on `controller.estimate_curves` and
    forced monotone (SSIM/correlation non-increasing in eb, KS
    non-decreasing) so target inversion — and the property suite — can rely
    on monotonicity even where the sampled PSNR staircase wiggles."""
    from .controller import estimate_curves

    curves = estimate_curves(x, bounds, r_sp=r_sp, transform=transform)
    stats = stats_from_field(x, r_sp)
    # SZ's quality follows the measured quantization error, ZFP's the
    # sampled truncation error — both forced monotone non-increasing first
    ps_sz = np.minimum.accumulate(np.asarray(curves["psnr_sz_measured"], np.float64))
    ps_zfp = np.minimum.accumulate(np.asarray(curves["psnr_zfp"], np.float64))
    out = dict(curves)
    for codec, ps in (("sz", ps_sz), ("zfp", ps_zfp)):
        mse = mse_from_psnr(ps, stats.vr)
        ssim = np.array([ssim_from_mse_sampled(stats, float(m)) for m in mse])
        corr = correlation_from_mse(mse, stats.var)
        ks = np.array([ks_from_mse(stats, float(m)) for m in mse])
        out[f"ssim_{codec}"] = np.minimum.accumulate(ssim)
        out[f"correlation_{codec}"] = np.minimum.accumulate(corr)
        out[f"ks_{codec}"] = np.maximum.accumulate(ks)
    return out
