"""Codec registry — one dispatch point for every compression layer (DESIGN.md §2.1).

The paper fixes the codec set at {SZ, ZFP} (+ verbatim raw), but nothing in
Algorithm 1 is specific to those two: FRaZ (Underwood et al., 2020) layers
fixed-quality control over *any* error-bounded compressor, and the
black-box ratio-prediction line (Underwood et al., 2023) shows the
estimator idea generalizes too. This module therefore makes the codec set
a *registry*: the selector, the §7 controller, the shard-local engine, and
the checkpoint manifest all dispatch byte encode/decode through
`get(name)` instead of string-comparing "sz"/"zfp"/"raw" inline, and
`Policy.codecs` allowlists are validated against `names()`.

A codec is anything satisfying the `Codec` protocol:

* ``encode(view32, selection) -> bytes`` — Step 4 on a folded f32 view
  (or a shard of one), reading whatever bound it needs off the
  `Selection` (`eb_abs` for ZFP-style, `eb_sz` for SZ-style);
* ``decode(data) -> np.ndarray`` — the inverse, returning a *writeable*
  flat/shaped f32 array (callers reshape to the recorded view);
* capability flags the engines consult instead of hardcoding names:
  - ``blockwise``: reconstruction is 4^n-block-local, so shard-split
    encoding is bit-identical only on 4-aligned boundaries (ZFP);
  - ``pointwise_bound``: the reconstruction honors a pointwise
    |err| <= eb contract (everything registered today);
  - ``lossless``: reconstructs bit-exactly (raw);
  - ``device_encode``: the codec can finish Stage III in-graph
    (DESIGN.md §3.7) through ``encode_device(view32, selection)``,
    which returns container bytes decodable by the same ``decode`` —
    or None when the field must take the host coder (the fallback
    rules of §3.7). Consult with `supports_device_encode(name)` /
    `getattr(codec, "device_encode", False)` so third-party codecs
    that predate the flag keep satisfying the protocol.

The built-in three register at import. Registering a fourth codec makes it
addressable by `Policy(codecs=...)` allowlists and decodable from
manifests; plugging it into the *estimators* (so Algorithm 1 can price it)
is the follow-on step DESIGN.md §2.1 sketches.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from . import sz as _sz
from . import zfp as _zfp


@runtime_checkable
class Codec(Protocol):
    """The codec contract every registered compressor satisfies."""

    name: str
    blockwise: bool
    pointwise_bound: bool
    lossless: bool

    def encode(self, view32: np.ndarray, selection) -> bytes:  # pragma: no cover
        ...

    def decode(self, data: bytes) -> np.ndarray:  # pragma: no cover
        ...


@dataclass(frozen=True)
class _FnCodec:
    """A codec assembled from plain functions (how the built-ins register)."""

    name: str
    blockwise: bool
    pointwise_bound: bool
    lossless: bool
    _encode: Callable[[np.ndarray, object], bytes]
    _decode: Callable[[bytes], np.ndarray]
    #: device-resident Stage III (DESIGN.md §3.7): returns container bytes
    #: or None (host fallback); absent for host-only codecs
    _encode_device: Callable[[np.ndarray, object], bytes | None] | None = None

    @property
    def device_encode(self) -> bool:
        return self._encode_device is not None

    def encode(self, view32: np.ndarray, selection) -> bytes:
        return self._encode(view32, selection)

    def encode_device(self, view32: np.ndarray, selection) -> bytes | None:
        if self._encode_device is None:
            return None
        return self._encode_device(view32, selection)

    def decode(self, data: bytes) -> np.ndarray:
        return self._decode(data)


_REGISTRY: dict[str, Codec] = {}


def register(codec: Codec, *, replace: bool = False) -> Codec:
    """Register `codec` under `codec.name`; returns it for chaining."""
    name = codec.name
    if not replace and name in _REGISTRY:
        raise ValueError(f"codec {name!r} is already registered")
    _REGISTRY[name] = codec
    return codec


def get(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def is_registered(name: str) -> bool:
    return name in _REGISTRY


def lossy_names() -> tuple[str, ...]:
    return tuple(n for n, c in _REGISTRY.items() if not c.lossless)


def supports_device_encode(name: str) -> bool:
    """Whether `name` can finish Stage III in-graph (DESIGN.md §3.7).
    `getattr` default keeps pre-flag third-party codecs valid."""
    return bool(getattr(get(name), "device_encode", False))


def writeable_frombuffer(data: bytes, dtype) -> np.ndarray:
    """`np.frombuffer` that returns a WRITEABLE array: the bytearray
    round-trip costs one copy, where frombuffer over immutable bytes would
    hand back a read-only view — and restored trees must be trainable in
    place. The one place this contract lives; every raw/none decode path
    (registry raw codec, `decompress_pytree`, the checkpoint readers)
    routes through it."""
    return np.frombuffer(bytearray(data), dtype=np.dtype(dtype))


def _raw_decode(data: bytes) -> np.ndarray:
    return writeable_frombuffer(data, np.float32)


def _sz_encode_device(view, sel):
    # lazy import: device_encode pulls in the kernel tier, which most
    # registry consumers (pure host decode paths) never need
    from . import device_encode as _de

    return _de.sz_encode_device(view, sel.eb_sz)


def _zfp_encode_device(view, sel):
    from . import device_encode as _de

    return _de.zfp_encode_device(view, sel.eb_abs)


register(
    _FnCodec(
        "sz", blockwise=False, pointwise_bound=True, lossless=False,
        _encode=lambda view, sel: _sz.sz_compress(view, sel.eb_sz),
        _decode=_sz.sz_decompress,
        _encode_device=_sz_encode_device,
    )
)
register(
    _FnCodec(
        "zfp", blockwise=True, pointwise_bound=True, lossless=False,
        _encode=lambda view, sel: _zfp.zfp_compress(view, sel.eb_abs),
        _decode=_zfp.zfp_decompress,
        _encode_device=_zfp_encode_device,
    )
)
register(
    _FnCodec(
        "raw", blockwise=False, pointwise_bound=True, lossless=True,
        _encode=lambda view, sel: view.tobytes(),
        _decode=_raw_decode,
    )
)

#: the full built-in candidate set, in decision order — the default
#: `Policy.codecs` allowlist
DEFAULT_CODECS: tuple[str, ...] = ("sz", "zfp", "raw")


__all__ = [
    "Codec",
    "DEFAULT_CODECS",
    "get",
    "is_registered",
    "lossy_names",
    "names",
    "register",
    "supports_device_encode",
    "writeable_frombuffer",
]
