"""Stage III lossless entropy coding (paper §5.1.1, Fig. 1).

Host-side (numpy) Huffman coder used by the byte-emitting SZ path, plus the
Shannon-entropy bit-rate estimator used in-graph (Eqs. (5)/(6)).

Entropy coding is byte-stream manipulation, not tensor compute, so it stays
off the accelerator (DESIGN.md §3.6); in-graph callers use `entropy_bits`.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

MAX_CODE_LEN = 24
ESCAPE = 0  # symbol 0 of the shifted alphabet is the escape symbol


def _zstd():
    """Optional: zstandard shrinks the serialized Huffman table a bit; the
    codec must still work on a bare jax+numpy environment, so streams carry
    a flag byte and fall back to the raw table blob when it is absent."""
    try:
        import zstandard

        return zstandard
    except ImportError:
        return None


def entropy_bits(hist: np.ndarray) -> float:
    """Shannon entropy (bits/value) of a histogram — Eq. (5)."""
    p = hist.astype(np.float64)
    tot = p.sum()
    if tot <= 0:
        return 0.0
    p = p[p > 0] / tot
    return float(-(p * np.log2(p)).sum())


# ---------------------------------------------------------------------------
# Canonical Huffman
# ---------------------------------------------------------------------------


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths; dampen frequencies until max length fits."""
    f = freqs.astype(np.int64).copy()
    while True:
        lens = _huffman_lengths(f)
        if lens.max(initial=0) <= MAX_CODE_LEN:
            return lens
        f = (f + 1) // 2  # flatten the distribution, retry

def _huffman_lengths(freqs: np.ndarray) -> np.ndarray:
    sym = np.nonzero(freqs)[0]
    lens = np.zeros(len(freqs), dtype=np.int32)
    if len(sym) == 0:
        return lens
    if len(sym) == 1:
        lens[sym[0]] = 1
        return lens
    heap = [(int(freqs[s]), int(s), (int(s),)) for s in sym]
    heapq.heapify(heap)
    cnt = len(freqs)
    while len(heap) > 1:
        f1, _, g1 = heapq.heappop(heap)
        f2, _, g2 = heapq.heappop(heap)
        for s in g1 + g2:
            lens[s] += 1
        heapq.heappush(heap, (f1 + f2, cnt, g1 + g2))
        cnt += 1
    return lens


def _canonical_codes(lens: np.ndarray) -> np.ndarray:
    """Assign canonical codewords (MSB-first) from code lengths."""
    codes = np.zeros(len(lens), dtype=np.uint64)
    order = np.lexsort((np.arange(len(lens)), lens))
    code = 0
    prev_len = 0
    for s in order:
        ln = int(lens[s])
        if ln == 0:
            continue
        code <<= ln - prev_len
        codes[s] = code
        code += 1
        prev_len = ln
    return codes


@dataclass
class HuffmanTable:
    lens: np.ndarray   # (K,) int32
    codes: np.ndarray  # (K,) uint64, canonical, MSB-first

    def to_bytes(self) -> bytes:
        """Sparse serialization: (K, n_used) + flag byte + delta-coded
        symbols + lens, zstd-compressed when available (symbol runs are
        near-contiguous, lens are small), raw otherwise."""
        used = np.nonzero(self.lens)[0].astype(np.int64)
        deltas = np.diff(used, prepend=0).astype(np.uint32)
        blob = deltas.tobytes() + self.lens[used].astype(np.uint8).tobytes()
        z = _zstd()
        flag = 1 if z is not None else 0
        if z is not None:
            blob = z.ZstdCompressor(level=9).compress(blob)
        hdr = np.array([len(self.lens), len(used)], dtype=np.uint32).tobytes()
        return hdr + bytes([flag]) + blob

    @staticmethod
    def from_bytes(buf: bytes) -> "HuffmanTable":
        k, n = np.frombuffer(buf[:8], dtype=np.uint32)
        flag = buf[8]
        blob = buf[9:]
        if flag:
            z = _zstd()
            if z is None:
                raise RuntimeError(
                    "stream's Huffman table is zstd-compressed but the "
                    "'zstandard' package is not installed"
                )
            blob = z.ZstdDecompressor().decompress(blob)
        deltas = np.frombuffer(blob[: 4 * n], dtype=np.uint32).astype(np.int64)
        used = np.cumsum(deltas)
        lens = np.zeros(k, dtype=np.int32)
        lens[used] = np.frombuffer(blob[4 * n : 5 * n], dtype=np.uint8)
        return HuffmanTable(lens, _canonical_codes(lens))


def build_table(freqs: np.ndarray) -> HuffmanTable:
    lens = _code_lengths(freqs)
    return HuffmanTable(lens, _canonical_codes(lens))


def encode(symbols: np.ndarray, table: HuffmanTable) -> bytes:
    """Vectorized Huffman encode: per-symbol bit expansion + packbits."""
    lens = table.lens[symbols]
    total = int(lens.sum())
    if total == 0:
        return b""
    offsets = np.zeros(len(symbols) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    src = np.repeat(np.arange(len(symbols), dtype=np.int64), lens)
    bitpos = np.arange(total, dtype=np.int64) - offsets[src]
    words = table.codes[symbols][src]
    shifts = (lens[src] - 1 - bitpos).astype(np.uint64)
    bits = ((words >> shifts) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits).tobytes()


def decode(buf: bytes, table: HuffmanTable, count: int) -> np.ndarray:
    """Table-driven canonical Huffman decode (dense 2^maxlen lookup)."""
    if count == 0:
        return np.zeros(0, dtype=np.int64)
    maxlen = int(table.lens.max())
    # dense lookup: top `maxlen` bits -> (symbol, length)
    lut_sym = np.zeros(1 << maxlen, dtype=np.int64)
    lut_len = np.zeros(1 << maxlen, dtype=np.int32)
    for s in range(len(table.lens)):
        l = int(table.lens[s])
        if l == 0:
            continue
        prefix = int(table.codes[s]) << (maxlen - l)
        span = 1 << (maxlen - l)
        lut_sym[prefix : prefix + span] = s
        lut_len[prefix : prefix + span] = l
    bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8))
    bits = np.concatenate([bits, np.zeros(maxlen, dtype=np.uint8)])
    # precompute every bit-window as an int (vectorized), then walk them
    weights = (1 << np.arange(maxlen - 1, -1, -1)).astype(np.int64)
    windows = np.lib.stride_tricks.sliding_window_view(bits, maxlen).astype(np.int64) @ weights
    out = np.empty(count, dtype=np.int64)
    pos = 0
    for i in range(count):
        w = windows[pos]
        out[i] = lut_sym[w]
        pos += int(lut_len[w])
    return out
