"""Quality-target controller: fixed-PSNR and fixed-ratio modes (DESIGN.md §7).

The selection engine (DESIGN.md §1) answers "which codec is cheapest at
this error bound" — but callers usually hold a *quality* target ("give me
60 dB", "give me 8x"), not an error bound. This module inverts the
estimator math of DESIGN.md §4–§5 to solve for the per-field error bound
that meets the target, then hands the resulting `Selection` to the
ordinary encoders. There are NO trial compressions anywhere in the search
loop — the objective is always the *estimated* (or sample-measured)
rate-distortion curve:

* ``fixed_psnr`` — iso-distortion at the target. The closed-form
  inversion of Eq. (10) (`estimator.sz_delta_for_psnr`, snapped to
  `estimator.PSNR_MATCH_QUANTUM`) seeds SZ's bin size; a few secant steps
  against the *measured* quantization error of the sampled blocks absorb
  what the uniform-noise model misses (fields with constant runs land up
  to ~3 dB hot otherwise). ZFP's bound walks its estimated-PSNR staircase
  the same way. The codec with the smaller estimated rate *within the
  PSNR tolerance band* wins — Algorithm 1's iso-PSNR/min-rate rule,
  anchored at the caller's target instead of ZFP's achieved-at-eb PSNR.
* ``fixed_ratio`` — iso-rate. Both codecs are driven to the byte budget
  by a high-rate-model seed (rate moves ~1 bit/value per octave of bound)
  plus clamped secant steps, and the codec with the higher estimated PSNR
  at the budget wins — the rate-distortion dual of Algorithm 1.
* ``fixed_ssim`` / ``fixed_correlation`` / ``fixed_ks`` — metric targets
  (DESIGN.md §7.4). Every metric is a monotone function of the error
  variance, so `core/quality.py` converts the metric target into a
  per-field *equivalent-PSNR* target (closed form for SSIM/correlation
  from the sampled variance; a bisection on the sample-measured KS curve)
  and the fixed_psnr machinery solves it — same seeds, same secant, same
  min-rate-at-target codec choice, zero trial compressions.
* ``fixed_accuracy`` — the paper's bound-centric mode, delegated to
  `select_many` so all the modes share one call signature.

All candidate bounds for all fields are evaluated by ONE jitted launch
per round: the packed block batches of `select_many` gain a vmapped
candidate axis (`_sweep_jitted`), so each round is a `(1, fields)`-slot
program over blocks gathered once per field. fixed_psnr rounds use a
*light* sweep that returns only PSNR outputs, letting XLA dead-code-
eliminate the exact-coder bit count and the SZ entropy sort — the two
dominant costs — so the whole solve stays well under the encoders' time.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache as _lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import codecs as _codecs
from . import estimator as est
from . import quality as qual
from .policy import TARGET_FIELD, Policy, policy_from_kwargs
from .selector import (
    MAX_BATCH_FIELDS,
    Selection,
    _degenerate_selection,
    _fold_ndim,
    _max_batch_blocks,
    _next_pow2,
    select_many,
)

#: the codecs' working dtype is float32, so ratio targets are defined
#: against 32 bits/value (matching `compression_ratio`)
RAW_BITS = 32.0

#: fixed_psnr: ZFP is eligible only when its estimated PSNR lands within
#: this many dB above the target — the bit-plane staircase otherwise
#: overshoots by up to ~6 dB/plane, and "hit the target" beats "free extra
#: quality the caller did not ask to pay rate for". SZ's measured-error
#: refinement lands on the target by construction, so SZ always competes.
PSNR_TOL_DB = 0.5
#: a probe counts as meeting a PSNR target when it clears it minus this
#: slack (absorbs sampling noise without chasing ulps)
PSNR_SLACK_DB = 0.25

#: fixed_ratio: a codec is eligible when its estimated rate is within this
#: relative window of the budget (the solve keeps rate <= budget; this
#: rejects staircase undershoot past the ratio tolerance).
RATIO_TOL = 0.10
#: a rate probe counts as meeting the budget up to this relative overage —
#: rejecting a probe 0.2% over the budget in favor of one 20% under it
#: would miss the ratio window from the other side
RATE_SLACK = 0.02

#: the §4 SZ estimate carries the paper's flat +0.5 bits/value Huffman
#: cushion — a selection-side worst case, not what the byte coder pays. A
#: rate *target* cannot absorb a ~0.4-bit bias (it lands straight in the
#: achieved ratio), so the controller retargets with an empirical overhead
#: curve: near zero above ~1 bit/value of residual entropy, rising toward
#: the 1-bit/symbol Huffman floor as the PDF peaks (DESIGN.md §7).
SZ_HUFF_FLOOR = 0.08
SZ_HUFF_PEAK_SLOPE = 0.85

#: high-rate-model slopes used to seed and clamp the secant steps: one
#: octave of bound costs ~1 bit/value (Eq. (9) at high rate; exactly one
#: bit-plane for ZFP) == ~6.02 dB (Eq. (11))
DB_PER_OCTAVE = 20.0 * math.log10(2.0)
#: secant-slope clamps, [steepest, shallowest] (negative: metrics are
#: nonincreasing in the bound)
PSNR_SLOPE_CLAMP = (-30.0, -1.0)
RATE_SLOPE_CLAMP = (-4.0, -0.25)

#: refinement evals after the seed eval, by mode (fixed_psnr and the
#: §7.4 metric modes ride light sweeps; fixed_ratio rounds are full-rate
#: probes; every mode ends in one full pricing eval)
DEFAULT_ROUNDS = {
    "fixed_psnr": 3,
    "fixed_ratio": 3,
    "fixed_ssim": 3,
    "fixed_correlation": 3,
    "fixed_ks": 3,
}


@dataclass
class TargetSolution:
    """One field's solved target: the `Selection` to encode with, plus the
    estimates the solve ended on (what the controller *believes* it hit)."""

    selection: Selection
    mode: str
    target: float        # dB (fixed_psnr), ratio (fixed_ratio), eb (fixed_accuracy),
                         # metric value (fixed_ssim / fixed_correlation / fixed_ks)
    est_psnr: float      # estimated/measured PSNR of the chosen codec
    est_bitrate: float   # estimated bits/value of the chosen codec
    on_target: bool      # False when the solve could only get best-effort close
    #: predicted metric value of the chosen codec (§7.4 metric modes only;
    #: None elsewhere — the default keeps pre-metric cache entries and
    #: manifests deserializing unchanged)
    est_metric: float | None = None

    @property
    def est_ratio(self) -> float:
        return RAW_BITS / max(self.est_bitrate, 1e-6)


def _sz_coder_rate(br_est: np.ndarray) -> np.ndarray:
    """Map the §4 SZ estimate (entropy + flat +0.5 cushion) to the rate the
    byte coder actually pays: entropy + an overhead that decays to
    `SZ_HUFF_FLOOR` for rich residual PDFs and grows to the 1-bit/symbol
    Huffman floor as the PDF peaks. Monotone in `br_est` (slope >= 0.15),
    so the root-finding invariant survives the correction."""
    ent = np.maximum(np.asarray(br_est, np.float64) - est.SZ_BITRATE_OFFSET, 0.0)
    return ent + np.maximum(1.0 - SZ_HUFF_PEAK_SLOPE * ent, SZ_HUFF_FLOOR)


# ---------------------------------------------------------------------------
# The sweep: batched estimators + a vmapped candidate axis
# ---------------------------------------------------------------------------


def _sz_measured_psnr(nohalo, seg, bounds, delta_f, vr_f):
    """PSNR of the actual quantization error `x - delta*round(x/delta)` on
    the sampled blocks — what the SZ codec really achieves, including the
    sub-uniform error of fields with constant runs (values sitting exactly
    on bin centers), which the Eq. (11) model misses by up to ~3 dB."""
    nd = nohalo.ndim - 1
    n_s = nohalo.shape[0]
    d = delta_f[seg].reshape((-1,) + (1,) * nd)
    err = nohalo - d * jnp.round(nohalo / d)
    vr64 = jnp.maximum(vr_f, 1e-30)
    err2_blk = jnp.sum(jnp.square(err).reshape(n_s, -1), axis=1) / jnp.square(
        vr64[seg]
    )
    err2_f = est.field_sums(err2_blk, bounds)
    n_f = (bounds[1:] - bounds[:-1]).astype(jnp.float32) * float(4**nd)
    mse_over_vr2 = err2_f / jnp.maximum(n_f, 1.0)
    return -10.0 * jnp.log10(jnp.maximum(mse_over_vr2, 1e-60))


@_lru_cache(maxsize=64)
def _sweep_jitted(
    nd: int, n_blocks: int, n_fields: int, n_cand: int, transform: str, kind: str
):
    """Jitted (candidates x fields) estimator sweep over one packed batch.

    vmap adds the candidate axis to the per-field bound arrays only — the
    block batch is closed over, so XLA hoists the bound-independent work
    (gather view, exponents, BOT coefficients) out of the candidate loop
    instead of materializing `n_cand` copies of the blocks. kind='light'
    returns only the PSNR outputs, and XLA dead-code-eliminates the
    exact-coder bit count and the SZ entropy sort — the expensive
    stages — making fixed_psnr refinement rounds cheap; kind='rate' swaps
    the 31-plane exact ZFP coder for the one-pass closed-form block_bits
    model (fixed_ratio refinement probes); kind='full' is decision-grade.
    Cached per (ndim, padded blocks, padded fields, candidates, kind),
    same pow2 bucketing as `select_many` (DESIGN.md §1).
    """

    def eval_one(eb_f, delta_f, halo, seg, bounds, vr_f, size_f):
        # ZFP at eb_f and SZ at delta_f are independent estimators on the
        # same blocks; one slot evaluates both (DESIGN.md §4–§5)
        nohalo = halo[(slice(None),) + (slice(1, None),) * nd]
        zfp_mode = "model" if kind == "rate" else "exact"
        e_zfp = est.estimate_zfp_many(
            nohalo, seg, bounds, eb_f, vr_f, transform, mode=zfp_mode
        )
        ps_meas = _sz_measured_psnr(nohalo, seg, bounds, delta_f, vr_f)
        if kind == "light":
            return e_zfp.psnr, ps_meas
        e_sz = est.estimate_sz_many(halo, seg, bounds, delta_f, vr_f, size_f)
        return e_sz.bitrate, e_sz.psnr, e_zfp.bitrate, e_zfp.psnr, ps_meas

    def f(halo, seg, bounds, eb_cf, delta_cf, vr_f, size_f):
        return jax.vmap(eval_one, in_axes=(0, 0, None, None, None, None, None))(
            eb_cf, delta_cf, halo, seg, bounds, vr_f, size_f
        )

    return jax.jit(f)


@dataclass
class _Member:
    idx: int             # position in the caller's field list
    blocks: np.ndarray   # halo blocks, (n_blocks, 5, ..)
    vr: float
    size: int


class _Sweep:
    """One packed batch (same layout as `selector._select_batch`) exposing
    `full` / `light` candidate sweeps. Inputs are (n_cand, n_real_fields)
    per-field bounds (eb for ZFP, bin size delta for SZ); outputs are
    (n_cand, n_real_fields) arrays."""

    def __init__(self, nd: int, members: list[_Member], transform: str):
        self.nd, self.transform = nd, transform
        halo = np.concatenate([m.blocks for m in members], axis=0)
        seg = np.concatenate(
            [np.full(len(m.blocks), f, dtype=np.int32) for f, m in enumerate(members)]
        )
        n_real_blocks, self.n_real_fields = len(seg), len(members)
        self.n_blocks = _next_pow2(n_real_blocks)
        self.n_fields = _next_pow2(self.n_real_fields + 1)
        pad = self.n_blocks - n_real_blocks
        if pad:
            halo = np.concatenate([halo, np.zeros((pad,) + halo.shape[1:], np.float32)])
            seg = np.concatenate([seg, np.full(pad, self.n_fields - 1, np.int32)])
        bounds = np.zeros(self.n_fields + 1, np.int32)
        bounds[1 : self.n_real_fields + 1] = np.cumsum([len(m.blocks) for m in members])
        bounds[self.n_real_fields + 1 :] = n_real_blocks
        bounds[self.n_fields] = self.n_blocks
        vr_p = np.ones(self.n_fields, np.float32)
        vr_p[: self.n_real_fields] = [m.vr for m in members]
        size_p = np.ones(self.n_fields, np.float32)
        size_p[: self.n_real_fields] = [m.size for m in members]
        self._args = (
            jnp.asarray(halo), jnp.asarray(seg), jnp.asarray(bounds),
            jnp.asarray(vr_p), jnp.asarray(size_p),
        )

    def _run(self, eb_c, delta_c, kind: str):
        n_cand = eb_c.shape[0]
        ebp = np.ones((n_cand, self.n_fields), np.float32)
        ebp[:, : self.n_real_fields] = np.maximum(eb_c, 1e-38)
        dp = np.ones((n_cand, self.n_fields), np.float32)
        dp[:, : self.n_real_fields] = np.maximum(delta_c, 1e-38)
        halo, seg, bounds, vr, size = self._args
        fn = _sweep_jitted(
            self.nd, self.n_blocks, self.n_fields, n_cand, self.transform, kind
        )
        out = fn(halo, seg, bounds, jnp.asarray(ebp), jnp.asarray(dp), vr, size)
        return tuple(np.asarray(o)[:, : self.n_real_fields] for o in out)

    def full(self, eb_c, delta_c):
        """(br_sz, psnr_sz_model, br_zfp, psnr_zfp, psnr_sz_measured)."""
        return self._run(eb_c, delta_c, "full")

    def rate(self, eb_c, delta_c):
        """Same 5-tuple with the one-pass block_bits ZFP coder model —
        probe-grade rates for the fixed_ratio refinement rounds."""
        return self._run(eb_c, delta_c, "rate")

    def light(self, eb_c, delta_c):
        """(psnr_zfp, psnr_sz_measured) only — coder bits / entropy DCE'd."""
        return self._run(eb_c, delta_c, "light")


# ---------------------------------------------------------------------------
# Vectorized secant root-finding on a nonincreasing sampled curve
# ---------------------------------------------------------------------------


class _Secant:
    """Per-field secant iteration for `g(x) = target` where g is
    nonincreasing in x (= log2 bound) and only eval-able in batches.

    Tracks the best *feasible* probe (g clears the target: `g >= target`
    for PSNR, `g <= target` for rate — pass `ge=False`) closest to the
    target, plus a bracket for safeguarding; steps are clamped to the
    model slope range so a flat staircase section cannot fling the
    iterate."""

    def __init__(self, x0, g0, target, slope0, slope_clamp, ge: bool, x_lo, x_hi):
        F = len(x0)
        self.t, self.ge = np.asarray(target, np.float64), ge
        self.slope0, self.clamp = slope0, slope_clamp
        self.x_lo, self.x_hi = x_lo, x_hi
        self.xp = np.full(F, np.nan)
        self.gp = np.full(F, np.nan)
        self.xc, self.gc = np.asarray(x0, np.float64), np.asarray(g0, np.float64)
        # bracket: blo = largest x still clearing, bhi = smallest x missing
        self.blo = np.full(F, -np.inf)
        self.bhi = np.full(F, np.inf)
        self.x_best = np.full(F, np.nan)
        self.g_best = np.full(F, np.nan)
        self._absorb(self.xc, self.gc)

    def _clears(self, g):
        if self.ge:
            return g >= self.t - PSNR_SLACK_DB
        return g <= self.t * (1.0 + RATE_SLACK)

    def _absorb(self, x, g):
        ok = self._clears(g)
        # bracket sides follow g's direction, not feasibility: g is
        # nonincreasing in x, so probes with g above the target sit below
        # the root (-> blo) and probes below it sit above (-> bhi)
        above = ok if self.ge else ~ok
        self.blo = np.where(above, np.maximum(self.blo, x), self.blo)
        self.bhi = np.where(~above, np.minimum(self.bhi, x), self.bhi)
        # feasible-best: the clearing probe closest to the target
        gap = np.abs(g - self.t)
        better = ok & (np.isnan(self.g_best) | (gap < np.abs(self.g_best - self.t)))
        self.x_best = np.where(better, x, self.x_best)
        self.g_best = np.where(better, g, self.g_best)

    def propose(self):
        dx = self.xc - self.xp
        dg = self.gc - self.gp
        slope = np.where(np.abs(dx) > 1e-9, dg / np.maximum(np.abs(dx), 1e-9) * np.sign(dx), self.slope0)
        slope = np.clip(np.nan_to_num(slope, nan=self.slope0), *self.clamp)
        xn = self.xc + (self.t - self.gc) / slope
        # safeguard: project into the bracket when the secant leaves it
        have = np.isfinite(self.blo) & np.isfinite(self.bhi)
        mid = 0.5 * (self.blo + self.bhi)
        xn = np.where(have & ((xn <= self.blo) | (xn >= self.bhi)), mid, xn)
        return np.clip(xn, self.x_lo, self.x_hi)

    def step(self, xn, gn):
        self.xp, self.gp = self.xc, self.gc
        self.xc, self.gc = np.asarray(xn, np.float64), np.asarray(gn, np.float64)
        self._absorb(self.xc, self.gc)

    @property
    def found(self):
        return ~np.isnan(self.x_best)


# ---------------------------------------------------------------------------
# Mode solvers (vectorized across the fields of one batch)
# ---------------------------------------------------------------------------


#: refinement probes run on every k-th gathered block (the secant only
#: needs the curve's trend; the final pricing eval uses the full sample)
REFINE_STRIDE = 2


def _warm_seeds(warm, x0_s, x0_z, x_lo, x_hi):
    """Overlay cached warm-start seeds (log2 bounds, NaN = cold) onto the
    model seeds, clipped to the solver's x-range. With `warm=None` or
    all-NaN this returns the model seeds unchanged, so the cold program
    is untouched."""
    if warm is None:
        return x0_s, x0_z
    warm_s, warm_z = warm
    x0_s = np.where(
        np.isfinite(warm_s), np.clip(warm_s, x_lo, x_hi), x0_s
    )
    x0_z = np.where(
        np.isfinite(warm_z), np.clip(warm_z, x_lo, x_hi), x0_z
    )
    return x0_s, x0_z


def _solve_fixed_psnr(
    sweep: _Sweep, refine: _Sweep, vr: np.ndarray, target, rounds: int,
    r_sp: float, allowed: tuple[str, ...] = _codecs.DEFAULT_CODECS,
    warm=None,
) -> list[tuple[Selection, float, float, bool]]:
    """Per field: (Selection, est_psnr, est_bitrate, on_target).

    Seed: SZ bin size from the closed-form inversion of Eq. (10); ZFP
    bound at delta*/2 — or, per field, the previous save's solved bound
    when the decision cache offers a warm seed (`warm`, DESIGN.md §8):
    the secant then starts next to the root it found last step instead of
    on the model curve. Refine: `rounds` light-sweep secant steps drive
    both codecs' *observed* curves (measured quantization error for SZ,
    estimated truncation PSNR for ZFP) onto the target; one final full
    eval prices the two solutions for the min-rate choice.

    `target` is a scalar dB value, or a per-field (F,) array — the §7.4
    metric modes feed per-field equivalent-PSNR targets through the same
    solve (the secant, snap and eligibility tests are all elementwise, so
    the scalar path's numerics are untouched).
    """
    tq = (
        np.round(np.asarray(target, np.float64) / est.PSNR_MATCH_QUANTUM)
        * est.PSNR_MATCH_QUANTUM
    )
    delta_star = np.asarray(
        est.sz_delta_for_psnr(
            jnp.asarray(target, jnp.float32), jnp.asarray(vr, np.float32)
        ),
        np.float32,
    )
    lvr = np.log2(np.maximum(vr, 1e-30)).astype(np.float64)
    ld0 = np.log2(np.maximum(delta_star, 1e-38)).astype(np.float64)
    x0_s, x0_z = _warm_seeds(warm, ld0, ld0 - 1.0, lvr - 30.0, lvr + 1.0)
    pz0, ps0 = refine.light(np.exp2(x0_z)[None].astype(np.float32),
                            np.exp2(x0_s)[None].astype(np.float32))
    s_sz = _Secant(x0_s, ps0[0], tq, -DB_PER_OCTAVE, PSNR_SLOPE_CLAMP,
                   ge=True, x_lo=lvr - 30.0, x_hi=lvr + 1.0)
    s_z = _Secant(x0_z, pz0[0], tq, -DB_PER_OCTAVE, PSNR_SLOPE_CLAMP,
                  ge=True, x_lo=lvr - 30.0, x_hi=lvr + 1.0)
    for _ in range(rounds):
        xs, xz = s_sz.propose(), s_z.propose()
        pz, ps = refine.light(np.exp2(xz)[None].astype(np.float32),
                              np.exp2(xs)[None].astype(np.float32))
        s_z.step(xz, pz[0])
        s_sz.step(xs, ps[0])
    # final bounds: feasible-best, falling back to the seed (the
    # closed-form, model-exact bin for SZ absent a warm override)
    x_s = np.where(s_sz.found, s_sz.x_best, x0_s)
    x_z = np.where(s_z.found, s_z.x_best, x0_z)
    br_sz_raw, _, br_zfp, ps_zfp, ps_meas = sweep.full(
        np.exp2(x_z)[None].astype(np.float32), np.exp2(x_s)[None].astype(np.float32)
    )
    br_s = _sz_coder_rate(br_sz_raw[0])
    br_z, ps_z, ps_s = br_zfp[0], ps_zfp[0], ps_meas[0]
    zfp_ok = s_z.found & (ps_z <= tq + PSNR_TOL_DB) & (ps_z >= tq - PSNR_SLACK_DB)
    out = []
    F = len(vr)
    tq_f = np.broadcast_to(np.asarray(tq, np.float64), (F,))
    for f in range(F):
        tqf = float(tq_f[f])
        eb_s = float(np.exp2(x_s[f])) / 2.0
        cands = []
        if "sz" in allowed:
            cands.append(("sz", float(br_s[f]), float(ps_s[f]), eb_s))
        if zfp_ok[f] and "zfp" in allowed:
            cands.append(("zfp", float(br_z[f]), float(ps_z[f]), float(np.exp2(x_z[f]))))
        if not cands:
            # allowlist left only ZFP and its staircase missed the band:
            # best-effort on its solved bound (flagged off-target below)
            cands = [("zfp", float(br_z[f]), float(ps_z[f]), float(np.exp2(x_z[f])))]
        codec, br, ps, eb = min(cands, key=lambda c: c[1])
        if br >= RAW_BITS:
            # incompressible at this quality — raw is exact, PSNR = inf
            codec, br, ps = "raw", RAW_BITS, math.inf
        # raw is lossless (target exceeded by construction); a lossy codec
        # is on-target only when it actually landed within the contract
        on_target = codec == "raw" or abs(ps - tqf) <= 2.0 * PSNR_TOL_DB
        sel = Selection(
            codec, eb, eb_s, float(br_s[f]), float(br_z[f]),
            ps if codec != "raw" else tqf, float(vr[f]), r_sp,
        )
        out.append((sel, ps, br, on_target))
    return out


def _solve_fixed_ratio(
    sweep: _Sweep, refine: _Sweep, vr: np.ndarray, target: float, rounds: int,
    r_sp: float, allowed: tuple[str, ...] = _codecs.DEFAULT_CODECS,
    warm=None,
) -> list[tuple[Selection, float, float, bool]]:
    """Per field: (Selection, est_psnr, est_bitrate, on_target).

    Both codecs are driven to `rate <= RAW_BITS/target` (maximum quality
    inside the byte budget) from a mid-curve seed via the ~1 bit/octave
    high-rate model plus clamped secant steps; the higher-PSNR codec at
    the budget wins — iso-rate selection, the dual of Algorithm 1. SZ's
    entropy curve is continuous in the bin size, so it can land inside
    the ratio window even where ZFP's bit-plane staircase skips it.
    """
    br_t = RAW_BITS / float(target)
    lvr = np.log2(np.maximum(vr, 1e-30)).astype(np.float64)
    x0 = lvr - 8.0
    x0_s, x0_z = _warm_seeds(warm, x0, x0, lvr - 26.0, lvr)
    br_s0, _, br_z0, _, _ = refine.rate(
        np.exp2(x0_z)[None].astype(np.float32),
        np.exp2(x0_s)[None].astype(np.float32),
    )
    s_sz = _Secant(x0_s, _sz_coder_rate(br_s0[0]), br_t, -1.0, RATE_SLOPE_CLAMP,
                   ge=False, x_lo=lvr - 26.0, x_hi=lvr)
    s_z = _Secant(x0_z, br_z0[0], br_t, -1.0, RATE_SLOPE_CLAMP,
                  ge=False, x_lo=lvr - 26.0, x_hi=lvr)
    for _ in range(rounds):
        xs, xz = s_sz.propose(), s_z.propose()
        br_s, _, br_z, _, _ = refine.rate(np.exp2(xz)[None].astype(np.float32),
                                          np.exp2(xs)[None].astype(np.float32))
        s_sz.step(xs, _sz_coder_rate(br_s[0]))
        s_z.step(xz, br_z[0])
    # final bounds: feasible-best; an unreachable budget rails at the
    # loosest bound evaluated (best effort, flagged off-target below).
    # fmax, not maximum: with rounds=0 no secant step ran and xp is NaN
    x_s = np.where(s_sz.found, s_sz.x_best, np.fmax(s_sz.xc, s_sz.xp))
    x_z = np.where(s_z.found, s_z.x_best, np.fmax(s_z.xc, s_z.xp))

    def _price(xs, xz):
        br_sz_raw, _, br_zfp, ps_zfp, ps_meas = sweep.full(
            np.exp2(xz)[None].astype(np.float32), np.exp2(xs)[None].astype(np.float32)
        )
        return _sz_coder_rate(br_sz_raw[0]), br_zfp[0], ps_zfp[0], ps_meas[0]

    br_s, br_z, ps_z, ps_s = _price(x_s, x_z)
    # polish: the strided refine probes can sit a few % off the
    # full-sample curve; up to two corrective steps against the
    # full-sample price recenter fields that landed outside the rate
    # window (the first uses the ~1 bit/octave model slope, the second an
    # empirical slope from the first correction)
    lo_w, hi_w = br_t / (1.0 + RATIO_TOL), br_t * (1.0 + RATE_SLACK)
    prev = None
    for _ in range(2):
        # no `found` gate: a field whose refine probes never cleared the
        # budget (strided-sample bias, unreachable target) still gets
        # walked toward it; the x-clip bounds genuinely unreachable ones
        need_s = (br_s > hi_w) | (br_s < lo_w)
        need_z = (br_z > hi_w) | (br_z < lo_w)
        if not (need_s.any() or need_z.any()):
            break
        slope_s = np.full_like(br_s, -1.0)
        slope_z = np.full_like(br_z, -1.0)
        if prev is not None:
            px_s, pbr_s, px_z, pbr_z = prev
            ds, dz = x_s - px_s, x_z - px_z
            slope_s = np.where(np.abs(ds) > 1e-9, (br_s - pbr_s) / np.where(np.abs(ds) > 1e-9, ds, 1.0), -1.0)
            slope_z = np.where(np.abs(dz) > 1e-9, (br_z - pbr_z) / np.where(np.abs(dz) > 1e-9, dz, 1.0), -1.0)
            slope_s = np.clip(slope_s, -4.0, -0.1)
            slope_z = np.clip(slope_z, -4.0, -0.1)
        prev = (x_s.copy(), br_s.copy(), x_z.copy(), br_z.copy())
        x_s = np.clip(np.where(need_s, x_s + (br_t - br_s) / slope_s, x_s), lvr - 26.0, lvr)
        x_z = np.clip(np.where(need_z, x_z + (br_t - br_z) / slope_z, x_z), lvr - 26.0, lvr)
        br_s, br_z, ps_z, ps_s = _price(x_s, x_z)
    out = []
    for f in range(len(vr)):
        cands = []
        for name, br, ps, bound in (
            ("sz", float(br_s[f]), float(ps_s[f]), float(np.exp2(x_s[f])) / 2.0),
            ("zfp", float(br_z[f]), float(ps_z[f]), float(np.exp2(x_z[f]))),
        ):
            if name not in allowed:
                continue
            in_window = (br <= br_t * (1.0 + RATE_SLACK)) and (
                br >= br_t / (1.0 + RATIO_TOL)
            )
            cands.append((name, br, ps, bound, in_window))
        eligible = [c for c in cands if c[4]]
        if eligible:
            codec, br, ps, bound, _ = max(eligible, key=lambda c: c[2])
            on_target = True
        else:
            # best effort: closest estimated rate to the budget
            codec, br, ps, bound, _ = min(
                cands, key=lambda c: abs(math.log(max(c[1], 1e-6) / br_t))
            )
            on_target = False
        if br >= RAW_BITS:
            codec, br, ps = "raw", RAW_BITS, math.inf
            on_target = target <= 1.0 + 1e-9
        eb_s = float(np.exp2(x_s[f])) / 2.0
        sel = Selection(
            codec, bound if codec == "zfp" else eb_s, eb_s,
            float(br_s[f]), float(br_z[f]),
            ps if codec != "raw" else 0.0, float(vr[f]), r_sp,
        )
        out.append((sel, ps, br, on_target))
    return out


def _solve_fixed_metric(
    sweep: _Sweep, refine: _Sweep, batch: list[_Member], nd: int,
    vr: np.ndarray, mode: str, target: float, rounds: int, r_sp: float,
    allowed: tuple[str, ...] = _codecs.DEFAULT_CODECS, warm=None,
) -> list[tuple[Selection, float, float, bool, float]]:
    """Per field: (Selection, est_psnr, est_bitrate, on_target, est_metric)
    for the §7.4 metric modes (fixed_ssim / fixed_correlation / fixed_ks).

    Every supported metric is a monotone function of the error variance
    given the field's sampled statistics (`core/quality.py`), so the solve
    is: (1) compute per-field metric sufficient statistics from the same
    halo blocks the rate estimators use; (2) invert the metric target into
    a per-field equivalent-PSNR target — closed form for SSIM/correlation,
    an interpolation on the sample-measured KS curve for fixed_ks; (3) run
    the fixed-PSNR solve on the per-field target array (closed-form seed,
    clamped light-sweep secant, min-rate codec choice *at the metric
    target* — Algorithm 1's rule anchored on the caller's contract instead
    of at matched eb); (4) read the achieved metric back off the solved
    PSNR for telemetry and the on-target check. Zero trial compressions,
    same launch profile as fixed_psnr.
    """
    metric = qual.MODE_METRIC[mode]
    stats = [qual.stats_from_blocks(m.blocks, nd, m.vr) for m in batch]
    psnr_t = np.asarray(
        [qual.equivalent_psnr(metric, target, s) for s in stats], np.float64
    )
    solved = _solve_fixed_psnr(
        sweep, refine, vr, psnr_t, rounds, r_sp, allowed, warm=warm
    )
    tol = qual.TOLERANCE[metric]
    out = []
    for f, (sel, ps, br, _on) in enumerate(solved):
        if sel.codec == "raw":
            m_a = qual.LOSSLESS_VALUE[metric]
            on = True
        else:
            m_a = qual.metric_from_psnr(metric, ps, stats[f])
            # SSIM/correlation are floors (overshoot is free quality), KS a
            # ceiling; within-tolerance misses still count as on target
            on = qual.metric_gap(metric, m_a, float(target)) <= tol
        out.append((sel, ps, br, on, float(m_a)))
    return out


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def solve_many(
    fields,
    policy: Policy | str,
    *,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float | None = None,
    transform: str = "zfp",
    rounds: int | None = None,
    cache=None,
    names=None,
) -> list[TargetSolution]:
    """Solve the quality target for MANY fields with batched launches.

    `policy` is the quality contract (`core/policy.py`, DESIGN.md §2):

    * `Policy.fixed_psnr(db)`   — target dB, relative to each field's
                                  value range (as everywhere else);
    * `Policy.fixed_ratio(x)`   — x vs 32-bit raw;
    * `Policy.fixed_ssim(s)` / `Policy.fixed_correlation(rho)` /
      `Policy.fixed_ks(d)`      — §7.4 metric targets, inverted to
                                  per-field equivalent-PSNR targets via
                                  `core/quality.py` (solutions carry the
                                  predicted metric in `est_metric`);
    * `Policy.fixed_accuracy(...)` — delegates to `select_many` (the
                                  paper's bound-centric path) so all the
                                  modes share one entry point.

    The policy's `codecs` allowlist restricts which registered codecs
    compete (DESIGN.md §2.1); its `r_sp` sets the estimator sampling rate.
    Passing a mode *string* plus the old target/eb/r_sp keyword arguments
    is deprecated — the shim maps them onto the equivalent `Policy` (and
    therefore solves bit-identically) but warns.

    Fields that cannot carry a target — too small, constant, NaN-poisoned —
    fall back to raw exactly like `select_many` (`on_target=False` for
    fixed_ratio, since raw pins their ratio to 1). Fields whose sample
    would exceed a launch's block cap are strided down instead of being
    kicked to a per-field path, so every field stays inside the batched
    sweep. Returns one `TargetSolution` per input field, in order.

    `cache`/`names` enable the warm path (a `DecisionCache`, DESIGN.md
    §8): fingerprint-validated fields replay the previous save's
    `TargetSolution` without entering the sweep at all; invalidated
    entries can additionally warm-start the secant from the previously
    solved bound when the cache has `warm_start=True`.
    """
    if isinstance(policy, str):
        policy = policy_from_kwargs(
            "solve_many", mode=policy, eb_abs=eb_abs, eb_rel=eb_rel,
            target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp,
        )
    elif not isinstance(policy, Policy):
        raise TypeError(f"expected a Policy (or legacy mode str), got {policy!r}")
    elif any(v is not None for v in (target_psnr, target_ratio, eb_abs, eb_rel, r_sp)):
        raise ValueError("pass either policy= or the legacy target kwargs, not both")
    fields = list(fields)
    mode = policy.mode
    if mode == "raw":
        raise ValueError("solve_many has nothing to solve for Policy.raw()")
    if mode == "fixed_accuracy":
        sels = select_many(
            fields, policy=policy, transform=transform, cache=cache, names=names
        )
        # raw stores are lossless at exactly 32 b/v, whatever the estimates
        # said — keep the telemetry consistent with the target modes
        return [
            TargetSolution(
                s, mode, s.eb_abs,
                math.inf if s.codec == "raw" else s.psnr_target,
                RAW_BITS if s.codec == "raw" else min(s.br_sz, s.br_zfp),
                True,
            )
            for s in sels
        ]
    attr = TARGET_FIELD.get(mode)
    if attr is None:  # a future Policy mode this controller predates
        raise ValueError(
            f"solve_many cannot solve mode {mode!r}; supported target "
            f"modes: {', '.join(TARGET_FIELD)}"
        )
    target = float(getattr(policy, attr))
    n_rounds = DEFAULT_ROUNDS[mode] if rounds is None else rounds

    results: list[TargetSolution | None] = [None] * len(fields)
    groups = _build_solve_members(
        fields, range(len(fields)), results, mode, target, policy.r_sp
    )
    if cache is None:
        _solve_groups(
            groups, results, mode, target, n_rounds, policy.r_sp, transform,
            policy.codecs,
        )
        return results  # type: ignore[return-value]
    _solve_many_cached(
        fields, names, results, groups, cache, policy, mode, target, n_rounds,
        transform,
    )
    return results  # type: ignore[return-value]


def _solve_many_cached(
    fields,
    names,
    results: list[TargetSolution | None],
    groups: dict[int, list[_Member]],
    cache,
    policy: Policy,
    mode: str,
    target: float,
    n_rounds: int,
    transform: str,
) -> None:
    """Warm half of `solve_many`'s target modes (DESIGN.md §8), mirroring
    `selector._select_many_cached`: fingerprint each member against the
    cache, replay validated `TargetSolution`s, sweep only the misses.
    Misses whose entry merely drifted (key match, fingerprint mismatch)
    seed the secant from the previously solved bound when the cache opts
    into `warm_start` — the solution moved a little, so the old root is a
    better starting bracket than the model curve."""
    from . import predictor as _pred

    if names is None:
        raise ValueError("solve_many(cache=...) requires names=")
    names = list(names)
    if len(names) != len(fields):
        raise ValueError(
            f"names/fields length mismatch: {len(names)} vs {len(fields)}"
        )
    miss_groups: dict[int, list[_Member]] = {}
    warm: dict[int, tuple[float, float]] = {}
    to_store: list[tuple[int, str, tuple, str, dict]] = []
    for nd, members in groups.items():
        tuples = [(m.idx, m.blocks, 0.0, m.vr, m.size) for m in members]
        stats = _pred.stats_for_members(nd, tuples, policy.r_sp)
        for m, (_stats, fp) in zip(members, stats):
            i = m.idx
            x = fields[i]
            shape = tuple(np.shape(x))
            dtype = str(getattr(x, "dtype", np.asarray(x).dtype))
            entry = cache.lookup(names[i], shape, dtype, policy, transform, fp)
            if entry is not None and entry.solution is not None:
                results[i] = entry.to_solution()
                continue
            miss_groups.setdefault(nd, []).append(m)
            to_store.append((i, names[i], shape, dtype, fp))
            if cache.warm_start:
                prev = cache.stale(names[i], shape, dtype, policy, transform)
                if prev is not None and prev.solution is not None:
                    sel = prev.to_selection()
                    if sel.codec != "raw" and sel.eb_sz > 0:
                        x_s = math.log2(2.0 * sel.eb_sz)
                        x_z = (
                            math.log2(sel.eb_abs)
                            if sel.codec == "zfp" and sel.eb_abs > 0
                            else x_s - 1.0
                        )
                        warm[i] = (x_s, x_z)
    if miss_groups:
        _solve_groups(
            miss_groups, results, mode, target, n_rounds, policy.r_sp,
            transform, policy.codecs, warm=warm or None,
        )
    for i, name, shape, dtype, fp in to_store:
        sol = results[i]
        cache.store(
            name, shape, dtype, policy, transform, fp, sol.selection,
            solution=sol,
        )


def _build_solve_members(
    fields,
    indices,
    results: list[TargetSolution | None],
    mode: str,
    target: float,
    r_sp: float,
) -> dict[int, list[_Member]]:
    """Gather-side half of `solve_many`: fold + degenerate raw fallback
    (written straight into `results`) + monster-field sample stride-down,
    returning batchable members as nd -> [_Member]. Split out so the
    shard-local engine (DESIGN.md §6) can merge device-gathered members
    into the same batches as host-gathered ones — identical batch
    composition, hence bit-identical target solves on mixed pytrees."""
    groups: dict[int, list[_Member]] = {}
    for i, x in zip(indices, fields):
        arr = np.asarray(x, dtype=np.float32)
        view = _fold_ndim(arr)
        vr = float(np.max(view) - np.min(view)) if view.size else 0.0
        sel0 = _degenerate_selection(view, vr, None, None, r_sp)
        if sel0 is not None:
            # raw is lossless, so every quality-floor contract (PSNR and
            # the §7.4 metrics) is met by construction; only a *rate*
            # budget is genuinely missed (raw pins the ratio to 1)
            on = mode != "fixed_ratio"
            results[i] = TargetSolution(
                sel0, mode, target, math.inf, RAW_BITS, on,
                est_metric=qual.lossless_metric(mode),
            )
            continue
        starts = est.block_starts(view.shape, r_sp)
        cap = _max_batch_blocks(view.ndim)
        if len(starts) > cap:
            # monster field: stride the sample grid down to the launch cap
            # (lower effective r_sp) so it still rides the batched sweep
            starts = starts[:: -(-len(starts) // cap)]
        groups.setdefault(view.ndim, []).append(
            _Member(i, est.gather_blocks_np(view, starts, halo=True), vr, view.size)
        )
    return groups


def _solve_groups(
    groups: dict[int, list[_Member]],
    results: list[TargetSolution | None],
    mode: str,
    target: float,
    n_rounds: int,
    r_sp: float,
    transform: str,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
    warm: dict[int, tuple[float, float]] | None = None,
) -> None:
    """Drive the per-batch target solvers over pre-gathered `_Member`s.
    Shared by `solve_many` (host-gathered samples) and the shard-local
    engine (device-gathered samples, DESIGN.md §6): the solvers see the
    identical packed batches either way, so sharded target-mode decisions
    are bit-identical to the unsharded path by construction.

    `warm` maps a member index to cached (log2 SZ bin, log2 ZFP bound)
    secant seeds from an invalidated decision-cache entry (DESIGN.md §8);
    unmapped members keep the cold model seeds."""
    for nd, members in groups.items():
        cap = _max_batch_blocks(nd)
        lo = 0
        while lo < len(members):
            hi, blocks = lo, 0
            while hi < len(members) and (
                hi == lo
                or (
                    blocks + len(members[hi].blocks) <= cap
                    and hi - lo < MAX_BATCH_FIELDS
                )
            ):
                blocks += len(members[hi].blocks)
                hi += 1
            batch = members[lo:hi]
            sweep = _Sweep(nd, batch, transform)
            # refinement probes run on a strided sub-sample of the blocks
            # already in hand — the secant needs trends, not decision-grade
            # estimates; the final pricing eval uses the full sample
            refine = _Sweep(
                nd,
                [
                    _Member(m.idx, m.blocks[::REFINE_STRIDE], m.vr, m.size)
                    for m in batch
                ],
                transform,
            )
            vr_arr = np.asarray([m.vr for m in batch], np.float32)
            warm_batch = None
            if warm:
                warm_s = np.full(len(batch), np.nan)
                warm_z = np.full(len(batch), np.nan)
                for f, m in enumerate(batch):
                    if m.idx in warm:
                        warm_s[f], warm_z[f] = warm[m.idx]
                if np.isfinite(warm_s).any() or np.isfinite(warm_z).any():
                    warm_batch = (warm_s, warm_z)
            if mode in qual.MODE_METRIC:
                solved_m = _solve_fixed_metric(
                    sweep, refine, batch, nd, vr_arr, mode, target, n_rounds,
                    r_sp, codecs, warm=warm_batch,
                )
                for m, (sel, ps, br, on, met) in zip(batch, solved_m):
                    results[m.idx] = TargetSolution(
                        sel, mode, target, ps, br, on, est_metric=met
                    )
            else:
                solver = (
                    _solve_fixed_psnr if mode == "fixed_psnr" else _solve_fixed_ratio
                )
                solved = solver(
                    sweep, refine, vr_arr, target, n_rounds, r_sp, codecs,
                    warm=warm_batch,
                )
                for m, (sel, ps, br, on) in zip(batch, solved):
                    results[m.idx] = TargetSolution(sel, mode, target, ps, br, on)
            lo = hi


def solve(x, policy: Policy | str, **kw) -> TargetSolution:
    """Single-field convenience wrapper over `solve_many`."""
    return solve_many([x], policy, **kw)[0]


def estimate_curves(
    x,
    bounds,
    r_sp: float = est.DEFAULT_SAMPLING_RATE,
    transform: str = "zfp",
) -> dict[str, np.ndarray]:
    """Evaluate both estimated rate-distortion curves of one field at an
    array of bounds, in one vmapped launch (the controller's objective,
    exposed for benchmarks/tests — e.g. the monotonicity invariant the
    secant/bracket search relies on). `bounds[c]` is used as ZFP's error
    bound AND as SZ's bin size delta for candidate c. Returns arrays of
    len(bounds): ``br_sz``, ``psnr_sz``, ``br_zfp``, ``psnr_zfp``, and
    ``psnr_sz_measured`` (the sampled quantization-error PSNR the
    fixed_psnr refinement targets).
    """
    view = _fold_ndim(np.asarray(x, dtype=np.float32))
    vr = float(np.max(view) - np.min(view)) if view.size else 0.0
    if _degenerate_selection(view, vr, None, None, r_sp) is not None:
        raise ValueError("degenerate field has no estimator curve")
    starts = est.block_starts(view.shape, r_sp)
    member = _Member(0, est.gather_blocks_np(view, starts, halo=True), vr, view.size)
    sweep = _Sweep(view.ndim, [member], transform)
    b = np.asarray(bounds, np.float32).reshape(-1, 1)
    br_sz, psnr_sz, br_zfp, psnr_zfp, psnr_meas = sweep.full(b, b)
    return dict(
        br_sz=br_sz[:, 0], psnr_sz=psnr_sz[:, 0],
        br_zfp=br_zfp[:, 0], psnr_zfp=psnr_zfp[:, 0],
        psnr_sz_measured=psnr_meas[:, 0],
    )
