"""Public compression API: fields and pytrees (DESIGN.md §2).

A "field" (paper's unit of selection — one simulation variable) maps to one
named tensor. `compress_pytree` runs Algorithm 1 per leaf and returns the
compressed fields + the selection-bit stream, exactly the paper's
{C_i, s_i} output.
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from .selector import (
    CompressedField,
    compression_ratio,
    decompress,
    encode_with_selection,
    select_and_compress,
    select_many,
)


@dataclass
class CompressedTree:
    fields: dict[str, CompressedField]
    treedef: Any

    @property
    def selection_bits(self) -> dict[str, str]:
        return {k: v.codec for k, v in self.fields.items()}

    @property
    def nbytes(self) -> int:
        return sum(len(v.data) for v in self.fields.values())

    @property
    def raw_nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * 4 for v in self.fields.values())

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(self.nbytes, 1)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _default_workers() -> int:
    return max(1, min(8, (os.cpu_count() or 2) - 1))


def compress_pytree(
    tree: Any,
    eb_rel: float = 1e-4,
    eb_abs: float | None = None,
    r_sp: float = 0.05,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    workers: int | None = None,
) -> CompressedTree:
    """Run Algorithm 1 on every float leaf of `tree`.

    Selection is batched: sampled blocks of all eligible leaves go through
    ONE jitted estimator call (`select_many`), then the per-field SZ/ZFP
    byte encoders run on a thread pool (`workers`; 0 forces serial) — the
    paper's per-field independence makes both trivially parallel.
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named: list[tuple[str, np.ndarray]] = []
    compress_idx: list[int] = []
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        named.append((name, arr))
        if predicate is not None and not predicate(name, arr):
            continue
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        compress_idx.append(len(named) - 1)
    # original arrays go in; select_many casts to f32 one field at a time
    sels = select_many(
        [named[i][1] for i in compress_idx],
        eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp,
    )
    sel_of = dict(zip(compress_idx, sels))

    def encode(i: int) -> CompressedField:
        name, arr = named[i]
        if i not in sel_of:
            return CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
        # original array in: encode_with_selection casts to f32 internally
        # but records the true dtype, so decompress restores it
        return encode_with_selection(arr, sel_of[i])

    n_workers = _default_workers() if workers is None else workers
    if n_workers > 1 and len(named) > 1:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            encoded = list(ex.map(encode, range(len(named))))
    else:
        encoded = [encode(i) for i in range(len(named))]
    fields = {named[i][0]: cf for i, cf in enumerate(encoded)}
    return CompressedTree(fields=fields, treedef=treedef)


def decompress_pytree(ct: CompressedTree) -> Any:
    leaves = []
    for name, cf in ct.fields.items():
        if cf.codec == "raw" and cf.selection is None:
            arr = np.frombuffer(cf.data, dtype=np.dtype(cf.dtype)).reshape(cf.shape)
        else:
            arr = decompress(cf)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(ct.treedef, leaves)


__all__ = [
    "CompressedField",
    "CompressedTree",
    "compress_pytree",
    "decompress_pytree",
    "compression_ratio",
    "select_and_compress",
    "decompress",
]
