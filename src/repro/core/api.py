"""Public compression API: fields and pytrees (DESIGN.md §2, §7).

A "field" (paper's unit of selection — one simulation variable) maps to one
named tensor. `compress` / `compress_pytree` accept three quality modes:

* ``fixed_accuracy`` (default) — the paper's bound-centric contract: you
  give a pointwise error bound (`eb_abs`, or `eb_rel` relative to each
  field's value range) and Algorithm 1 picks the cheaper codec at that
  bound (DESIGN.md §1).
* ``fixed_psnr`` — you give `target_psnr` in dB and the quality-target
  controller (DESIGN.md §7) solves for the per-field bound that lands on
  it.
* ``fixed_ratio`` — you give `target_ratio` (x, vs 32-bit raw) and the
  controller solves for the bound whose estimated rate meets the budget.

`compress_pytree` runs the chosen mode per leaf and returns the compressed
fields + the selection-bit stream, exactly the paper's {C_i, s_i} output.
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from . import controller as _controller
from .selector import (
    CompressedField,
    Selection,
    compression_ratio,
    decompress,
    encode_with_selection,
    select_and_compress,
    select_many,
)


@dataclass
class ShardedCompressedField:
    """A field compressed shard-by-shard (DESIGN.md §6): the global codec
    decision plus one encoded `Segment` per unique data shard, each covering
    `view[start:stop]` of the folded f32 view. Reconstruction is
    bit-identical to whole-field encoding (SZ is elementwise, ZFP is
    4-block-local and shard boundaries are 4-aligned)."""

    codec: str
    shape: tuple[int, ...]
    dtype: str
    view_shape: tuple[int, ...]
    segments: list
    selection: Selection | None = None

    @property
    def nbytes(self) -> int:
        return sum(len(s.data) for s in self.segments)


@dataclass
class CompressedTree:
    fields: dict[str, CompressedField]
    treedef: Any

    @property
    def selection_bits(self) -> dict[str, str]:
        return {k: v.codec for k, v in self.fields.items()}

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.fields.values())

    @property
    def raw_nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * 4 for v in self.fields.values())

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(self.nbytes, 1)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _default_workers() -> int:
    return max(1, min(8, (os.cpu_count() or 2) - 1))


def _mode_selections(
    arrs: list[np.ndarray],
    mode: str,
    eb_abs: float | None,
    eb_rel: float | None,
    target_psnr: float | None,
    target_ratio: float | None,
    r_sp: float,
) -> list[Selection]:
    """Route one batch of fields through the mode's solver. fixed_accuracy
    keeps the Algorithm 1 fast path (`select_many`); the target modes run
    the controller (DESIGN.md §7) and unwrap its `TargetSolution`s."""
    if mode == "fixed_accuracy":
        return select_many(arrs, eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp)
    sols = _controller.solve_many(
        arrs, mode, target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp
    )
    return [s.selection for s in sols]


def compress(
    x: np.ndarray,
    mode: str = "fixed_accuracy",
    *,
    eb_rel: float = 1e-4,
    eb_abs: float | None = None,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    r_sp: float = 0.05,
) -> CompressedField:
    """Compress one field under a quality target; returns a `CompressedField`.

    Args:
      x: the field (any shape; evaluated in float32, the codecs' working
        dtype — the original dtype is recorded and restored by
        `decompress`). Ranks above 3 are folded to 3-D.
      mode: ``fixed_accuracy`` | ``fixed_psnr`` | ``fixed_ratio`` (above).
      eb_rel / eb_abs: fixed_accuracy only. `eb_abs` is a pointwise
        absolute bound, guaranteed on every value of the reconstruction;
        `eb_rel` scales it by the field's value range (max - min). `eb_abs`
        wins when both are given.
      target_psnr: fixed_psnr only — target PSNR in dB, defined against
        the field's value range (10 log10(VR^2 / MSE)). The achieved PSNR
        lands on the target (not merely above it); the reconstruction
        error stays pointwise-bounded by the bound the controller solved.
      target_ratio: fixed_ratio only — target compression ratio vs 32-bit
        raw. Met on the estimated rate within ~10%; there is no a-priori
        error bound in this mode (the controller reports the bound it
        chose in `.selection.eb_abs`).
      r_sp: block sampling rate for the estimators (paper default 5%).

    Raw fallback: fields that are too small (< 64 values or a dim < 4),
    constant, or NaN/inf-poisoned store verbatim with codec ``raw``; so
    does any field whose estimated rate exceeds 32 bits/value at the
    requested quality, and any stream that fails to beat raw after
    encoding. Raw streams reproduce the input bit-exactly.
    """
    x = np.asarray(x)
    if mode == "fixed_accuracy":
        return select_and_compress(x, eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp)
    sol = _controller.solve(
        x.astype(np.float32), mode,
        target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp,
    )
    return encode_with_selection(x, sol.selection)


def _is_multidevice(leaf: Any) -> bool:
    sharding = getattr(leaf, "sharding", None)
    try:
        return sharding is not None and len(sharding.device_set) > 1
    except Exception:  # noqa: BLE001 - any exotic sharding: stay unsharded
        return False


def compress_pytree(
    tree: Any,
    eb_rel: float = 1e-4,
    eb_abs: float | None = None,
    r_sp: float = 0.05,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    workers: int | None = None,
    mode: str = "fixed_accuracy",
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    sharded: bool | None = None,
) -> CompressedTree:
    """Compress every float leaf of `tree` under one quality mode.

    Args:
      tree: any pytree; leaf names come from the tree path.
      eb_rel / eb_abs: the fixed_accuracy bound (see `compress`). Ignored
        by the target modes.
      r_sp: estimator block sampling rate.
      predicate: `predicate(name, array) -> bool`; leaves it rejects ride
        through raw (exact bytes, original dtype). Non-float leaves always
        ride raw.
      workers: thread-pool width for the per-field byte encoders (0 forces
        serial; default: cpu-count-bounded). Selection/solving is batched
        regardless: sampled blocks of all eligible leaves go through ONE
        jitted estimator launch per round (`select_many`, or the
        controller sweep of DESIGN.md §7), then encoding overlaps on the
        pool — the paper's per-field independence makes both trivially
        parallel.
      mode / target_psnr / target_ratio: quality target per leaf, exactly
        as in `compress`. The per-field targets are independent: in
        fixed_psnr every leaf lands on the target dB against its own value
        range; in fixed_ratio every compressible leaf meets the ratio, so
        the tree-level ratio can exceed the target when raw-fallback
        leaves are rare and undershoot it when they dominate.
      sharded: route sharded `jax.Array` leaves through the shard-local
        engine (DESIGN.md §6): selection statistics are computed per
        device shard under `shard_map` and reconciled with a cheap
        collective — no full-tensor gather — and each leaf is encoded as
        per-shard `Segment`s inside a `ShardedCompressedField`. Decisions
        match the unsharded path (bit-identically for the sample-gather
        reconciliation; see `core/sharded.py`). Default None auto-enables
        when any leaf lives on more than one device; False forces the
        gather path.

    Returns a `CompressedTree`: per-leaf `CompressedField`s (the {C_i}
    streams) plus `.selection_bits` (the {s_i}).
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    if sharded is None:
        sharded = any(_is_multidevice(leaf) for _, leaf in leaves)
    if sharded:
        return _compress_pytree_sharded(
            leaves, treedef, eb_rel, eb_abs, r_sp, predicate, workers,
            mode, target_psnr, target_ratio,
        )
    named: list[tuple[str, np.ndarray]] = []
    compress_idx: list[int] = []
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        named.append((name, arr))
        if predicate is not None and not predicate(name, arr):
            continue
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        compress_idx.append(len(named) - 1)
    # original arrays go in; the solvers cast to f32 one field at a time
    sels = _mode_selections(
        [named[i][1] for i in compress_idx],
        mode, eb_abs, eb_rel, target_psnr, target_ratio, r_sp,
    )
    sel_of = dict(zip(compress_idx, sels))

    def encode(i: int) -> CompressedField:
        name, arr = named[i]
        if i not in sel_of:
            return CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
        # original array in: encode_with_selection casts to f32 internally
        # but records the true dtype, so decompress restores it
        return encode_with_selection(arr, sel_of[i])

    n_workers = _default_workers() if workers is None else workers
    if n_workers > 1 and len(named) > 1:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            encoded = list(ex.map(encode, range(len(named))))
    else:
        encoded = [encode(i) for i in range(len(named))]
    fields = {named[i][0]: cf for i, cf in enumerate(encoded)}
    return CompressedTree(fields=fields, treedef=treedef)


def _compress_pytree_sharded(
    leaves: list,
    treedef: Any,
    eb_rel: float,
    eb_abs: float | None,
    r_sp: float,
    predicate: Callable[[str, np.ndarray], bool] | None,
    workers: int | None,
    mode: str,
    target_psnr: float | None,
    target_ratio: float | None,
) -> CompressedTree:
    """The shard-local engine behind `compress_pytree(sharded=True)`: one
    `plan_tree` pass decides every float leaf without gathering it, then
    per-shard encoders run on the thread pool (DESIGN.md §6)."""
    from . import sharded as _sh

    named: list[tuple[str, Any]] = []
    compress_idx: list[int] = []
    for path, leaf in leaves:
        name = _leaf_name(path)
        if not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        named.append((name, leaf))
        if predicate is not None and not predicate(name, leaf):
            continue
        if not np.issubdtype(leaf.dtype, np.floating):
            continue
        compress_idx.append(len(named) - 1)
    plans = _sh.plan_tree(
        [named[i][1] for i in compress_idx], mode,
        eb_abs=eb_abs, eb_rel=eb_rel,
        target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp,
    )
    plan_of = dict(zip(compress_idx, plans))

    def encode(i: int):
        name, leaf = named[i]
        plan = plan_of.get(i)
        if plan is None:
            arr = np.asarray(leaf)
            return CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
        segments = _sh.encode_plan(leaf, plan)
        return ShardedCompressedField(
            _sh.field_codec(plan.selection.codec, segments),
            tuple(int(s) for s in np.shape(leaf)),
            str(leaf.dtype), plan.view_shape, segments, plan.selection,
        )

    n_workers = _default_workers() if workers is None else workers
    if n_workers > 1 and len(named) > 1:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            encoded = list(ex.map(encode, range(len(named))))
    else:
        encoded = [encode(i) for i in range(len(named))]
    fields = {named[i][0]: cf for i, cf in enumerate(encoded)}
    return CompressedTree(fields=fields, treedef=treedef)


def decompress_pytree(ct: CompressedTree) -> Any:
    """Invert `compress_pytree`: every lossy leaf reconstructs within its
    solved bound, every raw leaf bit-exactly (original dtype preserved).
    Sharded fields reassemble from their per-shard segments — on any
    device count, the elastic-restore contract of DESIGN.md §6."""
    from . import sharded as _sh

    leaves = []
    for name, cf in ct.fields.items():
        if isinstance(cf, ShardedCompressedField):
            view = _sh.decode_segments(cf.view_shape, cf.segments)
            arr = view.reshape(cf.shape).astype(np.dtype(cf.dtype))
        elif cf.codec == "raw" and cf.selection is None:
            arr = np.frombuffer(cf.data, dtype=np.dtype(cf.dtype)).reshape(cf.shape)
        else:
            arr = decompress(cf)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(ct.treedef, leaves)


__all__ = [
    "CompressedField",
    "CompressedTree",
    "ShardedCompressedField",
    "compress",
    "compress_pytree",
    "decompress_pytree",
    "compression_ratio",
    "select_and_compress",
    "decompress",
]
