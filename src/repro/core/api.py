"""Public compression API: fields and pytrees (DESIGN.md §2, §7).

A "field" (paper's unit of selection — one simulation variable) maps to one
named tensor. Quality travels as a `Policy` object (`core/policy.py`) —
ONE validated value carrying the mode, its target, the estimator sampling
rate, and the codec allowlist — instead of a spray of per-call kwargs:

* ``Policy.fixed_accuracy(eb_rel=...)`` / ``(eb_abs=...)`` — the paper's
  bound-centric contract: a pointwise error bound, Algorithm 1 picks the
  cheaper codec at that bound (DESIGN.md §1).
* ``Policy.fixed_psnr(db)`` — the quality-target controller (DESIGN.md §7)
  solves for the per-field bound that lands on the target dB.
* ``Policy.fixed_ratio(x)`` — the controller solves for the bound whose
  estimated rate meets the byte budget (x vs 32-bit raw).
* ``Policy.fixed_ssim(s)`` / ``Policy.fixed_correlation(rho)`` /
  ``Policy.fixed_ks(d)`` — the §7.4 quality-metric targets: the
  controller inverts the per-field metric curve (`core/quality.py`) to an
  equivalent-PSNR target and solves that with the same machinery — SSIM
  and correlation are floors, KS a ceiling, all with zero trial
  compressions.
* ``Policy.raw()`` — store verbatim (exact bytes, original dtype).

`compress_pytree` additionally takes a `PolicySet` — ordered
first-match-wins name rules over a default — so one tree can mix
contracts per leaf ("weights at eb_rel 1e-4, optimizer state at 8x").
Leaves are *grouped by resolved policy* and each group rides one packed
`select_many` / `solve_many` batch, so the single-policy tree still makes
every decision in one estimator launch (bit-identical to the pre-policy
API) and the pow2 jit bucketing of DESIGN.md §1 keeps the compile cache
hitting across groups.

The legacy keyword spelling (`mode=`, `eb_rel=`, `target_psnr=`, ...)
keeps working through a shim that maps it onto the equivalent `Policy`
and emits `DeprecationWarning`.

`compress_pytree` runs the resolved policy per leaf and returns the
compressed fields + the selection-bit stream, exactly the paper's
{C_i, s_i} output.
"""

from __future__ import annotations

import os
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from . import controller as _controller
from .policy import (
    Policy,
    PolicySet,
    as_policy_set,
    group_by_policy,
    policy_from_kwargs,
)
from .selector import (
    CompressedField,
    Selection,
    compression_ratio,
    decompress,
    encode_with_selection,
    select,
    select_and_compress,
    select_many,
)


def _dtype_itemsize(dtype: str) -> int:
    """Bytes per value of a recorded dtype string; tolerates extension
    dtypes (bfloat16 & friends) that numpy only knows once ml_dtypes has
    registered them."""
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        import ml_dtypes  # noqa: F401 - import registers the dtypes

        return np.dtype(dtype).itemsize


@dataclass
class ShardedCompressedField:
    """A field compressed shard-by-shard (DESIGN.md §6): the global codec
    decision plus one encoded `Segment` per unique data shard, each covering
    `view[start:stop]` of the folded f32 view. Reconstruction is
    bit-identical to whole-field encoding (SZ is elementwise, ZFP is
    4-block-local and shard boundaries are 4-aligned)."""

    codec: str
    shape: tuple[int, ...]
    dtype: str
    view_shape: tuple[int, ...]
    segments: list
    selection: Selection | None = None

    @property
    def nbytes(self) -> int:
        return sum(len(s.data) for s in self.segments)


@dataclass
class CompressedTree:
    fields: dict[str, CompressedField]
    treedef: Any

    @property
    def selection_bits(self) -> dict[str, str]:
        return {k: v.codec for k, v in self.fields.items()}

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.fields.values())

    @property
    def raw_nbytes(self) -> int:
        # the recorded dtype's itemsize, NOT a flat 4 bytes/value: mixed
        # trees carry f64/bf16/int raw leaves whose true footprint `.ratio`
        # must be measured against
        return sum(
            int(np.prod(v.shape)) * _dtype_itemsize(v.dtype)
            for v in self.fields.values()
        )

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(self.nbytes, 1)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _default_workers() -> int:
    return max(1, min(8, (os.cpu_count() or 2) - 1))


def _coerce_policy(
    where: str,
    policy,
    mode: str | None,
    eb_rel: float | None,
    eb_abs: float | None,
    target_psnr: float | None,
    target_ratio: float | None,
    r_sp: float | None,
    *,
    allow_set: bool = False,
    stacklevel: int = 4,
):
    """Resolve the (policy, legacy kwargs) pair every public entry point
    accepts: a Policy (or PolicySet where `allow_set`) passes through;
    legacy kwargs — including a bare mode string or a bare float bound in
    the `policy` slot — shim onto an equivalent Policy with a
    `DeprecationWarning`; nothing at all means the historical default
    (fixed_accuracy at eb_rel 1e-4)."""
    legacy = dict(
        mode=mode, eb_rel=eb_rel, eb_abs=eb_abs,
        target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp,
    )
    has_legacy = any(v is not None for v in legacy.values())
    if isinstance(policy, Policy) or (allow_set and isinstance(policy, PolicySet)):
        if has_legacy:
            raise ValueError(
                f"{where}: pass either policy= or the legacy quality kwargs, "
                "not both"
            )
        return policy
    if isinstance(policy, str):  # old positional `mode`
        if legacy["mode"] is not None:
            raise ValueError(f"{where}: mode given twice")
        legacy["mode"] = policy
    elif isinstance(policy, (int, float)):  # old positional `eb_rel`
        if legacy["eb_rel"] is not None:
            raise ValueError(f"{where}: eb_rel given twice")
        legacy["eb_rel"] = float(policy)
    elif policy is not None:
        raise TypeError(
            f"{where}: expected Policy{' | PolicySet' if allow_set else ''}, "
            f"got {type(policy).__name__}"
        )
    elif not has_legacy:
        return Policy.fixed_accuracy()  # the historical default contract
    return policy_from_kwargs(
        where, **legacy, default_eb_rel=1e-4, stacklevel=stacklevel
    )


def _policy_selections(
    arrs: list[np.ndarray], pol: Policy, cache=None, names=None
) -> list[Selection]:
    """Route one policy group of fields through its solver. fixed_accuracy
    keeps the Algorithm 1 fast path (`select_many`); the target modes run
    the controller (DESIGN.md §7) and unwrap its `TargetSolution`s.
    `cache`/`names` thread the warm decision path through either solver
    (DESIGN.md §8)."""
    if pol.mode == "fixed_accuracy":
        return select_many(arrs, policy=pol, cache=cache, names=names)
    sols = _controller.solve_many(arrs, pol, cache=cache, names=names)
    return [s.selection for s in sols]


def compress(
    x: np.ndarray,
    policy: Policy | str | None = None,
    *,
    device_encode: bool = False,
    mode: str | None = None,
    eb_rel: float | None = None,
    eb_abs: float | None = None,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    r_sp: float | None = None,
) -> CompressedField:
    """Compress one field under a quality policy; returns a `CompressedField`.

    Args:
      x: the field (any shape; evaluated in float32, the codecs' working
        dtype — the original dtype is recorded and restored by
        `decompress`). Ranks above 3 are folded to 3-D.
      policy: the quality contract (`core/policy.py`):
        `Policy.fixed_accuracy(eb_rel=...)` (default, at eb_rel 1e-4) |
        `Policy.fixed_psnr(db)` | `Policy.fixed_ratio(x)` |
        `Policy.fixed_ssim(s)` | `Policy.fixed_correlation(rho)` |
        `Policy.fixed_ks(d)` | `Policy.raw()`. Fixed-accuracy bounds are
        pointwise and guaranteed on every value of the reconstruction
        (`eb_rel` scales by the field's value range); fixed_psnr lands on
        the target dB (not merely above it); fixed_ratio meets the
        estimated byte budget within ~10% with the chosen bound reported
        in `.selection.eb_abs`; the §7.4 metric modes land on the metric
        target within the documented tolerances (`quality.TOLERANCE`),
        SSIM/correlation as floors and KS as a ceiling. The policy's
        `codecs` allowlist restricts
        which registered codecs compete; `r_sp` is the estimator block
        sampling rate (paper default 5%).
      device_encode: finish Stage III in-graph where the selected codec
        supports it (capability `device_encode`, DESIGN.md §3.7): packed
        stream bytes come off the device in one `device_get` instead of
        raw codes riding a host entropy coder. Decisions are unchanged;
        fields the device encoders decline (the §3.7 fallback rules)
        silently take the host coder. Default off.
      mode / eb_rel / eb_abs / target_psnr / target_ratio / r_sp:
        deprecated keyword spelling of the same contract — shimmed onto a
        `Policy` with a `DeprecationWarning`, decisions unchanged.

    Raw fallback: fields that are too small (< 64 values or a dim < 4),
    constant, or NaN/inf-poisoned store verbatim with codec ``raw``; so
    does any field whose estimated rate exceeds 32 bits/value at the
    requested quality, and any stream that fails to beat raw after
    encoding. Raw streams reproduce the input bit-exactly.
    """
    x = np.asarray(x)
    pol = _coerce_policy(
        "compress", policy, mode, eb_rel, eb_abs, target_psnr, target_ratio, r_sp
    )
    if pol.mode == "raw":
        return CompressedField("raw", x.tobytes(), x.shape, str(x.dtype))
    if pol.mode == "fixed_accuracy":
        sel = select(
            x.astype(np.float32), eb_abs=pol.eb_abs, eb_rel=pol.eb_rel,
            r_sp=pol.r_sp, codecs=pol.codecs,
        )
        return encode_with_selection(x, sel, device_encode=device_encode)
    sol = _controller.solve(x.astype(np.float32), pol)
    return encode_with_selection(x, sol.selection, device_encode=device_encode)


def _is_multidevice(leaf: Any) -> bool:
    sharding = getattr(leaf, "sharding", None)
    try:
        return sharding is not None and len(sharding.device_set) > 1
    except Exception:  # noqa: BLE001 - any exotic sharding: stay unsharded
        return False


def _named_leaves_with_policies(
    leaves: list,
    pset: PolicySet,
    predicate: Callable[[str, Any], bool] | None,
    materialize: bool,
) -> tuple[list[tuple[str, Any]], dict[int, Policy]]:
    """Shared leaf walk of the unsharded and sharded tree paths: name every
    leaf, resolve its policy, and keep only float leaves with a non-raw
    policy (that the deprecated `predicate`, when given, accepts) in the
    returned index -> Policy map."""
    named: list[tuple[str, Any]] = []
    pol_of: dict[int, Policy] = {}
    for path, leaf in leaves:
        name = _leaf_name(path)
        if materialize:
            leaf = np.asarray(leaf)
        elif not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        named.append((name, leaf))
        if predicate is not None and not predicate(name, leaf):
            continue
        if not np.issubdtype(leaf.dtype, np.floating):
            continue
        pol = pset.resolve(name)
        if pol.mode == "raw":
            continue
        pol_of[len(named) - 1] = pol
    return named, pol_of


def compress_pytree(
    tree: Any,
    policy: Policy | PolicySet | float | str | None = None,
    *,
    workers: int | None = None,
    sharded: bool | None = None,
    cache=None,
    device_encode: bool = False,
    eb_rel: float | None = None,
    eb_abs: float | None = None,
    r_sp: float | None = None,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    mode: str | None = None,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
) -> CompressedTree:
    """Compress every float leaf of `tree` under per-leaf quality policies.

    Args:
      tree: any pytree; leaf names come from the tree path.
      policy: a `Policy` applied to every float leaf, or a `PolicySet`
        resolving one per leaf name (ordered glob/regex rules, first match
        wins, then the default) — e.g.::

            PolicySet(default=Policy.fixed_accuracy(eb_rel=1e-4),
                      rules=[("opt/*", Policy.fixed_ratio(8.0))])

        Defaults to `Policy.fixed_accuracy()` (eb_rel 1e-4). Leaves whose
        resolved policy is `Policy.raw()` — and all non-float leaves —
        ride through raw (exact bytes, original dtype). Per-leaf targets
        are independent: in fixed_psnr every leaf lands on the target dB
        against its own value range; in the §7.4 metric modes
        (fixed_ssim / fixed_correlation / fixed_ks) every leaf lands on
        the metric target against its own sampled statistics; in
        fixed_ratio every compressible leaf meets the ratio, so the
        tree-level ratio can exceed the target when raw-fallback leaves
        are rare and undershoot it when they dominate.
      workers: thread-pool width for the per-field byte encoders (0 forces
        serial; default: cpu-count-bounded). Selection/solving is batched
        regardless: leaves are grouped by resolved policy and each group's
        sampled blocks go through ONE jitted estimator launch per round
        (`select_many`, or the controller sweep of DESIGN.md §7), then
        encoding overlaps on the pool — the paper's per-field independence
        makes both trivially parallel.
      sharded: route sharded `jax.Array` leaves through the shard-local
        engine (DESIGN.md §6): selection statistics are computed per
        device shard under `shard_map` and reconciled with a cheap
        collective — no full-tensor gather — and each leaf is encoded as
        per-shard `Segment`s inside a `ShardedCompressedField`. Decisions
        match the unsharded path (bit-identically for the sample-gather
        reconciliation; see `core/sharded.py`). Default None auto-enables
        when any leaf lives on more than one device; False forces the
        gather path.
      cache: a `DecisionCache` (DESIGN.md §8) carrying per-leaf decisions
        across repeated saves of the same tree. Leaves whose stats
        fingerprint validates replay the previous save's decision —
        bit-identical to the cold path — and skip the estimator launch;
        drifted or new leaves re-decide and refresh their entry. The
        caller owns the cache object and reuses it across calls
        (`CheckpointManager` persists it in the manifest).
      device_encode: finish Stage III in-graph for codecs with the
        `device_encode` capability (DESIGN.md §3.7) — the thread-pool
        encoders fetch packed stream bytes instead of running the host
        entropy coder. Applies on both the gathered and the shard-local
        (`sharded=True`) paths; decisions and manifests are unchanged,
        and declined fields fall back to the host coder per field.
      eb_rel / eb_abs / r_sp / mode / target_psnr / target_ratio /
        predicate: the deprecated kwarg spelling — shimmed onto a `Policy`
        (predicate rejections onto per-leaf raw) with a
        `DeprecationWarning`, decisions unchanged.

    Returns a `CompressedTree`: per-leaf `CompressedField`s (the {C_i}
    streams) plus `.selection_bits` (the {s_i}).
    """
    pol = _coerce_policy(
        "compress_pytree", policy, mode, eb_rel, eb_abs, target_psnr,
        target_ratio, r_sp, allow_set=True,
    )
    pset = as_policy_set(pol)
    if predicate is not None:
        warnings.warn(
            "compress_pytree(predicate=...) is deprecated; use PolicySet "
            "rules mapping rejected names to Policy.raw()",
            DeprecationWarning,
            stacklevel=2,
        )
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    if sharded is None:
        sharded = any(_is_multidevice(leaf) for _, leaf in leaves)
    if sharded:
        return _compress_pytree_sharded(
            leaves, treedef, pset, predicate, workers, cache=cache,
            device_encode=device_encode,
        )
    named, pol_of = _named_leaves_with_policies(
        leaves, pset, predicate, materialize=True
    )
    # original arrays go in; the solvers cast to f32 one field at a time
    sel_of: dict[int, Selection] = {}
    for p, idxs in group_by_policy(pol_of).items():
        sels = _policy_selections(
            [named[i][1] for i in idxs], p, cache=cache,
            names=[named[i][0] for i in idxs] if cache is not None else None,
        )
        sel_of.update(zip(idxs, sels))

    def encode(i: int) -> CompressedField:
        name, arr = named[i]
        if i not in sel_of:
            return CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
        # original array in: encode_with_selection casts to f32 internally
        # but records the true dtype, so decompress restores it
        return encode_with_selection(arr, sel_of[i], device_encode=device_encode)

    n_workers = _default_workers() if workers is None else workers
    if n_workers > 1 and len(named) > 1:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            encoded = list(ex.map(encode, range(len(named))))
    else:
        encoded = [encode(i) for i in range(len(named))]
    fields = {named[i][0]: cf for i, cf in enumerate(encoded)}
    return CompressedTree(fields=fields, treedef=treedef)


def _compress_pytree_sharded(
    leaves: list,
    treedef: Any,
    pset: PolicySet,
    predicate: Callable[[str, Any], bool] | None,
    workers: int | None,
    cache=None,
    device_encode: bool = False,
) -> CompressedTree:
    """The shard-local engine behind `compress_pytree(sharded=True)`: one
    `plan_tree` pass per policy group decides every float leaf without
    gathering it, then per-shard encoders run on the thread pool
    (DESIGN.md §6)."""
    from . import sharded as _sh

    named, pol_of = _named_leaves_with_policies(
        leaves, pset, predicate, materialize=False
    )
    plan_of: dict[int, Any] = {}
    for p, idxs in group_by_policy(pol_of).items():
        plans = _sh.plan_tree(
            [named[i][1] for i in idxs], p, cache=cache,
            names=[named[i][0] for i in idxs] if cache is not None else None,
        )
        plan_of.update(zip(idxs, plans))

    def encode(i: int):
        name, leaf = named[i]
        plan = plan_of.get(i)
        if plan is None:
            arr = np.asarray(leaf)
            return CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
        segments = _sh.encode_plan(leaf, plan, device_encode=device_encode)
        return ShardedCompressedField(
            _sh.field_codec(plan.selection.codec, segments),
            tuple(int(s) for s in np.shape(leaf)),
            str(leaf.dtype), plan.view_shape, segments, plan.selection,
        )

    n_workers = _default_workers() if workers is None else workers
    if n_workers > 1 and len(named) > 1:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            encoded = list(ex.map(encode, range(len(named))))
    else:
        encoded = [encode(i) for i in range(len(named))]
    fields = {named[i][0]: cf for i, cf in enumerate(encoded)}
    return CompressedTree(fields=fields, treedef=treedef)


def decompress_pytree(ct: CompressedTree) -> Any:
    """Invert `compress_pytree`: every lossy leaf reconstructs within its
    solved bound, every raw leaf bit-exactly (original dtype preserved).
    All restored leaves are WRITEABLE arrays — restored trees can be
    trained on in place. Sharded fields reassemble from their per-shard
    segments — on any device count, the elastic-restore contract of
    DESIGN.md §6."""
    from . import sharded as _sh

    leaves = []
    for name, cf in ct.fields.items():
        if isinstance(cf, ShardedCompressedField):
            view = _sh.decode_segments(cf.view_shape, cf.segments)
            arr = view.reshape(cf.shape).astype(np.dtype(cf.dtype))
        else:
            # `decompress` handles both raw conventions: selection-less raw
            # leaves restore exact original-dtype bytes, everything else
            # decodes through the codec registry (always writeable)
            arr = decompress(cf)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(ct.treedef, leaves)


__all__ = [
    "CompressedField",
    "CompressedTree",
    "Policy",
    "PolicySet",
    "ShardedCompressedField",
    "compress",
    "compress_pytree",
    "decompress_pytree",
    "compression_ratio",
    "select_and_compress",
    "decompress",
]
