"""Public compression API: fields and pytrees (DESIGN.md §2).

A "field" (paper's unit of selection — one simulation variable) maps to one
named tensor. `compress_pytree` runs Algorithm 1 per leaf and returns the
compressed fields + the selection-bit stream, exactly the paper's
{C_i, s_i} output.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from .selector import CompressedField, compression_ratio, decompress, select_and_compress


@dataclass
class CompressedTree:
    fields: dict[str, CompressedField]
    treedef: Any

    @property
    def selection_bits(self) -> dict[str, str]:
        return {k: v.codec for k, v in self.fields.items()}

    @property
    def nbytes(self) -> int:
        return sum(len(v.data) for v in self.fields.values())

    @property
    def raw_nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * 4 for v in self.fields.values())

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(self.nbytes, 1)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def compress_pytree(
    tree: Any,
    eb_rel: float = 1e-4,
    eb_abs: float | None = None,
    r_sp: float = 0.05,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
) -> CompressedTree:
    """Run Algorithm 1 independently on every float leaf of `tree`."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    fields: dict[str, CompressedField] = {}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        if predicate is not None and not predicate(name, arr):
            fields[name] = CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
            continue
        if not np.issubdtype(arr.dtype, np.floating):
            fields[name] = CompressedField("raw", arr.tobytes(), arr.shape, str(arr.dtype))
            continue
        fields[name] = select_and_compress(
            arr.astype(np.float32), eb_abs=eb_abs, eb_rel=eb_rel, r_sp=r_sp
        )
    return CompressedTree(fields=fields, treedef=treedef)


def decompress_pytree(ct: CompressedTree) -> Any:
    leaves = []
    for name, cf in ct.fields.items():
        if cf.codec == "raw" and cf.selection is None:
            arr = np.frombuffer(cf.data, dtype=np.dtype(cf.dtype)).reshape(cf.shape)
        else:
            arr = decompress(cf)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(ct.treedef, leaves)


__all__ = [
    "CompressedField",
    "CompressedTree",
    "compress_pytree",
    "decompress_pytree",
    "compression_ratio",
    "select_and_compress",
    "decompress",
]
