"""Policy objects — ONE quality contract across every compression layer
(DESIGN.md §2, §7).

The paper's output is a per-field decision {C_i, s_i}; what a caller holds
is a per-field *quality contract*: "pointwise bound eb", "land on T dB",
"fit in 1/R of raw". Before this module, that contract traveled as ~9
duplicated kwargs (`mode`, `eb_abs`, `eb_rel`, `target_psnr`,
`target_ratio`, `r_sp`, ...) copied across `core/api.py`,
`core/controller.py`, `core/sharded.plan_tree`,
`checkpoint.CheckpointConfig`, and `runtime/kvcomp.py`. A `Policy` is that
contract as one frozen, validated value object:

    Policy.fixed_accuracy(eb_rel=1e-4)      # the paper's bound-centric mode
    Policy.fixed_psnr(60.0)                 # §7 controller solves the bound
    Policy.fixed_ratio(8.0)                 # §7 iso-rate dual
    Policy.fixed_ssim(0.98)                 # §7.4 metric targets: structural
    Policy.fixed_correlation(0.999)         #   similarity / Pearson rho /
    Policy.fixed_ks(0.05)                   #   KS distribution distance
    Policy.raw()                            # store verbatim (exact bytes)

plus the estimator sampling rate (`r_sp`) and a codec *allowlist*
(`codecs`, validated against the DESIGN.md §2.1 registry) restricting
which registered codecs may compete for the field — `raw` is always
available as the safety-net fallback.

A `PolicySet` maps field *names* to policies with ordered first-match-wins
rules, so one checkpoint/serving tree can mix contracts:

    PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-4),
        rules=[("*/kv/*", Policy.fixed_ratio(8.0)),
               ("opt/*", Policy.raw())],
    )

Rule patterns are globs (`fnmatch` over the full leaf name) or, with an
``re:`` prefix, regexes (`re.search`). Policies are frozen and hashable:
`compress_pytree` groups leaves by resolved policy so each group rides one
packed `select_many`/`solve_many` batch (DESIGN.md §1) and the pow2 jit
bucketing still hits across groups.

Legacy keyword calls (`mode=`, `eb_rel=`, ...) are mapped onto a `Policy`
by `policy_from_kwargs` and emit `DeprecationWarning` — decisions are
bit-identical because the shim feeds the exact same solver path.
"""

from __future__ import annotations

import math
import re
import warnings
from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Iterable

from . import codecs as _codecs

#: estimator block sampling rate default (the paper's 5%; matches
#: `estimator.DEFAULT_SAMPLING_RATE` without importing the jax stack here)
DEFAULT_R_SP = 0.05
#: the bound-centric default of `compress_pytree` since PR 1
DEFAULT_EB_REL = 1e-4

MODES = (
    "fixed_accuracy",
    "fixed_psnr",
    "fixed_ratio",
    "fixed_ssim",
    "fixed_correlation",
    "fixed_ks",
    "raw",
)
#: the DESIGN.md §7.4 metric modes (solved via metric -> equivalent-PSNR
#: inversion in core/quality.py + core/controller.py)
METRIC_MODES = ("fixed_ssim", "fixed_correlation", "fixed_ks")
#: mode -> the Policy field holding its target (every solver-backed mode);
#: the single registry controller / sharded / checkpoint target extraction
#: reads, so adding a mode here is what makes it resolvable everywhere.
TARGET_FIELD = {
    "fixed_psnr": "target_psnr",
    "fixed_ratio": "target_ratio",
    "fixed_ssim": "target_ssim",
    "fixed_correlation": "target_correlation",
    "fixed_ks": "target_ks",
}


@dataclass(frozen=True)
class Policy:
    """One field's quality contract: mode + target + sampling + codec set.

    Construct through the classmethods (`fixed_accuracy` / `fixed_psnr` /
    `fixed_ratio` / `fixed_ssim` / `fixed_correlation` / `fixed_ks` /
    `raw`) — the bare constructor validates but does not default the
    mode-specific target fields. Frozen and hashable, so policies are
    usable as grouping keys and jit-static arguments.
    """

    mode: str
    eb_abs: float | None = None
    eb_rel: float | None = None
    target_psnr: float | None = None
    target_ratio: float | None = None
    target_ssim: float | None = None
    target_correlation: float | None = None
    target_ks: float | None = None
    r_sp: float = DEFAULT_R_SP
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; one of {MODES}")
        # normalize the allowlist: tuple, deduped, raw always available as
        # the degenerate/safety-net fallback
        cods = tuple(dict.fromkeys(self.codecs))
        for name in cods:
            if not _codecs.is_registered(name):
                raise ValueError(
                    f"codec {name!r} is not registered; known: "
                    f"{sorted(_codecs.names())} (core/codecs.py)"
                )
        if "raw" not in cods:
            cods = cods + ("raw",)
        object.__setattr__(self, "codecs", cods)
        if not (0.0 < self.r_sp <= 1.0):
            raise ValueError(f"r_sp must be in (0, 1], got {self.r_sp}")
        if self.mode == "fixed_accuracy":
            if self.eb_abs is None and self.eb_rel is None:
                raise ValueError("fixed_accuracy needs eb_abs or eb_rel")
            for v, n in ((self.eb_abs, "eb_abs"), (self.eb_rel, "eb_rel")):
                if v is not None and not (v > 0 and math.isfinite(v)):
                    raise ValueError(f"{n} must be finite and > 0, got {v}")
        elif self.mode == "fixed_psnr":
            if self.target_psnr is None or not math.isfinite(self.target_psnr):
                raise ValueError("fixed_psnr needs a finite target_psnr (dB)")
        elif self.mode == "fixed_ratio":
            if self.target_ratio is None or not self.target_ratio > 0:
                raise ValueError("fixed_ratio needs target_ratio > 0")
        elif self.mode == "fixed_ssim":
            if self.target_ssim is None or not (0.0 < self.target_ssim < 1.0):
                raise ValueError("fixed_ssim needs target_ssim in (0, 1)")
        elif self.mode == "fixed_correlation":
            if self.target_correlation is None or not (
                0.0 < self.target_correlation < 1.0
            ):
                raise ValueError(
                    "fixed_correlation needs target_correlation in (0, 1)"
                )
        elif self.mode == "fixed_ks":
            if self.target_ks is None or not (0.0 < self.target_ks < 1.0):
                raise ValueError("fixed_ks needs target_ks in (0, 1)")
        if self.mode != "raw" and not any(
            c for c in cods if c != "raw" and not _codecs.get(c).lossless
        ):
            raise ValueError(
                f"mode {self.mode!r} needs at least one lossy codec in the "
                f"allowlist (got {cods}); use Policy.raw() for verbatim storage"
            )

    # -- constructors -------------------------------------------------------

    @classmethod
    def fixed_accuracy(
        cls,
        eb_rel: float | None = None,
        eb_abs: float | None = None,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """The paper's bound-centric contract (Algorithm 1 at this bound).
        `eb_abs` wins when both bounds are given (matching the legacy
        kwargs); with neither, defaults to `eb_rel=1e-4`."""
        if eb_abs is not None:
            eb_rel = None
        elif eb_rel is None:
            eb_rel = DEFAULT_EB_REL
        return cls("fixed_accuracy", eb_abs=eb_abs, eb_rel=eb_rel,
                   r_sp=r_sp, codecs=tuple(codecs))

    @classmethod
    def fixed_psnr(
        cls,
        db: float,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """Land on `db` dB (value-range PSNR); §7 controller solves the bound."""
        return cls("fixed_psnr", target_psnr=float(db), r_sp=r_sp,
                   codecs=tuple(codecs))

    @classmethod
    def fixed_ratio(
        cls,
        x: float,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """Meet a byte budget: ratio `x` vs 32-bit raw (§7 iso-rate dual)."""
        return cls("fixed_ratio", target_ratio=float(x), r_sp=r_sp,
                   codecs=tuple(codecs))

    @classmethod
    def fixed_ssim(
        cls,
        target: float,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """Land on a structural-similarity floor in (0, 1); the §7.4 metric
        inversion converts it to a per-field PSNR target and the §7
        controller solves the bound (achieved within ±0.02)."""
        return cls("fixed_ssim", target_ssim=float(target), r_sp=r_sp,
                   codecs=tuple(codecs))

    @classmethod
    def fixed_correlation(
        cls,
        target: float,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """Land on a Pearson-correlation floor in (0, 1) between original and
        reconstruction (§7.4 metric inversion; achieved within ±0.005)."""
        return cls("fixed_correlation", target_correlation=float(target),
                   r_sp=r_sp, codecs=tuple(codecs))

    @classmethod
    def fixed_ks(
        cls,
        max_stat: float,
        *,
        r_sp: float = DEFAULT_R_SP,
        codecs: Iterable[str] = _codecs.DEFAULT_CODECS,
    ) -> "Policy":
        """Cap the Kolmogorov-Smirnov distance between the original and
        reconstructed value distributions at `max_stat` in (0, 1) (§7.4
        sample-measured inversion; achieved within ±0.02)."""
        return cls("fixed_ks", target_ks=float(max_stat), r_sp=r_sp,
                   codecs=tuple(codecs))

    @classmethod
    def raw(cls) -> "Policy":
        """Store verbatim — exact bytes, original dtype (replaces the old
        `predicate`-rejected path)."""
        return cls("raw", codecs=("raw",))

    # -- serialization (manifest v3) ----------------------------------------

    def spec(self) -> dict:
        """Compact JSON-safe form recorded per field in manifest v3."""
        out: dict = {"mode": self.mode}
        for k in ("eb_abs", "eb_rel", "target_psnr", "target_ratio",
                  "target_ssim", "target_correlation", "target_ks"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        if self.mode != "raw":
            out["r_sp"] = self.r_sp
            if self.codecs != _codecs.DEFAULT_CODECS:
                out["codecs"] = list(self.codecs)
        return out

    @classmethod
    def from_spec(cls, spec: dict) -> "Policy":
        kw = dict(spec)
        mode = kw.pop("mode", None)
        if mode not in MODES:
            raise ValueError(
                f"unknown quality mode {mode!r} in policy spec; supported "
                f"modes: {', '.join(MODES)}"
            )
        if "codecs" in kw:
            kw["codecs"] = tuple(kw["codecs"])
        if mode == "raw":
            return cls.raw()
        return cls(mode, **kw)


def _rule_matches(pattern, name: str) -> bool:
    if isinstance(pattern, re.Pattern):
        return pattern.search(name) is not None
    if pattern.startswith("re:"):
        return re.search(pattern[3:], name) is not None
    return fnmatchcase(name, pattern)


@dataclass(frozen=True)
class PolicySet:
    """Per-field policy resolution: ordered rules, first match wins, else
    `default`. Patterns are globs over the full leaf name ("opt/*",
    "*/kv/*"), ``re:``-prefixed regexes, or pre-compiled `re.Pattern`s."""

    default: Policy
    rules: tuple = field(default_factory=tuple)

    def __post_init__(self):
        if not isinstance(self.default, Policy):
            raise TypeError(f"default must be a Policy, got {type(self.default)}")
        rules = tuple(tuple(r) for r in self.rules)
        for pat, pol in rules:
            if not isinstance(pol, Policy):
                raise TypeError(f"rule {pat!r}: expected a Policy, got {type(pol)}")
            if isinstance(pat, str) and pat.startswith("re:"):
                re.compile(pat[3:])  # fail loudly at construction
            elif not isinstance(pat, (str, re.Pattern)):
                raise TypeError(f"rule pattern must be str or re.Pattern, got {pat!r}")
        object.__setattr__(self, "rules", rules)

    def resolve(self, name: str) -> Policy:
        for pat, pol in self.rules:
            if _rule_matches(pat, name):
                return pol
        return self.default


def group_by_policy(pol_of: dict[int, Policy]) -> "dict[Policy, list[int]]":
    """Leaf indices grouped by resolved policy: groups in first-appearance
    order, members in index order. A single-policy tree is ONE group with
    every index in the original order, so its packed decision batches —
    and therefore its decisions — are bit-identical to a direct
    `select_many`/`solve_many` call over the same fields."""
    groups: dict[Policy, list[int]] = {}
    for i in sorted(pol_of):
        groups.setdefault(pol_of[i], []).append(i)
    return groups


def policy_set_spec(pset: PolicySet) -> dict:
    """JSON-safe form of a PolicySet (manifest v3's top-level record)."""

    def pat_str(pat) -> str:
        return f"re:{pat.pattern}" if isinstance(pat, re.Pattern) else pat

    out: dict = {"default": pset.default.spec()}
    if pset.rules:
        out["rules"] = [[pat_str(p), pol.spec()] for p, pol in pset.rules]
    return out


# ---------------------------------------------------------------------------
# Serving-tier request resolution (DESIGN.md §9)
# ---------------------------------------------------------------------------


def request_kv_name(rid: int, context_len: int, long_threshold: int) -> str:
    """Canonical per-request KV-policy leaf name for the serving tier
    (DESIGN.md §9): ``kv/long/<rid>`` when the request's total context
    (prompt + budgeted new tokens) reaches `long_threshold`, else
    ``kv/short/<rid>``. The batcher resolves this name against its
    `PolicySet` ONCE at admission, so the page policy is a jit-static
    value for the request's whole lifetime."""
    kind = "long" if context_len >= long_threshold else "short"
    return f"kv/{kind}/{rid}"


def serving_policies(
    target_ratio: float = 8.0, *, r_sp: float = DEFAULT_R_SP
) -> "PolicySet":
    """The serving tier's stock PolicySet: long-context requests trade KV
    page fidelity for a `fixed_ratio` byte budget on evicted pages; short
    requests stay `raw` (evict/restore is bit-identical)."""
    return PolicySet(
        default=Policy.raw(),
        rules=(("kv/long/*", Policy.fixed_ratio(target_ratio, r_sp=r_sp)),),
    )


def as_policy_set(policy) -> PolicySet:
    """Coerce a Policy | PolicySet into a PolicySet."""
    if isinstance(policy, PolicySet):
        return policy
    if isinstance(policy, Policy):
        return PolicySet(default=policy)
    raise TypeError(
        f"expected Policy or PolicySet, got {type(policy).__name__}: {policy!r}"
    )


# ---------------------------------------------------------------------------
# Legacy-kwarg shim
# ---------------------------------------------------------------------------


def policy_from_kwargs(
    where: str,
    *,
    mode: str | None = None,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    r_sp: float | None = None,
    default_eb_rel: float | None = None,
    stacklevel: int = 3,
) -> Policy:
    """Map the deprecated kwarg spray onto a `Policy`, warning once per call
    site. The mapping reproduces each call site's legacy defaults exactly
    (eb_abs wins over eb_rel; `default_eb_rel` is the bound the old
    signature defaulted to, None where it used to raise), so shimmed calls
    decide — and encode — bit-identically to the old API."""
    mode = mode or "fixed_accuracy"
    r_sp = DEFAULT_R_SP if r_sp is None else r_sp
    if mode == "fixed_accuracy":
        if eb_abs is None and eb_rel is None:
            if default_eb_rel is None:
                raise ValueError("fixed_accuracy needs eb_abs or eb_rel")
            eb_rel = default_eb_rel
        pol = Policy.fixed_accuracy(eb_rel=eb_rel, eb_abs=eb_abs, r_sp=r_sp)
    elif mode == "fixed_psnr":
        if target_psnr is None:
            raise ValueError("fixed_psnr needs target_psnr")
        pol = Policy.fixed_psnr(target_psnr, r_sp=r_sp)
    elif mode == "fixed_ratio":
        if target_ratio is None:
            raise ValueError("fixed_ratio needs target_ratio")
        pol = Policy.fixed_ratio(target_ratio, r_sp=r_sp)
    elif mode in METRIC_MODES:
        raise ValueError(
            f"mode {mode!r} has no legacy-kwarg spelling; pass "
            f"policy=Policy.{mode}(target) instead (repro.core.policy)"
        )
    else:
        raise ValueError(
            f"unknown quality mode {mode!r}; supported modes: {', '.join(MODES)}"
        )
    warnings.warn(
        f"{where}: mode/eb/target keyword arguments are deprecated; pass "
        f"policy={_policy_repr(pol)} instead (repro.core.policy)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
    return pol


def _policy_repr(p: Policy) -> str:
    if p.mode == "fixed_accuracy":
        arg = f"eb_abs={p.eb_abs!r}" if p.eb_abs is not None else f"eb_rel={p.eb_rel!r}"
        return f"Policy.fixed_accuracy({arg})"
    if p.mode == "fixed_psnr":
        return f"Policy.fixed_psnr({p.target_psnr!r})"
    if p.mode == "fixed_ratio":
        return f"Policy.fixed_ratio({p.target_ratio!r})"
    attr = TARGET_FIELD.get(p.mode)
    if attr is not None:
        return f"Policy.{p.mode}({getattr(p, attr)!r})"
    return "Policy.raw()"


__all__ = [
    "DEFAULT_EB_REL",
    "DEFAULT_R_SP",
    "METRIC_MODES",
    "MODES",
    "TARGET_FIELD",
    "Policy",
    "PolicySet",
    "as_policy_set",
    "group_by_policy",
    "policy_from_kwargs",
    "policy_set_spec",
    "request_kv_name",
    "serving_policies",
]
