"""SZ-style prediction-based error-bounded lossy compressor (paper §2, §5.1).

Pipeline (Stage I/II/III of Fig. 1):
  PBT (integer Lorenzo, DESIGN.md §3.1)  ->  linear quantization (delta=2*eb)
  ->  Huffman entropy coding.

Two paths:
  * `sz_stats`      — jnp / jit-safe: reconstruction + exact rate/distortion
                      statistics (histogram entropy + the paper's +0.5 offset).
  * `sz_compress` / `sz_decompress` — host numpy byte codec (real Stage III).

The pointwise guarantee |x - x~| <= eb holds by construction (prequantization
+ Theorem 1: integer Lorenzo is lossless so the only error is quantization).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import entropy as _entropy
from .transforms import lorenzo_forward

#: symbols: 0 = escape (outlier), 1..2R+1 = residual shifted by R+1
RESIDUAL_RADIUS = 32767  # 2n-1 = 65535 bins, paper §6.3.2
#: bumped SZJX -> SZJ1 when the embedded Huffman-table serialization gained
#: its zstd/raw flag byte, so streams from the old layout fail the magic
#: check cleanly instead of erroring mid-decode
_MAGIC = b"SZJ1"
#: the device-encoded container version (DESIGN.md §3.7): byte layout is
#: identical to SZJ1 — same table, same payload bit stream, same outlier
#: section — but the quantization/Lorenzo stage ran in-graph (float32),
#: so the flag records provenance. `sz_decompress` accepts both.
DEVICE_MAGIC = b"SZJ2"


# ---------------------------------------------------------------------------
# in-graph statistics path
# ---------------------------------------------------------------------------


@dataclass
class SZStats:
    bitrate: jax.Array      # bits/value (entropy + 0.5 offset + outliers)
    psnr: jax.Array         # actual PSNR of the reconstruction
    mse: jax.Array
    recon: jax.Array        # reconstruction (error <= eb pointwise)
    outlier_frac: jax.Array


def sz_stats(x: jax.Array, eb: jax.Array | float, hist_radius: int = RESIDUAL_RADIUS) -> SZStats:
    """Exact rate/distortion of the SZ path, computed in-graph."""
    xf = x.astype(jnp.float32)
    delta = 2.0 * jnp.asarray(eb, jnp.float32)
    codes = jnp.round(xf / delta)
    recon = (codes * delta).astype(jnp.float32)
    d = lorenzo_forward(codes)
    clipped = jnp.clip(d, -hist_radius, hist_radius)
    outlier = jnp.abs(d) > hist_radius
    hist = jnp.histogram(
        clipped, bins=2 * hist_radius + 1, range=(-hist_radius - 0.5, hist_radius + 0.5)
    )[0]
    p = hist.astype(jnp.float32) / jnp.maximum(hist.sum(), 1)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    ofrac = jnp.mean(outlier.astype(jnp.float32))
    # entropy + Huffman suboptimality offset (paper §6.2) + escape payload
    bitrate = ent + 0.5 + ofrac * 64.0
    err = xf - recon
    mse = jnp.mean(jnp.square(err.astype(jnp.float32)))
    vr = jnp.maximum(jnp.max(xf) - jnp.min(xf), 1e-30).astype(jnp.float32)
    psnr = -10.0 * jnp.log10(jnp.maximum(mse, 1e-60) / (vr * vr))
    return SZStats(bitrate=bitrate, psnr=psnr, mse=mse, recon=recon, outlier_frac=ofrac)


# ---------------------------------------------------------------------------
# host byte codec
# ---------------------------------------------------------------------------


def _lorenzo_fwd_np(k: np.ndarray) -> np.ndarray:
    out = k
    for ax in range(k.ndim):
        out = np.diff(out, axis=ax, prepend=np.zeros_like(np.take(out, [0], axis=ax)))
    return out


def _lorenzo_inv_np(d: np.ndarray) -> np.ndarray:
    out = d
    for ax in range(d.ndim):
        out = np.cumsum(out, axis=ax)
    return out


def sz_container(
    shape: tuple[int, ...],
    delta: float,
    table: "_entropy.HuffmanTable",
    payload: bytes,
    outliers: np.ndarray,
    *,
    magic: bytes = _MAGIC,
) -> bytes:
    """Assemble the self-describing SZ container around an already-encoded
    Huffman payload. Shared by the host Stage III (`sz_encode_residuals`)
    and the device encode tier (`core/device_encode.py`), which packs the
    same payload bits in-graph (DESIGN.md §3.7) and only assembles here."""
    outliers = np.asarray(outliers, dtype=np.int64)
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    hdr = struct.pack(
        "<4sBdQI", magic, len(shape), float(delta), size, len(outliers)
    ) + struct.pack(f"<{len(shape)}q", *shape)
    tbl = table.to_bytes()
    return b"".join(
        [
            hdr,
            struct.pack("<I", len(tbl)), tbl,
            struct.pack("<Q", len(payload)), payload,
            outliers.tobytes(),
        ]
    )


def sz_encode_residuals(
    d: np.ndarray, shape: tuple[int, ...], delta: float, *, magic: bytes = _MAGIC
) -> bytes:
    """Stage III on precomputed Lorenzo residuals: symbols, Huffman table,
    payload, outlier section, container. Split from `sz_compress` so the
    device-encode parity suite can run the host coder on *device-computed*
    residuals and compare streams byte for byte (DESIGN.md §3.7)."""
    d = np.asarray(d).reshape(-1).astype(np.int64)
    esc_mask = np.abs(d) > RESIDUAL_RADIUS
    syms = np.where(esc_mask, 0, d + RESIDUAL_RADIUS + 1).astype(np.int64)
    freqs = np.bincount(syms, minlength=2 * RESIDUAL_RADIUS + 2)
    table = _entropy.build_table(freqs)
    payload = _entropy.encode(syms, table)
    return sz_container(shape, delta, table, payload, d[esc_mask], magic=magic)


def sz_compress(x: np.ndarray, eb: float) -> bytes:
    """Error-bounded compression to a self-describing byte stream."""
    x = np.asarray(x, dtype=np.float32)
    assert eb > 0, "error bound must be positive"
    delta = 2.0 * float(eb)
    codes = np.round(np.nan_to_num(x.astype(np.float64) / delta)).astype(np.int64)
    d = _lorenzo_fwd_np(codes)
    return sz_encode_residuals(d, x.shape, delta)


def sz_decompress(buf: bytes) -> np.ndarray:
    off = 0
    magic, ndim, delta, size, n_out = struct.unpack_from("<4sBdQI", buf, off)
    assert magic in (_MAGIC, DEVICE_MAGIC), (
        "not an SZJ1/SZJ2 stream (old/foreign format?)"
    )
    off += struct.calcsize("<4sBdQI")
    shape = struct.unpack_from(f"<{ndim}q", buf, off)
    off += 8 * ndim
    (tbl_len,) = struct.unpack_from("<I", buf, off)
    off += 4
    table = _entropy.HuffmanTable.from_bytes(buf[off : off + tbl_len])
    off += tbl_len
    (pay_len,) = struct.unpack_from("<Q", buf, off)
    off += 8
    syms = _entropy.decode(buf[off : off + pay_len], table, size)
    off += pay_len
    outliers = np.frombuffer(buf[off : off + 8 * n_out], dtype=np.int64)
    d = syms - (RESIDUAL_RADIUS + 1)
    esc = syms == 0
    d[esc] = outliers
    codes = _lorenzo_inv_np(d.reshape(shape))
    return (codes.astype(np.float64) * delta).astype(np.float32)


def sz_compressed_bits(buf: bytes) -> int:
    return 8 * len(buf)
