"""Stage I lossless transformations for energy compaction (paper §4).

Two families:

* PBT — prediction-based transformation (SZ's Lorenzo predictor, §4.1).
  TPU adaptation (DESIGN.md §3): we use the *prequantized integer Lorenzo*
  formulation. The n-dimensional Lorenzo residual is exactly the composition
  of first-order backward differences along each axis; its inverse is the
  composition of inclusive prefix-sums. Both are pure stencils / scans —
  fully parallel, no loop-carried dependency across the array.

* BOT — block orthogonal transformation (ZFP/SSEM, §4.2). The paper's
  parametric family T(t) covers HWT (t=0), DCT-II (t=1/4), slant,
  high-correlation (closest to ZFP's lifted transform) and Walsh-Hadamard.
  Orthogonality gives the L2-invariance of Lemma 2 / Theorem 3.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# PBT: n-dimensional Lorenzo transform as separable first-order differences
# ---------------------------------------------------------------------------


def lorenzo_forward(x: jax.Array) -> jax.Array:
    """n-D Lorenzo residual: x[i] - (inclusion/exclusion over preceding corner).

    Equivalent to applying a zero-padded backward difference along every axis.
    Lossless over integers; over floats it is the PBT of §4.1 with the
    original-neighbor prediction used by the estimator (§4.3).
    """
    out = x
    for axis in range(x.ndim):
        prev = jnp.roll(out, 1, axis=axis)
        # zero out the wrapped-around first slice
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, 1)
        prev = prev.at[tuple(idx)].set(0)
        out = out - prev
    return out


def lorenzo_inverse(d: jax.Array) -> jax.Array:
    """Inverse PBT: inclusive prefix-sum along every axis (exact in ints)."""
    out = d
    for axis in range(d.ndim):
        out = jnp.cumsum(out, axis=axis)
    return out


def lorenzo_predict(x: jax.Array) -> jax.Array:
    """The Lorenzo *prediction* for each point from original real neighbors.

    pred = x - lorenzo_forward(x); exposed for estimator diagnostics.
    """
    return x - lorenzo_forward(x)


# ---------------------------------------------------------------------------
# BOT: the parametric 4x4 orthogonal transform family (paper §4.2)
# ---------------------------------------------------------------------------

#: named parameter values for T(t)
BOT_PRESETS = {
    "hwt": 0.0,
    "dct2": 0.25,
    "slant": (2.0 / math.pi) * math.atan(1.0 / 3.0),
    "high_corr": (2.0 / math.pi) * math.atan(1.0 / 2.0),  # ~ZFP's transform
    "wht": 0.5,
    "zfp": (2.0 / math.pi) * math.atan(1.0 / 2.0),
}


def bot_matrix(t: float | str = "zfp") -> np.ndarray:
    """The paper's uniform parametric 4x4 orthogonal transform T(t)."""
    if isinstance(t, str):
        t = BOT_PRESETS[t]
    s = math.sqrt(2.0) * math.sin(math.pi / 2.0 * t)
    c = math.sqrt(2.0) * math.cos(math.pi / 2.0 * t)
    T = 0.5 * np.array(
        [
            [1.0, 1.0, 1.0, 1.0],
            [c, s, -s, -c],
            [1.0, -1.0, -1.0, 1.0],
            [s, -c, c, -s],
        ],
        dtype=np.float64,
    )
    return T


def bot_linf_gain(t: float | str = "zfp") -> float:
    """Max-abs-row-sum of T^t per axis = worst-case Linf amplification of the
    inverse transform; used to pick a conservative bit-plane cutoff so the
    user's absolute error bound holds pointwise after reconstruction
    (this is exactly why "ZFP over-preserves the compression error", §6.4).
    """
    T = bot_matrix(t)
    return float(np.abs(T.T).sum(axis=1).max())


def block_transform_nd(blocks: jax.Array, T: jax.Array, n: int, inverse: bool = False) -> jax.Array:
    """Apply the 1-D transform T along each of the trailing `n` axes (size 4).

    `blocks` has shape (..., 4, 4, ..., 4) — the paper's fold/unfold along
    D_1..D_n axes is an einsum contraction per axis (index remapping only,
    so the elementwise L2 norm is preserved per Lemma 2).
    """
    M = T.T if inverse else T
    M = jnp.asarray(M, dtype=blocks.dtype)
    out = blocks
    for axis in range(blocks.ndim - n, blocks.ndim):
        out = jnp.tensordot(out, M, axes=[[axis], [1]])
        # tensordot moved the contracted axis to the end; move it back
        out = jnp.moveaxis(out, -1, axis)
    return out


# ---------------------------------------------------------------------------
# Blocking: split an n-D field into 4^n blocks (pad edges), and back
# ---------------------------------------------------------------------------


def blockize(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """(d1,...,dn) -> (nblocks, 4, ..., 4). Edge blocks are padded by
    replicating the last valid element (keeps block statistics sane)."""
    ndim = x.ndim
    pads = []
    for s in x.shape:
        pads.append((0, (-s) % 4))
    x = jnp.pad(x, pads, mode="edge")
    shape = x.shape
    # interleave (d_i//4, 4)
    new_shape = []
    for s in shape:
        new_shape += [s // 4, 4]
    x = x.reshape(new_shape)
    # move all block-count axes first
    perm = [2 * i for i in range(ndim)] + [2 * i + 1 for i in range(ndim)]
    x = x.transpose(perm)
    nblk = int(np.prod(x.shape[:ndim]))
    return x.reshape((nblk,) + (4,) * ndim), shape


def unblockize(blocks: jax.Array, padded_shape: tuple[int, ...], orig_shape: tuple[int, ...]) -> jax.Array:
    ndim = len(padded_shape)
    grid = [s // 4 for s in padded_shape]
    x = blocks.reshape(tuple(grid) + (4,) * ndim)
    perm = []
    for i in range(ndim):
        perm += [i, ndim + i]
    x = x.transpose(perm).reshape(padded_shape)
    sl = tuple(slice(0, s) for s in orig_shape)
    return x[sl]
