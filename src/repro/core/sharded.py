"""Shard-local selection engine — distributed Algorithm 1 (DESIGN.md §6).

The paper's headline evaluation is *parallel*: 1,024 ranks, each
compressing its own fields. This module closes the reproduction's gap to
that setting: Stage I/II of Algorithm 1 runs under `shard_map` over the
training mesh, so every device computes estimator statistics on its LOCAL
shard and the per-field decision is reconciled with a cheap collective of
the §4–§5 sufficient statistics — no full-tensor gather ever happens on
the selection path, and the byte encoders then run per shard (each host
compresses only the bytes it already holds). The collectives make this
multi-HOST for free (DESIGN.md §6.2): under `jax.process_count() > 1`
the merged statistics — and hence every decision and bound — are
identical on all processes, and `encode_plan(..., host=)` filters the
segment list to the ones a given process owns, which is what the
checkpoint writer's per-host segment files build on.

Two reconciliation strategies, both exposed through `plan_tree`:

* ``stats`` (fixed_accuracy default) — each shard computes its owned
  sample blocks' sufficient statistics in-graph: value range via a global
  min/max, exact ZFP coder bits per block (integer), EC-point truncation
  error energy, and the SZ integer-Lorenzo residual *bin counts* at the
  iso-PSNR bin size. A `psum` over the mesh merges them exactly (integer
  sums and min/max are reduction-order-free), and the decision formulas of
  §4–§5 run on the merged statistics — the same expressions the unsharded
  batched path evaluates, so decisions agree to estimator ulps and the
  derived SZ bound is bit-identical thanks to the `PSNR_MATCH_QUANTUM`
  snap (DESIGN.md §1).
* ``samples`` (target modes, and an exact-parity option for
  fixed_accuracy) — each shard extracts its owned sample *blocks*
  (`r_sp` ≈ 5% of the bytes) with a one-plane `ppermute` halo exchange,
  they are all-gathered in global block order, and the existing batched
  deciders (`selector._run_select_batches`, the §7 controller) run on
  them. Because the gathered blocks are bit-identical to what
  `estimator.gather_blocks_np` would produce from the unsharded tensor,
  the decisions are bit-identical to the unsharded path by construction.

Block ownership: the global 4^n sample lattice (`estimator.block_starts`
on the *folded global view*) is partitioned on host from the sharding's
`devices_indices_map`; a block belongs to the shard containing it, and
within a replica group blocks round-robin across the replicas so even
fully-replicated fields parallelize. Eligibility requires every sharded
view dim to split evenly into 4-aligned shards (one mesh axis per dim);
anything else — uneven shards, sharded middle dims of a >3-D fold,
non-Named shardings — falls back to the gather path per field, which is
exactly the unsharded engine, so correctness never depends on layout.

The halo exchange: SZ's Lorenzo residuals predict each sample block from
its ORIGINAL neighbors (zero outside the domain). A shard's leading block
along a sharded dim needs the previous shard's trailing element plane, so
the body prepends one `ppermute`d plane per sharded dim (zeros arrive at
the global boundary, matching the convention); corner halos compose
because each exchange forwards the already-extended array.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from functools import lru_cache
from types import SimpleNamespace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.runtime import dist
from repro.runtime import sharding as rsh

from . import codecs as _codecs
from . import controller as ctl
from . import estimator as est
from . import quality as qual
from . import selector as select_mod
from .embedded import exact_coder_bits_blocks, plane_step
from .policy import TARGET_FIELD, Policy, policy_from_kwargs
from .selector import (
    Selection,
    _degenerate_selection,
    _fold_ndim,
    _max_batch_blocks,
    _next_pow2,
    _pick_codec,
    _run_select_batches,
)
from .transforms import block_transform_nd, bot_linf_gain, bot_matrix


def _smap(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (check_rep was renamed check_vma)."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - depends on jax version
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# Layout analysis: can this array's sharding carry the engine?
# ---------------------------------------------------------------------------


def fold_plan(shape: tuple[int, ...]) -> tuple[tuple[int, ...], list[tuple[int, ...]]]:
    """(view_shape, groups): the `selector._fold_ndim` fold expressed as a
    plan — groups[i] lists the ORIGINAL dims merged into view dim i.

    Genuinely-3-D fields (Hurricane/NYX volumes) keep all three dims:
    ranks above 3 fold leading axes into view dim 0 but never below 3-D,
    so the folded view stays eligible for the 3-D kernel tier
    (DESIGN.md §3.4–§3.5) and for 3-D shard-local selection. Only a
    leading dim too short for a 4-wide block (< 4) is merged away."""
    dims = list(shape)
    groups: list[tuple[int, ...]] = [(d,) for d in range(len(dims))]
    if len(dims) > 3:
        lead = tuple(range(len(dims) - 2))
        groups = [lead, (len(dims) - 2,), (len(dims) - 1,)]
        dims = [int(np.prod(shape[:-2]))] + list(shape[-2:])
    size = int(np.prod(shape)) if shape else 0
    while len(dims) > 1 and dims[0] < 4 and size:
        groups = [groups[0] + groups[1]] + groups[2:]
        dims = [dims[0] * dims[1]] + dims[2:]
    return tuple(dims), groups


@dataclass(frozen=True)
class ShardSeg:
    """One unique data shard of a field's folded view (replicas share it)."""

    start: tuple[int, ...]  # view coords
    stop: tuple[int, ...]
    devices: tuple[Any, ...]  # replica group, deterministic (device-id) order


@dataclass(frozen=True)
class FieldLayout:
    """How a field's folded global view maps onto mesh shards."""

    mesh: Mesh
    view_shape: tuple[int, ...]
    local_view: tuple[int, ...]  # uniform shard extent, view coords
    axis_of_dim: tuple[str | None, ...]  # mesh axis partitioning each view dim
    orig_spec: tuple  # PartitionSpec entries over the ORIGINAL dims
    segs: tuple[ShardSeg, ...]


def analyze(x: Any) -> FieldLayout | None:
    """The engine-eligible layout of `x`, or None (gather fallback).

    Eligible: NamedSharding on a concrete mesh; each sharded dim carried
    by exactly one mesh axis; folding merges only unsharded dims (except
    the leading one); every sharded view dim splits evenly into shards
    that are multiples of the 4-wide block. The returned `local_view` is
    identical on every device — a `shard_map` requirement."""
    mesh = rsh.mesh_of(x)
    if mesh is None or np.ndim(x) == 0:
        return None
    shape = tuple(int(s) for s in np.shape(x))
    spec = rsh.spec_entries(x)
    view_shape, fold_groups = fold_plan(shape)
    axis_of_dim: list[str | None] = []
    for vdim, group in enumerate(fold_groups):
        sharded = [d for d in group if spec[d] is not None]
        if not sharded:
            axis_of_dim.append(None)
            continue
        if sharded != [group[0]]:
            return None  # a merged inner dim is sharded: slices interleave
        entry = spec[group[0]]
        if not isinstance(entry, str):
            return None  # one dim over several mesh axes: keep it simple
        n = int(mesh.shape[entry])
        if n > 1:
            if shape[group[0]] % n:
                return None  # uneven shards break shard_map uniformity
            local = view_shape[vdim] // n
            if local % 4 or local < 4:
                return None  # shard boundary would split a 4-block
        axis_of_dim.append(entry if n > 1 else None)
    local_view = tuple(
        v // (mesh.shape[a] if a else 1) for v, a in zip(view_shape, axis_of_dim)
    )
    inner = {g[0]: int(np.prod([shape[d] for d in g[1:]], initial=1)) for g in fold_groups}
    lead = {g[0]: vd for vd, g in enumerate(fold_groups)}
    segs = []
    for start_o, stop_o, devs in rsh.unique_shards(x):
        start_v = [0] * len(view_shape)
        stop_v = list(view_shape)
        for d, vd in lead.items():
            start_v[vd] = start_o[d] * inner[d]
            stop_v[vd] = start_v[vd] + (stop_o[d] - start_o[d]) * inner[d]
        segs.append(ShardSeg(tuple(start_v), tuple(stop_v), devs))
    return FieldLayout(
        mesh, view_shape, local_view, tuple(axis_of_dim), tuple(spec), tuple(segs)
    )


# ---------------------------------------------------------------------------
# Block ownership: partition the global sample lattice across shards
# ---------------------------------------------------------------------------


def _owned_starts(
    layout: FieldLayout, starts: np.ndarray
) -> dict[Any, tuple[list[tuple[int, ...]], list[int]]]:
    """device -> (local extended-array block starts, global slot indices).

    A block belongs to the shard containing it (shard boundaries are
    4-aligned, so containment is total); within a replica group, blocks
    round-robin across the devices so replicated fields still spread the
    estimator work. Local starts index the halo-extended local array: the
    prepended plane shifts everything by +1, so the 5-wide halo window of
    global block `g` starts at `g - seg.start` exactly."""
    nd = len(layout.view_shape)
    segmap = {s.start: s for s in layout.segs}
    rr: dict[tuple, int] = {s.start: 0 for s in layout.segs}
    owned: dict[Any, tuple[list, list]] = {
        d: ([], []) for s in layout.segs for d in s.devices
    }
    for slot, g in enumerate(np.asarray(starts, np.int64)):
        key = tuple(
            (int(g[d]) // layout.local_view[d]) * layout.local_view[d]
            if layout.axis_of_dim[d]
            else 0
            for d in range(nd)
        )
        seg = segmap[key]
        j = rr[key]
        rr[key] = j + 1
        dev = seg.devices[j % len(seg.devices)]
        owned[dev][0].append(tuple(int(g[d]) - key[d] for d in range(nd)))
        owned[dev][1].append(slot)
    return owned


@lru_cache(maxsize=256)
def _starts_plan(layout: FieldLayout, starts_bytes: bytes, n_blocks: int):
    """Cached (owned-starts map, padded per-device count, stacked device
    array) for one (layout, sample grid): the partition is deterministic,
    and an in-situ loop re-saves the same shapes every checkpoint — this
    keeps the per-save host work at dict lookups instead of a fresh
    ownership sweep + device_put per field."""
    nd = len(layout.view_shape)
    starts = np.frombuffer(starts_bytes, np.int64).reshape(n_blocks, nd)
    owned = _owned_starts(layout, starts)
    mx = _next_pow2(max([len(v[1]) for v in owned.values()] + [1]))
    stacked = _stacked_starts(layout.mesh, owned, nd, mx)
    return owned, mx, stacked


def _stacked_starts(mesh: Mesh, per_dev: dict, nd: int, mx: int) -> jax.Array:
    """(n_devices, mx, nd+1) int32 — per-device [local starts | slot], padded
    with slot = -1, placed so shard_map hands each device its own row. The
    ownership map covers GLOBAL devices, so on a multi-process mesh the
    array is assembled via `make_array_from_callback` (each process
    contributes only its addressable rows — `device_put` cannot reach a
    remote device)."""
    n = int(mesh.devices.size)
    ns = NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names)))
    arr = np.zeros((n, mx, nd + 1), np.int32)
    arr[:, :, nd] = -1
    imap = ns.devices_indices_map((n, mx, nd + 1))
    for dev, idx in imap.items():
        row = 0 if idx[0].start is None else int(idx[0].start)
        lsts, slots = per_dev.get(dev, ([], []))
        for k, (lst, slot) in enumerate(zip(lsts, slots)):
            arr[row, k, :nd] = lst
            arr[row, k, nd] = slot
    return dist.put_global(arr, ns)


# ---------------------------------------------------------------------------
# The shard_map bodies
# ---------------------------------------------------------------------------


_F32 = jnp.float32


def _halo_extend(v: jax.Array, axis_of_dim: tuple, mesh: Mesh) -> jax.Array:
    """Prepend one halo plane per view dim: the previous shard's trailing
    plane along sharded dims (`ppermute`; index-0 shards receive zeros —
    the global-boundary convention), zeros along unsharded dims. Done dim
    by dim so corner halos compose through the already-extended planes."""
    for dim, ax in enumerate(axis_of_dim):
        if ax is not None and int(mesh.shape[ax]) > 1:
            n = int(mesh.shape[ax])
            plane = jax.lax.slice_in_dim(v, v.shape[dim] - 1, v.shape[dim], axis=dim)
            recv = jax.lax.ppermute(plane, ax, [(i, i + 1) for i in range(n - 1)])
            v = jnp.concatenate([recv, v], axis=dim)
        else:
            pad = jnp.zeros(v.shape[:dim] + (1,) + v.shape[dim + 1 :], v.dtype)
            v = jnp.concatenate([pad, v], axis=dim)
    return v


def _gather_ext(ext: jax.Array, lst: jax.Array, nd: int) -> jax.Array:
    """(mx, 5, ..) halo blocks of the extended local array at `lst` starts
    (traced values — unlike `estimator.gather_blocks`' static grid). Pad
    rows gather in-bounds garbage that callers mask / drop by slot."""
    mx = lst.shape[0]
    offs = jnp.arange(5)
    bidx = []
    for d in range(nd):
        i = jnp.clip(lst[:, d][:, None] + offs[None, :], 0, ext.shape[d] - 1)
        sh = [mx] + [1] * nd
        sh[1 + d] = 5
        bidx.append(i.reshape(sh))
    return ext[tuple(bidx)]


@dataclass(frozen=True)
class _FieldDesc:
    """Static per-field signature of one engine launch (the jit cache key)."""

    orig_local: tuple[int, ...]  # local shard shape, original dims
    orig_spec: tuple
    view_shape: tuple[int, ...]
    local_view: tuple[int, ...]
    axis_of_dim: tuple
    mx: int  # padded per-device block count


def _field_stats(halo, valid, eb, vr, size_f, nd, transform, all_axes):
    """One field's §4–§5 sufficient statistics from its owned halo blocks,
    psum-merged over the mesh, reduced to (br_sz, br_zfp, psnr_zfp, eb_sz)
    with exactly the formulas of `estimator.estimate_zfp_many` /
    `estimate_sz` — integer statistics (coder bits, bin counts, escape
    counts) merge exactly; the only floating sums (EC error energy) feed
    the PSNR whose `PSNR_MATCH_QUANTUM` snap absorbs reduction-order ulps
    before the SZ bound is derived (DESIGN.md §1, §6)."""
    bsz = 4**nd

    def psum(v):
        return jax.lax.psum(v, all_axes)

    nohalo = halo[(slice(None),) + (slice(1, None),) * nd]
    # --- ZFP at eb: exact coder bits (int) + EC truncation error (§5) ---
    n_s = nohalo.shape[0]
    mxab = jnp.maximum(jnp.max(jnp.abs(nohalo.reshape(n_s, -1)), axis=1), 1e-30)
    e = jnp.ceil(jnp.log2(mxab)).astype(jnp.int32)
    norm = nohalo * jnp.exp2(-e.astype(_F32)).reshape((-1,) + (1,) * nd)
    T = jnp.asarray(bot_matrix(transform), _F32)
    coeffs = block_transform_nd(norm, T, nd)
    gain_n = bot_linf_gain(transform) ** nd
    step = plane_step(eb, e, gain_n)
    bits_blk = exact_coder_bits_blocks(coeffs, step)  # integer-valued f32
    bits = psum(jnp.sum(jnp.where(valid, bits_blk, 0.0).astype(jnp.int32)))
    sel_pts = np.flatnonzero(est._ec_point_mask(nd).reshape(-1))
    s_ = step.reshape(-1, 1).astype(_F32)
    co = coeffs.reshape(n_s, -1)[:, sel_pts]
    mt = jnp.trunc(jnp.abs(co) / s_)
    rec = jnp.sign(co) * jnp.where(mt > 0, (mt + 0.5) * s_, 0.0)
    scale = jnp.exp2(e.astype(_F32)).reshape(-1, 1)
    vr32 = jnp.maximum(vr, 1e-30)
    err2n_blk = jnp.sum(jnp.square((co - rec) * scale), axis=1) / jnp.square(vr32)
    err2 = psum(jnp.sum(jnp.where(valid, err2n_blk, 0.0)))
    nblk = psum(jnp.sum(valid.astype(jnp.int32))).astype(_F32)
    br_zfp = bits.astype(_F32) / jnp.maximum(nblk * bsz, 1.0)
    mse_over_vr2 = err2 / jnp.maximum(nblk * len(sel_pts), 1.0)
    psnr = -10.0 * jnp.log10(jnp.maximum(mse_over_vr2, 1e-60))
    # --- iso-PSNR match -> SZ bin size (§1), then SZ bin counts (§4) ---
    delta = est.sz_delta_for_psnr(psnr, vr)
    eb_sz = jnp.clip(delta / 2.0, eb * 1e-6, eb)
    dlt = 2.0 * eb_sz
    d = jnp.round(halo / dlt)
    for ax in range(1, nd + 1):
        d = jax.lax.slice_in_dim(d, 1, d.shape[ax], axis=ax) - jax.lax.slice_in_dim(
            d, 0, d.shape[ax] - 1, axis=ax
        )
    k_raw = d.reshape(-1)
    valid_s = jnp.repeat(valid, bsz)
    half = (est.PDF_BINS - 1) // 2
    esc = psum(jnp.sum((valid_s & (jnp.abs(k_raw) > half)).astype(jnp.int32)))
    k = (jnp.clip(k_raw, -half, half) + half).astype(jnp.int32)
    hist = (
        jnp.zeros((est.PDF_BINS,), jnp.int32)
        .at[jnp.where(valid_s, k, 0)]
        .add(valid_s.astype(jnp.int32))
    )
    hist = psum(hist)  # the merged bin counts ARE the §4 sufficient statistic
    ofrac = esc.astype(_F32) / jnp.maximum(jnp.sum(hist), 1).astype(_F32)
    br_sz = est.sz_bitrate_from_hist(hist, ofrac, size_f)
    return br_sz, br_zfp, psnr, eb_sz


@lru_cache(maxsize=32)
def _engine_fn(
    mesh: Mesh,
    descs: tuple[_FieldDesc, ...],
    kind: str,
    transform: str,
    replicate_out: bool = False,
):
    """Jitted shard_map over one batch of engine-eligible fields.

    kind='samples': each device extracts its owned halo blocks; outputs
    (blocks, slots) stacked over devices for host reassembly into global
    block order. kind='stats': the full §4–§5 statistic computation +
    psum reconciliation runs in-graph; outputs per-field decision scalars.
    Cached per (mesh, field signatures, kind) — the checkpoint loop hits
    the same signature every step.

    `replicate_out` (multi-process meshes, samples mode): the host cannot
    `device_get` a cross-process-sharded output, so the blocks/slots are
    `all_gather`ed IN-GRAPH over every mesh axis and come back replicated
    (out_specs `P()`). The gather order differs from shard_map's stacking,
    but reassembly scatters by slot index, so the result is identical —
    every process sees the full global block set and the downstream
    deciders run on bit-identical inputs on every host."""
    names = tuple(mesh.axis_names)

    def body(xs, sts, eb_f, vr_f, size_f):
        blocks_out, slots_out, stats_out = [], [], []
        for i, (x_loc, st, dsc) in enumerate(zip(xs, sts, descs)):
            nd = len(dsc.view_shape)
            v = x_loc.reshape(dsc.local_view).astype(_F32)
            ext = _halo_extend(v, dsc.axis_of_dim, mesh)
            st = st[0]  # (1, mx, nd+1) -> (mx, nd+1)
            lst, slot = st[:, :nd], st[:, nd]
            halo = _gather_ext(ext, lst, nd)
            if kind == "samples":
                if replicate_out:
                    halo = jax.lax.all_gather(halo, names, axis=0, tiled=True)
                    slot = jax.lax.all_gather(slot, names, axis=0, tiled=True)
                blocks_out.append(halo)
                slots_out.append(slot)
            else:
                stats_out.append(
                    _field_stats(
                        halo, slot >= 0, eb_f[i], vr_f[i], size_f[i], nd, transform, names
                    )
                )
        if kind == "samples":
            return tuple(blocks_out), tuple(slots_out)
        return tuple(stats_out)

    in_specs = (
        tuple(PartitionSpec(*d.orig_spec) for d in descs),
        tuple(PartitionSpec(names) for _ in descs),
        PartitionSpec(),
        PartitionSpec(),
        PartitionSpec(),
    )
    if kind == "samples" and replicate_out:
        out_specs = (
            tuple(PartitionSpec() for _ in descs),
            tuple(PartitionSpec() for _ in descs),
        )
    elif kind == "samples":
        out_specs = (
            tuple(
                PartitionSpec(names, *([None] * len(d.view_shape))) for d in descs
            ),
            tuple(PartitionSpec(names) for _ in descs),
        )
    else:
        out_specs = tuple(
            (PartitionSpec(), PartitionSpec(), PartitionSpec(), PartitionSpec())
            for _ in descs
        )
    return jax.jit(_smap(body, mesh, in_specs, out_specs))


@jax.jit
def _minmax_jit(xs):
    """Per-field global (min, max) of the f32 view — XLA partitions the
    reduction shard-locally and all-reduces the scalars; no gather."""
    return [(jnp.min(x.astype(_F32)), jnp.max(x.astype(_F32))) for x in xs]


@jax.jit
def _moments_jit(xs):
    """Per-field global (min, max, mean, mean-of-squares) of the f32 view —
    the decision-cache fingerprint moments (DESIGN.md §8). Like
    `_minmax_jit`, XLA partitions the reductions shard-locally and
    all-reduces the scalars (the psum reconciliation of DESIGN.md §6), so
    every host derives the identical fingerprint without a gather; min/max
    are reduction-order-free, so the vr this yields matches `_minmax_jit`
    exactly."""
    outs = []
    for x in xs:
        v = x.astype(_F32)
        outs.append((jnp.min(v), jnp.max(v), jnp.mean(v), jnp.mean(v * v)))
    return outs


def _moments_fingerprint(
    view_shape: tuple[int, ...], vr: float, size: int,
    lo: float, hi: float, mean: float, msq: float, r_sp: float,
) -> dict:
    """Fingerprint record for an engine-eligible field, from the global
    value moments. Weaker than the host path's block-content digest — a
    hit certifies the global min/max/mean/mean-square (and the sample
    grid via view shape + r_sp) are unchanged, not the bytes — but any
    decision it replays still honors the policy's pointwise bound on the
    CURRENT data (the codecs guarantee `eb_abs` for whatever they encode;
    DESIGN.md §8.3), so drift past the moments can only cost rate
    optimality, never correctness."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"repro-dc1-moments")
    h.update(np.asarray(view_shape, np.int64).tobytes())
    h.update(
        np.asarray(
            [vr, float(size), r_sp, lo, hi, mean, msq], np.float64
        ).tobytes()
    )
    return dict(
        kind="moments", digest=h.hexdigest(), vr=vr, size=int(size),
        smin=lo, smax=hi, mean=mean, msq=msq,
    )


# ---------------------------------------------------------------------------
# plan_tree: decisions for a whole pytree, shard-locally
# ---------------------------------------------------------------------------


@dataclass
class FieldPlan:
    """One field's reconciled decision + the layout its bytes will ride."""

    selection: Selection
    solution: ctl.TargetSolution | None
    layout: FieldLayout | None  # None -> single gathered segment
    view_shape: tuple[int, ...]
    reconcile: str  # 'stats' | 'samples' | 'host' | 'degenerate' | 'cached'

    @property
    def sharded(self) -> bool:
        return self.layout is not None


def _shape_shim(view_shape: tuple[int, ...]) -> Any:
    size = int(np.prod(view_shape)) if view_shape else 1
    return SimpleNamespace(ndim=len(view_shape), shape=view_shape, size=size)


def _view_of(x: np.ndarray) -> np.ndarray:
    view = _fold_ndim(np.asarray(x, dtype=np.float32))
    return view.reshape(1) if view.ndim == 0 else view


def plan_tree(
    arrs: list,
    policy: Policy | str | None = None,
    *,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    target_psnr: float | None = None,
    target_ratio: float | None = None,
    r_sp: float | None = None,
    transform: str = "zfp",
    reconcile: str = "auto",
    cache=None,
    names=None,
) -> list[FieldPlan]:
    """Algorithm 1 (or a §7 target solve) over MANY possibly-sharded fields
    without gathering any of them, under ONE quality `Policy`
    (`core/policy.py` — mixed trees group by policy upstream in
    `compress_pytree`/the checkpoint writer and call this per group). The
    legacy mode-string + kwarg spelling shims onto the equivalent Policy
    with a `DeprecationWarning`.

    reconcile='auto' uses the in-graph sufficient-statistics psum for
    fixed_accuracy and the sample-block gather (bit-identical decisions)
    for the target modes; 'stats' / 'samples' force a strategy for
    fixed_accuracy ('stats' is invalid for target modes — the §7 secant
    needs the sampled curves). The §7.4 metric modes (fixed_ssim /
    fixed_correlation / fixed_ks) need no extra collectives: their
    sufficient statistics (sample variance + the sorted value sample for
    the KS quantization curve, `core/quality.py`) are derived from the
    SAME device-extracted halo blocks the secant already gathers, so
    metric solves decide bit-identically to the host path and the warm
    path guards them with the psum-reconciled moments fingerprint like
    any other target mode. Fields whose sharding the engine cannot
    carry (see `analyze`) gather and ride the ordinary host path; their
    decisions are by definition the unsharded ones.

    `cache`/`names` (a `DecisionCache`, DESIGN.md §8): engine-eligible
    fields fingerprint on psum-reconciled global value moments (one
    `_moments_jit` launch replaces the min/max launch — every host derives
    the same fingerprint, so shard-local saves share the cache); validated
    hits skip the engine launch entirely (`reconcile='cached'`).
    Host-fallback and degenerate fields bypass the cache and re-decide
    every call."""
    if isinstance(policy, Policy):
        if any(v is not None for v in (eb_abs, eb_rel, target_psnr, target_ratio, r_sp)):
            raise ValueError("pass either a Policy or the legacy kwargs, not both")
    elif policy is None or isinstance(policy, str):
        policy = policy_from_kwargs(
            "plan_tree", mode=policy, eb_abs=eb_abs, eb_rel=eb_rel,
            target_psnr=target_psnr, target_ratio=target_ratio, r_sp=r_sp,
        )
    else:
        raise TypeError(f"expected a Policy (or legacy mode str), got {policy!r}")
    mode, r_sp = policy.mode, policy.r_sp
    eb_abs, eb_rel = policy.eb_abs, policy.eb_rel
    codecs = policy.codecs
    if mode == "raw":
        raise ValueError("plan_tree has nothing to decide for Policy.raw()")
    if mode != "fixed_accuracy":
        if reconcile == "stats":
            raise ValueError("target modes require reconcile='samples'")
        reconcile_eff = "samples"
    else:
        reconcile_eff = "stats" if reconcile in ("auto", "stats") else "samples"
    if mode == "fixed_accuracy":
        target = eb_abs if eb_abs is not None else eb_rel
    else:
        attr = TARGET_FIELD.get(mode)
        if attr is None:
            raise ValueError(
                f"plan_tree cannot solve mode {mode!r}; supported modes: "
                f"fixed_accuracy, {', '.join(TARGET_FIELD)}"
            )
        target = float(getattr(policy, attr))

    arrs = list(arrs)
    n = len(arrs)
    if cache is not None:
        if names is None:
            raise ValueError("plan_tree(cache=...) requires names=")
        names = list(names)
        if len(names) != n:
            raise ValueError(
                f"names/arrs length mismatch: {len(names)} vs {n}"
            )
    plans: list[FieldPlan | None] = [None] * n
    layouts = [analyze(x) for x in arrs]
    # one global min/max launch for every engine-eligible field (size-0
    # fields have no reduction identity and pin vr = 0.0, like the host
    # path); the warm path widens it to the fingerprint moments launch
    vr_of: dict[int, float] = {
        i: 0.0 for i in range(n) if layouts[i] is not None and not np.size(arrs[i])
    }
    moments_of: dict[int, tuple[float, float, float, float]] = {}
    elig = [i for i in range(n) if layouts[i] is not None and i not in vr_of]
    if elig and cache is None:
        mm = jax.device_get(_minmax_jit([arrs[i] for i in elig]))
        for i, (lo, hi) in zip(elig, mm):
            # f32 subtraction first, matching the unsharded host path
            vr_of[i] = float(np.float32(hi) - np.float32(lo))
    elif elig:
        mm = jax.device_get(_moments_jit([arrs[i] for i in elig]))
        for i, (lo, hi, mean, msq) in zip(elig, mm):
            vr_of[i] = float(np.float32(hi) - np.float32(lo))
            moments_of[i] = (float(lo), float(hi), float(mean), float(msq))

    cache_store: list[tuple[int, str, tuple, str, dict]] = []
    host_idx: list[int] = []
    engine: list[tuple[int, np.ndarray]] = []  # (field index, global starts)
    for i, x in enumerate(arrs):
        lay = layouts[i]
        if lay is None:
            host_idx.append(i)
            continue
        view_shape = lay.view_shape
        vr = vr_of[i]
        # target modes mirror solve_many's degenerate handling: no bound
        # hints reach the raw fallback (eb defaults to 1e-3 * vr there)
        deg_eb = (eb_abs, eb_rel) if mode == "fixed_accuracy" else (None, None)
        sel0 = _degenerate_selection(_shape_shim(view_shape), vr, *deg_eb, r_sp)
        if sel0 is not None:
            sol = None
            if mode != "fixed_accuracy":
                # raw storage is exact: every quality floor is met (PSNR,
                # SSIM, correlation, KS) — only fixed_ratio misses target
                sol = ctl.TargetSolution(
                    sel0, mode, float(target), math.inf, ctl.RAW_BITS,
                    mode != "fixed_ratio",
                    est_metric=qual.lossless_metric(mode),
                )
            plans[i] = FieldPlan(sel0, sol, lay, view_shape, "degenerate")
            continue
        starts = est.block_starts(view_shape, r_sp)
        cap = _max_batch_blocks(len(view_shape))
        if len(starts) > cap:
            if mode == "fixed_accuracy":
                host_idx.append(i)  # select_many's monster-field fallback
                continue
            starts = starts[:: -(-len(starts) // cap)]  # controller's stride-down
        if cache is not None:
            shape = tuple(int(s) for s in np.shape(x))
            dtype = str(x.dtype)
            fp = _moments_fingerprint(
                view_shape, vr, int(np.prod(view_shape)), *moments_of[i], r_sp
            )
            entry = cache.lookup(names[i], shape, dtype, policy, transform, fp)
            if entry is not None and (
                mode == "fixed_accuracy" or entry.solution is not None
            ):
                sol = entry.to_solution() if entry.solution is not None else None
                plans[i] = FieldPlan(
                    entry.to_selection(), sol, lay, view_shape, "cached"
                )
                continue
            cache_store.append((i, names[i], shape, dtype, fp))
        engine.append((i, starts))

    # device-extracted sample blocks per engine field (samples mode), or
    # in-graph stats decisions written straight into `plans` (stats mode)
    blocks_of: dict[int, np.ndarray] = {}
    if engine:
        mesh_groups: dict[Mesh, list[tuple[int, np.ndarray]]] = {}
        for i, starts in engine:
            mesh_groups.setdefault(layouts[i].mesh, []).append((i, starts))
        for mesh, group in mesh_groups.items():
            _plan_engine_group(
                mesh, group, arrs, layouts, vr_of, plans, blocks_of, mode,
                float(target), eb_abs, eb_rel, r_sp, transform, reconcile_eff,
                codecs,
            )

    # Decide everything not yet planned in ONE merged batch run: host-side
    # members are gathered by the same helpers `select_many`/`solve_many`
    # use, engine members carry their device-extracted blocks, and merging
    # them in input order reproduces the unsharded batch composition
    # exactly — so mixed eligible/fallback pytrees still decide
    # bit-identically (the f32 cross-field reductions see the same packing).
    # host-fallback members gather to host; on a multi-process mesh the
    # fetch rides a replicating computation (`dist.to_numpy`) so every
    # host sees the identical array and derives the identical decision
    host_arrs = [dist.to_numpy(arrs[i]) for i in host_idx]
    if mode == "fixed_accuracy":
        results: list[Selection | None] = [None] * n
        if reconcile_eff == "samples" or host_idx:
            groups = select_mod._build_select_members(
                host_arrs, host_idx, results, eb_abs, eb_rel, r_sp, transform,
                codecs,
            )
            for i, blocks in blocks_of.items():
                lay = layouts[i]
                eb = float(eb_abs) if eb_abs is not None else float((eb_rel or 0.0) * vr_of[i])
                groups.setdefault(len(lay.view_shape), []).append(
                    (i, blocks, eb, vr_of[i], int(np.prod(lay.view_shape)))
                )
            for nd in groups:
                groups[nd].sort(key=lambda m: m[0])
            _run_select_batches(groups, results, r_sp, transform, codecs)
        for i in host_idx:
            plans[i] = FieldPlan(
                results[i], None, None, _host_view_shape(arrs[i]), "host"
            )
        for i in blocks_of:
            plans[i] = FieldPlan(
                results[i], None, layouts[i], layouts[i].view_shape, "samples"
            )
    else:
        results_t: list[ctl.TargetSolution | None] = [None] * n
        groups_t = ctl._build_solve_members(
            host_arrs, host_idx, results_t, mode, float(target), r_sp
        )
        for i, blocks in blocks_of.items():
            lay = layouts[i]
            groups_t.setdefault(len(lay.view_shape), []).append(
                ctl._Member(i, blocks, vr_of[i], int(np.prod(lay.view_shape)))
            )
        for nd in groups_t:
            groups_t[nd].sort(key=lambda m: m.idx)
        ctl._solve_groups(
            groups_t, results_t, mode, float(target), ctl.DEFAULT_ROUNDS[mode],
            r_sp, transform, codecs,
        )
        for i in host_idx:
            sol = results_t[i]
            plans[i] = FieldPlan(
                sol.selection, sol, None, _host_view_shape(arrs[i]), "host"
            )
        for i in blocks_of:
            sol = results_t[i]
            plans[i] = FieldPlan(
                sol.selection, sol, layouts[i], layouts[i].view_shape, "samples"
            )
    for i, name, shape, dtype, fp in cache_store:
        plan = plans[i]
        cache.store(
            name, shape, dtype, policy, transform, fp, plan.selection,
            solution=plan.solution,
        )
    return plans  # type: ignore[return-value]


def _host_view_shape(arr: np.ndarray) -> tuple[int, ...]:
    """Folded-view shape without materializing the f32 view (0-d -> (1,))."""
    vs = fold_plan(tuple(int(s) for s in np.shape(arr)))[0]
    return vs if vs else (1,)


def _plan_engine_group(
    mesh: Mesh,
    group: list[tuple[int, np.ndarray]],
    arrs: list,
    layouts: list,
    vr_of: dict[int, float],
    plans: list,
    blocks_of: dict[int, np.ndarray],
    mode: str,
    target: float,
    eb_abs: float | None,
    eb_rel: float | None,
    r_sp: float,
    transform: str,
    reconcile_eff: str,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> None:
    """Run one engine launch over the eligible fields of one mesh: stats
    mode writes finished plans; samples mode deposits the reassembled
    global-order blocks into `blocks_of` for the caller's merged batch run."""
    descs, stacked, ebs, vrs, sizes, owned_of = [], [], [], [], [], []
    for i, starts in group:
        lay: FieldLayout = layouts[i]
        starts = np.ascontiguousarray(np.asarray(starts, np.int64))
        owned, mx, stacked_i = _starts_plan(lay, starts.tobytes(), len(starts))
        stacked.append(stacked_i)
        local_orig = tuple(
            int(np.shape(arrs[i])[d])
            // (int(mesh.shape[e]) if isinstance(e, str) else 1)
            for d, e in enumerate(lay.orig_spec)
        )
        descs.append(
            _FieldDesc(local_orig, lay.orig_spec, lay.view_shape, lay.local_view, lay.axis_of_dim, mx)
        )
        vr = vr_of[i]
        eb = float(eb_abs) if eb_abs is not None else float((eb_rel or 0.0) * vr)
        ebs.append(eb)
        vrs.append(np.float32(vr))
        sizes.append(np.float32(int(np.prod(lay.view_shape))))
        owned_of.append((i, starts, owned))
    fn = _engine_fn(
        mesh,
        tuple(descs),
        "stats" if reconcile_eff == "stats" else "samples",
        transform,
        replicate_out=reconcile_eff != "stats" and dist.spans_processes(mesh),
    )
    xs = tuple(arrs[i] for i, _ in group)
    args = (
        xs,
        tuple(stacked),
        jnp.asarray(np.asarray(ebs, np.float32)),
        jnp.asarray(np.asarray(vrs, np.float32)),
        jnp.asarray(np.asarray(sizes, np.float32)),
    )
    if reconcile_eff == "stats":
        stats = jax.device_get(fn(*args))
        for (i, _, _), (br_sz, br_zfp, psnr, eb_sz), eb in zip(owned_of, stats, ebs):
            bs, bz = float(br_sz), float(br_zfp)
            codec = _pick_codec(bs, bz, codecs)
            sel = Selection(
                codec, float(eb), float(eb_sz), bs, bz, float(psnr), vr_of[i], r_sp
            )
            plans[i] = FieldPlan(sel, None, layouts[i], layouts[i].view_shape, "stats")
        return
    blocks_g, slots_g = fn(*args)
    # reassemble each field's sample blocks in GLOBAL block order — after
    # this, inputs to the deciders are bit-identical to the unsharded
    # host-gathered ones; the caller merges them with any host members
    for (i, starts, _), bl, sl in zip(owned_of, blocks_g, slots_g):
        bl = np.asarray(bl)
        sl = np.asarray(sl)
        keep = sl >= 0
        out = np.zeros((len(starts),) + bl.shape[1:], np.float32)
        out[sl[keep]] = bl[keep]
        blocks_of[i] = out


# ---------------------------------------------------------------------------
# Per-shard encoding / segment assembly (Step 4, shard-locally)
# ---------------------------------------------------------------------------


@dataclass
class Segment:
    """One encoded shard of a field: `data` covers view[start:stop]."""

    start: tuple[int, ...]
    stop: tuple[int, ...]
    codec: str
    data: bytes


def _local_device(devices: tuple) -> Any:
    """The replica device THIS process can address (multi-process jobs hold
    only their own shards; single-process emulation addresses all). The
    multi-host segment writer (`checkpoint/manager.py`, DESIGN.md §6.2)
    only ever asks for shards it OWNS (`dist.owner_host`), and the owner
    holds a replica by construction, so this raising means a caller
    skipped the ownership filter."""
    for d in devices:
        if getattr(d, "process_index", 0) == jax.process_index():
            return d
    raise ValueError(
        "no addressable replica of this shard on this process — fetch only "
        "segments owned by this host (dist.owner_host; DESIGN.md §6.2)"
    )


def encode_view_segment(
    view32: np.ndarray, sel: Selection, *, device_encode: bool = False
) -> tuple[str, bytes]:
    """Step 4 on one (shard of a) folded f32 view, mirroring
    `selector.encode_with_selection` including the never-bigger-than-raw
    safety net — applied per shard, so an incompressible shard of a
    compressible field degrades alone (DESIGN.md §6). Dispatches through
    the codec registry (DESIGN.md §2.1); with `device_encode`, codecs
    advertising the capability finish Stage III in-graph first and the
    host coder only runs when the device tier declines (DESIGN.md §3.7)."""
    if sel.codec == "raw":
        return "raw", view32.tobytes()
    codec = _codecs.get(sel.codec)
    data = None
    if device_encode and getattr(codec, "device_encode", False):
        data = codec.encode_device(view32, sel)
    if data is None:
        data = codec.encode(view32, sel)
    if len(data) >= view32.nbytes:
        return "raw", view32.tobytes()
    return sel.codec, data


def encode_plan(
    x: Any,
    plan: FieldPlan,
    host: int | None = None,
    *,
    device_encode: bool = False,
) -> list[Segment]:
    """Encode one field's bytes under its plan: per unique shard when the
    layout allows (each host touches only bytes it already holds), one
    gathered segment otherwise. Shard encoding reconstructs bit-identically
    to whole-field encoding because SZ's reconstruction is elementwise
    (`round(x/delta)*delta`) and ZFP's is 4-block-local with 4-aligned
    shard boundaries.

    `host=None` (single-controller) encodes EVERY segment. With a host
    index, only the segments that host OWNS are encoded — a replicated
    shard is written exactly once, by the process holding its lowest-id
    replica (`dist.owner_host`, the same rule on every host, so the
    per-host partition needs no coordination); gather-fallback fields
    write their single segment on host 0 (DESIGN.md §6.2)."""
    sel = plan.selection
    if not plan.sharded:
        if host is not None and host != 0:
            return []
        view = _view_of(dist.to_numpy(x))
        codec, data = encode_view_segment(view, sel, device_encode=device_encode)
        return [Segment((0,) * view.ndim, view.shape, codec, data)]
    segs = []
    for s in plan.layout.segs:
        if host is not None and dist.owner_host(s.devices) != host:
            continue
        local = rsh.shard_data(x, _local_device(s.devices))
        view = np.asarray(local, dtype=np.float32).reshape(
            tuple(b - a for a, b in zip(s.start, s.stop))
        )
        codec, data = encode_view_segment(view, sel, device_encode=device_encode)
        segs.append(Segment(s.start, s.stop, codec, data))
    return segs


def field_codec(sel_codec: str, segments: list) -> str:
    """The codec to RECORD for a field: the global decision bit, demoted
    to 'raw' when EVERY segment hit the never-bigger-than-raw safety net —
    mirroring the unsharded `encode_with_selection`, which rewrites the
    field codec when the whole stream failed to beat raw. Mixed outcomes
    keep the decision bit; the per-segment codecs in the manifest stay
    authoritative for decoding either way. Accepts `Segment`s or bare
    codec strings — the multi-host manifest assembler (DESIGN.md §6.2)
    evaluates the demote over the segment rows MERGED from every host's
    table, so the recorded codec matches the single-controller writer."""
    seg_codecs = [getattr(s, "codec", s) for s in segments]
    if sel_codec != "raw" and seg_codecs and all(c == "raw" for c in seg_codecs):
        return "raw"
    return sel_codec


def decode_segments(
    view_shape: tuple[int, ...], segments: list[Segment]
) -> np.ndarray:
    """Reassemble a field's f32 view from its (possibly per-shard) encoded
    segments — the elastic-restore core: any mesh (or none) can consume
    the result by resharding."""
    out = np.empty(view_shape, np.float32)
    for s in segments:
        extent = tuple(b - a for a, b in zip(s.start, s.stop))
        part = _codecs.get(s.codec).decode(s.data)
        out[tuple(slice(a, b) for a, b in zip(s.start, s.stop))] = part.reshape(extent)
    return out


__all__ = [
    "FieldLayout",
    "FieldPlan",
    "Segment",
    "ShardSeg",
    "analyze",
    "decode_segments",
    "encode_plan",
    "encode_view_segment",
    "plan_tree",
]
