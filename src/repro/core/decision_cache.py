"""Cross-step decision cache — the warm save path (DESIGN.md §8).

Successive training checkpoints are near-identical, yet every save re-ran
Algorithm 1's Stage I/II from scratch. FRaZ-style repeated-save workloads
(Underwood et al. 2020) and the black-box ratio-prediction results
(Underwood et al. 2023, arXiv 2305.08801) both observe that per-field
compression behavior is stable across steps unless the field's statistics
move; this module exploits that by carrying each field's decided
`Selection` (and, for the target modes of DESIGN.md §7, the solved
`TargetSolution`) forward from the previous save.

An entry is keyed by the tuple the decision is a pure function of:

    (field name, original shape, original dtype, Policy.spec(), transform)

`Policy.spec()` carries the mode AND its target value — including the
§7.4 metric targets (`target_ssim` / `target_correlation` / `target_ks`) —
so a `fixed_ssim(0.98)` entry can never collide with a `fixed_psnr(60)`
(or a `fixed_ssim(0.95)`) entry for the same field.

and guarded by a **stats fingerprint** (`core/predictor.py`): a content
digest over the exact sampled halo blocks Stage I consumes (plus vr, size
and the r_sp grid), together with the cheap residual moments. With the
default ``tolerance=0.0`` an entry validates only on digest equality —
the fingerprint then covers the entire preimage of the decision function,
so a validating hit *is* the decision the cold path would recompute, and
warm decisions/bounds/bytes are bit-identical to cold (the differential
suite in tests/test_decision_cache.py enforces this). ``tolerance > 0``
additionally accepts moment drift within a relative band; that trades
bit-identity for more hits and is safe for the quality contract either
way, because the codecs guarantee the *bound* (`eb_abs`, `eb_sz`) on
whatever data they encode — a stale decision can only cost rate
optimality, never correctness (DESIGN.md §8.3).

Invalidation is therefore structural: any change to shape, dtype, policy
or transform misses the key; any content drift beyond tolerance fails the
fingerprint; NaN-poisoned and degenerate fields never reach the cache at
all (the raw fallback of `selector._degenerate_selection` re-derives them
every save). The cache never serves a stale decision silently — every
lookup outcome lands in `events` and the hit/miss/invalidation counters.

`to_manifest` / `load_manifest` round-trip the cache through the
checkpoint manifest (JSON; floats survive exactly via repr round-trip),
so a restored run resumes warm (`checkpoint/manager.py`).
"""

from __future__ import annotations

import json
import math
import threading
from dataclasses import asdict, dataclass

from .controller import TargetSolution
from .policy import Policy
from .selector import Selection

#: fingerprint moment keys compared under ``tolerance > 0``, by
#: fingerprint kind (relative drift, each against its previous magnitude
#: floored by _TOL_FLOOR). 'blocks' fingerprints (host/select path) carry
#: residual moments; 'moments' fingerprints (sharded engine) carry the
#: psum-reconciled global value moments.
_MOMENT_KEYS = {
    "blocks": ("vr", "smin", "smax", "ra1", "rv2", "rk4"),
    "moments": ("vr", "smin", "smax", "mean", "msq"),
}
_TOL_FLOOR = 1e-30


def _policy_key(policy: Policy | str) -> str:
    """Canonical JSON of `Policy.spec()` — the manifest-v3 serialization,
    so the key survives the cache's own manifest round-trip."""
    if isinstance(policy, str):
        return policy
    return json.dumps(policy.spec(), sort_keys=True)


@dataclass
class CacheEntry:
    """One field's carried-forward decision + the fingerprint that guards it."""

    name: str
    shape: tuple[int, ...]
    dtype: str
    policy: str              # canonical Policy.spec() JSON
    transform: str
    fingerprint: dict        # predictor.fingerprint_of / sharded moments
    selection: dict          # Selection fields (dataclass asdict)
    solution: dict | None    # TargetSolution scalars for target modes
    step: int | None = None

    def to_selection(self) -> Selection:
        return Selection(**self.selection)

    def to_solution(self) -> TargetSolution:
        assert self.solution is not None
        return TargetSolution(selection=self.to_selection(), **self.solution)


def _entry_to_json(e: CacheEntry) -> dict:
    d = asdict(e)
    d["shape"] = list(e.shape)
    return d


def _entry_from_json(d: dict) -> CacheEntry:
    d = dict(d)
    d["shape"] = tuple(int(s) for s in d["shape"])
    return CacheEntry(**d)


class DecisionCache:
    """Cross-step per-field decision cache (DESIGN.md §8).

    Thread-safe (one lock around the entry map — `async_save` runs saves
    on a worker thread). Counters accumulate until `reset_stats()`;
    `events` holds the LAST lookup outcome per field name, which is what
    the golden trajectory and the bench hit-rate report consume.

    ``tolerance=0.0`` (default): entries validate only on fingerprint
    digest equality — warm decisions are bit-identical to cold.
    ``tolerance > 0``: entries additionally validate when every
    fingerprint moment drifted by less than `tolerance` relative to its
    previous value (vr-scale drift for the sample min/max) — more hits on
    slowly-moving fields, decisions possibly one step stale (bounds stay
    guaranteed; see the module docstring).

    ``warm_start=True`` lets the §7 controller seed its secant from an
    *invalidated* entry's solved bound (`stale`), cutting refinement
    rounds on drifted fields. Off by default: warm-started re-solves can
    differ from cold solves in ulps, and the default contract is
    bit-identity.
    """

    def __init__(self, tolerance: float = 0.0, warm_start: bool = False):
        if not (tolerance >= 0.0 and math.isfinite(tolerance)):
            raise ValueError(f"tolerance must be finite and >= 0, got {tolerance}")
        self.tolerance = float(tolerance)
        self.warm_start = bool(warm_start)
        self.entries: dict[str, CacheEntry] = {}
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.events: dict[str, str] = {}
        self._lock = threading.Lock()

    # -- lookup / store -----------------------------------------------------

    def _key_matches(
        self, e: CacheEntry, shape, dtype: str, pol_key: str, transform: str
    ) -> bool:
        return (
            e.shape == tuple(shape)
            and e.dtype == str(dtype)
            and e.policy == pol_key
            and e.transform == transform
        )

    def _fingerprint_valid(self, old: dict, new: dict) -> bool:
        if old.get("kind") != new.get("kind"):
            return False
        if old.get("digest") == new.get("digest"):
            return True
        if self.tolerance <= 0.0:
            return False
        # moment-drift band: every tracked moment must exist, be finite,
        # and sit within `tolerance` of its previous value. Value-location
        # moments (smin/smax) drift relative to the value range, not to
        # their own (possibly ~0) magnitude.
        keys = _MOMENT_KEYS.get(old.get("kind"))
        if keys is None:
            return False
        vr_scale = max(abs(float(old.get("vr", 0.0))), _TOL_FLOOR)
        for k in keys:
            if k not in old or k not in new:
                return False
            a, b = float(old[k]), float(new[k])
            if not (math.isfinite(a) and math.isfinite(b)):
                return False
            scale = vr_scale if k in ("smin", "smax") else max(abs(a), _TOL_FLOOR)
            if abs(b - a) > self.tolerance * scale:
                return False
        return True

    def lookup(
        self,
        name: str,
        shape,
        dtype: str,
        policy: Policy | str,
        transform: str,
        fingerprint: dict,
    ) -> CacheEntry | None:
        """The previous save's entry for `name` iff key AND fingerprint
        still hold; records the outcome ('hit' / 'miss' / 'invalidated')."""
        pol_key = _policy_key(policy)
        with self._lock:
            e = self.entries.get(name)
            if e is None:
                self.misses += 1
                self.events[name] = "miss"
                return None
            if not self._key_matches(e, shape, dtype, pol_key, transform):
                self.invalidations += 1
                self.events[name] = "invalidated"
                return None
            if not self._fingerprint_valid(e.fingerprint, fingerprint):
                self.invalidations += 1
                self.events[name] = "invalidated"
                return None
            self.hits += 1
            self.events[name] = "hit"
            return e

    def stale(
        self, name: str, shape, dtype: str, policy: Policy | str, transform: str
    ) -> CacheEntry | None:
        """The key-matching entry REGARDLESS of fingerprint — warm-start
        seed material for the §7 secant, never decision material."""
        pol_key = _policy_key(policy)
        with self._lock:
            e = self.entries.get(name)
            if e is not None and self._key_matches(e, shape, dtype, pol_key, transform):
                return e
            return None

    def store(
        self,
        name: str,
        shape,
        dtype: str,
        policy: Policy | str,
        transform: str,
        fingerprint: dict,
        selection: Selection,
        solution: TargetSolution | None = None,
        step: int | None = None,
    ) -> None:
        sol = None
        if solution is not None:
            sol = dict(
                mode=solution.mode, target=solution.target,
                est_psnr=solution.est_psnr, est_bitrate=solution.est_bitrate,
                on_target=solution.on_target, est_metric=solution.est_metric,
            )
        e = CacheEntry(
            name=name, shape=tuple(int(s) for s in shape), dtype=str(dtype),
            policy=_policy_key(policy), transform=transform,
            fingerprint=dict(fingerprint), selection=asdict(selection),
            solution=sol, step=step,
        )
        with self._lock:
            self.entries[name] = e

    # -- telemetry ----------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            looked = self.hits + self.misses + self.invalidations
            return dict(
                entries=len(self.entries), hits=self.hits, misses=self.misses,
                invalidations=self.invalidations,
                hit_rate=self.hits / looked if looked else 0.0,
            )

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = self.invalidations = 0
            self.events = {}

    def clear(self) -> None:
        with self._lock:
            self.entries = {}

    # -- manifest persistence (checkpoint/manager.py) -----------------------

    def to_manifest(self) -> dict:
        """JSON-safe record for manifest v3's `decision_cache` key. Floats
        round-trip exactly (json emits repr); inf/nan ride Python json's
        default non-strict handling, which our own readers accept."""
        with self._lock:
            return dict(
                version=1,
                tolerance=self.tolerance,
                entries=[_entry_to_json(e) for e in self.entries.values()],
            )

    def load_manifest(self, record: dict) -> None:
        """Merge a manifest record back in (restored runs resume warm).
        Existing same-name entries are overwritten — the manifest is the
        newer truth at restore time."""
        entries = [_entry_from_json(d) for d in record.get("entries", [])]
        with self._lock:
            for e in entries:
                self.entries[e.name] = e


__all__ = ["CacheEntry", "DecisionCache"]
