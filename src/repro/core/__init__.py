"""repro.core — the paper's contribution: rate-distortion-optimal online
selection between SZ-style (prediction-based) and ZFP-style (transform-based)
error-bounded lossy compression, plus the estimators that make it cheap."""

from . import codecs, quality
from .api import (
    CompressedField,
    CompressedTree,
    ShardedCompressedField,
    compress,
    compress_pytree,
    compression_ratio,
    decompress,
    decompress_pytree,
    select_and_compress,
)
from .controller import TargetSolution, estimate_curves, solve, solve_many
from .decision_cache import CacheEntry, DecisionCache
from .policy import Policy, PolicySet
from .predictor import (
    FieldStats,
    confidence,
    predict_curves,
    predict_selection,
    select_many_predicted,
)
from .selector import Selection, encode_with_selection, select, select_many
from .sz import SZStats, sz_compress, sz_decompress, sz_stats
from .zfp import ZFPStats, zfp_compress, zfp_decompress, zfp_stats

__all__ = [
    "CacheEntry",
    "CompressedField",
    "CompressedTree",
    "DecisionCache",
    "FieldStats",
    "Policy",
    "PolicySet",
    "Selection",
    "ShardedCompressedField",
    "SZStats",
    "TargetSolution",
    "ZFPStats",
    "codecs",
    "compress",
    "compress_pytree",
    "compression_ratio",
    "confidence",
    "decompress",
    "decompress_pytree",
    "encode_with_selection",
    "estimate_curves",
    "predict_curves",
    "predict_selection",
    "quality",
    "select",
    "select_and_compress",
    "select_many",
    "select_many_predicted",
    "solve",
    "solve_many",
    "sz_compress",
    "sz_decompress",
    "sz_stats",
    "zfp_compress",
    "zfp_decompress",
    "zfp_stats",
]
