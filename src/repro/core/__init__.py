"""repro.core — the paper's contribution: rate-distortion-optimal online
selection between SZ-style (prediction-based) and ZFP-style (transform-based)
error-bounded lossy compression, plus the estimators that make it cheap."""

from .api import (
    CompressedField,
    CompressedTree,
    compress_pytree,
    compression_ratio,
    decompress,
    decompress_pytree,
    select_and_compress,
)
from .selector import Selection, encode_with_selection, select, select_many
from .sz import SZStats, sz_compress, sz_decompress, sz_stats
from .zfp import ZFPStats, zfp_compress, zfp_decompress, zfp_stats

__all__ = [
    "CompressedField",
    "CompressedTree",
    "Selection",
    "SZStats",
    "ZFPStats",
    "compress_pytree",
    "compression_ratio",
    "decompress",
    "decompress_pytree",
    "encode_with_selection",
    "select",
    "select_and_compress",
    "select_many",
    "sz_compress",
    "sz_decompress",
    "sz_stats",
    "zfp_compress",
    "zfp_decompress",
    "zfp_stats",
]
