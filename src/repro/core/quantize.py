"""Stage II static vector quantization (paper §5.1).

Implements the three analyzed quantizer families (§5.1.4):

* linear  — SZ's equal-width bins, width delta = 2*eb (error <= eb).
* log     — log-scale bins (finer near zero; higher PSNR, worse entropy).
* equiprob — equal-probability bins (NUMARCK-style).

All quantizers return integer codes; `dequantize_*` reconstructs the bin
midpoint (the paper's "estimated value").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# -- linear (SZ) ------------------------------------------------------------


def linear_quantize(x: jax.Array, eb: float) -> jax.Array:
    """Prequantization onto the uniform grid with bin size 2*eb.

    |x - dequantize(quantize(x))| <= eb by construction (Theorem 1 then
    carries this bound through the Lorenzo PBT unchanged).
    """
    delta = 2.0 * eb
    return jnp.round(x / delta).astype(jnp.int32)


def linear_dequantize(codes: jax.Array, eb: float, dtype=jnp.float32) -> jax.Array:
    return (codes.astype(jnp.float64) * (2.0 * eb)).astype(dtype)


# -- log-scale (§5.1.4) ------------------------------------------------------


def log_quantize(
    x: jax.Array, n_bins_half: int, max_abs: float, dynamic_range: float = 1e6
) -> tuple[jax.Array, jax.Array]:
    """Log-scale quantization with ~2n-1 bins refining toward zero (§5.1.4):
    bin k covers max_abs * (b^(k-1), b^k] for k in (-n+1, 0]; |x| below the
    dynamic-range floor maps to the zero bin. Returns (codes, [b, max])."""
    n = n_bins_half
    mx = jnp.maximum(jnp.asarray(max_abs, jnp.float32), 1e-30)
    b = jnp.exp(jnp.log(jnp.asarray(dynamic_range, jnp.float32)) / n)
    mag = jnp.abs(x) / mx
    k = jnp.ceil(jnp.log(jnp.maximum(mag, 1e-30)) / jnp.log(b))  # <= 0
    k = jnp.clip(k, -(n - 1), 0)
    dead = mag < 1.0 / dynamic_range
    code = jnp.where(dead, 0, (k + n) * jnp.sign(x))
    return code.astype(jnp.int32), jnp.stack([b, mx])


def log_dequantize(codes: jax.Array, b_mx: jax.Array, dtype=jnp.float32, n_bins_half: int | None = None) -> jax.Array:
    """Inverse: geometric-midpoint reconstruction. `n_bins_half` must match
    the encoder's (defaults to inferring from the max code)."""
    b, mx = b_mx[0], b_mx[1]
    n = n_bins_half if n_bins_half is not None else jnp.max(jnp.abs(codes))
    k = jnp.abs(codes).astype(jnp.float32) - n  # <= 0
    mid = jnp.where(
        codes == 0,
        0.0,
        jnp.sign(codes).astype(jnp.float32) * mx * b ** (k - 0.5),
    )
    return mid.astype(dtype)


# -- equal-probability (NUMARCK-style, §5.1.4) --------------------------------


def equiprob_edges(x: jax.Array, n_bins: int) -> jax.Array:
    """Bin edges at equally spaced quantiles (the clustering approximation)."""
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)
    return jnp.quantile(x.reshape(-1).astype(jnp.float64), qs)


def equiprob_quantize(x: jax.Array, edges: jax.Array) -> jax.Array:
    return jnp.clip(jnp.searchsorted(edges, x.reshape(-1), side="right") - 1, 0, edges.shape[0] - 2).reshape(x.shape).astype(jnp.int32)


def equiprob_dequantize(codes: jax.Array, edges: jax.Array, dtype=jnp.float32) -> jax.Array:
    mids = (edges[:-1] + edges[1:]) / 2.0
    return mids[codes].astype(dtype)
