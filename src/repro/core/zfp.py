"""ZFP-style transform-based error-bounded lossy compressor (paper §2, §5.2).

Pipeline (Fig. 1): 4^n blocking -> exponent alignment -> fixed point ->
block orthogonal transform T(t) -> bit-plane embedded coding.

Two paths, mirroring sz.py:
  * `zfp_stats`     — jnp/jit-safe reconstruction + exact rate/distortion.
  * `zfp_compress` / `zfp_decompress` — host numpy byte codec with a real,
    decodable, *plane-sectioned group-tested* embedded coder (DESIGN.md §3.2):
    the bit stream is laid out plane-major across all blocks so both encode
    and decode are fully vectorized over blocks (TPU/SIMD-friendly layout,
    unlike ZFP's per-block serial group testing — same rate regime).

Pointwise guarantee: |x - x~| <= eb via the conservative plane cutoff
(`embedded.plane_step`), which is exactly why ZFP "over-preserves" error
relative to the bound (paper §6.4) and thus reaches a higher PSNR than SZ
at the same eb.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .embedded import (
    align_blocks,
    exact_coder_bits,
    plane_step,
    reconstruct_truncated,
)
from .transforms import blockize, bot_linf_gain, bot_matrix, block_transform_nd, unblockize

_MAGIC = b"ZFJX"


# ---------------------------------------------------------------------------
# in-graph statistics path
# ---------------------------------------------------------------------------


@dataclass
class ZFPStats:
    bitrate: jax.Array
    psnr: jax.Array
    mse: jax.Array
    recon: jax.Array
    mean_nsb: jax.Array  # the paper's n_sb-bar estimate target


def zfp_stats(x: jax.Array, eb: jax.Array | float, transform: str = "zfp") -> ZFPStats:
    """Exact rate/distortion of the ZFP path, computed in-graph."""
    xf = x.astype(jnp.float32)
    n = xf.ndim
    T = bot_matrix(transform)
    gain_n = bot_linf_gain(transform) ** n
    blocks, padded = blockize(xf)
    norm, e = align_blocks(blocks)
    coeffs = block_transform_nd(norm, jnp.asarray(T, jnp.float32), n)
    step = plane_step(jnp.asarray(eb, jnp.float32), e, gain_n)
    rec_coeffs = reconstruct_truncated(coeffs, step)
    total_bits = exact_coder_bits(coeffs, step)
    rec_norm = block_transform_nd(rec_coeffs, jnp.asarray(T, jnp.float32), n, inverse=True)
    shape = (-1,) + (1,) * n
    rec_blocks = rec_norm * jnp.exp2(e.astype(jnp.float32)).reshape(shape)
    recon = unblockize(rec_blocks, padded, xf.shape)
    from .embedded import significant_bits

    nsb = significant_bits(coeffs, step)
    err = xf - recon
    mse = jnp.mean(jnp.square(err.astype(jnp.float32)))
    vr = jnp.maximum(jnp.max(xf) - jnp.min(xf), 1e-30).astype(jnp.float32)
    psnr = -10.0 * jnp.log10(jnp.maximum(mse, 1e-60) / (vr * vr))
    bitrate = total_bits / xf.size
    return ZFPStats(bitrate=bitrate, psnr=psnr, mse=mse, recon=recon, mean_nsb=jnp.mean(nsb))


# ---------------------------------------------------------------------------
# host byte codec
# ---------------------------------------------------------------------------


def _prepare_blocks(x: np.ndarray, eb: float, transform: str):
    n = x.ndim
    T = bot_matrix(transform)  # float64
    gain_n = bot_linf_gain(transform) ** n
    blocks, padded = blockize(jnp.asarray(x, jnp.float32))
    blocks = np.asarray(blocks, dtype=np.float64)
    mx = np.maximum(np.abs(blocks).reshape(blocks.shape[0], -1).max(axis=1), 1e-30)
    e = np.ceil(np.log2(mx)).astype(np.int16)
    norm = blocks * np.exp2(-e.astype(np.float64)).reshape((-1,) + (1,) * n)
    coeffs = norm
    for axis in range(1, n + 1):
        coeffs = np.moveaxis(np.tensordot(coeffs, T, axes=[[axis], [1]]), -1, axis)
    raw = eb / (np.exp2(e.astype(np.float64)) * gain_n)
    pexp = np.floor(np.log2(np.maximum(raw, 2.0**-60)))
    step = np.exp2(pexp)
    q = np.trunc(coeffs.reshape(coeffs.shape[0], -1) / step[:, None]).astype(np.int64)
    return q, e, step, padded, gain_n, T


def _degree_order(nd: int) -> np.ndarray:
    """ZFP's total-degree coefficient ordering within a 4^nd block: low-degree
    (high-energy) coefficients first, so the significance staircase is
    monotone-ish and the k-prefix coder below stays near n_sb-bar bits."""
    idx = np.indices((4,) * nd).reshape(nd, -1)
    degree = idx.sum(axis=0)
    return np.argsort(degree, kind="stable")


def _k_width(bsz: int) -> int:
    """Bits to encode k in [0, bsz]."""
    return int(np.ceil(np.log2(bsz + 1)))


def _emit_planes(m: np.ndarray, neg: np.ndarray, nsb: np.ndarray) -> list[np.ndarray]:
    """Plane-major, degree-ordered k-prefix significance coding.

    Per plane & block: refinement bits of significant coeffs; a fixed-width
    k = 1 + rank of the last newly-significant remaining coefficient (0 if
    none); significance bits of the first k remaining coefficients only;
    signs of the newly significant. Vectorized over all blocks (m must
    already be in degree order).
    """
    parts: list[np.ndarray] = []
    nblk, bsz = m.shape
    w = _k_width(bsz)
    kshift = np.arange(w - 1, -1, -1, dtype=np.int64)
    maxp = int(nsb.max()) if nsb.size else 0
    for p in range(maxp - 1, -1, -1):
        active = nsb > p
        if not active.any():
            continue
        act = active[:, None]
        sig_prev = (m >> (p + 1)) > 0
        bit_p = ((m >> p) & 1).astype(np.uint8)
        # 1) refinement bits of already-significant coefficients
        parts.append(bit_p[act & sig_prev])
        # 2) k per active block with remaining coeffs (fixed width w)
        rem = act & ~sig_prev
        has_rem = rem.any(axis=1) & active
        rank = np.cumsum(rem, axis=1) - 1  # rank among remaining, valid on rem
        newly = rem & (bit_p == 1)
        k = np.max(np.where(newly, rank + 1, 0), axis=1)  # (nblk,)
        kb = ((k[has_rem, None] >> kshift[None, :]) & 1).astype(np.uint8)
        parts.append(kb.reshape(-1))
        # 3) significance bits of the first k remaining coefficients
        test = rem & (rank < k[:, None])
        parts.append(bit_p[test])
        # 4) signs of newly-significant coefficients
        parts.append(neg[newly].astype(np.uint8))
    return parts


def _read_planes(bits: np.ndarray, pos: int, nblk: int, bsz: int, nsb: np.ndarray):
    m = np.zeros((nblk, bsz), dtype=np.int64)
    neg = np.zeros((nblk, bsz), dtype=bool)
    w = _k_width(bsz)
    kweights = (1 << np.arange(w - 1, -1, -1)).astype(np.int64)
    maxp = int(nsb.max()) if nsb.size else 0
    for p in range(maxp - 1, -1, -1):
        active = nsb > p
        if not active.any():
            continue
        act = active[:, None]
        sig_prev = m > 0  # m currently holds bits above plane p
        m[active] <<= 1
        # 1) refinement
        ref_mask = act & sig_prev
        nref = int(ref_mask.sum())
        if nref:
            m[ref_mask] |= bits[pos : pos + nref]
        pos += nref
        # 2) k values
        rem = act & ~sig_prev
        has_rem = rem.any(axis=1) & active
        ngrp = int(has_rem.sum())
        k = np.zeros(nblk, dtype=np.int64)
        if ngrp:
            kb = bits[pos : pos + ngrp * w].reshape(ngrp, w)
            k[has_rem] = kb @ kweights
        pos += ngrp * w
        # 3) significance bits of the first k remaining coefficients
        rank = np.cumsum(rem, axis=1) - 1
        test = rem & (rank < k[:, None])
        nbm = int(test.sum())
        newly = np.zeros_like(rem)
        if nbm:
            bmb = bits[pos : pos + nbm]
            m[test] |= bmb
            newly[test] = bmb.astype(bool)
        pos += nbm
        # 4) signs
        nnew = int(newly.sum())
        if nnew:
            neg[newly] = bits[pos : pos + nnew].astype(bool)
        pos += nnew
    return m, neg, pos


def zfp_container(
    shape: tuple[int, ...],
    padded: tuple[int, ...],
    eb: float,
    transform: str,
    e: np.ndarray,
    nsb: np.ndarray,
    nbits: int,
    payload: bytes,
) -> bytes:
    """Assemble the ZFJX container around an already-packed plane payload.
    Shared by the host Stage III (`zfp_encode_quantized`) and the device
    encode tier (`core/device_encode.py`), whose in-graph plane emitter
    produces the identical plane-major bit stream (DESIGN.md §3.7)."""
    n = len(shape)
    hdr = struct.pack("<4sBdQ", _MAGIC, n, float(eb), len(e)) + struct.pack(
        f"<{n}q{n}q", *shape, *padded
    )
    return b"".join(
        [
            hdr,
            transform.encode().ljust(16, b"\0"),
            np.asarray(e, np.int16).tobytes(),
            np.asarray(nsb, np.uint8).tobytes(),
            struct.pack("<Q", int(nbits)),
            payload,
        ]
    )


def zfp_encode_quantized(
    q: np.ndarray,
    e: np.ndarray,
    shape: tuple[int, ...],
    padded: tuple[int, ...],
    eb: float,
    transform: str = "zfp",
) -> bytes:
    """Stage III on precomputed quantized block coefficients: degree
    ordering, plane-sectioned emission, container. `q` is (nblk, 4^n) in
    *raw* (pre-degree-order) layout, `e` the per-block exponents. Split
    from `zfp_compress` so the device-encode parity suite can run the host
    coder on *device-computed* codes and diff streams byte for byte
    (DESIGN.md §3.7)."""
    n = len(shape)
    q = np.asarray(q, dtype=np.int64).reshape(len(e), 4**n)
    order = _degree_order(n)
    q = q[:, order]  # degree-ordered layout for the k-prefix coder
    m = np.abs(q)
    neg = q < 0
    mx = m.max(axis=1) if m.size else np.zeros(0, dtype=np.int64)
    nsb = np.zeros(len(m), dtype=np.uint8)
    nz = mx > 0
    nsb[nz] = np.floor(np.log2(mx[nz])).astype(np.uint8) + 1
    parts = _emit_planes(m, neg, nsb)
    allbits = np.concatenate(parts) if parts else np.zeros(0, dtype=np.uint8)
    payload = np.packbits(allbits).tobytes()
    return zfp_container(
        shape, padded, eb, transform, e, nsb, int(allbits.size), payload
    )


def zfp_compress(x: np.ndarray, eb: float, transform: str = "zfp") -> bytes:
    x = np.asarray(x, dtype=np.float32)
    q, e, step, padded, gain_n, _ = _prepare_blocks(x, eb, transform)
    return zfp_encode_quantized(q, e, x.shape, padded, eb, transform)


def zfp_decompress(buf: bytes) -> np.ndarray:
    off = 0
    magic, n, eb, nblk = struct.unpack_from("<4sBdQ", buf, off)
    assert magic == _MAGIC, "not a ZFJX stream"
    off += struct.calcsize("<4sBdQ")
    dims = struct.unpack_from(f"<{n}q{n}q", buf, off)
    off += 16 * n
    shape, padded = tuple(dims[:n]), tuple(dims[n:])
    transform = buf[off : off + 16].rstrip(b"\0").decode()
    off += 16
    e = np.frombuffer(buf[off : off + 2 * nblk], dtype=np.int16)
    off += 2 * nblk
    nsb = np.frombuffer(buf[off : off + nblk], dtype=np.uint8)
    off += nblk
    (nbits,) = struct.unpack_from("<Q", buf, off)
    off += 8
    bits = np.unpackbits(np.frombuffer(buf[off:], dtype=np.uint8))[:nbits].astype(np.int64)
    bsz = 4**n
    m, neg, _ = _read_planes(bits, 0, nblk, bsz, nsb.astype(np.int64))
    inv = np.argsort(_degree_order(n))  # undo the degree-ordered layout
    m = m[:, inv]
    neg = neg[:, inv]
    gain_n = bot_linf_gain(transform) ** n
    raw = eb / (np.exp2(e.astype(np.float64)) * gain_n)
    step = np.exp2(np.floor(np.log2(np.maximum(raw, 2.0**-60))))
    mag = np.where(m > 0, (m.astype(np.float64) + 0.5) * step[:, None], 0.0)
    coeffs = np.where(neg, -mag, mag).reshape((nblk,) + (4,) * n)
    T = bot_matrix(transform)
    rec = coeffs
    for axis in range(1, n + 1):
        rec = np.moveaxis(np.tensordot(rec, T.T, axes=[[axis], [1]]), -1, axis)
    rec = rec * np.exp2(e.astype(np.float64)).reshape((-1,) + (1,) * n)
    out = unblockize(jnp.asarray(rec, jnp.float32), padded, shape)
    return np.asarray(out, dtype=np.float32)
