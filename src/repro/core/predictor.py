"""Statistical ratio/PSNR prediction + stats fingerprints (DESIGN.md §8).

"Black-Box Statistical Prediction of Lossy Compression Ratios for
Scientific Data" (Underwood et al. 2023, arXiv 2305.08801) shows that a
handful of cheap per-field statistics predict compression-ratio curves
well enough to skip sampled estimation for most fields. This module is
that idea fitted to our Algorithm-1 pipeline (paper §5.3):

* `stats_for_members` computes per-field **moments** — value range,
  sample min/max, Lorenzo-residual absolute/second/fourth moments
  (variance, kurtosis), a value-variance spectral-slope proxy, and a
  host-side residual IQR — over exactly the packed halo-block batch that
  `selector._select_batch` launches for Stage I (same padding buckets,
  same field-ordered prefix-sum reduction, `estimator.field_sums`), so
  the warm path adds one tiny jitted launch per (nd, bucket) and the
  cold path pays nothing.
* `predict_curves` turns those moments into predicted bitrate/PSNR
  curves for both codecs: SZ rides the Gaussian-entropy rate of the
  quantized residual (monotone non-increasing in the error bound by
  construction) with Eq. (11) PSNR; ZFP rides a significant-bit-plane
  model of the same residual scale. `predict_selection` then replays
  Algorithm 1 (iso-PSNR match, min-rate pick) on the predicted curves.
* every prediction carries a **confidence** in [0, 1] built from sample
  size, residual kurtosis (heavy tails break the entropy model), and the
  Laplacian-vs-Gaussian shape ratio; `select_many_predicted` routes
  fields below `CONFIDENCE_THRESHOLD` — and all degenerate fields — to
  the existing sampled estimator, keeping the quality contract exact
  where the model is least trustworthy (the arXiv 2310.14133 stance:
  cheapen the estimate, never the contract).
* `fingerprint_of` digests the sampled halo blocks + (vr, size, r_sp)
  into the content fingerprint `core/decision_cache.py` keys on: the
  digest covers the complete preimage of the batched Stage-I decision,
  which is what makes a validated cache hit bit-identical to cold.

Prediction is OPT-IN (`select_many_predicted`); the default
`select_many` path always runs the sampled estimator, so frozen goldens
and the paper-replication benches are untouched.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from functools import lru_cache as _lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import codecs as _codecs
from . import estimator as est
from . import selector as _sel

#: predictions below this confidence route to the sampled estimator
CONFIDENCE_THRESHOLD = 0.5
#: fields with fewer sampled residuals than this never predict (the
#: moment estimates are too noisy to beat one cheap sampled launch)
MIN_CONFIDENT_SIZE = 4096
#: ZFP's measured truncation error sits WELL below the bound `eb` (the
#: kept bit-planes quantize most coefficients much finer than the last
#: one): PSNR_sp lands 23-34 dB above the naive -20*log10(eb/vr) across
#: the bench suites. The center of that band, calibrated against
#: `estimate_zfp(mode='exact')`; the +-6 dB spread costs the iso-PSNR
#: match about one bit of predicted SZ rate.
ZFP_PSNR_OFFSET = 28.0
#: residual kurtosis above the Gaussian/Laplacian band (3..6) decays
#: confidence with this scale — heavy tails break the entropy model
KURTOSIS_SCALE = 10.0
#: fingerprint format tag; bump on any change to the digest preimage
_FP_TAG = b"repro-dc1"

_LOG2_2PIE = math.log2(2.0 * math.pi * math.e)


@dataclass
class FieldStats:
    """Cheap per-field sufficient statistics (moments normalized by vr)."""

    vr: float          # value range (max - min of the folded f32 view)
    size: int          # folded element count
    n_blocks: int      # sampled blocks backing the moments
    smin: float        # sampled min / max (vr-normalized to [0, 1] span)
    smax: float
    ra1: float         # mean |residual| / vr
    rv2: float         # mean residual^2 / vr^2 (residual variance proxy)
    rk4: float         # mean residual^4 / vr^4
    vv2: float         # value variance / vr^2 (spectral-slope proxy:
                       # rv2/vv2 is the high-frequency energy fraction)
    iqr: float         # residual interquartile range / vr (host-side)
    nd: int
    r_sp: float

    @property
    def kurtosis(self) -> float:
        return self.rk4 / max(self.rv2 * self.rv2, 1e-38)


# ---------------------------------------------------------------------------
# Packed moments launch — same batch layout as selector._select_batch
# ---------------------------------------------------------------------------


@_lru_cache(maxsize=64)
def _moments_jitted(nd: int, n_blocks: int, n_fields: int):
    """Per-field moment reduction over a packed halo-block batch.

    Mirrors `_batched_estimates_jitted`'s cache discipline: one compile
    per (ndim, padded block bucket, padded field bucket). The residual is
    the nd-fold backward difference of the halo block — the same
    first-order Lorenzo stencil Stage I samples — normalized per field by
    vr so the f32 prefix sums stay comparable across co-batched fields
    (the `field_sums` contract)."""

    def f(halo, seg, bounds, vr_f):
        nohalo = halo[(slice(None),) + (slice(1, None),) * nd]
        d = halo
        for ax in range(1, nd + 1):
            d = jnp.diff(d, axis=ax)
        inv_vr = 1.0 / jnp.maximum(vr_f, 1e-30)
        dn = d.reshape(d.shape[0], -1) * inv_vr[seg][:, None]
        vn = nohalo.reshape(nohalo.shape[0], -1) * inv_vr[seg][:, None]
        cols = jnp.stack(
            [
                jnp.sum(jnp.abs(dn), axis=1),
                jnp.sum(dn * dn, axis=1),
                jnp.sum((dn * dn) * (dn * dn), axis=1),
                jnp.sum(vn, axis=1),
                jnp.sum(vn * vn, axis=1),
            ],
            axis=1,
        )
        sums = est.field_sums(cols, bounds)  # (n_fields, 5)
        bmin = jnp.min(nohalo.reshape(nohalo.shape[0], -1), axis=1)
        bmax = jnp.max(nohalo.reshape(nohalo.shape[0], -1), axis=1)
        fmin = jnp.full((n_fields,), jnp.inf, jnp.float32).at[seg].min(bmin)
        fmax = jnp.full((n_fields,), -jnp.inf, jnp.float32).at[seg].max(bmax)
        return sums, fmin, fmax

    return jax.jit(f)


def fingerprint_of(
    halo: np.ndarray, vr: float, size: int, r_sp: float
) -> str:
    """Content digest over the complete preimage of the batched Stage-I
    decision for one field: the sampled halo blocks themselves plus the
    (vr, size, r_sp) scalars the estimators consume. Equal digests =>
    `_select_batch` is a pure function of equal inputs => equal decision."""
    h = hashlib.blake2b(digest_size=16)
    h.update(_FP_TAG)
    h.update(np.asarray(halo.shape, np.int64).tobytes())
    h.update(np.asarray([vr, float(size), r_sp], np.float64).tobytes())
    h.update(np.ascontiguousarray(halo, dtype=np.float32).tobytes())
    return h.hexdigest()


def stats_for_members(
    nd: int,
    members: list[tuple[int, np.ndarray, float, float, int]],
    r_sp: float,
) -> list[tuple[FieldStats, dict]]:
    """(FieldStats, fingerprint record) per member, in member order.

    `members` are `_build_select_members` tuples
    (result index, halo blocks, eb, vr, size); the launch is chunked by
    the same per-ndim block/field caps as `_run_select_batches`."""
    out: list[tuple[FieldStats, dict]] = []
    cap = _sel._max_batch_blocks(nd)
    lo = 0
    while lo < len(members):
        hi, blocks = lo, 0
        while hi < len(members) and (
            hi == lo
            or (
                blocks + len(members[hi][1]) <= cap
                and hi - lo < _sel.MAX_BATCH_FIELDS
            )
        ):
            blocks += len(members[hi][1])
            hi += 1
        out.extend(_stats_batch(nd, members[lo:hi], r_sp))
        lo = hi
    return out


def _stats_batch(nd, members, r_sp) -> list[tuple[FieldStats, dict]]:
    halo = np.concatenate([m[1] for m in members], axis=0)
    seg = np.concatenate(
        [np.full(len(m[1]), f, dtype=np.int32) for f, m in enumerate(members)]
    )
    n_real_blocks, n_real_fields = len(seg), len(members)
    n_blocks = _sel._next_pow2(n_real_blocks)
    n_fields = _sel._next_pow2(n_real_fields + 1)
    pad = n_blocks - n_real_blocks
    if pad:
        halo_p = np.concatenate(
            [halo, np.zeros((pad,) + halo.shape[1:], np.float32)]
        )
        seg_p = np.concatenate([seg, np.full(pad, n_fields - 1, np.int32)])
    else:
        halo_p, seg_p = halo, seg
    bounds = np.zeros(n_fields + 1, np.int32)
    bounds[1 : n_real_fields + 1] = np.cumsum([len(m[1]) for m in members])
    bounds[n_real_fields + 1 :] = n_real_blocks
    bounds[n_fields] = n_blocks
    vr_l = [m[3] for m in members] + [1.0] * (n_fields - n_real_fields)
    fn = _moments_jitted(nd, n_blocks, n_fields)
    sums, fmin, fmax = fn(
        jnp.asarray(halo_p), jnp.asarray(seg_p), jnp.asarray(bounds),
        jnp.asarray(vr_l, jnp.float32),
    )
    sums = np.asarray(sums)
    fmin, fmax = np.asarray(fmin), np.asarray(fmax)
    nblk_f = np.diff(bounds)[:n_real_fields]
    bsz = 4**nd
    out = []
    for f, (_, blocks_f, _eb, vr, size) in enumerate(members):
        nres = float(max(int(nblk_f[f]) * bsz, 1))
        ra1, rv2, rk4, sv1, sv2 = (float(s) / nres for s in sums[f])
        vv2 = max(sv2 - sv1 * sv1, 0.0)
        # host-side residual IQR on the same nd-fold difference (sampled
        # blocks only — a percentile has no prefix-sum form)
        d = blocks_f
        for ax in range(1, nd + 1):
            d = np.diff(d, axis=ax)
        dn = d.reshape(-1) / max(vr, 1e-30)
        q75, q25 = np.percentile(dn, [75.0, 25.0]) if dn.size else (0.0, 0.0)
        stats = FieldStats(
            vr=vr, size=int(size), n_blocks=int(nblk_f[f]),
            smin=float(fmin[f]), smax=float(fmax[f]),
            ra1=ra1, rv2=rv2, rk4=rk4, vv2=vv2, iqr=float(q75 - q25),
            nd=nd, r_sp=r_sp,
        )
        fp = dict(
            kind="blocks",
            digest=fingerprint_of(blocks_f, vr, int(size), r_sp),
            vr=vr, size=int(size), n=int(nblk_f[f]),
            smin=stats.smin, smax=stats.smax,
            ra1=ra1, rv2=rv2, rk4=rk4,
        )
        out.append((stats, fp))
    return out


# ---------------------------------------------------------------------------
# Predicted rate/PSNR curves + Algorithm 1 on the model
# ---------------------------------------------------------------------------


#: quadrature resolution for the expected-occupancy integrals of the SZ
#: rate model (bins grouped by residual quantile, O(1) per error bound)
_QUAD_K = 512
#: per-value overhead of the exact ZFP coder over the pure bit-plane
#: count (group tests, sign/guard bits, per-block exponent ramp) —
#: calibrated against `estimate_zfp(mode='exact')` on the bench suites
ZFP_RATE_OVERHEAD = 5.4


def _sz_bitrate_model(stats: FieldStats, eb_sz: np.ndarray) -> np.ndarray:
    """Expected SAMPLED-ESTIMATOR SZ rate at half-bin `eb_sz` under the
    Gaussian residual model (std sqrt(rv2)*vr, bin size 2*eb_sz).

    Prices exactly what `estimator.sz_bitrate_from_hist` prices, term by
    term, in expectation over an r_sp sample of n_samp residuals:

    * entropy of the delta-quantized Gaussian (analytic, capped at the
      log2(n_samp) a finite sample can exhibit) + the Miller-Madow bias
      term the estimator adds back;
    * the Chao1 Huffman-table cost: expected occupied bins / singleton /
      doubleton counts from Poissonized bin occupancy (lambda_k =
      n_samp * P(bin k)), integrated in residual-quantile space so the
      cost is O(_QUAD_K) no matter how many bins the bound implies;
    * the 64-bit escape payload for residuals beyond +-half bins.

    The result is forced monotone non-increasing in eb_sz (the physical
    truth; the occupancy quadrature can wiggle by ulps at coarse bins)."""
    sigma = math.sqrt(max(stats.rv2, 1e-38)) * max(stats.vr, 1e-30)
    n_samp = float(max(stats.n_blocks, 1) * 4**stats.nd)
    size = float(max(stats.size, 1))
    half = (est.PDF_BINS - 1) // 2
    eb_arr = np.asarray(eb_sz, np.float64)
    delta = 2.0 * np.maximum(np.atleast_1d(eb_arr), 1e-300)
    q = delta / sigma                      # bin width in residual-sigma units
    t_max = np.minimum(8.0, half * q)      # integrate to 8 sigma or the clip
    grid = (np.arange(_QUAD_K, dtype=np.float64) + 0.5) / _QUAD_K
    t = grid[None, :] * t_max[:, None]     # (n_eb, K) midpoints
    dt = (t_max / _QUAD_K)[:, None]
    phi = np.exp(-0.5 * t * t) / math.sqrt(2.0 * math.pi)
    lam = n_samp * q[:, None] * phi        # expected sample count per bin
    nbins = 2.0 * dt / q[:, None]          # bins per quadrature cell (+-t)
    n_obs = np.sum(nbins * -np.expm1(-lam), axis=1)
    f1 = np.sum(nbins * lam * np.exp(-lam), axis=1)
    f2 = np.sum(nbins * 0.5 * lam * lam * np.exp(-lam), axis=1)
    chao1 = n_obs + f1 * np.maximum(f1 - 1.0, 0.0) / (2.0 * (f2 + 1.0))
    table = est.TABLE_BITS_PER_SYMBOL * np.minimum(chao1, est.PDF_BINS) / size
    with np.errstate(divide="ignore"):
        ent = np.sum(
            2.0 * dt * phi * -np.log2(np.maximum(q[:, None] * phi, 1e-300)),
            axis=1,
        )
    ent = np.minimum(np.maximum(ent, 0.0), math.log2(max(n_samp, 2.0)))
    ent = ent + (n_obs - 1.0) / (2.0 * n_samp * est.LN2)   # Miller-Madow
    ofrac = np.array(
        [math.erfc(min(v, 30.0) / math.sqrt(2.0)) for v in half * q]
    )
    rate = ent + est.SZ_BITRATE_OFFSET + 64.0 * ofrac + table
    # enforce the physical monotonicity in the bound
    order = np.argsort(delta)
    mono = np.minimum.accumulate(rate[order])
    rate = np.empty_like(rate)
    rate[order] = mono
    return rate.reshape(eb_arr.shape) if eb_arr.shape else rate[0]


def _zfp_bitrate_model(stats: FieldStats, eb: np.ndarray) -> np.ndarray:
    """ZFP rate at bound `eb`: a significant-bit-plane count model. Of a
    4^nd block's coefficients, the AC mass sits at the residual scale
    (log2(2*sigma/eb) planes significant) and one DC coefficient at the
    value scale (log2(vr/2/eb) planes); per-value group/sign overhead and
    the header amortize over the block, plus the calibrated
    `ZFP_RATE_OVERHEAD` of the exact coder. Monotone non-increasing in
    eb."""
    bsz = 4**stats.nd
    sigma = math.sqrt(max(stats.rv2, 1e-38)) * max(stats.vr, 1e-30)
    eb = np.maximum(np.asarray(eb, np.float64), 1e-300)
    ac = np.maximum(np.log2(2.0 * sigma / eb), 0.0)
    dc = np.maximum(np.log2(0.5 * max(stats.vr, 1e-30) / eb), 0.0)
    rate = ((bsz - 1) * ac + dc) / bsz + 8.0 / bsz + 0.25
    # cap at the 32 b/v raw fallback (controller.RAW_BITS): past that the
    # selector stores raw f32 anyway
    return np.minimum(rate + ZFP_RATE_OVERHEAD, 32.0)


def _zfp_psnr_model(stats: FieldStats, eb: np.ndarray) -> np.ndarray:
    eb_rel = np.maximum(np.asarray(eb, np.float64), 1e-300) / max(
        stats.vr, 1e-30
    )
    return -20.0 * np.log10(eb_rel) + ZFP_PSNR_OFFSET


def predict_curves(stats: FieldStats, ebs) -> dict:
    """Predicted (bitrate, PSNR) curves for both codecs at absolute error
    bounds `ebs` — the black-box curves of arXiv 2305.08801, from moments
    alone. SZ's PSNR is exact Eq. (11); rates are models."""
    ebs = np.asarray(ebs, np.float64)
    return dict(
        eb=ebs,
        br_sz=_sz_bitrate_model(stats, ebs),
        br_zfp=_zfp_bitrate_model(stats, ebs),
        psnr_sz=np.asarray(
            -20.0 * np.log10(np.maximum(ebs / max(stats.vr, 1e-30), 1e-300))
            + 10.0 * math.log10(3.0)
        ),
        psnr_zfp=_zfp_psnr_model(stats, ebs),
    )


def confidence(stats: FieldStats) -> float:
    """How much to trust the moment model for this field, in [0, 1].

    Hard zeros: degenerate value range, non-finite or non-positive
    residual variance (constant fields). Soft factors: sample size
    (tiny fields -> noisy moments), residual kurtosis above the
    Gaussian/Laplacian band (heavy tails break the entropy model), and
    the |.|-to-std shape ratio drifting from the Gaussian sqrt(2/pi)
    (multi-modal / spiky residuals)."""
    if not (stats.vr > 0.0 and math.isfinite(stats.vr)):
        return 0.0
    if not (stats.rv2 > 0.0 and math.isfinite(stats.rv2)):
        return 0.0
    if not math.isfinite(stats.rk4):
        return 0.0
    c_size = min(1.0, stats.size / float(MIN_CONFIDENT_SIZE))
    c_tail = 1.0 / (1.0 + max(0.0, stats.kurtosis - 6.0) / KURTOSIS_SCALE)
    shape = stats.ra1 / (math.sqrt(stats.rv2) * math.sqrt(2.0 / math.pi))
    c_shape = 1.0 / (1.0 + 2.0 * abs(math.log(max(shape, 1e-12))))
    return c_size * c_tail * c_shape


def predict_selection(
    stats: FieldStats,
    eb_abs: float,
    codecs: tuple[str, ...] = _codecs.DEFAULT_CODECS,
) -> _sel.Selection:
    """Algorithm 1 replayed on the predicted curves: ZFP PSNR at the
    bound -> iso-PSNR SZ half-bin (same PSNR_MATCH_QUANTUM snap and clip
    as the sampled path) -> min predicted rate."""
    eb = float(eb_abs)
    psnr_z = float(_zfp_psnr_model(stats, eb))
    psnr_q = round(psnr_z / est.PSNR_MATCH_QUANTUM) * est.PSNR_MATCH_QUANTUM
    delta = max(stats.vr, 1e-30) * math.sqrt(12.0) * 10.0 ** (-psnr_q / 20.0)
    eb_sz = min(max(delta / 2.0, eb * 1e-6), eb)
    br_sz = float(_sz_bitrate_model(stats, eb_sz))
    br_zfp = float(_zfp_bitrate_model(stats, eb))
    codec = _sel._pick_codec(br_sz, br_zfp, codecs)
    return _sel.Selection(
        codec, eb, eb_sz, br_sz, br_zfp, psnr_z, stats.vr, stats.r_sp
    )


def select_many_predicted(
    fields,
    eb_abs: float | None = None,
    eb_rel: float | None = None,
    r_sp: float | None = None,
    transform: str = "zfp",
    codecs: tuple[str, ...] | None = None,
    *,
    policy=None,
    confidence_threshold: float = CONFIDENCE_THRESHOLD,
) -> tuple[list[_sel.Selection], list[str]]:
    """`select_many` with the predictor in front: confident fields take
    the moment-model decision, low-confidence fields fall back to the
    sampled estimator, degenerate fields keep the raw fallback. Returns
    (selections, routes) with routes[i] in
    {'predicted', 'sampled', 'degenerate'}.

    Opt-in by design: predicted decisions follow the model, not the
    sampled estimate, so this is NOT the path behind the frozen goldens
    or `compress_pytree` — it serves overhead-critical in-situ loops that
    accept model-grade selection accuracy (paper §6: the two codecs'
    rates differ by >1 b/v on most fields, so model error rarely flips)."""
    if policy is not None:
        if policy.mode != "fixed_accuracy":
            raise ValueError(
                "select_many_predicted takes a fixed_accuracy policy, got "
                f"{policy.mode!r}"
            )
        if any(v is not None for v in (eb_abs, eb_rel, r_sp, codecs)):
            raise ValueError(
                "pass either policy= or eb_abs/eb_rel/r_sp/codecs, not both"
            )
        eb_abs, eb_rel = policy.eb_abs, policy.eb_rel
        r_sp, codecs = policy.r_sp, policy.codecs
    r_sp = est.DEFAULT_SAMPLING_RATE if r_sp is None else r_sp
    codecs = _codecs.DEFAULT_CODECS if codecs is None else codecs
    fields = list(fields)
    results: list[_sel.Selection | None] = [None] * len(fields)
    groups = _sel._build_select_members(
        fields, range(len(fields)), results, eb_abs, eb_rel, r_sp, transform,
        codecs,
    )
    routes = ["degenerate" if r is not None else "" for r in results]
    fallback: dict[int, list] = {}
    for nd, members in groups.items():
        stats = stats_for_members(nd, members, r_sp)
        for m, (s, _fp) in zip(members, stats):
            i = m[0]
            if confidence(s) >= confidence_threshold:
                results[i] = predict_selection(s, m[2], codecs)
                routes[i] = "predicted"
            else:
                fallback.setdefault(nd, []).append(m)
                routes[i] = "sampled"
    if fallback:
        _sel._run_select_batches(fallback, results, r_sp, transform, codecs)
    return results, routes  # type: ignore[return-value]


__all__ = [
    "CONFIDENCE_THRESHOLD",
    "FieldStats",
    "confidence",
    "fingerprint_of",
    "predict_curves",
    "predict_selection",
    "select_many_predicted",
    "stats_for_members",
]
