"""Device-resident Stage III: in-graph bitstream encode (DESIGN.md §3.7).

The PR 4 kernel tier stopped at quantized codes + bit accounting and
shipped raw codes to the host coder — the last host roundtrip on the save
path (the old DESIGN.md §3.6 rule). This module finishes Stage III
in-graph for both codecs, emitting into the `kernels/pack.py` word arena
so the only transfer per field is one `jax.device_get` of packed words
(plus the small per-block sidecars the containers carry anyway):

* **SZ** — two-pass device Huffman: pass 1 jits quantize + Lorenzo
  (`kernels/ops.lorenzo_encode`, the Pallas tier for 2-D/3-D) and a
  65536-bin histogram; the host builds the canonical code table from the
  fetched histogram (tiny — `entropy.build_table` on O(2^16) symbols) and
  knows the exact payload size (`sum(freqs * lens)`); pass 2 jits the
  table-lookup code/length gather, the exclusive prefix-sum of lengths,
  and the word-major `pack_codes_gather`. Escape literals ride the same
  launch: a rank-indexed `searchsorted` gather compacts outlier residuals
  into the container's int64 section. The stream is the SZJ1 layout under
  the versioned `SZJ2` magic (`sz.DEVICE_MAGIC`) — `sz_decompress`
  decodes both.

* **ZFP** — in-kernel plane emission: blockize/align/transform reuse the
  jit-safe §3 pieces; the arena is pre-sized from the closed-form
  `embedded.block_bits` rate model (the buffer-sizing idea of the
  black-box ratio-prediction line, PAPERS.md arXiv 2305.08801), and the
  plane-sectioned k-prefix layout of `zfp.py` is reproduced exactly in
  closed form over per-coefficient bit lengths: each (plane, block) emits
  seven right-aligned <= 32-bit chunks (refinement, the w-bit k field,
  test bits, signs — split at rank 32), whose values come from masked
  shift-sum reductions and whose offsets from one prefix sum, merged by
  the scatter `pack_codes` (see `_zfp_pass2b`). The container is the
  unchanged ZFJX format — the host decoder needs no changes.

Parity contract (what the tests and the `device_encode_parity` gate
check): fed the SAME quantized codes, the device packer and the host
Stage III produce byte-identical streams (`sz.sz_encode_residuals` /
`zfp.zfp_encode_quantized` exist exactly for this). The integrated path
quantizes in float32 (like every in-graph path since `sz_stats` /
`zfp_stats`), so codes can differ from the float64 host quantizer at
rounding boundaries — the reconstruction honors the same pointwise bound
either way.

Fallback rules (DESIGN.md §3.7) — `None` from any encoder means "use the
host coder", never a truncated stream:

* the rate model under-estimated and the emitted bits overran the arena
  (`pack` drops out-of-range writes, and the true total is checked);
* code magnitudes exceed float32-exact integer range (2^23 for SZ codes,
  2^24 for ZFP plane magnitudes — the >= 24 bits/value regime where
  selection picks raw anyway);
* non-finite values, zero-size fields, or streams past int32 bit offsets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, pack

from . import entropy as _entropy
from . import sz as _sz
from . import zfp as _zfp
from .embedded import align_blocks
from .transforms import blockize, bot_linf_gain, bot_matrix, block_transform_nd

#: SZ symbol alphabet (escape + shifted residuals), as in core/sz.py
N_SYMBOLS = 2 * _sz.RESIDUAL_RADIUS + 2
#: float32 keeps integers exact below 2^24; SZ codes also pass through
#: Lorenzo corner sums (2^ndim terms), so the code guard is 2^23
_SZ_CODE_LIMIT = 2.0**23
_ZFP_MAG_LIMIT = 2.0**24
#: bit offsets are int32 prefix sums
_MAX_STREAM_BITS = 2**31 - 1


def _degree_order(nd: int) -> np.ndarray:
    idx = np.indices((4,) * nd).reshape(nd, -1)
    return np.argsort(idx.sum(axis=0), kind="stable")


# ---------------------------------------------------------------------------
# SZ: two-pass device Huffman
# ---------------------------------------------------------------------------


@jax.jit
def _sz_pass1(x, eb):
    """Quantize + Lorenzo (Pallas tier for 2-D/3-D) -> residuals, symbols,
    histogram, and the |x| max for the float32-exactness guard."""
    d = ops.lorenzo_encode(x, eb)
    syms = jnp.where(
        jnp.abs(d) > _sz.RESIDUAL_RADIUS, 0, d + _sz.RESIDUAL_RADIUS + 1
    ).astype(jnp.int32)
    hist = jnp.bincount(syms.reshape(-1), length=N_SYMBOLS)
    amax = jnp.max(jnp.abs(x))
    return d, syms, hist, amax


@functools.partial(jax.jit, static_argnames=("n_words", "esc_cap", "window"))
def _sz_pass2(syms, d, lut_codes, lut_lens, *, n_words, esc_cap, window):
    """Table-lookup gather + prefix-sum pack, and escape compaction.

    The packer is the gather form (`pack_codes_gather`): every emitted
    symbol has a code (`len >= 1`), so each arena word overlaps a bounded
    window of codes. Escapes compact by rank through `searchsorted` on the
    escape-count prefix sum — `esc_cap` gathers instead of a full-length
    scatter."""
    syms = syms.reshape(-1)
    lens = lut_lens[syms]
    codes = lut_codes[syms]
    offsets = jnp.cumsum(lens) - lens  # exclusive
    words = pack.pack_codes_gather(codes, lens, offsets, n_words, window)
    esc_rank = jnp.cumsum((syms == 0).astype(jnp.int32))
    tgt = jnp.arange(1, max(esc_cap, 1) + 1, dtype=jnp.int32)
    idx = jnp.clip(
        jnp.searchsorted(esc_rank, tgt, side="left"), 0, syms.shape[0] - 1
    )
    # lanes past the true escape count gather garbage; the host reads
    # exactly the first n_esc
    escapes = d.reshape(-1)[idx].astype(jnp.int32)
    return words, escapes


def sz_device_residuals(x, eb: float) -> np.ndarray:
    """Device-computed Lorenzo residuals (parity/debug surface): the exact
    codes the device encoder packs, for feeding `sz.sz_encode_residuals`."""
    d, _, _, _ = _sz_pass1(jnp.asarray(x, jnp.float32), jnp.float32(eb))
    return np.asarray(jax.device_get(d))


def sz_encode_device(x, eb: float) -> bytes | None:
    """Device-resident SZ encode -> SZJ2 container bytes, or None (host
    fallback). `x` is the folded f32 view; `eb` the SZ bound (eb_sz)."""
    shape = tuple(np.shape(x))
    size = int(np.prod(shape, dtype=np.int64)) if shape else 0
    if size == 0 or eb <= 0:
        return None
    delta32 = np.float32(2.0) * np.float32(eb)
    if not np.isfinite(float(delta32)) or float(delta32) <= 0.0:
        return None
    x32 = jnp.asarray(x, jnp.float32)
    d, syms, hist, amax = _sz_pass1(x32, jnp.float32(eb))
    freqs, amax = jax.device_get((hist, amax))
    amax = float(amax)
    if not np.isfinite(amax) or amax / float(delta32) >= _SZ_CODE_LIMIT:
        return None
    freqs = np.asarray(freqs, dtype=np.int64)
    table = _entropy.build_table(freqs)
    payload_bits = int((freqs * table.lens.astype(np.int64)).sum())
    if payload_bits > _MAX_STREAM_BITS:
        return None
    n_esc = int(freqs[0])
    n_words = pack.arena_words(payload_bits)
    esc_cap = pack.arena_words(32 * n_esc) if n_esc else 0
    # payload_bits is exact (sum(freqs*lens)), so unlike ZFP's modeled
    # budget these can't under-size — but the drop-mode arena makes a
    # short buffer silently truncate, so guard the invariant anyway
    if 32 * n_words < payload_bits or esc_cap < n_esc:
        return None
    emitted = table.lens[(freqs > 0) & (table.lens > 0)]
    min_len = int(emitted.min()) if emitted.size else 1
    words, escapes = _sz_pass2(
        syms, d,
        jnp.asarray(table.codes.astype(np.uint32)),
        jnp.asarray(table.lens.astype(np.int32)),
        n_words=n_words, esc_cap=esc_cap,
        window=pack.gather_window(min_len),
    )
    words_np, esc_np = jax.device_get((words, escapes))
    payload = pack.words_to_bytes(words_np, payload_bits)
    outliers = np.asarray(esc_np[:n_esc], dtype=np.int64)
    # container delta is the float32 value the device divided by, so the
    # decoder multiplies by exactly the encoder's bin size
    return _sz.sz_container(
        shape, float(delta32), table, payload, outliers, magic=_sz.DEVICE_MAGIC
    )


# ---------------------------------------------------------------------------
# ZFP: model-sized arena + in-graph plane emission
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("transform",))
def _zfp_pass1(x, *, transform):
    """Blockize + exponent-align + BOT (all §3 jit-safe pieces, f32)."""
    n = x.ndim
    T = jnp.asarray(bot_matrix(transform), jnp.float32)
    blocks, _ = blockize(x.astype(jnp.float32))
    norm, e = align_blocks(blocks)
    coeffs = block_transform_nd(norm, T, n)
    return coeffs, e


@functools.partial(jax.jit, static_argnames=("nd",))
def _zfp_pass2a(coeffs, step, *, nd):
    """Quantize to plane magnitudes (degree order) + the closed-form
    `block_bits` budget that sizes the arena (DESIGN.md §3.7)."""
    bsz = 4**nd
    w = int(np.ceil(np.log2(bsz + 1)))
    nblk = coeffs.shape[0]
    c = coeffs.reshape(nblk, bsz)[:, _degree_order(nd)]
    mf = jnp.trunc(jnp.abs(c) / step[:, None])
    mmax = jnp.max(mf) if mf.size else jnp.float32(0.0)
    m = jnp.minimum(mf, 2.0**31 - 1).astype(jnp.int32)
    neg = c < 0
    mx = jnp.max(m, axis=1) if m.size else jnp.zeros((nblk,), jnp.int32)
    nsb = jnp.where(
        mx > 0,
        jnp.floor(jnp.log2(jnp.maximum(mx.astype(jnp.float32), 1.0))) + 1.0,
        0.0,
    ).astype(jnp.int32)
    nsb_c = jnp.where(
        m > 0,
        jnp.floor(jnp.log2(jnp.maximum(m.astype(jnp.float32), 1.0))) + 1.0,
        0.0,
    )
    # the block_bits payload model: w*maxplane + sum(nsb) + 2*nsig per block
    # (headers live in the e/nsb sidecars, not the packed payload)
    model = (
        w * jnp.sum(nsb.astype(jnp.float32))
        + jnp.sum(nsb_c)
        + 2.0 * jnp.sum((m > 0).astype(jnp.float32))
    )
    maxp = jnp.max(nsb) if nsb.size else jnp.int32(0)
    return m, neg, nsb, model, maxp, mmax


@functools.partial(jax.jit, static_argnames=("n_words", "n_planes"))
def _zfp_pass2b(m, neg, nsb, *, n_words, n_planes):
    """The plane-sectioned k-prefix emitter of `zfp._emit_planes`, in
    closed form over per-coefficient bit lengths (DESIGN.md §3.7).

    Instead of replaying the host's per-plane boolean-mask concatenation
    bit by bit, every plane/block/section quantity follows from one tensor
    `nc[i] = bitlength(m[i])`: at plane p, a coefficient is already
    significant iff `nc >= p+2`, becomes significant iff `nc == p+1` (and
    that equality IS the tested bit's value), and the section ranks are
    exclusive prefix counts of those masks — one int8 cumsum over the
    shared `nc >= t` tensor yields every rank for every plane. Each
    (plane, block) then emits seven right-aligned chunks of <= 32 bits
    (refinement lo/hi, the w-bit k field, test lo/hi, sign lo/hi), built
    by masked shift-sum reductions; chunk offsets are one exclusive prefix
    sum, and the scatter packer merges the mostly-empty slot grid into the
    arena. No data-dependent control flow, and ~1% of the scatter volume
    of the per-bit formulation — what makes the emitter viable on the
    2-core XLA:CPU bench host.
    """
    if n_planes == 0:
        return jnp.zeros((n_words,), jnp.uint32), jnp.int32(0)
    nblk, bsz = m.shape
    w = int(np.ceil(np.log2(bsz + 1)))
    P = n_planes
    mf = jnp.maximum(m, 1).astype(jnp.float32)
    nc = jnp.where(m > 0, jnp.floor(jnp.log2(mf)) + 1.0, 0.0).astype(jnp.int8)
    t_ax = jnp.arange(1, P + 2, dtype=jnp.int8)[:, None, None]
    ge = nc[None] >= t_ax  # (P+1, nblk, bsz)
    g8 = ge.astype(jnp.int8)
    # exclusive prefix counts; int8 suffices (bsz <= 64) and halves traffic
    C = jnp.cumsum(g8, axis=2, dtype=jnp.int8) - g8
    p_ax = jnp.arange(P, dtype=jnp.int32)[:, None, None]
    i_ax = jnp.arange(bsz, dtype=jnp.int8)[None, None, :]
    act = p_ax < nsb[None, :, None].astype(jnp.int32)
    ref = ge[1:]  # significant before plane p: nc >= p+2
    rank_ref = C[1:]
    newly = ge[:-1] & ~ge[1:]  # becomes significant at p: nc == p+1
    rank_sign = C[:-1] - C[1:]
    rank_rem = i_ax - rank_ref
    rem = act & ~ge[1:]
    k8 = jnp.max(jnp.where(newly, rank_rem + 1, 0), axis=2).astype(jnp.int8)
    cnt_rem = jnp.sum(rem, axis=2, dtype=jnp.int32)
    has_rem = act[:, :, 0] & (cnt_rem > 0)
    cnt_ref = jnp.sum(ref, axis=2, dtype=jnp.int32)
    cnt_new = jnp.sum(newly, axis=2, dtype=jnp.int32)
    refbit = ((m[None] >> p_ax) & 1).astype(jnp.uint32)
    testbit = newly.astype(jnp.uint32)  # the tested bit IS [nc == p+1]
    negb = neg[None].astype(jnp.uint32)

    def partvals(mask, bits, rank8, cnt):
        """Right-aligned values of a section's lo (ranks < 32) and hi
        (ranks >= 32) 32-bit chunks, as masked shift-sum reductions."""
        rank = rank8.astype(jnp.int32)
        expo = jnp.clip(cnt[:, :, None] - 1 - rank, 0, 63)
        sh_lo = jnp.where(cnt[:, :, None] > 32, 31 - rank, expo)
        v_lo = jnp.sum(
            jnp.where(mask & (rank8 < 32),
                      bits << jnp.clip(sh_lo, 0, 31).astype(jnp.uint32), 0),
            axis=2, dtype=jnp.uint32)
        v_hi = jnp.sum(
            jnp.where(mask & (rank8 >= 32),
                      bits << jnp.clip(expo, 0, 31).astype(jnp.uint32), 0),
            axis=2, dtype=jnp.uint32)
        return v_lo, jnp.minimum(cnt, 32), v_hi, jnp.maximum(cnt - 32, 0)

    test = rem & (rank_rem < k8[:, :, None])
    rA, rlA, rB, rlB = partvals(ref, refbit, rank_ref, cnt_ref)
    tA, tlA, tB, tlB = partvals(
        test, testbit, rank_rem, jnp.minimum(k8.astype(jnp.int32), cnt_rem))
    sA, slA, sB, slB = partvals(newly, negb, rank_sign, cnt_new)
    klen = jnp.where(has_rem, w, 0)

    def inter(a, b):
        return jnp.stack([a, b], axis=2).reshape(P, -1)

    # stream order: planes DESCENDING; per plane: block-major refinement,
    # then the k fields, then test bits, then signs — the host layout
    lens = jnp.concatenate(
        [inter(rlA, rlB), klen, inter(tlA, tlB), inter(slA, slB)],
        axis=1)[::-1].reshape(-1)
    vals = jnp.concatenate(
        [inter(rA, rB), k8.astype(jnp.uint32), inter(tA, tB), inter(sA, sB)],
        axis=1)[::-1].reshape(-1)
    offs = jnp.cumsum(lens) - lens
    total = offs[-1] + lens[-1]
    return pack.pack_codes(vals, lens, offs, n_words), total


def _zfp_step(e_np: np.ndarray, eb: float, gain_n: float) -> np.ndarray | None:
    """The power-of-two truncation step, float64, EXACTLY the formula the
    decoder (and `_prepare_blocks`) evaluates — then cast to f32 for the
    device (powers of two are exact). None when it leaves f32 range."""
    raw = eb / (np.exp2(e_np.astype(np.float64)) * gain_n)
    pexp = np.floor(np.log2(np.maximum(raw, 2.0**-60)))
    if pexp.size and (pexp.min() < -126 or pexp.max() > 127):
        return None
    return np.exp2(pexp).astype(np.float32)


def zfp_device_codes(x, eb: float, transform: str = "zfp"):
    """Device-computed quantized codes (parity/debug surface): (q, e) in
    raw block layout, for feeding `zfp.zfp_encode_quantized`."""
    x32 = jnp.asarray(x, jnp.float32)
    nd = x32.ndim
    coeffs, e = _zfp_pass1(x32, transform=transform)
    e_np = np.asarray(jax.device_get(e), dtype=np.int16)
    step = _zfp_step(e_np, eb, bot_linf_gain(transform) ** nd)
    assert step is not None, "step outside f32 range"
    # c / step is exact in f32 (power-of-two step), so the f64 trunc here
    # reproduces the device's plane magnitudes bit for bit below 2^24
    c = np.asarray(jax.device_get(coeffs), dtype=np.float64).reshape(len(e_np), -1)
    q = np.trunc(c / step.astype(np.float64)[:, None]).astype(np.int64)
    return q, e_np


def zfp_encode_device(x, eb: float, transform: str = "zfp") -> bytes | None:
    """Device-resident ZFP encode -> ZFJX container bytes, or None (host
    fallback). `x` is the folded f32 view; `eb` the absolute bound."""
    shape = tuple(np.shape(x))
    size = int(np.prod(shape, dtype=np.int64)) if shape else 0
    if size == 0 or eb <= 0 or not np.isfinite(eb):
        return None
    x32 = jnp.asarray(x, jnp.float32)
    nd = x32.ndim
    bsz = 4**nd
    w = int(np.ceil(np.log2(bsz + 1)))
    padded = tuple(s + (-s) % 4 for s in shape)
    coeffs, e = _zfp_pass1(x32, transform=transform)
    e_np = np.asarray(jax.device_get(e), dtype=np.int16)
    nblk = len(e_np)
    step = _zfp_step(e_np, eb, bot_linf_gain(transform) ** nd)
    if step is None:
        return None
    m, neg, nsb, model, maxp, mmax = _zfp_pass2a(
        coeffs, jnp.asarray(step), nd=nd
    )
    model, maxp, mmax = jax.device_get((model, maxp, mmax))
    if not np.isfinite(float(mmax)) or float(mmax) >= _ZFP_MAG_LIMIT:
        return None
    n_planes = min(24, -(-int(maxp) // 4) * 4) if int(maxp) else 0
    # int32 bit-offset headroom for the worst-case emission of this launch
    if nblk * (3 * bsz + w) * max(n_planes, 1) > _MAX_STREAM_BITS:
        return None
    n_words = pack.arena_words(float(model))
    words, total = _zfp_pass2b(m, neg, nsb, n_words=n_words, n_planes=n_planes)
    words_np, total_bits, nsb_np = jax.device_get((words, total, nsb))
    total_bits = int(total_bits)
    if total_bits > 32 * n_words:
        # the block_bits model under-estimated past the pow2 slack: the
        # arena dropped bits — clean per-field host fallback, never a
        # truncated stream (DESIGN.md §3.7)
        return None
    payload = pack.words_to_bytes(words_np, total_bits)
    return _zfp.zfp_container(
        shape, padded, float(eb), transform, e_np,
        np.asarray(nsb_np, dtype=np.uint8), total_bits, payload,
    )


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------


def encode_field_device(view32, sel) -> bytes | None:
    """Capability entry point behind the codec registry (`device_encode`):
    dispatch one folded f32 view to the device encoder for its selected
    codec. None -> caller uses the host coder."""
    if sel.codec == "sz":
        return sz_encode_device(view32, sel.eb_sz)
    if sel.codec == "zfp":
        return zfp_encode_device(view32, sel.eb_abs)
    return None


__all__ = [
    "encode_field_device",
    "sz_device_residuals",
    "sz_encode_device",
    "zfp_device_codes",
    "zfp_encode_device",
]
