"""Stage II dynamic quantization: embedded (bit-plane) coding (paper §5.2).

ZFP-style pipeline per 4^n block:

  1. exponent alignment — each block is normalized by 2^e_max so all values
     share one binade (the "different exponent offsets" of §5.2.2);
  2. BOT (transforms.block_transform_nd);
  3. bit-plane truncation at a power-of-two step chosen conservatively from
     the user's absolute error bound and the transform's Linf gain
     (DESIGN.md §3; this reproduces ZFP's over-preservation, §6.4);
  4. rate = significant bits within the encoded plane window + per-plane
     significance bitmaps (vectorized stand-in for group testing).

Everything here is jnp and jit-safe; the byte-emitting coder lives in
`zfp.py` (host side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

#: header bits per block in the byte format: e_max (int16) + n_planes (uint8)
BLOCK_HEADER_BITS = 24


def block_exponent(blocks: jax.Array) -> jax.Array:
    """e s.t. 2^e >= max|block| > 2^(e-1); shape (nblocks,). Empty-safe."""
    n = blocks.ndim - 1
    mx = jnp.max(jnp.abs(blocks), axis=tuple(range(1, n + 1)))
    mx = jnp.maximum(mx, 1e-30)
    return jnp.ceil(jnp.log2(mx)).astype(jnp.int32)


def align_blocks(blocks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Normalize each block into [-1, 1] by its power-of-two exponent."""
    e = block_exponent(blocks)
    scale = jnp.exp2(-e.astype(blocks.dtype))
    shape = (-1,) + (1,) * (blocks.ndim - 1)
    return blocks * scale.reshape(shape), e


def plane_step(eb: float | jax.Array, e_max: jax.Array, linf_gain_n: float) -> jax.Array:
    """Power-of-two truncation step in *normalized* block space.

    Guarantees |reconstruction error| <= eb pointwise: the inverse BOT
    amplifies Linf error by at most linf_gain_n (= gain^ndim), and
    denormalization multiplies by 2^e_max.
    """
    raw = eb / (jnp.exp2(e_max.astype(jnp.float32)) * linf_gain_n)
    p = jnp.floor(jnp.log2(jnp.maximum(raw, 2.0**-60)))
    return jnp.exp2(p)


def truncate_planes(coeffs: jax.Array, step: jax.Array) -> jax.Array:
    """Truncate coefficients toward zero at the bit-plane boundary `step`.

    (Truncation, not rounding: embedded coding drops the low planes.)
    """
    shape = (-1,) + (1,) * (coeffs.ndim - 1)
    s = step.reshape(shape).astype(coeffs.dtype)
    return jnp.trunc(coeffs / s) * s


def reconstruct_truncated(coeffs: jax.Array, step: jax.Array) -> jax.Array:
    """Decoder-side reconstruction: midpoint of the truncated magnitude bin.

    Matches the byte codec in `zfp.py`: m = trunc(|c|/s); c~ = sign*(m+.5)*s
    for m > 0, else 0. Error per coefficient < step (<= step/2 after the
    midpoint shift), which the conservative `plane_step` turns into a
    pointwise bound <= eb after the inverse BOT.
    """
    shape = (-1,) + (1,) * (coeffs.ndim - 1)
    s = step.reshape(shape).astype(coeffs.dtype)
    m = jnp.trunc(jnp.abs(coeffs) / s)
    return jnp.sign(coeffs) * jnp.where(m > 0, (m + 0.5) * s, 0.0)


def significant_bits(coeffs: jax.Array, step: jax.Array) -> jax.Array:
    """n_sb per coefficient: encoded bits between its MSB plane and the
    truncation plane (the staircase count of Fig. 5). Shape = coeffs.shape."""
    shape = (-1,) + (1,) * (coeffs.ndim - 1)
    s = step.reshape(shape).astype(jnp.float32)
    q = jnp.abs(coeffs.astype(jnp.float32)) / s
    # number of bits of floor(q): 0 if q < 1
    return jnp.where(q >= 1.0, jnp.floor(jnp.log2(jnp.maximum(q, 1.0))) + 1.0, 0.0)


def exact_coder_bits_blocks(
    coeffs: jax.Array, step: jax.Array, max_planes: int = 31
) -> jax.Array:
    """EXACT per-block bit count of the plane-sectioned k-prefix coder in
    zfp.py, computed vectorized in-graph (static 31-plane loop; magnitudes
    beyond 2^31 saturate, i.e. bit-rates >= ~32 b/v — the raw-fallback
    regime). Shape (nblk,) — the batched selection engine segment-sums this
    per field (DESIGN.md §5).

    Mirrors _emit_planes: per plane, refinement bits + w-bit k field per
    block with remaining coeffs + k tested significance bits + signs.
    """
    n = coeffs.ndim - 1
    bsz = 4**n
    w = int(np.ceil(np.log2(bsz + 1)))
    nblk = coeffs.shape[0]
    s = step.reshape((-1,) + (1,) * n).astype(jnp.float32)
    mf = jnp.trunc(jnp.abs(coeffs.astype(jnp.float32)) / s)
    m = jnp.minimum(mf, 2.0**31 - 1).astype(jnp.int32).reshape(nblk, bsz)
    # degree order so ranks match the byte coder
    idx = np.indices((4,) * n).reshape(n, -1).sum(axis=0)
    order = np.argsort(idx, kind="stable")
    m = m[:, order]
    mx = jnp.max(m, axis=1)
    nsb = jnp.where(mx > 0, jnp.floor(jnp.log2(jnp.maximum(mx.astype(jnp.float32), 1.0))) + 1.0, 0.0).astype(jnp.int32)
    total = jnp.zeros((nblk,), jnp.float32)
    for p in range(max_planes):
        active = nsb > p
        act = active[:, None]
        sig_prev = jnp.right_shift(m, p + 1) > 0
        bit_p = jnp.bitwise_and(jnp.right_shift(m, p), 1)
        nref = jnp.sum((act & sig_prev).astype(jnp.float32), axis=1)
        rem = act & ~sig_prev
        has_rem = jnp.any(rem, axis=1) & active
        rank = jnp.cumsum(rem.astype(jnp.int32), axis=1) - 1
        newly = rem & (bit_p == 1)
        k = jnp.max(jnp.where(newly, rank + 1, 0), axis=1)
        total = total + nref + w * has_rem.astype(jnp.float32)
        total = total + k.astype(jnp.float32) + jnp.sum(newly.astype(jnp.float32), axis=1)
    return total + BLOCK_HEADER_BITS


def exact_coder_bits(coeffs: jax.Array, step: jax.Array, max_planes: int = 31) -> jax.Array:
    """Total exact coder bits over all blocks (sum of the per-block counts)."""
    return jnp.sum(exact_coder_bits_blocks(coeffs, step, max_planes))


def block_bits(coeffs: jax.Array, step: jax.Array, sign_bits: bool = True) -> jax.Array:
    """Total encoded bits per block under the plane-sectioned, degree-ordered
    k-prefix embedded coder of `zfp.py`:

    per block ~= header + sum(n_sb) magnitude bits
               + w bits (k field) per visited plane
               + ~1 pre-significance test bit + 1 sign bit per significant
                 coefficient.
    Benchmarks report the estimate-vs-actual gap, which plays the role of the
    paper's Huffman-vs-entropy gap for SZ.
    """
    n = coeffs.ndim - 1
    bsz = 4**n
    w = int(np.ceil(np.log2(bsz + 1)))
    nsb = significant_bits(coeffs, step)
    axes = tuple(range(1, n + 1))
    max_planes = jnp.max(nsb, axis=axes)  # planes actually visited
    sig = jnp.sum(nsb, axis=axes)
    nsig = jnp.sum((nsb > 0).astype(jnp.float32), axis=axes)
    bits = BLOCK_HEADER_BITS + w * max_planes + sig
    if sign_bits:
        bits = bits + 2.0 * nsig
    return bits
