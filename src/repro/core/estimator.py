"""Online compression-quality estimation (paper §4.3, §5 — Steps 1 & 2).

Per field, from a small blockwise sample (default r_sp = 5%):

* SZ  (PBT + linear quantization + Huffman):
    - PSNR via Eq. (11)  — closed form in the bin size, data-independent.
    - bit-rate via Eq. (9): Shannon entropy of the delta-binned PDF of the
      Lorenzo prediction errors (original-neighbor prediction, §4.3)
      + the +0.5 bits/value Huffman-suboptimality offset (§6.2)
* ZFP (BOT + embedded coding):
    - bit-rate via the mean significant-bit count n_sb-bar of r_sp_ec-sampled
      points of sampled blocks (staircase property, §5.2.1) + coder overhead.
    - PSNR via the truncation errors of the sampled points (§5.2.2); valid in
      the original space by the L2 invariance of Theorem 3.

All functions are jnp and jit-compatible so the estimator can also run
in-graph (gradient/KV compression); the checkpoint writer calls them on host.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .embedded import BLOCK_HEADER_BITS, plane_step, significant_bits
from .transforms import bot_linf_gain, bot_matrix, block_transform_nd

DEFAULT_SAMPLING_RATE = 0.05  # paper default
PDF_BINS = 65535  # paper §6.3.2
SZ_BITRATE_OFFSET = 0.5  # paper §6.2
#: points sampled per block for embedded-coding estimation (paper §5.2.2)
EC_POINTS = {1: 3, 2: 9, 3: 16}


def _table_bits_per_symbol() -> float:
    """Serialized Huffman-table cost per symbol, matching what entropy.py
    will actually emit in THIS environment: ~5 bits with the zstd'd
    delta+length serialization, the full 40 bits (4-byte symbol delta +
    1-byte code length) when `zstandard` is absent and the table ships as
    the flagged raw blob. On rich-alphabet fields the difference is
    whole bits/value, so a fixed 5.0 would bias both Algorithm 1 and the
    DESIGN.md §7 rate targeting in bare environments.

    `REPRO_SZ_TABLE_BITS` overrides the probe — a test hook that lets the
    golden-decision suite regenerate its frozen expectations for *both*
    environments (zstd and bare) from either one, since this constant is
    baked into the jitted estimator programs at import time."""
    override = os.environ.get("REPRO_SZ_TABLE_BITS")
    if override:
        return float(override)
    try:
        import zstandard  # noqa: F401

        return 5.0
    except ImportError:
        return 40.0


TABLE_BITS_PER_SYMBOL = _table_bits_per_symbol()
LN2 = math.log(2.0)


# ---------------------------------------------------------------------------
# Step 1 — blockwise sampling
# ---------------------------------------------------------------------------


def _split_strides(target: int, nd: int) -> tuple[int, ...]:
    """Split 1/r_sp into nd per-dimension block strides, 'fixed in the same
    dimension and different across dimensions' (paper §4.3)."""
    strides = []
    rem = max(target, 1)
    for i in range(nd - 1, 0, -1):
        f = max(1, int(round(rem ** (1.0 / (i + 1)))))
        # nudge successive dims apart so sample lattices don't alias
        if strides and f == strides[-1] and f > 1:
            f -= 1
        strides.append(f)
        rem = max(1, int(round(rem / f)))
    strides.append(max(rem, 1))
    return tuple(strides)


def block_starts(shape: tuple[int, ...], r_sp: float) -> np.ndarray:
    """(n_s, nd) int array of sampled 4^n block origins (static, host-side)."""
    nd = len(shape)
    strides = _split_strides(int(round(1.0 / max(r_sp, 1e-6))), nd)
    axes = []
    for d, s in zip(shape, strides):
        nb = max(d // 4, 1)
        axes.append(np.arange(0, nb, s, dtype=np.int64) * 4)
    grids = np.meshgrid(*axes, indexing="ij")
    return np.stack([g.reshape(-1) for g in grids], axis=1)


def _gather_blocks_impl(xp, x, starts: np.ndarray, halo: bool):
    """Shared numpy/jnp gather: blocks (n_s, 4, ..) — or (n_s, 5, ..) with
    a leading halo of *original real neighbors* (zero outside the domain,
    matching `lorenzo_forward`'s boundary convention). One implementation
    so the host and device paths cannot drift apart."""
    nd = x.ndim
    lo = -1 if halo else 0
    offs = xp.arange(lo, 4)
    idx = []
    masks = []
    for d in range(nd):
        i = xp.asarray(starts[:, d])[:, None] + offs[None, :]
        masks.append(i >= 0)
        idx.append(xp.clip(i, 0, x.shape[d] - 1))
    ns = starts.shape[0]
    w = 4 - lo
    # broadcasted advanced indexing: (n_s, w, w, ...)
    bidx = []
    for d in range(nd):
        sh = [ns] + [1] * nd
        sh[1 + d] = w
        bidx.append(idx[d].reshape(sh))
    out = x[tuple(bidx)]
    if halo:
        for d in range(nd):
            sh = [ns] + [1] * nd
            sh[1 + d] = w
            out = out * masks[d].reshape(sh).astype(out.dtype)
    return out


def gather_blocks_np(x: np.ndarray, starts: np.ndarray, halo: bool = False) -> np.ndarray:
    """Host-side twin of `gather_blocks`, used by the batched selection
    engine: sampled blocks of MANY fields are gathered on host (r_sp of the
    data), packed into one batch, and shipped to the device in a single
    transfer instead of one full-field transfer per leaf."""
    return _gather_blocks_impl(np, x, starts, halo)


def gather_blocks(x: jax.Array, starts: np.ndarray, halo: bool = False) -> jax.Array:
    """Device-side sampled-block gather (jit-safe)."""
    return _gather_blocks_impl(jnp, x, starts, halo)


def lorenzo_residual_samples(
    x: jax.Array, starts: np.ndarray, delta: jax.Array | float | None = None
) -> jax.Array:
    """Prediction errors of the sampled points, predicted from original real
    neighbors (§4.3 — 'the sampling process for PBT will not introduce
    additional errors'). Returns (n_s * 4^nd,) residuals.

    With `delta`, values are prequantized to integer codes first, so the
    residual distribution exactly matches the TPU-adapted integer-Lorenzo
    codec (DESIGN.md §3.1) including the rounding-noise inflation; without
    it, this is the paper's original-float PBT (mode='paper').
    """
    nd = x.ndim
    hal = gather_blocks(x, starts, halo=True)  # (n_s, 5, ..)
    if delta is not None:
        hal = jnp.round(hal / jnp.asarray(delta, hal.dtype))
    d = hal
    for ax in range(1, nd + 1):
        upper = jax.lax.slice_in_dim(d, 1, d.shape[ax], axis=ax)
        lower = jax.lax.slice_in_dim(d, 0, d.shape[ax] - 1, axis=ax)
        d = upper - lower
    return d.reshape(-1)


# ---------------------------------------------------------------------------
# Step 2 — SZ estimation
# ---------------------------------------------------------------------------


@dataclass
class Estimate:
    bitrate: jax.Array
    psnr: jax.Array


def sz_psnr(eb: jax.Array | float, vr: jax.Array | float) -> jax.Array:
    """Eq. (11): PSNR_sz = -20 log10(eb/VR) + 10 log10(3)."""
    eb_rel = jnp.asarray(eb, jnp.float32) / jnp.asarray(vr, jnp.float32)
    return -20.0 * jnp.log10(jnp.maximum(eb_rel, 1e-30)) + 10.0 * math.log10(3.0)


#: the iso-PSNR match point is snapped to this grid (dB) before inverting
#: Eq. (10). 0.05 dB is far below the estimator's accuracy, but it makes the
#: derived bin size bit-identical between the per-field and batched paths:
#: a 1-ulp PSNR difference otherwise shifts delta by 1 ulp, flips a few
#: round(x/delta) results sitting at .5, and the Chao1 table-cost estimate
#: (singleton/doubleton counts) amplifies those flips into multi-bit rate
#: swings on near-unique-residual fields (DESIGN.md §4).
PSNR_MATCH_QUANTUM = 0.05


def sz_delta_for_psnr(psnr: jax.Array, vr: jax.Array | float) -> jax.Array:
    """Invert Eq. (10): delta = VR * sqrt(12) * 10^(-PSNR/20), with PSNR
    snapped to the PSNR_MATCH_QUANTUM grid (see above)."""
    psnr_q = jnp.round(psnr / PSNR_MATCH_QUANTUM) * PSNR_MATCH_QUANTUM
    return jnp.asarray(vr, jnp.float32) * math.sqrt(12.0) * 10.0 ** (-psnr_q / 20.0)


def sz_bitrate_from_hist(
    hist: jax.Array, ofrac: jax.Array, size: jax.Array | float, n_pdf: int = PDF_BINS
) -> jax.Array:
    """Eq. (9) bit-rate from a dense residual-bin-count histogram: sample
    entropy with the Miller-Madow plug-in-bias correction, the Chao1
    Huffman-table cost, the +0.5 offset, and the 64-bit escape payload.

    THE §4 reduction — shared by `estimate_sz` (one field's sampled
    histogram) and the shard-local engine's statistics reconciliation
    (`core/sharded.py`, DESIGN.md §6), whose psum merges per-shard bin
    counts into exactly this input. Keeping it in one place is what lets
    estimator fixes (the Miller-Madow / table-cost kind) land in every
    path at once instead of silently diverging the sharded decisions.

    * Miller-Madow: the plug-in entropy of an r_sp sample under-reads a
      rich alphabet by ~(m-1)/(2n) nats — half a bit/value on intermittent
      fields — exactly the bias a rate estimate cannot afford.
    * Chao1 table cost: symbol richness extrapolated from singleton /
      doubleton counts, priced at what entropy.py will actually serialize
      (TABLE_BITS_PER_SYMBOL), amortized over the FULL field size.
    """
    n_samp = jnp.maximum(hist.sum(), 1).astype(jnp.float32)
    p = hist.astype(jnp.float32) / n_samp
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
    n_obs = jnp.sum((hist > 0).astype(jnp.float32))
    ent = ent + (n_obs - 1.0) / (2.0 * n_samp * LN2)
    f1 = jnp.sum((hist == 1).astype(jnp.float32))
    f2 = jnp.sum((hist == 2).astype(jnp.float32))
    chao1 = n_obs + f1 * jnp.maximum(f1 - 1.0, 0.0) / (2.0 * (f2 + 1.0))
    table_bits = TABLE_BITS_PER_SYMBOL * jnp.minimum(chao1, float(n_pdf))
    # escape symbols carry a raw 64-bit residual payload (sz.py)
    return ent + SZ_BITRATE_OFFSET + ofrac * 64.0 + table_bits / jnp.maximum(size, 1)


def estimate_sz(
    x: jax.Array,
    delta: jax.Array | float,
    starts: np.ndarray,
    vr: jax.Array | float,
    n_pdf: int = PDF_BINS,
    mode: str = "integer",
) -> Estimate:
    """Eq. (9) entropy bit-rate (+0.5 offset) and Eq. (11) PSNR.

    mode='paper'   — PDF of float Lorenzo residuals binned by delta (§5.1).
    mode='integer' — PDF of integer-code residuals, matching the
                     prequantized codec exactly (default; DESIGN.md §3.1).
    """
    delta = jnp.asarray(delta, jnp.float32)
    half = (n_pdf - 1) // 2
    if mode == "integer":
        k_raw = lorenzo_residual_samples(x, starts, delta=delta)
    else:
        k_raw = jnp.round(lorenzo_residual_samples(x, starts) / delta)
    ofrac = jnp.mean((jnp.abs(k_raw) > half).astype(jnp.float32))  # escapes
    k = jnp.clip(k_raw, -half, half)
    hist = jnp.histogram(k, bins=n_pdf, range=(-half - 0.5, half + 0.5))[0]
    br = sz_bitrate_from_hist(hist, ofrac, x.size, n_pdf)
    return Estimate(bitrate=br, psnr=sz_psnr(delta / 2.0, vr))


# ---------------------------------------------------------------------------
# Step 2 — ZFP estimation
# ---------------------------------------------------------------------------


def _ec_point_mask(nd: int) -> np.ndarray:
    """Fixed point pattern inside a 4^nd block (3/9/16 pts for 1/2/3-D)."""
    m = np.zeros((4,) * nd, dtype=bool)
    if nd == 1:
        m[np.array([0, 1, 3])] = True
    elif nd == 2:
        for i in (0, 1, 3):
            for j in (0, 2, 3):
                m[i, j] = True
    else:
        # 16 of 64: a 2x2x4 lattice
        m[np.ix_((0, 2), (1, 3), (0, 1, 2, 3))] = True
    return m


def estimate_zfp(
    x: jax.Array,
    eb: jax.Array | float,
    starts: np.ndarray,
    vr: jax.Array | float,
    transform: str = "zfp",
    mode: str = "exact",
) -> Estimate:
    """ZFP quality estimate from sampled blocks.

    mode='paper' — n_sb-bar bit-rate from r_sp_ec-subsampled points
                   (§5.2.1) + coder-overhead terms.
    mode='exact' — run the exact coder bit counter on the sampled blocks
                   (default): same sampling overhead profile, no model bias;
                   the only estimation error left is sampling error.
    PSNR is PSNR_sp (§5.2.2) in both modes; Theorem 3 transfers it to the
    original space.
    """
    nd = x.ndim
    blocks = gather_blocks(x, starts, halo=False).astype(jnp.float32)
    n_s = blocks.shape[0]
    mx = jnp.maximum(jnp.max(jnp.abs(blocks.reshape(n_s, -1)), axis=1), 1e-30)
    e = jnp.ceil(jnp.log2(mx)).astype(jnp.int32)
    norm = blocks * jnp.exp2(-e.astype(jnp.float32)).reshape((-1,) + (1,) * nd)
    T = jnp.asarray(bot_matrix(transform), jnp.float32)
    coeffs = block_transform_nd(norm, T, nd)
    gain_n = bot_linf_gain(transform) ** nd
    step = plane_step(jnp.asarray(eb, jnp.float32), e, gain_n)
    nsb = significant_bits(coeffs, step)  # (n_s, 4, ..)
    pmask = _ec_point_mask(nd)
    flat_nsb = nsb.reshape(n_s, -1)
    flat_co = coeffs.reshape(n_s, -1)
    sel = np.flatnonzero(pmask.reshape(-1))  # concrete (jit-static) indices
    samp_nsb = flat_nsb[:, sel]  # (n_s, n_ec)
    bsz = 4**nd
    # bit-rate: mean n_sb (staircase interpolation == mean over uniform
    # sample) + coder overhead (header + group bits + sign bits) per value
    if mode == "exact":
        from .embedded import exact_coder_bits

        bitrate = exact_coder_bits(coeffs, step) / (n_s * bsz)
    else:
        nbar = jnp.mean(samp_nsb)
        max_planes = jnp.mean(jnp.max(samp_nsb, axis=1))
        sig_frac = jnp.mean((samp_nsb > 0).astype(jnp.float32))
        w = math.ceil(math.log2(bsz + 1))
        overhead = (BLOCK_HEADER_BITS + w * max_planes) / bsz + 2.0 * sig_frac
        bitrate = nbar + overhead
    # PSNR: truncation error of the sampled points, de-normalized; Theorem 3
    # makes the transformed-space MSE equal the original-space MSE
    s = step.reshape(-1, 1).astype(jnp.float32)
    co = flat_co[:, sel]
    m = jnp.trunc(jnp.abs(co) / s)
    rec = jnp.sign(co) * jnp.where(m > 0, (m + 0.5) * s, 0.0)
    scale = jnp.exp2(e.astype(jnp.float32)).reshape(-1, 1)
    err = (co - rec) * scale
    mse_sp = jnp.mean(jnp.square(err))
    vr64 = jnp.maximum(jnp.asarray(vr, jnp.float32), 1e-30)
    psnr = -10.0 * jnp.log10(jnp.maximum(mse_sp, 1e-60)) + 20.0 * jnp.log10(vr64)
    return Estimate(bitrate=bitrate, psnr=psnr)


# ---------------------------------------------------------------------------
# Batched multi-field estimation (DESIGN.md §4–§5)
#
# Sampled blocks of MANY fields are packed along a single leading axis in
# FIELD ORDER: blocks [bounds[f], bounds[f+1]) belong to field f, with the
# boundary array computed on host at pack time. Every per-field quantity is
# then a prefix-sum + two boundary gathers — no scatters, which XLA:CPU
# serializes and which would otherwise dominate the whole launch. One jitted
# program replaces one estimator launch per field; padded batch/field
# buckets (select_many) keep the jit cache small.
# ---------------------------------------------------------------------------


def field_sums(x: jax.Array, bounds: jax.Array) -> jax.Array:
    """Per-field sums of field-ordered rows: x is (S,) or (S, C) with rows
    [bounds[f], bounds[f+1]) belonging to field f; returns (F,) / (F, C).

    The window is a difference of two global prefix sums, so callers must
    keep the summand magnitudes comparable across fields: integer-valued
    columns go through exact int32 accumulation (pass an int dtype), and
    float columns should be normalized per field first — a raw f32 cumsum
    over a huge batch loses the small fields to cancellation.
    `select_many` additionally caps a batch at MAX_BATCH_BLOCKS so int32
    bit totals cannot overflow."""
    cs = jnp.cumsum(x, axis=0)
    cs = jnp.concatenate([jnp.zeros_like(cs[:1]), cs], axis=0)
    return cs[bounds[1:]] - cs[bounds[:-1]]


def estimate_zfp_many(
    blocks: jax.Array,
    seg: jax.Array,
    bounds: jax.Array,
    eb_f: jax.Array,
    vr_f: jax.Array,
    transform: str = "zfp",
    mode: str = "exact",
) -> Estimate:
    """`estimate_zfp` for a packed batch of blocks from many fields.
    `blocks` is (total_blocks, 4, ..) in field order, seg[i] = field of
    block i, bounds the (n_fields+1,) block boundary array; returns
    per-field Estimate arrays of shape (n_fields,).

    mode='exact' — run the exact coder bit counter (31-plane loop), the
    decision-grade default. mode='model' — the closed-form `block_bits`
    coder model (one pass instead of 31): same staircase structure with a
    small model bias, ~5-10x cheaper; the quality-target controller's
    refinement probes use it and settle on an exact eval (DESIGN.md §7).

    Per-field results match the single-field path up to float reduction
    order: the per-block compute (exponent alignment, BOT, coder bit
    count, truncation error of the EC sample points) is identical; only
    the final mean becomes a boundary-windowed prefix-sum.
    """
    nd = blocks.ndim - 1
    bsz = 4**nd
    blocks = blocks.astype(jnp.float32)
    n_s = blocks.shape[0]
    mx = jnp.maximum(jnp.max(jnp.abs(blocks.reshape(n_s, -1)), axis=1), 1e-30)
    e = jnp.ceil(jnp.log2(mx)).astype(jnp.int32)
    norm = blocks * jnp.exp2(-e.astype(jnp.float32)).reshape((-1,) + (1,) * nd)
    T = jnp.asarray(bot_matrix(transform), jnp.float32)
    coeffs = block_transform_nd(norm, T, nd)
    gain_n = bot_linf_gain(transform) ** nd
    step = plane_step(eb_f[seg], e, gain_n)
    if mode == "exact":
        from .embedded import exact_coder_bits_blocks

        bits_blk = exact_coder_bits_blocks(coeffs, step)  # (n_s,) integer-valued
    else:
        from .embedded import block_bits

        bits_blk = block_bits(coeffs, step)  # integer-valued floats
    # PSNR from the EC sample points, exactly as in estimate_zfp
    pmask = _ec_point_mask(nd)
    sel = np.flatnonzero(pmask.reshape(-1))
    s = step.reshape(-1, 1).astype(jnp.float32)
    co = coeffs.reshape(n_s, -1)[:, sel]
    m = jnp.trunc(jnp.abs(co) / s)
    rec = jnp.sign(co) * jnp.where(m > 0, (m + 0.5) * s, 0.0)
    scale = jnp.exp2(e.astype(jnp.float32)).reshape(-1, 1)
    vr64 = jnp.maximum(vr_f, 1e-30)
    # normalize the error energy per field BEFORE the global prefix sum —
    # value ranges differ by orders of magnitude across a checkpoint, and a
    # shared f32 cumsum would cancel the small fields away
    err2n_blk = jnp.sum(jnp.square((co - rec) * scale), axis=1) / jnp.square(
        vr64[seg]
    )
    bits_f = field_sums(bits_blk.astype(jnp.int32), bounds).astype(jnp.float32)
    err2n_f = field_sums(err2n_blk, bounds)
    nblk_f = (bounds[1:] - bounds[:-1]).astype(jnp.float32)
    bitrate = bits_f / jnp.maximum(nblk_f * bsz, 1.0)
    mse_over_vr2 = err2n_f / jnp.maximum(nblk_f * len(sel), 1.0)
    psnr = -10.0 * jnp.log10(jnp.maximum(mse_over_vr2, 1e-60))
    return Estimate(bitrate=bitrate, psnr=psnr)


def estimate_sz_many(
    halo_blocks: jax.Array,
    seg: jax.Array,
    bounds: jax.Array,
    delta_f: jax.Array,
    vr_f: jax.Array,
    size_f: jax.Array,
    n_pdf: int = PDF_BINS,
) -> Estimate:
    """`estimate_sz(mode='integer')` for a packed batch of halo blocks.

    `halo_blocks` is (total_blocks, 5, ..) — field-ordered sampled blocks
    with the leading original-neighbor halo already gathered (zero outside
    the domain); `bounds` is the (n_fields+1,) BLOCK boundary array.

    The per-field residual PDFs are NOT materialized as an
    (n_fields, n_pdf) histogram (n_pdf = 65535 makes that the dominant cost
    at checkpoint scale). Instead samples are sorted by (field, bin) once —
    field order is preserved, so host-computed boundaries stay valid — and
    entropy / Chao1 table cost come from run-length counts: identical
    probabilities at O(samples log samples), independent of n_fields, with
    zero scatters.
    """
    nd = halo_blocks.ndim - 1
    delta_f = delta_f.astype(jnp.float32)
    half = (n_pdf - 1) // 2
    shape = (-1,) + (1,) * nd
    hal = jnp.round(halo_blocks / delta_f[seg].reshape(shape))
    d = hal
    for ax in range(1, nd + 1):
        upper = jax.lax.slice_in_dim(d, 1, d.shape[ax], axis=ax)
        lower = jax.lax.slice_in_dim(d, 0, d.shape[ax] - 1, axis=ax)
        d = upper - lower
    bsz = 4**nd
    k_raw = d.reshape(-1)  # (total_blocks * 4^nd,)
    n_samples = k_raw.shape[0]
    seg_s = jnp.repeat(seg, bsz)
    sbounds = bounds * bsz  # sample-level field boundaries
    n_samp_f = (sbounds[1:] - sbounds[:-1]).astype(jnp.float32)
    # escape fraction from the unsorted (field-ordered) samples (exact
    # integer counting — see field_sums)
    esc = (jnp.abs(k_raw) > half).astype(jnp.int32)
    ofrac = field_sums(esc, sbounds).astype(jnp.float32) / jnp.maximum(n_samp_f, 1.0)
    k = jnp.clip(k_raw, -half, half)
    # (field, bin) sort; seg is nondecreasing so fields stay contiguous at
    # [sbounds[f], sbounds[f+1]) and only bins reorder within each field.
    key = jnp.sort(seg_s * (n_pdf + 1) + (k + half).astype(jnp.int32))
    pos = jnp.arange(n_samples, dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), key[1:] != key[:-1]])
    # next run start after each position, via a reverse cumulative min
    fpos = jnp.where(first, pos, n_samples)
    nxt_incl = jnp.flip(jax.lax.cummin(jnp.flip(fpos)))
    nxt = jnp.concatenate([nxt_incl[1:], jnp.full((1,), n_samples, jnp.int32)])
    counts = (nxt - pos).astype(jnp.float32)  # run length, valid at run starts
    fid = key // (n_pdf + 1)
    p = counts / jnp.maximum(n_samp_f[fid], 1.0)
    # per-run PDF mass terms: |p log2 p| <= ~0.53, so the f32 prefix sum
    # stays accurate; the count columns go through exact int32 accumulation
    plogp = jnp.where(first, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    firsti = first.astype(jnp.int32)
    icols = jnp.stack(
        [
            firsti,                                   # n_obs
            firsti * (counts == 1.0),                 # Chao1 singletons
            firsti * (counts == 2.0),                 # Chao1 doubletons
        ],
        axis=1,
    )
    ent = -field_sums(plogp, sbounds)
    isums = field_sums(icols, sbounds).astype(jnp.float32)  # (F, 3)
    n_obs, f1, f2 = isums[:, 0], isums[:, 1], isums[:, 2]
    # Miller-Madow plug-in-bias correction, as in `estimate_sz`
    ent = ent + (n_obs - 1.0) / (2.0 * jnp.maximum(n_samp_f, 1.0) * LN2)
    chao1 = n_obs + f1 * jnp.maximum(f1 - 1.0, 0.0) / (2.0 * (f2 + 1.0))
    table_bits = TABLE_BITS_PER_SYMBOL * jnp.minimum(chao1, float(n_pdf))
    br = ent + SZ_BITRATE_OFFSET + ofrac * 64.0 + table_bits / jnp.maximum(size_f, 1.0)
    return Estimate(bitrate=br, psnr=sz_psnr(delta_f / 2.0, vr_f))
