"""Fault-tolerant checkpointing with the paper's per-field codec selection.

Two layouts, both behind one reader (manifest v3; the `layout` key picks
the reader):

flat (`CheckpointConfig.sharded=False`): tensors are gathered and saved
whole, so a restarted job may reload under ANY device count / mesh
(elastic scaling by gathering):

  <dir>/step_000123/
    manifest.json   # version: 3, layout: "flat"; the Policy/PolicySet
                    # spec; field table (name, codec s_i, shape, dtype,
                    # offset, nbytes, eb, resolved policy); wall time
    data.bin        # concatenated per-field streams (codec registry)
  <dir>/LATEST      # atomic pointer (written last)

segments (`CheckpointConfig.sharded=True`, DESIGN.md §6): the
shard-local engine (`core/sharded.py`) makes every codec decision from
per-shard statistics reconciled with a psum — no full-tensor gather —
and each field is encoded as per-shard *segments*, written to per-host
data files:

  <dir>/step_000123/
    manifest.json      # version: 3, layout: "segments"; per field:
                       # codec, eb, view_shape, resolved policy and a
                       # segment table [{start, stop, codec, host,
                       # offset, nbytes}] in folded-view coordinates
    data.<host>.bin    # this host's segments, concatenated
  <dir>/LATEST

Restore is elastic for both layouts: `restore` reassembles full tensors
from whatever segments exist (a segment checkpoint saved on 8 devices
reloads on 1, 4, or 32 — segment reassembly is mesh-free), and
`restore_tree(shardings=...)` re-shards the result onto ANY target mesh.
Pre-policy checkpoints stay readable forever: v1 manifests (no version
key, flat) and v2 manifests (version: 2, segments) dispatch to the same
readers. Every restored leaf is a WRITEABLE array.

Writes are atomic (tmp dir + rename); `keep_n` old checkpoints are pruned;
`async_save` runs serialization+IO off the training thread (the in-situ
model of the paper: compress while the next step computes) and re-raises
any worker exception from `wait()` — encoder failures are never silently
dropped.

Codec selection is batched: ALL lossy fields of one policy group go
through one `select_many`/`solve_many` estimator launch (one padded
block batch, one device round-trip per group) — or one shard-local
`plan_tree` launch in the segment layout — then per-field byte encoding
runs on a `workers`-wide thread pool so encoding of field i overlaps
with encoding of field j and with the sequential writer draining results
in order.

Quality travels as a `Policy` / `PolicySet` (`core/policy.py`,
DESIGN.md §2, §7): `CheckpointConfig.policy` holds the per-tensor
contract — the bound-centric default (``Policy.fixed_accuracy()``),
``Policy.fixed_psnr(db)`` / ``Policy.fixed_ratio(x)`` solved by the
quality-target controller ("every checkpoint is 8x smaller" as a storage
contract), or a `PolicySet` mixing contracts per tensor name
("weights at eb_rel 1e-4, `opt/*` at 8x"). Tensors are grouped by
resolved policy and each group rides one batched decision launch.

With a bare `Policy`, weights default to lossy and optimizer state
(`opt/*`) to raw (Adam moments are cheap to compress but sensitive near
zero) via the default `lossy` callable; with a `PolicySet`, the set's
rules govern everything (map `opt/*` to `Policy.raw()` — or to a lossy
policy — yourself). In the segment layout, policy-raw leaves also write per-shard
segments (exact original-dtype bytes, codec ``none``), so optimizer
state never gathers either.

Manifests are **v3**: `layout` ("flat" | "segments") picks the reader,
the top-level `policy` records the configured Policy/PolicySet spec, and
every field row records its *resolved* policy next to the codec and
bound — restore-side tooling can audit exactly what each tensor was
promised. v1 (no version key) and v2 (`version: 2`, segment layout)
checkpoints stay readable behind the same `restore`.

The legacy kwarg spelling (`CheckpointConfig(eb_rel=...)`, `mode=`,
`target_psnr=`, `target_ratio=`, `r_sp=`) shims onto an equivalent
`Policy` with a `DeprecationWarning`; decisions and bytes are unchanged.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import jax
import numpy as np

from repro.core import codecs, controller
from repro.core import selector as sel
from repro.core.policy import (
    Policy,
    PolicySet,
    as_policy_set,
    group_by_policy,
    policy_from_kwargs,
    policy_set_spec,
)


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep_n: int = 3
    # the quality contract (DESIGN.md §2, §7): one Policy for every lossy
    # tensor, or a PolicySet resolving one per tensor name. Default:
    # Policy.fixed_accuracy() (eb_rel 1e-4).
    policy: Policy | PolicySet | None = None
    compress: bool = True
    workers: int = 4  # thread-pool width for per-field byte encoding (0 = serial)
    # shard-local engine (DESIGN.md §6): decisions from per-shard statistics,
    # per-shard segment encoding, segment-layout manifest — no gather
    sharded: bool = False
    # cross-step decision cache (DESIGN.md §8): False = cold every save
    # (pre-§8 behavior, byte-identical); True = manager-owned
    # `DecisionCache()` (bit-identity contract, tolerance 0); or pass a
    # configured `DecisionCache` instance to share one across managers or
    # to opt into tolerance>0 / warm_start. The cache rides the manifest
    # (`decision_cache` key) so `restore` leaves the next save warm.
    cache: Any = False
    # deprecated kwarg spelling (None = unset) — shimmed onto `policy`
    eb_rel: float | None = None
    r_sp: float | None = None
    mode: str | None = None
    target_psnr: float | None = None
    target_ratio: float | None = None

    def __post_init__(self):
        if isinstance(self.policy, (int, float)):
            # old positional `eb_rel` in the policy slot
            if self.eb_rel is not None:
                raise ValueError("CheckpointConfig: eb_rel given twice")
            self.eb_rel, self.policy = float(self.policy), None
        legacy = (self.eb_rel, self.r_sp, self.mode, self.target_psnr, self.target_ratio)
        if any(v is not None for v in legacy):
            if self.policy is not None:
                raise ValueError(
                    "CheckpointConfig: pass either policy= or the legacy "
                    "quality kwargs, not both"
                )
            self.policy = policy_from_kwargs(
                "CheckpointConfig", mode=self.mode, eb_rel=self.eb_rel,
                target_psnr=self.target_psnr, target_ratio=self.target_ratio,
                r_sp=self.r_sp, default_eb_rel=1e-4, stacklevel=4,
            )
        elif self.policy is None:
            self.policy = Policy.fixed_accuracy()

    @property
    def policy_set(self) -> PolicySet:
        return as_policy_set(self.policy)


def _leaf_items(tree: Any) -> list[tuple[str, np.ndarray]]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def _leaf_items_raw(tree: Any) -> list[tuple[str, Any]]:
    """Like `_leaf_items` but WITHOUT materializing leaves on host — the
    sharded writer must see the original jax.Arrays to reach their shards."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        out.append((name, leaf))
    return out


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


#: spec recorded for leaves that ride raw (non-float, lossy-rejected, or
#: policy-raw) — the manifest row's `policy` key is always present in v3
_RAW_SPEC = {"mode": "raw"}


def _field_policy_spec(pol: Policy | None) -> dict:
    return pol.spec() if pol is not None else dict(_RAW_SPEC)


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None
        # resolve cfg.cache -> DecisionCache | None (DESIGN.md §8)
        cache = cfg.cache
        if cache is True:
            from repro.core.decision_cache import DecisionCache

            cache = DecisionCache()
        elif cache is False or cache is None:
            cache = None
        self.cache = cache

    # -- save ---------------------------------------------------------------

    def _default_lossy(self) -> Callable[[str], bool]:
        """With a bare Policy, optimizer state (`opt/*`) defaults to raw;
        with a PolicySet the rules govern raw-ness themselves, so every
        eligible leaf goes through policy resolution."""
        if isinstance(self.cfg.policy, PolicySet):
            return lambda name: True
        return lambda name: not name.startswith("opt/")

    def _resolve_policies(
        self, items: list, lossy: Callable[[str], bool]
    ) -> dict[int, Policy]:
        """index -> resolved Policy for every leaf that will compress:
        float, >= 64 values, accepted by `lossy`, and not policy-raw."""
        cfg = self.cfg
        pset = cfg.policy_set
        pol_of: dict[int, Policy] = {}
        for i, (name, leaf) in enumerate(items):
            if not (
                cfg.compress
                and lossy(name)
                and np.issubdtype(leaf.dtype, np.floating)
                and leaf.size >= 64
            ):
                continue
            pol = pset.resolve(name)
            if pol.mode == "raw":
                continue
            pol_of[i] = pol
        return pol_of

    def save(self, step: int, tree: Any, lossy: Callable[[str], bool] | None = None) -> str:
        """Synchronous atomic save. Each tensor's quality policy comes from
        `cfg.policy` (a `PolicySet` resolves per name); `lossy(name)` is a
        hard per-call override forcing names to raw (default: with a bare
        Policy, float leaves under 'opt/' ride raw). With `cfg.sharded`,
        writes the per-shard segment layout via the shard-local engine
        (DESIGN.md §6) — no full-tensor gather."""
        if lossy is None:
            lossy = self._default_lossy()
        if self.cfg.sharded:
            return self._save_sharded(step, tree, lossy)
        cfg = self.cfg
        tmp = os.path.join(cfg.directory, f".tmp_step_{step:09d}_{os.getpid()}")
        final = os.path.join(cfg.directory, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        fields = []
        t0 = time.time()
        items = _leaf_items(tree)
        pol_of = self._resolve_policies(items, lossy)
        # Steps 1-3 for every lossy field in ONE batched estimator launch
        # per round AND policy group (the solvers cast to f32 one field at
        # a time and keep only the sampled blocks, so no full-tree f32
        # copy materializes; a single-policy tree is one group, exactly
        # the pre-policy batch composition)
        sel_of: dict[int, sel.Selection] = {}
        for pol, idxs in group_by_policy(pol_of).items():
            arrs = [items[i][1] for i in idxs]
            names = [items[i][0] for i in idxs] if self.cache is not None else None
            if pol.mode == "fixed_accuracy":
                sels = sel.select_many(
                    arrs, policy=pol, cache=self.cache, names=names
                )
            else:
                sols = controller.solve_many(
                    arrs, pol, cache=self.cache, names=names
                )
                sels = [s.selection for s in sols]
            sel_of.update(zip(idxs, sels))

        def _encode(i: int) -> tuple[bytes, str, float]:
            name, arr = items[i]
            s = sel_of.get(i)
            if s is None:
                return arr.tobytes(), "none", 0.0
            cf = sel.encode_with_selection(arr, s)  # casts to f32 internally
            return cf.data, cf.codec, s.eb_abs

        with open(os.path.join(tmp, "data.bin"), "wb") as f:
            off = 0
            for i, ((name, arr), (data, codec, eb)) in enumerate(
                zip(items, self._encoded_in_order(items, _encode))
            ):
                f.write(data)
                fields.append(
                    dict(
                        name=name, codec=codec, shape=list(arr.shape),
                        dtype=str(arr.dtype), offset=off, nbytes=len(data), eb=eb,
                        policy=_field_policy_spec(pol_of.get(i)),
                    )
                )
                off += len(data)
        manifest = self._manifest(step, fields, off, t0, extra=dict(layout="flat"))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return self._publish(tmp, final)

    def _encoded_in_order(self, items: list, encode: Callable[[int], Any]):
        """Yield `encode(i)` in input order while a bounded thread pool runs
        ahead of the write cursor — only `2 * workers` results may sit
        encoded-but-unwritten, so byte streams can't pile up past RAM.
        Shared by the v1 and v2 writers so the window/drain logic cannot
        drift between the layouts."""
        cfg = self.cfg
        pool = (
            ThreadPoolExecutor(max_workers=cfg.workers)
            if cfg.workers > 1 and len(items) > 1
            else None
        )
        window = 2 * cfg.workers if pool else 1
        futs: deque = deque()
        nxt = 0
        try:
            for i in range(len(items)):
                if pool is not None:
                    while nxt < len(items) and len(futs) < window:
                        futs.append(pool.submit(encode, nxt))
                        nxt += 1
                    yield futs.popleft().result()
                else:
                    yield encode(i)
        finally:
            if pool is not None:
                pool.shutdown()

    def _manifest(self, step: int, fields: list, total_bytes: int, t0: float,
                  extra: dict | None = None) -> dict:
        """Manifest fields shared by both layouts (v3: `layout` comes in
        `extra`; `policy` records the configured Policy/PolicySet, and the
        legacy `mode`/`target` keys mirror the DEFAULT policy so pre-v3
        tooling keeps reading something sensible)."""
        default = self.cfg.policy_set.default
        man = dict(
            step=step,
            version=3,
            policy=policy_set_spec(self.cfg.policy_set),
            mode=default.mode,
            target=(
                default.target_psnr if default.mode == "fixed_psnr"
                else default.target_ratio if default.mode == "fixed_ratio"
                else default.eb_rel if default.eb_rel is not None
                else default.eb_abs
            ),
            fields=fields,
            total_bytes=total_bytes,
            raw_bytes=int(
                sum(
                    int(np.prod(fl["shape"] or [1])) * np.dtype(fl["dtype"]).itemsize
                    for fl in fields
                )
            ),
            wall_time=time.time(),
            save_seconds=time.time() - t0,
            selection_bits={fl["name"]: fl["codec"] for fl in fields},
        )
        if extra:
            man.update(extra)
        if self.cache is not None:
            # persist the warm-save state (DESIGN.md §8.4): a restored run
            # reloads these entries and its first save revalidates them
            man["decision_cache"] = self.cache.to_manifest()
        return man

    def _publish(self, tmp: str, final: str) -> str:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(os.path.join(self.cfg.directory, ".LATEST_tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(
            os.path.join(self.cfg.directory, ".LATEST_tmp"),
            os.path.join(self.cfg.directory, "LATEST"),
        )
        self._prune()
        return final

    def _save_sharded(self, step: int, tree: Any, lossy: Callable[[str], bool]) -> str:
        """The segment-layout writer: shard-local decisions
        (`core/sharded.plan_tree`, one launch per policy group), per-shard
        segment encoding on the thread pool, per-host data files.
        Policy-raw and non-float leaves write exact original-dtype bytes,
        also per shard (codec ``none``) — nothing in this path gathers a
        tensor that the engine's layout analysis can keep sharded."""
        from repro.core import sharded as shd
        from repro.runtime import sharding as rsh

        if jax.process_count() > 1:
            # the segment writer is single-controller: one process fetches
            # every unique shard and writes one manifest. True multi-host
            # saves need per-host segment tables + manifest assembly (§6.2).
            raise NotImplementedError(
                "sharded checkpoint writing is single-process for now; "
                "run the save from a single-controller job or use sharded=False"
            )
        cfg = self.cfg
        tmp = os.path.join(cfg.directory, f".tmp_step_{step:09d}_{os.getpid()}")
        final = os.path.join(cfg.directory, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        t0 = time.time()
        items = _leaf_items_raw(tree)
        pol_of = self._resolve_policies(items, lossy)
        plan_of: dict[int, Any] = {}
        for pol, idxs in group_by_policy(pol_of).items():
            names = [items[i][0] for i in idxs] if self.cache is not None else None
            plans = shd.plan_tree(
                [items[i][1] for i in idxs], pol, cache=self.cache, names=names
            )
            plan_of.update(zip(idxs, plans))
        host = int(jax.process_index())

        def _encode(i: int):
            """-> (view_shape, codec, eb, eb_sz, [(start, stop, codec, bytes)])"""
            name, leaf = items[i]
            plan = plan_of.get(i)
            if plan is not None:
                encoded = shd.encode_plan(leaf, plan)
                segs = [(s.start, s.stop, s.codec, s.data) for s in encoded]
                sel = plan.selection
                codec = shd.field_codec(sel.codec, encoded)
                return plan.view_shape, codec, sel.eb_abs, sel.eb_sz, segs
            shape = tuple(int(s) for s in np.shape(leaf))
            if rsh.mesh_of(leaf) is not None and np.ndim(leaf) > 0:
                segs = [
                    (start, stop, "none",
                     rsh.shard_data(leaf, shd._local_device(devs)).tobytes())
                    for start, stop, devs in rsh.unique_shards(leaf)
                ]
            else:
                arr = np.asarray(leaf)
                segs = [((0,) * arr.ndim, shape, "none", arr.tobytes())]
            return shape, "none", 0.0, 0.0, segs

        fields = []
        with open(os.path.join(tmp, f"data.{host}.bin"), "wb") as f:
            off = 0
            for i, ((name, leaf), (view_shape, codec, eb, eb_sz, segs)) in enumerate(
                zip(items, self._encoded_in_order(items, _encode))
            ):
                seg_rows = []
                for start, stop, seg_codec, data in segs:
                    f.write(data)
                    seg_rows.append(
                        dict(
                            start=list(start), stop=list(stop),
                            codec=seg_codec, host=host,
                            offset=off, nbytes=len(data),
                        )
                    )
                    off += len(data)
                fields.append(
                    dict(
                        name=name, codec=codec,
                        shape=list(np.shape(leaf)), dtype=str(leaf.dtype),
                        view_shape=list(view_shape), eb=eb, eb_sz=eb_sz,
                        nbytes=sum(r["nbytes"] for r in seg_rows),
                        segments=seg_rows,
                        policy=_field_policy_spec(pol_of.get(i)),
                    )
                )
        manifest = self._manifest(
            step, fields, off, t0, extra=dict(layout="segments", hosts=[host])
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return self._publish(tmp, final)

    def async_save(self, step: int, tree: Any, **kw) -> threading.Thread:
        """Snapshot now; serialize+write on a worker thread. Unsharded saves
        snapshot to host memory; sharded saves snapshot DEVICE-side
        (`jnp.copy`, sharding-preserving) so a training step that donates
        or overwrites its buffers cannot race the background writer — the
        copy costs transient HBM, not a gather. Any exception the worker
        hits — encoder failures included — is re-raised by `wait()`."""
        if self.cfg.sharded:
            import jax.numpy as jnp

            host_tree = jax.tree_util.tree_map(
                lambda x: jnp.copy(x) if isinstance(x, jax.Array) else np.array(x),
                tree,
            )
        else:
            host_tree = jax.tree_util.tree_map(lambda x: np.array(x), tree)
        self.wait()
        self._exc = None

        def _run() -> None:
            try:
                self.save(step, host_tree, **kw)
            except BaseException as e:  # noqa: BLE001 - surfaced by wait()
                self._exc = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()
        return self._thread

    def wait(self) -> None:
        """Join the async save, re-raising whatever it raised: a failed
        checkpoint must fail loudly, not leave a stale LATEST behind."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._exc = getattr(self, "_exc", None), None
        if exc is not None:
            raise exc

    def _prune(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.cfg.directory) if d.startswith("step_")
        )
        for d in steps[: -self.cfg.keep_n]:
            shutil.rmtree(os.path.join(self.cfg.directory, d), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> int | None:
        p = os.path.join(self.cfg.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[-1])

    def restore(self, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
        """Returns (step, {name: array}). Mesh-agnostic for BOTH layouts:
        the v1 single-file reader stays supported, and v2 per-shard
        segments reassemble into full tensors regardless of the saving
        mesh — the caller (or `restore_tree(shardings=...)`) reshards."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if self.cache is not None and "decision_cache" in manifest:
            # resume warm: the next save revalidates these entries against
            # fresh fingerprints before trusting any of them (DESIGN.md §8)
            self.cache.load_manifest(manifest["decision_cache"])
        # layout dispatch: v3 records it explicitly; v2 is always the
        # segment layout, v1 (no version key) always the flat one
        version = int(manifest.get("version", 1))
        layout = manifest.get("layout", "segments" if version == 2 else "flat")
        if layout == "segments":
            return step, self._restore_v2(d, manifest)
        out: dict[str, np.ndarray] = {}
        with open(os.path.join(d, "data.bin"), "rb") as f:
            blob = f.read()
        for fl in manifest["fields"]:
            seg = blob[fl["offset"] : fl["offset"] + fl["nbytes"]]
            shape, dtype = tuple(fl["shape"]), np.dtype(fl["dtype"])
            if fl["codec"] == "none":
                # exact original-dtype bytes (non-float / policy-raw rows)
                arr = codecs.writeable_frombuffer(seg, dtype).reshape(shape)
            elif fl["codec"] == "raw":
                # selection-era raw rows hold f32 working-dtype bytes
                arr = (
                    codecs.writeable_frombuffer(seg, np.float32)
                    .reshape(shape)
                    .astype(dtype)
                )
            else:
                cf = sel.CompressedField(fl["codec"], seg, shape, fl["dtype"])
                arr = sel.decompress(cf)
            out[fl["name"]] = arr
        return step, out

    def _restore_v2(self, d: str, manifest: dict) -> dict[str, np.ndarray]:
        """Elastic v2 reader: paste each field's segments into its folded
        view (decompressing lossy ones), then reshape to the original
        shape/dtype. Works for any saving mesh — segments carry their own
        view coordinates."""
        from repro.core import sharded as shd

        blobs: dict[int, bytes] = {}

        def blob(host: int) -> bytes:
            if host not in blobs:
                with open(os.path.join(d, f"data.{host}.bin"), "rb") as f:
                    blobs[host] = f.read()
            return blobs[host]

        out: dict[str, np.ndarray] = {}
        for fl in manifest["fields"]:
            shape, dtype = tuple(fl["shape"]), np.dtype(fl["dtype"])
            vshape = tuple(fl["view_shape"])
            rows = fl["segments"]
            if fl["codec"] == "none":
                arr = np.empty(vshape, dtype)  # writeable by construction
                for sg in rows:
                    data = blob(sg["host"])[sg["offset"] : sg["offset"] + sg["nbytes"]]
                    ext = tuple(b - a for a, b in zip(sg["start"], sg["stop"]))
                    arr[tuple(slice(a, b) for a, b in zip(sg["start"], sg["stop"]))] = (
                        np.frombuffer(data, dtype).reshape(ext)
                    )
                out[fl["name"]] = arr.reshape(shape)
                continue
            segments = [
                shd.Segment(
                    tuple(sg["start"]), tuple(sg["stop"]), sg["codec"],
                    blob(sg["host"])[sg["offset"] : sg["offset"] + sg["nbytes"]],
                )
                for sg in rows
            ]
            view = shd.decode_segments(vshape, segments)
            out[fl["name"]] = view.reshape(shape).astype(dtype)
        return out

    def restore_tree(
        self, template: Any, step: int | None = None, shardings: Any = None
    ) -> tuple[int, Any]:
        """Restore into the structure of `template` (names must match).

        `shardings` (optional pytree of `jax.sharding.Sharding` matching
        `template`) re-shards every leaf onto a TARGET mesh as it loads —
        the elastic-restore path: a checkpoint saved on one mesh resumes
        under any other device count or layout (DESIGN.md §6)."""
        step, flat = self.restore(step)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        vals = []
        for path, leaf in leaves:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = flat[name]
            vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), vals
        )
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda v, s: jax.device_put(v, s), tree, shardings
            )
        return step, tree
