"""Fault-tolerant checkpointing with the paper's per-field codec selection.

Two layouts, both behind one reader (manifest v3; the `layout` key picks
the reader):

flat (`CheckpointConfig.sharded=False`): tensors are gathered and saved
whole, so a restarted job may reload under ANY device count / mesh
(elastic scaling by gathering):

  <dir>/step_000123/
    manifest.json   # version: 3, layout: "flat"; the Policy/PolicySet
                    # spec; field table (name, codec s_i, shape, dtype,
                    # offset, nbytes, eb, resolved policy); wall time
    data.bin        # concatenated per-field streams (codec registry)
  <dir>/LATEST      # atomic pointer (written last)

segments (`CheckpointConfig.sharded=True`, DESIGN.md §6): the
shard-local engine (`core/sharded.py`) makes every codec decision from
per-shard statistics reconciled with a psum — no full-tensor gather —
and each field is encoded as per-shard *segments*, written to per-host
data files:

  <dir>/step_000123/
    manifest.json      # version: 3, layout: "segments"; per field:
                       # codec, eb, view_shape, resolved policy and a
                       # segment table [{start, stop, codec, host,
                       # offset, nbytes}] in folded-view coordinates;
                       # hosts + per-host completion (byte counts)
    data.<host>.bin    # one per host: that host's segments, concatenated
    segtable.<host>.json  # multi-host only: the host's segment rows,
                       # merged into the manifest by host 0
    commit.<host>      # per-host completion marker, written LAST
  <dir>/LATEST

The segment writer is genuinely **multi-host** (DESIGN.md §6.2): under
`jax.process_count() > 1`, the psum reconciliation makes every process
derive the IDENTICAL per-field decisions, then each process encodes and
writes only the shards it owns (`dist.owner_host` — one writer per
replicated shard, no coordination needed) into its own `data.<host>.bin`
plus a `segtable.<host>.json` row table and a `commit.<host>` marker.
A bounded barrier (`CheckpointConfig.barrier_timeout_s`) fences the
write phase — a dead or straggling host FAILS the save on every live
host instead of hanging the job (after up to `save_retries` bounded
requeues of the write phase under fresh barrier keys, which absorbs
transient stragglers) — after which host 0 merges the segment
tables into one manifest (recording `hosts` and per-host `completion`
byte counts) and atomically promotes the step directory. A save that
dies mid-flight therefore never publishes: the tmp directory is simply
abandoned and the previous step stays restorable. `restore` refuses any
segment manifest whose completion markers are missing or whose data
files are short (`IncompleteCheckpointError`).

Restore is elastic for both layouts: `restore` reassembles full tensors
from whatever segments exist (a segment checkpoint saved on 8 devices
reloads on 1, 4, or 32 — segment reassembly is mesh-free), and
`restore_tree(shardings=...)` re-shards the result onto ANY target mesh.
Pre-policy checkpoints stay readable forever: v1 manifests (no version
key, flat) and v2 manifests (version: 2, segments) dispatch to the same
readers. Every restored leaf is a WRITEABLE array.

Writes are atomic (tmp dir + rename); `keep_n` old checkpoints are pruned;
`async_save` runs serialization+IO off the training thread (the in-situ
model of the paper: compress while the next step computes) and re-raises
any worker exception from `wait()` — encoder failures are never silently
dropped.

Codec selection is batched: ALL lossy fields of one policy group go
through one `select_many`/`solve_many` estimator launch (one padded
block batch, one device round-trip per group) — or one shard-local
`plan_tree` launch in the segment layout — then per-field byte encoding
runs on a `workers`-wide thread pool so encoding of field i overlaps
with encoding of field j and with the sequential writer draining results
in order.

Quality travels as a `Policy` / `PolicySet` (`core/policy.py`,
DESIGN.md §2, §7): `CheckpointConfig.policy` holds the per-tensor
contract — the bound-centric default (``Policy.fixed_accuracy()``),
``Policy.fixed_psnr(db)`` / ``Policy.fixed_ratio(x)`` solved by the
quality-target controller ("every checkpoint is 8x smaller" as a storage
contract), the §7.4 metric targets (``Policy.fixed_ssim(s)`` /
``Policy.fixed_correlation(rho)`` / ``Policy.fixed_ks(d)``), or a
`PolicySet` mixing contracts per tensor name ("weights at eb_rel 1e-4,
`opt/*` at 8x"). Tensors are grouped by resolved policy and each group
rides one batched decision launch. Every target-mode field row records
a `quality` dict (resolved target, estimated PSNR/bitrate/metric,
on_target) in the manifest, so what each tensor was promised — and what
the controller believes it got — audits from the manifest alone.

With a bare `Policy`, weights default to lossy and optimizer state
(`opt/*`) to raw (Adam moments are cheap to compress but sensitive near
zero) via the default `lossy` callable; with a `PolicySet`, the set's
rules govern everything (map `opt/*` to `Policy.raw()` — or to a lossy
policy — yourself). In the segment layout, policy-raw leaves also write per-shard
segments (exact original-dtype bytes, codec ``none``), so optimizer
state never gathers either.

Manifests are **v3**: `layout` ("flat" | "segments") picks the reader,
the top-level `policy` records the configured Policy/PolicySet spec, and
every field row records its *resolved* policy next to the codec and
bound — restore-side tooling can audit exactly what each tensor was
promised. v1 (no version key) and v2 (`version: 2`, segment layout)
checkpoints stay readable behind the same `restore`.

The legacy kwarg spelling (`CheckpointConfig(eb_rel=...)`, `mode=`,
`target_psnr=`, `target_ratio=`, `r_sp=`) shims onto an equivalent
`Policy` with a `DeprecationWarning`; decisions and bytes are unchanged.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import jax
import numpy as np

from repro.core import codecs, controller
from repro.core import selector as sel
from repro.runtime import dist
from repro.core.policy import (
    TARGET_FIELD,
    Policy,
    PolicySet,
    as_policy_set,
    group_by_policy,
    policy_from_kwargs,
    policy_set_spec,
)


class IncompleteCheckpointError(RuntimeError):
    """A segment checkpoint is missing per-host completion markers (or its
    data files are shorter than the recorded byte counts): some host's
    write never finished, so the manifest must not be trusted."""


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep_n: int = 3
    # the quality contract (DESIGN.md §2, §7): one Policy for every lossy
    # tensor, or a PolicySet resolving one per tensor name. Default:
    # Policy.fixed_accuracy() (eb_rel 1e-4).
    policy: Policy | PolicySet | None = None
    compress: bool = True
    workers: int = 4  # thread-pool width for per-field byte encoding (0 = serial)
    # shard-local engine (DESIGN.md §6): decisions from per-shard statistics,
    # per-shard segment encoding, segment-layout manifest — no gather
    sharded: bool = False
    # cross-step decision cache (DESIGN.md §8): False = cold every save
    # (pre-§8 behavior, byte-identical); True = manager-owned
    # `DecisionCache()` (bit-identity contract, tolerance 0); or pass a
    # configured `DecisionCache` instance to share one across managers or
    # to opt into tolerance>0 / warm_start. The cache rides the manifest
    # (`decision_cache` key) so `restore` leaves the next save warm.
    cache: Any = False
    # device-resident Stage III (DESIGN.md §3.7): when True, codecs that
    # advertise the `device_encode` capability pack their bitstreams
    # in-graph and only the packed words cross the interconnect; fields
    # the device tier declines (fallback rules of §3.7) take the host
    # coder, so streams stay byte-identical either way
    device_encode: bool = False
    # multi-host save fencing (DESIGN.md §6.2): how long any host waits at
    # the write/publish barriers before FAILING the save (a straggler or
    # dead host must surface as an exception, never as a hang)
    barrier_timeout_s: float = 120.0
    # bounded requeue on `BarrierTimeout` (DESIGN.md §6.2): a transiently
    # straggling host (GC pause, FS hiccup) fails the attempt on every
    # live host; each retry re-runs the write phase under a FRESH save
    # sequence number — fresh KV barrier keys, so a late arrival at the
    # abandoned attempt's barrier can never satisfy the new one. 0
    # disables. The count actually used is `manager.last_save_retries`
    # (and `thread.save_result["retries"]` for async saves).
    save_retries: int = 1
    # deprecated kwarg spelling (None = unset) — shimmed onto `policy`
    eb_rel: float | None = None
    r_sp: float | None = None
    mode: str | None = None
    target_psnr: float | None = None
    target_ratio: float | None = None

    def __post_init__(self):
        if isinstance(self.policy, (int, float)):
            # old positional `eb_rel` in the policy slot
            if self.eb_rel is not None:
                raise ValueError("CheckpointConfig: eb_rel given twice")
            self.eb_rel, self.policy = float(self.policy), None
        legacy = (self.eb_rel, self.r_sp, self.mode, self.target_psnr, self.target_ratio)
        if any(v is not None for v in legacy):
            if self.policy is not None:
                raise ValueError(
                    "CheckpointConfig: pass either policy= or the legacy "
                    "quality kwargs, not both"
                )
            self.policy = policy_from_kwargs(
                "CheckpointConfig", mode=self.mode, eb_rel=self.eb_rel,
                target_psnr=self.target_psnr, target_ratio=self.target_ratio,
                r_sp=self.r_sp, default_eb_rel=1e-4, stacklevel=4,
            )
        elif self.policy is None:
            self.policy = Policy.fixed_accuracy()

    @property
    def policy_set(self) -> PolicySet:
        return as_policy_set(self.policy)


def _leaf_items(tree: Any) -> list[tuple[str, np.ndarray]]:
    """Host copies of every leaf. `dist.to_numpy` replicates leaves this
    process cannot fully address (a collective — in a multi-process job
    every host must walk the same tree at the same point), so the flat
    layout stays usable beyond one process: decisions are derived from
    identical gathered arrays on every host and host 0 alone writes."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, dist.to_numpy(leaf)))
    return out


def _leaf_items_raw(tree: Any) -> list[tuple[str, Any]]:
    """Like `_leaf_items` but WITHOUT materializing leaves on host — the
    sharded writer must see the original jax.Arrays to reach their shards."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        out.append((name, leaf))
    return out


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


#: spec recorded for leaves that ride raw (non-float, lossy-rejected, or
#: policy-raw) — the manifest row's `policy` key is always present in v3
_RAW_SPEC = {"mode": "raw"}


def _field_policy_spec(pol: Policy | None) -> dict:
    return pol.spec() if pol is not None else dict(_RAW_SPEC)


def _quality_record(sol: Any) -> dict | None:
    """Manifest field row `quality` key for a §7 target solve: the resolved
    target next to what the controller estimates it achieved — restore-side
    tooling can audit the quality contract per tensor without re-deciding.
    `est_metric` appears only for the §7.4 metric modes (fixed_ssim /
    fixed_correlation / fixed_ks); None for fixed_accuracy/raw rows (no
    solve happened, the bound in `eb` is the whole contract)."""
    if sol is None:
        return None
    rec = dict(
        mode=sol.mode, target=sol.target, est_psnr=sol.est_psnr,
        est_bitrate=sol.est_bitrate, on_target=sol.on_target,
    )
    if sol.est_metric is not None:
        rec["est_metric"] = sol.est_metric
    return rec


class _HostBlobs:
    """Range reader over a step directory's per-host data files: a host's
    file is opened on first touch and only the spans asked for are read —
    the elastic restore's locality primitive (a process restoring its own
    shards never reads bytes from a data file it doesn't need)."""

    def __init__(self, d: str):
        self._d = d
        self._files: dict[int, Any] = {}

    def read(self, host: int, offset: int, nbytes: int) -> bytes:
        f = self._files.get(host)
        if f is None:
            f = self._files[host] = open(
                os.path.join(self._d, f"data.{host}.bin"), "rb"
            )
        f.seek(offset)
        return f.read(nbytes)

    @property
    def hosts_opened(self) -> list[int]:
        return sorted(self._files)

    def close(self) -> None:
        for f in self._files.values():
            f.close()
        self._files.clear()


def _flat_span(
    start: tuple, stop: tuple, shape: tuple[int, ...]
) -> tuple[int, int]:
    """Conservative C-order flat element range [lo, hi) bounding the box
    start:stop of an array of `shape`. The fold (`core/sharded.fold_plan`)
    only merges adjacent dims — a pure C-order reshape — so spans computed
    in ORIGINAL and FOLDED coordinates index the same flat element order
    and are directly comparable: the basis of restore-side segment
    filtering. Conservative means a span may cover extra elements (a box
    is not flat-contiguous), never fewer — a needed segment is never
    skipped."""
    if not shape:
        return 0, 1
    if any(int(b) <= int(a) for a, b in zip(start, stop)):
        return 0, 0
    lo = int(np.ravel_multi_index(tuple(int(a) for a in start), shape))
    hi = int(np.ravel_multi_index(tuple(int(b) - 1 for b in stop), shape)) + 1
    return lo, hi


def _spans_overlap(a: tuple[int, int], b: tuple[int, int]) -> bool:
    return a[0] < b[1] and b[0] < a[1]


def _need_span(sharding: Any, shape: tuple[int, ...]) -> tuple[int, int]:
    """The conservative flat span of the elements THIS process must hold
    under a target `sharding`: the union bounding range of its addressable
    shards' index boxes. (0, 0) when no shard of the field lands here."""
    try:
        imap = sharding.devices_indices_map(tuple(shape))
    except Exception:
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        return 0, size
    pid = dist.process_index()
    lo = hi = None
    for dev, idx in imap.items():
        if int(getattr(dev, "process_index", 0)) != pid:
            continue
        start, stop = [], []
        for sl, dim in zip(idx, shape):
            a, b, _ = sl.indices(dim)
            start.append(a)
            stop.append(b)
        a, b = _flat_span(tuple(start), tuple(stop), tuple(shape))
        lo = a if lo is None else min(lo, a)
        hi = b if hi is None else max(hi, b)
    if lo is None:
        return 0, 0
    return lo, hi


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None
        # per-manager save counter: barrier names must be fresh per save
        # (re-saving one step would otherwise reuse a consumed barrier);
        # SPMD symmetry keeps it in lockstep on every host
        self._save_seq = 0
        # segment locality of the last multi-host `restore_tree` (tests +
        # ops introspection): {"segments_decoded", "segments_total",
        # "hosts_opened"}
        self.last_restore_stats: dict | None = None
        # BarrierTimeout requeues the last completed save needed (§6.2)
        self.last_save_retries = 0
        # resolve cfg.cache -> DecisionCache | None (DESIGN.md §8)
        cache = cfg.cache
        if cache is True:
            from repro.core.decision_cache import DecisionCache

            cache = DecisionCache()
        elif cache is False or cache is None:
            cache = None
        self.cache = cache

    # -- save ---------------------------------------------------------------

    def _default_lossy(self) -> Callable[[str], bool]:
        """With a bare Policy, optimizer state (`opt/*`) defaults to raw;
        with a PolicySet the rules govern raw-ness themselves, so every
        eligible leaf goes through policy resolution."""
        if isinstance(self.cfg.policy, PolicySet):
            return lambda name: True
        return lambda name: not name.startswith("opt/")

    def _resolve_policies(
        self, items: list, lossy: Callable[[str], bool]
    ) -> dict[int, Policy]:
        """index -> resolved Policy for every leaf that will compress:
        float, >= 64 values, accepted by `lossy`, and not policy-raw."""
        cfg = self.cfg
        pset = cfg.policy_set
        pol_of: dict[int, Policy] = {}
        for i, (name, leaf) in enumerate(items):
            if not (
                cfg.compress
                and lossy(name)
                and np.issubdtype(leaf.dtype, np.floating)
                and leaf.size >= 64
            ):
                continue
            pol = pset.resolve(name)
            if pol.mode == "raw":
                continue
            pol_of[i] = pol
        return pol_of

    def _retry_barrier_timeout(self, attempt_fn: Callable[[], str]) -> str:
        """Bounded `BarrierTimeout` requeue (DESIGN.md §6.2). Each attempt
        consumes its own `_save_seq` value — the counter stays in lockstep
        on every host (all hosts run the same attempt loop), so the retry's
        KV barrier keys (`ckpt:{step}:{seq}:*`) are fresh on every host and
        a straggler arriving late at an abandoned attempt's barrier cannot
        satisfy the new one. Only the write/publish phase is retried —
        device collectives (plan/gather) run once, upstream. Exhausting
        `cfg.save_retries` re-raises the timeout: a persistently dead host
        must fail the save, not loop. `last_save_retries` records how many
        requeues the returning attempt needed."""
        retries = max(0, int(self.cfg.save_retries))
        self.last_save_retries = 0
        for attempt in range(retries + 1):
            try:
                return attempt_fn()
            except dist.BarrierTimeout:
                if attempt >= retries:
                    raise
                self.last_save_retries = attempt + 1
        raise AssertionError("unreachable")

    def save(self, step: int, tree: Any, lossy: Callable[[str], bool] | None = None) -> str:
        """Synchronous atomic save. Each tensor's quality policy comes from
        `cfg.policy` (a `PolicySet` resolves per name); `lossy(name)` is a
        hard per-call override forcing names to raw (default: with a bare
        Policy, float leaves under 'opt/' ride raw). With `cfg.sharded`,
        writes the per-shard segment layout via the shard-local engine
        (DESIGN.md §6) — no full-tensor gather. Saves that die at a
        multi-host barrier are requeued up to `cfg.save_retries` times
        under fresh barrier keys before the `BarrierTimeout` surfaces."""
        if lossy is None:
            lossy = self._default_lossy()
        if self.cfg.sharded:
            return self._save_sharded(step, tree, lossy)
        return self._retry_barrier_timeout(
            lambda: self._save_flat(step, tree, lossy)
        )

    def _save_flat(self, step: int, tree: Any, lossy: Callable[[str], bool]) -> str:
        """One attempt of the flat (gathered) writer — `save` wraps it in
        the bounded BarrierTimeout requeue. `_leaf_items` is a collective
        only for leaves not yet on host; the async path materializes the
        snapshot on the calling thread first, so a worker-thread retry
        re-walks plain host arrays."""
        cfg = self.cfg
        final = os.path.join(cfg.directory, f"step_{step:09d}")
        t0 = time.time()
        # the gather (a collective beyond one process) runs on EVERY host;
        # selection + writing then run on host 0 alone — flat multi-host
        # saves are correct but gather-bound, sharded=True is the one that
        # scales (DESIGN.md §6.2)
        items = _leaf_items(tree)
        seq = self._save_seq
        self._save_seq += 1
        if dist.process_index() != 0:
            dist.barrier(
                f"ckpt:{step}:{seq}:published", self.cfg.barrier_timeout_s
            )
            return final
        tmp = os.path.join(cfg.directory, f".tmp_step_{step:09d}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        fields = []
        pol_of = self._resolve_policies(items, lossy)
        # Steps 1-3 for every lossy field in ONE batched estimator launch
        # per round AND policy group (the solvers cast to f32 one field at
        # a time and keep only the sampled blocks, so no full-tree f32
        # copy materializes; a single-policy tree is one group, exactly
        # the pre-policy batch composition)
        sel_of: dict[int, sel.Selection] = {}
        sol_of: dict[int, controller.TargetSolution] = {}
        for pol, idxs in group_by_policy(pol_of).items():
            arrs = [items[i][1] for i in idxs]
            names = [items[i][0] for i in idxs] if self.cache is not None else None
            if pol.mode == "fixed_accuracy":
                sels = sel.select_many(
                    arrs, policy=pol, cache=self.cache, names=names
                )
            else:
                sols = controller.solve_many(
                    arrs, pol, cache=self.cache, names=names
                )
                sol_of.update(zip(idxs, sols))
                sels = [s.selection for s in sols]
            sel_of.update(zip(idxs, sels))

        def _encode(i: int) -> tuple[bytes, str, float]:
            name, arr = items[i]
            s = sel_of.get(i)
            if s is None:
                return arr.tobytes(), "none", 0.0
            cf = sel.encode_with_selection(  # casts to f32 internally
                arr, s, device_encode=self.cfg.device_encode
            )
            return cf.data, cf.codec, s.eb_abs

        with open(os.path.join(tmp, "data.bin"), "wb") as f:
            off = 0
            for i, ((name, arr), (data, codec, eb)) in enumerate(
                zip(items, self._encoded_in_order(items, _encode))
            ):
                f.write(data)
                row = dict(
                    name=name, codec=codec, shape=list(arr.shape),
                    dtype=str(arr.dtype), offset=off, nbytes=len(data), eb=eb,
                    policy=_field_policy_spec(pol_of.get(i)),
                )
                q = _quality_record(sol_of.get(i))
                if q is not None:
                    row["quality"] = q
                fields.append(row)
                off += len(data)
        manifest = self._manifest(step, fields, off, t0, extra=dict(layout="flat"))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        out = self._publish(tmp, final)
        dist.barrier(f"ckpt:{step}:{seq}:published", self.cfg.barrier_timeout_s)
        return out

    def _encoded_in_order(self, items: list, encode: Callable[[int], Any]):
        """Yield `encode(i)` in input order while a bounded thread pool runs
        ahead of the write cursor — only `2 * workers` results may sit
        encoded-but-unwritten, so byte streams can't pile up past RAM.
        Shared by the v1 and v2 writers so the window/drain logic cannot
        drift between the layouts."""
        cfg = self.cfg
        pool = (
            ThreadPoolExecutor(max_workers=cfg.workers)
            if cfg.workers > 1 and len(items) > 1
            else None
        )
        window = 2 * cfg.workers if pool else 1
        futs: deque = deque()
        nxt = 0
        try:
            for i in range(len(items)):
                if pool is not None:
                    while nxt < len(items) and len(futs) < window:
                        futs.append(pool.submit(encode, nxt))
                        nxt += 1
                    yield futs.popleft().result()
                else:
                    yield encode(i)
        finally:
            if pool is not None:
                pool.shutdown()

    def _manifest(self, step: int, fields: list, total_bytes: int, t0: float,
                  extra: dict | None = None) -> dict:
        """Manifest fields shared by both layouts (v3: `layout` comes in
        `extra`; `policy` records the configured Policy/PolicySet, and the
        legacy `mode`/`target` keys mirror the DEFAULT policy so pre-v3
        tooling keeps reading something sensible)."""
        default = self.cfg.policy_set.default
        # legacy `target` mirror: every target mode (fixed_psnr / ratio /
        # the §7.4 metric modes) reports its policy target via
        # TARGET_FIELD; fixed_accuracy reports the bound, raw None
        tgt_attr = TARGET_FIELD.get(default.mode)
        man = dict(
            step=step,
            version=3,
            policy=policy_set_spec(self.cfg.policy_set),
            mode=default.mode,
            target=(
                getattr(default, tgt_attr) if tgt_attr is not None
                else default.eb_rel if default.eb_rel is not None
                else default.eb_abs
            ),
            fields=fields,
            total_bytes=total_bytes,
            raw_bytes=int(
                sum(
                    int(np.prod(fl["shape"] or [1])) * np.dtype(fl["dtype"]).itemsize
                    for fl in fields
                )
            ),
            wall_time=time.time(),
            save_seconds=time.time() - t0,
            selection_bits={fl["name"]: fl["codec"] for fl in fields},
        )
        if extra:
            man.update(extra)
        if self.cache is not None:
            # persist the warm-save state (DESIGN.md §8.4): a restored run
            # reloads these entries and its first save revalidates them
            man["decision_cache"] = self.cache.to_manifest()
        return man

    def _publish(self, tmp: str, final: str) -> str:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(os.path.join(self.cfg.directory, ".LATEST_tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(
            os.path.join(self.cfg.directory, ".LATEST_tmp"),
            os.path.join(self.cfg.directory, "LATEST"),
        )
        self._prune()
        return final

    def _save_sharded(self, step: int, tree: Any, lossy: Callable[[str], bool]) -> str:
        """The segment-layout writer: shard-local decisions
        (`core/sharded.plan_tree`, one launch per policy group), per-shard
        segment encoding on the thread pool, per-host data files.
        Policy-raw and non-float leaves write exact original-dtype bytes,
        also per shard (codec ``none``) — nothing in this path gathers a
        tensor that the engine's layout analysis can keep sharded."""
        t0 = time.time()
        items, pol_of, plan_of = self._plan_sharded(tree, lossy)
        # only the write phase retries: `_plan_sharded` holds the device
        # collectives, which must not re-issue out of program order
        return self._retry_barrier_timeout(
            lambda: self._write_sharded(step, t0, items, pol_of, plan_of)
        )

    def _plan_sharded(self, tree: Any, lossy: Callable[[str], bool]):
        """Stage I/II for the segment writer: resolve policies and run the
        shard-local decision launches (`plan_tree`, one per policy group).
        Contains every COLLECTIVE of the save — psum reconciliation,
        moments fingerprints, fallback gathers — so in a multi-process job
        it must run on the main thread, in program order, on every host;
        `_write_sharded` (pure host IO + KV barriers) is then free to run
        on the async writer thread (DESIGN.md §6.2)."""
        from repro.core import sharded as shd

        items = _leaf_items_raw(tree)
        pol_of = self._resolve_policies(items, lossy)
        plan_of: dict[int, Any] = {}
        for pol, idxs in group_by_policy(pol_of).items():
            names = [items[i][0] for i in idxs] if self.cache is not None else None
            plans = shd.plan_tree(
                [items[i][1] for i in idxs], pol, cache=self.cache, names=names
            )
            plan_of.update(zip(idxs, plans))
        return items, pol_of, plan_of

    def _write_sharded(
        self, step: int, t0: float, items: list, pol_of: dict, plan_of: dict
    ) -> str:
        """Step 4 + publication, per host (DESIGN.md §6.2):

        1. every host encodes the segments it OWNS (`dist.owner_host` —
           replicated shards get exactly one writer, gather-fallback and
           host-array fields write on host 0) into `data.<host>.bin`;
        2. it records its rows in `segtable.<host>.json` (multi-host) and
           fsyncs, then writes the `commit.<host>` completion marker LAST;
        3. a bounded barrier fences the write phase — a dead/straggling
           host raises `BarrierTimeout` on every live host, the tmp dir is
           abandoned, nothing is ever promoted;
        4. host 0 merges the per-host segment tables into the manifest
           (recording `hosts` + per-host `completion` byte counts) and
           atomically promotes; a final bounded barrier makes every host
           return only after the step is visible (or raise if host 0
           died before publishing)."""
        from repro.core import sharded as shd
        from repro.runtime import sharding as rsh

        cfg = self.cfg
        host, nproc = dist.process_index(), dist.process_count()
        seq = self._save_seq
        self._save_seq += 1
        # multi-host tmp dirs must agree across processes (shared FS);
        # single-process keeps the pid suffix so concurrent managers in
        # tests cannot collide
        tag = "shared" if nproc > 1 else str(os.getpid())
        tmp = os.path.join(cfg.directory, f".tmp_step_{step:09d}_{tag}")
        final = os.path.join(cfg.directory, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        only = host if nproc > 1 else None

        def _encode(i: int):
            """-> (view_shape, sel_codec, eb, eb_sz, [(start, stop, codec, bytes)])

            `sel_codec` is the DECISION bit; the recorded field codec (the
            raw demote over every segment) is evaluated at manifest
            assembly, where all hosts' rows are visible."""
            name, leaf = items[i]
            plan = plan_of.get(i)
            if plan is not None:
                encoded = shd.encode_plan(
                    leaf, plan, host=only,
                    device_encode=self.cfg.device_encode,
                )
                segs = [(s.start, s.stop, s.codec, s.data) for s in encoded]
                sel = plan.selection
                return plan.view_shape, sel.codec, sel.eb_abs, sel.eb_sz, segs
            shape = tuple(int(s) for s in np.shape(leaf))
            if rsh.mesh_of(leaf) is not None and np.ndim(leaf) > 0:
                segs = [
                    (start, stop, "none",
                     rsh.shard_data(leaf, shd._local_device(devs)).tobytes())
                    for start, stop, devs in rsh.unique_shards(leaf)
                    if only is None or dist.owner_host(devs) == only
                ]
            elif only is not None and only != 0:
                segs = []  # host arrays are identical everywhere: host 0 writes
            else:
                arr = np.asarray(leaf)
                segs = [((0,) * arr.ndim, shape, "none", arr.tobytes())]
            return shape, "none", 0.0, 0.0, segs

        fields = []
        with open(os.path.join(tmp, f"data.{host}.bin"), "wb") as f:
            off = 0
            for i, ((name, leaf), (view_shape, sel_codec, eb, eb_sz, segs)) in enumerate(
                zip(items, self._encoded_in_order(items, _encode))
            ):
                seg_rows = []
                for start, stop, seg_codec, data in segs:
                    f.write(data)
                    seg_rows.append(
                        dict(
                            start=list(start), stop=list(stop),
                            codec=seg_codec, host=host,
                            offset=off, nbytes=len(data),
                        )
                    )
                    off += len(data)
                row = dict(
                    name=name, sel_codec=sel_codec,
                    shape=list(np.shape(leaf)), dtype=str(leaf.dtype),
                    view_shape=list(view_shape), eb=eb, eb_sz=eb_sz,
                    segments=seg_rows,
                    policy=_field_policy_spec(pol_of.get(i)),
                )
                plan = plan_of.get(i)
                q = _quality_record(plan.solution if plan is not None else None)
                if q is not None:
                    row["quality"] = q
                fields.append(row)
            if nproc > 1:
                f.flush()
                os.fsync(f.fileno())
        if nproc > 1:
            with open(os.path.join(tmp, f"segtable.{host}.json"), "w") as f:
                json.dump([fl["segments"] for fl in fields], f)
                f.flush()
                os.fsync(f.fileno())
        # the completion marker comes LAST: its existence certifies this
        # host's data + segment table are durably on disk (fsync only
        # matters multi-host — single-host's commit point stays the
        # atomic directory rename, and the sync would be pure latency)
        marker = os.path.join(tmp, f"commit.{host}")
        with open(marker + ".tmp", "w") as f:
            json.dump({"nbytes": off, "fields": len(fields)}, f)
            if nproc > 1:
                f.flush()
                os.fsync(f.fileno())
        os.replace(marker + ".tmp", marker)
        dist.barrier(f"ckpt:{step}:{seq}:written", cfg.barrier_timeout_s)
        if host == 0:
            self._assemble_and_publish(step, t0, tmp, final, fields, nproc)
        dist.barrier(f"ckpt:{step}:{seq}:published", cfg.barrier_timeout_s)
        return final

    def _assemble_and_publish(
        self, step: int, t0: float, tmp: str, final: str, fields: list, nproc: int
    ) -> None:
        """Host 0's manifest assembly: verify every host's completion
        marker, merge the per-host segment tables (decision metadata is
        replicated — psum reconciliation makes it identical on every host,
        so host 0's copies are authoritative), evaluate the per-field raw
        demote over the MERGED rows, and atomically promote."""
        from repro.core import sharded as shd

        completion: dict[str, int] = {}
        for h in range(nproc):
            marker = os.path.join(tmp, f"commit.{h}")
            if not os.path.exists(marker):  # pragma: no cover - barrier fences this
                raise IncompleteCheckpointError(
                    f"host {h} passed the write barrier without a completion "
                    f"marker ({marker})"
                )
            with open(marker) as f:
                completion[str(h)] = int(json.load(f)["nbytes"])
            if h > 0:
                with open(os.path.join(tmp, f"segtable.{h}.json")) as f:
                    for fl, rows in zip(fields, json.load(f)):
                        fl["segments"].extend(rows)
        total = 0
        for fl in fields:
            fl["segments"].sort(key=lambda r: (tuple(r["start"]), r["host"]))
            fl["nbytes"] = sum(r["nbytes"] for r in fl["segments"])
            fl["codec"] = shd.field_codec(
                fl.pop("sel_codec"), [r["codec"] for r in fl["segments"]]
            )
            total += fl["nbytes"]
        manifest = self._manifest(
            step, fields, total, t0,
            extra=dict(
                layout="segments", hosts=list(range(nproc)), completion=completion
            ),
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            if nproc > 1:
                f.flush()
                os.fsync(f.fileno())
        self._publish(tmp, final)

    def async_save(self, step: int, tree: Any, **kw) -> threading.Thread:
        """Snapshot now; serialize+write on a worker thread. Unsharded saves
        snapshot to host memory; sharded saves snapshot DEVICE-side
        (a sharding-preserving jitted copy) so a training step that donates
        or overwrites its buffers cannot race the background writer — the
        copy costs transient HBM, not a gather. Any exception the worker
        hits — encoder failures included — is re-raised by `wait()`.

        The sharded save is PIPELINED (DESIGN.md §6.2): stats→solve (every
        device collective, `_plan_sharded`) runs here on the calling
        thread before the method returns — multi-host jobs must issue
        collectives in program order on the main thread — while
        encode→drain→barrier→publish (`_write_sharded`: host IO plus
        KV-service fences, all thread-safe) overlaps with step N+1 on the
        worker. A transiently straggling host is requeued up to
        `cfg.save_retries` times under fresh barrier keys; a persistent
        one surfaces as `BarrierTimeout` from `wait()`, never as a hang.
        On success the returned thread carries
        ``thread.save_result = {"path", "retries"}``."""
        self.wait()
        self._exc = None
        lossy = kw.pop("lossy", None)
        if kw:
            raise TypeError(f"async_save: unexpected kwargs {sorted(kw)}")
        if lossy is None:
            lossy = self._default_lossy()
        if self.cfg.sharded:
            snap = jax.tree_util.tree_map(
                lambda x: dist.device_copy(x) if isinstance(x, jax.Array)
                else np.array(x),
                tree,
            )
            t0 = time.time()
            items, pol_of, plan_of = self._plan_sharded(snap, lossy)
            # gather-fallback fields fetch at encode time — a collective
            # when the array spans processes — so materialize them on the
            # calling thread; the worker then never touches devices it
            # cannot address
            items = [
                (name, dist.to_numpy(leaf))
                if i in plan_of and not plan_of[i].sharded
                and isinstance(leaf, jax.Array)
                else (name, leaf)
                for i, (name, leaf) in enumerate(items)
            ]
            run = lambda: self._retry_barrier_timeout(  # noqa: E731
                lambda: self._write_sharded(step, t0, items, pol_of, plan_of)
            )
        else:
            # flat snapshot: `dist.to_numpy` is itself a collective for
            # leaves this process cannot fully address — calling thread too
            host_tree = jax.tree_util.tree_map(dist.to_numpy, tree)
            run = lambda: self.save(step, host_tree, lossy=lossy)  # noqa: E731

        def _run() -> None:
            try:
                path = run()
                # surfaced on the returned thread object: the async
                # caller's view of where the save landed and how many
                # BarrierTimeout requeues it needed (§6.2)
                thread.save_result = dict(
                    path=path, retries=self.last_save_retries
                )
            except BaseException as e:  # noqa: BLE001 - surfaced by wait()
                self._exc = e

        thread = threading.Thread(target=_run, daemon=True)
        thread.save_result = None
        self._thread = thread
        thread.start()
        return thread

    def wait(self) -> None:
        """Join the async save, re-raising whatever it raised: a failed
        checkpoint must fail loudly, not leave a stale LATEST behind."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._exc = getattr(self, "_exc", None), None
        if exc is not None:
            raise exc

    def _prune(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.cfg.directory) if d.startswith("step_")
        )
        for d in steps[: -self.cfg.keep_n]:
            shutil.rmtree(os.path.join(self.cfg.directory, d), ignore_errors=True)
        if not steps:
            return
        # GC torn writes: a crash between staging and promotion leaves a
        # `.tmp_step_*` dir behind forever. Any tmp older than the newest
        # COMMITTED step can never be promoted (promotion is monotone), so
        # it is garbage; a tmp at/above the newest step may be a save in
        # flight on another process and is left alone.
        newest = int(steps[-1].split("_")[1])
        for d in os.listdir(self.cfg.directory):
            if not d.startswith(".tmp_step_"):
                continue
            try:
                tmp_step = int(d.split("_")[2])
            except (IndexError, ValueError):
                continue
            if tmp_step < newest:
                shutil.rmtree(
                    os.path.join(self.cfg.directory, d), ignore_errors=True
                )

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> int | None:
        p = os.path.join(self.cfg.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[-1])

    def _resolve_step_dir(self, step: int | None) -> tuple[int, str]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.cfg.directory}")
        return step, os.path.join(self.cfg.directory, f"step_{step:09d}")

    def _load_manifest(self, d: str) -> tuple[dict, str]:
        """Read + vet a step's manifest -> (manifest, layout).

        Layout dispatch: v3 records it explicitly; v2 is always the
        segment layout, v1 (no version key) always the flat one.
        Multi-host segment manifests — those carrying a `completion` key
        (DESIGN.md §6.2) — are validated against their per-host markers
        and data-file sizes: a checkpoint some host never finished must be
        REJECTED (`IncompleteCheckpointError`), not silently decoded
        short. Pre-completion manifests skip the check, so old
        checkpoints stay readable."""
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if self.cache is not None and "decision_cache" in manifest:
            # resume warm: the next save revalidates these entries against
            # fresh fingerprints before trusting any of them (DESIGN.md §8)
            self.cache.load_manifest(manifest["decision_cache"])
        version = int(manifest.get("version", 1))
        layout = manifest.get("layout", "segments" if version == 2 else "flat")
        if layout == "segments" and "completion" in manifest:
            for h in manifest.get("hosts", []):
                if not os.path.exists(os.path.join(d, f"commit.{h}")):
                    raise IncompleteCheckpointError(
                        f"{d}: completion marker commit.{h} is missing — "
                        f"host {h}'s write never finished; refusing to decode"
                    )
                want = int(manifest["completion"].get(str(h), 0))
                data = os.path.join(d, f"data.{h}.bin")
                have = os.path.getsize(data) if os.path.exists(data) else -1
                if have < want:
                    raise IncompleteCheckpointError(
                        f"{d}: data.{h}.bin holds {have} bytes but the "
                        f"manifest records {want} — truncated write"
                    )
        return manifest, layout

    def restore(self, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
        """Returns (step, {name: array}). Mesh-agnostic for BOTH layouts:
        the v1 single-file reader stays supported, and v2 per-shard
        segments reassemble into full tensors regardless of the saving
        mesh — the caller (or `restore_tree(shardings=...)`) reshards."""
        step, d = self._resolve_step_dir(step)
        manifest, layout = self._load_manifest(d)
        if layout == "segments":
            return step, self._restore_v2(d, manifest)
        out: dict[str, np.ndarray] = {}
        with open(os.path.join(d, "data.bin"), "rb") as f:
            blob = f.read()
        for fl in manifest["fields"]:
            seg = blob[fl["offset"] : fl["offset"] + fl["nbytes"]]
            shape, dtype = tuple(fl["shape"]), np.dtype(fl["dtype"])
            if fl["codec"] == "none":
                # exact original-dtype bytes (non-float / policy-raw rows)
                arr = codecs.writeable_frombuffer(seg, dtype).reshape(shape)
            elif fl["codec"] == "raw":
                # selection-era raw rows hold f32 working-dtype bytes
                arr = (
                    codecs.writeable_frombuffer(seg, np.float32)
                    .reshape(shape)
                    .astype(dtype)
                )
            else:
                cf = sel.CompressedField(fl["codec"], seg, shape, fl["dtype"])
                arr = sel.decompress(cf)
            out[fl["name"]] = arr
        return step, out

    def _restore_v2(
        self, d: str, manifest: dict,
        need: dict[str, tuple[int, int]] | None = None,
    ) -> dict[str, np.ndarray]:
        """Elastic v2/v3 reader: paste each field's segments into its folded
        view (decompressing lossy ones), then reshape to the original
        shape/dtype. Works for any saving mesh — segments carry their own
        view coordinates, and each row's `host` key addresses the per-host
        data file it lives in (range reads via `_HostBlobs`: a file is
        opened only if a needed segment lives there).

        `need` (the multi-host `restore_tree` path) maps field name -> the
        conservative flat element span this process must materialize:
        only segments overlapping the span are read and decoded, the rest
        of the view buffer stays unfilled — IO and decode work scale with
        the LOCAL shard, not the global tensor. Fields with unfilled
        regions are only safe to consume shard-wise (`dist.put_global`
        slices exactly the addressable region), which is why the filter is
        reserved for that caller. `last_restore_stats` records the
        locality actually achieved."""
        from repro.core import sharded as shd

        blobs = _HostBlobs(d)
        n_total = n_decoded = 0
        out: dict[str, np.ndarray] = {}
        try:
            for fl in manifest["fields"]:
                shape, dtype = tuple(fl["shape"]), np.dtype(fl["dtype"])
                vshape = tuple(fl["view_shape"])
                rows = fl["segments"]
                n_total += len(rows)
                span = need.get(fl["name"]) if need is not None else None
                if span is not None:
                    rows = [
                        sg for sg in rows
                        if _spans_overlap(
                            span, _flat_span(sg["start"], sg["stop"], vshape)
                        )
                    ]
                n_decoded += len(rows)
                if fl["codec"] == "none":
                    arr = np.empty(vshape, dtype)  # writeable by construction
                    for sg in rows:
                        data = blobs.read(sg["host"], sg["offset"], sg["nbytes"])
                        ext = tuple(b - a for a, b in zip(sg["start"], sg["stop"]))
                        arr[
                            tuple(slice(a, b) for a, b in zip(sg["start"], sg["stop"]))
                        ] = np.frombuffer(data, dtype).reshape(ext)
                    out[fl["name"]] = arr.reshape(shape)
                    continue
                segments = [
                    shd.Segment(
                        tuple(sg["start"]), tuple(sg["stop"]), sg["codec"],
                        blobs.read(sg["host"], sg["offset"], sg["nbytes"]),
                    )
                    for sg in rows
                ]
                view = shd.decode_segments(vshape, segments)
                out[fl["name"]] = view.reshape(shape).astype(dtype)
            self.last_restore_stats = dict(
                segments_total=n_total,
                segments_decoded=n_decoded,
                hosts_opened=blobs.hosts_opened,
            )
        finally:
            blobs.close()
        return out

    def restore_tree(
        self, template: Any, step: int | None = None, shardings: Any = None
    ) -> tuple[int, Any]:
        """Restore into the structure of `template` (names must match).

        `shardings` (optional pytree of `jax.sharding.Sharding` matching
        `template`) re-shards every leaf onto a TARGET mesh as it loads —
        the elastic-restore path: a checkpoint saved at ANY mesh and host
        count resumes under any other (DESIGN.md §6). Leaves are placed
        with `dist.put_global`, so a target sharding spanning processes is
        built shard-by-shard — nothing is ever sent to a device this
        process cannot address. In a multi-process job, segment-layout
        restores additionally read + decode only the segments this
        process's addressable shards intersect (`last_restore_stats`
        reports the locality)."""
        step, d = self._resolve_step_dir(step)
        manifest, layout = self._load_manifest(d)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        names = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves
        ]
        shard_list = (
            jax.tree_util.tree_structure(template).flatten_up_to(shardings)
            if shardings is not None
            else None
        )
        if layout == "segments":
            need = None
            if shard_list is not None and dist.is_multihost():
                need = {
                    name: _need_span(s, tuple(np.shape(leaf)))
                    for name, s, (_, leaf) in zip(names, shard_list, leaves)
                }
            flat = self._restore_v2(d, manifest, need=need)
        else:
            _, flat = self.restore(step)
        vals = []
        for name, (path, leaf) in zip(names, leaves):
            arr = flat[name]
            vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        if shard_list is not None:
            vals = [dist.put_global(v, s) for v, s in zip(vals, shard_list)]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), vals
        )
        return step, tree
