"""Fault-tolerant checkpointing with the paper's per-field codec selection.

Layout (mesh-agnostic — tensors are saved unsharded, so a restarted job may
reload under ANY device count / mesh: elastic scaling):

  <dir>/step_000123/
    manifest.json   # step, field table (name, codec s_i, shape, dtype,
                    # offset, nbytes, eb), config hash, wall time
    data.bin        # concatenated per-field streams (SZ/ZFP/raw)
  <dir>/LATEST      # atomic pointer (written last)

Writes are atomic (tmp dir + rename); `keep_n` old checkpoints are pruned;
`async_save` runs serialization+IO off the training thread (the in-situ
model of the paper: compress while the next step computes).

Codec selection is batched: ALL lossy fields go through one
`select_many` estimator launch (one padded block batch, one device
round-trip per checkpoint), then per-field SZ/ZFP byte encoding runs on a
`workers`-wide thread pool so encoding of field i overlaps with encoding
of field j and with the sequential writer draining results in order.

Weights default to lossy (value-range-relative eb, Algorithm 1 per tensor);
optimizer state defaults to raw (Adam moments are cheap to compress but
sensitive near zero) — both policies are per-call overridable.

Quality targets (DESIGN.md §7): `CheckpointConfig.mode` switches the lossy
policy from the bound-centric default (``fixed_accuracy`` + `eb_rel`) to
``fixed_psnr`` / ``fixed_ratio``, where the quality-target controller
solves each tensor's error bound from `target_psnr` (dB) or `target_ratio`
(x vs 32-bit raw) — e.g. "every checkpoint is 8x smaller" as a storage
contract. The manifest records the mode and target next to the per-field
bounds, so restore-side tooling can audit what was promised.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import jax
import numpy as np

from repro.core import controller
from repro.core import selector as sel


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep_n: int = 3
    eb_rel: float = 1e-4
    compress: bool = True
    r_sp: float = 0.05
    workers: int = 4  # thread-pool width for per-field byte encoding (0 = serial)
    # quality-target mode (DESIGN.md §7): "fixed_accuracy" uses eb_rel;
    # "fixed_psnr" / "fixed_ratio" solve per-tensor bounds from the target
    mode: str = "fixed_accuracy"
    target_psnr: float | None = None
    target_ratio: float | None = None


def _leaf_items(tree: Any) -> list[tuple[str, np.ndarray]]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, lossy: Callable[[str], bool] | None = None) -> str:
        """Synchronous atomic save. `lossy(name)` selects per-field policy
        (default: float leaves not under 'opt/' are lossy-compressed)."""
        if lossy is None:
            lossy = lambda name: not name.startswith("opt/")
        cfg = self.cfg
        tmp = os.path.join(cfg.directory, f".tmp_step_{step:09d}_{os.getpid()}")
        final = os.path.join(cfg.directory, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        fields = []
        t0 = time.time()
        items = _leaf_items(tree)
        lossy_idx = [
            i
            for i, (name, arr) in enumerate(items)
            if cfg.compress
            and lossy(name)
            and np.issubdtype(arr.dtype, np.floating)
            and arr.size >= 64
        ]
        # Steps 1-3 for every lossy field in ONE batched estimator launch
        # per round (the solvers cast to f32 one field at a time and keep
        # only the sampled blocks, so no full-tree f32 copy materializes)
        lossy_fields = [items[i][1] for i in lossy_idx]
        if cfg.mode == "fixed_accuracy":
            sels = sel.select_many(lossy_fields, eb_rel=cfg.eb_rel, r_sp=cfg.r_sp)
        else:
            sols = controller.solve_many(
                lossy_fields, cfg.mode,
                target_psnr=cfg.target_psnr, target_ratio=cfg.target_ratio,
                r_sp=cfg.r_sp,
            )
            sels = [s.selection for s in sols]
        sel_of = dict(zip(lossy_idx, sels))

        def _encode(i: int) -> tuple[bytes, str, float]:
            name, arr = items[i]
            s = sel_of.get(i)
            if s is None:
                return arr.tobytes(), "none", 0.0
            cf = sel.encode_with_selection(arr, s)  # casts to f32 internally
            return cf.data, cf.codec, s.eb_abs

        pool = (
            ThreadPoolExecutor(max_workers=cfg.workers)
            if cfg.workers > 1 and len(items) > 1
            else None
        )
        # the writer drains results in field order while the pool encodes
        # ahead of the write cursor — but only a bounded window ahead, so
        # encoded-but-unwritten byte streams can't pile up past RAM
        window = 2 * cfg.workers if pool else 1
        futs: deque = deque()
        nxt = 0
        try:
            with open(os.path.join(tmp, "data.bin"), "wb") as f:
                off = 0
                for i, (name, arr) in enumerate(items):
                    if pool is not None:
                        while nxt < len(items) and len(futs) < window:
                            futs.append(pool.submit(_encode, nxt))
                            nxt += 1
                        data, codec, eb = futs.popleft().result()
                    else:
                        data, codec, eb = _encode(i)
                    f.write(data)
                    fields.append(
                        dict(
                            name=name, codec=codec, shape=list(arr.shape),
                            dtype=str(arr.dtype), offset=off, nbytes=len(data), eb=eb,
                        )
                    )
                    off += len(data)
        finally:
            if pool is not None:
                pool.shutdown()
        manifest = dict(
            step=step,
            mode=cfg.mode,
            target=(
                cfg.target_psnr if cfg.mode == "fixed_psnr"
                else cfg.target_ratio if cfg.mode == "fixed_ratio"
                else cfg.eb_rel
            ),
            fields=fields,
            total_bytes=off,
            raw_bytes=int(sum(int(np.prod(f["shape"] or [1])) * np.dtype(f["dtype"]).itemsize for f in fields)),
            wall_time=time.time(),
            save_seconds=time.time() - t0,
            selection_bits={f["name"]: f["codec"] for f in fields},
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(os.path.join(cfg.directory, ".LATEST_tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(
            os.path.join(cfg.directory, ".LATEST_tmp"),
            os.path.join(cfg.directory, "LATEST"),
        )
        self._prune()
        return final

    def async_save(self, step: int, tree: Any, **kw) -> threading.Thread:
        """Snapshot to host memory now; serialize+write on a worker thread."""
        host_tree = jax.tree_util.tree_map(lambda x: np.array(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree), kwargs=kw, daemon=True
        )
        self._thread.start()
        return self._thread

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.cfg.directory) if d.startswith("step_")
        )
        for d in steps[: -self.cfg.keep_n]:
            shutil.rmtree(os.path.join(self.cfg.directory, d), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> int | None:
        p = os.path.join(self.cfg.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[-1])

    def restore(self, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
        """Returns (step, {name: array}). Mesh-agnostic: caller reshards."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out: dict[str, np.ndarray] = {}
        with open(os.path.join(d, "data.bin"), "rb") as f:
            blob = f.read()
        for fl in manifest["fields"]:
            seg = blob[fl["offset"] : fl["offset"] + fl["nbytes"]]
            shape, dtype = tuple(fl["shape"]), np.dtype(fl["dtype"])
            if fl["codec"] == "none":
                arr = np.frombuffer(seg, dtype=dtype).reshape(shape)
            else:
                cf = sel.CompressedField(fl["codec"], seg, shape, fl["dtype"])
                arr = sel.decompress(cf)
            out[fl["name"]] = arr
        return step, out

    def restore_tree(self, template: Any, step: int | None = None) -> tuple[int, Any]:
        """Restore into the structure of `template` (names must match)."""
        step, flat = self.restore(step)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        vals = []
        for path, leaf in leaves:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = flat[name]
            vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return step, jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), vals
        )
