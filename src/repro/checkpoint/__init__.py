from .manager import CheckpointConfig, CheckpointManager, IncompleteCheckpointError

__all__ = ["CheckpointConfig", "CheckpointManager", "IncompleteCheckpointError"]
