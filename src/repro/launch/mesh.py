"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod (256 chips); (2,16,16)
    ('pod','data','model') for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for smoke/integration tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
