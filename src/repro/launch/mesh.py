"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod (256 chips); (2,16,16)
    ('pod','data','model') for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for smoke/integration tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_emulated_mesh(shape=(2, 4), axes=("data", "model")):
    """Mesh over emulated CPU devices (DESIGN.md §6 test harness).

    Requires `XLA_FLAGS=--xla_force_host_platform_device_count=N` to be in
    the environment BEFORE jax initializes — tests get this from
    `tests/conftest.py`'s early-import hook; scripts (benchmarks, the
    sharded-checkpoint dryrun) set it at the top of their own module,
    before importing jax."""
    n = int(jax.device_count())
    need = 1
    for s in shape:
        need *= int(s)
    if n < need:
        raise RuntimeError(
            f"make_emulated_mesh{tuple(shape)} needs {need} devices, have {n}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
            "initializes"
        )
    return jax.make_mesh(shape, axes)
