"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod (256 chips); (2,16,16)
    ('pod','data','model') for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh for smoke/integration tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_emulated_mesh(shape=(2, 4), axes=("data", "model")):
    """Mesh over emulated CPU devices (DESIGN.md §6 test harness).

    Requires `XLA_FLAGS=--xla_force_host_platform_device_count=N` to be in
    the environment BEFORE jax initializes — tests get this from
    `tests/conftest.py`'s early-import hook; scripts (benchmarks, the
    sharded-checkpoint dryrun) set it at the top of their own module,
    before importing jax. In a multi-PROCESS job
    (`repro.runtime.dist.initialize` — workers spawned by
    `launch/mhrun.py`), `jax.device_count()` is already GLOBAL, so the
    same call builds the same mesh over all hosts' devices: 8 global
    devices give an identical (2, 4) layout at 1, 2, or 4 processes,
    which is what makes cross-host-count decision parity testable."""
    n = int(jax.device_count())
    need = 1
    for s in shape:
        need *= int(s)
    if n < need:
        raise RuntimeError(
            f"make_emulated_mesh{tuple(shape)} needs {need} devices, have {n}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
            "initializes (per process under launch/mhrun.py)"
        )
    return jax.make_mesh(shape, axes)


def describe_mesh(mesh) -> dict:
    """Loggable mesh summary including the per-process device split —
    the multi-host dryrun and test workers record it so a mis-assembled
    job (wrong device counts per host) is visible in the artifacts."""
    per_process: dict[int, int] = {}
    for d in mesh.devices.flat:
        p = int(getattr(d, "process_index", 0))
        per_process[p] = per_process.get(p, 0) + 1
    return dict(
        shape=dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        devices=int(mesh.devices.size),
        process_index=int(jax.process_index()),
        process_count=int(jax.process_count()),
        devices_per_process={str(k): v for k, v in sorted(per_process.items())},
    )
