"""Sharded-checkpoint dryrun scenario (DESIGN.md §6).

Emulates the paper's parallel setting on CPU: builds an 8-device
('data', 'model') mesh, synthesizes a train-state-like pytree of sharded
fields (FSDP-style weight sharding + replicated small tensors + raw
optimizer state), then exercises the full shard-local pipeline:

  1. `CheckpointManager(sharded=True).save` — decisions from per-shard
     statistics (no gather), per-shard segment encoding, segment manifest;
  2. elastic restore under a DIFFERENT mesh shape via
     `restore_tree(shardings=...)`;
  3. a parity check against the unsharded writer.

Run it to sanity-check a jax upgrade or a new mesh layout end to end:

    PYTHONPATH=src python -m repro.launch.shardckpt [--fields 12] [--dim 512]

`--processes N` (N in {2, 4}) runs the MULTI-HOST dryrun instead
(DESIGN.md §6.2): N worker processes join one distributed CPU job via
`launch/mhrun.py` (8 global emulated devices split across them), save
one sharded checkpoint cooperatively — per-host `data.<host>.bin` +
completion markers, host-0 manifest assembly — then elastically restore
it with per-host segment locality, and the driver prints each host's
byte counts and locality stats:

    PYTHONPATH=src python -m repro.launch.shardckpt --processes 2
"""

import os
import sys

if "--mh-worker" not in sys.argv:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import argparse
import json
import tempfile
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import Policy
from repro.launch.mesh import describe_mesh, make_emulated_mesh


def synth_state(mesh, n_fields: int, dim: int, seed: int = 0):
    """A train-state-like pytree: weights sharded FSDP-style over 'data' /
    TP-style over 'model', a replicated norm table, raw optimizer moments.
    Placement rides `dist.put_global`, so the same synthesis works when
    `mesh` spans processes (every worker seeds identically and contributes
    only its addressable shards) — the multi-host dryrun and test workers
    build their state with exactly this function."""
    from repro.runtime import dist

    rng = np.random.default_rng(seed)
    tree: dict = {"params": {}, "opt": {}}
    shardings: dict = {"params": {}, "opt": {}}
    for i in range(n_fields):
        name = f"layer{i:02d}/w"
        x = np.cumsum(rng.standard_normal((dim, dim)), axis=0).astype(np.float32)
        spec = P("data", None) if i % 2 == 0 else P(None, "model")
        tree["params"][name] = dist.put_global(x, NamedSharding(mesh, spec))
        shardings["params"][name] = NamedSharding(mesh, spec)
        m = (0.01 * rng.standard_normal((dim, dim))).astype(np.float32)
        tree["opt"][name] = dist.put_global(m, NamedSharding(mesh, spec))
        shardings["opt"][name] = NamedSharding(mesh, spec)
    norm = np.linspace(0.9, 1.1, dim, dtype=np.float32)
    tree["params"]["norm"] = dist.put_global(norm, NamedSharding(mesh, P()))
    shardings["params"]["norm"] = NamedSharding(mesh, P())
    # int32: jax without x64 canonicalizes wider ints on placement, which
    # would make the restored-through-device value differ from the saved one
    tree["step"] = np.array(1234, np.int32)
    shardings["step"] = NamedSharding(mesh, P())
    return tree, shardings


def _mh_dryrun(spec: dict, pid: int) -> dict:
    """Worker body for `--processes N`: cooperative sharded save + local
    elastic restore on the shared 8-device (2, 4) mesh."""
    a = spec["args"]
    mesh = make_emulated_mesh((2, jax.device_count() // 2), ("data", "model"))
    tree, shardings = synth_state(mesh, int(a["fields"]), int(a["dim"]))
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=a["directory"],
            policy=Policy.fixed_accuracy(eb_rel=float(a["eb_rel"])),
            sharded=True,
            barrier_timeout_s=60.0,
        )
    )
    t0 = time.perf_counter()
    path = mgr.save(1, tree)
    t_save = time.perf_counter() - t0
    own_bytes = os.path.getsize(os.path.join(path, f"data.{pid}.bin"))
    t0 = time.perf_counter()
    _, restored = mgr.restore_tree(tree, shardings=shardings)
    t_restore = time.perf_counter() - t0
    w0 = np.asarray(
        jax.jit(lambda x: x, out_shardings=NamedSharding(mesh, P()))(
            restored["params"]["layer00/w"]
        )
    )
    exact = bool(
        np.allclose(
            w0,
            np.asarray(
                jax.jit(lambda x: x, out_shardings=NamedSharding(mesh, P()))(
                    tree["params"]["layer00/w"]
                )
            ),
            atol=float(a["eb_rel"]) * float(np.abs(w0).max() + 1.0),
        )
    )
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    return dict(
        mesh=describe_mesh(mesh),
        path=path,
        save_seconds=t_save,
        restore_seconds=t_restore,
        own_bytes=int(own_bytes),
        total_bytes=int(man["total_bytes"]),
        restore_stats=mgr.last_restore_stats,
        within_bound=exact,
    )


def _run_multiprocess(args) -> None:
    from repro.launch import mhrun

    with tempfile.TemporaryDirectory() as wd:
        results = mhrun.run(
            [sys.executable, "-m", "repro.launch.shardckpt", "--mh-worker"],
            args.processes,
            scenario="dryrun",
            args=dict(
                fields=args.fields, dim=args.dim, eb_rel=args.eb_rel,
                directory=os.path.join(wd, "ckpt"),
            ),
            local_devices=8 // args.processes,
            timeout_s=600.0,
            workdir=os.path.join(wd, "mhrun"),
        )
        payloads = mhrun.require_success(results)
        for p in payloads:
            mesh = p["mesh"]
            print(
                f"host {mesh['process_index']}/{mesh['process_count']}: "
                f"wrote {p['own_bytes'] / 1e6:.2f} MB of "
                f"{p['total_bytes'] / 1e6:.2f} MB total; save {p['save_seconds']:.2f}s, "
                f"restore {p['restore_seconds']:.2f}s decoding "
                f"{p['restore_stats']['segments_decoded']}/"
                f"{p['restore_stats']['segments_total']} segments "
                f"from hosts {p['restore_stats']['hosts_opened']} "
                f"(within_bound={p['within_bound']})"
            )
        if not all(p["within_bound"] for p in payloads):
            raise SystemExit("MULTI-HOST DRYRUN FAILURE: restored values out of bound")
    print(f"multi-host dryrun OK ({args.processes} processes)")


def main() -> None:
    if "--mh-worker" in sys.argv:
        from repro.launch import mhrun

        raise SystemExit(mhrun.worker_main(sys.argv[-1], {"dryrun": _mh_dryrun}))
    ap = argparse.ArgumentParser()
    ap.add_argument("--fields", type=int, default=12)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--eb-rel", type=float, default=1e-3)
    ap.add_argument(
        "--processes", type=int, default=1, choices=(1, 2, 4),
        help="run the multi-host dryrun with N distributed worker processes",
    )
    args = ap.parse_args()
    if args.processes > 1:
        _run_multiprocess(args)
        return

    mesh = make_emulated_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} emulated devices)")
    tree, _ = synth_state(mesh, args.fields, args.dim)

    with tempfile.TemporaryDirectory() as d_sh, tempfile.TemporaryDirectory() as d_un:
        msh = CheckpointManager(
            CheckpointConfig(
                directory=d_sh,
                policy=Policy.fixed_accuracy(eb_rel=args.eb_rel),
                sharded=True,
            )
        )
        t0 = time.perf_counter()
        path = msh.save(1, tree)
        t_sh = time.perf_counter() - t0
        with open(os.path.join(path, "manifest.json")) as f:
            man = json.load(f)
        n_segs = sum(len(fl["segments"]) for fl in man["fields"])
        print(f"sharded save: {t_sh:.2f}s  {man['total_bytes']/1e6:.2f} MB "
              f"({man['raw_bytes']/max(man['total_bytes'],1):.2f}x) "
              f"{len(man['fields'])} fields / {n_segs} segments")

        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        mun = CheckpointManager(
            CheckpointConfig(directory=d_un, policy=Policy.fixed_accuracy(eb_rel=args.eb_rel))
        )
        t0 = time.perf_counter()
        mun.save(1, host_tree)
        t_un = time.perf_counter() - t0
        print(f"gather-then-compress save: {t_un:.2f}s "
              f"(shard-local is {t_un / max(t_sh, 1e-9):.2f}x)")

        # elastic restore: consume the 2x4 checkpoint under a 4x2 mesh
        mesh2 = make_emulated_mesh((4, 2), ("data", "model"))
        _, shardings2 = synth_state(mesh2, args.fields, args.dim)
        t0 = time.perf_counter()
        _, restored = msh.restore_tree(tree, shardings=shardings2)
        t_rs = time.perf_counter() - t0
        w0 = "layer00/w"
        ok_spec = restored["params"][w0].sharding.mesh.devices.shape == (4, 2)
        print(f"elastic restore onto 4x2 mesh: {t_rs:.2f}s resharded={ok_spec}")

        # decision + value parity against the unsharded writer
        _, f_sh = msh.restore()
        _, f_un = mun.restore()
        mism = [k for k in f_un if not np.array_equal(f_un[k], f_sh[k])]
        bits_sh = man["selection_bits"]
        with open(os.path.join(d_un, f"step_{1:09d}", "manifest.json")) as f:
            bits_un = json.load(f)["selection_bits"]
        flips = [k for k in bits_un if bits_un[k] != bits_sh.get(k)]
        print(f"parity: {len(mism)} value mismatches, {len(flips)} decision flips "
              f"across {len(f_un)} fields")
        if mism or flips:
            raise SystemExit(f"PARITY FAILURE: {mism[:3]} {flips[:3]}")
    print("dryrun OK")


if __name__ == "__main__":
    main()
