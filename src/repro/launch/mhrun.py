"""Multi-process job runner for the emulated multi-host harness.

The §6.2 test story needs REAL `jax.process_count() > 1` jobs, which a
single pytest process cannot host (one jax runtime per process). This
module spawns N python workers, each joining one distributed CPU job via
`repro.runtime.dist.initialize` (gloo collectives + per-process emulated
devices), runs a named scenario in every worker, and collects per-host
results/exit codes — the machinery behind `tests/multihost/`, the
`launch/shardckpt.py --processes` dryrun, and the bench-gate parity
smoke.

Protocol (shared filesystem, no sockets beyond jax's own coordinator):

* the runner picks a free coordinator port, writes one `spec.json`
  (coordinator address, process count, per-process device count,
  scenario name + args, output dir), and launches `cmd + [spec.json]`
  once per process with `MHRUN_PROCESS_ID=<pid>` in the environment
  (XLA_FLAGS is scrubbed so the parent's emulated-device setting cannot
  leak into workers — `worker_init` re-derives it from the spec);
* each worker calls `worker_init(spec_path)` FIRST (before any jax
  device use), runs its scenario, and reports through
  `write_result(...)` -> `result.<pid>.json`; uncaught scenario
  exceptions become `{"error": ...}` results with a nonzero exit;
* the runner enforces a wall-clock deadline (straggler/fault tests rely
  on workers dying or timing out) and returns one `HostResult` per
  process: exit code, captured output, parsed result payload (None when
  the worker died before reporting — exactly what the fault-injection
  assertions look for).
"""

from __future__ import annotations

import dataclasses
import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Sequence


def free_port() -> int:
    """An OS-assigned free TCP port for the jax coordinator."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return int(s.getsockname()[1])


@dataclasses.dataclass
class HostResult:
    """One worker's outcome: exit code, captured stdout+stderr, and the
    payload it reported (None if it died before `write_result`)."""

    process_id: int
    returncode: int
    output: str
    result: dict | None

    @property
    def ok(self) -> bool:
        return self.returncode == 0 and self.result is not None and (
            "error" not in self.result
        )


def run(
    cmd: Sequence[str],
    num_processes: int,
    *,
    scenario: str,
    args: dict | None = None,
    local_devices: int = 2,
    timeout_s: float = 600.0,
    workdir: str | None = None,
    extra_env: dict[str, str] | None = None,
) -> list[HostResult]:
    """Launch `num_processes` workers of `cmd` as one distributed job.

    `cmd` is the worker program (e.g. ``[sys.executable, worker_py]``);
    the spec path is appended as its last argument. Workers that outlive
    `timeout_s` are killed (-9) — a hung barrier in a worker must fail
    the TEST, not the suite."""
    wd = workdir or tempfile.mkdtemp(prefix="mhrun_")
    os.makedirs(wd, exist_ok=True)
    spec = dict(
        coordinator=f"127.0.0.1:{free_port()}",
        num_processes=int(num_processes),
        local_devices=int(local_devices),
        scenario=scenario,
        args=dict(args or {}),
        outdir=wd,
    )
    spec_path = os.path.join(wd, "spec.json")
    with open(spec_path, "w") as f:
        json.dump(spec, f, indent=1)

    procs: list[tuple[int, subprocess.Popen, Any]] = []
    for pid in range(num_processes):
        env = os.environ.copy()
        # the parent's emulated-device flags must not leak: each worker
        # derives its own --xla_force_host_platform_device_count from the
        # spec (worker_init), BEFORE its jax backend initializes
        env.pop("XLA_FLAGS", None)
        env["MHRUN_PROCESS_ID"] = str(pid)
        if extra_env:
            env.update(extra_env)
        log = open(os.path.join(wd, f"out.{pid}.log"), "w+")
        p = subprocess.Popen(
            list(cmd) + [spec_path], env=env, stdout=log, stderr=subprocess.STDOUT
        )
        procs.append((pid, p, log))

    deadline = time.monotonic() + timeout_s
    for pid, p, _ in procs:
        left = deadline - time.monotonic()
        try:
            p.wait(timeout=max(left, 0.1))
        except subprocess.TimeoutExpired:
            pass
    for pid, p, _ in procs:
        if p.poll() is None:
            p.kill()
            p.wait()

    results: list[HostResult] = []
    for pid, p, log in procs:
        log.seek(0)
        output = log.read()
        log.close()
        payload = None
        rpath = os.path.join(wd, f"result.{pid}.json")
        if os.path.exists(rpath):
            try:
                with open(rpath) as f:
                    payload = json.load(f)
            except (OSError, ValueError):
                payload = None
        results.append(HostResult(pid, int(p.returncode), output, payload))
    return results


def require_success(results: list[HostResult]) -> list[dict]:
    """All-hosts-ok assertion helper: returns the per-host payloads (by
    process id) or raises with every failed host's captured output."""
    bad = [r for r in results if not r.ok]
    if bad:
        msgs = []
        for r in bad:
            err = (r.result or {}).get("error", "<no result file>")
            msgs.append(
                f"--- host {r.process_id} exit={r.returncode} error={err}\n"
                f"{r.output[-4000:]}"
            )
        raise AssertionError(
            f"{len(bad)}/{len(results)} hosts failed:\n" + "\n".join(msgs)
        )
    return [r.result for r in sorted(results, key=lambda r: r.process_id)]


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


def worker_init(spec_path: str) -> tuple[dict, int]:
    """Join the distributed job described by `spec_path` -> (spec, pid).

    Must run before anything touches jax devices: it routes through
    `repro.runtime.dist.initialize`, which forces the per-process
    emulated device count into XLA_FLAGS and switches CPU collectives to
    gloo before `jax.distributed.initialize`."""
    with open(spec_path) as f:
        spec = json.load(f)
    pid = int(os.environ["MHRUN_PROCESS_ID"])
    from repro.runtime import dist

    dist.initialize(
        spec["coordinator"],
        int(spec["num_processes"]),
        pid,
        local_device_count=int(spec["local_devices"]),
    )
    return spec, pid


def write_result(spec: dict, pid: int, payload: dict) -> None:
    """Report this worker's payload atomically (rename) so the runner
    never reads a half-written JSON."""
    path = os.path.join(spec["outdir"], f"result.{pid}.json")
    with open(path + ".tmp", "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(path + ".tmp", path)


def worker_main(spec_path: str, scenarios: dict[str, Any]) -> int:
    """Generic worker entrypoint: init, dispatch `spec['scenario']` from
    `scenarios` (a name -> fn(spec, pid) registry), report, exit code.
    Exceptions are reported as `{"error": repr}` with exit 1 so the
    runner can distinguish 'scenario failed' from 'process vanished'."""
    spec, pid = worker_init(spec_path)
    try:
        fn = scenarios[spec["scenario"]]
        payload = fn(spec, pid) or {}
    except BaseException as e:  # noqa: BLE001 - reported to the runner
        import traceback

        traceback.print_exc()
        write_result(spec, pid, {"error": f"{type(e).__name__}: {e}"})
        return 1
    write_result(spec, pid, payload)
    return 0


__all__ = [
    "HostResult",
    "free_port",
    "require_success",
    "run",
    "worker_init",
    "worker_main",
    "write_result",
]
