"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 200 \
      --smoke --ckpt-dir /tmp/ckpt --compress-ckpt --compress-grads

Features: deterministic data pipeline, AdamW, activation-checkpointed
scan-over-layers, lossy-compressed checkpoints with Algorithm-1 selection,
auto-resume from the latest checkpoint (fault tolerance), error-feedback
gradient compression, async checkpoint writes.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.core import Policy, PolicySet
from repro.data import DataConfig, synthetic_batch
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.optim import AdamWConfig, GradCompressConfig
from repro.runtime import sharding
from repro.runtime.steps import init_opt_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--d-model", type=int, default=None, help="override width")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-eb", type=float, default=1e-4)
    ap.add_argument(
        "--ckpt-opt-ratio", type=float, default=None,
        help="also lossy-compress optimizer state, at this fixed ratio "
        "(a PolicySet: weights keep the eb bound, opt/* gets the budget)",
    )
    ap.add_argument("--compress-ckpt", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, head_dim=args.d_model // cfg.n_heads
        )
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)
    model = build_model(cfg)

    n_dev = len(jax.devices())
    mesh = make_local_mesh() if n_dev == 1 else make_production_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5))
    gc_cfg = GradCompressConfig() if args.compress_grads else None
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    params = rnn.init_tree(model.desc(), jax.random.key(0))
    opt_state = init_opt_state(params, gc_cfg)
    start_step = 0

    mgr = None
    if args.ckpt_dir:
        ckpt_policy: Policy | PolicySet = Policy.fixed_accuracy(eb_rel=args.ckpt_eb)
        if args.ckpt_opt_ratio:
            ckpt_policy = PolicySet(
                default=ckpt_policy,
                rules=[("opt/*", Policy.fixed_ratio(args.ckpt_opt_ratio))],
            )
        mgr = CheckpointManager(
            CheckpointConfig(
                args.ckpt_dir, policy=ckpt_policy, compress=args.compress_ckpt
            )
        )
        if args.resume and mgr.latest_step() is not None:
            tmpl = {"params": params, "opt": opt_state["adam"]}
            start_step, restored = mgr.restore_tree(tmpl)
            params = restored["params"]
            opt_state["adam"] = restored["opt"]
            print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    step_fn = make_train_step(model, opt_cfg, gc_cfg)
    rules = sharding.TRAIN_RULES
    with sharding.activate(mesh, rules):
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = {
                k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, step).items()
            }
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                extra = ""
                if "wire_bits_per_value" in metrics:
                    extra = f" wire_bits={float(metrics['wire_bits_per_value']):.2f}"
                print(
                    f"step {step:5d} loss {losses[-1]:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f}{extra}",
                    flush=True,
                )
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.async_save(step + 1, {"params": params, "opt": opt_state["adam"]})
        if mgr is not None:
            mgr.wait()
            mgr.save(args.steps, {"params": params, "opt": opt_state["adam"]})
    dt = time.time() - t0
    print(f"[done] {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return {"losses": losses, "seconds": dt, "params": params}


if __name__ == "__main__":
    main()
