"""Batched serving driver: prefill + greedy decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.runtime import sharding
from repro.runtime.steps import make_decode_step, make_prefill_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    mesh = make_local_mesh() if len(jax.devices()) == 1 else make_production_mesh()

    rng = np.random.default_rng(0)
    b = args.batch
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (b, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.gen
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.float32
        )

    prefill = make_prefill_step(model)
    decode = make_decode_step(model, sample=args.sample)
    with sharding.activate(mesh, sharding.SERVE_RULES):
        cache = model.init_cache(b, max_len)
        t0 = time.time()
        logits, cache = jax.jit(prefill)(params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t_prefill = time.time() - t0
        jit_decode = jax.jit(decode)
        toks = [nxt]
        key = jax.random.key(1)
        t0 = time.time()
        for i in range(args.gen - 1):
            key, sub = jax.random.split(key)
            if args.sample:
                nxt, cache = jit_decode(params, nxt, cache, sub)
            else:
                nxt, cache = jit_decode(params, nxt, cache)
            toks.append(nxt)
        jax.block_until_ready(nxt)
        t_decode = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    tput = b * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.prompt_len} toks x{b}: {t_prefill:.2f}s; "
          f"decode {args.gen - 1} steps: {t_decode:.2f}s ({tput:.1f} tok/s)")
    print("[serve] sample output ids:", np.asarray(out[0, :16]))
    return {"tokens": np.asarray(out), "prefill_s": t_prefill, "decode_s": t_decode}


if __name__ == "__main__":
    main()
