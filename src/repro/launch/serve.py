"""Batched serving driver: prefill + greedy decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 64 --gen 32

Continuous mode (`--continuous`) runs the compression-aware serving tier
(DESIGN.md §9) instead: a `ContinuousBatcher` with the paged KV pool under
synthetic Poisson arrivals — long-context requests resolve to a
`Policy.fixed_ratio` byte budget for compress-on-evict, short ones stay raw.

  PYTHONPATH=src python -m repro.launch.serve --smoke --continuous
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.decision_cache import DecisionCache
from repro.core.policy import serving_policies
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.runtime import sharding
from repro.runtime.batcher import ContinuousBatcher, Request
from repro.runtime.steps import make_decode_step, make_prefill_step


def run_continuous(args, cfg, model, params) -> dict:
    """Continuous serving under Poisson arrivals (arrival clock = decode
    steps). Prompt lengths mix short and long around `--long-threshold`
    so both PolicySet arms (raw / fixed_ratio) are exercised."""
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen
    decisions = DecisionCache()
    b = ContinuousBatcher(
        model, params, slots=args.slots, max_len=max_len, eos_id=-1,
        page_tokens=args.page_tokens, arena_pages=args.arena_pages,
        policies=serving_policies(args.target_ratio),
        long_threshold=args.long_threshold, decisions=decisions,
    )
    if not b.paged:
        raise SystemExit(f"--continuous needs the paged KV pool; {args.arch} "
                         "does not support it (MLA / quantized KV)")
    short_len = max(4, args.prompt_len // 4)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(
                1, cfg.vocab, args.prompt_len if i % 2 else short_len
            ).astype(np.int32),
            max_new=args.gen,
        )
        for i in range(args.requests)
    ]
    arrive = np.cumsum(rng.exponential(1.0 / args.rate, size=len(reqs)))
    t0 = time.time()
    clock, nxt_req, steps, decoded = 0.0, 0, 0, 0
    pending: list[Request] = []
    peak_resident = 0
    while nxt_req < len(reqs) or pending or b.preempted or b.live.any():
        while nxt_req < len(reqs) and arrive[nxt_req] <= clock:
            pending.append(reqs[nxt_req])
            nxt_req += 1
        while b.preempted and b.try_admit(b.preempted[0]):
            b.preempted.pop(0)
        while pending and b.try_admit(pending[0]):
            pending.pop(0)
        if b.live.any():
            decoded += int(b.live.sum())
            b.step()
            steps += 1
        peak_resident = max(peak_resident, b.resident_kv_bytes())
        clock += 1.0
    wall = time.time() - t0
    done = sum(r.done for r in reqs)
    out = {
        "completed": done,
        "steps": steps,
        "decode_tok_s": decoded / max(wall, 1e-9),
        "evictions": b.stats["evictions"],
        "restores": b.stats["restores"],
        "page_reuses": b.stats["page_reuses"],
        "peak_resident_kv_bytes": peak_resident,
        "decision_hits": decisions.hits,
    }
    print(f"[serve --continuous] {done}/{len(reqs)} requests in {steps} "
          f"decode steps ({out['decode_tok_s']:.1f} tok/s); "
          f"evictions {out['evictions']}, restores {out['restores']}, "
          f"page reuses {out['page_reuses']}, "
          f"peak resident KV {peak_resident / 1e6:.2f} MB, "
          f"decision-cache hits {decisions.hits}")
    assert done == len(reqs), f"continuous serving dropped {len(reqs) - done}"
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous serving: paged KV pool + Poisson arrivals")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.25,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--arena-pages", type=int, default=None)
    ap.add_argument("--target-ratio", type=float, default=8.0)
    ap.add_argument("--long-threshold", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    if args.continuous:
        return run_continuous(args, cfg, model, params)
    mesh = make_local_mesh() if len(jax.devices()) == 1 else make_production_mesh()

    rng = np.random.default_rng(0)
    b = args.batch
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (b, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.gen
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.float32
        )

    prefill = make_prefill_step(model)
    decode = make_decode_step(model, sample=args.sample)
    with sharding.activate(mesh, sharding.SERVE_RULES):
        cache = model.init_cache(b, max_len)
        t0 = time.time()
        logits, cache = jax.jit(prefill)(params, batch, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t_prefill = time.time() - t0
        jit_decode = jax.jit(decode)
        toks = [nxt]
        key = jax.random.key(1)
        t0 = time.time()
        for i in range(args.gen - 1):
            key, sub = jax.random.split(key)
            if args.sample:
                nxt, cache = jit_decode(params, nxt, cache, sub)
            else:
                nxt, cache = jit_decode(params, nxt, cache)
            toks.append(nxt)
        jax.block_until_ready(nxt)
        t_decode = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    tput = b * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.prompt_len} toks x{b}: {t_prefill:.2f}s; "
          f"decode {args.gen - 1} steps: {t_decode:.2f}s ({tput:.1f} tok/s)")
    print("[serve] sample output ids:", np.asarray(out[0, :16]))
    return {"tokens": np.asarray(out), "prefill_s": t_prefill, "decode_s": t_decode}


if __name__ == "__main__":
    main()
