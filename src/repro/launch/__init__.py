from . import mesh, shapes
