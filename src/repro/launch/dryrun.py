import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, extract memory/cost/collective numbers for the roofline analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Scan-body correction: XLA's cost_analysis counts a scan body ONCE, so
FLOPs/bytes/collectives are also lowered for 1- and 2-layer-unit variants
of the same cell and extrapolated linearly (a + b*units) to the full depth.
memory_analysis comes from the full-depth compile (buffers are reused
across scan iterations, so it needs no correction).
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import nn as rnn
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import sharding

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # B/s / chip
ICI_BW = 50e9        # B/s / link

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def hlo_collective_bytes(text: str) -> dict[str, float]:
    """Per-device bytes moved by collectives, summed per op kind."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(text):
        ty, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(ty):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[op] = out.get(op, 0.0) + nbytes
    return out


# ---------------------------------------------------------------------------
# per-family layer-unit scaling (for the scan-body extrapolation)
# ---------------------------------------------------------------------------


def with_units(cfg: ModelConfig, n: int) -> ModelConfig:
    """Reduced-depth variant with layers UNROLLED so cost_analysis counts
    every body (a lax.scan body is costed once regardless of trip count)."""
    if cfg.encdec:
        return dataclasses.replace(cfg, n_layers=n, n_enc_layers=n, unroll_layers=True)
    if cfg.xlstm is not None:
        per = cfg.xlstm.m_per_group + cfg.xlstm.s_per_group
        return dataclasses.replace(cfg, n_layers=n * per, unroll_layers=True)
    if cfg.hybrid is not None:
        return dataclasses.replace(cfg, n_layers=n * cfg.hybrid.every, unroll_layers=True)
    nd = cfg.moe.n_dense_layers if cfg.moe else 0
    return dataclasses.replace(cfg, n_layers=nd + n, unroll_layers=True)


def full_units(cfg: ModelConfig) -> float:
    if cfg.encdec:
        return cfg.n_layers
    if cfg.xlstm is not None:
        return cfg.n_layers / (cfg.xlstm.m_per_group + cfg.xlstm.s_per_group)
    if cfg.hybrid is not None:
        return cfg.n_layers / cfg.hybrid.every  # tail folded in (~2% error)
    nd = cfg.moe.n_dense_layers if cfg.moe else 0
    return cfg.n_layers - nd


# ---------------------------------------------------------------------------
# analytic model FLOPs (roofline reference)
# ---------------------------------------------------------------------------


def count_params(model) -> tuple[float, float]:
    """(total, active) parameter counts; MoE expert tensors scaled by
    top_k/n_experts for the active count."""
    cfg = model.cfg
    leaves, _ = jax.tree_util.tree_flatten_with_path(rnn.abstract_tree(model.desc()))
    total = active = 0.0
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = float(np.prod(leaf.shape))
        total += n
        if cfg.moe and ("/w_gate" in name or "/w_up" in name or "/w_down" in name) and len(leaf.shape) >= 4:
            active += n * (cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return total, active


def model_flops(model, kind: str, b: int, seq: int) -> float:
    total, active = count_params(model)
    if kind == "train":
        return 6.0 * active * b * seq
    if kind == "prefill":
        return 2.0 * active * b * seq
    return 2.0 * active * b  # decode: one token


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shardings(batch_abs: dict, mesh, global_batch: int):
    from jax.sharding import NamedSharding, PartitionSpec

    dp = _dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_n = int(np.prod([sizes[a] for a in dp]))
    first = (dp[0] if len(dp) == 1 else dp) if global_batch % dp_n == 0 else None

    def _s(leaf):
        parts = [first] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, PartitionSpec(*parts))

    return jax.tree_util.tree_map(_s, batch_abs)


def lower_cell(arch: str, shape_name: str, mesh, *, units: int | None = None,
               opt_cfg: adamw.AdamWConfig | None = None, variant: str = "baseline"):
    """Lower+compile one cell (optionally at a reduced layer-unit count).
    variant: 'baseline' | 'tp_weights' (no FSDP over weight embed dims) |
    'seqkv' (sequence-sharded KV cache when heads can't shard).
    Returns (compiled, info dict)."""
    cfg0 = shp.shape_config(get_config(arch), shape_name)
    cfg = with_units(cfg0, units) if units is not None else cfg0
    if variant in ("kvq8", "combo"):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if variant in ("moegroups", "ds_best") and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=32)
        )
    model = build_model(cfg)
    spec = shp.input_specs(cfg, shape_name)
    kind = spec["kind"]
    if kind == "train":
        rules = sharding.TRAIN_RULES_TP if variant == "tp_weights" else sharding.TRAIN_RULES
    else:
        rules = sharding.SERVE_RULES
    params_abs = rnn.abstract_tree(model.desc())
    if variant in ("bf16params", "ds_best"):
        # bf16 parameter storage (f32 adam moments remain the master copy):
        # halves FSDP all-gather AND gradient-reduction bytes
        params_abs = jax.tree_util.tree_map(
            lambda sdt: jax.ShapeDtypeStruct(sdt.shape, jnp.bfloat16)
            if sdt.dtype == jnp.float32 else sdt,
            params_abs,
        )
    axes = rnn.axes_tree(model.desc())
    pshard = sharding.tree_shardings(axes, rules, mesh, abstract=params_abs)
    bshard = batch_shardings(spec["batch"], mesh, spec["global_batch"])
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    with sharding.activate(mesh, rules):
        if kind == "train":
            def f32(s):
                return jax.ShapeDtypeStruct(s.shape, jnp.float32)
            opt_abs = {
                "m": jax.tree_util.tree_map(f32, params_abs),
                "v": jax.tree_util.tree_map(f32, params_abs),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            from jax.sharding import NamedSharding, PartitionSpec

            oshard = {
                "m": pshard, "v": pshard,
                "step": NamedSharding(mesh, PartitionSpec()),
            }

            def step(params, opt, batch):
                (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(
                    params, batch
                )
                new_p, new_o, om = adamw.update(opt_cfg, grads, opt, params)
                return new_p, new_o, {"loss": loss, **om}

            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, spec["batch"])
        else:
            b = spec["global_batch"]
            cache_abs = model.cache_desc(b, spec["cache_len"])
            head_sizes = {cfg.n_kv_heads, cfg.n_heads}
            cshard = sharding.cache_sharding(
                cache_abs, mesh, b, head_sizes,
                seq_shard=variant in ("seqkv", "combo"),
            )

            if kind == "prefill":
                def step(params, batch, cache):
                    logits, cache = model.forward(params, batch, cache=cache)
                    return logits[:, -1:], cache
                jitted = jax.jit(
                    step, in_shardings=(pshard, bshard, cshard), donate_argnums=(2,)
                )
                lowered = jitted.lower(params_abs, spec["batch"], cache_abs)
            else:
                def step(params, tokens, cache):
                    logits, cache = model.forward(params, {"tokens": tokens}, cache=cache)
                    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache
                tok_abs = spec["batch"]["tokens"]
                tshard = batch_shardings({"t": tok_abs}, mesh, b)["t"]
                jitted = jax.jit(
                    step, in_shardings=(pshard, tshard, cshard), donate_argnums=(2,)
                )
                lowered = jitted.lower(params_abs, tok_abs, cache_abs)

        compiled = lowered.compile()
    return compiled, {"cfg": cfg, "model": model, "spec": spec}


def analyze_cell(arch: str, shape_name: str, mesh_name: str, extrapolate: bool = True,
                 variant: str = "baseline") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    cfg0 = shp.shape_config(get_config(arch), shape_name)
    ok, why = shp.applicable(cfg0, shape_name)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
                 "variant": variant}
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    try:
        t0 = time.time()
        compiled, info = lower_cell(arch, shape_name, mesh, variant=variant)
        rec["compile_seconds"] = round(time.time() - t0, 1)
        ca = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        rec["cost_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
        }
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        coll = hlo_collective_bytes(compiled.as_text())
        rec["collectives_raw"] = coll
        spec = info["spec"]

        if extrapolate:
            vals = {}
            for u in (1, 2):
                c_u, _ = lower_cell(arch, shape_name, mesh, units=u, variant=variant)
                ca_u = c_u.cost_analysis() or {}
                vals[u] = {
                    "flops": float(ca_u.get("flops", 0.0)),
                    "bytes": float(ca_u.get("bytes accessed", 0.0)),
                    "coll": sum(hlo_collective_bytes(c_u.as_text()).values()),
                }
            L = full_units(info["cfg"])
            corr = {}
            for k in ("flops", "bytes", "coll"):
                b_ = vals[2][k] - vals[1][k]
                a_ = vals[1][k] - b_
                corr[k] = a_ + b_ * L
            rec["corrected"] = {
                "flops": corr["flops"],
                "bytes": corr["bytes"],
                "collective_bytes": corr["coll"],
                "units": L,
            }
        mf = model_flops(info["model"], spec["kind"], spec["global_batch"], spec["seq"])
        rec["model_flops"] = mf
        flops = rec.get("corrected", rec["cost_raw"])["flops"]
        bts = rec.get("corrected", rec["cost_raw"])["bytes"]
        cb = rec.get("corrected", {}).get(
            "collective_bytes", sum(coll.values())
        )
        # cost_analysis is per-device under SPMD
        rec["roofline"] = {
            "t_compute_s": flops / PEAK_FLOPS,
            "t_memory_s": bts / HBM_BW,
            "t_collective_s": cb / ICI_BW,
            "useful_flops_ratio": mf / chips / max(flops, 1.0),
        }
        terms = rec["roofline"]
        dom = max(
            ("t_compute_s", "t_memory_s", "t_collective_s"), key=lambda k: terms[k]
        )
        rec["roofline"]["dominant"] = dom
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "tp_weights", "seqkv", "kvq8", "bf16params", "combo", "moegroups", "ds_best"])
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes_ = list(shp.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape_name in shapes_:
            for mesh_name in meshes:
                suffix = "" if args.variant == "baseline" else f"__{args.variant}"
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
                )
                if os.path.exists(path):
                    print(f"[cached] {path}")
                    continue
                print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ...", flush=True)
                rec = analyze_cell(
                    arch, shape_name, mesh_name,
                    extrapolate=not args.no_extrapolate, variant=args.variant,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = rec.get("reason", rec.get("error", ""))
                rl = rec.get("roofline", {})
                print(
                    f"  -> {status} {extra} compile={rec.get('compile_seconds', '-')}s "
                    f"dom={rl.get('dominant', '-')}",
                    flush=True,
                )


if __name__ == "__main__":
    main()
