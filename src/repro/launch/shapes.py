"""Assigned input-shape set and abstract input specs per (arch x shape).

LM transformer shapes are seq_len x global_batch. decode_*/long_* lower
`serve_step` (one new token against a KV cache of seq_len), NOT train_step.
long_500k requires sub-quadratic attention: runs for SSM/hybrid archs
(xlstm, zamba2 — the latter with a 4k sliding window on its shared
attention block), skipped for pure full-attention archs (DESIGN.md §6).

`FIELD_SHAPES` / `compression_view` are the compression-side counterpart:
the canonical scientific-field shapes the 3-D kernel bench drives through
the kernel tiers, plus the fold plan each will compress as — genuinely-
3-D fields stay 3-D (the paper's Hurricane/NYX workloads ride the 3-D
Pallas kernels, DESIGN.md §3.4–§3.5) instead of being flattened to 2-D.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4_096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}

#: canonical scientific-field shapes per paper workload, CPU-bench scaled
#: (the *_full variants carry the real dataset dims for TPU runs);
#: benchmarks/bench_kernels3d.py derives its default cube sizes from here
FIELD_SHAPES = {
    "atm_2d": (384, 768),             # ATM climate plane (1800x3600 full)
    "hurricane_3d": (96, 256, 256),   # Hurricane volume (100x500x500 full)
    "nyx_3d": (128, 128, 128),        # NYX cosmology cube (512^3 full)
    "hurricane_full": (100, 500, 500),
    "nyx_full": (512, 512, 512),
}


def compression_view(shape: tuple[int, ...]) -> tuple[int, ...]:
    """The folded view shape `core.selector` / the kernel tier will see for
    a field of `shape` (delegates to `core.sharded.fold_plan`): rank > 3
    folds leading axes but never below 3-D, short (< 4) leading dims merge
    away — so e.g. a (T, Z, Y, X) time-stacked volume compresses as a 3-D
    stack, not a 2-D sheet."""
    from repro.core.sharded import fold_plan

    return fold_plan(tuple(int(s) for s in shape))[0]

I32 = jnp.int32
F32 = jnp.float32


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attn)"
    return True, ""


def shape_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Per-shape config tweaks (e.g. windowed shared attention in long mode)."""
    if shape_name == "long_500k" and cfg.hybrid is not None:
        return dataclasses.replace(cfg, attn_window=4_096)
    return cfg


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns {'kind', 'batch': {...}, 'decode_tokens': ..., 'cache_len': int}.
    For train, batch = full (tokens, labels, frontend stubs). For prefill,
    batch = prompt tokens (+ stubs). For decode, tokens are (B, 1) and
    cache_len is the preallocated KV length.
    """
    sh = SHAPES[shape_name]
    b, seq = sh["batch"], sh["seq"]
    kind = sh["kind"]
    out = {"kind": kind, "global_batch": b, "seq": seq}
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        ltxt = seq - (cfg.frontend_len if cfg.frontend == "vision" else 0)
        batch = {
            "tokens": sds((b, ltxt), I32),
            "labels": sds((b, ltxt), I32),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sds((b, cfg.frontend_len, cfg.d_model), F32)
        if cfg.encdec:
            batch["frames"] = sds((b, cfg.frontend_len, cfg.d_model), F32)
        out["batch"] = batch
    elif kind == "prefill":
        ltxt = seq - (cfg.frontend_len if cfg.frontend == "vision" else 0)
        batch = {"tokens": sds((b, ltxt), I32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sds((b, cfg.frontend_len, cfg.d_model), F32)
        if cfg.encdec:
            batch["frames"] = sds((b, cfg.frontend_len, cfg.d_model), F32)
        out["batch"] = batch
        out["cache_len"] = seq
    else:  # decode
        out["batch"] = {"tokens": sds((b, 1), I32)}
        # windowed hybrids cap the attention cache at the window
        cfg2 = shape_config(cfg, shape_name)
        cache_len = seq
        if shape_name == "long_500k":
            cache_len = cfg2.attn_window or 4_096
        out["cache_len"] = cache_len
    return out
