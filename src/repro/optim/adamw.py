"""AdamW with global-norm clipping and warmup+cosine schedule (no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params: Any) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def update(
    cfg: AdamWConfig, grads: Any, state: dict, params: Any
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_ = b1 * m + (1 - b1) * g
        v_ = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_ / bc1
        vh = v_ / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gn, "lr": lr},
    )
