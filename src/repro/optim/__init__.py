from . import adamw, compress
from .adamw import AdamWConfig
from .compress import GradCompressConfig
