"""Error-feedback gradient compression — the paper's Stage I/II applied to
distributed-training traffic (DESIGN.md §2, §6).

Each step, per gradient tensor:
  g' = g + residual                      (error feedback)
  k  = round(g' / (2*eb))                (prequantization — SZ Stage II)
  residual' = g' - 2*eb*k                (carried quantization error)
and the optimizer consumes the dequantized g~ = 2*eb*k. The integer codes
are what would cross the wire (cross-pod DCN all-reduce); `wire_bits`
reports their entropy-coded size in-graph (Eq. (5)-style), giving the bytes
saved without leaving XLA. eb is value-range-relative per tensor, so the
scheme is exactly the paper's error-bounded quantization with Theorem-1
semantics (pointwise error <= eb, zero drift thanks to error feedback).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import Policy


@dataclasses.dataclass(frozen=True)
class GradCompressConfig:
    eb_rel: float = 1e-3   # of each tensor's grad value range
    hist_bits: int = 8     # entropy estimated over 2^hist_bits clipped codes
    # optional Policy spelling of the bound (DESIGN.md §2): a fixed_accuracy
    # policy whose eb_rel overrides the field above — gradient traffic is
    # in-graph prequantization, so only the bound-centric contract applies
    policy: Policy | None = None

    def __post_init__(self):
        if self.policy is not None:
            if self.policy.mode != "fixed_accuracy" or self.policy.eb_rel is None:
                raise ValueError(
                    "gradient compression carries a value-range-relative "
                    "bound: pass Policy.fixed_accuracy(eb_rel=...)"
                )
            object.__setattr__(self, "eb_rel", self.policy.eb_rel)

    @classmethod
    def from_policy(cls, policy: Policy, hist_bits: int = 8) -> "GradCompressConfig":
        return cls(hist_bits=hist_bits, policy=policy)


def init(params: Any) -> dict:
    return {
        "residual": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    }


def compress(cfg: GradCompressConfig, grads: Any, state: dict) -> tuple[Any, dict, dict]:
    """Returns (dequantized grads, new state, metrics incl. wire bits/value)."""
    half = 2 ** (cfg.hist_bits - 1) - 1

    def one(g, r):
        g = g.astype(jnp.float32) + r
        vr = jnp.maximum(jnp.max(g) - jnp.min(g), 1e-12)
        eb = cfg.eb_rel * vr
        delta = 2.0 * eb
        k = jnp.round(g / delta)
        gq = k * delta
        resid = g - gq
        kc = jnp.clip(k, -half, half) + half
        hist = jnp.zeros(2 * half + 1, jnp.float32).at[kc.astype(jnp.int32).reshape(-1)].add(1.0)
        p = hist / jnp.maximum(hist.sum(), 1)
        ent = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))
        return gq, resid, ent

    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = treedef.flatten_up_to(state["residual"])
    outs = [one(g, r) for g, r in zip(flat, rflat)]
    gq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    sizes = jnp.asarray([g.size for g in flat], jnp.float32)
    ents = jnp.stack([o[2] for o in outs])
    wire_bits = jnp.sum(ents * sizes) / jnp.sum(sizes) + 0.5  # + Huffman offset
    return gq, {"residual": resid}, {"wire_bits_per_value": wire_bits}
