"""Pallas TPU kernels for the compression hot spots (validated in
interpret mode on CPU; TPU is the target)."""

from . import ops  # noqa: F401
