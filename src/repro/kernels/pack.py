"""Device-resident bitstream packing: the uint32 word-arena packer (DESIGN.md §3.7).

Stage III's byte emission used to be the one host-only step of the save
path; this module is the jit-safe core that moves it in-graph. Both device
encoders (`core/device_encode.py`) reduce their variable-length emissions
to the same primitive: a *monotone* sequence of (bit-offset, value, length)
writes into a preallocated uint32 word arena — no data-dependent control
flow, no data-dependent shapes. Two realizations of that primitive live
here, chosen by what the caller can promise:

* `pack_codes` — scatter form: each write lands in at most two words via
  masked shift/or scatter-adds. Tolerates zero-length writes, so it merges
  the ZFP chunk emitter's mostly-empty slot grid.
* `pack_codes_gather` — gather form: each *word* sums the shifted
  contributions of the bounded window of codes that can overlap it
  (`searchsorted` on the offset prefix sum finds the first). Requires
  every length >= 1 — the SZ Huffman stream qualifies (every emitted
  symbol has a code) — and on the 2-core XLA:CPU backend it beats the
  scatter form by avoiding the serialized scatter loop entirely.

Layout contract (what makes the arena byte-compatible with the host
coders): bit `b` of the stream lives in word `b >> 5` at bit `31 - (b & 31)`
— MSB-first within each big-endian word — so `words.byteswap().tobytes()`
truncated to `ceil(nbits/8)` is exactly what `np.packbits` would have
produced from the same bit sequence. The decoders (`core/sz.py`,
`core/zfp.py`) never change.

Everything is uint32-only: the repo runs with x64 disabled, and write
lengths capped at 32 (`MAX_CODE_LEN` is 24 for SZ; ZFP chunk parts are
right-aligned 32-bit halves) keep every shift strictly inside [0, 32).
Offsets are exclusive prefix sums, so writes to the same word never
collide on a bit — scatter `add` is `or` here by construction. Out-of-arena
writes (the rate model under-estimated) fall in `mode='drop'`: the arena
can *truncate* but never corrupt, and the caller detects truncation from
the true total bit count (DESIGN.md §3.7 fallback rules).

On TPU these lower to XLA scatters/gathers over VMEM-resident arenas; on
CPU the same program runs through the XLA:CPU path (the kernels' interpret
tier, DESIGN.md §3.3), which is what the `device_encode_speedup` bench
gate ratio measures.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

#: arena word width; the packer's only unit
WORD_BITS = 32


def arena_words(nbits: int, min_words: int = 64) -> int:
    """Arena size (in uint32 words) for a bit budget: the next power of two
    at or above `ceil(nbits/32)`. The pow2 bucketing bounds the jit compile
    cache exactly like the block-batch bucketing of DESIGN.md §1 — arenas
    of the same bucket share one compiled packer."""
    need = max(int(min_words), -(-int(nbits) // WORD_BITS))
    return 1 << int(np.ceil(np.log2(need)))


def pack_codes(
    codes: jnp.ndarray,
    lens: jnp.ndarray,
    offsets: jnp.ndarray,
    n_words: int,
) -> jnp.ndarray:
    """Pack variable-length codes (MSB-first) into a fresh word arena
    (scatter form).

    Args:
      codes: (N,) uint32 — each value's low `lens[i]` bits are the codeword.
      lens: (N,) int32 in [0, 32] — 0 emits nothing (dead slots are free).
      offsets: (N,) int32 — exclusive prefix sum of `lens`: bit offset of
        each code in the stream (monotone; the §3.7 prefix-sum layout).
      n_words: static arena size (`arena_words`).

    Returns the (n_words,) uint32 arena. A code lands in at most two words:
    `hi` carries the upper `len - spill` bits into word `off >> 5`, `lo`
    the remaining `spill` bits into the next word. All shifts stay in
    [0, 32) — `spill <= 31` because `len <= 32`.
    """
    codes = codes.astype(jnp.uint32)
    lens = lens.astype(jnp.int32)
    offsets = offsets.astype(jnp.int32)
    pos = offsets & (WORD_BITS - 1)
    w0 = offsets >> 5
    end = pos + lens
    spill = jnp.maximum(end - WORD_BITS, 0)
    hi_shift = jnp.clip(WORD_BITS - end, 0, WORD_BITS - 1).astype(jnp.uint32)
    hi = (codes >> spill.astype(jnp.uint32)) << hi_shift
    lo_shift = jnp.clip(WORD_BITS - spill, 0, WORD_BITS - 1).astype(jnp.uint32)
    lo = jnp.where(spill > 0, codes << lo_shift, jnp.uint32(0))
    live = lens > 0
    hi = jnp.where(live, hi, jnp.uint32(0))
    lo = jnp.where(live, lo, jnp.uint32(0))
    words = jnp.zeros((n_words,), jnp.uint32)
    words = words.at[w0].add(hi, mode="drop", indices_are_sorted=True)
    words = words.at[w0 + 1].add(lo, mode="drop", indices_are_sorted=True)
    return words


def gather_window(min_len: int) -> int:
    """Static gather window for `pack_codes_gather`: an upper bound on how
    many codes can overlap one 32-bit word when every code is at least
    `min_len` bits — one straddling the word start plus `32 // min_len`
    starting inside it, +1 slack. Bucketed to a small set so streams with
    different tables share compiled packers (the §1 bucketing rule)."""
    need = WORD_BITS // max(int(min_len), 1) + 2
    for cap in (6, 10, 18, 34):
        if need <= cap:
            return cap
    return 34


def pack_codes_gather(
    codes: jnp.ndarray,
    lens: jnp.ndarray,
    offsets: jnp.ndarray,
    n_words: int,
    window: int,
) -> jnp.ndarray:
    """Pack variable-length codes (MSB-first) into a fresh word arena
    (gather form): word `i` is the OR (sum — bits never collide) of the
    shifted contributions of the codes overlapping bits [32i, 32i+32).

    Contract: every `lens[i]` is in [1, 32] (no dead slots — the window
    bound breaks otherwise) and `window >= 32 // min(lens) + 2`
    (`gather_window`). `offsets` is the exclusive prefix sum of `lens`.
    Words past the last code read dead lanes and come out zero, so the
    pow2 arena slack is harmless.
    """
    n = codes.shape[0]
    starts = jnp.arange(n_words, dtype=jnp.int32) * WORD_BITS
    first = jnp.searchsorted(offsets, starts, side="right").astype(jnp.int32) - 1
    first = jnp.clip(first, 0, max(n - 1, 0))
    j = first[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    jc = jnp.clip(j, 0, max(n - 1, 0))
    off = offsets[jc]
    ln = lens[jc].astype(jnp.int32)
    c = codes[jc].astype(jnp.uint32)
    # t: how many bits of code j extend past this word's start
    t = off + ln - starts[:, None]
    live = (j < n) & (t > 0) & (off < starts[:, None] + WORD_BITS)
    contrib = jnp.where(
        t > WORD_BITS,
        c >> jnp.clip(t - WORD_BITS, 0, WORD_BITS - 1).astype(jnp.uint32),
        c << jnp.clip(WORD_BITS - t, 0, WORD_BITS - 1).astype(jnp.uint32),
    )
    return jnp.sum(
        jnp.where(live, contrib, jnp.uint32(0)), axis=1, dtype=jnp.uint32
    )


def words_to_bytes(words: np.ndarray, nbits: int) -> bytes:
    """Host finalizer: big-endian word arena -> the exact `np.packbits`
    byte stream for `nbits` bits. Bits past `nbits` were never written
    (the arena starts zeroed), so truncation is safe and the result is
    byte-identical to the host coders' payloads."""
    nbytes = -(-int(nbits) // 8)
    return np.asarray(words, dtype=np.uint32).byteswap().tobytes()[:nbytes]


__all__ = [
    "WORD_BITS",
    "arena_words",
    "gather_window",
    "pack_codes",
    "pack_codes_gather",
    "words_to_bytes",
]
