"""Jit'd public wrappers over the Pallas kernels, with padding + dispatch.

`lorenzo_encode` / `lorenzo_decode` and `bot_fused` accept arbitrary-shape
fields; 2-D shapes route to the Pallas kernels (padded up to tile
multiples), everything else falls back to the ref.py / core jnp paths.
On CPU the kernels run in interpret mode (TPU is the target)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.transforms import lorenzo_forward, lorenzo_inverse

from . import bot4, lorenzo, ref


def _pad_to(x: jax.Array, bm: int, bn: int) -> tuple[jax.Array, tuple[int, int]]:
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, (m, n)


def lorenzo_encode(x: jax.Array, eb, block=lorenzo.DEFAULT_BLOCK) -> jax.Array:
    """Quantize + n-D Lorenzo difference -> int32 codes (same shape)."""
    if x.ndim == 2 and x.shape[0] >= 8:
        xp, (m, n) = _pad_to(x, *block)
        return lorenzo.lorenzo2d_encode(xp, eb, block=block)[:m, :n]
    delta = 2.0 * jnp.asarray(eb, jnp.float32)
    return lorenzo_forward(jnp.round(x.astype(jnp.float32) / delta)).astype(jnp.int32)


def lorenzo_decode(d: jax.Array, eb, block=lorenzo.DEFAULT_BLOCK) -> jax.Array:
    """Inverse Lorenzo (n-D cumsum) + dequantize -> f32 reconstruction."""
    k = lorenzo_inverse(d.astype(jnp.float32))
    if d.ndim == 2 and d.shape[0] >= 8:
        kp, (m, n) = _pad_to(k.astype(jnp.int32), *block)
        return lorenzo.dequantize2d(kp, eb, block=block)[:m, :n]
    return k * (2.0 * jnp.asarray(eb, jnp.float32))


def bot_fused(x: jax.Array, eb, transform: str = "zfp", block=bot4.DEFAULT_BLOCK):
    """Fused ZFP-style transform/truncate -> (recon, bits-per-block)."""
    if x.ndim == 2:
        xp, (m, n) = _pad_to(x, *block)
        recon, bits = bot4.bot2d_fused(xp, eb, transform=transform, block=block)
        return recon[:m, :n], bits[: -(-m // 4), : -(-n // 4)]
    # non-2D fields use the core jnp path
    from repro.core.zfp import zfp_stats

    st = zfp_stats(x, eb, transform=transform)
    return st.recon, None
