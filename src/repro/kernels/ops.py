"""Jit'd public wrappers over the Pallas kernels, with padding + dispatch.

`lorenzo_encode` / `lorenzo_decode` and `bot_fused` accept arbitrary-shape
fields; 2-D AND 3-D shapes route to the Pallas kernels (padded up to tile
multiples, with the tile clamped down near the field so small fields do
not pad to a full default tile), everything else falls back to the
ref.py / core jnp paths. On CPU the kernels run in interpret mode (TPU is
the target).

Dispatch is decided by ONE shared predicate (`pallas_rank`): the Lorenzo
and BOT wrappers must agree on which fields ride the kernel tier, or a
tiny field could encode on one path and be priced on another
(DESIGN.md §3.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.transforms import lorenzo_forward, lorenzo_inverse

from . import bot4, lorenzo

#: per-rank tile granularity the clamped/padded tile must respect:
#: trailing dim multiples of 128 (VREG lanes), second-to-last multiples of
#: 8 (f32 sublanes), leading 3-D dim multiples of 4 (one BOT block).
_GRAIN = {2: (8, 128), 3: (4, 8, 128)}


def pallas_rank(shape: tuple[int, ...]) -> int | None:
    """The Pallas kernel tier (2 or 3) serving `shape`, or None for the
    jnp reference path.

    THE shared dispatch predicate: `lorenzo_encode` once required
    `shape[0] >= 8` while `bot_fused` gated only on `ndim == 2`, so a
    (4, 40) field encoded on the reference path but priced on the kernel
    path. Every non-empty 2-D/3-D shape rides the kernel tier — the tile
    clamp rounds short leading dims up to the sublane granularity and the
    zero padding is exact (Lorenzo's backward differences never look into
    trailing pad rows; BOT pad blocks are sliced off recon and bits) — so
    in-graph callers like `kvcomp.bot_compress_kv` always get real
    per-block bits. Keeping the predicate in one place makes the wrappers
    agree by construction (covered by
    tests/test_kernels3d.py::test_dispatch_predicate_shared).
    """
    nd = len(shape)
    if nd in (2, 3) and all(s > 0 for s in shape):
        return nd
    return None


def _clamp_block(shape: tuple[int, ...], block: tuple[int, ...]) -> tuple[int, ...]:
    """Shrink the default tile toward the (granularity-rounded) field so a
    small field pads to its own rounded shape, not to a full default tile."""
    grain = _GRAIN[len(shape)]
    return tuple(
        min(b, -(-s // g) * g) for s, b, g in zip(shape, block, grain)
    )


def _tile(shape: tuple[int, ...], block, default: tuple[int, ...]) -> tuple[int, ...]:
    """The launch tile: the caller's block, the TPU VMEM-shaped default,
    or — in interpret mode on CPU — one whole-field tile. The interpreter
    re-enters the kernel body per grid step, so on CPU the per-step
    overhead dominates any VMEM-shaped tiling, and interpret mode has no
    VMEM limit to respect; a single step keeps the emulated-device bench
    (`benchmarks/bench_kernels3d.py`) measuring the fused math, not the
    interpreter."""
    if block is None:
        block = default
        if jax.default_backend() == "cpu":
            block = tuple(1 << 30 for _ in shape)
    return _clamp_block(shape, block)


def _pad_to(x: jax.Array, block: tuple[int, ...]):
    pads = tuple((0, (-s) % b) for s, b in zip(x.shape, block))
    shape = x.shape
    if any(p for _, p in pads):
        x = jnp.pad(x, pads)
    return x, shape


def lorenzo_encode(x: jax.Array, eb, block=None) -> jax.Array:
    """Quantize + n-D Lorenzo difference -> int32 codes (same shape)."""
    rank = pallas_rank(x.shape)
    if rank == 2:
        blk = _tile(x.shape, block, lorenzo.DEFAULT_BLOCK)
        xp, (m, n) = _pad_to(x, blk)
        return lorenzo.lorenzo2d_encode(xp, eb, block=blk)[:m, :n]
    if rank == 3:
        blk = _tile(x.shape, block, lorenzo.DEFAULT_BLOCK3)
        xp, (z, m, n) = _pad_to(x, blk)
        return lorenzo.lorenzo3d_encode(xp, eb, block=blk)[:z, :m, :n]
    delta = 2.0 * jnp.asarray(eb, jnp.float32)
    return lorenzo_forward(jnp.round(x.astype(jnp.float32) / delta)).astype(jnp.int32)


def lorenzo_decode(d: jax.Array, eb, block=None) -> jax.Array:
    """Inverse Lorenzo (n-D cumsum) + dequantize -> f32 reconstruction."""
    k = lorenzo_inverse(d.astype(jnp.float32))
    rank = pallas_rank(d.shape)
    if rank == 2:
        blk = _tile(d.shape, block, lorenzo.DEFAULT_BLOCK)
        kp, (m, n) = _pad_to(k.astype(jnp.int32), blk)
        return lorenzo.dequantize2d(kp, eb, block=blk)[:m, :n]
    if rank == 3:
        blk = _tile(d.shape, block, lorenzo.DEFAULT_BLOCK3)
        kp, (z, m, n) = _pad_to(k.astype(jnp.int32), blk)
        return lorenzo.dequantize3d(kp, eb, block=blk)[:z, :m, :n]
    return k * (2.0 * jnp.asarray(eb, jnp.float32))


def bot_fused(x: jax.Array, eb, transform: str = "zfp", block=None):
    """Fused ZFP-style transform/truncate -> (recon, bits-per-block)."""
    rank = pallas_rank(x.shape)
    if rank == 2:
        blk = _tile(x.shape, block, bot4.DEFAULT_BLOCK)
        xp, (m, n) = _pad_to(x, blk)
        recon, bits = bot4.bot2d_fused(xp, eb, transform=transform, block=blk)
        return recon[:m, :n], bits[: -(-m // 4), : -(-n // 4)]
    if rank == 3:
        blk = _tile(x.shape, block, bot4.DEFAULT_BLOCK3)
        xp, (z, m, n) = _pad_to(x, blk)
        recon, bits = bot4.bot3d_fused(xp, eb, transform=transform, block=blk)
        return recon[:z, :m, :n], bits[: -(-z // 4), : -(-m // 4), : -(-n // 4)]
    # other ranks use the core jnp path
    from repro.core.zfp import zfp_stats

    st = zfp_stats(x, eb, transform=transform)
    return st.recon, None
