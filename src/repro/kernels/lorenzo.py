"""Pallas TPU kernels: fused prequantize + 2-D/3-D integer-Lorenzo encode.

The SZ Stage I+II hot spot (DESIGN.md §3.1, §3.3, §3.4). One pass over
HBM: round(x / 2eb) and the n-D Lorenzo difference of the integer codes,
tiled through VMEM. Tile-boundary neighbors are fetched with extra *views*
of the same input one element back (1-element-granular index maps on
blocks with size-1 dims), so no halo padding or materialized shifted
copies are needed. In 2-D that is one row + one column + one corner view;
in 3-D it is the full lower halo shell — three faces, three edges, and
one corner over a (bz, bm, bn) grid (DESIGN.md §3.4).

TPU mapping notes:
  * (bm, bn) = (256, 256) default in 2-D — 256 KiB f32 per tile, lane dim
    a multiple of 128 for clean (8,128) VREG tiling; (8, 128, 256) in 3-D
    (1 MiB f32 per tile) with the same trailing-dim alignment.
  * round / sub are VPU element ops; the whole kernel is memory-bound, so
    fusing quantize+stencil halves HBM traffic vs running them separately.
  * grid is fully parallel (no carried state — this is the entire point of
    the prequantized reformulation vs sequential SZ).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (256, 256)
DEFAULT_BLOCK3 = (8, 128, 256)


def _encode_kernel(eb_ref, x_ref, top_ref, left_ref, corner_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    delta = 2.0 * eb_ref[0, 0]
    k = jnp.round(x_ref[...] / delta)
    # halo rows/cols are views of the same array one element back; mask the
    # domain boundary (Lorenzo predicts 0 outside the domain)
    top = jnp.round(top_ref[...] / delta) * (i > 0)  # (1, bn)
    left = jnp.round(left_ref[...] / delta) * (j > 0)  # (bm, 1)
    corner = jnp.round(corner_ref[...] / delta) * ((i > 0) & (j > 0))  # (1,1)
    k_up = jnp.concatenate([top, k[:-1, :]], axis=0)
    k_left = jnp.concatenate([left, k[:, :-1]], axis=1)
    ul_row = jnp.concatenate([corner, top[:, :-1]], axis=1)  # (1, bn)
    k_ul = jnp.concatenate([ul_row, k_left[:-1, :]], axis=0)
    d = k - k_up - k_left + k_ul
    out_ref[...] = d.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def lorenzo2d_encode(
    x: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused quantize+Lorenzo for a 2-D f32 field -> int32 residual codes.

    Requires shape divisible by `block` (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = x.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            # one-row view starting at element row i*bm - 1 (clamped at 0;
            # the kernel masks i == 0 anyway)
            pl.BlockSpec((1, bn), lambda i, j: (i * bm - 1, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j * bn - 1)),
            pl.BlockSpec((1, 1), lambda i, j: (i * bm - 1, j * bn - 1)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(eb_arr, x, x, x, x)


def _encode3d_kernel(
    eb_ref, x_ref, zf_ref, yf_ref, xf_ref, zy_ref, zx_ref, yx_ref, c_ref, out_ref
):
    """3-D extension of `_encode_kernel` (DESIGN.md §3.4): the lower halo
    shell of the (bz, bm, bn) tile arrives as seven views of the same
    input one element back — faces (1,bm,bn)/(bz,1,bn)/(bz,bm,1), edges
    (1,1,bn)/(1,bm,1)/(bz,1,1) and the (1,1,1) corner. They are assembled
    into the (bz+1, bm+1, bn+1) extended cube, and the 3-D Lorenzo
    residual is the composition of one backward difference per axis —
    exactly `transforms.lorenzo_forward` restricted to the tile."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    g = pl.program_id(2)
    delta = 2.0 * eb_ref[0, 0]

    def q(ref, keep):
        # quantize a halo view, masking the domain boundary (Lorenzo
        # predicts 0 outside the domain)
        return jnp.round(ref[...] / delta) * keep

    k = jnp.round(x_ref[...] / delta)  # (bz, bm, bn)
    zf = q(zf_ref, i > 0)  # (1, bm, bn) plane at z-1
    yf = q(yf_ref, j > 0)  # (bz, 1, bn) plane at y-1
    xf = q(xf_ref, g > 0)  # (bz, bm, 1) plane at x-1
    zy = q(zy_ref, (i > 0) & (j > 0))  # (1, 1, bn)
    zx = q(zx_ref, (i > 0) & (g > 0))  # (1, bm, 1)
    yx = q(yx_ref, (j > 0) & (g > 0))  # (bz, 1, 1)
    c = q(c_ref, (i > 0) & (j > 0) & (g > 0))  # (1, 1, 1)
    # extended cube: plane 0 carries the z-1 halo, row/col 0 of every
    # plane carry the y-1 / x-1 halos, composed exactly like the shard
    # engine's dim-by-dim halo extension (core/sharded.py)
    plane0 = jnp.concatenate(
        [
            jnp.concatenate([c, zy], axis=2),  # (1, 1, bn+1)
            jnp.concatenate([zx, zf], axis=2),  # (1, bm, bn+1)
        ],
        axis=1,
    )
    body = jnp.concatenate(
        [
            jnp.concatenate([yx, yf], axis=2),  # (bz, 1, bn+1)
            jnp.concatenate([xf, k], axis=2),  # (bz, bm, bn+1)
        ],
        axis=1,
    )
    d = jnp.concatenate([plane0, body], axis=0)  # (bz+1, bm+1, bn+1)
    for ax in range(3):
        d = jax.lax.slice_in_dim(d, 1, d.shape[ax], axis=ax) - jax.lax.slice_in_dim(
            d, 0, d.shape[ax] - 1, axis=ax
        )
    out_ref[...] = d.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def lorenzo3d_encode(
    x: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int, int] = DEFAULT_BLOCK3,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused quantize+Lorenzo for a 3-D f32 field -> int32 residual codes.

    Requires shape divisible by `block` (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    z, m, n = x.shape
    bz, bm, bn = block
    assert z % bz == 0 and m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (z // bz, m // bm, n // bn)
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    # each halo view starts one element back along its offset dims (clamped
    # at 0 by pallas; the kernel masks the boundary programs anyway)
    return pl.pallas_call(
        _encode3d_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, g: (0, 0)),
            pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
            pl.BlockSpec((1, bm, bn), lambda i, j, g: (i * bz - 1, j, g)),
            pl.BlockSpec((bz, 1, bn), lambda i, j, g: (i, j * bm - 1, g)),
            pl.BlockSpec((bz, bm, 1), lambda i, j, g: (i, j, g * bn - 1)),
            pl.BlockSpec((1, 1, bn), lambda i, j, g: (i * bz - 1, j * bm - 1, g)),
            pl.BlockSpec((1, bm, 1), lambda i, j, g: (i * bz - 1, j, g * bn - 1)),
            pl.BlockSpec((bz, 1, 1), lambda i, j, g: (i, j * bm - 1, g * bn - 1)),
            pl.BlockSpec((1, 1, 1), lambda i, j, g: (i * bz - 1, j * bm - 1, g * bn - 1)),
        ],
        out_specs=pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
        out_shape=jax.ShapeDtypeStruct((z, m, n), jnp.int32),
        interpret=interpret,
    )(eb_arr, x, x, x, x, x, x, x, x)


def _dequant_kernel(eb_ref, k_ref, out_ref):
    delta = 2.0 * eb_ref[0, 0]
    out_ref[...] = k_ref[...].astype(jnp.float32) * delta


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize2d(
    k: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Reconstruction from integer codes (decode-side Stage II inverse).

    The Lorenzo inverse itself (2-D cumsum) is left to XLA's optimized scan;
    this kernel fuses only the elementwise dequantize."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = k.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(eb_arr, k)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize3d(
    k: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int, int] = DEFAULT_BLOCK3,
    interpret: bool | None = None,
) -> jax.Array:
    """3-D twin of `dequantize2d`: elementwise dequantize of integer codes
    (the Lorenzo inverse — a 3-D cumsum — stays with XLA's optimized scan)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    z, m, n = k.shape
    bz, bm, bn = block
    assert z % bz == 0 and m % bm == 0 and n % bn == 0
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(z // bz, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, g: (0, 0)),
            pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
        ],
        out_specs=pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
        out_shape=jax.ShapeDtypeStruct((z, m, n), jnp.float32),
        interpret=interpret,
    )(eb_arr, k)
