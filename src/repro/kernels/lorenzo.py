"""Pallas TPU kernel: fused prequantize + 2-D integer-Lorenzo encode/decode.

The SZ Stage I+II hot spot (DESIGN.md §3.1, §3.3). One pass over HBM:
round(x / 2eb) and the 2-D Lorenzo difference of the integer codes, tiled
through VMEM. Tile-boundary neighbors are fetched with one extra row / one
extra column / one corner *view* of the same input (1-element-granular
index maps on (1, bn)/(bm, 1)/(1, 1) blocks), so no halo padding or
materialized shifted copies are needed.

TPU mapping notes:
  * (bm, bn) = (256, 256) default — 256 KiB f32 per tile, lane dim a
    multiple of 128 for clean (8,128) VREG tiling.
  * round / sub are VPU element ops; the whole kernel is memory-bound, so
    fusing quantize+stencil halves HBM traffic vs running them separately.
  * grid is fully parallel (no carried state — this is the entire point of
    the prequantized reformulation vs sequential SZ).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (256, 256)


def _encode_kernel(eb_ref, x_ref, top_ref, left_ref, corner_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    delta = 2.0 * eb_ref[0, 0]
    k = jnp.round(x_ref[...] / delta)
    # halo rows/cols are views of the same array one element back; mask the
    # domain boundary (Lorenzo predicts 0 outside the domain)
    top = jnp.round(top_ref[...] / delta) * (i > 0)  # (1, bn)
    left = jnp.round(left_ref[...] / delta) * (j > 0)  # (bm, 1)
    corner = jnp.round(corner_ref[...] / delta) * ((i > 0) & (j > 0))  # (1,1)
    k_up = jnp.concatenate([top, k[:-1, :]], axis=0)
    k_left = jnp.concatenate([left, k[:, :-1]], axis=1)
    ul_row = jnp.concatenate([corner, top[:, :-1]], axis=1)  # (1, bn)
    k_ul = jnp.concatenate([ul_row, k_left[:-1, :]], axis=0)
    d = k - k_up - k_left + k_ul
    out_ref[...] = d.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def lorenzo2d_encode(
    x: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused quantize+Lorenzo for a 2-D f32 field -> int32 residual codes.

    Requires shape divisible by `block` (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = x.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    grid = (m // bm, n // bn)
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            # one-row view starting at element row i*bm - 1 (clamped at 0;
            # the kernel masks i == 0 anyway)
            pl.BlockSpec((1, bn), lambda i, j: (i * bm - 1, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j * bn - 1)),
            pl.BlockSpec((1, 1), lambda i, j: (i * bm - 1, j * bn - 1)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(eb_arr, x, x, x, x)


def _dequant_kernel(eb_ref, k_ref, out_ref):
    delta = 2.0 * eb_ref[0, 0]
    out_ref[...] = k_ref[...].astype(jnp.float32) * delta


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize2d(
    k: jax.Array,
    eb: jax.Array | float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Reconstruction from integer codes (decode-side Stage II inverse).

    The Lorenzo inverse itself (2-D cumsum) is left to XLA's optimized scan;
    this kernel fuses only the elementwise dequantize."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = k.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(eb_arr, k)
