"""Pallas TPU kernels: fused ZFP Stage I+II surrogate for 2-D/3-D fields.

Per VMEM tile: 4x4 (or 4x4x4) blocking -> exponent alignment -> block
orthogonal transform T(t) (paper §4.2) -> bit-plane truncation ->
(reconstruction, bits-per-block). This is the in-graph hot spot for
KV-cache / activation compression and for accelerating `zfp_stats`.

TPU mapping notes (DESIGN.md §3.2, §3.5):
  * the 4-point transform is expressed as small tensordots against a
    constant 4x4 matrix — two per block in 2-D, three in 3-D; batched over
    the tile's blocks these hit the MXU as (nblk*4^{n-1}, 4) x (4, 4)
    matmuls;
  * exponent alignment uses exp2/log2 on the VPU instead of integer
    exponent plumbing (no bit-twiddling datapath on TPU vector lanes);
  * the bits output uses the closed-form `block_bits` model (the exact
    plane-sectioned count needs a 31-iteration plane loop — measured as
    not worth the VPU time in-kernel; ops.py exposes the exact host count).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.transforms import bot_linf_gain, bot_matrix

DEFAULT_BLOCK = (128, 256)
DEFAULT_BLOCK3 = (8, 64, 256)
BLOCK_HEADER_BITS = 24  # must match repro.core.embedded


def _bot_kernel(eb_ref, T_ref, x_ref, recon_ref, bits_ref, *, gain2):
    bm, bn = x_ref.shape
    nb_r, nb_c = bm // 4, bn // 4
    eb = eb_ref[0, 0]
    x = x_ref[...]
    # -> (nb_r, nb_c, 4, 4) block layout
    b = x.reshape(nb_r, 4, nb_c, 4).transpose(0, 2, 1, 3)
    mx = jnp.maximum(jnp.max(jnp.abs(b), axis=(2, 3)), 1e-30)
    e = jnp.ceil(jnp.log2(mx))
    scale = jnp.exp2(-e)[..., None, None]
    norm = b * scale
    # c = T @ B @ T^T via two tensordots (batched 4x4 matmuls on the MXU)
    Tm = T_ref[...]
    c = jnp.einsum("ab,xybc,dc->xyad", Tm, norm, Tm)
    # conservative power-of-two bit-plane cutoff (over-preservation, §6.4)
    raw = eb / (jnp.exp2(e) * gain2)
    step = jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(raw, 2.0**-60))))[..., None, None]
    q = jnp.abs(c) / step
    m = jnp.trunc(q)
    nsb = jnp.where(m >= 1.0, jnp.floor(jnp.log2(jnp.maximum(m, 1.0))) + 1.0, 0.0)
    # rate model (see module docstring): header + w*maxplane + sum nsb + 2*nsig
    w = math.ceil(math.log2(16 + 1))
    sig = jnp.sum(nsb, axis=(2, 3))
    nsig = jnp.sum((nsb > 0.0).astype(jnp.float32), axis=(2, 3))
    maxp = jnp.max(nsb, axis=(2, 3))
    bits_ref[...] = BLOCK_HEADER_BITS + w * maxp + sig + 2.0 * nsig
    # midpoint reconstruction + inverse transform + de-normalization
    rc = jnp.sign(c) * jnp.where(m > 0, (m + 0.5) * step, 0.0)
    rb = jnp.einsum("ba,xybc,cd->xyad", Tm, rc, Tm)
    rb = rb / scale
    recon_ref[...] = rb.transpose(0, 2, 1, 3).reshape(bm, bn)


@functools.partial(jax.jit, static_argnames=("transform", "block", "interpret"))
def bot2d_fused(
    x: jax.Array,
    eb: jax.Array | float,
    transform: str = "zfp",
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused ZFP-style transform+truncate for a 2-D f32 field.

    Returns (reconstruction (m, n) f32, bits (m/4, n/4) f32).
    Requires shape divisible by `block` (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, n = x.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0 and bm % 4 == 0 and bn % 4 == 0
    T = np.asarray(bot_matrix(transform), np.float32)
    gain2 = float(bot_linf_gain(transform) ** 2)
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    kernel = functools.partial(_bot_kernel, gain2=gain2)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((4, 4), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm // 4, bn // 4), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m // 4, n // 4), jnp.float32),
        ],
        interpret=interpret,
    )(eb_arr, jnp.asarray(T), x)


def _bot3d_kernel(eb_ref, T_ref, x_ref, recon_ref, bits_ref, *, gain3):
    """4x4x4 generalization of `_bot_kernel` (DESIGN.md §3.5): one more
    blocked axis, T(t) applied along all three block axes (three batched
    tensordots on the MXU), and the same closed-form `block_bits` rate
    model with the 3-D coder constants (w = ceil(log2(64+1)) = 7)."""
    bz, bm, bn = x_ref.shape
    nb_z, nb_r, nb_c = bz // 4, bm // 4, bn // 4
    eb = eb_ref[0, 0]
    x = x_ref[...]
    # -> (nb_z, nb_r, nb_c, 4, 4, 4) block layout
    b = x.reshape(nb_z, 4, nb_r, 4, nb_c, 4).transpose(0, 2, 4, 1, 3, 5)
    mx = jnp.maximum(jnp.max(jnp.abs(b), axis=(3, 4, 5)), 1e-30)
    e = jnp.ceil(jnp.log2(mx))
    scale = jnp.exp2(-e)[..., None, None, None]
    norm = b * scale
    # c = T applied along each block axis, as three batched 4x4 matmuls
    Tm = T_ref[...]
    c = jnp.einsum("ai,bj,ck,xyzijk->xyzabc", Tm, Tm, Tm, norm)
    # conservative power-of-two bit-plane cutoff (over-preservation, §6.4)
    raw = eb / (jnp.exp2(e) * gain3)
    step = jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(raw, 2.0**-60))))[
        ..., None, None, None
    ]
    q = jnp.abs(c) / step
    m = jnp.trunc(q)
    nsb = jnp.where(m >= 1.0, jnp.floor(jnp.log2(jnp.maximum(m, 1.0))) + 1.0, 0.0)
    # rate model (see module docstring): header + w*maxplane + sum nsb + 2*nsig
    w = math.ceil(math.log2(64 + 1))
    sig = jnp.sum(nsb, axis=(3, 4, 5))
    nsig = jnp.sum((nsb > 0.0).astype(jnp.float32), axis=(3, 4, 5))
    maxp = jnp.max(nsb, axis=(3, 4, 5))
    bits_ref[...] = BLOCK_HEADER_BITS + w * maxp + sig + 2.0 * nsig
    # midpoint reconstruction + inverse transform + de-normalization
    rc = jnp.sign(c) * jnp.where(m > 0, (m + 0.5) * step, 0.0)
    rb = jnp.einsum("ia,jb,kc,xyzijk->xyzabc", Tm, Tm, Tm, rc)
    rb = rb / scale
    recon_ref[...] = rb.transpose(0, 3, 1, 4, 2, 5).reshape(bz, bm, bn)


@functools.partial(jax.jit, static_argnames=("transform", "block", "interpret"))
def bot3d_fused(
    x: jax.Array,
    eb: jax.Array | float,
    transform: str = "zfp",
    block: tuple[int, int, int] = DEFAULT_BLOCK3,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused ZFP-style transform+truncate for a 3-D f32 field.

    Returns (reconstruction (z, m, n) f32, bits (z/4, m/4, n/4) f32).
    Requires shape divisible by `block` (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    z, m, n = x.shape
    bz, bm, bn = block
    assert z % bz == 0 and m % bm == 0 and n % bn == 0
    assert bz % 4 == 0 and bm % 4 == 0 and bn % 4 == 0
    T = np.asarray(bot_matrix(transform), np.float32)
    gain3 = float(bot_linf_gain(transform) ** 3)
    eb_arr = jnp.full((1, 1), eb, jnp.float32)
    kernel = functools.partial(_bot3d_kernel, gain3=gain3)
    return pl.pallas_call(
        kernel,
        grid=(z // bz, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, g: (0, 0)),
            pl.BlockSpec((4, 4), lambda i, j, g: (0, 0)),
            pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
        ],
        out_specs=[
            pl.BlockSpec((bz, bm, bn), lambda i, j, g: (i, j, g)),
            pl.BlockSpec((bz // 4, bm // 4, bn // 4), lambda i, j, g: (i, j, g)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((z, m, n), jnp.float32),
            jax.ShapeDtypeStruct((z // 4, m // 4, n // 4), jnp.float32),
        ],
        interpret=interpret,
    )(eb_arr, jnp.asarray(T), x)
