"""Pure-jnp oracles for the Pallas kernels (allclose-validated in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import bot_linf_gain, bot_matrix, lorenzo_forward, lorenzo_inverse


def lorenzo_encode_ref(x: jax.Array, eb: jax.Array | float) -> jax.Array:
    """round(x/2eb) then n-D integer Lorenzo difference."""
    delta = 2.0 * jnp.asarray(eb, jnp.float32)
    k = jnp.round(x.astype(jnp.float32) / delta)
    return lorenzo_forward(k).astype(jnp.int32)


def lorenzo_decode_ref(d: jax.Array, eb: jax.Array | float) -> jax.Array:
    """Inverse: n-D cumsum of codes, then dequantize."""
    delta = 2.0 * jnp.asarray(eb, jnp.float32)
    k = lorenzo_inverse(d.astype(jnp.float32))
    return k * delta


#: rank-specific aliases kept for the existing kernel parity tests — the
#: reference is rank-generic (`lorenzo_forward` folds per axis)
lorenzo2d_encode_ref = lorenzo_encode_ref
lorenzo2d_decode_ref = lorenzo_decode_ref
lorenzo3d_encode_ref = lorenzo_encode_ref
lorenzo3d_decode_ref = lorenzo_decode_ref


def bot2d_fused_ref(
    x: jax.Array, eb: jax.Array | float, transform: str = "zfp"
) -> tuple[jax.Array, jax.Array]:
    """Blockize -> align -> BOT -> truncate -> (recon, bits/block)."""
    m, n = x.shape
    assert m % 4 == 0 and n % 4 == 0
    T = jnp.asarray(bot_matrix(transform), jnp.float32)
    gain2 = float(bot_linf_gain(transform) ** 2)
    b = x.astype(jnp.float32).reshape(m // 4, 4, n // 4, 4).transpose(0, 2, 1, 3)
    mx = jnp.maximum(jnp.max(jnp.abs(b), axis=(2, 3)), 1e-30)
    e = jnp.ceil(jnp.log2(mx))
    scale = jnp.exp2(-e)[..., None, None]
    norm = b * scale
    c = jnp.einsum("ab,xybc,dc->xyad", T, norm, T)
    raw = jnp.asarray(eb, jnp.float32) / (jnp.exp2(e) * gain2)
    step = jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(raw, 2.0**-60))))[..., None, None]
    q = jnp.abs(c) / step
    mm = jnp.trunc(q)
    nsb = jnp.where(mm >= 1.0, jnp.floor(jnp.log2(jnp.maximum(mm, 1.0))) + 1.0, 0.0)
    w = math.ceil(math.log2(17))
    sig = jnp.sum(nsb, axis=(2, 3))
    nsig = jnp.sum((nsb > 0.0).astype(jnp.float32), axis=(2, 3))
    maxp = jnp.max(nsb, axis=(2, 3))
    bits = 24.0 + w * maxp + sig + 2.0 * nsig
    rc = jnp.sign(c) * jnp.where(mm > 0, (mm + 0.5) * step, 0.0)
    rb = jnp.einsum("ba,xybc,cd->xyad", T, rc, T)
    rb = rb / scale
    recon = rb.transpose(0, 2, 1, 3).reshape(m, n)
    return recon, bits


def bot3d_fused_ref(
    x: jax.Array, eb: jax.Array | float, transform: str = "zfp"
) -> tuple[jax.Array, jax.Array]:
    """4x4x4 blockize -> align -> BOT -> truncate -> (recon, bits/block)."""
    z, m, n = x.shape
    assert z % 4 == 0 and m % 4 == 0 and n % 4 == 0
    T = jnp.asarray(bot_matrix(transform), jnp.float32)
    gain3 = float(bot_linf_gain(transform) ** 3)
    b = (
        x.astype(jnp.float32)
        .reshape(z // 4, 4, m // 4, 4, n // 4, 4)
        .transpose(0, 2, 4, 1, 3, 5)
    )
    mx = jnp.maximum(jnp.max(jnp.abs(b), axis=(3, 4, 5)), 1e-30)
    e = jnp.ceil(jnp.log2(mx))
    scale = jnp.exp2(-e)[..., None, None, None]
    norm = b * scale
    c = jnp.einsum("ai,bj,ck,xyzijk->xyzabc", T, T, T, norm)
    raw = jnp.asarray(eb, jnp.float32) / (jnp.exp2(e) * gain3)
    step = jnp.exp2(jnp.floor(jnp.log2(jnp.maximum(raw, 2.0**-60))))[
        ..., None, None, None
    ]
    q = jnp.abs(c) / step
    mm = jnp.trunc(q)
    nsb = jnp.where(mm >= 1.0, jnp.floor(jnp.log2(jnp.maximum(mm, 1.0))) + 1.0, 0.0)
    w = math.ceil(math.log2(65))
    sig = jnp.sum(nsb, axis=(3, 4, 5))
    nsig = jnp.sum((nsb > 0.0).astype(jnp.float32), axis=(3, 4, 5))
    maxp = jnp.max(nsb, axis=(3, 4, 5))
    bits = 24.0 + w * maxp + sig + 2.0 * nsig
    rc = jnp.sign(c) * jnp.where(mm > 0, (mm + 0.5) * step, 0.0)
    rb = jnp.einsum("ia,jb,kc,xyzijk->xyzabc", T, T, T, rc)
    rb = rb / scale
    recon = rb.transpose(0, 3, 1, 4, 2, 5).reshape(z, m, n)
    return recon, bits
