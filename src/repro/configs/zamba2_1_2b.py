"""zamba2-1.2b — Mamba2 backbone + shared attention [arXiv:2411.15242]."""
from repro.models.config import HybridCfg, ModelConfig, SSMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,            # 6 groups x 6 mamba + shared attn, +2 tail
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        ssm=SSMCfg(state=64, head_dim=64, expand=2, conv=4, chunk=256),
        hybrid=HybridCfg(every=6, concat_embed=True),
        sub_quadratic=True,     # SSM decode; shared attn windowed in long mode
        attn_window=None,       # set to 4096 by the long_500k shape
    )
