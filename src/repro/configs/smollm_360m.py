"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab=49152,
        mlp_type="swiglu",
    )
