"""llama4-scout-17b-16e — MoE 16 experts top-1 + shared [hf:meta-llama]."""
from repro.models.config import ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        moe=MoECfg(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1, d_ff_shared=8192),
    )
