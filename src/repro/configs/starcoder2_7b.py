"""starcoder2-7b — GQA, RoPE, GELU MLP [arXiv:2402.19173]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab=49152,
        mlp_type="gelu",
    )
