"""internvl2-76b — InternViT + InternLM2 backbone [arXiv:2404.16821].

VLM: the vision frontend is a STUB (input_specs provides precomputed patch
embeddings); this config is the 80L InternLM2-based language backbone."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        mlp_type="swiglu",
        frontend="vision",
        frontend_len=256,
    )
