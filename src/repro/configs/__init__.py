"""Architecture registry: --arch <id> resolves through ARCHS."""

from importlib import import_module

_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-76b": "internvl2_76b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "starcoder2-7b": "starcoder2_7b",
    "smollm-360m": "smollm_360m",
    "minitron-4b": "minitron_4b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCHS = tuple(_MODULES)


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").config()
