"""minitron-4b — pruned nemotron (squared-ReLU MLP) [arXiv:2407.14679]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256000,
        mlp_type="relu2",
    )
