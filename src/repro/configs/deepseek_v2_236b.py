"""deepseek-v2-236b — MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE
[arXiv:2405.04434]."""
from repro.models.config import MLACfg, ModelConfig, MoECfg


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,            # the single leading dense layer's FFN
        vocab=102400,
        head_dim=128,
        mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
        moe=MoECfg(
            n_experts=160, top_k=6, d_ff_expert=1536,
            n_shared=2, d_ff_shared=3072, n_dense_layers=1,
        ),
    )
