"""seamless-m4t-large-v2 — enc-dec multimodal backbone [arXiv:2308.11596].

Audio frontend is a STUB (input_specs provides precomputed frame
embeddings); 24L encoder + 24L decoder with cross-attention."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=24,            # decoder
        n_enc_layers=24,        # encoder
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256206,
        mlp_type="swiglu",
        encdec=True,
        frontend="audio",
        frontend_len=1024,
    )
