"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517]. 48L, d=2048, 4H."""
from repro.models.config import ModelConfig, XLSTMCfg


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,            # 6 groups x (7 mLSTM + 1 sLSTM)
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,                 # xLSTM blocks have no separate FFN
        vocab=50304,
        xlstm=XLSTMCfg(m_per_group=7, s_per_group=1, proj_factor=2.0, chunk=256),
        sub_quadratic=True,     # recurrent decode -> long_500k runs
    )
