"""Deterministic synthetic data pipeline with document packing.

Keyed by (seed, step, shard): a restarted or elastically re-scaled job
replays exactly the same global batch order — the straggler/elasticity
story of DESIGN.md §6. Tokens follow a Zipfian unigram draw with Markov
locality so LM losses move during smoke training (pure uniform tokens give
flat loss). A binary-file reader covers the "real corpus" path.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    doc_len_mean: int = 512
    eos_id: int = 0


def _rng_for(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard, 0xC0FFEE])
    )


def synthetic_batch(cfg: DataConfig, step: int, shard: int = 0, n_shards: int = 1) -> dict:
    """One shard's (tokens, labels) for `step` — pure function of the key."""
    b = cfg.global_batch // n_shards
    rng = _rng_for(cfg, step, shard)
    # zipf unigram with markov locality + packed documents
    base = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len)).astype(np.int64)
    tok = base % (cfg.vocab - 1) + 1
    drift = rng.integers(0, 16, size=(b, cfg.seq_len))
    tok = np.where(drift < 8, np.roll(tok, 1, axis=1), tok)  # local correlation
    # insert document boundaries (packing)
    n_docs = max(cfg.seq_len // max(cfg.doc_len_mean, 16), 1)
    for i in range(b):
        cuts = rng.integers(1, cfg.seq_len, size=n_docs)
        tok[i, cuts] = cfg.eos_id
    labels = np.concatenate([tok[:, 1:], np.full((b, 1), cfg.eos_id, tok.dtype)], axis=1)
    return {"tokens": tok.astype(np.int32), "labels": labels.astype(np.int32)}


def make_batch_iterator(
    cfg: DataConfig, start_step: int = 0, shard: int = 0, n_shards: int = 1
) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step, shard, n_shards)
        step += 1


def read_binary_corpus(path: str, cfg: DataConfig, step: int) -> dict:
    """Real-corpus path: flat int32 token file, strided deterministic reads."""
    data = np.memmap(path, dtype=np.int32, mode="r")
    n = cfg.global_batch * cfg.seq_len
    total = len(data) - 1
    off = (step * n) % max(total - n, 1)
    tok = np.array(data[off : off + n]).reshape(cfg.global_batch, cfg.seq_len)
    lab = np.array(data[off + 1 : off + 1 + n]).reshape(cfg.global_batch, cfg.seq_len)
    return {"tokens": tok, "labels": lab}
