"""Logical-axis sharding rules (MaxText-style) + mesh context.

Model code annotates params/activations with *logical* axes; a rules table
maps them onto mesh axes per mode. Swapping rules swaps the parallelism
layout without touching model code.

Default layout (DESIGN.md §6), mesh ('pod', 'data', 'model'):
  * DP over pod x data (batch),
  * TP over model (heads / mlp / experts / vocab),
  * FSDP: weight 'embed' dims sharded over data -> 2-D weight sharding, so
    even deepseek-v2-236b fits v5e HBM (params gathered per-layer by XLA).
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import nn

TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": "data",      # FSDP axis for weights
    "heads": "model",
    "mlp": "model",
    "experts": "model",
    "kv": "model",
    "layers": None,
    "norm": None,
}

#: §Perf variant: weights TP-only (no FSDP gather/all-reduce over 'data' for
#: weight embed dims) — wins when params/16 fits HBM (small/medium models)
TRAIN_RULES_TP: dict[str, Any] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "mlp": "model",
    "experts": "model",
    "kv": "model",
    "layers": None,
    "norm": None,
}

SERVE_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "embed": None,        # no FSDP gather on the decode critical path
    "heads": "model",
    "mlp": "model",
    "experts": "model",
    "kv": "model",
    "layers": None,
    "norm": None,
}


def spec_for_axes(axes: tuple, rules: dict, mesh: Mesh) -> PartitionSpec:
    """Resolve logical axes -> PartitionSpec, dropping axes not in the mesh
    and never using one mesh axis twice in a single spec."""
    names = set(mesh.axis_names)
    used: set[str] = set()
    parts = []
    for ax in axes:
        rule = rules.get(ax) if ax is not None else None
        if rule is None:
            parts.append(None)
            continue
        cand = (rule,) if isinstance(rule, str) else tuple(rule)
        cand = tuple(a for a in cand if a in names and a not in used)
        if not cand:
            parts.append(None)
        else:
            used.update(cand)
            parts.append(cand[0] if len(cand) == 1 else cand)
    return PartitionSpec(*parts)


def tree_specs(axes_tree: Any, rules: dict, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda axes: spec_for_axes(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(axes_tree: Any, rules: dict, mesh: Mesh, abstract: Any = None) -> Any:
    """NamedShardings for a logical-axes tree. With `abstract` (matching
    ShapeDtypeStruct tree), mesh axes that do not divide a dimension are
    dropped (pjit argument shardings require exact divisibility)."""
    specs = tree_specs(axes_tree, rules, mesh)
    if abstract is None:
        return jax.tree_util.tree_map(
            lambda spec: NamedSharding(mesh, spec),
            specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fit(spec: PartitionSpec, leaf) -> NamedSharding:
        parts = []
        for i, d in enumerate(leaf.shape):
            p = spec[i] if i < len(spec) else None
            if p is None:
                parts.append(None)
                continue
            names = (p,) if isinstance(p, str) else tuple(p)
            n = int(np.prod([sizes[a] for a in names]))
            parts.append(p if d % n == 0 else None)
        return NamedSharding(mesh, PartitionSpec(*parts))

    return jax.tree_util.tree_map(
        _fit, specs, abstract, is_leaf=lambda x: isinstance(x, PartitionSpec)
    )


def cache_sharding(
    cache_desc: Any,
    mesh: Mesh,
    batch: int,
    head_sizes: set[int] = frozenset(),
    seq_shard: bool = False,
) -> Any:
    """KV/state caches: shard the batch dim over (pod, data) and any
    head-bearing dim over model, identified by size matching.

    Finds the first dim equal to `batch` (sharded DP if divisible) and the
    first later dim whose size is in `head_sizes` and divisible by the model
    axis (sharded 'model'). Leading layer-stack dims stay replicated.

    seq_shard (§Perf variant): when no head dim can take the model axis
    (n_kv_heads < model size — e.g. phi4's 8 KV heads on a 16-way model
    axis), shard the *sequence* dim of the cache over 'model' instead, so
    the KV cache never replicates (GSPMD inserts the partial-softmax
    reductions). Cuts decode HBM residency by ~model_size/1.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_n = int(np.prod([sizes[a] for a in dp])) if dp else 1
    model_n = sizes.get("model", 1)
    dp_spec = dp[0] if len(dp) == 1 else dp

    def _spec(leaf):
        parts = [None] * len(leaf.shape)
        bdim = None
        for i, s in enumerate(leaf.shape):
            if s == batch and bdim is None:
                bdim = i
                if batch % dp_n == 0:
                    parts[i] = dp_spec
                break
        if bdim is not None:
            placed = False
            for j in range(bdim + 1, len(leaf.shape)):
                if leaf.shape[j] in head_sizes and leaf.shape[j] % model_n == 0:
                    parts[j] = "model"
                    placed = True
                    break
            if not placed and seq_shard:
                for j in range(bdim + 1, len(leaf.shape)):
                    if leaf.shape[j] >= 128 * model_n and leaf.shape[j] % model_n == 0:
                        parts[j] = "model"  # sequence dim
                        break
        return PartitionSpec(*parts)

    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, _spec(s)), cache_desc)


# ---------------------------------------------------------------------------
# Shard-layout introspection (DESIGN.md §6): the plumbing the shard-local
# compression engine (core/sharded.py) and the sharded checkpoint writer use
# to reason about WHERE a jax.Array's bytes physically live without ever
# gathering them.
# ---------------------------------------------------------------------------


def mesh_of(x: Any) -> Mesh | None:
    """The concrete Mesh behind `x`'s sharding, or None for host arrays /
    single-device / non-Named shardings (callers fall back to gathering)."""
    sharding = getattr(x, "sharding", None)
    if not isinstance(sharding, NamedSharding):
        return None
    mesh = sharding.mesh
    # AbstractMesh (jax >= 0.5 tracing contexts) has no devices to address
    return mesh if hasattr(mesh, "devices") else None


def spec_entries(x: Any) -> tuple:
    """`x`'s PartitionSpec padded with None to its rank (one entry per dim)."""
    spec = tuple(x.sharding.spec)
    return spec + (None,) * (np.ndim(x) - len(spec))


def unique_shards(x: Any) -> list[tuple[tuple[int, ...], tuple[int, ...], tuple]]:
    """[(start, stop, replica_devices)] — one entry per *unique* data shard
    of `x`, in row-major shard order, with the devices holding each replica
    ordered deterministically (by device id). Start/stop are global index
    bounds per dim. This is the authoritative data-placement map the
    sharded checkpoint writer iterates: fetching `addressable_shards` for
    exactly one device per entry touches every byte exactly once."""
    imap = x.sharding.devices_indices_map(np.shape(x))
    by_slice: dict[tuple, list] = {}
    for dev, idx in imap.items():
        key = tuple(
            (0 if sl.start is None else int(sl.start),
             int(np.shape(x)[d]) if sl.stop is None else int(sl.stop))
            for d, sl in enumerate(idx)
        )
        by_slice.setdefault(key, []).append(dev)
    out = []
    for key in sorted(by_slice):
        devs = tuple(sorted(by_slice[key], key=lambda d: d.id))
        start = tuple(k[0] for k in key)
        stop = tuple(k[1] for k in key)
        out.append((start, stop, devs))
    return out


def shard_data(x: Any, device) -> np.ndarray:
    """Host copy of `x`'s local shard on `device` (no cross-device gather)."""
    for sh in x.addressable_shards:
        if sh.device == device:
            return np.asarray(sh.data)
    raise ValueError(f"device {device} holds no addressable shard of this array")


@contextlib.contextmanager
def activate(mesh: Mesh, rules: dict):
    """Bind the activation-constraint hook used by nn.shard()."""

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _divisible(dim: int, part) -> bool:
        if part is None:
            return True
        names = (part,) if isinstance(part, str) else part
        n = int(np.prod([sizes[a] for a in names]))
        return dim % n == 0

    def shard_fn(x, axes):
        if len(axes) != x.ndim:
            return x
        spec = spec_for_axes(axes, rules, mesh)
        parts = [p if _divisible(d, p) else None for d, p in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec)))]
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*parts)))

    nn.set_shard_fn(shard_fn)
    try:
        # jax >= 0.5 spells the mesh context jax.set_mesh; on older jax the
        # Mesh object itself is the context manager
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            yield
    finally:
        nn.set_shard_fn(None)
