"""Multi-host runtime primitives (DESIGN.md §6.2).

Everything the multi-host checkpoint protocol needs from the jax
distributed runtime, behind one small surface so the rest of the repo
never touches `jax._src`:

* `initialize(...)` — one-call process bring-up: forces the emulated CPU
  device count into XLA_FLAGS *before* jax initializes, switches the CPU
  backend's cross-process collectives on (gloo — without it every
  multi-process computation fails with "Multiprocess computations aren't
  implemented on the CPU backend"), and runs
  `jax.distributed.initialize`. Used by the multi-process test workers
  (`tests/multihost/worker.py`), the `launch/shardckpt.py` dryrun, and
  the bench-gate parity smoke; a real pod launch calls it with its own
  coordinator address.
* `barrier(name, timeout_s)` — a *bounded* host barrier on the
  distributed KV service (not a device collective, so it is safe from a
  background writer thread). A straggler past the deadline raises
  `BarrierTimeout` on the waiting hosts instead of hanging the job —
  the §6.2 save protocol's liveness guarantee.
* `key_value_set/get` — the coordinator KV store, for small cross-host
  handshakes.
* `replicate(x)` / `to_numpy(x)` — fetch helpers for arrays that are NOT
  fully addressable from this process (a jitted identity with a
  fully-replicated out-sharding is a *computation*, which gloo supports,
  whereas a bare `np.asarray` on such an array raises). The shard-local
  engine uses them for layout-ineligible fields so the multi-host
  gather-fallback decisions stay bit-identical to the single-controller
  path.
* `put_global(value, sharding)` — build a (possibly multi-process)
  jax.Array from host data without `device_put`-ing to non-addressable
  devices (`jax.make_array_from_callback`): the elastic-restore path and
  the test workers' state synthesis.

Single-process behavior is the identity: barriers no-op, `to_numpy` is
`np.asarray`, `put_global` is `device_put` — so every call site runs
unchanged under the ordinary single-controller tests.
"""

from __future__ import annotations

import os
from functools import lru_cache
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class BarrierTimeout(RuntimeError):
    """A bounded barrier expired: some host is dead or straggling."""


def process_index() -> int:
    return int(jax.process_index())


def process_count() -> int:
    return int(jax.process_count())


def is_multihost() -> bool:
    return process_count() > 1


def client():
    """The distributed-coordination client, or None (single process)."""
    try:
        from jax._src import distributed

        return distributed.global_state.client
    except Exception:  # pragma: no cover - jax internals moved
        return None


def initialize(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    local_device_count: int | None = None,
    initialization_timeout: int = 60,
) -> None:
    """Bring this process into an N-process (emulated or real) jax job.

    Must run before jax touches the backend: `local_device_count` is
    forced via `--xla_force_host_platform_device_count` (the
    `tests/conftest.py` early-import trick, per process), and the CPU
    collectives implementation is switched to gloo so cross-process
    `psum`/`all_gather` — the §6.1 reconciliation — work on the CPU
    backend. On jax versions where the config knob is gone (newer
    releases default to a working implementation) the update is a no-op.
    """
    if local_device_count is not None:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={local_device_count}"
            ).strip()
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - knob absent/renamed on newer jax
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        initialization_timeout=initialization_timeout,
    )


def barrier(name: str, timeout_s: float) -> None:
    """Wait until every process reaches `name`, at most `timeout_s`.

    Runs on the coordinator KV service — safe off the main thread, no
    device collective. Raises `BarrierTimeout` when the deadline expires
    (straggler/dead host) so the caller FAILS the save instead of
    hanging; any other coordination error (e.g. the coordinator process
    died) re-raises as-is. Single-process: no-op."""
    if process_count() <= 1:
        return
    c = client()
    if c is None:  # pragma: no cover - defensive
        raise RuntimeError("multi-process job without a distributed client")
    try:
        c.wait_at_barrier(name, int(timeout_s * 1000))
    except Exception as e:  # jaxlib surfaces DEADLINE_EXCEEDED XlaRuntimeError
        msg = str(e)
        if "DEADLINE" in msg.upper() or "timed out" in msg.lower():
            raise BarrierTimeout(
                f"barrier {name!r} timed out after {timeout_s:g}s — a host "
                "is dead or straggling; failing the save instead of hanging"
            ) from e
        raise


def key_value_set(key: str, value: str) -> None:
    c = client()
    if c is None:
        raise RuntimeError("key_value_set needs an initialized distributed runtime")
    c.key_value_set(key, value)


def key_value_get(key: str, timeout_s: float) -> str:
    c = client()
    if c is None:
        raise RuntimeError("key_value_get needs an initialized distributed runtime")
    return c.blocking_key_value_get(key, int(timeout_s * 1000))


# ---------------------------------------------------------------------------
# Cross-process array fetch / placement
# ---------------------------------------------------------------------------


def spans_processes(mesh: Mesh) -> bool:
    """True when `mesh` holds devices of more than one process."""
    procs = {getattr(d, "process_index", 0) for d in mesh.devices.flat}
    return len(procs) > 1


@lru_cache(maxsize=32)
def _replicate_fn(mesh: Mesh):
    out = NamedSharding(mesh, PartitionSpec())
    return jax.jit(lambda x: x, out_shardings=out)


def replicate(x: jax.Array) -> jax.Array:
    """`x` resharded fully-replicated on its own mesh (a computation, so
    it works across processes under gloo where plain device_put cannot)."""
    sharding = getattr(x, "sharding", None)
    mesh = getattr(sharding, "mesh", None)
    if mesh is None or not hasattr(mesh, "devices"):
        raise ValueError("replicate() needs a NamedSharding-backed jax.Array")
    return _replicate_fn(mesh)(x)


_device_copy_fn = None


def device_copy(x: jax.Array) -> jax.Array:
    """Sharding-preserving device-side copy (a jitted `jnp.copy`, so the
    output buffer is distinct from the input's — XLA never aliases without
    donation). The async-save snapshot: works across processes because the
    copy is a computation, not a host transfer."""
    global _device_copy_fn
    if _device_copy_fn is None:
        import jax.numpy as jnp

        _device_copy_fn = jax.jit(lambda v: jnp.copy(v))
    return _device_copy_fn(x)


def to_numpy(x: Any) -> np.ndarray:
    """Host copy of any leaf, including jax.Arrays this process cannot
    fully address (replicated via `replicate` first). The multi-host
    spelling of `np.asarray` — every process gets the identical value."""
    if isinstance(x, jax.Array) and not (
        x.is_fully_addressable or x.is_fully_replicated
    ):
        x = replicate(x)
    return np.asarray(x)


def put_global(value: np.ndarray, sharding: Any) -> jax.Array:
    """Place host `value` (identical on every process) under `sharding`,
    even when the sharding spans processes: each process contributes only
    its addressable shards (`make_array_from_callback`), so nothing is
    ever sent to a non-addressable device."""
    mesh = getattr(sharding, "mesh", None)
    if (
        isinstance(sharding, NamedSharding)
        and mesh is not None
        and hasattr(mesh, "devices")
        and spans_processes(mesh)
    ):
        value = np.asarray(value)

        def _shard(idx):
            part = np.asarray(value[idx])
            # ascontiguousarray promotes 0-d to (1,), which the runtime rejects
            return np.ascontiguousarray(part) if part.ndim else part

        return jax.make_array_from_callback(value.shape, sharding, _shard)
    return jax.device_put(value, sharding)


def owner_host(devices: tuple) -> int:
    """The process that WRITES a replicated shard: the one holding the
    lowest-id replica (`runtime/sharding.unique_shards` orders device
    groups by id, so every host derives the same owner without talking)."""
    return int(getattr(devices[0], "process_index", 0))


__all__ = [
    "BarrierTimeout",
    "barrier",
    "client",
    "device_copy",
    "initialize",
    "is_multihost",
    "key_value_get",
    "key_value_set",
    "owner_host",
    "process_count",
    "process_index",
    "put_global",
    "replicate",
    "spans_processes",
    "to_numpy",
]
