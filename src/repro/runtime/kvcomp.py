"""KV-cache / activation compression helpers (DESIGN.md §2 third row, §7,
§9).

In-graph compressors for activation-resident tensors, all direct
applications of the paper's Stage II:

* `quantize_kv` / `dequantize_kv` — per-(token, head) linear quantization to
  int8 (SZ's static vector quantization; also wired into apply_attn via
  ModelConfig.kv_quant).
* `bot_compress_kv` — the ZFP-style fused BOT+truncate surrogate from the
  Pallas kernel, for host-offloaded KV pages: returns the reconstruction and
  exact bits/block so the runtime can decide page-out format online
  (Algorithm-1-style, per page). The page's quality contract is the same
  `Policy` object as everywhere else (DESIGN.md §2): a
  `Policy.fixed_accuracy(...)` bound, or `Policy.fixed_ratio(x)` to give
  the page a byte budget — an in-graph octave grid of candidate bounds is
  scored by the sampled ZFP estimator (DESIGN.md §5) and the tightest
  bound whose estimated rate meets the budget is used — the quality-target
  controller's inversion (DESIGN.md §7) specialised to a static grid so it
  never leaves the accelerator, with no trial compressions: one fused
  kernel pass at the chosen bound. The legacy `eb_rel=`/`target_ratio=`
  kwargs shim onto the equivalent Policy with a `DeprecationWarning`.
* `compress_page` / `decompress_page` — the page-granular evict/restore
  entry points of the serving tier (DESIGN.md §9): a `CompressedPage`
  carries exact bytes under `Policy.raw()` (evict/restore round-trips are
  bit-identical) or the BOT reconstruction plus exact bit accounting under
  a lossy policy. Fixed-ratio bound solving is bookkept through a
  `DecisionCache` (DESIGN.md §8.2): pages freeze once decode moves past
  them, so a re-evicted page's content digest matches and the solved
  bound is replayed without re-scoring the candidate grid.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as est
from repro.core.policy import Policy
from repro.core.selector import Selection

#: in-graph candidate bounds for the ratio-budget path: VR * 2^-j. The
#: octave spacing matches the ZFP bit-plane staircase (rate moves ~1
#: bit/value per octave), so a finer grid would not land meaningfully
#: closer; 2^-20 .. 2^-1 spans lossless-ish to 1-plane quality.
_RATIO_GRID_OCTAVES = range(20, 0, -1)


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., Dh) -> (int8 codes, f32 scales broadcastable on the last dim)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _budget_eb(page: jax.Array, vr: jax.Array, target_ratio: float) -> jax.Array:
    """Smallest candidate bound whose *estimated* ZFP rate meets the byte
    budget (jit-safe; DESIGN.md §7). Estimated on r_sp-sampled blocks with
    the same closed-form `block_bits` accounting the fused kernel reports
    (`estimate_zfp_many(mode='model')`) — scoring with a different bit
    counter than the one the caller compares against the budget would
    systematically miss it. One vmapped pass over the grid costs
    ~r_sp * n_candidates of a full pass. Falls back to the loosest
    candidate when even that misses the budget (the caller's bits output
    still reports the truth). The grid solve is jitted per (shape,
    target) so the serving tier's per-evict calls don't re-trace the
    vmapped estimator (eager tracing dominates small-page evict cost)."""
    return _budget_eb_jit(float(target_ratio))(page, vr)


@functools.lru_cache(maxsize=None)
def _budget_eb_jit(target_ratio: float):
    br_budget = 32.0 / target_ratio

    @jax.jit
    def solve(page, vr):
        starts = est.block_starts(page.shape, est.DEFAULT_SAMPLING_RATE)
        blocks = est.gather_blocks(page, starts, halo=False)
        seg = jnp.zeros(len(starts), jnp.int32)
        bounds = jnp.asarray([0, len(starts)], jnp.int32)
        ebs = vr * jnp.asarray(
            [2.0**-j for j in _RATIO_GRID_OCTAVES], jnp.float32
        )

        def rate(eb):
            e = est.estimate_zfp_many(
                blocks, seg, bounds, eb[None], vr[None], mode="model"
            )
            return e.bitrate[0]

        rates = jax.vmap(rate)(ebs)  # nonincreasing along the grid
        ok = rates <= br_budget
        idx = jnp.argmax(ok)  # first (tightest) candidate meeting the budget
        return jnp.where(jnp.any(ok), ebs[idx], ebs[-1])

    return solve


#: the historical page default: a 1e-2 value-range-relative bound
DEFAULT_KV_POLICY = Policy.fixed_accuracy(eb_rel=1e-2)


def bot_compress_kv(
    page: jax.Array,
    policy: Policy | None = None,
    *,
    eb_rel: float | None = None,
    target_ratio: float | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ZFP-path compression of a 2-D or 3-D KV page: (tokens, heads*dh)
    flat pages, or (pages, page_tokens, heads*dh) paged-attention stacks —
    the latter ride the 4x4x4 kernel tier (DESIGN.md §3.5), which exploits
    cross-page correlation of adjacent pages instead of flattening it away.

    `policy` is the page's quality contract (static at trace time, so the
    whole call stays jit-safe): `Policy.fixed_accuracy(eb_rel=...)` — a
    hard `eb_rel * value_range` bound (default: eb_rel 1e-2) or an
    absolute `eb_abs` — or `Policy.fixed_ratio(x)`, which solves the
    bound in-graph from the page's byte budget (see module docstring).
    The legacy `eb_rel=` / `target_ratio=` kwargs shim onto the
    equivalent Policy with a `DeprecationWarning`.

    Returns (reconstruction, bits-per-block) from the fused Pallas kernel;
    callers compare sum(bits) against 8*page.nbytes to pick a page format.
    """
    from repro.kernels import ops

    if isinstance(policy, (int, float)):  # old positional `eb_rel`
        if eb_rel is not None:
            raise ValueError("bot_compress_kv: eb_rel given twice")
        policy, eb_rel = None, float(policy)
    if policy is None:
        if eb_rel is not None or target_ratio is not None:
            if target_ratio is not None:
                policy = Policy.fixed_ratio(target_ratio)
            else:
                policy = Policy.fixed_accuracy(eb_rel=eb_rel)
            warnings.warn(
                "bot_compress_kv(eb_rel=/target_ratio=) is deprecated; pass "
                f"policy=Policy.{policy.mode}(...) (repro.core.policy)",
                DeprecationWarning,
                stacklevel=2,
            )
        else:
            policy = DEFAULT_KV_POLICY
    elif eb_rel is not None or target_ratio is not None:
        raise ValueError("pass either policy= or the legacy kwargs, not both")
    page32 = page.astype(jnp.float32)
    vr = jnp.maximum(jnp.max(page32) - jnp.min(page32), 1e-12)
    eb = _policy_eb(page32, vr, policy)
    recon, bits = ops.bot_fused(page32, eb)
    return recon.astype(page.dtype), bits


def _policy_eb(page32: jax.Array, vr: jax.Array, policy: Policy) -> jax.Array:
    """The page's error bound under `policy` (jit-safe; shared by
    `bot_compress_kv` and the serving tier's `compress_page`)."""
    if policy.mode == "fixed_ratio":
        return _budget_eb(page32, vr, policy.target_ratio)
    if policy.mode == "fixed_accuracy":
        if policy.eb_abs is not None:
            return jnp.asarray(policy.eb_abs, jnp.float32)
        return policy.eb_rel * vr
    raise ValueError(
        f"KV page compression supports fixed_accuracy/fixed_ratio policies, "
        f"got {policy.mode!r} (fixed_psnr needs the host-side controller)"
    )


# ---------------------------------------------------------------------------
# Page-granular evict/restore entry points (serving tier, DESIGN.md §9)
# ---------------------------------------------------------------------------

#: transform key the serving tier's DecisionCache entries are stored under
PAGE_TRANSFORM = "kv_page"
_PAGE_FP_TAG = b"repro.kvpage.v1:"


@dataclasses.dataclass
class CompressedPage:
    """One evicted KV page (or cross-layer page stack) at rest.

    ``codec == 'raw'``: `payload` holds the exact page bytes — restore is
    bit-identical by construction (the `serving_page_parity` gate's
    contract). ``codec == 'zfp'``: the device-resident encode tier
    (DESIGN.md §3.7) packed the page in-graph and `payload` holds real
    ZFJX container bytes — `nbytes == len(payload)` is the literal
    resident footprint. ``codec == 'bot'``: `payload` holds the
    fused-kernel reconstruction in the page dtype; `nbytes` is the exact
    `ceil(sum(bits)/8)` accounting the kernel reports — what the
    bitpacked store holds on the 'zfp' path, and what the serving
    benchmark charges as resident bytes.
    """

    codec: str                     # "raw" | "zfp" | "bot"
    payload: bytes | np.ndarray
    shape: tuple[int, ...]
    dtype: str
    nbytes: int                    # honest resident-byte accounting
    eb: float = 0.0                # solved bound (0.0 for raw)
    clean: bool = False            # content still bit-equal to the arena copy


def _page_fingerprint(page: np.ndarray, vr: float, policy: Policy) -> dict:
    """Content digest over the full preimage of the page decision: the page
    bytes plus (vr, shape) and the policy already in the cache key — the
    `DecisionCache` fingerprint contract (DESIGN.md §8.2) applied to a KV
    page. Pages freeze once decode moves past them, so the digest of a
    re-evicted frozen page matches and the solved bound replays."""
    h = hashlib.blake2b(digest_size=16)
    h.update(_PAGE_FP_TAG)
    h.update(np.asarray(page.shape, np.int64).tobytes())
    h.update(np.asarray([vr, policy.target_ratio or 0.0], np.float64).tobytes())
    h.update(np.ascontiguousarray(page).tobytes())
    return {"kind": PAGE_TRANSFORM, "digest": h.hexdigest()}


def compress_page(
    page,
    policy: Policy,
    *,
    cache=None,
    name: str | None = None,
    device_encode: bool = False,
) -> CompressedPage:
    """Compress one KV page (2-D) or cross-layer page stack (3-D, riding
    the 4x4x4 kernel tier) for eviction from the serving arena
    (DESIGN.md §9).

    `Policy.raw()` stores the exact bytes — the short-request default of
    the serving PolicySet, and the mode the parity gate round-trips.
    Lossy policies solve the bound with `_policy_eb` (the same in-graph
    grid/bound path as `bot_compress_kv`) and store the reconstruction
    plus exact bit accounting.

    `cache` is an optional `DecisionCache` (with `name`): the solved bound
    is stored under ``(name, shape, dtype, policy, 'kv_page')`` guarded by
    a content digest, so re-evicting an unchanged page replays the bound
    without re-scoring the fixed-ratio candidate grid — the warm-path
    discipline of DESIGN.md §8 on the serving path.

    `device_encode` routes lossy pages through the device-resident ZFP
    encoder (DESIGN.md §3.7): the page is bit-packed in-graph and the
    evicted payload is real ZFJX container bytes instead of a
    reconstruction array — the resident footprint becomes literal. Pages
    the device tier declines (§3.7 fallback rules, or streams that fail
    to beat raw) take the existing 'bot' path unchanged.
    """
    arr = np.asarray(page)
    if policy.mode == "raw":
        return CompressedPage(
            codec="raw", payload=arr.tobytes(), shape=arr.shape,
            dtype=str(arr.dtype), nbytes=arr.nbytes, clean=True,
        )
    page32 = jnp.asarray(arr, jnp.float32)
    vr = jnp.maximum(jnp.max(page32) - jnp.min(page32), 1e-12)
    eb = None
    fp = None
    if cache is not None:
        if name is None:
            raise ValueError("compress_page: cache= needs name=")
        fp = _page_fingerprint(arr, float(vr), policy)
        hit = cache.lookup(name, arr.shape, str(arr.dtype), policy,
                           PAGE_TRANSFORM, fp)
        if hit is not None:
            eb = jnp.asarray(hit.selection["eb_abs"], jnp.float32)
    if eb is None:
        eb = _policy_eb(page32, vr, policy)
    if device_encode:
        from repro.core import device_encode as _de

        payload = _de.zfp_encode_device(page32, float(eb))
        if payload is not None and len(payload) < arr.nbytes:
            if cache is not None and cache.events.get(name) != "hit":
                cache.store(
                    name, arr.shape, str(arr.dtype), policy, PAGE_TRANSFORM,
                    fp,
                    Selection(codec="zfp", eb_abs=float(eb), eb_sz=0.0,
                              br_sz=0.0,
                              br_zfp=8.0 * len(payload) / max(arr.size, 1),
                              psnr_target=0.0, vr=float(vr),
                              r_sp=policy.r_sp),
                )
            return CompressedPage(
                codec="zfp", payload=payload, shape=arr.shape,
                dtype=str(arr.dtype), nbytes=len(payload),
                eb=float(eb), clean=False,
            )
    from repro.kernels import ops

    recon, bits = ops.bot_fused(page32, eb)
    total_bits = float(jnp.sum(bits))
    if cache is not None and cache.events.get(name) != "hit":
        cache.store(
            name, arr.shape, str(arr.dtype), policy, PAGE_TRANSFORM, fp,
            Selection(codec="zfp", eb_abs=float(eb), eb_sz=0.0, br_sz=0.0,
                      br_zfp=total_bits / max(arr.size, 1),
                      psnr_target=0.0, vr=float(vr), r_sp=policy.r_sp),
        )
    return CompressedPage(
        codec="bot",
        payload=np.asarray(recon.astype(arr.dtype)),
        shape=arr.shape, dtype=str(arr.dtype),
        nbytes=-(-int(total_bits) // 8), eb=float(eb), clean=False,
    )


def decompress_page(cp: CompressedPage) -> np.ndarray:
    """Restore an evicted page into arena form (DESIGN.md §9). Raw pages
    reconstruct the exact bytes; device-packed 'zfp' pages decode their
    ZFJX stream through the host decoder; BOT pages return the
    bounded-error reconstruction the kernel produced at evict time."""
    if cp.codec == "raw":
        buf = bytearray(cp.payload)  # writeable, like decompress_pytree
        return np.frombuffer(buf, dtype=np.dtype(cp.dtype)).reshape(cp.shape)
    if cp.codec == "zfp":
        from repro.core.zfp import zfp_decompress

        rec = zfp_decompress(bytes(cp.payload))
        return rec.reshape(cp.shape).astype(np.dtype(cp.dtype))
    if cp.codec == "bot":
        return np.asarray(cp.payload)
    raise ValueError(f"unknown page codec {cp.codec!r}")
