"""KV-cache / activation compression helpers (DESIGN.md §2, third row).

Two in-graph compressors for activation-resident tensors, both direct
applications of the paper's Stage II:

* `quantize_kv` / `dequantize_kv` — per-(token, head) linear quantization to
  int8 (SZ's static vector quantization; also wired into apply_attn via
  ModelConfig.kv_quant).
* `bot_compress_kv` — the ZFP-style fused BOT+truncate surrogate from the
  Pallas kernel, for host-offloaded KV pages: returns the reconstruction and
  exact bits/block so the runtime can decide page-out format online
  (Algorithm-1-style, per page).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., Dh) -> (int8 codes, f32 scales broadcastable on the last dim)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def bot_compress_kv(page: jax.Array, eb_rel: float = 1e-2) -> tuple[jax.Array, jax.Array]:
    """ZFP-path compression of a 2-D KV page (e.g. (tokens, heads*dh)).

    Returns (reconstruction, bits-per-block) from the fused Pallas kernel;
    callers compare sum(bits) against 8*page.nbytes to pick a page format.
    """
    from repro.kernels import ops

    vr = jnp.maximum(jnp.max(page) - jnp.min(page), 1e-12)
    recon, bits = ops.bot_fused(page.astype(jnp.float32), eb_rel * vr)
    return recon.astype(page.dtype), bits
