"""KV-cache / activation compression helpers (DESIGN.md §2 third row, §7).

Two in-graph compressors for activation-resident tensors, both direct
applications of the paper's Stage II:

* `quantize_kv` / `dequantize_kv` — per-(token, head) linear quantization to
  int8 (SZ's static vector quantization; also wired into apply_attn via
  ModelConfig.kv_quant).
* `bot_compress_kv` — the ZFP-style fused BOT+truncate surrogate from the
  Pallas kernel, for host-offloaded KV pages: returns the reconstruction and
  exact bits/block so the runtime can decide page-out format online
  (Algorithm-1-style, per page). The page's quality contract is the same
  `Policy` object as everywhere else (DESIGN.md §2): a
  `Policy.fixed_accuracy(...)` bound, or `Policy.fixed_ratio(x)` to give
  the page a byte budget — an in-graph octave grid of candidate bounds is
  scored by the sampled ZFP estimator (DESIGN.md §5) and the tightest
  bound whose estimated rate meets the budget is used — the quality-target
  controller's inversion (DESIGN.md §7) specialised to a static grid so it
  never leaves the accelerator, with no trial compressions: one fused
  kernel pass at the chosen bound. The legacy `eb_rel=`/`target_ratio=`
  kwargs shim onto the equivalent Policy with a `DeprecationWarning`.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core import estimator as est
from repro.core.policy import Policy

#: in-graph candidate bounds for the ratio-budget path: VR * 2^-j. The
#: octave spacing matches the ZFP bit-plane staircase (rate moves ~1
#: bit/value per octave), so a finer grid would not land meaningfully
#: closer; 2^-20 .. 2^-1 spans lossless-ish to 1-plane quality.
_RATIO_GRID_OCTAVES = range(20, 0, -1)


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., Dh) -> (int8 codes, f32 scales broadcastable on the last dim)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _budget_eb(page: jax.Array, vr: jax.Array, target_ratio: float) -> jax.Array:
    """Smallest candidate bound whose *estimated* ZFP rate meets the byte
    budget (jit-safe; DESIGN.md §7). Estimated on r_sp-sampled blocks with
    the same closed-form `block_bits` accounting the fused kernel reports
    (`estimate_zfp_many(mode='model')`) — scoring with a different bit
    counter than the one the caller compares against the budget would
    systematically miss it. One vmapped pass over the grid costs
    ~r_sp * n_candidates of a full pass. Falls back to the loosest
    candidate when even that misses the budget (the caller's bits output
    still reports the truth)."""
    br_budget = 32.0 / float(target_ratio)
    starts = est.block_starts(page.shape, est.DEFAULT_SAMPLING_RATE)
    blocks = est.gather_blocks(page, starts, halo=False)
    seg = jnp.zeros(len(starts), jnp.int32)
    bounds = jnp.asarray([0, len(starts)], jnp.int32)
    ebs = vr * jnp.asarray([2.0**-j for j in _RATIO_GRID_OCTAVES], jnp.float32)

    def rate(eb):
        e = est.estimate_zfp_many(
            blocks, seg, bounds, eb[None], vr[None], mode="model"
        )
        return e.bitrate[0]

    rates = jax.vmap(rate)(ebs)  # nonincreasing along the grid
    ok = rates <= br_budget
    idx = jnp.argmax(ok)  # first (tightest) candidate meeting the budget
    return jnp.where(jnp.any(ok), ebs[idx], ebs[-1])


#: the historical page default: a 1e-2 value-range-relative bound
DEFAULT_KV_POLICY = Policy.fixed_accuracy(eb_rel=1e-2)


def bot_compress_kv(
    page: jax.Array,
    policy: Policy | None = None,
    *,
    eb_rel: float | None = None,
    target_ratio: float | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ZFP-path compression of a 2-D or 3-D KV page: (tokens, heads*dh)
    flat pages, or (pages, page_tokens, heads*dh) paged-attention stacks —
    the latter ride the 4x4x4 kernel tier (DESIGN.md §3.5), which exploits
    cross-page correlation of adjacent pages instead of flattening it away.

    `policy` is the page's quality contract (static at trace time, so the
    whole call stays jit-safe): `Policy.fixed_accuracy(eb_rel=...)` — a
    hard `eb_rel * value_range` bound (default: eb_rel 1e-2) or an
    absolute `eb_abs` — or `Policy.fixed_ratio(x)`, which solves the
    bound in-graph from the page's byte budget (see module docstring).
    The legacy `eb_rel=` / `target_ratio=` kwargs shim onto the
    equivalent Policy with a `DeprecationWarning`.

    Returns (reconstruction, bits-per-block) from the fused Pallas kernel;
    callers compare sum(bits) against 8*page.nbytes to pick a page format.
    """
    from repro.kernels import ops

    if isinstance(policy, (int, float)):  # old positional `eb_rel`
        if eb_rel is not None:
            raise ValueError("bot_compress_kv: eb_rel given twice")
        policy, eb_rel = None, float(policy)
    if policy is None:
        if eb_rel is not None or target_ratio is not None:
            if target_ratio is not None:
                policy = Policy.fixed_ratio(target_ratio)
            else:
                policy = Policy.fixed_accuracy(eb_rel=eb_rel)
            warnings.warn(
                "bot_compress_kv(eb_rel=/target_ratio=) is deprecated; pass "
                f"policy=Policy.{policy.mode}(...) (repro.core.policy)",
                DeprecationWarning,
                stacklevel=2,
            )
        else:
            policy = DEFAULT_KV_POLICY
    elif eb_rel is not None or target_ratio is not None:
        raise ValueError("pass either policy= or the legacy kwargs, not both")
    page32 = page.astype(jnp.float32)
    vr = jnp.maximum(jnp.max(page32) - jnp.min(page32), 1e-12)
    if policy.mode == "fixed_ratio":
        eb = _budget_eb(page32, vr, policy.target_ratio)
    elif policy.mode == "fixed_accuracy":
        eb = policy.eb_abs if policy.eb_abs is not None else policy.eb_rel * vr
    else:
        raise ValueError(
            f"bot_compress_kv supports fixed_accuracy/fixed_ratio policies, "
            f"got {policy.mode!r} (fixed_psnr needs the host-side controller)"
        )
    recon, bits = ops.bot_fused(page32, eb)
    return recon.astype(page.dtype), bits
