from . import dist, sharding
