from . import sharding
