"""Continuous-batching serving scheduler with a compression-aware paged KV
pool (DESIGN.md §9).

Two cache layouts behind one scheduler:

* **Paged** (the serving tier, default when the model supports it): each
  slot owns a page table over a shared per-layer page arena, and the
  position clock is a per-slot vector — so requests at different depths
  decode in one batch and admission happens mid-wave the moment a slot
  frees. Page pressure preempts the youngest-admitted request (LIFO, so
  the oldest always progresses); its pages are compressed on evict
  (`kvcomp.compress_page`) under the request's `Policy` — resolved once
  at admission from a `PolicySet` via `request_kv_name`, long-context
  requests taking `fixed_ratio` byte budgets while short ones stay raw —
  and decompressed back into freshly allocated pages on resume. Pages
  freeze once decode moves past them, so re-evicting an unchanged page
  reuses its `CompressedPage` (and, through the `DecisionCache`
  fingerprints, replays the solved bound instead of re-scoring the grid).

* **Legacy contiguous** (`paged=False`): the fixed `slots x max_len`
  cache with a shared scalar clock — new requests join at clock zero
  only; kept for model families without paged support (MLA, int8 KV).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import Policy, PolicySet, as_policy_set, request_kv_name
from repro.runtime import kvcomp


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # serving-tier state (paged pool, DESIGN.md §9)
    policy: Any = None  # quality contract, resolved once at admission
    pname: str = ""  # canonical policy leaf name (request_kv_name)
    resume_len: int = 0  # context tokens held compressed after preemption
    page_comp: dict = dataclasses.field(default_factory=dict)
    evictions: int = 0


class ContinuousBatcher:
    """Slot-based continuous batching over a shared decode step.

    Paged mode: the model's cache is `slots` per-slot clocks + page tables
    over `arena_pages` shared pages of `page_tokens` tokens per layer
    (page 0 is reserved scratch for dead slots). Prefill runs batch-1
    against a contiguous sub-cache and is spliced into the slot's pages.

    `policies` (a `Policy` or `PolicySet`) is resolved per request at
    admission under the name `request_kv_name(rid, prompt+max_new,
    long_threshold)`; the resolved policy drives compress-on-evict.
    `decisions` is an optional `DecisionCache` for warm-path bound replay
    on re-evicted frozen pages (DESIGN.md §8.2).
    """

    def __init__(
        self,
        model,
        params,
        slots: int,
        max_len: int,
        eos_id: int = 0,
        *,
        paged: bool | None = None,
        page_tokens: int = 16,
        arena_pages: int | None = None,
        policies: Policy | PolicySet | None = None,
        long_threshold: int = 256,
        decisions=None,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        if paged is None:
            cfg = getattr(model, "cfg", None)
            paged = (
                hasattr(model, "paged_cache_desc")
                and cfg is not None
                and getattr(cfg, "mla", None) is None
                and not getattr(cfg, "kv_quant", False)
            )
        self.paged = bool(paged)
        self.live = np.zeros(slots, dtype=bool)
        self.requests: dict[int, Request] = {}
        self.slot_req = [-1] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.preempted: list[Request] = []
        self.stats = {"evictions": 0, "restores": 0, "page_reuses": 0}
        if self.paged:
            self.page_tokens = int(page_tokens)
            self.max_pages = -(-max_len // self.page_tokens)
            self.arena_pages = int(arena_pages or slots * self.max_pages)
            if self.arena_pages < self.max_pages:
                raise ValueError(
                    f"arena_pages={self.arena_pages} < max_pages="
                    f"{self.max_pages}: one max-length request must always fit"
                )
            self.cache = model.init_paged_cache(
                slots, self.arena_pages, self.page_tokens, self.max_pages
            )
            # allocator hands out ids 1..arena_pages (0 = scratch), low first
            self.free_pages = list(range(self.arena_pages, 0, -1))
            self.slot_pages: list[list[int]] = [[] for _ in range(slots)]
            self.slot_len = np.zeros(slots, np.int32)
            self.ptab_host = np.zeros((slots, self.max_pages), np.int32)
            self.admit_seq = np.zeros(slots, np.int64)
            self._seq = 0
            self.policies = as_policy_set(
                policies if policies is not None else Policy.raw()
            )
            self.long_threshold = int(long_threshold)
            self.decisions = decisions
        else:
            if policies is not None or decisions is not None:
                raise ValueError(
                    "policies=/decisions= need the paged KV pool (paged=True)"
                )
            self.cache = model.init_cache(slots, max_len)
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, params, tokens, cache):
        logits, cache = self.model.forward(params, {"tokens": tokens}, cache=cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    # -- paged arena plumbing ------------------------------------------------

    def _page_keys(self):
        """(short key, cache path) per arena tensor whose pages evict."""
        keys = [("k", ("blocks", "k")), ("v", ("blocks", "v"))]
        if "dense_blocks" in self.cache:
            keys += [("dk", ("dense_blocks", "k")), ("dv", ("dense_blocks", "v"))]
        return keys

    @staticmethod
    def _get(tree, path):
        for k in path:
            tree = tree[k]
        return tree

    def _set(self, path, val):
        node = self.cache
        for k in path[:-1]:
            node = node[k]
        node[path[-1]] = val

    def _prefill(self, prompt: np.ndarray):
        """Batch-1 contiguous prefill; returns (first token, sub-cache)."""
        L = len(prompt)
        if self.paged:
            sub_len = -(-L // self.page_tokens) * self.page_tokens
        else:
            sub_len = self.max_len
        sub = self.model.init_cache(1, sub_len)
        logits, sub = self.model.forward(
            self.params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache=sub
        )
        return int(jnp.argmax(logits[0, -1])), sub

    def _splice_prefill(self, sub, pids: list[int]) -> None:
        """Scatter a contiguous batch-1 prefill cache into arena pages."""
        pt = self.page_tokens
        npg = len(pids)
        idx = jnp.asarray(pids, jnp.int32)
        for _, path in self._page_keys():
            arena = self._get(self.cache, path)
            src = self._get(sub, path)  # (nl, 1, npg*pt, hkv, dh)
            nl = src.shape[0]
            s = src[:, 0].reshape((nl, npg, pt) + src.shape[3:])
            self._set(path, arena.at[:, idx].set(s.astype(arena.dtype)))

    def _free_slot_pages(self, slot: int) -> None:
        self.free_pages.extend(reversed(self.slot_pages[slot]))
        self.slot_pages[slot] = []
        self.ptab_host[slot, :] = 0
        self.slot_len[slot] = 0

    # -- compress-on-evict / decompress-on-hit (DESIGN.md §9) ----------------

    def _evict(self, slot: int) -> None:
        """Preempt the request in `slot`: compress its pages, free them."""
        rid = self.slot_req[slot]
        req = self.requests[rid]
        pt = self.page_tokens
        lens = int(self.slot_len[slot])
        nstore = -(-lens // pt)
        for key, path in self._page_keys():
            arena = self._get(self.cache, path)
            for p in range(nstore):
                cp = req.page_comp.get((key, p))
                if cp is not None and cp.clean and (p + 1) * pt <= lens:
                    # frozen since restore: its compressed form still holds
                    self.stats["page_reuses"] += 1
                    continue
                pid = self.slot_pages[slot][p]
                page = np.asarray(arena[:, pid])  # (nl, pt, hkv, dh)
                page = page.reshape(page.shape[0], pt, -1)  # 3-D: 4x4x4 tier
                req.page_comp[(key, p)] = kvcomp.compress_page(
                    page,
                    req.policy,
                    cache=self.decisions,
                    name=f"{req.pname}/{key}{p}",
                )
        req.resume_len = lens
        req.evictions += 1
        self._free_slot_pages(slot)
        self.live[slot] = False
        self.slot_req[slot] = -1
        self.preempted.append(req)
        self.stats["evictions"] += 1

    def _preempt_one(self, exclude: tuple[int, ...] = ()) -> bool:
        """Evict the youngest-admitted live slot (LIFO keeps the oldest
        request progressing, which bounds restart churn)."""
        cands = [
            s for s in range(self.slots) if self.live[s] and s not in exclude
        ]
        if not cands:
            return False
        self._evict(max(cands, key=lambda s: int(self.admit_seq[s])))
        return True

    def _resume(self, req: Request, slot: int) -> bool:
        """Decompress a preempted request's pages into fresh arena pages."""
        pt = self.page_tokens
        lens = req.resume_len
        need = lens // pt + 1
        if len(self.free_pages) < need:
            return False
        pids = [self.free_pages.pop() for _ in range(need)]
        nstore = -(-lens // pt)
        for key, path in self._page_keys():
            arena = self._get(self.cache, path)
            for p in range(nstore):
                cp = req.page_comp[(key, p)]
                page = kvcomp.decompress_page(cp)
                page = jnp.asarray(
                    page.reshape((arena.shape[0], pt) + arena.shape[3:])
                ).astype(arena.dtype)
                arena = arena.at[:, pids[p]].set(page)
            self._set(path, arena)
        # arena now equals the store: frozen pages are reusable at the next
        # evict; the partial tail page will be rewritten, so drop it
        for k in list(req.page_comp):
            if (k[1] + 1) * pt <= lens:
                req.page_comp[k].clean = True
            else:
                del req.page_comp[k]
        req.resume_len = 0
        self._bind(req, slot, pids, lens, int(req.out[-1]))
        self.stats["restores"] += 1
        return True

    def _bind(self, req, slot, pids, lens, next_tok):
        self.slot_pages[slot] = pids
        self.ptab_host[slot, :] = 0
        self.ptab_host[slot, : len(pids)] = pids
        self.slot_len[slot] = lens
        self.tokens = self.tokens.at[slot, 0].set(next_tok)
        self.live[slot] = True
        self.slot_req[slot] = req.rid
        self.admit_seq[slot] = self._seq
        self._seq += 1
        self.requests[req.rid] = req

    # -- admission ----------------------------------------------------------

    def try_admit(self, req: Request) -> bool:
        """Admit into a free slot (or resume a preempted request). With the
        paged pool, per-slot clocks make admission legal mid-wave; the
        legacy contiguous cache shares one scalar clock, so new requests
        join at clock zero only."""
        if self.paged:
            return self._admit_paged(req)
        return self._admit_legacy(req)

    def _admit_paged(self, req: Request) -> bool:
        free = [i for i in range(self.slots) if not self.live[i]]
        if not free:
            return False
        if req.resume_len:
            return self._resume(req, free[0])
        pt = self.page_tokens
        L = int(len(req.prompt))
        need = L // pt + 1
        if need > self.max_pages:
            raise ValueError(
                f"prompt of {L} tokens needs {need} pages > max_pages="
                f"{self.max_pages} (max_len={self.max_len})"
            )
        if req.max_new > 1 and len(self.free_pages) < need:
            return False
        # resolve the quality contract once; jit-static for the lifetime
        req.pname = request_kv_name(req.rid, L + req.max_new, self.long_threshold)
        req.policy = self.policies.resolve(req.pname)
        nxt, sub = self._prefill(req.prompt)
        req.out.append(nxt)
        self.requests[req.rid] = req
        if nxt == self.eos_id or req.max_new <= 1:
            # EOS (or a 1-token budget) at prefill terminates at admission —
            # no decode slot, no pages
            req.done = True
            return True
        pids = [self.free_pages.pop() for _ in range(need)]
        self._splice_prefill(sub, pids[: -(-L // pt)])
        self._bind(req, free[0], pids, L, nxt)
        return True

    def _admit_legacy(self, req: Request) -> bool:
        free = [i for i in range(self.slots) if not self.live[i]]
        if not free:
            return False
        if self.live.any() and int(self.cache["pos"]) > 0:
            return False  # mid-wave admission needs per-slot clocks (paged)
        nxt, sub_cache = self._prefill(req.prompt)
        req.out.append(nxt)
        self.requests[req.rid] = req
        if nxt == self.eos_id or req.max_new <= 1:
            req.done = True
            return True
        if not self.live.any() and int(self.cache["pos"]) > 0:
            self.cache = self.model.init_cache(self.slots, self.max_len)  # reset
        slot = free[0]

        # splice slot-0 of sub_cache into our slot (batch dim = first dim
        # whose size is 1 in sub / slots in main)
        def splice(main, sub):
            if not hasattr(sub, "ndim") or sub.ndim == 0:
                return main
            for ax in range(sub.ndim):
                if sub.shape[ax] == 1 and main.shape[ax] == self.slots:
                    idx = [slice(None)] * sub.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return main.at[tuple(idx)].set(sub)
            return main

        pos = self.cache["pos"]
        self.cache = jax.tree_util.tree_map(splice, self.cache, sub_cache)
        self.cache["pos"] = jnp.maximum(pos, sub_cache["pos"])  # shared clock
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.live[slot] = True
        self.slot_req[slot] = req.rid
        return True

    # -- one decode iteration over all live slots ----------------------------

    def _ensure_decode_pages(self) -> None:
        """Give every live slot a page for its next write position; page
        pressure preempts LIFO (never the slot being served first, and the
        arena >= max_pages invariant guarantees the oldest always fits)."""
        order = sorted(
            (s for s in range(self.slots) if self.live[s]),
            key=lambda s: int(self.admit_seq[s]),
        )
        for slot in order:
            if not self.live[slot]:
                continue  # preempted while serving an older slot
            need_idx = int(self.slot_len[slot]) // self.page_tokens
            if need_idx < len(self.slot_pages[slot]):
                continue
            if need_idx >= self.max_pages:
                self._finish(slot)  # page table exhausted: hit max_len
                continue
            while not self.free_pages:
                if not self._preempt_one(exclude=(slot,)):
                    raise RuntimeError(
                        "paged KV pool deadlock: no free pages and no "
                        "preemptable slot (arena_pages too small?)"
                    )
            pid = self.free_pages.pop()
            self.slot_pages[slot].append(pid)
            self.ptab_host[slot, need_idx] = pid

    def _finish(self, slot: int) -> None:
        rid = self.slot_req[slot]
        req = self.requests[rid]
        req.done = True
        if self.paged:
            self._free_slot_pages(slot)
            req.page_comp.clear()
        self.live[slot] = False
        self.slot_req[slot] = -1

    def step(self) -> list[int]:
        """Advance every live slot one token; returns finished rids."""
        if not self.live.any():
            return []
        if self.paged:
            self._ensure_decode_pages()
            if not self.live.any():
                return []
            self.cache["pos"] = jnp.asarray(self.slot_len)
            self.cache["page_table"] = jnp.asarray(self.ptab_host)
        nxt, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.tokens = nxt
        finished = []
        for slot in range(self.slots):
            if not self.live[slot]:
                continue
            rid = self.slot_req[slot]
            req = self.requests[rid]
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            if self.paged:
                self.slot_len[slot] += 1
            # limit counts emitted tokens (prefill token included), so a
            # request with max_new=N receives exactly N tokens
            if tok == self.eos_id or len(req.out) >= req.max_new:
                self._finish(slot)
                finished.append(rid)
        return finished

    # -- accounting ----------------------------------------------------------

    def resident_kv_bytes(self) -> int:
        """Honest resident-KV accounting (the serving benchmark's metric):
        live arena pages at raw size + the compressed store held by
        preempted requests (`CompressedPage.nbytes` is exact bit
        accounting from the kernel, or exact bytes for raw pages)."""
        if not self.paged:
            return sum(
                int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                for a in jax.tree_util.tree_leaves(self.cache)
            )
        per_page = 0
        for _, path in self._page_keys():
            a = self._get(self.cache, path)
            per_page += (
                int(np.prod(a.shape)) // a.shape[1] * np.dtype(a.dtype).itemsize
            )
        live_pages = sum(len(p) for p in self.slot_pages)
        comp = sum(
            cp.nbytes for r in self.preempted for cp in r.page_comp.values()
        )
        return live_pages * per_page + comp

    # -- driver --------------------------------------------------------------

    def run(self, reqs: list[Request], max_iters: int = 10_000) -> list[Request]:
        """Drive a full workload: admit when slots free, decode until done.
        Preempted requests resume ahead of fresh admissions (their context
        is already paid for)."""
        pending = list(reqs)
        it = 0
        while (pending or self.preempted or self.live.any()) and it < max_iters:
            while self.preempted and self.try_admit(self.preempted[0]):
                self.preempted.pop(0)
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            self.step()
            it += 1
        return reqs
