"""Continuous-batching serving scheduler (production serving substrate).

Maintains a fixed-slot decode batch; requests join free slots after a
prefill, leave on EOS/limit, and the decode step runs every iteration over
whichever slots are live (masked). Per-slot KV offsets use the cache's ring
addressing; no recompilation as requests come and go (shapes are static).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching over a shared decode step.

    The model's cache is allocated once for `slots x max_len`. Prefill runs
    per joining request into its slot (batch-1 prefill against a slot view
    is emulated by re-prefilling the slot's sub-cache; on TPU serving this
    would be a paged-attention insert — same interface).
    """

    def __init__(self, model, params, slots: int, max_len: int, eos_id: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(slots, max_len)
        self.live = np.zeros(slots, dtype=bool)
        self.requests: dict[int, Request] = {}
        self.slot_req = [-1] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.steps_done = np.zeros(slots, dtype=np.int64)
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, params, tokens, cache):
        logits, cache = self.model.forward(params, {"tokens": tokens}, cache=cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    # -- admission ----------------------------------------------------------

    def try_admit(self, req: Request) -> bool:
        """Admit into a free slot. Slots share one position clock (scalar
        cache 'pos'), so new requests join at clock zero only; when all
        slots drain the clock resets. A paged KV pool with per-slot offsets
        generalizes this to fully-async admission on real hardware — the
        scheduler logic (slots, masking, splicing) is identical."""
        free = [i for i in range(self.slots) if not self.live[i]]
        if not free:
            return False
        if self.live.any() and int(self.cache["pos"]) > 0:
            return False  # mid-wave admission needs per-slot clocks (paged KV)
        if not self.live.any() and int(self.cache["pos"]) > 0:
            self.cache = self.model.init_cache(self.slots, self.max_len)  # reset
        slot = free[0]
        # prefill the whole batch cache at the request's slot: run a batch
        # prefill with the prompt broadcast only into this slot via masking.
        # (simple + correct for slot-respecting models; a paged KV pool
        # replaces this on real hardware)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        sub_cache = self.model.init_cache(1, self.max_len)
        logits, sub_cache = self.model.forward(
            self.params, {"tokens": prompt}, cache=sub_cache
        )
        # splice slot-0 of sub_cache into our slot (batch dim = first dim
        # whose size is 1 in sub / slots in main)
        def splice(main, sub):
            if not hasattr(sub, "ndim") or sub.ndim == 0:
                return main
            for ax in range(sub.ndim):
                if sub.shape[ax] == 1 and main.shape[ax] == self.slots:
                    idx = [slice(None)] * sub.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return main.at[tuple(idx)].set(sub)
            return main

        pos = self.cache["pos"]
        self.cache = jax.tree_util.tree_map(splice, self.cache, sub_cache)
        self.cache["pos"] = jnp.maximum(pos, sub_cache["pos"])  # shared clock
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out.append(nxt)
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.live[slot] = True
        self.slot_req[slot] = req.rid
        self.steps_done[slot] = 0
        self.requests[req.rid] = req
        return True

    # -- one decode iteration over all live slots ----------------------------

    def step(self) -> list[int]:
        """Advance every live slot one token; returns finished rids."""
        if not self.live.any():
            return []
        nxt, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.tokens = nxt
        finished = []
        for slot in range(self.slots):
            if not self.live[slot]:
                continue
            rid = self.slot_req[slot]
            req = self.requests[rid]
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            self.steps_done[slot] += 1
            if tok == self.eos_id or self.steps_done[slot] >= req.max_new:
                req.done = True
                self.live[slot] = False
                self.slot_req[slot] = -1
                finished.append(rid)
        return finished

    def run(self, reqs: list[Request], max_iters: int = 10_000) -> list[Request]:
        """Drive a full workload: admit when slots free, decode until done."""
        pending = list(reqs)
        it = 0
        while (pending or self.live.any()) and it < max_iters:
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            self.step()
            it += 1
        return reqs
