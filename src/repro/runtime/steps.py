"""train_step / serve_step builders: jit-wrapped, mesh-aware, donation-ready.

These are the functions the launcher jits and the dry-run lowers. They take
explicit param/optimizer trees (no global state) and are pure.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import BaseLM
from repro.optim import adamw, compress


def make_train_step(model: BaseLM, opt_cfg: adamw.AdamWConfig, grad_comp: compress.GradCompressConfig | None = None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    opt_state may contain 'gc' (gradient-compression residuals) when
    grad_comp is enabled.
    """

    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        metrics = dict(aux)
        if grad_comp is not None:
            grads, gc_state, gm = compress.compress(grad_comp, grads, opt_state["gc"])
            metrics.update(gm)
        new_params, new_adam, om = adamw.update(opt_cfg, grads, opt_state["adam"], params)
        metrics.update(om)
        new_state = {"adam": new_adam}
        if grad_comp is not None:
            new_state["gc"] = gc_state
        return new_params, new_state, metrics

    return step


def init_opt_state(params: Any, grad_comp: compress.GradCompressConfig | None = None) -> dict:
    out = {"adam": adamw.init(params)}
    if grad_comp is not None:
        out["gc"] = compress.init(params)
    return out


def make_prefill_step(model: BaseLM):
    """serve prefill: (params, batch, cache) -> (last-token logits, cache)."""

    def prefill(params, batch, cache):
        logits, cache = model.forward(params, batch, cache=cache)
        return logits[:, -1:], cache

    return prefill


def make_decode_step(model: BaseLM, sample: bool = False, temperature: float = 1.0):
    """serve decode: (params, tokens (B,1), cache[, key]) -> (next, cache)."""

    def decode(params, tokens, cache, key=None):
        logits, cache = model.forward(params, {"tokens": tokens}, cache=cache)
        if sample:
            nxt = jax.random.categorical(key, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return nxt.astype(jnp.int32), cache

    return decode
