"""Hypothesis property tests for the statistical ratio predictor
(core/predictor.py, DESIGN.md §8.1) — optional dependency.

Three property families, per the predictor's contract:

* predicted bitrate curves are monotone non-increasing in the error
  bound (and PSNR curves monotone non-increasing in the bound too);
* on synthetic fields with KNOWN statistics (Gaussian white noise,
  random walks, noisy ramps — the families the Gaussian-residual model
  is built for) the prediction error against the sampled estimator is
  bounded, and the measured moments match their analytic values;
* provably-hard fields (heavy tails, constant, tiny) fall below the
  confidence threshold and route to the sampled / degenerate fallback,
  bit-identical to plain `select_many`.

`pytest.importorskip` keeps a bare jax+numpy+pytest environment green;
the CI `property` job installs hypothesis and runs these for real.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import predictor as pred
from repro.core import selector as _sel

pytestmark = pytest.mark.property


def _stats_of(x, r_sp=0.05):
    results = [None]
    groups = _sel._build_select_members(
        [x], [0], results, None, 1e-3, r_sp, "zfp"
    )
    assert groups, "field unexpectedly degenerate"
    ((nd, members),) = groups.items()
    ((stats, _fp),) = pred.stats_for_members(nd, members, r_sp)
    return stats


def _field(kind, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    if kind == "white2d":
        x = scale * rng.standard_normal((128, 128))
    elif kind == "walk2d":
        x = np.cumsum(scale * rng.standard_normal((128, 128)), axis=0)
    elif kind == "walk3d":
        x = np.cumsum(scale * rng.standard_normal((24, 48, 48)), axis=2)
    else:  # ramp3d
        x = np.linspace(0.0, 4.0 * scale, 16 * 48 * 48).reshape(16, 48, 48)
        x = x + 0.05 * scale * rng.standard_normal(x.shape)
    return x.astype(np.float32)


KINDS = ["white2d", "walk2d", "walk3d", "ramp3d"]


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(KINDS),
    scale=st.sampled_from([0.05, 1.0, 300.0]),
)
def test_bitrate_curves_monotone_non_increasing(seed, kind, scale):
    """Rate never rises as the bound loosens — at ANY scale, including
    the Chao1-table-dominated tight-bound regime."""
    stats = _stats_of(_field(kind, seed, scale))
    ebs = stats.vr * np.geomspace(1e-7, 0.3, 48)
    curves = pred.predict_curves(stats, ebs)
    assert np.all(np.diff(curves["br_sz"]) <= 1e-9)
    assert np.all(np.diff(curves["br_zfp"]) <= 1e-9)
    assert np.all(curves["br_sz"] >= 0.0)
    assert np.all(curves["br_zfp"] >= 0.0)
    assert np.all(np.diff(curves["psnr_sz"]) <= 1e-9)
    assert np.all(np.diff(curves["psnr_zfp"]) <= 1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), sigma=st.sampled_from([0.1, 2.0, 50.0]))
def test_moments_match_known_statistics(seed, sigma):
    """iid N(0, sigma) in 2-D: the Lorenzo residual is the double
    difference with variance 4*sigma^2 and exactly Gaussian shape."""
    rng = np.random.default_rng(seed)
    stats = _stats_of((sigma * rng.standard_normal((128, 128))).astype(np.float32))
    est_res_std = np.sqrt(stats.rv2) * stats.vr
    assert est_res_std == pytest.approx(2.0 * sigma, rel=0.25)
    assert 2.2 <= stats.kurtosis <= 4.2
    assert pred.confidence(stats) >= pred.CONFIDENCE_THRESHOLD


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(KINDS),
    eb_rel=st.sampled_from([1e-2, 1e-3, 1e-4]),
)
def test_prediction_error_bounded_on_known_fields(seed, kind, eb_rel):
    """Against the sampled estimator: ZFP rate within an absolute band
    everywhere; SZ rate within an absolute-or-relative band while the
    sampled rate is still below the 32 b/v raw fallback — past raw, both
    paths store raw f32 regardless of the exact figure, so the property
    degrades to directional agreement (the model must also say "past
    useful", not report a cheap rate)."""
    x = _field(kind, seed)
    stats = _stats_of(x)
    assert pred.confidence(stats) >= pred.CONFIDENCE_THRESHOLD
    eb = float(eb_rel * (x.max() - x.min()))
    sampled = _sel.select_many([x], eb_abs=eb)[0]
    p = pred.predict_selection(stats, eb)
    assert abs(p.br_zfp - sampled.br_zfp) <= 3.0
    if sampled.br_sz < 32.0:
        assert abs(p.br_sz - sampled.br_sz) <= max(4.0, 0.55 * sampled.br_sz)
    else:
        assert p.br_sz >= 0.8 * 32.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_low_confidence_routes_to_sampled_fallback(seed):
    rng = np.random.default_rng(seed)
    heavy = rng.standard_cauchy((128, 128)).astype(np.float32)
    tiny = rng.standard_normal((12, 12)).astype(np.float32)
    const = np.full((64, 64), 3.25, np.float32)
    sels, routes = pred.select_many_predicted(
        [heavy, tiny, const], eb_rel=1e-3
    )
    assert routes[0] == "sampled"  # heavy tails break the entropy model
    assert routes[1] == "sampled"  # too few samples to trust the moments
    assert routes[2] == "degenerate"  # constant: vr == 0 -> raw fallback
    assert sels[2].codec == "raw"
    assert pred.confidence(_stats_of(heavy)) < pred.CONFIDENCE_THRESHOLD
    assert pred.confidence(_stats_of(tiny)) < pred.CONFIDENCE_THRESHOLD
    # the sampled fallback re-batches exactly like plain select_many on
    # this tree (heavy+tiny share the 2-D launch), so it must agree
    ref = _sel.select_many([heavy, tiny, const], eb_rel=1e-3)
    assert sels[0] == ref[0] and sels[1] == ref[1]


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(["white2d", "walk3d"]),
)
def test_predicted_selection_respects_bound_fields(seed, kind):
    """Structural invariants of the predicted Selection: the SZ bound
    never exceeds the user bound, rates are positive, and the codec is
    the argmin of the predicted rates (Algorithm 1 on the model)."""
    x = _field(kind, seed)
    stats = _stats_of(x)
    eb = float(1e-3 * (x.max() - x.min()))
    p = pred.predict_selection(stats, eb)
    assert 0.0 < p.eb_sz <= p.eb_abs == eb
    assert p.br_sz > 0.0 and p.br_zfp > 0.0
    if p.codec == "sz":
        assert p.br_sz <= p.br_zfp or p.br_zfp >= 32.0
    elif p.codec == "zfp":
        assert p.br_zfp <= p.br_sz or p.br_sz >= 32.0
