"""Batched multi-field selection engine: select_many vs per-field select."""

import numpy as np
import pytest

from repro.core import Policy
from repro.core import decompress, encode_with_selection, select, select_many
from repro.core.api import compress_pytree, decompress_pytree


def _field_suite(n_fields=36, seed=0):
    """A >=32-field 'checkpoint' mixing shapes, dims, and characteristics so
    both codecs (and the raw fallback) appear among the decisions."""
    rng = np.random.default_rng(seed)
    fields = {}
    for i in range(n_fields):
        k = i % 6
        n = 96 + 16 * (i % 3)
        xx, yy = np.meshgrid(np.linspace(0, 6, n), np.linspace(0, 6, n))
        if k == 0:  # smooth — SZ territory
            f = np.sin(xx * (1 + i / 10)) * np.cos(yy) + 1e-3 * rng.standard_normal((n, n))
        elif k == 1:  # rough
            f = rng.standard_normal((n, n))
        elif k == 2:  # high-frequency smooth — ZFP territory at tight eb
            f = np.sin(20 * xx) * np.cos(20 * yy)
        elif k == 3:  # random walk
            f = np.cumsum(rng.standard_normal((n, n)), axis=0)
        elif k == 4:  # 3-D field
            z = np.linspace(0, 4, 16)
            f = np.sin(xx[None, :64, :64] + z[:, None, None]) + 0.01 * rng.standard_normal((16, 64, 64))
        else:  # 1-D field
            f = np.cumsum(rng.standard_normal(4096))
        fields[f"f{i:02d}"] = f.astype(np.float32)
    return fields


def test_select_many_matches_per_field_select():
    """Acceptance: identical codec decision on every field of a >=32-field
    pytree, plus near-identical estimates."""
    fields = _field_suite()
    assert len(fields) >= 32
    arrs = list(fields.values())
    many = select_many(arrs, eb_rel=1e-4)
    codecs = set()
    for name, arr, m in zip(fields, arrs, many):
        s = select(arr, eb_rel=1e-4)
        assert m.codec == s.codec, (name, m.codec, s.codec, m.br_sz, s.br_sz, m.br_zfp, s.br_zfp)
        assert m.eb_abs == pytest.approx(s.eb_abs, rel=1e-6)
        assert m.br_sz == pytest.approx(s.br_sz, rel=2e-3, abs=1e-3)
        assert m.br_zfp == pytest.approx(s.br_zfp, rel=2e-3, abs=1e-3)
        assert m.psnr_target == pytest.approx(s.psnr_target, rel=2e-3)
        codecs.add(m.codec)
    assert "sz" in codecs and "zfp" in codecs  # the suite exercises both


def test_select_many_degenerate_fields():
    """Tiny / constant / 0-d fields short-circuit to raw, same as select."""
    arrs = [
        np.arange(10, dtype=np.float32),              # too small
        np.full((64, 64), 3.0, dtype=np.float32),     # zero value range
        np.float32(1.5).reshape(()),                  # 0-d
        np.sin(np.linspace(0, 6, 4096)).astype(np.float32).reshape(64, 64),
    ]
    many = select_many(arrs, eb_rel=1e-3)
    assert [m.codec for m in many[:3]] == ["raw", "raw", "raw"]
    assert many[3].codec == select(arrs[3], eb_rel=1e-3).codec


def test_select_many_encode_roundtrip_bounded():
    """encode_with_selection honors the bound for batched decisions."""
    fields = _field_suite(n_fields=8, seed=3)
    arrs = list(fields.values())
    many = select_many(arrs, eb_rel=1e-3)
    for arr, m in zip(arrs, many):
        cf = encode_with_selection(arr, m)
        rec = decompress(cf).reshape(arr.shape)
        vr = arr.max() - arr.min()
        tol = 1e-3 * vr + 4 * np.spacing(np.abs(arr).max() + 1e-30)
        assert np.abs(arr - rec).max() <= tol


def test_compress_pytree_uses_batched_path_same_result():
    """compress_pytree (batched + threaded) decisions == per-field select."""
    fields = _field_suite(n_fields=12, seed=7)
    ct = compress_pytree(fields, Policy.fixed_accuracy(eb_rel=1e-4))
    for name, arr in fields.items():
        s = select(arr, eb_rel=1e-4)
        cf = ct.fields[name]
        # encode_with_selection may downgrade to raw if the stream beat raw
        assert cf.codec in (s.codec, "raw")
        if cf.selection is not None and cf.codec != "raw":
            assert cf.selection.codec == s.codec
    out = decompress_pytree(ct)
    for name, arr in fields.items():
        vr = arr.max() - arr.min()
        assert np.abs(out[name] - arr).max() <= 1e-4 * vr * 1.05


def test_compress_pytree_serial_matches_threaded():
    fields = _field_suite(n_fields=6, seed=11)
    ct_threaded = compress_pytree(fields, Policy.fixed_accuracy(eb_rel=1e-3), workers=4)
    ct_serial = compress_pytree(fields, Policy.fixed_accuracy(eb_rel=1e-3), workers=0)
    for name in fields:
        assert ct_threaded.fields[name].codec == ct_serial.fields[name].codec
        assert ct_threaded.fields[name].data == ct_serial.fields[name].data
