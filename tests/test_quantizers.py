"""§5.1.4 quantizer families: roundtrip + the paper's qualitative claims."""

import jax.numpy as jnp
import numpy as np

from repro.core import quantize as q


def test_linear_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    eb = 1e-3
    k = q.linear_quantize(x, eb)
    back = q.linear_dequantize(k, eb)
    assert float(jnp.max(jnp.abs(back - x))) <= eb * 1.001


def test_log_roundtrip_relative_error():
    rng = np.random.default_rng(1)
    x = jnp.asarray((rng.standard_normal(4096) * 10 ** rng.uniform(-3, 1, 4096)).astype(np.float32))
    n = 512
    codes, bmx = q.log_quantize(x, n, float(jnp.max(jnp.abs(x))))
    back = q.log_dequantize(codes, bmx, n_bins_half=n)
    mask = np.abs(np.asarray(x)) > float(bmx[1]) * 1e-6  # outside dead zone
    rel = np.abs(np.asarray(back) - np.asarray(x))[mask] / np.abs(np.asarray(x))[mask]
    # per-bin relative error bounded by the log bin width
    b = float(bmx[0])
    assert rel.max() <= b - 1.0 + 1e-3, (rel.max(), b)


def test_equiprob_uniform_occupancy():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(1 << 14).astype(np.float32))
    edges = q.equiprob_edges(x, 64)
    codes = q.equiprob_quantize(x, edges)
    hist = np.bincount(np.asarray(codes).reshape(-1), minlength=64)
    # equal-probability bins: occupancy within 30% of uniform
    assert hist.min() > 0.7 * x.size / 64 and hist.max() < 1.3 * x.size / 64
    back = q.equiprob_dequantize(codes, edges)
    assert np.all(np.isfinite(np.asarray(back)))
