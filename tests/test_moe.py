"""MoE dispatch correctness: sort-based capacity dispatch vs direct compute."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import blocks, nn
from repro.models.config import ModelConfig, MoECfg


def _cfg(groups=1, cap=8.0, k=2, e=8):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64,
        moe=MoECfg(n_experts=e, top_k=k, d_ff_expert=16, capacity_factor=cap,
                   dispatch_groups=groups),
    )


def _direct_moe(p, x, cfg):
    """Reference: per-token dense dispatch over all experts (no capacity)."""
    b, l, d = x.shape
    mo = cfg.moe
    xn = nn.rms_norm(x, p["norm"], cfg.norm_eps).reshape(-1, d)
    logits = nn.dense(xn, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, sel = jax.lax.top_k(probs, mo.top_k)
    w = w / w.sum(-1, keepdims=True)
    y = jnp.zeros_like(xn)
    for ei in range(mo.n_experts):
        g = xn @ p["w_gate"][ei].astype(x.dtype)
        u = xn @ p["w_up"][ei].astype(x.dtype)
        o = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ p["w_down"][ei].astype(x.dtype)
        m = (sel == ei).astype(jnp.float32) * w
        y = y + o * m.sum(-1, keepdims=True).astype(x.dtype)
    return y.reshape(b, l, d)


@pytest.mark.parametrize("groups", [1, 4])
def test_moe_matches_direct_when_capacity_ample(groups):
    cfg = _cfg(groups=groups, cap=float(_cfg().moe.n_experts))  # no drops
    params = nn.init_tree(blocks.desc_moe(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)).astype(np.float32))
    got = blocks.apply_moe(params, x, cfg)
    want = _direct_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = _cfg(cap=0.1)  # tiny capacity: most tokens dropped, no NaNs
    params = nn.init_tree(blocks.desc_moe(cfg), jax.random.key(1))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 32, 32)).astype(np.float32))
    y = blocks.apply_moe(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    # dropped tokens contribute zero, so output norm is below the no-drop run
    cfg2 = _cfg(cap=8.0)
    y2 = blocks.apply_moe(params, x, cfg2)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y2)) + 1e-3


def test_moe_grouped_equals_ungrouped_with_ample_capacity():
    cfg1 = _cfg(groups=1, cap=8.0)
    cfg4 = _cfg(groups=4, cap=8.0)
    params = nn.init_tree(blocks.desc_moe(cfg1), jax.random.key(2))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)).astype(np.float32))
    y1 = blocks.apply_moe(params, x, cfg1)
    y4 = blocks.apply_moe(params, x, cfg4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=2e-5)
