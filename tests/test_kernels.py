"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bot4, lorenzo, ops, ref


def _field(shape, seed, kind="walk"):
    rng = np.random.default_rng(seed)
    if kind == "walk":
        return jnp.asarray(np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32))
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


SHAPES = [(256, 256), (512, 384), (300, 517), (64, 1024), (8, 128)]
BLOCKS = [(256, 256), (128, 128), (8, 128)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", ["walk", "noise"])
def test_lorenzo_kernel_matches_ref(shape, kind):
    x = _field(shape, 0, kind)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got = ops.lorenzo_encode(x, eb)
    want = ref.lorenzo2d_encode_ref(x, eb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", BLOCKS)
def test_lorenzo_kernel_block_sweep(block):
    x = _field((512, 512), 1)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got = lorenzo.lorenzo2d_encode(x, eb, block=block)
    want = ref.lorenzo2d_encode_ref(x, eb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("eb_rel", [1e-2, 1e-3, 1e-5])
def test_lorenzo_roundtrip_bound(eb_rel):
    x = _field((300, 200), 2)
    eb = eb_rel * float(jnp.max(x) - jnp.min(x))
    rec = ops.lorenzo_decode(ops.lorenzo_encode(x, eb), eb)
    tol = eb + 4 * float(np.spacing(np.float32(float(jnp.max(jnp.abs(x))))))
    assert float(jnp.max(jnp.abs(rec - x))) <= tol


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("transform", ["zfp", "hwt", "dct2"])
def test_bot_kernel_matches_ref(shape, transform):
    x = _field(shape, 3)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got_r, got_b = ops.bot_fused(x, eb, transform=transform)
    m, n = shape
    xp = jnp.pad(x, ((0, (-m) % 4), (0, (-n) % 4)))
    want_r, want_b = ref.bot2d_fused_ref(xp, eb, transform=transform)
    np.testing.assert_allclose(
        np.asarray(got_r), np.asarray(want_r)[:m, :n], atol=1e-5 * float(jnp.max(jnp.abs(x)))
    )
    np.testing.assert_allclose(
        np.asarray(got_b), np.asarray(want_b)[: -(-m // 4), : -(-n // 4)], rtol=1e-6
    )


def test_bot_kernel_error_bound():
    x = _field((256, 256), 4)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    rec, _ = ops.bot_fused(x, eb)
    assert float(jnp.max(jnp.abs(rec - x))) <= eb


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lorenzo_dtype_sweep(dtype):
    x = _field((128, 128), 5).astype(dtype)
    eb = 1e-2 * float(jnp.max(x.astype(jnp.float32)) - jnp.min(x.astype(jnp.float32)))
    got = ops.lorenzo_encode(x.astype(jnp.float32), eb)
    want = ref.lorenzo2d_encode_ref(x.astype(jnp.float32), eb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernels_are_jittable_and_lowerable():
    """The kernels must lower+compile under jit (TPU-target path health)."""
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c1 = jax.jit(lambda a: lorenzo.lorenzo2d_encode(a, 1e-3)).lower(x).compile()
    assert c1.cost_analysis() is not None
    c2 = jax.jit(lambda a: bot4.bot2d_fused(a, 1e-3)).lower(x).compile()
    assert c2 is not None
