"""Policy-object API (core/policy.py, core/codecs.py; DESIGN.md §2, §2.1):
resolution rules, validation, per-policy batch grouping, the deprecation
shims (old kwargs -> identical bytes + DeprecationWarning), manifest v3,
and the restored-leaf contracts (writeable arrays, honest `.ratio`)."""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import (
    Policy,
    PolicySet,
    codecs,
    compress,
    compress_pytree,
    decompress_pytree,
    select_many,
    solve_many,
)
from benchmarks.common import psnr as _psnr


def _field(seed=0, shape=(128, 96), walk=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    if walk:
        x = np.cumsum(x, axis=0)
    return x.astype(np.float32)


# ---------------------------------------------------------------------------
# Policy / PolicySet semantics
# ---------------------------------------------------------------------------


def test_policy_validation():
    with pytest.raises(ValueError):
        Policy("nope")
    with pytest.raises(ValueError):
        Policy("fixed_psnr")  # no target
    with pytest.raises(ValueError):
        Policy("fixed_ratio", target_ratio=0.0)
    with pytest.raises(ValueError):
        Policy("fixed_accuracy")  # no bound
    with pytest.raises(ValueError):
        Policy.fixed_accuracy(eb_rel=-1e-3)
    with pytest.raises(ValueError):
        Policy.fixed_accuracy(r_sp=0.0)
    with pytest.raises(ValueError):
        Policy.fixed_accuracy(codecs=("unregistered-codec",))
    with pytest.raises(ValueError):
        # no lossy codec left for a lossy mode
        Policy.fixed_psnr(60.0, codecs=("raw",))
    # raw is always appended to the allowlist as the fallback
    assert Policy.fixed_accuracy(codecs=("sz", "zfp")).codecs == ("sz", "zfp", "raw")
    # frozen + hashable (grouping keys, jit-static args)
    assert Policy.fixed_ratio(8.0) == Policy.fixed_ratio(8.0)
    assert len({Policy.fixed_ratio(8.0), Policy.fixed_ratio(8.0)}) == 1


def test_policy_spec_roundtrip():
    for pol in (
        Policy.fixed_accuracy(eb_rel=1e-3),
        Policy.fixed_accuracy(eb_abs=0.25, r_sp=0.1),
        Policy.fixed_psnr(60.0),
        Policy.fixed_ratio(8.0, codecs=("sz",)),
        Policy.raw(),
    ):
        assert Policy.from_spec(json.loads(json.dumps(pol.spec()))) == pol


def test_policyset_first_match_wins_and_default_fallback():
    p_def = Policy.fixed_accuracy(eb_rel=1e-4)
    p_kv = Policy.fixed_ratio(8.0)
    p_opt = Policy.raw()
    pset = PolicySet(
        default=p_def,
        rules=[
            ("*/kv/*", p_kv),
            ("re:^opt/", p_opt),
            ("opt/special", Policy.fixed_psnr(70.0)),  # shadowed: first match wins
        ],
    )
    assert pset.resolve("layer0/kv/cache") is p_kv
    assert pset.resolve("opt/m") is p_opt
    assert pset.resolve("opt/special") is p_opt  # earlier re: rule wins
    assert pset.resolve("params/w") is p_def
    with pytest.raises(TypeError):
        PolicySet(default="not a policy")
    with pytest.raises(TypeError):
        PolicySet(default=p_def, rules=[(123, p_kv)])


def test_codec_registry():
    assert set(codecs.names()) >= {"sz", "zfp", "raw"}
    sz = codecs.get("sz")
    assert not sz.lossless and sz.pointwise_bound
    assert codecs.get("zfp").blockwise and not codecs.get("sz").blockwise
    assert codecs.get("raw").lossless
    with pytest.raises(KeyError):
        codecs.get("fpzip")
    with pytest.raises(ValueError):
        codecs.register(codecs.get("sz"))  # duplicate name
    # raw decode hands back a WRITEABLE array (trainable in place)
    out = codecs.get("raw").decode(np.arange(4, dtype=np.float32).tobytes())
    assert out.flags.writeable


def test_codec_allowlist_restricts_selection():
    f = _field(1)  # a walk: SZ wins under the full allowlist
    full = select_many([f], policy=Policy.fixed_accuracy(eb_rel=1e-3))[0]
    assert full.codec == "sz"
    only_zfp = select_many(
        [f], policy=Policy.fixed_accuracy(eb_rel=1e-3, codecs=("zfp",))
    )[0]
    assert only_zfp.codec in ("zfp", "raw")
    # estimates are the same program; only the pick is restricted
    assert only_zfp.br_sz == full.br_sz and only_zfp.br_zfp == full.br_zfp
    sols = solve_many([f], Policy.fixed_ratio(8.0, codecs=("sz",)))
    assert sols[0].selection.codec in ("sz", "raw")


# ---------------------------------------------------------------------------
# Per-policy batch grouping
# ---------------------------------------------------------------------------


def test_policyset_grouping_matches_per_policy_calls():
    """A mixed-PolicySet tree decides each leaf exactly as a dedicated
    single-policy call over that leaf's group would."""
    tree = {
        "w/a": _field(1),
        "w/b": _field(2, walk=False),
        "opt/m": _field(3),
        "opt/v": _field(4),
    }
    p_acc = Policy.fixed_accuracy(eb_rel=1e-3)
    p_ratio = Policy.fixed_ratio(8.0)
    pset = PolicySet(default=p_acc, rules=[("opt/*", p_ratio)])
    ct = compress_pytree(tree, pset, workers=0)

    ref_acc = select_many([tree["w/a"], tree["w/b"]], policy=p_acc)
    ref_ratio = [s.selection for s in solve_many([tree["opt/m"], tree["opt/v"]], p_ratio)]
    assert ct.fields["w/a"].selection == ref_acc[0]
    assert ct.fields["w/b"].selection == ref_acc[1]
    assert ct.fields["opt/m"].selection == ref_ratio[0]
    assert ct.fields["opt/v"].selection == ref_ratio[1]


def test_single_policy_tree_identical_to_direct_select_many():
    """The api_redesign invariant: one policy -> one group -> the exact
    pre-policy batch composition and decisions."""
    tree = {f"f{i}": _field(i, walk=i % 2 == 0) for i in range(6)}
    ct = compress_pytree(tree, Policy.fixed_accuracy(eb_rel=1e-3), workers=0)
    ref = select_many(list(tree.values()), eb_rel=1e-3)
    for (name, _), r in zip(sorted(tree.items()), ref):
        s = ct.fields[name].selection
        assert (s.codec, s.eb_abs, s.eb_sz, s.br_sz, s.br_zfp) == (
            r.codec, r.eb_abs, r.eb_sz, r.br_sz, r.br_zfp
        ), name


def test_mixed_policyset_tree_roundtrip_meets_targets():
    """Acceptance: fixed_accuracy + fixed_psnr + fixed_ratio leaves in ONE
    tree, each meeting its own §7 tolerance after the round-trip."""
    tree = {
        "acc/w": _field(10),
        "psnr/w": _field(11),
        "ratio/w": _field(12),
        "meta": np.arange(32, dtype=np.int32),
    }
    eb_rel, target_db, target_x = 1e-3, 60.0, 8.0
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=eb_rel),
        rules=[
            ("psnr/*", Policy.fixed_psnr(target_db)),
            ("ratio/*", Policy.fixed_ratio(target_x)),
        ],
    )
    ct = compress_pytree(tree, pset, workers=0)
    out = decompress_pytree(ct)
    np.testing.assert_array_equal(out["meta"], tree["meta"])
    a = tree["acc/w"]
    assert np.abs(out["acc/w"] - a).max() <= eb_rel * (a.max() - a.min()) * 1.001
    assert abs(_psnr(tree["psnr/w"], out["psnr/w"]) - target_db) <= 1.0
    cf = ct.fields["ratio/w"]
    ratio = tree["ratio/w"].nbytes / len(cf.data)
    assert abs(ratio / target_x - 1.0) <= 0.10


# ---------------------------------------------------------------------------
# Deprecation shims: identical bytes + a warning
# ---------------------------------------------------------------------------


def _warns_deprecated():
    return pytest.warns(DeprecationWarning)


def test_compress_shim_bytes_identical():
    f = _field(20)
    new = compress(f, Policy.fixed_psnr(55.0))
    with _warns_deprecated():
        old = compress(f, "fixed_psnr", target_psnr=55.0)
    assert (old.codec, old.data) == (new.codec, new.data)
    new = compress(f, Policy.fixed_accuracy(eb_rel=1e-3))
    with _warns_deprecated():
        old = compress(f, eb_rel=1e-3)
    assert (old.codec, old.data) == (new.codec, new.data)


def test_compress_pytree_shim_bytes_identical():
    tree = {"a": _field(21), "b": _field(22, walk=False), "i": np.arange(9)}
    new = compress_pytree(tree, Policy.fixed_accuracy(eb_rel=1e-3), workers=0)
    with _warns_deprecated():
        old = compress_pytree(tree, eb_rel=1e-3, workers=0)
    assert old.selection_bits == new.selection_bits
    assert all(old.fields[k].data == new.fields[k].data for k in new.fields)
    # the old positional-eb_rel spelling too
    with _warns_deprecated():
        old2 = compress_pytree(tree, 1e-3, workers=0)
    assert all(old2.fields[k].data == new.fields[k].data for k in new.fields)


def test_predicate_shim_warns_and_matches_policyset():
    tree = {"w": _field(23), "skip": _field(24)}
    with _warns_deprecated():
        old = compress_pytree(
            tree, Policy.fixed_accuracy(eb_rel=1e-3), workers=0,
            predicate=lambda name, arr: name != "skip",
        )
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-3), rules=[("skip", Policy.raw())]
    )
    new = compress_pytree(tree, pset, workers=0)
    assert old.selection_bits == new.selection_bits
    assert old.fields["skip"].codec == "raw"
    assert all(old.fields[k].data == new.fields[k].data for k in new.fields)


def test_solve_many_shim_matches_policy():
    f = _field(25)
    new = solve_many([f], Policy.fixed_ratio(6.0))[0]
    with _warns_deprecated():
        old = solve_many([f], "fixed_ratio", target_ratio=6.0)[0]
    assert old.selection == new.selection and old.on_target == new.on_target


def test_plan_tree_shim_warns():
    from repro.core import sharded as shd

    f = _field(26)
    new = shd.plan_tree([f], Policy.fixed_accuracy(eb_rel=1e-3))
    with _warns_deprecated():
        old = shd.plan_tree([f], "fixed_accuracy", eb_rel=1e-3)
    assert old[0].selection == new[0].selection


def test_checkpoint_config_shim(tmp_path):
    with _warns_deprecated():
        cfg = CheckpointConfig(str(tmp_path), eb_rel=1e-3)
    assert cfg.policy == Policy.fixed_accuracy(eb_rel=1e-3)
    with _warns_deprecated():
        cfg = CheckpointConfig(str(tmp_path), mode="fixed_ratio", target_ratio=8.0)
    assert cfg.policy == Policy.fixed_ratio(8.0)
    with pytest.raises(ValueError):
        CheckpointConfig(str(tmp_path), policy=Policy.raw(), eb_rel=1e-3)


def test_kvcomp_shim():
    import jax.numpy as jnp

    from repro.runtime import kvcomp

    page = jnp.asarray(_field(27, (64, 64)))
    r_new, b_new = kvcomp.bot_compress_kv(page, Policy.fixed_accuracy(eb_rel=1e-2))
    with _warns_deprecated():
        r_old, b_old = kvcomp.bot_compress_kv(page, eb_rel=1e-2)
    np.testing.assert_array_equal(np.asarray(r_old), np.asarray(r_new))
    np.testing.assert_array_equal(np.asarray(b_old), np.asarray(b_new))
    with pytest.raises(ValueError):
        kvcomp.bot_compress_kv(page, Policy.fixed_psnr(60.0))


def test_policy_and_legacy_kwargs_together_raise():
    f = _field(28)
    with pytest.raises(ValueError):
        compress(f, Policy.fixed_psnr(60.0), target_psnr=50.0)
    with pytest.raises(ValueError):
        solve_many([f], Policy.fixed_ratio(8.0), target_ratio=6.0)


# ---------------------------------------------------------------------------
# Satellite bugfixes: writeable restores, honest raw_nbytes
# ---------------------------------------------------------------------------


def test_policy_raw_compress_roundtrips_any_dtype():
    """compress(x, Policy.raw()) stores exact original-dtype bytes and
    decompress() inverts it bit-exactly — f64 precision, int payloads."""
    from repro.core import decompress

    for arr in (
        (np.arange(64, dtype=np.float64) * np.pi).reshape(8, 8),
        np.arange(64, dtype=np.int32).reshape(8, 8),
        np.arange(64, dtype=np.float16).reshape(8, 8),
    ):
        cf = compress(arr, Policy.raw())
        assert cf.codec == "raw" and cf.selection is None
        out = decompress(cf)
        assert out.dtype == arr.dtype and out.flags.writeable
        np.testing.assert_array_equal(out, arr)


def test_checkpoint_restores_lossy_raw_f64_field(tmp_path):
    """A float64 field whose *selection* lands on raw (constant ->
    degenerate) stores f32 working bytes in the flat layout; restore must
    decode them as f32 and cast, not reinterpret as f64."""
    tree = {"const64": np.full((64, 64), 2.5, np.float64), "w": _field(43)}
    mgr = CheckpointManager(
        CheckpointConfig(str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3), workers=0)
    )
    path = mgr.save(1, tree)
    man = json.load(open(os.path.join(path, "manifest.json")))
    by_name = {f["name"]: f for f in man["fields"]}
    assert by_name["const64"]["codec"] == "raw"  # degenerate -> selection raw
    _, flat = mgr.restore()
    assert flat["const64"].dtype == np.float64 and flat["const64"].flags.writeable
    np.testing.assert_array_equal(flat["const64"], tree["const64"])


def test_kvcomp_positional_eb_rel_shim():
    import jax.numpy as jnp

    from repro.runtime import kvcomp

    page = jnp.asarray(_field(29, (64, 64)))
    r_new, b_new = kvcomp.bot_compress_kv(page, Policy.fixed_accuracy(eb_rel=1e-2))
    with _warns_deprecated():
        r_old, b_old = kvcomp.bot_compress_kv(page, 1e-2)  # old positional eb_rel
    np.testing.assert_array_equal(np.asarray(r_old), np.asarray(r_new))
    np.testing.assert_array_equal(np.asarray(b_old), np.asarray(b_new))


def test_select_many_policy_conflicts_raise():
    f = _field(31)
    pol = Policy.fixed_accuracy(eb_rel=1e-3)
    with pytest.raises(ValueError):
        select_many([f], r_sp=0.2, policy=pol)
    with pytest.raises(ValueError):
        select_many([f], codecs=("zfp",), policy=pol)


def test_decompress_pytree_leaves_writeable():
    tree = {
        "w": _field(30),
        "ids": np.arange(256, dtype=np.int32),  # raw, no selection
        "tiny": np.ones(4, np.float32),         # degenerate raw, with selection
    }
    out = decompress_pytree(compress_pytree(tree, workers=0))
    for name, leaf in (("w", out["w"]), ("ids", out["ids"]), ("tiny", out["tiny"])):
        assert leaf.flags.writeable, name
        leaf[...] = 0  # in-place training must not raise


def test_raw_nbytes_uses_recorded_dtype_itemsize():
    import ml_dtypes

    tree = {
        "f64": np.cumsum(np.ones((32, 32)), axis=0),            # 8 B/value
        "bf16": np.zeros((16, 16), dtype=ml_dtypes.bfloat16),   # 2 B/value
        "i32": np.arange(100, dtype=np.int32),                  # 4 B/value
        "i8": np.arange(64, dtype=np.int8),                     # 1 B/value
    }
    ct = compress_pytree(tree, workers=0)
    expect = sum(np.asarray(v).nbytes for v in tree.values())
    assert ct.raw_nbytes == expect
    assert ct.ratio == ct.raw_nbytes / max(ct.nbytes, 1)


# ---------------------------------------------------------------------------
# Manifest v3 + old-version readers
# ---------------------------------------------------------------------------


def test_manifest_v3_records_resolved_policies(tmp_path):
    tree = {
        "params/w": _field(40),
        "opt/m": _field(41),
        "meta": np.arange(8, dtype=np.int64),
    }
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-3),
        rules=[("opt/*", Policy.fixed_ratio(8.0))],
    )
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), policy=pset, workers=0))
    path = mgr.save(3, tree)
    man = json.load(open(os.path.join(path, "manifest.json")))
    assert man["version"] == 3 and man["layout"] == "flat"
    assert Policy.from_spec(man["policy"]["default"]) == pset.default
    assert man["policy"]["rules"] == [["opt/*", Policy.fixed_ratio(8.0).spec()]]
    by_name = {f["name"]: f for f in man["fields"]}
    assert by_name["params/w"]["policy"]["mode"] == "fixed_accuracy"
    assert by_name["opt/m"]["policy"]["mode"] == "fixed_ratio"
    assert by_name["meta"]["policy"] == {"mode": "raw"}
    # the fixed_ratio leaf met its byte budget (±10%)
    fl = by_name["opt/m"]
    assert abs((tree["opt/m"].nbytes / fl["nbytes"]) / 8.0 - 1.0) <= 0.10
    # restored leaves are writeable, dtypes preserved
    _, flat = mgr.restore()
    for name, arr in flat.items():
        assert arr.flags.writeable, name
    np.testing.assert_array_equal(flat["meta"], tree["meta"])


def test_v1_manifest_still_restorable(tmp_path):
    """A v3-flat checkpoint stripped back to the v1 manifest shape (no
    version/layout/policy keys) restores through the same reader."""
    tree = {"w": _field(42), "ids": np.arange(64, dtype=np.int32)}
    mgr = CheckpointManager(
        CheckpointConfig(str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3), workers=0)
    )
    path = mgr.save(1, tree)
    _, ref = mgr.restore()
    mpath = os.path.join(path, "manifest.json")
    man = json.load(open(mpath))
    for key in ("version", "layout", "policy"):
        man.pop(key)
    for fl in man["fields"]:
        fl.pop("policy")
    json.dump(man, open(mpath, "w"))
    step, flat = mgr.restore()
    assert step == 1
    for name in ref:
        np.testing.assert_array_equal(flat[name], ref[name], err_msg=name)
        assert flat[name].flags.writeable, name
