"""Hypothesis property tests for Stage I transforms — Theorems 1 & 3.

`pytest.importorskip` keeps a bare jax+numpy+pytest environment green; the
deterministic smoke versions live in test_core_transforms.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

pytestmark = pytest.mark.property

from hypothesis import given, settings, strategies as st

from repro.core.transforms import (
    blockize,
    block_transform_nd,
    bot_matrix,
    lorenzo_forward,
    lorenzo_inverse,
    unblockize,
)

DIMS = st.sampled_from([(64,), (17,), (16, 24), (9, 33), (8, 12, 20), (5, 6, 7)])


@settings(max_examples=20, deadline=None)
@given(shape=DIMS, seed=st.integers(0, 2**31 - 1))
def test_lorenzo_roundtrip_exact_on_integers(shape, seed):
    """PBT is lossless over integer codes (the prequantization invariant)."""
    rng = np.random.default_rng(seed)
    k = rng.integers(-1000, 1000, size=shape).astype(np.float32)
    d = lorenzo_forward(jnp.asarray(k))
    back = lorenzo_inverse(d)
    np.testing.assert_array_equal(np.asarray(back), k)


@settings(max_examples=20, deadline=None)
@given(shape=DIMS, seed=st.integers(0, 2**31 - 1))
def test_theorem1_pointwise_error_preserved(shape, seed):
    """Theorem 1: X - X~ == X_pbt - X~_pbt pointwise (over exact integers)."""
    rng = np.random.default_rng(seed)
    k = rng.integers(-500, 500, size=shape).astype(np.float64)
    kq = np.round(k + rng.uniform(-0.4, 0.4, size=shape))  # perturbed codes
    d, dq = lorenzo_forward(jnp.asarray(k)), lorenzo_forward(jnp.asarray(kq))
    lhs = k - np.asarray(lorenzo_inverse(dq))
    rhs = np.asarray(d) - np.asarray(dq)
    # the pointwise error of reconstruction-from-perturbed-codes equals the
    # residual-space error after the (linear) inverse accumulates it:
    np.testing.assert_allclose(
        np.asarray(lorenzo_inverse(jnp.asarray(rhs))), lhs, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(t=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1), nd=st.integers(1, 3))
def test_lemma2_l2_invariance_any_dim(t, seed, nd):
    """Lemma 2: BOT preserves the elementwise L2 norm for any t, any ndim."""
    rng = np.random.default_rng(seed)
    blocks = jnp.asarray(rng.standard_normal((7,) + (4,) * nd).astype(np.float32))
    T = jnp.asarray(bot_matrix(float(t)), jnp.float32)
    out = block_transform_nd(blocks, T, nd)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(out)), float(jnp.linalg.norm(blocks)), rtol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nd=st.integers(1, 3))
def test_theorem3_mse_preserved_through_bot(seed, nd):
    """Theorem 3: L2 error in coefficient space == L2 error in data space."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((5,) + (4,) * nd).astype(np.float32))
    T = jnp.asarray(bot_matrix("zfp"), jnp.float32)
    c = block_transform_nd(x, T, nd)
    noise = jnp.asarray(rng.standard_normal(c.shape).astype(np.float32)) * 0.01
    x_rec = block_transform_nd(c + noise, T, nd, inverse=True)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(x - x_rec)), float(jnp.linalg.norm(noise)), rtol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(shape=DIMS, seed=st.integers(0, 2**31 - 1))
def test_blockize_roundtrip(shape, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    blocks, padded = blockize(x)
    assert blocks.shape[1:] == (4,) * len(shape)
    back = unblockize(blocks, padded, shape)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
