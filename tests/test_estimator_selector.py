"""Estimator accuracy + Algorithm-1 selection behavior (paper §6.2)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Policy
from repro.core import select, sz_compress, zfp_compress
from repro.core import estimator as est
from repro.core.api import compress_pytree, decompress_pytree


def _fields(n=256):
    rng = np.random.default_rng(0)
    xx, yy = np.meshgrid(np.linspace(0, 6, n), np.linspace(0, 6, n))
    z = np.linspace(0, 4, 64)
    return {
        "smooth": (np.sin(xx) * np.cos(yy) + 1e-3 * rng.standard_normal((n, n))).astype(np.float32),
        "rough": rng.standard_normal((n, n)).astype(np.float32),
        "ramp": (2 * xx + yy + 0.05 * rng.standard_normal((n, n))).astype(np.float32),
        "hur3d": (
            np.sin(xx[None, :128, :128] * 2 + z[:, None, None]) * np.exp(-z[:, None, None] / 3)
            + 0.01 * rng.standard_normal((64, 128, 128))
        ).astype(np.float32),
    }


@pytest.mark.parametrize("r_sp", [0.01, 0.05, 0.10])
def test_bitrate_estimation_error_bounded(r_sp):
    """Paper Tables 2-3 analogue: avg relative BR error small at all rates."""
    errs_sz, errs_zfp = [], []
    for name, f in _fields().items():
        vr = f.max() - f.min()
        eb = 1e-3 * vr
        sel = select(f, eb_abs=eb, r_sp=r_sp)
        a_sz = 8 * len(sz_compress(f, sel.eb_sz)) / f.size
        a_zfp = 8 * len(zfp_compress(f, eb)) / f.size
        errs_sz.append((sel.br_sz - a_sz) / a_sz)
        errs_zfp.append((sel.br_zfp - a_zfp) / a_zfp)
    # paper: within ~8.5% (SZ) / ~5.7% (ZFP) at 5%; allow margin at 1%
    lim = 0.25 if r_sp < 0.05 else 0.15
    assert np.mean(np.abs(errs_sz)) < lim, errs_sz
    assert np.mean(np.abs(errs_zfp)) < lim, errs_zfp


def test_psnr_estimation_close():
    """Paper: PSNR estimation error a few percent; SZ's is closed-form."""
    from repro.core import sz_stats, zfp_stats

    for name, f in _fields().items():
        vr = f.max() - f.min()
        eb = 1e-3 * vr
        sel = select(f, eb_abs=eb)
        st_z = zfp_stats(jnp.asarray(f), eb)
        # estimated ZFP PSNR (the match target) within 5% of actual
        assert abs(sel.psnr_target - float(st_z.psnr)) / float(st_z.psnr) < 0.05, name
        st_s = sz_stats(jnp.asarray(f), sel.eb_sz)
        # iso-PSNR match: SZ's actual PSNR lands near the target
        assert abs(float(st_s.psnr) - sel.psnr_target) / sel.psnr_target < 0.05, name


def test_selection_accuracy_on_field_suite():
    """Fig. 7 analogue: the picked codec is (near-)best on every field."""
    ok, tot, degradation = 0, 0, []
    for name, f in _fields().items():
        for eb_rel in (1e-3, 1e-4):
            vr = f.max() - f.min()
            eb = eb_rel * vr
            sel = select(f, eb_abs=eb)
            a_sz = 8 * len(sz_compress(f, sel.eb_sz)) / f.size
            a_zfp = 8 * len(zfp_compress(f, eb)) / f.size
            best = "sz" if a_sz < a_zfp else "zfp"
            tot += 1
            if sel.codec == best:
                ok += 1
            else:
                picked = a_sz if sel.codec == "sz" else a_zfp
                degradation.append(picked / min(a_sz, a_zfp) - 1)
    assert ok / tot >= 0.85, (ok, tot)
    # wrong picks (if any) must be near-ties — the paper's observation
    assert all(d < 0.1 for d in degradation), degradation


def test_sampling_is_subsampled():
    starts = est.block_starts((256, 256), 0.05)
    frac = len(starts) / ((256 // 4) * (256 // 4))
    assert 0.02 <= frac <= 0.10


def test_residual_sampling_matches_full_lorenzo():
    """Sampled residuals == the full-array Lorenzo residual at those points."""
    from repro.core.transforms import lorenzo_forward

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    starts = est.block_starts((64, 64), 0.25)
    r = np.asarray(est.lorenzo_residual_samples(x, starts)).reshape(-1, 4, 4)
    full = np.asarray(lorenzo_forward(x))
    for b, (i, j) in enumerate(starts):
        np.testing.assert_allclose(r[b], full[i : i + 4, j : j + 4], atol=1e-5)


def test_compress_pytree_roundtrip():
    rng = np.random.default_rng(5)
    tree = {
        "w": rng.standard_normal((128, 64)).astype(np.float32),
        "b": rng.standard_normal((64,)).astype(np.float32),
        "step": np.array(7, dtype=np.int32),
        "nested": {"emb": np.cumsum(rng.standard_normal((96, 96)), 0).astype(np.float32)},
    }
    ct = compress_pytree(tree, Policy.fixed_accuracy(eb_rel=1e-4))
    assert set(ct.selection_bits) == {"w", "b", "step", "nested/emb"}
    out = decompress_pytree(ct)
    np.testing.assert_array_equal(out["step"], tree["step"])
    for k in ("w", "b"):
        vr = tree[k].max() - tree[k].min()
        assert np.abs(out[k] - tree[k]).max() <= 1e-4 * vr * 1.02
    vr = tree["nested"]["emb"].max() - tree["nested"]["emb"].min()
    assert np.abs(out["nested"]["emb"] - tree["nested"]["emb"]).max() <= 1e-4 * vr * 1.02
    assert ct.ratio > 1.0
