"""Sharded-vs-unsharded parity: the DESIGN.md §6 correctness contract.

On 8 emulated CPU devices (tests/conftest.py), the shard-local engine must
produce the SAME per-field decisions as the single-host path — and
decompressed bytes must match exactly — for mixed pytrees in all three
quality modes, including the elastic restore-under-a-different-mesh case.
Distributed correctness is easy to get silently wrong; every assertion
here is equality, not tolerance.
"""

import json
import os

import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import Policy, PolicySet
from repro.core import estimator as est
from repro.core import sharded as shd
from repro.core.api import ShardedCompressedField, compress_pytree, decompress_pytree
from repro.core.selector import select_many

pytestmark = [pytest.mark.usefixtures("emulated_devices"), pytest.mark.multidevice]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


def _mixed_tree(mesh, seed=0):
    """Mixed sharded pytree: DP/TP/2-D-sharded/replicated lossy fields, a
    5-D fold, plus degenerate + non-float + policy-raw leaves."""
    rng = np.random.default_rng(seed)

    def mk(shape, spec, walk_axis=0):
        x = np.cumsum(rng.standard_normal(shape), axis=walk_axis).astype(np.float32)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {
        "dp": mk((128, 96), P("data", None)),
        "tp": mk((96, 128), P(None, "model")),
        "both": mk((64, 64, 32), P("data", "model", None)),
        # genuinely-3-D volume with the LAST view dim sharded: the halo
        # plane ppermutes along the minor axis too (ISSUE 4, 4x4x4 tier)
        "vol": mk((32, 64, 64), P("data", None, "model"), walk_axis=2),
        "repl": mk((128, 64), P()),
        "conv": mk((2, 3, 8, 32, 32), P()),  # 5-D fold
        "rough": jax.device_put(
            rng.standard_normal((96, 96)).astype(np.float32),
            NamedSharding(mesh, P("data", None)),
        ),
        # 50-row shards are not 4-aligned -> engine-ineligible host fallback;
        # its members must merge into the SAME batches as the engine fields
        "uneven": jax.device_put(
            np.cumsum(rng.standard_normal((100, 64)), axis=0).astype(np.float32),
            NamedSharding(mesh, P("data", None)),
        ),
        "tiny": mk((8,), P()),
        "const": jax.device_put(
            np.full((64, 64), 3.0, np.float32), NamedSharding(mesh, P("data", None))
        ),
        "ids": jax.device_put(
            np.arange(1024, dtype=np.int32).reshape(32, 32),
            NamedSharding(mesh, P("data", None)),
        ),
        "step": np.array(7, np.int64),
    }


def _host_tree(tree):
    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


# ---------------------------------------------------------------------------
# engine internals: the reconciliation building blocks
# ---------------------------------------------------------------------------


def test_gathered_sample_blocks_bit_identical(mesh):
    """The samples reconciliation feeds the deciders the EXACT blocks the
    unsharded host gather would produce — including halo values across
    shard boundaries and zeros at the domain boundary."""
    rng = np.random.default_rng(3)
    x = np.cumsum(rng.standard_normal((128, 96)), axis=0).astype(np.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    lay = shd.analyze(xs)
    assert lay is not None and lay.axis_of_dim == ("data", None)
    starts = est.block_starts(lay.view_shape, 0.05)
    ref = est.gather_blocks_np(x, starts, halo=True)

    fn = shd._engine_fn(mesh, tuple(), "samples", "zfp")  # noqa: F841 warm cache path
    plans = shd.plan_tree([xs], Policy.fixed_accuracy(eb_rel=1e-3), reconcile="samples")
    assert plans[0].reconcile == "samples"
    # reproduce the gather the engine did and compare block-for-block
    owned, mx, stacked = shd._starts_plan(
        lay, np.ascontiguousarray(starts.astype(np.int64)).tobytes(), len(starts)
    )
    got_slots = sorted(s for _, slots in owned.values() for s in slots)
    assert got_slots == list(range(len(starts)))  # every block owned exactly once

    efn = shd._engine_fn(
        mesh,
        (shd._FieldDesc((64, 96), lay.orig_spec, lay.view_shape, lay.local_view, lay.axis_of_dim, mx),),
        "samples",
        "zfp",
    )
    z = np.zeros(1, np.float32)
    blocks_g, slots_g = efn((xs,), (stacked,), z, z, z)
    bl, sl = np.asarray(blocks_g[0]), np.asarray(slots_g[0])
    out = np.zeros_like(ref)
    keep = sl >= 0
    out[sl[keep]] = bl[keep]
    np.testing.assert_array_equal(out, ref)


def test_layout_eligibility_rules(mesh):
    rng = np.random.default_rng(4)
    f32 = np.float32
    # shard not 4-aligned: 100 over 2-way 'data' gives 50-row shards
    x = jax.device_put(rng.standard_normal((100, 64)).astype(f32), NamedSharding(mesh, P("data", None)))
    assert shd.analyze(x) is None
    # ...while 64 over 4-way 'model' (16-wide shards) is eligible
    x = jax.device_put(rng.standard_normal((100, 64)).astype(f32), NamedSharding(mesh, P(None, "model")))
    assert shd.analyze(x) is not None
    # shard smaller than a block: 8 / 4-way model = 2 < 4
    x = jax.device_put(rng.standard_normal((8, 64)).astype(f32), NamedSharding(mesh, P("model", None)))
    assert shd.analyze(x) is None
    # sharded middle dim of a >3-D fold interleaves -> ineligible
    x = jax.device_put(
        rng.standard_normal((4, 8, 16, 16)).astype(f32), NamedSharding(mesh, P(None, "data", None, None))
    )
    assert shd.analyze(x) is None
    # leading dim of a >3-D fold is fine
    x = jax.device_put(
        rng.standard_normal((4, 8, 16, 16)).astype(f32), NamedSharding(mesh, P("data", None, None, None))
    )
    lay = shd.analyze(x)
    assert lay is not None and lay.view_shape == (32, 16, 16)
    assert lay.local_view == (16, 16, 16)
    # host arrays have no layout
    assert shd.analyze(np.zeros((64, 64), f32)) is None


# ---------------------------------------------------------------------------
# decision + roundtrip parity, all three modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reconcile", ["samples", "stats"])
def test_fixed_accuracy_decision_parity(mesh, reconcile):
    """Manifest decisions (codec, eb, eb_sz) equal the unsharded path for
    both reconciliation strategies — 'samples' bit-identically by
    construction, 'stats' through the psum'd sufficient statistics."""
    tree = _mixed_tree(mesh)
    host = _host_tree(tree)
    names = [k for k in tree if np.issubdtype(np.asarray(host[k]).dtype, np.floating)]
    arrs = [tree[k] for k in names]
    plans = shd.plan_tree(arrs, Policy.fixed_accuracy(eb_rel=1e-3), reconcile=reconcile)
    ref = select_many([host[k] for k in names], eb_rel=1e-3)
    codecs = set()
    reconciles = set()
    for name, p, r in zip(names, plans, ref):
        s = p.selection
        assert s.codec == r.codec, (name, reconcile, s, r)
        assert s.eb_abs == r.eb_abs, (name, reconcile)
        assert s.eb_sz == r.eb_sz, (name, reconcile)
        codecs.add(s.codec)
        reconciles.add(p.reconcile)
        if name == "vol":  # the 3-D volume must ride the engine, not gather
            assert p.reconcile == reconcile, (name, p.reconcile)
        if reconcile == "samples":
            # bit-identical estimates for EVERY field — engine members and
            # host-fallback members merge into the unsharded batch packing,
            # so even the f32 cross-field reductions match exactly
            assert s.br_sz == r.br_sz and s.br_zfp == r.br_zfp, (name, p.reconcile)
    assert {"sz", "zfp", "raw"} <= codecs  # the tree exercises every branch
    assert "host" in reconciles  # the mixed-composition case is really here


@pytest.mark.slow
@pytest.mark.parametrize(
    "mode,pol",
    [
        ("fixed_accuracy", Policy.fixed_accuracy(eb_rel=1e-3)),
        ("fixed_psnr", Policy.fixed_psnr(60.0)),
        ("fixed_ratio", Policy.fixed_ratio(6.0)),
    ],
)
def test_compress_pytree_parity_all_modes(mesh, mode, pol):
    """compress_pytree(sharded) vs unsharded: identical selection bits and
    bit-identical decompressed bytes for a mixed pytree in every mode."""
    tree = _mixed_tree(mesh)
    host = _host_tree(tree)
    ct = compress_pytree(tree, pol)
    ct_ref = compress_pytree(host, pol, sharded=False)
    out = decompress_pytree(ct)
    ref = decompress_pytree(ct_ref)
    for name in ct_ref.fields:
        cf, rf = ct.fields[name], ct_ref.fields[name]
        assert cf.codec == rf.codec, (name, mode)
        if isinstance(cf, ShardedCompressedField) and cf.selection and rf.selection:
            assert cf.selection.eb_abs == rf.selection.eb_abs, (name, mode)
            assert cf.selection.eb_sz == rf.selection.eb_sz, (name, mode)
            # the per-shard safety net never quietly diverged on these trees
            assert all(s.codec == cf.codec for s in cf.segments), name
        np.testing.assert_array_equal(out[name], ref[name], err_msg=f"{name} ({mode})")
        assert np.asarray(out[name]).dtype == np.asarray(ref[name]).dtype


@pytest.mark.slow
@pytest.mark.parametrize(
    "mode,pol",
    [
        ("fixed_accuracy", Policy.fixed_accuracy(eb_rel=1e-3)),
        ("fixed_psnr", Policy.fixed_psnr(60.0)),
        ("fixed_ratio", Policy.fixed_ratio(6.0)),
    ],
)
def test_checkpoint_manifest_and_bytes_parity(mesh, tmp_path, mode, pol):
    """Sharded CheckpointManager vs unsharded: same manifest decisions and
    identical restored tensors, in all three CheckpointConfig modes."""
    tree = _mixed_tree(mesh)
    host = _host_tree(tree)
    m_sh = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path / "sh"), policy=pol, sharded=True)
    )
    m_un = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path / "un"), policy=pol)
    )
    p_sh = m_sh.save(1, tree)
    p_un = m_un.save(1, host)
    man_sh = json.load(open(os.path.join(p_sh, "manifest.json")))
    man_un = json.load(open(os.path.join(p_un, "manifest.json")))
    # both manifests are v3; the layout key picks the reader
    assert man_sh["version"] == 3 and man_sh["layout"] == "segments"
    assert man_un["version"] == 3 and man_un["layout"] == "flat"
    assert man_sh["policy"] == man_un["policy"] == {"default": pol.spec()}
    assert man_sh["selection_bits"] == man_un["selection_bits"]
    eb_sh = {f["name"]: f["eb"] for f in man_sh["fields"]}
    eb_un = {f["name"]: f["eb"] for f in man_un["fields"]}
    assert eb_sh == eb_un
    _, f_sh = m_sh.restore()
    _, f_un = m_un.restore()
    assert set(f_sh) == set(f_un)
    for name in f_un:
        np.testing.assert_array_equal(f_sh[name], f_un[name], err_msg=name)
        assert f_sh[name].dtype == f_un[name].dtype, name


def test_mixed_policyset_sharded(mesh, tmp_path):
    """Acceptance: fixed_accuracy + fixed_psnr + fixed_ratio leaves in ONE
    sharded tree — through compress_pytree(sharded) AND the checkpoint
    writer — each meeting its own §7 tolerance, with the manifest
    recording the resolved per-field policies (and staying readable after
    a rewrite to the v2 manifest shape)."""
    rng = np.random.default_rng(11)

    def mk(seed, walk_axis=0):
        x = np.cumsum(rng.standard_normal((128, 96)), axis=walk_axis).astype(np.float32)
        return x, jax.device_put(x, NamedSharding(mesh, P("data", None)))

    eb_rel, target_db, target_x = 1e-3, 60.0, 6.0
    h_acc, s_acc = mk(0)
    h_psnr, s_psnr = mk(1)
    h_ratio, s_ratio = mk(2)
    tree = {"acc/w": s_acc, "psnr/w": s_psnr, "ratio/w": s_ratio,
            "meta": np.arange(16, dtype=np.int32)}
    host = {"acc/w": h_acc, "psnr/w": h_psnr, "ratio/w": h_ratio}
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=eb_rel),
        rules=[("psnr/*", Policy.fixed_psnr(target_db)),
               ("ratio/*", Policy.fixed_ratio(target_x))],
    )

    def check(out, nbytes_of):
        assert np.abs(out["acc/w"] - h_acc).max() <= eb_rel * (h_acc.max() - h_acc.min()) * 1.001
        from benchmarks.common import psnr as _ps
        assert abs(_ps(h_psnr, out["psnr/w"]) - target_db) <= 1.0
        assert abs((h_ratio.nbytes / nbytes_of("ratio/w")) / target_x - 1.0) <= 0.10

    ct = compress_pytree(tree, pset, workers=0)
    for name in host:
        assert isinstance(ct.fields[name], ShardedCompressedField), name
    out = decompress_pytree(ct)
    check(out, lambda n: ct.fields[n].nbytes)

    mgr = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), policy=pset, sharded=True, workers=0)
    )
    path = mgr.save(4, tree)
    man = json.load(open(os.path.join(path, "manifest.json")))
    assert man["version"] == 3 and man["layout"] == "segments"
    by_name = {f["name"]: f for f in man["fields"]}
    assert by_name["acc/w"]["policy"]["mode"] == "fixed_accuracy"
    assert by_name["psnr/w"]["policy"]["mode"] == "fixed_psnr"
    assert by_name["ratio/w"]["policy"]["mode"] == "fixed_ratio"
    assert by_name["meta"]["policy"] == {"mode": "raw"}
    _, flat = mgr.restore()
    check(flat, lambda n: by_name[n]["nbytes"])
    np.testing.assert_array_equal(flat["meta"], np.arange(16, dtype=np.int32))

    # the v2 manifest shape (version: 2, no layout/policy keys) still reads
    man_v2 = dict(man)
    man_v2["version"] = 2
    man_v2.pop("layout"), man_v2.pop("policy")
    for fl in man_v2["fields"]:
        fl.pop("policy")
    json.dump(man_v2, open(os.path.join(path, "manifest.json"), "w"))
    _, flat_v2 = mgr.restore()
    for name in flat:
        np.testing.assert_array_equal(flat_v2[name], flat[name], err_msg=name)


def test_restore_under_different_mesh(mesh, tmp_path):
    """Elasticity: a checkpoint saved on a (2,4) mesh restores under (4,2)
    and (8,1) meshes — and with no mesh at all — with identical values."""
    tree = _mixed_tree(mesh)
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3),
            sharded=True,
        )
    )
    mgr.save(5, tree)
    _, flat = mgr.restore()  # mesh-free reassembly
    for shape2 in [(4, 2), (8, 1)]:
        mesh2 = jax.make_mesh(shape2, ("data", "model"))
        shardings = {
            "dp": NamedSharding(mesh2, P("data", None)),
            "tp": NamedSharding(mesh2, P(None, "model")),
            "both": NamedSharding(mesh2, P("data", "model", None)),
            "vol": NamedSharding(mesh2, P("data", None, "model")),
            "repl": NamedSharding(mesh2, P()),
            "conv": NamedSharding(mesh2, P()),
            "rough": NamedSharding(mesh2, P("data", None)),
            "uneven": NamedSharding(mesh2, P()),
            "tiny": NamedSharding(mesh2, P()),
            "const": NamedSharding(mesh2, P("data", None)),
            "ids": NamedSharding(mesh2, P("data", None)),
            "step": NamedSharding(mesh2, P()),
        }
        _, restored = mgr.restore_tree(tree, shardings=shardings)
        for name in shardings:
            leaf = restored[name]
            assert leaf.sharding.mesh.devices.shape == shape2, name
            np.testing.assert_array_equal(np.asarray(leaf), flat[name], err_msg=name)


def test_flat_layout_readable_by_sharded_reader(mesh, tmp_path):
    """The sharded-configured reader accepts single-file (flat) checkpoints
    — layout dispatch is per manifest, not per config."""
    tree = _host_tree(_mixed_tree(mesh))
    pol = Policy.fixed_accuracy(eb_rel=1e-3)
    m_v1 = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=pol))
    path = m_v1.save(2, tree)
    assert os.path.exists(os.path.join(path, "data.bin"))
    m_reader = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), policy=pol, sharded=True)
    )
    step, flat = m_reader.restore()
    assert step == 2
    for name, arr in flat.items():
        assert np.all(np.isfinite(arr)) or name in ("step",), name
    np.testing.assert_array_equal(flat["ids"], np.asarray(tree["ids"]))


def test_sharded_segments_layout(mesh, tmp_path):
    """Segment-layout manifests record per-shard segments whose extents
    tile each field's folded view, and per-host data files hold exactly
    the concatenated segment bytes."""
    tree = _mixed_tree(mesh)
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3),
            sharded=True,
        )
    )
    path = mgr.save(1, tree)
    man = json.load(open(os.path.join(path, "manifest.json")))
    by_name = {f["name"]: f for f in man["fields"]}
    assert len(by_name["dp"]["segments"]) == 2  # 2-way 'data' sharding
    assert len(by_name["tp"]["segments"]) == 4  # 4-way 'model' sharding
    assert len(by_name["both"]["segments"]) == 8
    assert len(by_name["vol"]["segments"]) == 8  # 3-D: 2-way z x 4-way x
    for fl in man["fields"]:
        covered = 0
        for sg in fl["segments"]:
            ext = [b - a for a, b in zip(sg["start"], sg["stop"])]
            covered += int(np.prod(ext)) if ext else 1
        view = int(np.prod(fl["view_shape"])) if fl["view_shape"] else 1
        assert covered == view, fl["name"]
    data = open(os.path.join(path, f"data.{man['hosts'][0]}.bin"), "rb").read()
    assert len(data) == man["total_bytes"]
