"""Test-session plumbing: multi-device emulation + golden-file options.

Multi-device emulation (the DESIGN.md §6 test harness): XLA only reads
`--xla_force_host_platform_device_count` when the backend initializes, so
the flag must be in the environment BEFORE anything imports jax. pytest
imports conftest.py before collecting any test module, which makes this
top-level assignment the "early-import" pattern: every test in the suite
sees 8 emulated CPU devices on a bare single-CPU CI runner, and sharded
tests (`tests/test_sharded_compress.py`, the multi-device cases in
`tests/test_sharding.py`) run for real instead of skipping. If jax was
somehow initialized first (e.g. a plugin imported it), the
`emulated_devices` fixture skips those tests instead of failing them.
"""

import os
import sys
from pathlib import Path

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

# benchmarks.common is the canonical ATM/Hurricane-like field generator the
# golden suite freezes; make it importable when pytest is launched from
# anywhere (the repo root is not otherwise guaranteed on sys.path)
_ROOT = str(Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden",
        action="store_true",
        default=False,
        help="rewrite tests/golden/*.json from the current estimators "
        "(tests/test_golden_decisions.py) instead of comparing against them",
    )


@pytest.fixture(scope="session")
def emulated_devices():
    """Session-scoped gate for tests that need the 8 emulated devices."""
    import jax

    if jax.device_count() < 8:
        pytest.skip(
            "needs 8 emulated devices — jax initialized before conftest set "
            "XLA_FLAGS (run via pytest, not with a preloaded jax)"
        )
    return jax.devices()


@pytest.fixture(scope="session")
def update_golden(request):
    return request.config.getoption("--update-golden")
