"""Integration tests: training loop, checkpoint/restart fault tolerance,
gradient compression, data determinism, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Policy
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.optim import AdamWConfig, GradCompressConfig
from repro.runtime.steps import init_opt_state, make_train_step


def _setup(arch="smollm-360m", layers=2, gc=None):
    cfg = reduced_for_smoke(get_config(arch)).scaled(n_layers=layers)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    opt = init_opt_state(params, gc)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=100, warmup_steps=5), gc))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    return model, params, opt, step, dcfg


def _run(params, opt, step, dcfg, n, start=0):
    losses = []
    for s in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    return params, opt, losses


def test_training_reduces_loss():
    _, params, opt, step, dcfg = _setup()
    _, _, losses = _run(params, opt, step, dcfg, 25)
    assert np.mean(losses[-5:]) < losses[0] - 0.3, losses


def test_grad_compression_convergence_tracks_baseline():
    _, p0, o0, s0, dcfg = _setup()
    _, _, base = _run(p0, o0, s0, dcfg, 20)
    gc = GradCompressConfig(eb_rel=1e-3)
    _, p1, o1, s1, _ = _setup(gc=gc)
    _, _, comp = _run(p1, o1, s1, dcfg, 20)
    # compressed-gradient training must track the baseline closely
    assert abs(np.mean(comp[-5:]) - np.mean(base[-5:])) < 0.25, (base[-5:], comp[-5:])


def test_data_pipeline_deterministic_and_sharded():
    dcfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    a = synthetic_batch(dcfg, step=3)
    b = synthetic_batch(dcfg, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(dcfg, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard determinism: different shards differ, same shard reproduces
    s0 = synthetic_batch(dcfg, 5, shard=0, n_shards=2)
    s1 = synthetic_batch(dcfg, 5, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_checkpoint_save_restore_resume(tmp_path):
    model, params, opt, step, dcfg = _setup()
    params, opt, _ = _run(params, opt, step, dcfg, 5)
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), compress=False))
    mgr.save(5, {"params": params, "opt": opt["adam"]})
    assert mgr.latest_step() == 5
    # simulate failure: fresh process state, restore, continue
    _, params2, opt2, step2, _ = _setup()
    st, restored = mgr.restore_tree({"params": params2, "opt": opt2["adam"]})
    assert st == 5
    for (ka, a), (kb, b) in zip(
        jax.tree_util.tree_leaves_with_path(params),
        jax.tree_util.tree_leaves_with_path(restored["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continues training
    opt2["adam"] = restored["opt"]
    _, _, losses = _run(restored["params"], opt2, step2, dcfg, 3, start=5)
    assert np.isfinite(losses).all()


def test_checkpoint_lossy_roundtrip_bounded(tmp_path):
    _, params, opt, _, _ = _setup()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-4), compress=True))
    mgr.save(1, {"params": params})
    _, restored = mgr.restore_tree({"params": params})
    for (pa, a), b in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_leaves(restored["params"]),
    ):
        a = np.asarray(a, dtype=np.float32)
        b = np.asarray(b, dtype=np.float32)
        vr = a.max() - a.min()
        if a.size >= 64 and vr > 0:
            assert np.abs(a - b).max() <= 1e-4 * vr * 1.05, pa
    # compressed manifest exists and records selection bits
    import json, glob
    man = json.load(open(glob.glob(str(tmp_path) + "/step_*/manifest.json")[0]))
    assert man["total_bytes"] < man["raw_bytes"]
    assert set(man["selection_bits"].values()) <= {"sz", "zfp", "raw", "none"}


def test_checkpoint_keep_n_and_atomicity(tmp_path):
    _, params, _, _, _ = _setup(layers=1)
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep_n=2, compress=False))
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": params})
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000003", "step_000000004"]
    assert mgr.latest_step() == 4
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_checkpoint_prunes_torn_tmp_dirs(tmp_path):
    """Regression: a crash between staging and promotion leaves a
    `.tmp_step_*` dir behind; the keep-N pruner must GC tmps older than
    the newest committed step while leaving newer (possibly in-flight)
    tmps alone."""
    _, params, _, _, _ = _setup(layers=1)
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep_n=2, compress=False))
    mgr.save(1, {"params": params})
    # plant a torn write: a save at step 2 that crashed before promotion
    torn = tmp_path / ".tmp_step_000000002_12345"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    # and an in-flight staging dir AHEAD of the next commit
    live = tmp_path / ".tmp_step_000000009_67890"
    live.mkdir()
    mgr.save(3, {"params": params})
    names = set(os.listdir(tmp_path))
    assert torn.name not in names, "torn tmp older than newest commit must be GCed"
    assert live.name in names, "tmp at/above newest commit may be in flight"
    assert mgr.latest_step() == 3


def test_async_checkpoint(tmp_path):
    _, params, _, _, _ = _setup(layers=1)
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), compress=False))
    t = mgr.async_save(7, {"params": params})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_restore_different_mesh(tmp_path):
    """Checkpoint is mesh-agnostic: save from one layout, restore under a
    different (1,1) mesh and device_put with new shardings."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.mesh import make_local_mesh

    _, params, _, _, _ = _setup(layers=1)
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), compress=False))
    mgr.save(1, {"params": params})
    _, restored = mgr.restore_tree({"params": params})
    mesh = make_local_mesh()
    sh = NamedSharding(mesh, PartitionSpec())
    placed = jax.tree_util.tree_map(lambda a: jax.device_put(a, sh), restored["params"])
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_prefill_decode():
    from repro.runtime.steps import make_decode_step, make_prefill_step

    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    b = 2
    cache = model.init_cache(b, 32)
    prompts = jnp.ones((b, 8), jnp.int32)
    logits, cache = jax.jit(make_prefill_step(model))(params, {"tokens": prompts}, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    decode = jax.jit(make_decode_step(model))
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        nxt, cache = decode(params, nxt, cache)
    assert int(cache["pos"]) == 8 + 4
