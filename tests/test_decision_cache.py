"""Differential test layer for the warm save path (DESIGN.md §8).

The contract under test: with the default ``DecisionCache(tolerance=0.0)``,
a warm save is *bit-identical* to a cold save — same codec decisions, same
error bounds, same encoded bytes — whenever the cache validates, and any
change that could alter the decision (content drift, scale jump, NaN
injection, dtype/shape change, a different Policy) invalidates the entry
and re-decides from scratch. The cache must never serve a stale decision.

One subtlety this suite is careful about: Stage I's f32 prefix-sum
estimator makes each field's estimate depend on which fields share its
packed launch (ulp-level batch composition, see `selector.select_many`).
After a partial invalidation the misses re-decide in a *smaller* batch, so
the differential reference for those fields is a fresh cold call on the
SAME subset — not the original full-tree cold call.
"""

import json

import numpy as np
import pytest

import repro.core as rc
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import controller as ctl
from repro.core import selector as sel
from repro.core.decision_cache import CacheEntry, DecisionCache
from repro.core.policy import Policy


def _fields(seed=0):
    rng = np.random.default_rng(seed)
    smooth2d = np.cumsum(
        rng.standard_normal((96, 96)).astype(np.float32), axis=0
    )
    ramp3d = (
        np.linspace(0.0, 4.0, 16 * 48 * 48, dtype=np.float32).reshape(16, 48, 48)
        + 0.05 * rng.standard_normal((16, 48, 48)).astype(np.float32)
    )
    rough1d = rng.standard_normal((4096,)).astype(np.float32)
    return [smooth2d, ramp3d, rough1d]


NAMES = ["smooth2d", "ramp3d", "rough1d"]
POL = Policy.fixed_accuracy(eb_rel=1e-3)


# -- warm ≡ cold: decisions, bounds, bytes --------------------------------


def test_warm_decisions_bit_identical_to_cold():
    fields = _fields()
    cold = sel.select_many(fields, policy=POL)
    cache = DecisionCache()
    first = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    warm = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    assert first == cold  # populating pass must not change decisions
    assert warm == cold  # served-from-cache pass is bit-identical
    assert cache.stats()["hits"] == len(fields)
    assert all(cache.events[n] == "hit" for n in NAMES)


def test_warm_bytes_bit_identical_to_cold():
    fields = _fields()
    tree = dict(zip(NAMES, fields))
    cold = rc.compress_pytree(tree, policy=POL)
    cache = DecisionCache()
    rc.compress_pytree(tree, policy=POL, cache=cache)
    warm = rc.compress_pytree(tree, policy=POL, cache=cache)
    for name in cold.fields:
        assert warm.fields[name].data == cold.fields[name].data
        assert warm.fields[name].codec == cold.fields[name].codec
    assert cache.stats()["hits"] == len(fields)


@pytest.mark.parametrize("mode", ["fixed_psnr", "fixed_ratio"])
def test_warm_solutions_bit_identical_to_cold(mode):
    fields = _fields()
    pol = Policy.fixed_psnr(60.0) if mode == "fixed_psnr" else Policy.fixed_ratio(8.0)
    cold = ctl.solve_many(fields, pol)
    cache = DecisionCache()
    first = ctl.solve_many(fields, pol, cache=cache, names=NAMES)
    warm = ctl.solve_many(fields, pol, cache=cache, names=NAMES)
    assert first == cold
    assert warm == cold
    assert cache.stats()["hits"] == len(fields)


def test_epsilon_perturbation_invalidates_and_matches_subset_cold():
    """An ulp-scale nudge still flips the content digest: the entry must
    invalidate and the re-decision must equal a fresh cold call on the
    same miss subset (batch-composition-faithful reference)."""
    fields = _fields()
    cache = DecisionCache()
    sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    bumped = [fields[0].copy(), fields[1], fields[2]]
    bumped[0][0, 0] = np.nextafter(bumped[0][0, 0], np.float32(np.inf))
    warm = sel.select_many(bumped, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "invalidated"
    assert cache.events["ramp3d"] == "hit"
    assert cache.events["rough1d"] == "hit"
    # the re-decided field ran alone -> compare against a solo cold call
    ref = sel.select_many([bumped[0]], policy=POL)
    assert warm[0] == ref[0]
    # untouched fields still serve the original decision
    cold = sel.select_many(fields, policy=POL)
    assert warm[1] == cold[1] and warm[2] == cold[2]


# -- invalidation triggers -------------------------------------------------


def test_scale_jump_invalidates():
    fields = _fields()
    cache = DecisionCache()
    sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    jumped = [fields[0] * 1000.0, fields[1], fields[2]]
    warm = sel.select_many(jumped, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "invalidated"
    assert warm[0] == sel.select_many([jumped[0]], policy=POL)[0]
    # the re-decided bound tracks the new value range, not the cached one
    assert warm[0].eb_abs == pytest.approx(
        1000.0 * POL.eb_rel * np.ptp(fields[0]), rel=1e-5
    )


def test_nan_injection_rederives_raw_never_stale():
    fields = _fields()
    cache = DecisionCache()
    first = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    assert first[0].codec != "raw"
    poisoned = [fields[0].copy(), fields[1], fields[2]]
    poisoned[0][3, 3] = np.nan
    warm = sel.select_many(poisoned, policy=POL, cache=cache, names=NAMES)
    assert warm[0].codec == "raw"  # degenerate fallback, not the cached sz/zfp
    # degenerate fields bypass the cache entirely: the stale entry must not
    # have been overwritten, and recovering the clean field hits again
    recovered = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    assert recovered[0] == first[0]
    assert cache.events["smooth2d"] == "hit"


def test_dtype_change_invalidates():
    fields = _fields()
    cache = DecisionCache()
    sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    as64 = [fields[0].astype(np.float64), fields[1], fields[2]]
    sel.select_many(as64, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "invalidated"


def test_shape_change_invalidates():
    fields = _fields()
    cache = DecisionCache()
    sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    reshaped = [fields[0].reshape(48, 192), fields[1], fields[2]]
    warm = sel.select_many(reshaped, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "invalidated"
    assert warm[0] == sel.select_many([reshaped[0]], policy=POL)[0]


def test_policy_change_invalidates():
    fields = _fields()
    cache = DecisionCache()
    sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    tighter = Policy.fixed_accuracy(eb_rel=1e-5)
    warm = sel.select_many(fields, policy=tighter, cache=cache, names=NAMES)
    assert all(cache.events[n] == "invalidated" for n in NAMES)
    assert warm == sel.select_many(fields, policy=tighter)
    # and the cache now holds the tighter-policy decisions
    again = sel.select_many(fields, policy=tighter, cache=cache, names=NAMES)
    assert again == warm and cache.events["smooth2d"] == "hit"


def test_solve_mode_entries_do_not_serve_fixed_accuracy():
    """A fixed_psnr entry and a fixed_accuracy entry share nothing: the
    policy key separates them, so switching modes always re-decides."""
    fields = _fields()
    cache = DecisionCache()
    ctl.solve_many(fields, Policy.fixed_psnr(60.0), cache=cache, names=NAMES)
    warm = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    assert all(cache.events[n] == "invalidated" for n in NAMES)
    assert warm == sel.select_many(fields, policy=POL)


# -- tolerance > 0 and warm-start -----------------------------------------


def test_tolerance_band_accepts_tiny_drift_rejects_jumps():
    fields = _fields()
    cache = DecisionCache(tolerance=0.05)
    first = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    drifted = [fields[0] * (1.0 + 1e-7), fields[1], fields[2]]
    warm = sel.select_many(drifted, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "hit"  # within the moment band
    assert warm[0] == first[0]  # served decision is the previous one
    jumped = [fields[0] * 3.0, fields[1], fields[2]]
    sel.select_many(jumped, policy=POL, cache=cache, names=NAMES)
    assert cache.events["smooth2d"] == "invalidated"


def test_warm_start_resolve_matches_quality_target():
    """warm_start seeds the secant from the stale bound; the re-solve must
    still land on target (quality contract is solver-enforced, not cached)."""
    fields = _fields()
    pol = Policy.fixed_psnr(60.0)
    cache = DecisionCache(warm_start=True)
    ctl.solve_many(fields, pol, cache=cache, names=NAMES)
    drifted = [f * 1.3 for f in fields]
    warm = ctl.solve_many(drifted, pol, cache=cache, names=NAMES)
    for sol in warm:
        if sol.selection.codec != "raw" and sol.on_target:
            assert sol.est_psnr == pytest.approx(60.0, abs=1.0)


# -- persistence -----------------------------------------------------------


def test_manifest_roundtrip_preserves_bit_identity():
    fields = _fields()
    cache = DecisionCache()
    cold = sel.select_many(fields, policy=POL, cache=cache, names=NAMES)
    record = json.loads(json.dumps(cache.to_manifest()))  # full JSON trip
    reloaded = DecisionCache()
    reloaded.load_manifest(record)
    warm = sel.select_many(fields, policy=POL, cache=reloaded, names=NAMES)
    assert warm == cold
    assert reloaded.stats()["hits"] == len(fields)


def test_checkpoint_manager_persists_and_resumes_warm(tmp_path):
    fields = _fields()
    tree = dict(zip(NAMES, fields))
    cfg = CheckpointConfig(directory=str(tmp_path), policy=POL, cache=True)
    mgr = CheckpointManager(cfg)
    mgr.save(0, tree)
    mgr.save(1, tree)
    assert mgr.cache.stats()["hits"] == len(fields)

    def rows(step):
        with open(tmp_path / f"step_{step:09d}" / "manifest.json") as f:
            man = json.load(f)
        return {f_["name"]: (f_["codec"], f_["nbytes"], f_["eb"])
                for f_ in man["fields"]}

    assert rows(0) == rows(1)
    with open(tmp_path / "step_000000001" / "manifest.json") as f:
        man = json.load(f)
    assert len(man["decision_cache"]["entries"]) == len(fields)

    # a NEW manager restoring this checkpoint resumes warm
    mgr2 = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), policy=POL, cache=True))
    step, flat = mgr2.restore()
    assert step == 1 and set(flat) == set(NAMES)
    mgr2.save(2, tree)
    assert mgr2.cache.stats()["hits"] == len(fields)
    assert rows(2) == rows(0)


def test_cache_off_by_default_manifest_clean(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=POL))
    mgr.save(0, dict(zip(NAMES, _fields())))
    with open(tmp_path / "step_000000000" / "manifest.json") as f:
        man = json.load(f)
    assert "decision_cache" not in man
    assert mgr.cache is None


# -- sharded engine --------------------------------------------------------


def test_sharded_plan_tree_warm_parity(emulated_devices):
    import jax

    from repro.core import sharded as shd

    mesh = jax.sharding.Mesh(np.array(emulated_devices[:4]), ("x",))
    spec = jax.sharding.PartitionSpec("x")
    rng = np.random.default_rng(7)
    fields = [
        jax.device_put(
            np.cumsum(rng.standard_normal((64, 64)).astype(np.float32), axis=0),
            jax.sharding.NamedSharding(mesh, spec),
        ),
        jax.device_put(
            np.cumsum(
                rng.standard_normal((32, 48, 16)).astype(np.float32), axis=1
            ),
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(None, "x")
            ),
        ),
    ]
    names = ["wa", "wb"]
    for pol in (POL, Policy.fixed_psnr(60.0)):
        cold = shd.plan_tree(fields, pol)
        cache = DecisionCache()
        shd.plan_tree(fields, pol, cache=cache, names=names)
        warm = shd.plan_tree(fields, pol, cache=cache, names=names)
        assert [p.reconcile for p in warm] == ["cached", "cached"]
        for pc, pw in zip(cold, warm):
            assert pw.selection == pc.selection
            ec = shd.encode_plan(fields[cold.index(pc)], pc)
            ew = shd.encode_plan(fields[cold.index(pc)], pw)
            assert [s.data for s in ec] == [s.data for s in ew]
        assert cache.stats()["hits"] == len(fields)


# -- API misuse ------------------------------------------------------------


def test_cache_requires_names():
    fields = _fields()
    with pytest.raises(ValueError, match="names"):
        sel.select_many(fields, policy=POL, cache=DecisionCache())
    with pytest.raises(ValueError, match="names"):
        sel.select_many(fields, policy=POL, cache=DecisionCache(),
                        names=["just_one"])


def test_cache_rejects_bad_tolerance():
    with pytest.raises(ValueError):
        DecisionCache(tolerance=-0.1)
    with pytest.raises(ValueError):
        DecisionCache(tolerance=float("nan"))


def test_entry_roundtrips_selection_and_solution():
    fields = _fields()
    cache = DecisionCache()
    sols = ctl.solve_many(fields, Policy.fixed_psnr(60.0), cache=cache,
                          names=NAMES)
    e = cache.entries["smooth2d"]
    assert isinstance(e, CacheEntry)
    assert e.to_selection() == sols[0].selection
    assert e.to_solution() == sols[0]
