"""Deterministic tests for Stage I transforms (paper Theorems 1 & 3).

The randomized hypothesis versions live in test_property_transforms.py
behind `pytest.importorskip("hypothesis")`.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.transforms import (
    BOT_PRESETS,
    blockize,
    block_transform_nd,
    bot_linf_gain,
    bot_matrix,
    lorenzo_forward,
    lorenzo_inverse,
    unblockize,
)

DIMS = [(64,), (17,), (16, 24), (9, 33), (8, 12, 20), (5, 6, 7)]


@pytest.mark.parametrize("shape", DIMS)
def test_lorenzo_roundtrip_exact_on_integers(shape):
    """PBT is lossless over integer codes (the prequantization invariant)."""
    rng = np.random.default_rng(hash(shape) % 2**31)
    k = rng.integers(-1000, 1000, size=shape).astype(np.float32)
    d = lorenzo_forward(jnp.asarray(k))
    back = lorenzo_inverse(d)
    np.testing.assert_array_equal(np.asarray(back), k)


@pytest.mark.parametrize("preset", sorted(BOT_PRESETS))
def test_bot_matrix_orthogonal(preset):
    T = bot_matrix(preset)
    np.testing.assert_allclose(T @ T.T, np.eye(4), atol=1e-12)


@pytest.mark.parametrize("nd", [1, 2, 3])
def test_lemma2_l2_invariance_any_dim(nd):
    """Lemma 2: BOT preserves the elementwise L2 norm for any ndim."""
    rng = np.random.default_rng(nd)
    blocks = jnp.asarray(rng.standard_normal((7,) + (4,) * nd).astype(np.float32))
    for t in (0.0, 0.25, 0.5, BOT_PRESETS["zfp"]):
        T = jnp.asarray(bot_matrix(float(t)), jnp.float32)
        out = block_transform_nd(blocks, T, nd)
        np.testing.assert_allclose(
            float(jnp.linalg.norm(out)), float(jnp.linalg.norm(blocks)), rtol=1e-5
        )


@pytest.mark.parametrize("shape", DIMS)
def test_blockize_roundtrip(shape):
    rng = np.random.default_rng(len(shape))
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    blocks, padded = blockize(x)
    assert blocks.shape[1:] == (4,) * len(shape)
    back = unblockize(blocks, padded, shape)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("shape", DIMS)
def test_theorem1_pointwise_error_preserved(shape):
    """Theorem 1: X - X~ == X_pbt - X~_pbt pointwise (over exact integers)."""
    rng = np.random.default_rng(sum(shape))
    k = rng.integers(-500, 500, size=shape).astype(np.float64)
    kq = np.round(k + rng.uniform(-0.4, 0.4, size=shape))  # perturbed codes
    d, dq = lorenzo_forward(jnp.asarray(k)), lorenzo_forward(jnp.asarray(kq))
    lhs = k - np.asarray(lorenzo_inverse(dq))
    rhs = np.asarray(d) - np.asarray(dq)
    np.testing.assert_allclose(
        np.asarray(lorenzo_inverse(jnp.asarray(rhs))), lhs, atol=1e-6
    )


@pytest.mark.parametrize("nd", [1, 2, 3])
def test_theorem3_mse_preserved_through_bot(nd):
    """Theorem 3: L2 error in coefficient space == L2 error in data space."""
    rng = np.random.default_rng(nd)
    x = jnp.asarray(rng.standard_normal((5,) + (4,) * nd).astype(np.float32))
    T = jnp.asarray(bot_matrix("zfp"), jnp.float32)
    c = block_transform_nd(x, T, nd)
    noise = jnp.asarray(rng.standard_normal(c.shape).astype(np.float32)) * 0.01
    x_rec = block_transform_nd(c + noise, T, nd, inverse=True)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(x - x_rec)), float(jnp.linalg.norm(noise)), rtol=1e-4
    )


def test_bot_inverse_transform():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 4, 4)).astype(np.float32))
    T = jnp.asarray(bot_matrix("dct2"), jnp.float32)
    c = block_transform_nd(x, T, 2)
    back = block_transform_nd(c, T, 2, inverse=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-5)


def test_linf_gain_sane():
    g = bot_linf_gain("zfp")
    assert 1.0 <= g <= 2.0
