"""Unit tests for the logical-axis sharding rules + mesh utilities."""

import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as sh


def _mesh(shape=(1, 1), axes=("data", "model")):
    return jax.make_mesh(shape, axes)


def test_spec_resolution_basic():
    mesh = _mesh()
    assert sh.spec_for_axes(("embed", "mlp"), sh.TRAIN_RULES, mesh) == P("data", "model")
    assert sh.spec_for_axes(("vocab", "embed"), sh.TRAIN_RULES, mesh) == P("model", "data")
    assert sh.spec_for_axes(("norm",), sh.TRAIN_RULES, mesh) == P(None)


def test_spec_never_reuses_mesh_axis():
    mesh = _mesh()
    # experts and mlp both map to 'model' — second one must drop to None
    spec = sh.spec_for_axes(("experts", "embed", "mlp"), sh.TRAIN_RULES, mesh)
    assert spec == P("model", "data", None)


def test_spec_drops_axes_missing_from_mesh():
    mesh = _mesh()
    spec = sh.spec_for_axes(("batch", None, None), sh.TRAIN_RULES, mesh)
    # 'pod' not in the mesh: batch maps to just 'data'
    assert spec == P("data", None, None)


def test_tree_shardings_divisibility_filter():
    mesh = _mesh()
    abstract = {"w": jax.ShapeDtypeStruct((7, 8), np.float32)}
    axes = {"w": ("vocab", "embed")}
    shd = sh.tree_shardings(axes, sh.TRAIN_RULES, mesh, abstract=abstract)
    # both divisible by 1 on a (1,1) mesh
    assert shd["w"].spec == P("model", "data")
    mesh2 = jax.make_mesh((1,), ("model",))
    shd2 = sh.tree_shardings(axes, {"vocab": "model", "embed": None}, mesh2, abstract=abstract)
    assert shd2["w"].spec == P("model", None)


def test_cache_sharding_finds_batch_and_heads():
    mesh = _mesh()
    cache = {
        "k": jax.ShapeDtypeStruct((32, 128, 1024, 8, 64), np.float32),  # (L,B,M,H,D)
        "pos": jax.ShapeDtypeStruct((), np.int32),
    }
    shd = sh.cache_sharding(cache, mesh, batch=128, head_sizes={8})
    assert shd["k"].spec == P(None, "data", None, "model", None)
    assert shd["pos"].spec == P()


def test_cache_sharding_seq_fallback():
    mesh = _mesh()
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 4096, 3, 64), np.float32)}
    # no dim matches a head size -> baseline leaves everything but batch
    base = sh.cache_sharding(cache, mesh, batch=128, head_sizes={999})
    assert base["k"].spec == P(None, "data", None, None, None)
    # seq variant shards the first long divisible dim (the sequence) instead
    seq = sh.cache_sharding(cache, mesh, batch=128, head_sizes={999}, seq_shard=True)
    assert seq["k"].spec == P(None, "data", "model", None, None)
    # head dim takes priority over seq when it matches
    pri = sh.cache_sharding(cache, mesh, batch=128, head_sizes={3}, seq_shard=True)
    assert pri["k"].spec == P(None, "data", None, "model", None)


def test_activation_constraint_guard():
    """nn.shard drops mesh axes that don't divide the dim."""
    import jax.numpy as jnp
    from repro.models import nn

    mesh = _mesh()
    with sh.activate(mesh, sh.TRAIN_RULES):
        x = jnp.zeros((4, 8, 15, 32))  # 15 'heads' on 1-way model: fine
        out = nn.shard(x, "batch", None, "heads", None)
        assert out.shape == x.shape
    assert nn._SHARD_FN is None  # deactivated


def test_mesh_builders():
    from repro.launch.mesh import make_local_mesh

    m = make_local_mesh()
    assert m.axis_names == ("data", "model")
    assert int(np.prod(m.devices.shape)) == 1


# ---------------------------------------------------------------------------
# Multi-device cases — previously impossible on 1 CPU device, now running
# for real on the 8 emulated devices tests/conftest.py provides.
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_emulated_mesh_builder(emulated_devices):
    from repro.launch.mesh import make_emulated_mesh

    m = make_emulated_mesh((2, 4), ("data", "model"))
    assert m.devices.shape == (2, 4)
    with pytest.raises(RuntimeError, match="devices"):
        make_emulated_mesh((16, 16), ("data", "model"))


@pytest.mark.multidevice
def test_sharded_constraint_actually_shards(emulated_devices):
    """On a real multi-device mesh, nn.shard() constraints materialize as
    multi-device shardings with per-device shards of the expected size."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec
    from repro.models import nn

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with sh.activate(mesh, sh.TRAIN_RULES):

        @jax.jit
        def f(x):
            return nn.shard(x, "batch", None, "heads", None)

        out = f(jnp.zeros((16, 8, 12, 32)))
    assert len(out.sharding.device_set) == 8
    # batch 16 over 2-way data, heads 12 over 4-way model (trailing Nones
    # may be normalized away by the sharding)
    spec = tuple(out.sharding.spec)
    assert spec[:3] == ("data", None, "model") and all(p is None for p in spec[3:])
    assert out.addressable_shards[0].data.shape == (8, 8, 3, 32)


@pytest.mark.multidevice
def test_unique_shards_and_replicas(emulated_devices):
    """`unique_shards` dedupes replica groups and tiles the array exactly."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jax.device_put(
        np.arange(64 * 32, dtype=np.float32).reshape(64, 32),
        NamedSharding(mesh, P("data", None)),
    )
    shards = sh.unique_shards(x)
    assert len(shards) == 2  # 2 data shards, each replicated over 4 model devices
    assert all(len(devs) == 4 for _, _, devs in shards)
    assert [s[0] for s in shards] == [(0, 0), (32, 0)]
    assert [s[1] for s in shards] == [(32, 32), (64, 32)]
    got = np.empty((64, 32), np.float32)
    for start, stop, devs in shards:
        got[tuple(slice(a, b) for a, b in zip(start, stop))] = sh.shard_data(x, devs[0])
    np.testing.assert_array_equal(got, np.asarray(x))
    # replicated array: one segment, all devices in the group
    r = jax.device_put(np.zeros((8, 8), np.float32), NamedSharding(mesh, P()))
    (seg,) = sh.unique_shards(r)
    assert seg[:2] == ((0, 0), (8, 8)) and len(seg[2]) == 8


@pytest.mark.multidevice
def test_mesh_of_and_spec_entries(emulated_devices):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jax.device_put(np.zeros((16, 8, 4), np.float32), NamedSharding(mesh, P("data")))
    assert sh.mesh_of(x) is not None
    assert sh.spec_entries(x) == ("data", None, None)
    assert sh.mesh_of(np.zeros(3)) is None


@pytest.mark.multidevice
def test_cache_sharding_places_multidevice(emulated_devices):
    """cache_sharding on a real (2,4) mesh: batch over data, heads over
    model, and the seq fallback — checked against actual shard shapes."""
    import jax
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((4, 16, 256, 8, 16), np.float32)}
    shd = sh.cache_sharding(cache, mesh, batch=16, head_sizes={8})
    assert shd["k"].spec == P(None, "data", None, "model", None)
    arr = jax.device_put(np.zeros((4, 16, 256, 8, 16), np.float32), shd["k"])
    assert arr.addressable_shards[0].data.shape == (4, 8, 256, 2, 16)
