"""Unit tests for the logical-axis sharding rules + mesh utilities."""

import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as sh


def _mesh(shape=(1, 1), axes=("data", "model")):
    return jax.make_mesh(shape, axes)


def test_spec_resolution_basic():
    mesh = _mesh()
    assert sh.spec_for_axes(("embed", "mlp"), sh.TRAIN_RULES, mesh) == P("data", "model")
    assert sh.spec_for_axes(("vocab", "embed"), sh.TRAIN_RULES, mesh) == P("model", "data")
    assert sh.spec_for_axes(("norm",), sh.TRAIN_RULES, mesh) == P(None)


def test_spec_never_reuses_mesh_axis():
    mesh = _mesh()
    # experts and mlp both map to 'model' — second one must drop to None
    spec = sh.spec_for_axes(("experts", "embed", "mlp"), sh.TRAIN_RULES, mesh)
    assert spec == P("model", "data", None)


def test_spec_drops_axes_missing_from_mesh():
    mesh = _mesh()
    spec = sh.spec_for_axes(("batch", None, None), sh.TRAIN_RULES, mesh)
    # 'pod' not in the mesh: batch maps to just 'data'
    assert spec == P("data", None, None)


def test_tree_shardings_divisibility_filter():
    mesh = _mesh()
    abstract = {"w": jax.ShapeDtypeStruct((7, 8), np.float32)}
    axes = {"w": ("vocab", "embed")}
    shd = sh.tree_shardings(axes, sh.TRAIN_RULES, mesh, abstract=abstract)
    # both divisible by 1 on a (1,1) mesh
    assert shd["w"].spec == P("model", "data")
    mesh2 = jax.make_mesh((1,), ("model",))
    shd2 = sh.tree_shardings(axes, {"vocab": "model", "embed": None}, mesh2, abstract=abstract)
    assert shd2["w"].spec == P("model", None)


def test_cache_sharding_finds_batch_and_heads():
    mesh = _mesh()
    cache = {
        "k": jax.ShapeDtypeStruct((32, 128, 1024, 8, 64), np.float32),  # (L,B,M,H,D)
        "pos": jax.ShapeDtypeStruct((), np.int32),
    }
    shd = sh.cache_sharding(cache, mesh, batch=128, head_sizes={8})
    assert shd["k"].spec == P(None, "data", None, "model", None)
    assert shd["pos"].spec == P()


def test_cache_sharding_seq_fallback():
    mesh = _mesh()
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 4096, 3, 64), np.float32)}
    # no dim matches a head size -> baseline leaves everything but batch
    base = sh.cache_sharding(cache, mesh, batch=128, head_sizes={999})
    assert base["k"].spec == P(None, "data", None, None, None)
    # seq variant shards the first long divisible dim (the sequence) instead
    seq = sh.cache_sharding(cache, mesh, batch=128, head_sizes={999}, seq_shard=True)
    assert seq["k"].spec == P(None, "data", "model", None, None)
    # head dim takes priority over seq when it matches
    pri = sh.cache_sharding(cache, mesh, batch=128, head_sizes={3}, seq_shard=True)
    assert pri["k"].spec == P(None, "data", None, "model", None)


def test_activation_constraint_guard():
    """nn.shard drops mesh axes that don't divide the dim."""
    import jax.numpy as jnp
    from repro.models import nn

    mesh = _mesh()
    with sh.activate(mesh, sh.TRAIN_RULES):
        x = jnp.zeros((4, 8, 15, 32))  # 15 'heads' on 1-way model: fine
        out = nn.shard(x, "batch", None, "heads", None)
        assert out.shape == x.shape
    assert nn._SHARD_FN is None  # deactivated


def test_mesh_builders():
    from repro.launch.mesh import make_local_mesh

    m = make_local_mesh()
    assert m.axis_names == ("data", "model")
    assert int(np.prod(m.devices.shape)) == 1
