"""async_save error handling: a failed checkpoint must fail loudly.

Before the fix, `async_save` ran `save` on a bare Thread — an encoder
exception killed the worker silently, `wait()` joined cleanly, and the
training loop kept running with NO checkpoint on disk (and a stale
LATEST pointing at an older step). Now the worker parks the exception and
`wait()` re-raises it."""

import numpy as np
import pytest

from repro.core import Policy
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import selector as sel


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": np.cumsum(rng.standard_normal((96, 96)), axis=0).astype(np.float32),
        "b": rng.standard_normal((96,)).astype(np.float32),
    }


def test_async_save_surfaces_encoder_exception(tmp_path, monkeypatch):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))

    def boom(*a, **k):
        raise ValueError("encoder exploded")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    mgr.async_save(1, _tree())
    with pytest.raises(ValueError, match="encoder exploded"):
        mgr.wait()
    # the failed save must not have published anything
    assert mgr.latest_step() is None


def test_async_save_recovers_after_failure(tmp_path, monkeypatch):
    """A later good save works and wait() no longer re-raises stale errors."""
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))
    orig = sel.encode_with_selection

    def boom(*a, **k):
        raise RuntimeError("transient")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    mgr.async_save(1, _tree())
    with pytest.raises(RuntimeError):
        mgr.wait()
    monkeypatch.setattr(sel, "encode_with_selection", orig)
    mgr.async_save(2, _tree())
    mgr.wait()  # no raise
    step, flat = mgr.restore()
    assert step == 2 and "w" in flat
    mgr.wait()  # idempotent: the old exception is not replayed


def test_sync_save_propagates_inline(tmp_path, monkeypatch):
    """The synchronous path already propagated via Future.result(); keep it."""
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))

    def boom(*a, **k):
        raise ValueError("encoder exploded")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    with pytest.raises(ValueError, match="encoder exploded"):
        mgr.save(1, _tree())
