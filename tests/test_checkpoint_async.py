"""async_save error handling: a failed checkpoint must fail loudly.

Before the fix, `async_save` ran `save` on a bare Thread — an encoder
exception killed the worker silently, `wait()` joined cleanly, and the
training loop kept running with NO checkpoint on disk (and a stale
LATEST pointing at an older step). Now the worker parks the exception and
`wait()` re-raises it."""

import numpy as np
import pytest

from repro.core import Policy
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import selector as sel


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": np.cumsum(rng.standard_normal((96, 96)), axis=0).astype(np.float32),
        "b": rng.standard_normal((96,)).astype(np.float32),
    }


def test_async_save_surfaces_encoder_exception(tmp_path, monkeypatch):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))

    def boom(*a, **k):
        raise ValueError("encoder exploded")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    mgr.async_save(1, _tree())
    with pytest.raises(ValueError, match="encoder exploded"):
        mgr.wait()
    # the failed save must not have published anything
    assert mgr.latest_step() is None


def test_async_save_recovers_after_failure(tmp_path, monkeypatch):
    """A later good save works and wait() no longer re-raises stale errors."""
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))
    orig = sel.encode_with_selection

    def boom(*a, **k):
        raise RuntimeError("transient")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    mgr.async_save(1, _tree())
    with pytest.raises(RuntimeError):
        mgr.wait()
    monkeypatch.setattr(sel, "encode_with_selection", orig)
    mgr.async_save(2, _tree())
    mgr.wait()  # no raise
    step, flat = mgr.restore()
    assert step == 2 and "w" in flat
    mgr.wait()  # idempotent: the old exception is not replayed


def test_sync_save_propagates_inline(tmp_path, monkeypatch):
    """The synchronous path already propagated via Future.result(); keep it."""
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_accuracy(eb_rel=1e-3)))

    def boom(*a, **k):
        raise ValueError("encoder exploded")

    monkeypatch.setattr(sel, "encode_with_selection", boom)
    with pytest.raises(ValueError, match="encoder exploded"):
        mgr.save(1, _tree())


# ---------------------------------------------------------------------------
# BarrierTimeout requeue (DESIGN.md §6.2): a transiently straggling host
# fails the attempt; the manager re-runs the write phase under a FRESH
# save sequence (fresh KV barrier keys) up to cfg.save_retries times.
# ---------------------------------------------------------------------------

from repro.runtime import dist  # noqa: E402


def _flaky_barrier(fail_first_n):
    """A dist.barrier stand-in that times out on its first N calls and
    records every barrier key it saw."""
    calls = []

    def barrier(name, timeout_s):
        calls.append(name)
        if len(calls) <= fail_first_n:
            raise dist.BarrierTimeout(f"barrier {name!r} timed out (injected)")

    return barrier, calls


def test_save_requeues_once_on_barrier_timeout(tmp_path, monkeypatch):
    mgr = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_psnr(50.0))
    )
    barrier, calls = _flaky_barrier(fail_first_n=1)
    monkeypatch.setattr(dist, "barrier", barrier)
    path = mgr.save(1, _tree())
    assert mgr.last_save_retries == 1
    # each attempt consumed its own save sequence -> fresh barrier keys,
    # so a late arrival at the abandoned attempt can never satisfy the new one
    assert len(calls) == 2 and calls[0] != calls[1]
    step, flat = mgr.restore()
    assert step == 1 and flat["w"].shape == (96, 96)
    assert path.endswith("step_000000001")


def test_save_persistent_barrier_timeout_raises(tmp_path, monkeypatch):
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=str(tmp_path), policy=Policy.fixed_psnr(50.0), save_retries=2
        )
    )
    barrier, calls = _flaky_barrier(fail_first_n=10**9)
    monkeypatch.setattr(dist, "barrier", barrier)
    with pytest.raises(dist.BarrierTimeout):
        mgr.save(1, _tree())
    assert len(calls) == 3  # initial attempt + save_retries requeues
    assert len(set(calls)) == 3  # every attempt under its own seq


def test_save_retries_zero_disables_requeue(tmp_path, monkeypatch):
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=str(tmp_path), policy=Policy.fixed_psnr(50.0), save_retries=0
        )
    )
    barrier, calls = _flaky_barrier(fail_first_n=10**9)
    monkeypatch.setattr(dist, "barrier", barrier)
    with pytest.raises(dist.BarrierTimeout):
        mgr.save(1, _tree())
    assert len(calls) == 1


def test_async_save_result_reports_retries(tmp_path, monkeypatch):
    """The async caller's view: wait() is clean after a requeued save and
    thread.save_result carries the landing path + retry count."""
    mgr = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_psnr(50.0))
    )
    barrier, _calls = _flaky_barrier(fail_first_n=1)
    monkeypatch.setattr(dist, "barrier", barrier)
    thread = mgr.async_save(4, _tree())
    mgr.wait()  # no raise: the single injected timeout was absorbed
    assert thread.save_result == {
        "path": thread.save_result["path"],
        "retries": 1,
    }
    assert thread.save_result["path"].endswith("step_000000004")
    step, _ = mgr.restore()
    assert step == 4


def test_async_save_persistent_timeout_surfaces_in_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(
        CheckpointConfig(
            directory=str(tmp_path), policy=Policy.fixed_psnr(50.0), save_retries=1
        )
    )
    barrier, calls = _flaky_barrier(fail_first_n=10**9)
    monkeypatch.setattr(dist, "barrier", barrier)
    thread = mgr.async_save(5, _tree())
    with pytest.raises(dist.BarrierTimeout):
        mgr.wait()
    assert thread.save_result is None
    assert len(calls) == 2
    # host 0 publishes BEFORE the final fence, so the bytes may be on disk
    # — but the save still FAILED loudly: no silent success, no hang
