"""Continuous-batching scheduler: slot reuse, wave admission, correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.runtime.batcher import ContinuousBatcher, Request


def _setup():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    return cfg, model, params


def test_batcher_matches_single_stream():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, 8).astype(np.int32) for _ in range(3)]
    b = ContinuousBatcher(model, params, slots=4, max_len=32, eos_id=-1)
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    b.run(reqs)
    assert all(r.done for r in reqs)
    # reference: single-request greedy decode
    for r in reqs:
        cache = model.init_cache(1, 32)
        logits, cache = model.forward(params, {"tokens": jnp.asarray(r.prompt)[None]}, cache=cache)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(5):
            lg, cache = model.forward(
                params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)}, cache=cache
            )
            toks.append(int(jnp.argmax(lg[0, -1])))
        assert r.out[: len(toks)] == toks[: len(r.out)], (r.rid, r.out, toks)


def test_batcher_waves_reuse_slots():
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32), max_new=3)
        for i in range(5)
    ]
    b = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1)
    b.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 3 for r in reqs)
