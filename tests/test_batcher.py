"""Continuous-batching scheduler: slot reuse, wave admission, correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.runtime.batcher import ContinuousBatcher, Request


def _setup():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    return cfg, model, params


def test_batcher_matches_single_stream():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, 8).astype(np.int32) for _ in range(3)]
    b = ContinuousBatcher(model, params, slots=4, max_len=32, eos_id=-1)
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    b.run(reqs)
    assert all(r.done for r in reqs)
    # reference: single-request greedy decode
    for r in reqs:
        cache = model.init_cache(1, 32)
        logits, cache = model.forward(params, {"tokens": jnp.asarray(r.prompt)[None]}, cache=cache)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(5):
            lg, cache = model.forward(
                params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)}, cache=cache
            )
            toks.append(int(jnp.argmax(lg[0, -1])))
        assert r.out[: len(toks)] == toks[: len(r.out)], (r.rid, r.out, toks)


def test_batcher_waves_reuse_slots():
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32), max_new=3)
        for i in range(5)
    ]
    b = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1)
    b.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 3 for r in reqs)


def test_max_new_counts_emitted_tokens():
    """Regression: max_new=N must yield EXACTLY N tokens (the prefill
    token counts), on both the paged and the legacy contiguous path."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    for paged in (True, False):
        reqs = [
            Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new=5)
            for i in range(3)
        ]
        b = ContinuousBatcher(model, params, slots=4, max_len=32, eos_id=-1,
                              paged=paged)
        assert b.paged == paged
        b.run(reqs)
        for r in reqs:
            assert r.done and len(r.out) == 5, (paged, r.rid, r.out)


def test_eos_at_prefill_terminates_at_admission():
    """A request whose FIRST emitted token is EOS must finish at admission
    without ever occupying a decode slot (or, paged, any pages)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, 8).astype(np.int32)
    # learn the greedy first token, then make it the EOS id
    probe = ContinuousBatcher(model, params, slots=1, max_len=32, eos_id=-1)
    first_tok, _ = probe._prefill(prompt)
    for paged in (True, False):
        b = ContinuousBatcher(model, params, slots=2, max_len=32,
                              eos_id=first_tok, paged=paged)
        req = Request(rid=0, prompt=prompt, max_new=8)
        assert b.try_admit(req)
        assert req.done and req.out == [first_tok]
        assert not b.live.any()  # no slot occupied
        if paged:
            assert len(b.free_pages) == b.arena_pages  # no pages either
        assert b.step() == []  # nothing to decode


def test_paged_mid_wave_admission():
    """Per-slot clocks admit a new request while another is mid-decode —
    the legacy shared-clock path refuses exactly this."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    p0 = rng.integers(1, cfg.vocab, 8).astype(np.int32)
    p1 = rng.integers(1, cfg.vocab, 8).astype(np.int32)
    b = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1)
    assert b.paged
    assert b.try_admit(Request(rid=0, prompt=p0, max_new=10))
    for _ in range(3):
        b.step()  # slot 0 is now mid-wave
    r1 = Request(rid=1, prompt=p1, max_new=5)
    assert b.try_admit(r1)  # joins at clock 8 while slot 0 sits at 11
    b.run([])
    assert r1.done and len(r1.out) == 5
    # legacy path: same schedule is refused mid-wave
    bl = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1,
                           paged=False)
    assert bl.try_admit(Request(rid=0, prompt=p0, max_new=10))
    bl.step()
    assert not bl.try_admit(Request(rid=1, prompt=p1, max_new=5))


def test_paged_decode_matches_reference_streams():
    """Paged decode (page-table gather + per-slot clocks) reproduces the
    single-request contiguous reference token-for-token."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, 8).astype(np.int32) for _ in range(3)]
    b = ContinuousBatcher(model, params, slots=4, max_len=32, eos_id=-1,
                          page_tokens=8)
    assert b.paged
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    b.run(reqs)
    for r in reqs:
        cache = model.init_cache(1, 32)
        logits, cache = model.forward(
            params, {"tokens": jnp.asarray(r.prompt)[None]}, cache=cache
        )
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(5):
            lg, cache = model.forward(
                params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)}, cache=cache
            )
            toks.append(int(jnp.argmax(lg[0, -1])))
        assert r.out == toks, (r.rid, r.out, toks)


def test_paged_evict_restore_parity_under_pressure():
    """Compress-on-evict / decompress-on-hit at Policy.raw is invisible:
    a page-starved arena (forced LIFO preemption) decodes the same token
    streams as a pressure-free one."""
    from repro.core.policy import Policy

    cfg, model, params = _setup()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab, 12).astype(np.int32) for _ in range(4)]

    def run(arena_pages):
        b = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1,
                              page_tokens=8, arena_pages=arena_pages,
                              policies=Policy.raw())
        reqs = [Request(rid=i, prompt=p, max_new=20) for i, p in enumerate(prompts)]
        b.run(reqs)
        return reqs, b

    ref, calm = run(arena_pages=None)
    cur, tight = run(arena_pages=5)
    assert calm.stats["evictions"] == 0
    assert tight.stats["evictions"] > 0 and tight.stats["restores"] > 0
    for a, c in zip(ref, cur):
        assert a.done and c.done and len(c.out) == 20
        assert a.out == c.out, (a.rid, a.out, c.out)


def test_paged_policyset_resolved_per_request():
    """Admission resolves the request's quality contract once from the
    PolicySet: long-context requests get the fixed_ratio budget, short
    ones stay raw — and a lossy serving run still completes."""
    from repro.core.policy import serving_policies

    cfg, model, params = _setup()
    rng = np.random.default_rng(7)
    b = ContinuousBatcher(model, params, slots=2, max_len=32, eos_id=-1,
                          page_tokens=8, arena_pages=5,
                          policies=serving_policies(8.0), long_threshold=24)
    short = Request(rid=0, prompt=rng.integers(1, cfg.vocab, 4).astype(np.int32),
                    max_new=8)   # 4 + 8 < 24 -> raw
    long = Request(rid=1, prompt=rng.integers(1, cfg.vocab, 12).astype(np.int32),
                   max_new=20)  # 12 + 20 >= 24 -> fixed_ratio
    b.run([short, long])
    assert short.policy.mode == "raw" and short.pname == "kv/short/0"
    assert long.policy.mode == "fixed_ratio" and long.pname == "kv/long/1"
    assert short.done and long.done
    assert len(short.out) == 8 and len(long.out) == 20
