"""Hypothesis property tests for the byte codecs (optional dependency).

`pytest.importorskip` keeps a bare jax+numpy+pytest environment green; the
deterministic twins of these properties live in test_core_codecs.py.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

pytestmark = pytest.mark.property

from hypothesis import given, settings, strategies as st

from repro.core import sz_compress, sz_decompress, zfp_compress, zfp_decompress

from test_core_codecs import KINDS, SHAPES, _field, _tol


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(KINDS),
    eb_rel=st.sampled_from([1e-2, 1e-3, 1e-4]),
    shape=st.sampled_from(SHAPES),
)
def test_property_bounds_hold(seed, kind, eb_rel, shape):
    """Hypothesis: both codecs respect the user bound on arbitrary fields."""
    x = _field(shape, kind, seed)
    eb = eb_rel * (x.max() - x.min() + 1e-30)
    assert np.abs(x - sz_decompress(sz_compress(x, eb))).max() <= _tol(eb, x)
    assert np.abs(x - zfp_decompress(zfp_compress(x, eb))).max() <= _tol(eb, x)
