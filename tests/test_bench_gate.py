"""Unit tests for the CI bench gate's comparator (tools/bench_gate.py).

The gate's measurement half runs real benches (too slow for tier-1 — the
CI `bench` job runs it end to end); the COMPARATOR half is pure dict
logic and must be airtight: a missed decision flip or a mis-thresholded
ratio silently re-opens the regression hole the gate exists to close.
"""

import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", ROOT / "tools" / "bench_gate.py"
    )
    mod = importlib.util.module_from_spec(spec)
    sys.modules["bench_gate"] = mod
    spec.loader.exec_module(mod)
    return mod


def _metrics(codec="sz", eb_sz=1.0, speedup=3.0, err=0.1, warm=None):
    return {
        "decisions": {"f": {"codec": codec, "eb_sz": eb_sz}},
        "ratios": {"kernels3d_encode_stats_speedup": speedup},
        "estimation_error_b": err,
        "warm_save": warm
        if warm is not None
        else {"warm_overhead_pct": 2.0, "hit_rate": 1.0, "flips": []},
    }


def _baseline():
    return {
        "decisions": {"table40": {"f": {"codec": "sz", "eb_sz": 1.0}}},
        "ratios": {"kernels3d_encode_stats_speedup": 3.0},
        "estimation_error_b": 0.1,
    }


def test_gate_passes_on_identical_metrics(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    checks = bg.gate(_metrics(), _baseline())
    assert checks and all(c["passed"] for c in checks)


def test_gate_fails_on_decision_flip(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    checks = bg.gate(_metrics(codec="zfp"), _baseline())
    bad = [c for c in checks if not c["passed"]]
    assert len(bad) == 1 and bad[0]["name"] == "decisions[table40]"
    # a moved iso-PSNR bound (eb_sz) is a flip too
    checks = bg.gate(_metrics(eb_sz=1.001), _baseline())
    assert not [c for c in checks if c["name"] == "decisions[table40]"][0]["passed"]


def test_gate_ratio_threshold_is_20_percent(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    ok = bg.gate(_metrics(speedup=2.5), _baseline())  # floor = 2.4
    assert all(c["passed"] for c in ok)
    bad = bg.gate(_metrics(speedup=2.3), _baseline())
    assert not [c for c in bad if "kernels3d" in c["name"]][0]["passed"]


def test_gate_estimation_error_ceiling(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    # ceil = 0.1 * 1.2 + 0.05 = 0.17
    ok = bg.gate(_metrics(err=0.16), _baseline())
    assert all(c["passed"] for c in ok)
    bad = bg.gate(_metrics(err=0.2), _baseline())
    assert not [c for c in bad if c["name"] == "estimation_error_b"][0]["passed"]


def test_gate_warm_save_parity_fails_on_flips(monkeypatch):
    """Any warm-vs-cold decision flip fails the gate — parity is absolute,
    no baseline involved. A dropped cache hit fails the same check."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    ok = bg.gate(_metrics(), _baseline())
    assert [c for c in ok if c["name"] == "warm_save_parity"][0]["passed"]
    bad = bg.gate(
        _metrics(warm={"warm_overhead_pct": 2.0, "hit_rate": 1.0, "flips": ["atm/f0"]}),
        _baseline(),
    )
    par = [c for c in bad if c["name"] == "warm_save_parity"][0]
    assert not par["passed"] and "atm/f0" in par["detail"]
    bad = bg.gate(
        _metrics(warm={"warm_overhead_pct": 2.0, "hit_rate": 0.9, "flips": []}),
        _baseline(),
    )
    assert not [c for c in bad if c["name"] == "warm_save_parity"][0]["passed"]


def test_gate_warm_save_overhead_ceiling(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    at = bg.gate(
        _metrics(
            warm={
                "warm_overhead_pct": bg.WARM_OVERHEAD_MAX_PCT,
                "hit_rate": 1.0,
                "flips": [],
            }
        ),
        _baseline(),
    )
    assert [c for c in at if c["name"] == "warm_save_overhead_pct"][0]["passed"]
    over = bg.gate(
        _metrics(
            warm={
                "warm_overhead_pct": bg.WARM_OVERHEAD_MAX_PCT + 0.1,
                "hit_rate": 1.0,
                "flips": [],
            }
        ),
        _baseline(),
    )
    assert not [c for c in over if c["name"] == "warm_save_overhead_pct"][0]["passed"]


def test_gate_warm_ratio_rides_baseline_rule(monkeypatch):
    """warm_save_speedup is gated by the same >20%-regression rule as the
    other throughput ratios."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    base = _baseline()
    base["ratios"]["warm_save_speedup"] = 2.0
    m = _metrics()
    m["ratios"]["warm_save_speedup"] = 1.7  # floor = 1.6
    assert [c for c in bg.gate(m, base) if c["name"] == "warm_save_speedup"][0]["passed"]
    m["ratios"]["warm_save_speedup"] = 1.5
    assert not [c for c in bg.gate(m, base) if c["name"] == "warm_save_speedup"][0][
        "passed"
    ]


def test_gate_multihost_parity_is_absolute(monkeypatch):
    """`multihost_save_parity` needs no baseline: the flip and mismatch
    lists must simply be empty — any cross-host-count divergence in
    decisions, manifest, or decompressed bytes fails the gate."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["multihost"] = {"hosts": [1, 2], "flips": [], "value_mismatches": []}
    ok = bg.gate(m, _baseline())
    assert [c for c in ok if c["name"] == "multihost_save_parity"][0]["passed"]
    m["multihost"] = {
        "hosts": [1, 2], "flips": ["2p:params/layer00/w"], "value_mismatches": [],
    }
    bad = [c for c in bg.gate(m, _baseline()) if c["name"] == "multihost_save_parity"][0]
    assert not bad["passed"] and "2p:params/layer00/w" in bad["detail"]
    m["multihost"] = {
        "hosts": [1, 2], "flips": [], "value_mismatches": ["2p:opt/layer00/w"],
    }
    assert not [
        c for c in bg.gate(m, _baseline()) if c["name"] == "multihost_save_parity"
    ][0]["passed"]


def test_gate_multihost_check_skipped_without_metric(monkeypatch):
    """Decisions-only baseline refreshes don't run the multi-process smoke;
    the gate must not emit (or fail) the check when the metric is absent."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    checks = bg.gate(_metrics(), _baseline())
    assert not [c for c in checks if c["name"] == "multihost_save_parity"]


def test_gate_device_encode_parity_is_absolute(monkeypatch):
    """`device_encode_parity` needs no baseline: the mismatch list must be
    empty — any device/host stream divergence (or an all-declined vacuous
    run, which the bench reports as `(declined)` entries) fails the gate.
    Decisions-only refreshes skip the bench; the check must then not emit."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["device_encode"] = {
        "parity_mismatches": [], "speedups": {"sz": 3.0, "zfp": 2.0}, "fields": 2,
    }
    ok = bg.gate(m, _baseline())
    assert [c for c in ok if c["name"] == "device_encode_parity"][0]["passed"]
    m["device_encode"]["parity_mismatches"] = ["rho:zfp"]
    bad = [c for c in bg.gate(m, _baseline()) if c["name"] == "device_encode_parity"][0]
    assert not bad["passed"] and "rho:zfp" in bad["detail"]
    m["device_encode"]["parity_mismatches"] = ["rho:sz (declined)"]
    assert not [
        c for c in bg.gate(m, _baseline()) if c["name"] == "device_encode_parity"
    ][0]["passed"]
    checks = bg.gate(_metrics(), _baseline())
    assert not [c for c in checks if c["name"] == "device_encode_parity"]


def test_gate_fails_closed_on_unbaselined_field(monkeypatch):
    """A field added to the smoke suite without --update-baseline must
    fail the decision check, not ride along ungated."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["decisions"]["new_field"] = {"codec": "sz", "eb_sz": 2.0}
    checks = bg.gate(m, _baseline())
    dec = [c for c in checks if c["name"] == "decisions[table40]"][0]
    assert not dec["passed"] and "new_field (no baseline)" in dec["detail"]


def test_gate_fails_closed_without_baseline_key(monkeypatch):
    """A missing env key / metric must FAIL, not silently pass — fail-open
    gates rot."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table5")
    checks = bg.gate(_metrics(), _baseline())
    assert not [c for c in checks if c["name"] == "decisions[table5]"][0]["passed"]
    checks = bg.gate(_metrics(), {})
    # every baseline-DEPENDENT check must fail; the warm_save checks are
    # deliberately absolute (parity/ceiling) and stay green
    assert not any(
        c["passed"] for c in checks if not c["name"].startswith("warm_save")
    )
    assert [c for c in checks if c["name"].startswith("warm_save")]


def test_committed_baseline_covers_both_env_keys():
    """benchmarks/baseline.json must carry decisions for BOTH Huffman-table
    environments (zstd and bare), like the golden suite, so the gate works
    in the bare tier-1 env and in the full CI env."""
    import json

    base = json.loads((ROOT / "benchmarks" / "baseline.json").read_text())
    assert {"table5", "table40"} <= set(base["decisions"])
    assert set(base["ratios"]) == {
        "kernels3d_encode_stats_speedup",
        "selection_batched_speedup",
        "sharded_save_speedup",
        "warm_save_speedup",
        "device_encode_speedup",
    }
    assert base["estimation_error_b"] >= 0.0


def _quality(violations=None, frac=None, lossy=42, overhead=1.4):
    return {
        "violations": {"ssim": 0.01, "correlation": 0.002, "ks": 0.005}
        if violations is None
        else violations,
        "on_target_frac": {"ssim": 1.0, "correlation": 1.0, "ks": 0.95}
        if frac is None
        else frac,
        "lossy_fields": lossy,
        "solve_overhead_ratio": overhead,
    }


def test_gate_quality_passes_within_tolerance(monkeypatch):
    """quality_target_accuracy / quality_solve_overhead are ABSOLUTE checks
    (no baseline key): within-tolerance worst gaps + high claimed fraction
    + a non-vacuous run + a bounded overhead ratio all pass."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["quality"] = _quality()
    checks = bg.gate(m, _baseline())
    acc = [c for c in checks if c["name"] == "quality_target_accuracy"][0]
    ovh = [c for c in checks if c["name"] == "quality_solve_overhead"][0]
    assert acc["passed"] and ovh["passed"]


def test_gate_quality_fails_on_violation(monkeypatch):
    """A claimed-on-target field measuring outside quality.TOLERANCE fails,
    per metric and with the offending gap in the detail."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    for metric, tol in bg.QUALITY_TOLERANCE.items():
        m = _metrics()
        m["quality"] = _quality()
        m["quality"]["violations"][metric] = tol + 0.001
        acc = [
            c for c in bg.gate(m, _baseline())
            if c["name"] == "quality_target_accuracy"
        ][0]
        assert not acc["passed"] and metric in acc["detail"]
        # exactly at tolerance still passes (<=, not <)
        m["quality"]["violations"][metric] = tol
        acc = [
            c for c in bg.gate(m, _baseline())
            if c["name"] == "quality_target_accuracy"
        ][0]
        assert acc["passed"]


def test_gate_quality_fails_on_low_claim_fraction_or_vacuous(monkeypatch):
    """A solver that stops claiming targets (honest misses everywhere) or a
    run that solved nothing lossy must fail — both would otherwise make
    the violation number vacuously green."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["quality"] = _quality(frac={"ssim": 0.5, "correlation": 1.0, "ks": 1.0})
    acc = [
        c for c in bg.gate(m, _baseline()) if c["name"] == "quality_target_accuracy"
    ][0]
    assert not acc["passed"] and "claimed on_target" in acc["detail"]
    m["quality"] = _quality(lossy=0)
    acc = [
        c for c in bg.gate(m, _baseline()) if c["name"] == "quality_target_accuracy"
    ][0]
    assert not acc["passed"] and "vacuous" in acc["detail"]
    # an unmeasured metric fails closed too
    m["quality"] = _quality(violations={"ssim": 0.01, "correlation": 0.002})
    acc = [
        c for c in bg.gate(m, _baseline()) if c["name"] == "quality_target_accuracy"
    ][0]
    assert not acc["passed"] and "ks: not measured" in acc["detail"]


def test_gate_quality_solve_overhead_ceiling(monkeypatch):
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    m = _metrics()
    m["quality"] = _quality(overhead=bg.QUALITY_SOLVE_OVERHEAD_MAX)
    assert [
        c for c in bg.gate(m, _baseline()) if c["name"] == "quality_solve_overhead"
    ][0]["passed"]
    m["quality"] = _quality(overhead=bg.QUALITY_SOLVE_OVERHEAD_MAX + 0.01)
    assert not [
        c for c in bg.gate(m, _baseline()) if c["name"] == "quality_solve_overhead"
    ][0]["passed"]


def test_gate_quality_checks_skipped_without_metric(monkeypatch):
    """Decisions-only baseline refreshes skip the quality bench; the gate
    must not emit (or fail) the quality checks when the metric is absent."""
    bg = _load_gate()
    monkeypatch.setattr(bg, "_env_key", lambda: "table40")
    checks = bg.gate(_metrics(), _baseline())
    assert not [c for c in checks if c["name"].startswith("quality_")]
