"""Hypothesis property tests for the quality-metrics subsystem
(core/quality.py, DESIGN.md §7.4) — optional dependency.

Two property families, per the subsystem's contract:

* predicted metric-vs-bound curves are monotone in the error bound for
  BOTH codecs: SSIM and correlation non-increasing, KS non-decreasing
  (target inversion relies on this — `metric_curves` forces it, and
  these tests pin the promise across field families and scales);
* on synthetic fields where the residual models apply (Gaussian white
  noise, random walks, noisy ramps), the §7.4 estimators agree with the
  metric MEASURED on the real encode+decode reconstruction in the
  contract's direction: floors (SSIM/correlation) never over-promised by
  more than the tolerance, the KS ceiling never under-promised.

`pytest.importorskip` keeps a bare jax+numpy+pytest environment green;
the CI `property` job installs hypothesis and runs these for real.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import Policy, decompress, encode_with_selection, solve_many
from repro.core import quality as qual

pytestmark = pytest.mark.property


def _field(kind, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    if kind == "white2d":
        x = scale * rng.standard_normal((96, 96))
    elif kind == "walk2d":
        x = np.cumsum(scale * rng.standard_normal((96, 96)), axis=0)
    elif kind == "walk3d":
        x = np.cumsum(scale * rng.standard_normal((16, 32, 32)), axis=2)
    else:  # ramp3d
        x = np.linspace(0.0, 4.0 * scale, 12 * 32 * 32).reshape(12, 32, 32)
        x = x + 0.05 * scale * rng.standard_normal(x.shape)
    return x.astype(np.float32)


KINDS = ["white2d", "walk2d", "walk3d", "ramp3d"]
BOUNDS = np.logspace(-4, 0, 12)


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.25, 16.0),
)
def test_metric_curves_monotone_in_bound(kind, seed, scale):
    """SSIM/correlation non-increasing, KS non-decreasing in eb, for both
    codec curves — exactly the invariant the §7.4 inversion needs."""
    x = _field(kind, seed, scale)
    bounds = BOUNDS * float(np.ptp(x))
    curves = qual.metric_curves(x, bounds)
    for codec in ("sz", "zfp"):
        ssim = np.asarray(curves[f"ssim_{codec}"])
        corr = np.asarray(curves[f"correlation_{codec}"])
        ks = np.asarray(curves[f"ks_{codec}"])
        assert np.all(np.diff(ssim) <= 1e-12)
        assert np.all(np.diff(corr) <= 1e-12)
        assert np.all(np.diff(ks) >= -1e-12)
        # SSIM's true range is [-1, 1]: coarse quantization can flip the
        # mean's sign and take the luminance term slightly negative
        assert np.all((-1.0 - 1e-9 <= ssim) & (ssim <= 1.0 + 1e-9))
        assert np.all((-1.0 - 1e-9 <= corr) & (corr <= 1.0 + 1e-9))
        assert np.all((0.0 - 1e-12 <= ks) & (ks <= 1.0 + 1e-12))


@settings(max_examples=8, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**16),
    metric=st.sampled_from(["ssim", "correlation", "ks"]),
)
def test_estimator_agrees_with_measured(kind, seed, metric):
    """Solve a mid-range target, encode+decode for real, and check the
    solver's `est_metric` against the measured metric in the contract's
    one-sided direction (floors may only overshoot, the KS ceiling may
    only undershoot) within quality.TOLERANCE."""
    x = _field(kind, seed)
    target = {"ssim": 0.95, "correlation": 0.995, "ks": 0.1}[metric]
    pol = {
        "ssim": Policy.fixed_ssim,
        "correlation": Policy.fixed_correlation,
        "ks": Policy.fixed_ks,
    }[metric](target)
    sol = solve_many([x], pol)[0]
    assert sol.est_metric is not None
    cf = encode_with_selection(x, sol.selection)
    rec = decompress(cf).reshape(x.shape)
    achieved = qual.measured_metric(metric, x, rec)
    # estimate honest against measurement...
    assert qual.metric_gap(metric, achieved, sol.est_metric) <= qual.TOLERANCE[metric]
    # ...and a claimed-on-target solve honest against the TARGET
    if sol.on_target:
        assert qual.metric_gap(metric, achieved, target) <= qual.TOLERANCE[metric]


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    scale=st.floats(0.5, 8.0),
    var_frac=st.floats(1e-6, 0.25),
)
def test_sampled_ssim_inversion_consistent(seed, scale, var_frac):
    """mse_for_ssim_sampled and ssim_from_mse_sampled are mutual inverses
    along the measured quantization curve, and the sampled SSIM never
    exceeds the independent-error closed form (correlated quantization
    error only depresses contrast/structure)."""
    x = _field("walk2d", seed, scale)
    stats = qual.stats_from_field(x)
    mse = var_frac * stats.var
    s = qual.ssim_from_mse_sampled(stats, mse)
    assert -1.0 <= s <= 1.0
    assert s <= qual.ssim_from_mse(mse, stats.var, stats.vr) + 1e-9
    if 0.0 < s < 1.0:
        mse_back = qual.mse_for_ssim_sampled(stats, s)
        s_back = qual.ssim_from_mse_sampled(stats, mse_back)
        assert abs(s_back - s) <= 1e-6 + 1e-3 * (1.0 - s)
