"""Codec correctness: error bounds, roundtrips, rate accounting (SZ & ZFP)."""

import numpy as np
import pytest

from repro.core import (
    select,
    select_and_compress,
    decompress,
    sz_compress,
    sz_decompress,
    sz_stats,
    zfp_compress,
    zfp_decompress,
    zfp_stats,
)
from repro.core import entropy as ent

import jax.numpy as jnp


def _tol(eb, x):
    # f32-output guarantee: eb plus a few output ulps (same as real SZ/ZFP)
    return eb + 4 * np.spacing(np.abs(x).max() + 1e-30)


def _field(shape, kind, seed):
    rng = np.random.default_rng(seed)
    if kind == "noise":
        return rng.standard_normal(shape).astype(np.float32)
    if kind == "smooth":
        grids = np.meshgrid(*[np.linspace(0, 4, s) for s in shape], indexing="ij")
        out = np.ones(shape)
        for g in grids:
            out = out * np.sin(g)
        return (out + 0.01 * rng.standard_normal(shape)).astype(np.float32)
    if kind == "walk":
        return np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)
    raise ValueError(kind)


SHAPES = [(2048,), (96, 80), (24, 40, 32)]
KINDS = ["noise", "smooth", "walk"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("eb_rel", [1e-2, 1e-3, 1e-5])
def test_sz_error_bound_and_roundtrip(shape, kind, eb_rel):
    x = _field(shape, kind, 7)
    eb = eb_rel * (x.max() - x.min() + 1e-30)
    buf = sz_compress(x, eb)
    rec = sz_decompress(buf)
    assert rec.shape == x.shape and rec.dtype == np.float32
    assert np.abs(x - rec).max() <= _tol(eb, x)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("eb_rel", [1e-2, 1e-3, 1e-5])
def test_zfp_error_bound_and_roundtrip(shape, kind, eb_rel):
    x = _field(shape, kind, 11)
    eb = eb_rel * (x.max() - x.min() + 1e-30)
    buf = zfp_compress(x, eb)
    rec = zfp_decompress(buf)
    assert rec.shape == x.shape and rec.dtype == np.float32
    assert np.abs(x - rec).max() <= _tol(eb, x)


def test_bounds_hold_fixed_seeds():
    """Deterministic twin of the hypothesis property test (which lives in
    test_property_codecs.py behind pytest.importorskip)."""
    for seed in (0, 17, 23):
        for kind in KINDS:
            x = _field((96, 80), kind, seed)
            eb = 1e-3 * (x.max() - x.min() + 1e-30)
            assert np.abs(x - sz_decompress(sz_compress(x, eb))).max() <= _tol(eb, x)
            assert np.abs(x - zfp_decompress(zfp_compress(x, eb))).max() <= _tol(eb, x)


def test_stats_match_actual_bytes_sz():
    """In-graph rate statistics track the byte codec within ~15%."""
    x = _field((256, 256), "smooth", 3)
    eb = 1e-3 * (x.max() - x.min())
    st_ = sz_stats(jnp.asarray(x), eb)
    actual = 8 * len(sz_compress(x, eb)) / x.size
    assert abs(float(st_.bitrate) - actual) / actual < 0.25
    # reconstruction identical up to dequantize dtype handling
    assert np.abs(np.asarray(st_.recon) - sz_decompress(sz_compress(x, eb))).max() < 2e-5 * (
        np.abs(x).max()
    )


def test_stats_match_actual_bytes_zfp():
    x = _field((256, 256), "smooth", 3)
    eb = 1e-3 * (x.max() - x.min())
    st_ = zfp_stats(jnp.asarray(x), eb)
    actual = 8 * len(zfp_compress(x, eb)) / x.size
    assert abs(float(st_.bitrate) - actual) / actual < 0.1
    # the stats path runs in f32, the byte codec in f64 — truncation-boundary
    # jitter can move single coefficients one step; both stay within the bound
    rec = zfp_decompress(zfp_compress(x, eb))
    np.testing.assert_allclose(np.asarray(st_.recon), rec, atol=2 * eb)


def test_zfp_overpreserves_vs_sz():
    """§6.4: at the same eb, ZFP's actual error is well below the bound."""
    x = _field((128, 128), "smooth", 5)
    eb = 1e-3 * (x.max() - x.min())
    err_sz = np.abs(x - sz_decompress(sz_compress(x, eb))).max()
    err_zfp = np.abs(x - zfp_decompress(zfp_compress(x, eb))).max()
    assert err_zfp < err_sz  # over-preservation

    st_sz = sz_stats(jnp.asarray(x), eb)
    st_zfp = zfp_stats(jnp.asarray(x), eb)
    assert float(st_zfp.psnr) > float(st_sz.psnr)


def test_huffman_roundtrip():
    rng = np.random.default_rng(0)
    syms = rng.geometric(0.05, size=20000).clip(0, 400).astype(np.int64)
    freqs = np.bincount(syms, minlength=401)
    table = ent.build_table(freqs)
    buf = ent.encode(syms, table)
    table2 = ent.HuffmanTable.from_bytes(table.to_bytes())
    out = ent.decode(buf, table2, len(syms))
    np.testing.assert_array_equal(out, syms)
    # rate is within 10% of entropy + table
    h = ent.entropy_bits(freqs)
    assert 8 * len(buf) / len(syms) <= h * 1.1 + 1.0


def test_huffman_degenerate_single_symbol():
    syms = np.zeros(100, dtype=np.int64)
    table = ent.build_table(np.bincount(syms, minlength=3))
    buf = ent.encode(syms, table)
    out = ent.decode(buf, ent.HuffmanTable.from_bytes(table.to_bytes()), 100)
    np.testing.assert_array_equal(out, syms)


@pytest.mark.parametrize("kind", KINDS)
def test_select_and_compress_roundtrip(kind):
    x = _field((128, 96), kind, 9)
    cf = select_and_compress(x, eb_rel=1e-3)
    rec = decompress(cf)
    vr = x.max() - x.min()
    assert np.abs(x - rec).max() <= _tol(1e-3 * vr, x)
    assert cf.codec in ("sz", "zfp", "raw")


def test_select_constant_field_is_raw_or_tiny():
    x = np.full((64, 64), 3.14, dtype=np.float32)
    cf = select_and_compress(x, eb_rel=1e-3)
    rec = decompress(cf)
    np.testing.assert_allclose(rec, x, atol=1e-6)


def test_select_tiny_field_raw():
    x = np.arange(10, dtype=np.float32)
    cf = select_and_compress(x, eb_rel=1e-3)
    assert cf.codec == "raw"
    np.testing.assert_array_equal(decompress(cf), x)
