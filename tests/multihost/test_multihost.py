"""Multi-process differential tests for the §6.2 checkpoint protocol.

Every test here spawns REAL multi-process jax jobs (via
`repro.launch.mhrun` + `tests/multihost/worker.py`) over 8 global
emulated CPU devices, split 1x8 / 2x4 / 4x2 across {1, 2, 4} processes.
Because the global device set — and hence the (2, 4) mesh and every
shard boundary — is identical at every host count, the psum-reconciled
Stage I/II decisions, error bounds, segment geometry, and decompressed
bytes must be BIT-identical to the single-process golden path; the suite
asserts exactly that, plus the §6.2 failure guarantees (no partial
manifest ever promoted, no hang on straggler, incomplete checkpoints
rejected).

Marked `multihost` (and `slow`): tier-1 runs exclude it; the dedicated
CI leg runs `-m multihost`.
"""

import os
import sys

import pytest

pytestmark = [pytest.mark.multihost, pytest.mark.slow]

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(os.path.dirname(_HERE))
_WORKER = os.path.join(_HERE, "worker.py")
_SRC = os.path.join(_ROOT, "src")

HOST_COUNTS = (1, 2, 4)


def _run(nproc: int, scenario: str, args: dict, timeout_s: float = 600.0):
    from repro.launch import mhrun

    results = mhrun.run(
        [sys.executable, _WORKER],
        nproc,
        scenario=scenario,
        args=args,
        local_devices=8 // nproc,
        timeout_s=timeout_s,
        extra_env={
            "PYTHONPATH": _SRC + os.pathsep + os.environ.get("PYTHONPATH", "")
        },
    )
    return results


def _payloads(results):
    from repro.launch import mhrun

    return mhrun.require_success(results)


@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    """One cooperative sharded save of the same synthetic state at every
    host count -> {nproc: (directory, per-host-agreed payload)}."""
    out = {}
    for nproc in HOST_COUNTS:
        d = str(tmp_path_factory.mktemp(f"save{nproc}p"))
        payloads = _payloads(_run(nproc, "save", dict(directory=d)))
        for p in payloads[1:]:
            assert p == payloads[0], f"hosts of the {nproc}p job disagree"
        out[nproc] = (d, payloads[0])
    return out


def test_save_parity_across_host_counts(saved):
    """Decisions, bounds, codecs, segment geometry, and decompressed bytes
    at 2 and 4 processes are bit-identical to the 1-process golden path."""
    _, golden = saved[1]
    for nproc in HOST_COUNTS[1:]:
        _, got = saved[nproc]
        assert got["summary"]["selection_bits"] == golden["summary"]["selection_bits"]
        assert got["summary"] == golden["summary"], f"{nproc}p manifest diverges"
        assert got["hashes"] == golden["hashes"], f"{nproc}p bytes diverge"


def test_policy_mix_exercised(saved):
    """The differential state really does mix the three contract modes."""
    _, golden = saved[1]
    modes = {
        fl["policy"]["mode"] for fl in golden["summary"]["fields"].values()
    }
    assert {"fixed_accuracy", "fixed_psnr", "fixed_ratio", "raw"} <= modes


def test_elastic_restore_matrix(saved):
    """A checkpoint saved at P hosts restores at every Q in {1, 2, 4} onto
    a DIFFERENT (4, 2) mesh, bit-identical to the golden values."""
    _, golden = saved[1]
    for save_p, (d, _) in saved.items():
        for restore_q in HOST_COUNTS:
            payloads = _payloads(_run(restore_q, "restore", dict(directory=d)))
            for p in payloads:
                assert p["step"] == 1
                assert p["resharded"], (save_p, restore_q)
                assert p["hashes"] == golden["hashes"], (
                    f"save@{save_p}p restore@{restore_q}p diverges"
                )


def test_restore_locality(saved):
    """Multi-process restores only decode the segments their addressable
    shards intersect — strictly fewer than the whole manifest."""
    d, _ = saved[2]
    payloads = _payloads(_run(4, "restore", dict(directory=d)))
    for p in payloads:
        st = p["stats"]
        assert 0 < st["segments_decoded"] <= st["segments_total"]
    assert any(
        p["stats"]["segments_decoded"] < p["stats"]["segments_total"]
        for p in payloads
    ), "no host skipped any segment: locality filter inert"


def test_fault_sigkill_never_promotes(tmp_path):
    """SIGKILL of a non-zero host mid-save: survivors raise BarrierTimeout,
    the tmp dir is never promoted, the previous step still restores."""
    d = str(tmp_path / "ckpt")
    results = _run(
        2, "fault_kill",
        dict(directory=d, victim=1, barrier_timeout_s=10.0),
        timeout_s=420.0,
    )
    by_pid = {r.process_id: r for r in results}
    assert by_pid[1].returncode == -9, "victim was supposed to die by SIGKILL"
    survivor = by_pid[0]
    # the reported result is authoritative, not the exit code: jax's
    # coordination service fatally aborts a process whose peer died — at
    # interpreter exit, after the scenario completed and reported
    assert survivor.result is not None, survivor.output[-2000:]
    assert "error" not in survivor.result, survivor.result
    assert survivor.result["err"] == "BarrierTimeout"
    assert survivor.result["latest"] == 1
    assert not survivor.result["step2_promoted"]
    assert survivor.result["fields_restored"] > 0
    leftovers = [f for f in os.listdir(d) if f.startswith("step_")]
    assert leftovers == ["step_000000001"]


def test_fault_straggler_raises_everywhere(tmp_path):
    """A host straggling past the barrier deadline fails the save with
    BarrierTimeout on EVERY host — never a hang — and nothing is promoted."""
    d = str(tmp_path / "ckpt")
    results = _run(
        2, "fault_straggler",
        dict(directory=d, victim=1, delay=25.0, barrier_timeout_s=8.0),
        timeout_s=420.0,
    )
    payloads = _payloads(results)
    for p in payloads:
        assert p["err"] == "BarrierTimeout"
        assert p["latest"] == 1
        assert not p["step2_promoted"]


def test_restore_rejects_missing_marker(tmp_path):
    """A manifest whose per-host completion marker is gone is rejected by
    restore_tree on every host."""
    d = str(tmp_path / "ckpt")
    payloads = _payloads(
        _run(2, "restore_reject", dict(directory=d), timeout_s=420.0)
    )
    for p in payloads:
        assert p["err"] == "IncompleteCheckpointError"


def test_async_overlap_isolation(tmp_path):
    """Pipelined async save: live params donated/rebound right after issue;
    the step-1 manifest must decode the PRE-mutation bytes on every host."""
    d = str(tmp_path / "ckpt")
    payloads = _payloads(
        _run(2, "async_mutate", dict(directory=d), timeout_s=420.0)
    )
    for p in payloads:
        assert p["pre_mutation"], "async save observed post-mutation bytes"
        assert p["issue_seconds"] < p["total_seconds"]
