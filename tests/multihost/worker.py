"""Worker program for the multi-host differential tests (DESIGN.md §6.2).

Spawned by `repro.launch.mhrun` — one python process per emulated host,
all joined into a single distributed CPU job (gloo collectives). Every
scenario builds the SAME (2, 4) mesh over 8 GLOBAL devices regardless of
how many processes hold them (1x8, 2x4, 4x2 local), so shard layouts —
and therefore Stage I/II decisions — must come out bit-identical at
every host count: the differential parity the suite asserts.

Scenarios (dispatched by `spec["scenario"]`):

* ``save``            — cooperative sharded save under the mixed
  PolicySet (fixed_accuracy default + fixed_psnr + fixed_ratio rules +
  raw optimizer state); reports a manifest summary (decisions, bounds,
  per-segment layout) and sha256 hashes of every restored field.
* ``restore``         — elastic restore of an existing checkpoint onto a
  DIFFERENT (4, 2) mesh; reports value hashes + per-host locality stats.
* ``fault_kill``      — a healthy baseline save, then a save where the
  victim host SIGKILLs itself at the write barrier; survivors must see
  `BarrierTimeout`, and the previous step must still restore.
* ``fault_straggler`` — same, but the victim sleeps past the barrier
  deadline instead of dying; every host must raise, nothing promoted.
* ``restore_reject``  — deletes one completion marker from a finished
  checkpoint; every host's restore must raise
  `IncompleteCheckpointError`.
* ``async_mutate``    — pipelined `async_save`, live params donated away
  immediately after issue; the manifest must decode the PRE-mutation
  bytes (device snapshot isolation under the multi-host drain).

Fault hooks monkeypatch `repro.runtime.dist.barrier` (the checkpoint
writer always calls it through the module attribute), which keeps the
production code free of test-only injection points.
"""

import hashlib
import os
import signal
import sys
import time

import numpy as np


def _policy_mix():
    from repro.core import Policy
    from repro.core.policy import PolicySet

    return PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-3),
        rules=[
            ("params/layer00/w", Policy.fixed_psnr(60.0)),
            ("params/layer01/w", Policy.fixed_ratio(6.0)),
            ("opt/*", Policy.raw()),
        ],
    )


def _mesh(shape=(2, 4)):
    import jax

    from repro.launch.mesh import make_emulated_mesh

    assert jax.device_count() == 8, jax.device_count()
    return make_emulated_mesh(tuple(shape), ("data", "model"))


def _state(mesh, a):
    from repro.launch.shardckpt import synth_state

    return synth_state(mesh, int(a.get("fields", 3)), int(a.get("dim", 128)))


def _manager(a, **over):
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    kw = dict(
        directory=a["directory"],
        policy=_policy_mix(),
        sharded=True,
        barrier_timeout_s=float(a.get("barrier_timeout_s", 60.0)),
    )
    kw.update(over)
    return CheckpointManager(CheckpointConfig(**kw))


def _hashes(flat: dict) -> dict:
    out = {}
    for name, arr in sorted(flat.items()):
        h = hashlib.sha256()
        h.update(str(arr.dtype).encode())
        h.update(str(tuple(arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
        out[name] = h.hexdigest()
    return out


def _summary(path: str) -> dict:
    """Host/offset-free manifest digest: everything that must be
    bit-identical across host counts (decisions, bounds, codecs, byte
    counts, segment geometry) and nothing that legitimately differs
    (which host wrote a segment, where in its file)."""
    import json

    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    fields = {}
    for fl in man["fields"]:
        fields[fl["name"]] = dict(
            codec=fl["codec"],
            eb=fl["eb"],
            eb_sz=fl["eb_sz"],
            nbytes=fl["nbytes"],
            policy=fl["policy"],
            segments=sorted(
                [sg["start"], sg["stop"], sg["codec"], sg["nbytes"]]
                for sg in fl["segments"]
            ),
        )
    return dict(
        total_bytes=man["total_bytes"],
        selection_bits=man["selection_bits"],
        fields=fields,
    )


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def scenario_save(spec, pid):
    a = spec["args"]
    mesh = _mesh()
    tree, _ = _state(mesh, a)
    mgr = _manager(a)
    step = int(a.get("step", 1))
    path = mgr.save(step, tree)
    _, flat = mgr.restore(step)
    return dict(summary=_summary(path), hashes=_hashes(flat))


def scenario_restore(spec, pid):
    a = spec["args"]
    mesh = _mesh(a.get("mesh", (4, 2)))
    tree, shardings = _state(mesh, a)
    from repro.runtime import dist

    mgr = _manager(a)
    step, restored = mgr.restore_tree(tree, shardings=shardings)
    flat = {}

    def _walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                _walk(f"{prefix}/{k}" if prefix else str(k), v)
        else:
            flat[prefix] = dist.to_numpy(node)

    _walk("", restored)
    w0 = restored["params"]["layer00/w"]
    target = tuple(int(s) for s in a.get("mesh", (4, 2)))
    return dict(
        step=step,
        hashes=_hashes(flat),
        stats=mgr.last_restore_stats,
        resharded=tuple(w0.sharding.mesh.devices.shape) == target,
    )


def _hooked_save(spec, pid, hook):
    """Baseline save of step 1, then a step-2 save with `hook` wrapping
    `dist.barrier`; returns what every surviving host observed."""
    from repro.runtime import dist

    a = spec["args"]
    mesh = _mesh()
    tree, _ = _state(mesh, a)
    mgr = _manager(a)
    mgr.save(1, tree)
    orig = dist.barrier

    def barrier(name, timeout_s):
        hook(name, pid)
        return orig(name, timeout_s)

    dist.barrier = barrier
    err = None
    try:
        mgr.save(2, tree)
    except dist.BarrierTimeout:
        err = "BarrierTimeout"
    finally:
        dist.barrier = orig
    _, flat = mgr.restore()  # previous step must still restore cleanly
    return dict(
        err=err,
        latest=mgr.latest_step(),
        step2_promoted=os.path.exists(
            os.path.join(a["directory"], "step_000000002")
        ),
        fields_restored=len(flat),
    )


def scenario_fault_kill(spec, pid):
    victim = int(spec["args"].get("victim", 1))

    def hook(name, p):
        if ":written" in name and p == victim:
            os.kill(os.getpid(), signal.SIGKILL)

    return _hooked_save(spec, pid, hook)


def scenario_fault_straggler(spec, pid):
    a = spec["args"]
    victim = int(a.get("victim", 1))
    delay = float(a.get("delay", 25.0))

    def hook(name, p):
        if ":written" in name and p == victim:
            time.sleep(delay)

    return _hooked_save(spec, pid, hook)


def scenario_restore_reject(spec, pid):
    from repro.checkpoint import IncompleteCheckpointError
    from repro.runtime import dist

    a = spec["args"]
    mesh = _mesh()
    tree, shardings = _state(mesh, a)
    mgr = _manager(a)
    path = mgr.save(1, tree)
    if pid == 0:
        os.remove(os.path.join(path, f"commit.{spec['num_processes'] - 1}"))
    dist.barrier("reject:marker-removed", 60.0)
    err = None
    try:
        mgr.restore_tree(tree, shardings=shardings)
    except IncompleteCheckpointError:
        err = "IncompleteCheckpointError"
    return dict(err=err)


def scenario_async_mutate(spec, pid):
    import jax

    a = spec["args"]
    mesh = _mesh()
    tree, _ = _state(mesh, a)
    mgr = _manager(a)
    t0 = time.perf_counter()
    mgr.async_save(1, tree)
    t_issue = time.perf_counter() - t0
    # clobber the live state the moment the save is issued: donation
    # invalidates the input buffers where the backend supports it, and the
    # rebinding alone guarantees the writer can only be reading its own
    # snapshot
    mutate = jax.jit(
        lambda t: jax.tree_util.tree_map(lambda x: x * 2 + 1, t),
        donate_argnums=0,
    )
    tree = mutate(tree)
    jax.block_until_ready(tree)
    mgr.wait()
    t_total = time.perf_counter() - t0
    _, flat = mgr.restore(1)

    # reference: a synchronous save of the identical pristine state
    # (synth_state is seed-deterministic) in a second directory
    pristine, _ = _state(mesh, a)
    ref = _manager(a, directory=a["directory"] + "_ref")
    ref.save(1, pristine)
    _, ref_flat = ref.restore(1)
    return dict(
        pre_mutation=_hashes(flat) == _hashes(ref_flat),
        issue_seconds=t_issue,
        total_seconds=t_total,
    )


SCENARIOS = {
    "save": scenario_save,
    "restore": scenario_restore,
    "fault_kill": scenario_fault_kill,
    "fault_straggler": scenario_fault_straggler,
    "restore_reject": scenario_restore_reject,
    "async_mutate": scenario_async_mutate,
}


if __name__ == "__main__":
    from repro.launch import mhrun

    sys.exit(mhrun.worker_main(sys.argv[-1], SCENARIOS))
