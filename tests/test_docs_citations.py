"""The docs-citation gate is tier-1: every `DESIGN.md §N` citation in the
repo resolves to a real DESIGN.md section (see tools/check_design_citations)."""

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_design_citations_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_design_citations.py")],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stderr or proc.stdout


def test_checker_catches_dangling(tmp_path):
    """The gate actually gates: a fabricated dangling citation fails."""
    import shutil

    root = tmp_path / "repo"
    (root / "tools").mkdir(parents=True)
    (root / "src").mkdir()
    shutil.copy(ROOT / "tools" / "check_design_citations.py", root / "tools")
    (root / "DESIGN.md").write_text("# D\n\n## §1 — only section\n")
    # assembled so the dangling literal never appears in THIS file's source
    dangling = "DESIGN" + ".md §" + "9"
    (root / "src" / "m.py").write_text(f'"""Cites DESIGN.md §1 and {dangling}."""\n')
    proc = subprocess.run(
        [sys.executable, str(root / "tools" / "check_design_citations.py")],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 1
    assert "§9" in proc.stderr
