"""Quality-target controller (DESIGN.md §7): fixed-PSNR and fixed-ratio
modes hit their targets on the actual encoded streams, the estimated
curves are monotone (the invariant the bisection relies on), and the
target modes ride the whole pytree/checkpoint/KV plumbing."""

import numpy as np
import pytest

from repro.core import (
    Policy,
    compress,
    compress_pytree,
    decompress,
    decompress_pytree,
    encode_with_selection,
    estimate_curves,
    solve,
    solve_many,
)


def _fields():
    rng = np.random.default_rng(0)
    n = 256
    xx, yy = np.meshgrid(np.linspace(0, 6, n), np.linspace(0, 6, n))
    return {
        "smooth": (np.sin(xx) * np.cos(yy) + 1e-3 * rng.standard_normal((n, n))).astype(np.float32),
        "noisy": (np.sin(4 * xx) * np.cos(3 * yy) + 0.05 * rng.standard_normal((n, n))).astype(np.float32),
        "rough": rng.standard_normal((n, n)).astype(np.float32),
        "walk3d": np.cumsum(rng.standard_normal((16, 64, 64)), axis=1).astype(np.float32),
    }


from benchmarks.common import psnr as _psnr  # the paper's value-range PSNR


@pytest.mark.parametrize("target", [45.0, 60.0, 75.0])
def test_fixed_psnr_within_1db(target):
    """Acceptance: achieved PSNR of the real roundtrip within 1 dB of the
    target on smooth / noisy / rough / 3-D fields."""
    fields = _fields()
    sols = solve_many(list(fields.values()), Policy.fixed_psnr(target))
    for (name, f), s in zip(fields.items(), sols):
        assert s.selection.codec in ("sz", "zfp"), name
        assert s.on_target, name
        cf = encode_with_selection(f, s.selection)
        rec = decompress(cf).reshape(f.shape)
        ach = _psnr(f, rec)
        assert abs(ach - target) <= 1.0, (name, target, ach)


@pytest.mark.parametrize("target", [4.0, 8.0, 16.0])
def test_fixed_ratio_within_10pct(target):
    """Acceptance: achieved compression ratio of the real byte stream
    within 10% of the target."""
    fields = _fields()
    sols = solve_many(list(fields.values()), Policy.fixed_ratio(target))
    for (name, f), s in zip(fields.items(), sols):
        assert s.selection.codec in ("sz", "zfp"), name
        assert s.on_target, name
        cf = encode_with_selection(f, s.selection)
        ratio = (f.size * 4) / len(cf.data)
        assert abs(ratio / target - 1.0) <= 0.10, (name, target, ratio)
        # the stream must actually decode
        rec = decompress(cf).reshape(f.shape)
        assert np.isfinite(rec).all()


def test_constant_and_degenerate_fields_fall_back_raw():
    arrs = [
        np.full((64, 64), 3.0, np.float32),   # constant
        np.arange(10, dtype=np.float32),       # too small
        np.float32(1.5).reshape(()),           # 0-d
    ]
    for mode, pol in (
        ("fixed_psnr", Policy.fixed_psnr(60.0)),
        ("fixed_ratio", Policy.fixed_ratio(8.0)),
    ):
        sols = solve_many(arrs, pol)
        assert [s.selection.codec for s in sols] == ["raw"] * 3
        # raw is lossless, so a PSNR target is met (inf) and a ratio
        # target is not (raw pins ratio to 1)
        assert all(s.on_target == (mode == "fixed_psnr") for s in sols)
        for a, s in zip(arrs, sols):
            rec = decompress(encode_with_selection(a, s.selection))
            np.testing.assert_array_equal(rec.reshape(a.shape), a)


def test_estimated_curves_monotone_in_bound():
    """The secant/bracket invariant: estimated PSNR and bit-rate of BOTH
    codecs are nonincreasing in the bound (eb for ZFP, bin size for SZ)
    over the operational range — rates below the 32 bits/value raw cutoff,
    where the solver actually lands. (Past the cutoff the Chao1 table term
    is pure sampling statistics and may wiggle; every such field goes raw
    regardless.) Checked on a fine grid; slack covers reduction noise."""
    fields = _fields()
    for name, f in fields.items():
        vr = float(f.max() - f.min())
        bounds = vr * np.exp2(np.linspace(-20, -1, 24)).astype(np.float32)
        c = estimate_curves(f, bounds)
        operational = np.asarray(c["br_sz"], np.float64) <= 34.0
        for key in ("br_sz", "psnr_sz", "br_zfp", "psnr_zfp", "psnr_sz_measured"):
            curve = np.asarray(c[key], np.float64)
            diffs = np.diff(curve)
            ok = diffs <= 1e-3 + 1e-4 * np.abs(curve[:-1])
            if key == "br_sz":
                ok = ok | ~operational[:-1]
            assert ok.all(), (name, key)


def test_fixed_psnr_matches_single_field_solve():
    f = _fields()["noisy"]
    s1 = solve(f, Policy.fixed_psnr(55.0))
    s2 = solve_many([f], Policy.fixed_psnr(55.0))[0]
    assert s1.selection.codec == s2.selection.codec
    assert s1.selection.eb_sz == pytest.approx(s2.selection.eb_sz, rel=1e-6)


def test_invalid_mode_and_missing_targets_raise():
    f = _fields()["noisy"]
    # Policy validates at construction (core/policy.py)
    with pytest.raises(ValueError):
        Policy("fixed_psnr")
    with pytest.raises(ValueError):
        Policy("fixed_ratio")
    with pytest.raises(ValueError):
        Policy.fixed_ratio(-2.0)
    with pytest.raises(ValueError):
        Policy("no_such_mode", target_psnr=60.0)
    # ... and the legacy mode-string path still validates before warning
    with pytest.raises(ValueError):
        solve(f, "no_such_mode", target_psnr=60.0)
    with pytest.raises(ValueError):
        solve_many([f], "fixed_accuracy")
    with pytest.raises(ValueError):
        solve_many([f], Policy.raw())


def test_fixed_accuracy_mode_delegates_to_selection():
    from repro.core import select

    f = _fields()["noisy"]
    sol = solve(f, Policy.fixed_accuracy(eb_rel=1e-3))
    ref = select(f, eb_rel=1e-3)
    assert sol.selection.codec == ref.codec
    assert sol.selection.eb_abs == pytest.approx(ref.eb_abs, rel=1e-6)


def test_pytree_mixed_mode_roundtrip():
    """The same mixed pytree (float 2-D/3-D, int, tiny, constant leaves)
    roundtrips under all three modes; int/degenerate leaves bit-exact."""
    fields = _fields()
    tree = {
        "layers": [fields["smooth"], fields["walk3d"]],
        "noisy": fields["noisy"],
        "step": np.arange(8, dtype=np.int32),
        "tiny": np.ones(8, np.float32),
        "const": np.full((64, 64), 2.5, np.float32),
    }
    for mode, pol, target in (
        ("fixed_accuracy", Policy.fixed_accuracy(eb_rel=1e-4), None),
        ("fixed_psnr", Policy.fixed_psnr(60.0), 60.0),
        ("fixed_ratio", Policy.fixed_ratio(8.0), 8.0),
    ):
        ct = compress_pytree(tree, pol)
        out = decompress_pytree(ct)
        np.testing.assert_array_equal(out["step"], tree["step"])
        np.testing.assert_array_equal(out["tiny"], tree["tiny"])
        np.testing.assert_array_equal(out["const"], tree["const"])
        for a, b in zip(
            [fields["smooth"], fields["walk3d"], fields["noisy"]],
            [out["layers"][0], out["layers"][1], out["noisy"]],
        ):
            assert a.shape == b.shape and a.dtype == b.dtype
            if mode == "fixed_psnr":
                assert _psnr(a, b) >= target - 1.0
        if mode == "fixed_ratio":
            # per-leaf targets: every compressible leaf meets the ratio
            for name in ("layers/0", "layers/1", "noisy"):
                cf = ct.fields[name]
                ratio = int(np.prod(cf.shape)) * 4 / len(cf.data)
                assert ratio >= target * 0.9, (name, ratio)


def test_compress_single_field_modes():
    f = _fields()["noisy"]
    cf = compress(f, Policy.fixed_psnr(50.0))
    assert abs(_psnr(f, decompress(cf).reshape(f.shape)) - 50.0) <= 1.0
    cf = compress(f, Policy.fixed_ratio(8.0))
    assert abs((f.size * 4 / len(cf.data)) / 8.0 - 1.0) <= 0.10
    cf = compress(f, Policy.fixed_accuracy(eb_rel=1e-3))  # bound-centric path
    rec = decompress(cf).reshape(f.shape)
    vr = f.max() - f.min()
    assert np.abs(f - rec).max() <= 1e-3 * vr * 1.001


def test_checkpoint_manager_target_modes(tmp_path):
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

    fields = _fields()
    tree = {"w1": fields["smooth"], "w2": fields["noisy"], "opt/m": fields["rough"]}
    mgr = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), policy=Policy.fixed_ratio(8.0), workers=0,
    ))
    mgr.save(7, tree)
    step, out = mgr.restore()
    assert step == 7
    # weights hit the per-tensor ratio target; opt state stayed raw
    import json, os

    with open(os.path.join(str(tmp_path), "step_000000007", "manifest.json")) as fh:
        manifest = json.load(fh)
    assert manifest["mode"] == "fixed_ratio" and manifest["target"] == 8.0
    by_name = {f["name"]: f for f in manifest["fields"]}
    assert by_name["opt/m"]["codec"] == "none"
    for name in ("w1", "w2"):
        fl = by_name[name]
        ratio = int(np.prod(fl["shape"])) * 4 / fl["nbytes"]
        assert ratio >= 8.0 * 0.9, (name, ratio)
        assert out[name].shape == tree[name].shape


def test_kv_ratio_budget():
    import jax
    import jax.numpy as jnp

    from repro.runtime import kvcomp

    rng = np.random.default_rng(1)
    page = jnp.asarray(np.cumsum(rng.standard_normal((256, 256)), 1).astype(np.float32))
    for target in (4.0, 8.0):
        recon, bits = kvcomp.bot_compress_kv(page, Policy.fixed_ratio(target))
        total = float(jnp.sum(bits))
        # budget semantics: estimated-rate-guided bound meets the byte
        # budget, with at most ~one bit-plane (octave) of undershoot
        assert total <= 32.0 * page.size / target * 1.05, target
        assert total >= 32.0 * page.size / (target * 4.0), target
        vr = float(jnp.max(page) - jnp.min(page))
        assert float(jnp.max(jnp.abs(recon - page))) <= 0.1 * vr
    # jit-safe (in-graph page-out decisions)
    f = jax.jit(lambda p: kvcomp.bot_compress_kv(p, Policy.fixed_ratio(8.0)))
    _, bits_j = f(page)
    assert float(jnp.sum(bits_j)) <= 32.0 * page.size / 8.0 * 1.05
