"""Device-resident Stage III (DESIGN.md §3.7): packer parity + fallbacks.

The load-bearing contract: fed the SAME quantized codes, the in-graph
packer and the host Stage III produce BYTE-IDENTICAL streams — so every
device-packed container decodes through the unchanged host decoders. The
parity surfaces (`sz_device_residuals`, `zfp_device_codes`) exist exactly
so these tests (and the `device_encode_parity` bench gate) can feed the
host encoder the device's codes and compare bytes, independent of the
f32-vs-f64 quantization boundary noted in the module docstring.
"""

import numpy as np
import pytest

from repro.core import api, codecs, device_encode as de, selector, sz, zfp
from repro.core.policy import Policy
from repro.runtime import kvcomp


def _tol(eb, x):
    return eb + 4 * np.spacing(np.abs(x).max() + 1e-30)


def _field(shape, kind, seed):
    rng = np.random.default_rng(seed)
    if kind == "noise":
        return rng.standard_normal(shape).astype(np.float32)
    if kind == "smooth":
        grids = np.meshgrid(*[np.linspace(0, 4, s) for s in shape], indexing="ij")
        out = np.ones(shape)
        for g in grids:
            out = out * np.sin(g)
        return (out + 0.01 * rng.standard_normal(shape)).astype(np.float32)
    if kind == "walk":
        return np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)
    raise ValueError(kind)


SHAPES = [(2048,), (96, 80), (24, 40, 32), (30, 29)]  # incl. ragged
KINDS = ["smooth", "walk"]


# ---------------------------------------------------------------------------
# byte parity on the same codes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_sz_device_stream_byte_parity(shape, kind):
    x = _field(shape, kind, 3)
    eb = 1e-3 * float(x.max() - x.min())
    dev = de.sz_encode_device(x, eb)
    assert dev is not None
    # the host Stage III over the device's own residuals
    d = de.sz_device_residuals(x, eb)
    delta = float(np.float32(2.0) * np.float32(eb))
    host = sz.sz_encode_residuals(d, x.shape, delta, magic=sz.DEVICE_MAGIC)
    assert dev == host


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_zfp_device_stream_byte_parity(shape, kind):
    x = _field(shape, kind, 5)
    eb = 1e-3 * float(x.max() - x.min())
    dev = de.zfp_encode_device(x, eb)
    assert dev is not None
    q, e = de.zfp_device_codes(x, eb)
    padded = tuple(s + (-s) % 4 for s in x.shape)
    host = zfp.zfp_encode_quantized(q, e, x.shape, padded, eb)
    assert dev == host


def test_sz_parity_escape_heavy():
    """Outliers past RESIDUAL_RADIUS exercise the escape-literal scatter."""
    rng = np.random.default_rng(11)
    x = np.cumsum(rng.standard_normal((64, 64)), axis=0).astype(np.float32)
    x[::7, ::5] += 1e4 * rng.standard_normal(x[::7, ::5].shape).astype(np.float32)
    eb = 1e-6 * float(x.max() - x.min())
    dev = de.sz_encode_device(x, eb)
    assert dev is not None
    d = de.sz_device_residuals(x, eb)
    assert np.sum(np.abs(d) > sz.RESIDUAL_RADIUS) > 0  # escapes really fired
    delta = float(np.float32(2.0) * np.float32(eb))
    assert dev == sz.sz_encode_residuals(d, x.shape, delta, magic=sz.DEVICE_MAGIC)


def test_constant_field_parity():
    """All-zero symbols / zero bit-planes — the degenerate stream shapes."""
    x = np.full((32, 32), 3.25, np.float32)
    dev = de.sz_encode_device(x, 1e-3)
    d = de.sz_device_residuals(x, 1e-3)
    delta = float(np.float32(2.0) * np.float32(1e-3))
    assert dev == sz.sz_encode_residuals(d, x.shape, delta, magic=sz.DEVICE_MAGIC)
    devz = de.zfp_encode_device(x, 1e-3)
    q, e = de.zfp_device_codes(x, 1e-3)
    assert devz == zfp.zfp_encode_quantized(q, e, x.shape, x.shape, 1e-3)


# ---------------------------------------------------------------------------
# host decoders consume device streams; bound holds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
def test_device_streams_decode_within_bound(shape):
    x = _field(shape, "walk", 9)
    eb = 1e-3 * float(x.max() - x.min())
    rec_sz = sz.sz_decompress(de.sz_encode_device(x, eb)).reshape(x.shape)
    assert np.abs(rec_sz - x).max() <= _tol(eb, x)
    rec_zfp = zfp.zfp_decompress(de.zfp_encode_device(x, eb)).reshape(x.shape)
    assert np.abs(rec_zfp - x).max() <= _tol(eb, x)


def test_sz_device_magic_roundtrips():
    x = _field((64, 64), "smooth", 2)
    buf = de.sz_encode_device(x, 1e-3)
    assert buf[:4] == sz.DEVICE_MAGIC
    # host streams keep the SZJ1 magic; the decoder accepts both
    assert sz.sz_compress(x, 1e-3)[:4] != sz.DEVICE_MAGIC
    sz.sz_decompress(buf)


# ---------------------------------------------------------------------------
# fallback rules: None means host coder, never a truncated stream
# ---------------------------------------------------------------------------


def test_zero_size_and_bad_bounds_fall_back():
    empty = np.zeros((0,), np.float32)
    assert de.sz_encode_device(empty, 1e-3) is None
    assert de.zfp_encode_device(empty, 1e-3) is None
    x = _field((16, 16), "walk", 1)
    assert de.sz_encode_device(x, 0.0) is None
    assert de.zfp_encode_device(x, 0.0) is None
    assert de.zfp_encode_device(x, float("nan")) is None


def test_code_magnitude_guard_falls_back():
    """Bound so tight the codes leave f32-exact integer range -> None."""
    x = (1e6 * _field((32, 32), "walk", 4)).astype(np.float32)
    assert de.sz_encode_device(x, 1e-4) is None
    assert de.zfp_encode_device(x, 1e-6) is None


def test_arena_overflow_guard_falls_back(monkeypatch):
    """A rate-model under-estimate must surface as a clean None (the pack
    arena DROPS out-of-range bits, and the emitter's true bit total is
    checked against capacity) — never as a truncated container."""
    monkeypatch.setattr(de.pack, "arena_words", lambda bits, min_words=1: 1)
    x = _field((64, 64), "walk", 8)
    assert de.zfp_encode_device(x, 1e-3 * float(x.max() - x.min())) is None


def test_encode_with_selection_falls_back_to_host(monkeypatch):
    """Through the registry path: a declining device tier means the host
    coder runs and the field still encodes + decodes normally."""
    monkeypatch.setattr(de.pack, "arena_words", lambda bits, min_words=1: 1)
    x = _field((64, 64), "walk", 8)
    cf = selector.encode_with_selection(
        x, selector.select(x, eb_rel=1e-3), device_encode=True
    )
    rec = api.decompress(cf).reshape(x.shape)
    eb = 1e-3 * float(x.max() - x.min())
    assert np.abs(rec - x).max() <= _tol(eb, x)


# ---------------------------------------------------------------------------
# integration: registry capability, api flag, kv page codec
# ---------------------------------------------------------------------------


def test_registry_capability_flags():
    assert codecs.supports_device_encode("sz")
    assert codecs.supports_device_encode("zfp")
    assert not codecs.supports_device_encode("raw")
    # pre-flag third-party codecs keep satisfying the protocol
    class Legacy:
        name, blockwise, pointwise_bound, lossless = "legacy", False, True, False

        def encode(self, v, s):
            return v.tobytes()

        def decode(self, b):
            return codecs.writeable_frombuffer(b, np.float32)

    assert not getattr(Legacy(), "device_encode", False)


@pytest.mark.parametrize("sharded", [False, True])
def test_compress_pytree_device_encode_roundtrip(sharded):
    rng = np.random.default_rng(6)
    tree = {
        "walk": np.cumsum(rng.standard_normal((64, 64)), 0).astype(np.float32),
        "noise": rng.standard_normal((512,)).astype(np.float32),
        "small": np.arange(3, dtype=np.float32),
    }
    ct = api.compress_pytree(
        tree, policy=Policy.fixed_accuracy(eb_rel=1e-3),
        sharded=sharded, device_encode=True,
    )
    back = api.decompress_pytree(ct)
    for k, v in tree.items():
        vr = float(v.max() - v.min()) if v.size else 0.0
        assert np.abs(back[k] - v).max() <= _tol(1e-3 * vr, v)


def test_kv_page_device_encode_roundtrip():
    rng = np.random.default_rng(7)
    page = np.cumsum(rng.standard_normal((64, 256)), axis=0).astype(np.float32)
    cp = kvcomp.compress_page(
        page, Policy.fixed_accuracy(eb_rel=1e-2), device_encode=True
    )
    assert cp.codec == "zfp"
    assert cp.nbytes == len(cp.payload) < page.nbytes  # literal footprint
    rec = kvcomp.decompress_page(cp)
    assert rec.shape == page.shape and rec.dtype == page.dtype
    vr = float(page.max() - page.min())
    assert np.abs(rec - page).max() <= _tol(1e-2 * vr, page)
    # raw policy is untouched by the flag: exact bytes either way
    raw = kvcomp.compress_page(page, Policy.raw(), device_encode=True)
    assert raw.codec == "raw"
    assert np.array_equal(kvcomp.decompress_page(raw), page)
