"""Golden decision-regression suite: frozen (codec, eb, estimated bits).

The paper's headline number is ~99% selection accuracy; nothing in the
ordinary unit tests would notice if an estimator or controller refactor
shifted a handful of borderline fields to the other codec while every
roundtrip bound still held. This suite freezes the full decision tuple for
seeded ATM/Hurricane-like synthetic fields (benchmarks/common.py, the same
generators the paper-replication benches use) and fails on ANY change:

* codec flip -> hard failure (the selection itself regressed);
* eb / eb_sz drift -> hard failure (the iso-PSNR match moved);
* estimated bit-rates beyond a small tolerance -> failure (the §4–§5
  estimators moved; tolerance covers jax-version ulps, not model changes).

Regenerate intentionally with:

    pytest tests/test_golden_decisions.py --update-golden

Goldens are keyed by the active Huffman-table cost
(`estimator.TABLE_BITS_PER_SYMBOL`: 5 with zstandard, 40 bare) because the
§4 table-cost term legitimately differs between environments; regenerate
the other environment's key via the `REPRO_SZ_TABLE_BITS` override, e.g.

    REPRO_SZ_TABLE_BITS=5 pytest tests/test_golden_decisions.py --update-golden
"""

import json
from pathlib import Path

import pytest

from repro.core import Policy
from repro.core import estimator as est
from repro.core import select_many, solve_many

GOLDEN_DIR = Path(__file__).parent / "golden"

#: decision margins below this (|br_sz - br_zfp|, bits/value) would make a
#: golden flaky across jax versions; the update path asserts none exist
MIN_MARGIN = 0.05
#: estimated-rate drift tolerance (bits/value): generous vs float noise,
#: tiny vs any real estimator change
BR_ATOL = 5e-3


def _suite_fields():
    from benchmarks.common import atm_suite, hurricane_suite, nyx_suite

    fields = {}
    fields.update({f"atm/{k}": v for k, v in atm_suite(8, size=(96, 192)).items()})
    fields.update(
        {f"hur/{k}": v for k, v in hurricane_suite(6, size=(16, 48, 48)).items()}
    )
    # genuinely-3-D volumes big enough for the 3-D kernel tier (ISSUE 4):
    # exercises the 4x4x4 batched Stage I/II stats end to end
    fields.update({f"nyx/{k}": v for k, v in nyx_suite(4, size=(32, 32, 32)).items()})
    return fields


def _env_key() -> str:
    return f"table{int(est.TABLE_BITS_PER_SYMBOL)}"


def _decide(fields, eb_rel):
    # the Policy spelling — frozen goldens also pin the policy path to the
    # historical kwarg decisions (the api_redesign invariant)
    sels = select_many(list(fields.values()), policy=Policy.fixed_accuracy(eb_rel=eb_rel))
    return {
        name: dict(
            codec=s.codec,
            eb=float(s.eb_abs),
            eb_sz=float(s.eb_sz),
            br_sz=round(float(s.br_sz), 4),
            br_zfp=round(float(s.br_zfp), 4),
        )
        for name, s in zip(fields, sels)
    }


def _solve(fields, pol):
    sols = solve_many(list(fields.values()), pol)
    return {
        name: dict(
            codec=t.selection.codec,
            eb=float(t.selection.eb_abs),
            on_target=bool(t.on_target),
            est_bitrate=round(float(t.est_bitrate), 3),
        )
        for name, t in zip(fields, sols)
    }


def _check_or_update(
    path: Path,
    current: dict,
    update: bool,
    eb_rtol: float = 1e-6,
    br_keys=("br_sz", "br_zfp", "est_bitrate"),
):
    key = _env_key()
    existing = json.loads(path.read_text()) if path.exists() else {}
    if update:
        existing[key] = current
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(existing, indent=1, sort_keys=True) + "\n")
        return
    if key not in existing:
        pytest.skip(
            f"no golden for {key} in {path.name}; run --update-golden in this "
            "environment (or with REPRO_SZ_TABLE_BITS set)"
        )
    frozen = existing[key]
    assert set(frozen) == set(current), "golden field set changed — regenerate"
    for name, want in frozen.items():
        got = current[name]
        assert got["codec"] == want["codec"], (
            f"{name}: selection flipped {want['codec']} -> {got['codec']} "
            f"(was {want}, now {got})"
        )
        assert got["eb"] == pytest.approx(want["eb"], rel=eb_rtol), name
        if "eb_sz" in want:
            assert got["eb_sz"] == pytest.approx(want["eb_sz"], rel=1e-5), (
                f"{name}: iso-PSNR match moved"
            )
        if "on_target" in want:
            assert got["on_target"] == want["on_target"], name
        if "event" in want:
            assert got["event"] == want["event"], (
                f"{name}: cache event changed {want['event']} -> {got['event']}"
            )
        for k in br_keys:
            if k in want:
                assert got[k] == pytest.approx(want[k], abs=BR_ATOL), (
                    f"{name}: estimated rate {k} drifted {want[k]} -> {got[k]}"
                )


def test_golden_fixed_accuracy(update_golden):
    fields = _suite_fields()
    current = _decide(fields, eb_rel=1e-3)
    if update_golden:
        margins = {
            n: abs(d["br_sz"] - d["br_zfp"])
            for n, d in current.items()
            if d["codec"] != "raw"
        }
        thin = {n: m for n, m in margins.items() if m < MIN_MARGIN}
        assert not thin, f"fields too close to the decision margin for a golden: {thin}"
    _check_or_update(GOLDEN_DIR / "fixed_accuracy.json", current, update_golden)


def test_golden_warm_trajectory(update_golden):
    """Frozen 3-step repeated-save trajectory through the decision cache
    (DESIGN.md §8): step 0 cold-populates, step 1 replays identical data
    (all hits), step 2 scale-jumps one field and ulp-nudges another (both
    invalidate and re-decide; everything else stays a hit). Freezes the
    cache EVENT next to the decision tuple, so a silent change to the
    fingerprint/invalidation rules fails even if the decisions happen to
    agree. One --update-golden pass regenerates all three steps."""
    import numpy as np

    from repro.core.decision_cache import DecisionCache

    fields = _suite_fields()
    names = list(fields)
    pol = Policy.fixed_accuracy(eb_rel=1e-3)
    cache = DecisionCache()
    jump, nudge = names[0], names[1]
    steps = []
    for step in range(3):
        cur = {n: v.copy() for n, v in fields.items()}
        if step == 2:
            cur[jump] = cur[jump] * 1000.0
            a = cur[nudge]
            a.flat[0] = np.nextafter(a.flat[0], np.float32(np.inf))
        cache.reset_stats()
        sels = select_many(
            list(cur.values()), policy=pol, cache=cache, names=names
        )
        steps.append(
            {
                name: dict(
                    event=cache.events.get(name, "degenerate"),
                    codec=s.codec,
                    eb=float(s.eb_abs),
                    eb_sz=float(s.eb_sz),
                    br_sz=round(float(s.br_sz), 4),
                    br_zfp=round(float(s.br_zfp), 4),
                )
                for name, s in zip(names, sels)
            }
        )
    # structural invariants, independent of the frozen numbers
    assert all(d["event"] in ("miss", "degenerate") for d in steps[0].values())
    assert all(d["event"] in ("hit", "degenerate") for d in steps[1].values())

    def _dec(d):
        return {k: v for k, v in d.items() if k != "event"}

    assert {n: _dec(d) for n, d in steps[1].items()} == {
        n: _dec(d) for n, d in steps[0].items()
    }, "warm step must replay the cold decisions bit-identically"
    assert steps[2][jump]["event"] == "invalidated"
    assert steps[2][nudge]["event"] == "invalidated"
    assert all(
        steps[2][n]["event"] in ("hit", "degenerate")
        for n in names
        if n not in (jump, nudge)
    )
    current = {
        f"step{i}/{n}": d for i, s in enumerate(steps) for n, d in s.items()
    }
    _check_or_update(GOLDEN_DIR / "warm_trajectory.json", current, update_golden)


def test_golden_fixed_psnr(update_golden):
    fields = _suite_fields()
    current = _solve(fields, Policy.fixed_psnr(60.0))
    # the solved bound rides measured sample curves -> slightly looser than
    # the closed-form fixed_accuracy eb (still far below any model change)
    _check_or_update(GOLDEN_DIR / "fixed_psnr.json", current, update_golden, eb_rtol=1e-4)


def test_golden_fixed_ratio(update_golden):
    fields = _suite_fields()
    current = _solve(fields, Policy.fixed_ratio(6.0))
    _check_or_update(GOLDEN_DIR / "fixed_ratio.json", current, update_golden, eb_rtol=1e-4)
