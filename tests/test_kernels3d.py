"""3-D Pallas kernel tier vs jnp oracles (DESIGN.md §3.4–§3.5), plus the
shared dispatch predicate that keeps the Lorenzo and BOT wrappers routing
the same fields to the same tier."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embedded
from repro.core.transforms import block_transform_nd, bot_linf_gain, bot_matrix
from repro.kernels import bot4, lorenzo, ops, ref


def _field(shape, seed, kind="walk"):
    rng = np.random.default_rng(seed)
    if kind == "walk":
        return jnp.asarray(
            np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)
        )
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


#: tile multiples, clamped-tile shapes, and ragged padded-edge shapes
SHAPES3 = [(16, 128, 256), (32, 96, 96), (13, 50, 67), (8, 130, 259)]
BLOCKS3 = [(8, 128, 256), (8, 32, 128), (4, 16, 128)]


@pytest.mark.parametrize("shape", SHAPES3)
@pytest.mark.parametrize("kind", ["walk", "noise"])
def test_lorenzo3d_kernel_matches_ref(shape, kind):
    x = _field(shape, 0, kind)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got = ops.lorenzo_encode(x, eb)
    want = ref.lorenzo3d_encode_ref(x, eb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", BLOCKS3)
def test_lorenzo3d_kernel_block_sweep(block):
    x = _field((16, 128, 256), 1)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got = lorenzo.lorenzo3d_encode(x, eb, block=block)
    want = ref.lorenzo3d_encode_ref(x, eb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("eb_rel", [1e-2, 1e-3, 1e-5])
def test_lorenzo3d_roundtrip_bound(eb_rel):
    x = _field((12, 60, 77), 2)
    eb = eb_rel * float(jnp.max(x) - jnp.min(x))
    codes = ops.lorenzo_encode(x, eb)
    # decode-side parity: kernel dequantize == reference decode, bit-exact
    rec = ops.lorenzo_decode(codes, eb)
    want = ref.lorenzo3d_decode_ref(codes, eb)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(want))
    tol = eb + 4 * float(np.spacing(np.float32(float(jnp.max(jnp.abs(x))))))
    assert float(jnp.max(jnp.abs(rec - x))) <= tol


@pytest.mark.parametrize("shape", SHAPES3)
@pytest.mark.parametrize("transform", ["zfp", "hwt", "dct2"])
def test_bot3d_kernel_matches_ref(shape, transform):
    x = _field(shape, 3)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    got_r, got_b = ops.bot_fused(x, eb, transform=transform)
    z, m, n = shape
    xp = jnp.pad(x, tuple((0, (-s) % 4) for s in shape))
    want_r, want_b = ref.bot3d_fused_ref(xp, eb, transform=transform)
    np.testing.assert_allclose(
        np.asarray(got_r),
        np.asarray(want_r)[:z, :m, :n],
        atol=1e-5 * float(jnp.max(jnp.abs(x))),
    )
    np.testing.assert_allclose(
        np.asarray(got_b),
        np.asarray(want_b)[: -(-z // 4), : -(-m // 4), : -(-n // 4)],
        rtol=1e-6,
    )


def test_bot3d_block_bits_agreement():
    """The kernel's in-tile closed-form rate model must equal
    `embedded.block_bits` evaluated on the same coefficients — the
    selector's §5 coder model and the kernel tier cannot drift apart.
    Coefficients are rebuilt with the kernel's own contraction (one
    einsum) so the comparison is exact: a different contraction order
    shifts knife-edge coefficients across a bit-plane boundary, which is
    contraction ulps, not a rate-model difference."""
    x = _field((16, 96, 128), 4)  # 4-multiples: blockize pads nothing
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    _, got_b = ops.bot_fused(x, eb)
    z, m, n = x.shape
    b = x.reshape(z // 4, 4, m // 4, 4, n // 4, 4).transpose(0, 2, 4, 1, 3, 5)
    blocks = b.reshape(-1, 4, 4, 4)
    norm, e = embedded.align_blocks(blocks)
    T = jnp.asarray(bot_matrix("zfp"), jnp.float32)
    coeffs = jnp.einsum("ai,bj,ck,xijk->xabc", T, T, T, norm)
    step = embedded.plane_step(jnp.float32(eb), e, bot_linf_gain("zfp") ** 3)
    want = np.asarray(embedded.block_bits(coeffs, step))
    got = np.asarray(got_b).reshape(-1)
    # a coefficient sitting exactly on a bit-plane boundary can gain/lose
    # one significant bit under a different einsum lowering; everything
    # else must match the closed-form model exactly
    diff = np.abs(got - want)
    assert np.mean(diff > 0) < 5e-3, f"{np.mean(diff > 0):.4f} blocks differ"
    assert diff.max() <= 8.0, "beyond a knife-edge plane flip: model drifted"
    assert abs(float(np.mean(got - want))) / 64.0 < 1e-4  # bits/value
    # and the selector's generic transform path agrees to the same ulps
    coeffs2 = block_transform_nd(norm, T, 3)
    want2 = np.asarray(embedded.block_bits(coeffs2, step))
    assert abs(float(np.mean(want2 - got))) / 64.0 < 1e-4  # bits/value


def test_bot3d_error_bound():
    x = _field((32, 64, 64), 5)
    eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
    rec, _ = ops.bot_fused(x, eb)
    assert float(jnp.max(jnp.abs(rec - x))) <= eb


def test_dispatch_predicate_shared():
    """The ISSUE-4 bugfix: ONE predicate decides the kernel tier for both
    wrappers, so no field encodes on one path and prices on another.
    Short leading dims (a 4-token KV page, a 7-plane volume) stay on the
    kernel tier via sublane padding — `bot_compress_kv` relies on real
    per-block bits for every 2-D/3-D page."""
    assert ops.pallas_rank((256, 256)) == 2
    assert ops.pallas_rank((96, 256, 256)) == 3
    assert ops.pallas_rank((4, 40)) == 2  # short pages pad into the tier
    assert ops.pallas_rank((7, 64, 64)) == 3
    assert ops.pallas_rank((4096,)) is None
    assert ops.pallas_rank((0, 40)) is None  # empty: nothing to tile
    assert ops.pallas_rank((2, 3, 8, 32, 32)) is None  # >3-D: fold first
    for shape in [(4, 40), (8, 40), (7, 64, 64), (8, 64, 64), (4096,)]:
        x = _field(shape, 6)
        eb = 1e-3 * float(jnp.max(x) - jnp.min(x))
        # lorenzo agrees with the rank-generic reference on BOTH paths
        np.testing.assert_array_equal(
            np.asarray(ops.lorenzo_encode(x, eb)),
            np.asarray(ref.lorenzo_encode_ref(x, eb)),
        )
        # bot reports per-block bits exactly when the kernel tier serves
        # the shape — the same predicate, observable from outside
        _, bits = ops.bot_fused(x, eb)
        assert (bits is not None) == (ops.pallas_rank(shape) is not None), shape


def test_fold_plans_keep_3d_fields_3d():
    """Genuinely-3-D fields must reach the kernel tier as 3-D views; only
    rank > 3 folds (to 3-D, never to 2-D) and short leading dims merge."""
    from repro.launch.shapes import compression_view

    assert compression_view((96, 256, 256)) == (96, 256, 256)
    assert compression_view((8, 64, 64, 64)) == (512, 64, 64)
    assert compression_view((2, 3, 8, 32, 32)) == (48, 32, 32)
    assert compression_view((2, 96, 96)) == (192, 96)  # z < 4: no 4-block
    assert ops.pallas_rank(compression_view((8, 64, 64, 64))) == 3


def test_kernels3d_are_jittable_and_lowerable():
    """The 3-D kernels must lower+compile under jit (TPU-target health)."""
    x = jax.ShapeDtypeStruct((16, 128, 256), jnp.float32)
    c1 = jax.jit(lambda a: lorenzo.lorenzo3d_encode(a, 1e-3)).lower(x).compile()
    assert c1.cost_analysis() is not None
    c2 = jax.jit(lambda a: bot4.bot3d_fused(a, 1e-3)).lower(x).compile()
    assert c2 is not None


def test_select_3d_batched_matches_per_field():
    """Batched 3-D decisions == per-field reference decisions (Stage I/II
    over 4x4x4 blocks; acceptance criterion of ISSUE 4)."""
    from benchmarks.common import hurricane_suite, nyx_suite
    from repro.core import select, select_many

    fields = list(hurricane_suite(4, size=(16, 48, 48)).values())
    fields += list(nyx_suite(3, size=(32, 32, 32)).values())
    many = select_many(fields, eb_rel=1e-3)
    for f, m in zip(fields, many):
        s = select(f, eb_abs=float(m.eb_abs))
        assert m.codec == s.codec
        assert m.eb_sz == pytest.approx(s.eb_sz, rel=1e-6)
