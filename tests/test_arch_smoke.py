"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn

B, L = 2, 32


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = reduced_for_smoke(get_config(name))
    model = build_model(cfg)
    params = nn.init_tree(model.desc(), jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    logits, _ = model.forward(params, batch, cache=None)
    exp_len = L + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))

    # one SGD step: loss must be finite, grads finite, loss near ln(V) at init
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab)) < 3.0

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat, _ = jax.tree_util.tree_flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = model.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_step(name):
    cfg = reduced_for_smoke(get_config(name))
    model = build_model(cfg)
    params = nn.init_tree(model.desc(), jax.random.key(1))
    rng = np.random.default_rng(1)
    cache = model.init_cache(B, 64)
    sb = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.encdec:
        sb["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    logits, cache = model.forward(params, sb, cache=cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert int(cache["pos"]) == 1
    # second step advances the position
    logits, cache = model.forward(params, {"tokens": jnp.zeros((B, 1), jnp.int32)}, cache=cache)
    assert int(cache["pos"]) == 2


@pytest.mark.parametrize("name", ["smollm-360m", "xlstm-1.3b", "zamba2-1.2b", "seamless-m4t-large-v2", "deepseek-v2-236b"])
def test_decode_matches_parallel(name):
    """Token-by-token decode equals the parallel forward (per family)."""
    cfg = reduced_for_smoke(get_config(name))
    model = build_model(cfg)
    params = nn.init_tree(model.desc(), jax.random.key(2))
    rng = np.random.default_rng(2)
    batch = _batch(cfg, rng)
    logits_full, _ = model.forward(params, batch, cache=None)
    if cfg.frontend == "vision":
        logits_full = logits_full[:, cfg.frontend_len :]
    cache = model.init_cache(B, 64)
    outs = []
    for t in range(8):
        sb = {"tokens": batch["tokens"][:, t : t + 1]}
        if cfg.encdec and t == 0:
            sb["frames"] = batch["frames"]
        lg, cache = model.forward(params, sb, cache=cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full[:, :8]))) + 1e-6
    diff = float(jnp.max(jnp.abs(dec - logits_full[:, :8])))
    assert diff / scale < 0.05, (diff, scale)  # bf16 chunked-vs-recurrent
