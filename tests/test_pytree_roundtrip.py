"""compress_pytree / decompress_pytree round-trips on mixed pytrees:
non-float leaves, 0-d scalars, >3-D tensors, policy-raw fields — plus the
restored-leaf contracts: every leaf WRITEABLE, `.ratio` measured against
true per-dtype raw bytes."""

import numpy as np

from repro.core import Policy, PolicySet
from repro.core.api import compress_pytree, decompress_pytree


def _mixed_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((128, 96)).astype(np.float32),
        "wd": np.cumsum(rng.standard_normal((96, 96)), 0),   # float64
        "conv": rng.standard_normal((2, 3, 8, 32, 32)).astype(np.float32),  # 5-D
        "bias": rng.standard_normal((96,)).astype(np.float32),
        "step": np.array(1234, dtype=np.int64),            # 0-d int
        "lr": np.array(3e-4, dtype=np.float32),            # 0-d float
        "mask": rng.integers(0, 2, (64, 64)).astype(bool),
        "ids": rng.integers(0, 50_000, (512,)).astype(np.int32),
        "nested": {
            "emb": np.cumsum(rng.standard_normal((80, 80)), 0).astype(np.float32),
            "counts": np.arange(17, dtype=np.uint32),
        },
    }


def test_mixed_tree_shapes_and_dtypes_preserved():
    tree = _mixed_tree()
    ct = compress_pytree(tree, Policy.fixed_accuracy(eb_rel=1e-4))
    out = decompress_pytree(ct)
    flat_in = {
        "w": tree["w"], "wd": tree["wd"], "conv": tree["conv"],
        "bias": tree["bias"], "step": tree["step"], "lr": tree["lr"],
        "mask": tree["mask"], "ids": tree["ids"],
        "nested/emb": tree["nested"]["emb"],
        "nested/counts": tree["nested"]["counts"],
    }
    flat_out = {
        "w": out["w"], "wd": out["wd"], "conv": out["conv"],
        "bias": out["bias"], "step": out["step"], "lr": out["lr"],
        "mask": out["mask"], "ids": out["ids"],
        "nested/emb": out["nested"]["emb"],
        "nested/counts": out["nested"]["counts"],
    }
    for k, v in flat_in.items():
        assert flat_out[k].shape == v.shape, k
        # dtype preserved for every leaf (float leaves carry f32-precision
        # values but keep their declared dtype)
        assert flat_out[k].dtype == v.dtype, k
        if not np.issubdtype(v.dtype, np.floating):
            # non-float leaves ride raw: bits exactly preserved
            np.testing.assert_array_equal(flat_out[k], v)


def test_float_leaves_respect_error_bound():
    tree = _mixed_tree(seed=5)
    eb_rel = 1e-4
    ct = compress_pytree(tree, Policy.fixed_accuracy(eb_rel=eb_rel))
    out = decompress_pytree(ct)
    for k in ("w", "bias"):
        vr = tree[k].max() - tree[k].min()
        assert np.abs(out[k] - tree[k]).max() <= eb_rel * vr * 1.05, k
    vr = tree["conv"].max() - tree["conv"].min()
    assert np.abs(out["conv"] - tree["conv"]).max() <= eb_rel * vr * 1.05
    vr = tree["nested"]["emb"].max() - tree["nested"]["emb"].min()
    assert np.abs(out["nested"]["emb"] - tree["nested"]["emb"]).max() <= eb_rel * vr * 1.05
    # 0-d float is below the size floor -> raw, exactly preserved
    np.testing.assert_array_equal(out["lr"], tree["lr"])


def test_policy_raw_fields_stay_exact():
    tree = _mixed_tree(seed=9)
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-2),
        rules=[("w", Policy.raw()), ("nested/emb", Policy.raw())],
    )
    ct = compress_pytree(tree, pset)
    assert ct.fields["w"].codec == "raw"
    assert ct.fields["nested/emb"].codec == "raw"
    out = decompress_pytree(ct)
    np.testing.assert_array_equal(out["w"], tree["w"])
    np.testing.assert_array_equal(out["nested"]["emb"], tree["nested"]["emb"])
    assert out["w"].dtype == tree["w"].dtype
    # non-skipped float leaves still compressed
    assert ct.fields["conv"].codec in ("sz", "zfp", "raw")
    assert ct.ratio > 1.0


def test_empty_and_list_pytrees():
    ct = compress_pytree({"a": []})
    out = decompress_pytree(ct)
    assert out == {"a": []}
    tree = [np.arange(8, dtype=np.float32), np.float64(2.0).reshape(())]
    out = decompress_pytree(compress_pytree(tree))
    assert out[0].shape == (8,)
    np.testing.assert_allclose(out[0], tree[0])
