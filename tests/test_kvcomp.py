"""KV-cache compression: int8 quantized cache correctness + BOT page path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.core import Policy
from repro.runtime import kvcomp


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 8, 32)).astype(np.float32))
    q, s = kvcomp.quantize_kv(x)
    back = kvcomp.dequantize_kv(q, s, jnp.float32)
    err = jnp.max(jnp.abs(back - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_int8_kv_cache_decode_close_to_fp():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    cfg_q = cfg.scaled(kv_quant=True)
    m_fp = build_model(cfg)
    m_q = build_model(cfg_q)
    params = rnn.init_tree(m_fp.desc(), jax.random.key(0))
    toks = jnp.arange(24, dtype=jnp.int32)[None, :].repeat(2, 0) % cfg.vocab
    c_fp = m_fp.init_cache(2, 32)
    c_q = m_q.init_cache(2, 32)
    assert c_q["blocks"]["k"].dtype == jnp.int8
    lf, _ = m_fp.forward(params, {"tokens": toks}, cache=c_fp)
    lq, _ = m_q.forward(params, {"tokens": toks}, cache=c_q)
    scale = float(jnp.max(jnp.abs(lf))) + 1e-6
    assert float(jnp.max(jnp.abs(lf - lq))) / scale < 0.08  # int8 noise only


def test_bot_page_compression():
    rng = np.random.default_rng(1)
    page = jnp.asarray(np.cumsum(rng.standard_normal((256, 256)), 1).astype(np.float32))
    recon, bits = kvcomp.bot_compress_kv(page, Policy.fixed_accuracy(eb_rel=1e-2))
    vr = float(jnp.max(page) - jnp.min(page))
    assert float(jnp.max(jnp.abs(recon - page))) <= 1e-2 * vr
    assert float(jnp.sum(bits)) < 8 * page.size * 4  # beats raw f32


def test_fixed_ratio_budget_met_on_compressible_page():
    """The in-graph octave grid solves a bound whose ACTUAL kernel bits
    meet the byte budget on a smooth (compressible) page."""
    rng = np.random.default_rng(2)
    page = jnp.asarray(
        np.cumsum(np.cumsum(rng.standard_normal((256, 256)), 0), 1).astype(np.float32)
        / 256.0
    )
    ratio = 8.0
    recon, bits = kvcomp.bot_compress_kv(page, Policy.fixed_ratio(ratio))
    total = float(jnp.sum(bits))
    budget_bits = 32.0 / ratio * page.size
    # the bound is solved on the r_sp-sampled estimate; allow its
    # sampling error, not a change of regime
    assert total <= budget_bits * 1.15, (total, budget_bits)
    # and the solved bound is a real error bound
    vr = float(jnp.max(page) - jnp.min(page))
    assert float(jnp.max(jnp.abs(recon - page))) <= vr / 2


def test_fixed_ratio_fallback_reports_honest_bits():
    """On incompressible noise at an unreachable ratio the solver falls
    back to the loosest candidate (vr/2) and the returned bits stay
    honest — they exceed the budget instead of pretending to meet it."""
    rng = np.random.default_rng(3)
    page = jnp.asarray(rng.uniform(-1.0, 1.0, (256, 256)).astype(np.float32))
    ratio = 64.0  # 0.5 bits/value: unreachable for uniform noise
    recon, bits = kvcomp.bot_compress_kv(page, Policy.fixed_ratio(ratio))
    total = float(jnp.sum(bits))
    assert total > 32.0 / ratio * page.size, "fallback must not fake the budget"
    vr = float(jnp.max(page) - jnp.min(page))
    # loosest grid candidate is vr/2 — still a hard pointwise bound
    assert float(jnp.max(jnp.abs(recon - page))) <= vr / 2 + 1e-6


def test_compress_page_raw_roundtrip_bit_identical():
    rng = np.random.default_rng(4)
    page = rng.standard_normal((2, 8, 64)).astype("bfloat16")
    cp = kvcomp.compress_page(page, Policy.raw())
    assert cp.codec == "raw" and cp.clean and cp.nbytes == page.nbytes
    back = kvcomp.decompress_page(cp)
    assert back.dtype == page.dtype and back.tobytes() == page.tobytes()


def test_compress_page_decision_cache_replays_bound():
    from repro.core.decision_cache import DecisionCache

    rng = np.random.default_rng(5)
    page = np.cumsum(rng.standard_normal((2, 8, 64)), 1).astype(np.float32)
    cache = DecisionCache()
    pol = Policy.fixed_ratio(8.0)
    a = kvcomp.compress_page(page, pol, cache=cache, name="kv/long/0/k0")
    assert cache.events["kv/long/0/k0"] == "miss"
    b = kvcomp.compress_page(page, pol, cache=cache, name="kv/long/0/k0")
    assert cache.events["kv/long/0/k0"] == "hit"  # frozen page: digest match
    assert a.eb == b.eb and a.nbytes == b.nbytes
    # content change invalidates the fingerprint (no stale bound replay)
    kvcomp.compress_page(page * 2.0, pol, cache=cache, name="kv/long/0/k0")
    assert cache.events["kv/long/0/k0"] == "invalidated"
