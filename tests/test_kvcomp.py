"""KV-cache compression: int8 quantized cache correctness + BOT page path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.core import Policy
from repro.runtime import kvcomp


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 8, 32)).astype(np.float32))
    q, s = kvcomp.quantize_kv(x)
    back = kvcomp.dequantize_kv(q, s, jnp.float32)
    err = jnp.max(jnp.abs(back - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_int8_kv_cache_decode_close_to_fp():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    cfg_q = cfg.scaled(kv_quant=True)
    m_fp = build_model(cfg)
    m_q = build_model(cfg_q)
    params = rnn.init_tree(m_fp.desc(), jax.random.key(0))
    toks = jnp.arange(24, dtype=jnp.int32)[None, :].repeat(2, 0) % cfg.vocab
    c_fp = m_fp.init_cache(2, 32)
    c_q = m_q.init_cache(2, 32)
    assert c_q["blocks"]["k"].dtype == jnp.int8
    lf, _ = m_fp.forward(params, {"tokens": toks}, cache=c_fp)
    lq, _ = m_q.forward(params, {"tokens": toks}, cache=c_q)
    scale = float(jnp.max(jnp.abs(lf))) + 1e-6
    assert float(jnp.max(jnp.abs(lf - lq))) / scale < 0.08  # int8 noise only


def test_bot_page_compression():
    rng = np.random.default_rng(1)
    page = jnp.asarray(np.cumsum(rng.standard_normal((256, 256)), 1).astype(np.float32))
    recon, bits = kvcomp.bot_compress_kv(page, Policy.fixed_accuracy(eb_rel=1e-2))
    vr = float(jnp.max(page) - jnp.min(page))
    assert float(jnp.max(jnp.abs(recon - page))) <= 1e-2 * vr
    assert float(jnp.sum(bits)) < 8 * page.size * 4  # beats raw f32
