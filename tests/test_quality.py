"""Quality-metrics subsystem (DESIGN.md §7.4): SSIM / correlation / KS as
first-class Policy targets.

Covers the new-subsystem surface end to end: targets actually achieved on
real encode+decode round-trips (one-sided `metric_gap` within the
documented tolerances), Policy spec/from_spec JSON round-trips and the
unknown-mode errors, PolicySet grouping with mixed PSNR/SSIM/correlation
trees, manifest-v3 `quality` rows + restore, decision-cache key
separation and warm bit-identity, and sharded `plan_tree` parity with the
host solver.
"""

import json

import numpy as np
import pytest

from repro.core import (
    Policy,
    PolicySet,
    compress_pytree,
    decompress,
    decompress_pytree,
    encode_with_selection,
    solve_many,
)
from repro.core import quality as qual
from repro.core.decision_cache import DecisionCache
from repro.core.policy import METRIC_MODES, policy_from_kwargs


def _fields():
    rng = np.random.default_rng(11)
    smooth = np.cumsum(
        np.cumsum(rng.standard_normal((48, 48)).astype(np.float32), 0), 1
    )
    noisy = (
        np.cumsum(rng.standard_normal((40, 40)).astype(np.float32), 0)
        + 0.05 * rng.standard_normal((40, 40)).astype(np.float32)
    )
    vol = np.cumsum(rng.standard_normal((12, 24, 24)).astype(np.float32), 1)
    return {"smooth": smooth, "noisy": noisy, "vol": vol}


POLICY_OF = {
    "ssim": Policy.fixed_ssim,
    "correlation": Policy.fixed_correlation,
    "ks": Policy.fixed_ks,
}
TARGET_OF = {"ssim": 0.97, "correlation": 0.995, "ks": 0.1}


@pytest.mark.parametrize("metric", sorted(TARGET_OF))
def test_metric_targets_achieved_on_roundtrip(metric):
    """Solve -> encode -> decode -> measure: every claimed-on-target field
    must land within quality.TOLERANCE, one-sided (floors for
    SSIM/correlation, ceiling for KS). Zero trial compressions by
    construction — solve_many runs before any encode."""
    target = TARGET_OF[metric]
    fields = _fields()
    sols = solve_many(list(fields.values()), POLICY_OF[metric](target))
    claimed = 0
    for (name, a), sol in zip(fields.items(), sols):
        assert sol.mode == f"fixed_{metric}" and sol.target == target
        assert sol.est_metric is not None
        cf = encode_with_selection(a, sol.selection)
        rec = decompress(cf).reshape(a.shape)
        achieved = qual.measured_metric(metric, a, rec)
        gap = qual.metric_gap(metric, achieved, target)
        if sol.on_target:
            claimed += 1
            assert gap <= qual.TOLERANCE[metric], (
                f"{name}: measured {metric} {achieved:.4f} misses "
                f"target {target} by {gap:+.4f}"
            )
        # the estimate must be honest in the contract's direction: for
        # floors (ssim/correlation) measured quality may exceed the
        # estimate freely but not undershoot it; for the KS ceiling the
        # estimate is conservative, so measured may only be lower
        assert (
            qual.metric_gap(metric, achieved, sol.est_metric)
            <= qual.TOLERANCE[metric]
        )
    assert claimed >= 2, "solver claimed almost nothing on-target"


def test_metric_spec_json_roundtrip():
    """spec() -> JSON -> from_spec reproduces each metric policy exactly."""
    for pol in (
        Policy.fixed_ssim(0.98),
        Policy.fixed_correlation(0.999),
        Policy.fixed_ks(0.05, r_sp=0.1),
    ):
        spec = json.loads(json.dumps(pol.spec()))
        assert Policy.from_spec(spec) == pol


def test_unknown_mode_errors_name_supported_modes():
    with pytest.raises(ValueError, match="unknown quality mode 'fixed_vibes'"):
        Policy.from_spec({"mode": "fixed_vibes", "target_ssim": 0.9})
    with pytest.raises(ValueError, match="fixed_ssim"):
        # the message must enumerate the supported modes
        Policy.from_spec({"mode": "nope"})
    with pytest.raises(ValueError, match="no legacy-kwarg spelling"):
        policy_from_kwargs("test", mode="fixed_ssim")
    with pytest.raises(ValueError, match="unknown quality mode"):
        policy_from_kwargs("test", mode="fixed_nonsense")


def test_metric_policy_validation():
    for ctor in (Policy.fixed_ssim, Policy.fixed_correlation, Policy.fixed_ks):
        with pytest.raises(ValueError):
            ctor(0.0)
        with pytest.raises(ValueError):
            ctor(1.5)


def test_mixed_policyset_tree_grouping():
    """One tree, three contracts: each leaf resolves its own mode and the
    manifest of selections reflects per-mode targets."""
    fields = _fields()
    pset = PolicySet(
        default=Policy.fixed_ssim(0.97),
        rules=[
            ("noisy", Policy.fixed_psnr(50.0)),
            ("vol", Policy.fixed_correlation(0.995)),
        ],
    )
    ct = compress_pytree(dict(fields), pset, workers=0)
    out = decompress_pytree(ct)
    for name, a in fields.items():
        assert out[name].shape == a.shape
    rec = out["vol"]
    assert qual.measured_correlation(fields["vol"], rec) >= 0.995 - qual.TOLERANCE[
        "correlation"
    ]


def test_solve_many_unknown_mode_raises():
    from repro.core import controller as ctl

    pol = Policy.fixed_ssim(0.97)
    object.__setattr__(pol, "mode", "fixed_mystery")
    with pytest.raises(ValueError, match="fixed_mystery"):
        ctl.solve_many([_fields()["noisy"]], pol)


def test_decision_cache_keys_separate_metric_targets():
    """fixed_ssim(0.98), fixed_ssim(0.95) and fixed_psnr(60) must never
    share a cache entry for the same field."""
    cache = DecisionCache()
    x = _fields()["smooth"]
    for pol in (
        Policy.fixed_ssim(0.98),
        Policy.fixed_ssim(0.95),
        Policy.fixed_psnr(60.0),
    ):
        solve_many([x], pol, cache=cache, names=["f"])
    # one name -> latest entry only, but lookups under the other policies miss
    sols = solve_many([x], Policy.fixed_psnr(60.0), cache=cache, names=["f"])
    assert cache.events["f"] == "hit"
    solve_many([x], Policy.fixed_ssim(0.98), cache=cache, names=["f"])
    assert cache.events["f"] == "invalidated"  # key mismatch, not a stale hit
    assert sols[0].mode == "fixed_psnr"


def test_warm_metric_solve_bit_identical():
    """Second solve through a validating cache replays the cold decision
    exactly (selection AND solution scalars), with est_metric persisted."""
    cache = DecisionCache()
    fields = _fields()
    arrs, names = list(fields.values()), list(fields)
    pol = Policy.fixed_ks(0.1)
    cold = solve_many(arrs, pol, cache=cache, names=names)
    warm = solve_many(arrs, pol, cache=cache, names=names)
    assert all(cache.events[n] == "hit" for n in names)
    for c, w in zip(cold, warm):
        assert c.selection == w.selection
        assert (c.mode, c.target, c.est_psnr, c.est_bitrate, c.on_target,
                c.est_metric) == (
            w.mode, w.target, w.est_psnr, w.est_bitrate, w.on_target,
            w.est_metric,
        )


def test_manifest_v3_quality_rows_and_restore(tmp_path):
    """Flat manifests record per-field quality rows (mode / target /
    est_metric / on_target) and the legacy top-level target mirrors the
    metric target; restore round-trips."""
    from repro.checkpoint import CheckpointConfig, CheckpointManager

    fields = _fields()
    mgr = CheckpointManager(
        CheckpointConfig(directory=str(tmp_path), policy=Policy.fixed_ssim(0.97))
    )
    path = mgr.save(3, dict(fields))
    man = json.load(open(f"{path}/manifest.json"))
    assert man["mode"] == "fixed_ssim" and man["target"] == 0.97
    rows = {fl["name"]: fl for fl in man["fields"]}
    for name in fields:
        q = rows[name]["quality"]
        assert q["mode"] == "fixed_ssim" and q["target"] == 0.97
        assert 0.0 < q["est_metric"] <= 1.0
        assert isinstance(q["on_target"], bool)
        assert rows[name]["policy"]["mode"] == "fixed_ssim"
    step, flat = mgr.restore()
    assert step == 3
    for name, a in fields.items():
        assert flat[name].shape == a.shape and flat[name].dtype == a.dtype


def test_sharded_plan_tree_matches_host_solver(emulated_devices):
    """Metric-mode plan_tree decisions on sharded arrays are bit-identical
    to the host solve_many path (the §6 sample-gather reconciliation)."""
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    from repro.core import sharded as shd

    fields = _fields()
    mesh = Mesh(np.array(jax.devices()[:2]), ("x",))
    arrs = []
    for name, a in fields.items():
        if a.ndim == 2:
            arrs.append(
                jax.device_put(a, NamedSharding(mesh, PartitionSpec("x", None)))
            )
        else:
            arrs.append(a)
    pol = Policy.fixed_correlation(0.995)
    plans = shd.plan_tree(arrs, pol)
    host = solve_many(list(fields.values()), pol)
    for plan, sol in zip(plans, host):
        assert plan.selection == sol.selection
        assert plan.solution.est_metric == sol.est_metric
        assert plan.solution.on_target == sol.on_target


def test_degenerate_fields_report_lossless_metric():
    """Tiny/constant fields ride raw and report the metric's lossless value
    with on_target=True (raw meets every floor/ceiling except a ratio)."""
    tiny = np.ones((2, 2), np.float32)
    for metric, pol in (
        ("ssim", Policy.fixed_ssim(0.9)),
        ("ks", Policy.fixed_ks(0.05)),
    ):
        sol = solve_many([tiny], pol)[0]
        assert sol.selection.codec == "raw"
        assert sol.on_target is True
        assert sol.est_metric == qual.LOSSLESS_VALUE[metric]


def test_metric_modes_tuple_exported():
    assert METRIC_MODES == ("fixed_ssim", "fixed_correlation", "fixed_ks")
    assert set(qual.MODE_METRIC) == set(METRIC_MODES)
