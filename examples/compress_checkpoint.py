"""Compress a model checkpoint with per-tensor SZ/ZFP auto-selection
(the paper's fields == named tensors), report per-field selection bits,
compression ratio, and verify the error bound on every tensor.

  PYTHONPATH=src python examples/compress_checkpoint.py
"""

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.core.api import compress_pytree, decompress_pytree


def main():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=8, d_model=512)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    eb_rel = 1e-4
    ct = compress_pytree(params, eb_rel=eb_rel)
    print(f"tensors: {len(ct.fields)}; raw {ct.raw_nbytes/1e6:.1f} MB -> "
          f"{ct.nbytes/1e6:.1f} MB (CR {ct.ratio:.2f}x) at eb_rel={eb_rel:g}")
    picks = {}
    for name, codec in ct.selection_bits.items():
        picks[codec] = picks.get(codec, 0) + 1
    print("selection bits:", picks)
    rec = decompress_pytree(ct)
    worst = 0.0
    for (name, a), b in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_leaves(rec),
    ):
        a = np.asarray(a)
        vr = float(a.max() - a.min()) or 1.0
        worst = max(worst, float(np.abs(a - b).max()) / (eb_rel * vr))
    print(f"worst max|err|/eb across tensors: {worst:.3f} (<= ~1.0)")


if __name__ == "__main__":
    main()
