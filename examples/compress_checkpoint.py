"""Compress a model checkpoint with per-tensor SZ/ZFP auto-selection
(the paper's fields == named tensors), report per-field selection bits,
compression ratio, and verify the error bound on every tensor — then do
the same under quality targets (DESIGN.md §7): a fixed-PSNR checkpoint
("every tensor at 60 dB"), a fixed-ratio checkpoint ("8x smaller"), and
finally a MIXED `PolicySet` tree — weights on a fixed-accuracy bound,
optimizer state on a fixed-ratio budget — one checkpoint, two contracts.

  PYTHONPATH=src python examples/compress_checkpoint.py
"""

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.core import Policy, PolicySet
from repro.core.api import compress_pytree, decompress_pytree


def main():
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=8, d_model=512)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    eb_rel = 1e-4
    ct = compress_pytree(params, Policy.fixed_accuracy(eb_rel=eb_rel))
    print(f"tensors: {len(ct.fields)}; raw {ct.raw_nbytes/1e6:.1f} MB -> "
          f"{ct.nbytes/1e6:.1f} MB (CR {ct.ratio:.2f}x) at eb_rel={eb_rel:g}")
    picks = {}
    for name, codec in ct.selection_bits.items():
        picks[codec] = picks.get(codec, 0) + 1
    print("selection bits:", picks)
    rec = decompress_pytree(ct)
    worst = 0.0
    for (name, a), b in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_leaves(rec),
    ):
        a = np.asarray(a)
        vr = float(a.max() - a.min()) or 1.0
        worst = max(worst, float(np.abs(a - b).max()) / (eb_rel * vr))
    print(f"worst max|err|/eb across tensors: {worst:.3f} (<= ~1.0)")

    def psnr(a, b):
        vr = float(a.max() - a.min())
        mse = float(np.mean((np.asarray(a, np.float64) - np.asarray(b, np.float64)) ** 2))
        return -10.0 * np.log10(max(mse, 1e-300)) + 20.0 * np.log10(max(vr, 1e-30))

    # fixed-PSNR checkpoint: every lossy tensor lands on the target dB
    # (raw-fallback tensors — constant, tiny — are bit-exact, not "on
    # target", so filter by the selection bit, not by size)
    target_db = 60.0
    ct = compress_pytree(params, Policy.fixed_psnr(target_db))
    rec = decompress_pytree(ct)
    names = list(ct.fields)
    psnrs = [
        psnr(np.asarray(a), b)
        for name, (_, a), b in zip(
            names,
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_leaves(rec),
        )
        if ct.fields[name].codec != "raw"
    ]
    print(f"fixed_psnr {target_db:g} dB: CR {ct.ratio:.2f}x; achieved "
          f"[{min(psnrs):.1f}, {max(psnrs):.1f}] dB across lossy tensors")

    # fixed-ratio checkpoint: a storage contract, not a bound
    target_ratio = 8.0
    ct = compress_pytree(params, Policy.fixed_ratio(target_ratio))
    print(f"fixed_ratio {target_ratio:g}x: tree CR {ct.ratio:.2f}x "
          f"(raw-fallback leaves drag the tree total below the per-leaf target)")

    # mixed PolicySet: one train state, two contracts — weights keep a
    # hard bound, optimizer moments fit a byte budget (first match wins)
    state = {
        "params": params,
        "opt": jax.tree_util.tree_map(lambda p: 0.1 * np.asarray(p), params),
    }
    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=eb_rel),
        rules=[("opt/*", Policy.fixed_ratio(target_ratio))],
    )
    ct = compress_pytree(state, pset)
    n_opt = sum(1 for n in ct.fields if n.startswith("opt/"))
    print(f"mixed PolicySet: {len(ct.fields) - n_opt} weight tensors at "
          f"eb_rel={eb_rel:g}, {n_opt} optimizer tensors at "
          f"{target_ratio:g}x; tree CR {ct.ratio:.2f}x")
    decompress_pytree(ct)  # round-trips like any single-policy tree


if __name__ == "__main__":
    main()
