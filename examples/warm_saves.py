"""Warm saves (DESIGN.md §8): a repeated-save loop through the
cross-step decision cache — the in-situ checkpoint pattern where the
same tree is saved step after step and per-field statistics barely move.

Three parts:

1. the core API: `select_many(cache=, names=)` on an evolving tree —
   step 0 cold-populates, quiet steps are all hits with bit-identical
   decisions, and a field whose statistics jump is invalidated and
   re-decided cold;
2. the checkpoint manager: `CheckpointConfig(cache=True)` — the cache
   rides the v3 manifest, so a RESTARTED run's first save is already
   warm;
3. the opt-in statistical predictor (`select_many_predicted`): decisions
   from cheap moments alone for confident fields, sampled fallback for
   the rest.

  PYTHONPATH=src python examples/warm_saves.py
"""

import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import Policy, select_many
from repro.core.decision_cache import DecisionCache
from repro.core.predictor import select_many_predicted


def make_state(rng, drift=0.0):
    """A small 'training state': smooth 2-D fields + one 3-D volume.
    `drift` nudges every value, emulating a training step's tiny update."""
    base = {
        "w/embed": np.cumsum(rng.standard_normal((256, 192)), axis=0),
        "w/attn": np.cumsum(rng.standard_normal((192, 256)), axis=1),
        "w/field3d": np.cumsum(rng.standard_normal((16, 48, 48)), axis=2),
    }
    return {k: (v + drift).astype(np.float32) for k, v in base.items()}


def main():
    rng = np.random.default_rng(0)
    state = make_state(rng)
    names, arrs = list(state), list(state.values())
    pol = Policy.fixed_accuracy(eb_rel=1e-3)

    # -- 1. the core API ---------------------------------------------------
    cache = DecisionCache()  # tolerance=0.0: bit-identical or re-decide
    cold = select_many(arrs, policy=pol)
    for step in range(3):
        cur = [a.copy() for a in arrs]
        if step == 2:  # one field's scale jumps -> its entry invalidates
            cur[0] = cur[0] * 1000.0
        cache.reset_stats()
        sels = select_many(cur, policy=pol, cache=cache, names=names)
        st = cache.stats()
        print(f"step {step}: hits={st['hits']} misses={st['misses']} "
              f"invalidated={st['invalidations']} "
              f"events={ {n: cache.events[n] for n in names} }")
        if step == 1:
            assert sels == cold, "validated warm decisions are bit-identical"

    # -- 2. the checkpoint manager ----------------------------------------
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(d, policy=pol, cache=True))
        mgr.save(100, make_state(np.random.default_rng(0)))
        mgr.cache.reset_stats()
        mgr.save(200, make_state(np.random.default_rng(0)))
        print(f"manager save 2: {mgr.cache.stats()}")  # all hits

        # a restarted run restores the manifest -> its first save is warm
        mgr2 = CheckpointManager(CheckpointConfig(d, policy=pol, cache=True))
        mgr2.restore()  # loads the decision_cache record from the manifest
        mgr2.cache.reset_stats()
        mgr2.save(300, make_state(np.random.default_rng(0)))
        print(f"restarted run, first save: {mgr2.cache.stats()}")

    # -- 3. the opt-in predictor ------------------------------------------
    heavy = rng.standard_cauchy((128, 128)).astype(np.float32)
    _sels, routes = select_many_predicted(arrs + [heavy], eb_rel=1e-3)
    print("predictor routes:", dict(zip(names + ["x/heavy"], routes)))


if __name__ == "__main__":
    main()
