"""Multi-host checkpointing end to end (DESIGN.md §6.2): spawn a real
2-process `jax.distributed` job on this machine, save one sharded
checkpoint cooperatively — each host writes only the segments it owns —
then restore it elastically with per-host segment locality, and inspect
the on-disk layout the protocol leaves behind (per-host data files,
completion markers, the host-0-assembled v3 manifest).

The worker body is `repro.launch.shardckpt`'s dryrun scenario — the same
one `python -m repro.launch.shardckpt --processes 2` runs; this example
drives it through `repro.launch.mhrun` directly so the checkpoint
directory survives for inspection.

  PYTHONPATH=src python examples/multihost_checkpoint.py
"""

import json
import os
import sys
import tempfile

from repro.launch import mhrun

PROCESSES = 2
FIELDS = 4
DIM = 256


def main():
    with tempfile.TemporaryDirectory() as wd:
        ckpt_dir = os.path.join(wd, "ckpt")
        results = mhrun.run(
            [sys.executable, "-m", "repro.launch.shardckpt", "--mh-worker"],
            PROCESSES,
            scenario="dryrun",
            args=dict(fields=FIELDS, dim=DIM, eb_rel=1e-3, directory=ckpt_dir),
            local_devices=8 // PROCESSES,  # same 8-device global mesh as 1p
            timeout_s=600.0,
            workdir=os.path.join(wd, "mhrun"),
        )
        payloads = mhrun.require_success(results)

        for p in payloads:
            mesh = p["mesh"]
            st = p["restore_stats"]
            print(
                f"host {mesh['process_index']}/{mesh['process_count']}: "
                f"wrote {p['own_bytes'] / 1e6:.2f} MB of "
                f"{p['total_bytes'] / 1e6:.2f} MB; elastic restore decoded "
                f"{st['segments_decoded']}/{st['segments_total']} segments "
                f"from data files {st['hosts_opened']} "
                f"(within_bound={p['within_bound']})"
            )

        # the layout the §6.2 protocol leaves on disk
        step_dir = payloads[0]["path"]
        print(f"\n{os.path.basename(step_dir)}/")
        for name in sorted(os.listdir(step_dir)):
            size = os.path.getsize(os.path.join(step_dir, name))
            print(f"  {name:<22} {size:>9} B")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            man = json.load(f)
        # multi-host manifests carry the writer set and per-host byte
        # counts; restore refuses the step if any commit marker or byte
        # is missing (IncompleteCheckpointError)
        print(f"manifest: version={man['version']} hosts={man['hosts']} "
              f"completion={man['completion']}")
        segs = [s for fl in man["fields"] for s in fl["segments"]]
        by_host = {h: sum(s["nbytes"] for s in segs if s["host"] == h)
                   for h in man["hosts"]}
        print(f"{len(segs)} segments; bytes by owning host: {by_host}")


if __name__ == "__main__":
    main()
