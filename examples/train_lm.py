"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with lossy-compressed checkpoints + error-feedback compressed gradients.
Checkpoints carry a mixed `PolicySet` (DESIGN.md §2): weights on a
fixed-accuracy bound, optimizer state on an 8x fixed-ratio budget
(`--ckpt-opt-ratio` in launch/train.py).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: smollm-360m config narrowed to 16 layers @ d=768.)
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    train_mod.main(
        [
            "--arch", "smollm-360m",
            "--n-layers", "16",
            "--d-model", "768",
            "--steps", str(args.steps),
            "--seq", "256",
            "--batch", "8",
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100",
            "--ckpt-opt-ratio", "8",
            "--compress-ckpt",
            "--compress-grads",
            "--resume",
        ]
    )


if __name__ == "__main__":
    main()
