"""Quality-metric targets (DESIGN.md §7.4): SSIM, Pearson correlation
and the Kolmogorov-Smirnov statistic as first-class Policy targets.

Four parts:

1. solve + encode under each metric target and compare the MEASURED
   metric of the real reconstruction against the target — every claimed
   `on_target` field lands within `quality.TOLERANCE`, with zero trial
   compressions in the search loop;
2. the predicted metric-vs-bound curves (`quality.metric_curves`) that
   the inversion walks: SSIM/correlation monotone non-increasing in the
   error bound, KS non-decreasing, for both codecs;
3. a mixed-metric `PolicySet` over one tree — each leaf carries its own
   contract, exactly like mixing fixed_psnr and fixed_ratio;
4. a checkpoint save whose v3 manifest records the per-field `quality`
   audit row (mode / target / est_psnr / est_metric / on_target).

  PYTHONPATH=src python examples/quality_metrics.py
"""

import json
import os
import tempfile

import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core import (
    Policy,
    PolicySet,
    compress_pytree,
    decompress,
    encode_with_selection,
    solve_many,
)
from repro.core import quality


def make_fields(rng):
    """Paper-style smooth fields plus one noisy one."""
    return {
        "temp2d": np.cumsum(
            np.cumsum(rng.standard_normal((192, 192)), 0), 1
        ).astype(np.float32),
        "wind3d": np.cumsum(
            rng.standard_normal((24, 48, 48)), axis=2
        ).astype(np.float32),
        "flux": (
            np.cumsum(rng.standard_normal((160, 160)), 0)
            + 0.1 * rng.standard_normal((160, 160))
        ).astype(np.float32),
    }


def main():
    rng = np.random.default_rng(0)
    fields = make_fields(rng)
    names, arrs = list(fields), list(fields.values())

    # -- 1. solve, encode, measure ----------------------------------------
    targets = [
        ("ssim", Policy.fixed_ssim(0.97), 0.97),
        ("correlation", Policy.fixed_correlation(0.999), 0.999),
        ("ks", Policy.fixed_ks(0.05), 0.05),
    ]
    for metric, pol, target in targets:
        sols = solve_many(arrs, pol)
        print(f"\n{pol.mode}({target}):")
        for name, a, sol in zip(names, arrs, sols):
            cf = encode_with_selection(a, sol.selection)
            rec = decompress(cf).reshape(a.shape)
            achieved = quality.measured_metric(metric, a, rec)
            gap = quality.metric_gap(metric, achieved, target)
            ratio = a.nbytes / max(cf.nbytes, 1)
            print(
                f"  {name:8s} {sol.selection.codec:>4} "
                f"est={sol.est_metric:.4f} measured={achieved:.4f} "
                f"gap={gap:+.4f} (tol {quality.TOLERANCE[metric]}) "
                f"ratio={ratio:.1f}x on_target={sol.on_target}"
            )

    # -- 2. the curves the inversion walks ---------------------------------
    x = fields["temp2d"]
    bounds = np.logspace(-4, -1, 8) * float(np.ptp(x))
    curves = quality.metric_curves(x, bounds)
    print("\nmetric-vs-bound curves on temp2d (SZ):")
    print("  eb/vr      ssim     corr      ks")
    for i, eb in enumerate(bounds):
        print(
            f"  {eb / np.ptp(x):7.1e} {curves['ssim_sz'][i]:.4f} "
            f"{curves['correlation_sz'][i]:.4f} {curves['ks_sz'][i]:.4f}"
        )

    # -- 3. mixed-metric PolicySet over one tree ---------------------------
    pset = PolicySet(
        default=Policy.fixed_ssim(0.97),
        rules=[
            ("flux", Policy.fixed_psnr(55.0)),  # noisy field: plain dB floor
            ("wind3d", Policy.fixed_ks(0.05)),  # distribution-critical
        ],
    )
    ct = compress_pytree(dict(fields), pset)
    print(
        f"\nmixed tree: {sum(f.nbytes for f in ct.fields.values())} bytes "
        f"vs {sum(a.nbytes for a in arrs)} raw"
    )

    # -- 4. the manifest audit row -----------------------------------------
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(directory=d, policy=pset))
        path = mgr.save(1, dict(fields))
        man = json.load(open(os.path.join(path, "manifest.json")))
        print("\nmanifest quality rows:")
        for fl in man["fields"]:
            q = fl.get("quality")
            if q:
                est = (
                    f"est_metric={q['est_metric']:.4f} "
                    if "est_metric" in q  # absent for non-metric modes
                    else ""
                )
                print(
                    f"  {fl['name']:8s} {q['mode']:18s} target={q['target']} "
                    f"{est}on_target={q['on_target']}"
                )


if __name__ == "__main__":
    main()
