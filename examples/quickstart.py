"""Quickstart: the paper's pipeline on a synthetic scientific field.

Runs Algorithm 1 (online rate-distortion-optimal selection between SZ and
ZFP) on a few fields with different characteristics, prints the estimated
vs. actual bit-rates, the selection bits, and verifies the error bound.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    select,
    select_and_compress,
    decompress,
    sz_compress,
    zfp_compress,
    compression_ratio,
)


def make_fields(n=256):
    rng = np.random.default_rng(0)
    xx, yy = np.meshgrid(np.linspace(0, 6, n), np.linspace(0, 6, n))
    return {
        "CLDHGH-like (smooth)": (np.sin(xx) * np.cos(yy) + 1e-3 * rng.standard_normal((n, n))).astype(np.float32),
        "PRECIP-like (mid)": (np.sin(4 * xx) * np.cos(3 * yy) + 0.05 * rng.standard_normal((n, n))).astype(np.float32),
        "turbulent (rough)": rng.standard_normal((n, n)).astype(np.float32),
    }


def main():
    eb_rel = 1e-3
    print(f"value-range-relative error bound: {eb_rel:g}\n")
    for name, field in make_fields().items():
        vr = field.max() - field.min()
        eb = eb_rel * vr
        sel = select(field, eb_abs=eb)
        cf = select_and_compress(field, eb_abs=eb)
        rec = decompress(cf)
        err = np.abs(field - rec).max()
        a_sz = 8 * len(sz_compress(field, sel.eb_sz)) / field.size
        a_zfp = 8 * len(zfp_compress(field, eb)) / field.size
        print(f"field: {name}")
        print(f"  estimated bit-rate  SZ {sel.br_sz:6.2f} | ZFP {sel.br_zfp:6.2f}  (iso-PSNR {sel.psnr_target:.1f} dB)")
        print(f"  actual bit-rate     SZ {a_sz:6.2f} | ZFP {a_zfp:6.2f}")
        print(f"  selection bit s_i = {cf.codec!r}; CR = {compression_ratio(cf):.2f}x")
        print(f"  max |err| / eb = {err / eb:.3f}  (bounded: {err <= eb * 1.001})\n")


if __name__ == "__main__":
    main()
