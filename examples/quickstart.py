"""Quickstart: the paper's pipeline on a synthetic scientific field.

Runs Algorithm 1 (online rate-distortion-optimal selection between SZ and
ZFP) on a few fields with different characteristics, prints the estimated
vs. actual bit-rates, the selection bits, and verifies the error bound —
then flips the contract around with the quality-target controller
(DESIGN.md §7): ask for a PSNR, ask for a ratio, and check what lands —
and finishes with the device-resident encode tier (DESIGN.md §3.7):
same bytes, but Stage III runs in-graph and only the compressed stream
crosses the device boundary.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import (
    Policy,
    compress,
    select,
    select_and_compress,
    decompress,
    sz_compress,
    zfp_compress,
    compression_ratio,
)


def make_fields(n=256):
    rng = np.random.default_rng(0)
    xx, yy = np.meshgrid(np.linspace(0, 6, n), np.linspace(0, 6, n))
    # a Hurricane-like 3-D volume rides the 4x4x4 kernel tier
    # (DESIGN.md §3.4, §3.5) through the very same API
    zz3, yy3, xx3 = np.meshgrid(*[np.linspace(0, 4, n // 4)] * 3, indexing="ij")
    return {
        "CLDHGH-like (smooth)": (np.sin(xx) * np.cos(yy) + 1e-3 * rng.standard_normal((n, n))).astype(np.float32),
        "PRECIP-like (mid)": (np.sin(4 * xx) * np.cos(3 * yy) + 0.05 * rng.standard_normal((n, n))).astype(np.float32),
        "turbulent (rough)": rng.standard_normal((n, n)).astype(np.float32),
        "Hurricane-like (3-D)": (
            np.sin(3 * zz3) * np.cos(2 * yy3) * np.sin(xx3)
            + 1e-2 * rng.standard_normal((n // 4,) * 3)
        ).astype(np.float32),
    }


def psnr(a, b):
    vr = float(a.max() - a.min())
    mse = float(np.mean((a.astype(np.float64) - b.astype(np.float64)) ** 2))
    return -10.0 * np.log10(max(mse, 1e-300)) + 20.0 * np.log10(max(vr, 1e-30))


def main():
    eb_rel = 1e-3
    print(f"value-range-relative error bound: {eb_rel:g}\n")
    for name, field in make_fields().items():
        vr = field.max() - field.min()
        eb = eb_rel * vr
        sel = select(field, eb_abs=eb)
        cf = select_and_compress(field, eb_abs=eb)
        rec = decompress(cf)
        err = np.abs(field - rec).max()
        a_sz = 8 * len(sz_compress(field, sel.eb_sz)) / field.size
        a_zfp = 8 * len(zfp_compress(field, eb)) / field.size
        print(f"field: {name}")
        print(f"  estimated bit-rate  SZ {sel.br_sz:6.2f} | ZFP {sel.br_zfp:6.2f}  (iso-PSNR {sel.psnr_target:.1f} dB)")
        print(f"  actual bit-rate     SZ {a_sz:6.2f} | ZFP {a_zfp:6.2f}")
        print(f"  selection bit s_i = {cf.codec!r}; CR = {compression_ratio(cf):.2f}x")
        print(f"  max |err| / eb = {err / eb:.3f}  (bounded: {err <= eb * 1.001})\n")

    # quality targets (DESIGN.md §7): a Policy names the quality, not the
    # bound — the same object every other layer takes (core/policy.py)
    print("fixed-PSNR: 'give me 60 dB'")
    for name, field in make_fields().items():
        cf = compress(field, Policy.fixed_psnr(60.0))
        rec = decompress(cf)
        print(f"  {name}: codec={cf.codec!r} achieved {psnr(field, rec):.2f} dB "
              f"at CR {compression_ratio(cf):.2f}x")
    print("fixed-ratio: 'give me 8x'")
    for name, field in make_fields().items():
        cf = compress(field, Policy.fixed_ratio(8.0))
        rec = decompress(cf)
        print(f"  {name}: codec={cf.codec!r} achieved CR {compression_ratio(cf):.2f}x "
              f"at {psnr(field, rec):.2f} dB")

    # device-resident encode (DESIGN.md §3.7): Stage III runs in-graph,
    # so only the compressed stream leaves the device — same bytes, and
    # the raw field never crosses the boundary
    volume = make_fields()["Hurricane-like (3-D)"]
    pol = Policy.fixed_accuracy(eb_rel=eb_rel)
    for flag in (False, True):  # first call per path warms the jit cache
        compress(volume, pol, device_encode=flag)
    t0 = time.perf_counter()
    cf_host = compress(volume, pol, device_encode=False)
    t_host = time.perf_counter() - t0
    t0 = time.perf_counter()
    cf_dev = compress(volume, pol, device_encode=True)
    t_dev = time.perf_counter() - t0
    # the unchanged host decoder reads the device-packed stream
    rec = decompress(cf_dev)
    vr = float(volume.max() - volume.min())
    assert np.abs(rec - volume).max() <= eb_rel * vr * 1.001
    moved = len(cf_dev.data)
    print("\ndevice-resident encode (device_encode=True) on the 3-D volume:")
    print(f"  codec={cf_dev.codec!r}; host-encode {t_host * 1e3:.0f} ms vs "
          f"device-encode {t_dev * 1e3:.0f} ms")
    print(f"  bytes crossing the device boundary: {volume.nbytes} (raw field) "
          f"-> {moved} (packed stream, {100.0 * moved / volume.nbytes:.1f}%)")


if __name__ == "__main__":
    main()
