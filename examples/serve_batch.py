"""Batched serving example: prefill + greedy decode with a KV cache on the
smoke-size smollm config, then page-out compression of a KV page under a
byte-budget `Policy` (DESIGN.md §2 layer 3, §7) — the same quality object
the checkpoint and pytree layers take.

  PYTHONPATH=src python examples/serve_batch.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Policy
from repro.launch import serve as serve_mod
from repro.runtime import kvcomp


def main():
    serve_mod.main(["--arch", "smollm-360m", "--smoke", "--batch", "4",
                    "--prompt-len", "64", "--gen", "32"])

    # the continuous tier (DESIGN.md §9): paged KV pool under Poisson
    # arrivals, long-context requests compressed on evict at 8x
    serve_mod.main(["--arch", "smollm-360m", "--smoke", "--continuous",
                    "--requests", "6", "--slots", "2", "--gen", "12"])

    # KV page-out under a Policy: give the page a byte budget and let the
    # in-graph estimator solve the bound (no trial compressions)
    rng = np.random.default_rng(0)
    page = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    for policy in (Policy.fixed_accuracy(eb_rel=1e-2), Policy.fixed_ratio(8.0)):
        recon, bits = kvcomp.bot_compress_kv(page, policy)
        achieved = 32.0 * page.size / float(jnp.sum(bits))
        err = float(jnp.max(jnp.abs(recon - page)))
        print(f"[kv] {policy.mode}: page CR {achieved:.2f}x, max|err| {err:.3g}")


if __name__ == "__main__":
    main()
