"""Batched serving example: prefill + greedy decode with a KV cache on the
smoke-size smollm config.

  PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch import serve as serve_mod


def main():
    serve_mod.main(["--arch", "smollm-360m", "--smoke", "--batch", "4",
                    "--prompt-len", "64", "--gen", "32"])


if __name__ == "__main__":
    main()
