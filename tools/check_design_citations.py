#!/usr/bin/env python
"""CI gate: every `DESIGN.md §N` citation in the repo's Python sources
must resolve to a real section header in DESIGN.md.

ROADMAP asks that DESIGN.md stay the architecture reference future PRs can
trust, which only works if docstring citations keep resolving as sections
are added/renumbered. This script needs nothing beyond the stdlib:

    python tools/check_design_citations.py [--list]

Exit status 0 when every citation resolves, 1 otherwise (with a
file:line report of the dangling ones). `--list` also prints every
citation found, so you can eyeball coverage.

What counts as a citation: any `§N` / `§N.M` token within a short window
after the literal string ``DESIGN.md`` (covering "DESIGN.md §4–§5",
"DESIGN.md §2, third row", "(DESIGN.md §1, §4–§5)", ...). Bare `§N`
tokens without the DESIGN.md prefix are ignored — those cite the paper,
not this repo's design doc.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
#: directories scanned for citations (every .py underneath, plus README.md)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
#: how far past "DESIGN.md" section tokens are collected; a token must
#: start within this many chars of the previous one (or of the prefix),
#: so unrelated § later in the text are not swept in
WINDOW = 16

SECTION = re.compile(r"§(\d+(?:\.\d+)?)")


def design_sections(design_path: Path) -> set[str]:
    secs: set[str] = set()
    for line in design_path.read_text().splitlines():
        if line.startswith("#"):
            secs.update(SECTION.findall(line))
    return secs


def citations_in(path: Path) -> list[tuple[int, str]]:
    """[(line_number, section)] for every DESIGN.md §-citation in `path`."""
    text = path.read_text()
    out: list[tuple[int, str]] = []
    for m in re.finditer(r"DESIGN\.md", text):
        cursor = m.end()
        while True:
            nxt = SECTION.search(text, cursor, cursor + WINDOW + 6)
            if nxt is None or nxt.start() > cursor + WINDOW:
                break
            line = text.count("\n", 0, nxt.start()) + 1
            out.append((line, nxt.group(1)))
            cursor = nxt.end()
    return out


def main(argv: list[str]) -> int:
    list_all = "--list" in argv
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("check_design_citations: DESIGN.md not found", file=sys.stderr)
        return 1
    sections = design_sections(design)
    files = [
        p
        for d in SCAN_DIRS
        for p in sorted((ROOT / d).rglob("*.py"))
        if (ROOT / d).is_dir()
    ]
    files.append(ROOT / "README.md")
    n_cites = 0
    dangling: list[str] = []
    for path in files:
        if not path.exists():
            continue
        for line, sec in citations_in(path):
            n_cites += 1
            rel = path.relative_to(ROOT)
            if list_all:
                print(f"  {rel}:{line}: §{sec}")
            if sec not in sections:
                dangling.append(f"{rel}:{line}: DESIGN.md §{sec} does not exist")
    if dangling:
        print("dangling DESIGN.md citations:", file=sys.stderr)
        for d in dangling:
            print(f"  {d}", file=sys.stderr)
        print(
            f"\n{len(dangling)} dangling of {n_cites} citations; "
            f"DESIGN.md defines §{{{', '.join(sorted(sections, key=lambda s: tuple(map(int, s.split('.')))))}}}",
            file=sys.stderr,
        )
        return 1
    print(
        f"check_design_citations: {n_cites} citations across "
        f"{len(files)} files all resolve ({len(sections)} sections)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
