#!/usr/bin/env python
"""CI benchmark-regression gate (ISSUE 4): a small-shape smoke subset of
the benchmark harness, compared against a committed baseline.

Per FRaZ (Underwood et al. 2020) and the black-box ratio-prediction work
(Underwood et al. 2023), compressor throughput/ratio regressions are
silent and workload-dependent — nothing in the unit tests notices when a
refactor halves the batched engine's speedup or flips a borderline
selection. This gate runs a handful of smoke benches and fails the job
when:

* any **decision flips** vs the committed baseline (exact codec + matched
  SZ bound per smoke field, keyed by the environment's Huffman-table cost
  like the golden suite), or
* any **throughput ratio regresses by more than 20%** vs the baseline, or
* the **warm save path** (DESIGN.md §8) flips any decision vs its cold
  reference, drops a cache hit, or costs more than
  `WARM_OVERHEAD_MAX_PCT` of encode time on the full-size repeated-save
  workload (the parity and overhead checks are absolute — they need no
  baseline; the warm-vs-cold selection speedup rides the 20% ratio rule), or
* the **multi-host save** (DESIGN.md §6.2) diverges across host counts:
  `benchmarks/bench_multihost.py` saves the same state under 1- and
  2-process distributed jobs and the `multihost_save_parity` check —
  absolute, like the warm parity — fails on ANY decision flip, manifest
  difference, or decompressed-byte mismatch, or
* the **paged serving tier** (DESIGN.md §9) corrupts a page across a
  compress-on-evict / decompress-on-hit cycle:
  `benchmarks/bench_serving.py` decodes the same workload with and
  without page pressure at `Policy.raw` and the `serving_page_parity`
  check — absolute — fails on any token mismatch, any raw round-trip
  byte difference, or a vacuous run that never evicted, or
* the **device-resident encode tier** (ISSUE 9, DESIGN.md §3.7) drifts
  from the host byte coders: `benchmarks/bench_device_encode.py` byte-
  compares the device-packed SZ/ZFP streams against the host Stage III
  over the device's own codes and the `device_encode_parity` check —
  absolute — fails on any stream mismatch (an all-declined run counts
  as vacuous and fails too); the end-to-end `device_encode_speedup`
  geomean rides the 20% ratio rule, or
* the **quality-metric targets** (DESIGN.md §7.4) stop landing:
  `benchmarks/bench_quality.py` solves SSIM / correlation / KS targets on
  the smoke suites, really encodes+decodes, and measures the metrics; the
  `quality_target_accuracy` check — absolute — fails when any
  claimed-on-target field measures outside `quality.TOLERANCE`, when the
  solver claims fewer than `QUALITY_ON_TARGET_MIN` of the fields, or when
  the run is vacuous; `quality_solve_overhead` — absolute — fails when the
  metric solves cost more than `QUALITY_SOLVE_OVERHEAD_MAX` x the
  fixed_ratio solve on the same fields (the §7 envelope).

Throughput is tracked as *ratios* (batched-vs-per-field selection speedup,
3-D-kernel-vs-fallback speedup, shard-local-vs-gather save speedup) and
estimation quality as bits/value error — machine-relative numbers a
committed baseline can gate across runner generations; raw wall times are
recorded in the report but never gated.

  python tools/bench_gate.py --out BENCH_10.json    # gate (CI `bench` job)
  python tools/bench_gate.py --update-baseline      # refresh the baseline
  REPRO_SZ_TABLE_BITS=5 python tools/bench_gate.py --update-baseline \
      --decisions-only                              # other env's decisions

Needs PYTHONPATH=src (and the repo root on sys.path for `benchmarks.*`).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# the sharded smoke needs the emulated devices BEFORE jax initializes
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))
if str(ROOT / "src") not in sys.path:
    sys.path.insert(0, str(ROOT / "src"))

BASELINE = ROOT / "benchmarks" / "baseline.json"
#: a ratio may lose at most this fraction vs its committed baseline
MAX_REGRESSION = 0.20
#: absolute slack (bits/value) on the estimation-error metric, so a
#: near-zero baseline does not gate on noise
EST_ABS_SLACK = 0.05
#: warm selection may cost at most this % of encode time on the
#: repeated-save workload (full-size fields — the smoke shapes are too
#: small to amortize the fixed per-launch cost, so this one bench runs
#: at `run_repeated_save`'s defaults). The DESIGN.md §8 target is <2%
#: (measured ~1.4-1.6%); the ceiling adds headroom for runner noise
#: while still failing if the warm path ever grows real per-field work.
WARM_OVERHEAD_MAX_PCT = 3.0
#: quality-metric targets (DESIGN.md §7.4) — all absolute, no baseline.
#: Tolerances mirror `repro.core.quality.TOLERANCE`; the measurement half
#: asserts they match so the copies cannot drift (gate() itself must stay
#: importable without PYTHONPATH=src for the comparator unit tests).
QUALITY_TOLERANCE = {"ssim": 0.02, "correlation": 0.005, "ks": 0.02}
#: the solver must CLAIM on_target on at least this fraction of smoke
#: fields (claimed misses are honest — see bench_quality — but a solver
#: that stops landing anywhere has regressed)
QUALITY_ON_TARGET_MIN = 0.9
#: metric solves may cost at most this multiple of fixed_ratio's solve
#: time on the same fields (geomean; the §7 overhead envelope — the
#: metric modes add only per-field numpy statistics to the shared secant)
QUALITY_SOLVE_OVERHEAD_MAX = 3.0


def _env_key() -> str:
    from repro.core import estimator as est

    return f"table{int(est.TABLE_BITS_PER_SYMBOL)}"


def _smoke_fields() -> dict:
    """Small fixed suite spanning 2-D and genuinely-3-D fields (ATM /
    Hurricane / NYX-like, the paper's three datasets at smoke scale)."""
    from benchmarks.common import atm_suite, hurricane_suite, nyx_suite

    fields = {}
    fields.update({f"atm/{k}": v for k, v in atm_suite(4, size=(96, 192)).items()})
    fields.update(
        {f"hur/{k}": v for k, v in hurricane_suite(3, size=(16, 48, 48)).items()}
    )
    fields.update({f"nyx/{k}": v for k, v in nyx_suite(3, size=(32, 32, 32)).items()})
    return fields


def _smoke_selections():
    """One selection pass shared by the decision and estimation metrics."""
    from repro.core import select_many

    fields = _smoke_fields()
    sels = select_many(list(fields.values()), eb_rel=1e-3)
    return fields, sels


def bench_decisions(fields, sels) -> dict:
    """Selection smoke: the full decision tuple per field (flip gate)."""
    return {
        name: {"codec": s.codec, "eb_sz": round(float(s.eb_sz), 10)}
        for name, s in zip(fields, sels)
    }


def bench_policyset_parity(fields, sels) -> list[str]:
    """Rerun the smoke decisions through the Policy-object API
    (`compress_pytree` with a `PolicySet` whose decoy rule matches
    nothing) and list every field whose decision differs from the direct
    `select_many` kwarg path — the api_redesign invariant: the policy
    grouping layer must flip NOTHING for a single-policy tree."""
    from repro.core import Policy, PolicySet, compress_pytree

    pset = PolicySet(
        default=Policy.fixed_accuracy(eb_rel=1e-3),
        rules=[("no-such-leaf/*", Policy.fixed_ratio(6.0))],
    )
    ct = compress_pytree(dict(fields), pset, workers=0)
    bad = []
    for name, s in zip(fields, sels):
        got = ct.fields[name].selection
        if got is None or got.codec != s.codec or got.eb_sz != s.eb_sz:
            bad.append(name)
    return bad


def bench_estimation_error(fields, sels) -> float:
    """Estimation smoke: mean |estimated - actual| bits/value over the
    smoke fields on each field's SELECTED codec (the §4–§5 estimators'
    end-to-end job; rises when either estimator drifts)."""
    import numpy as np

    from repro.core import sz_compress, zfp_compress

    errs = []
    for f, s in zip(fields.values(), sels):
        if s.codec == "sz":
            actual = 8.0 * len(sz_compress(f, s.eb_sz)) / f.size
            errs.append(abs(float(s.br_sz) - actual))
        elif s.codec == "zfp":
            actual = 8.0 * len(zfp_compress(f, s.eb_abs)) / f.size
            errs.append(abs(float(s.br_zfp) - actual))
    return float(np.mean(errs))


def _csv_cell(rows: list[str], row: int, col_name: str) -> str:
    header = rows[0].split(",")
    return rows[row].split(",")[header.index(col_name)]


def bench_ratios(repeat: int) -> tuple[dict, dict]:
    """The three throughput ratios + raw timings (recorded, not gated)."""
    from benchmarks import bench_kernels3d, bench_selection, bench_sharded

    raw: dict = {}
    k3 = bench_kernels3d.run(sizes=(64,), repeat=repeat)
    raw["kernels3d"] = k3
    sel = bench_selection.run_many(n_fields=12, repeat=repeat)
    raw["selection_many"] = sel
    sh = bench_sharded.run(n_fields=6, dim=768, repeat=repeat)
    raw["sharded"] = sh
    ratios = {
        "kernels3d_encode_stats_speedup": float(
            _csv_cell(k3, 1, "speedup_encode_stats")
        ),
        "selection_batched_speedup": float(_csv_cell(sel, 1, "speedup")),
        "sharded_save_speedup": float(_csv_cell(sh, 2, "speedup_vs_gather")),
    }
    return ratios, raw


def bench_warm_save() -> tuple[dict, dict]:
    """Repeated-save workload (DESIGN.md §8): the same tree saved through
    a `DecisionCache`, at `run_repeated_save`'s full field sizes (the one
    non-smoke bench here — see WARM_OVERHEAD_MAX_PCT). Returns (summary,
    raw rows); the summary's flips / hit_rate / overhead are gated
    absolutely, its warm-vs-cold selection speedup rides the baseline
    ratio rule."""
    from benchmarks import bench_overhead

    rows, summary = bench_overhead.run_repeated_save()
    return summary, {"repeated_save": rows}


def bench_multihost() -> dict:
    """Cross-host-count save parity (DESIGN.md §6.2): real 1- and
    2-process distributed saves of the same state, differenced. Gated
    absolutely by `multihost_save_parity` — the flip list must be empty."""
    from benchmarks import bench_multihost as mh

    return mh.run()


def bench_serving() -> dict:
    """Paged-serving evict/restore parity + compression report (DESIGN.md
    §9): tiny-arena forced-eviction run vs pressure-free run at Policy.raw.
    Gated absolutely by `serving_page_parity` — zero token mismatches,
    bit-identical raw page round-trips, and the eviction path actually
    exercised; the store-byte ratio and tok/s ratio ride along ungated."""
    from benchmarks import bench_serving as sv

    return sv.run()


def bench_device_encode(repeat: int) -> dict:
    """Device-resident Stage III (DESIGN.md §3.7): byte parity of the
    device packers against the host coders over the device's own codes,
    plus the end-to-end encode speedup aggregate on 64^3 smoke volumes.
    Gated absolutely by `device_encode_parity` — the mismatch list must
    be empty, and an all-declined run counts as a (vacuous) mismatch;
    `device_encode_speedup` (geomean over (field, codec) rows) rides the
    20% ratio rule."""
    from benchmarks import bench_device_encode as de

    return de.run(size=64, n_fields=2, repeat=repeat)


def bench_quality() -> tuple[dict, dict]:
    """Quality-metric target accuracy (DESIGN.md §7.4): smoke-scale
    achieved-vs-target with real encode+decode+measure, gated absolutely
    by `quality_target_accuracy` / `quality_solve_overhead`."""
    from benchmarks import bench_quality as bq
    from repro.core import quality as qual

    assert QUALITY_TOLERANCE == qual.TOLERANCE, (
        "tools/bench_gate.QUALITY_TOLERANCE drifted from "
        "repro.core.quality.TOLERANCE — update the gate copy"
    )
    out = bq.run(smoke=True)
    summary = {
        k: out[k]
        for k in (
            "violations", "on_target_frac", "lossy_fields",
            "solve_overhead_ratio",
        )
    }
    return summary, {"quality": out["rows"]}


def gate(metrics: dict, baseline: dict) -> list[dict]:
    """Compare current metrics against the baseline -> list of checks."""
    checks: list[dict] = []
    key = _env_key()
    parity = metrics.get("policyset_parity_mismatches")
    if parity is not None:
        checks.append(
            dict(
                name="policyset_parity",
                passed=not parity,
                detail=(
                    f"PolicySet route flipped: {parity}" if parity
                    else "PolicySet route matches select_many decisions"
                ),
            )
        )
    base_dec = baseline.get("decisions", {}).get(key)
    if base_dec is None:
        checks.append(
            dict(
                name=f"decisions[{key}]",
                passed=False,
                detail=f"no baseline for {key}; run --update-baseline "
                "(with REPRO_SZ_TABLE_BITS if cross-generating)",
            )
        )
    else:
        cur = metrics["decisions"]
        flips = [
            n
            for n in base_dec
            if n not in cur
            or cur[n]["codec"] != base_dec[n]["codec"]
            or abs(cur[n]["eb_sz"] - base_dec[n]["eb_sz"])
            > 1e-5 * max(abs(base_dec[n]["eb_sz"]), 1e-30)
        ]
        # fields in the smoke suite but not in the baseline are UNGATED —
        # fail closed so an extended suite forces an --update-baseline
        flips += sorted(f"{n} (no baseline)" for n in set(cur) - set(base_dec))
        checks.append(
            dict(
                name=f"decisions[{key}]",
                passed=not flips,
                detail=f"flipped/moved/unbaselined: {flips}" if flips else
                f"{len(base_dec)} decisions stable",
            )
        )
    for name, cur in metrics["ratios"].items():
        base = baseline.get("ratios", {}).get(name)
        if base is None:
            checks.append(dict(name=name, passed=False, detail="no baseline"))
            continue
        floor = base * (1.0 - MAX_REGRESSION)
        checks.append(
            dict(
                name=name,
                passed=cur >= floor,
                detail=f"{cur:.2f}x vs baseline {base:.2f}x (floor {floor:.2f}x)",
            )
        )
    warm = metrics.get("warm_save")
    if warm is not None:
        # differential parity is absolute — a validated warm hit must
        # replay the cold decision bit-identically, every save a hit
        checks.append(
            dict(
                name="warm_save_parity",
                passed=not warm["flips"] and warm["hit_rate"] >= 1.0,
                detail=(
                    f"flips={warm['flips']} hit_rate={warm['hit_rate']:.2f}"
                    if warm["flips"] or warm["hit_rate"] < 1.0
                    else f"no flips, hit rate {warm['hit_rate']:.2f}"
                ),
            )
        )
        checks.append(
            dict(
                name="warm_save_overhead_pct",
                passed=warm["warm_overhead_pct"] <= WARM_OVERHEAD_MAX_PCT,
                detail=f"{warm['warm_overhead_pct']:.2f}% of encode "
                f"(ceiling {WARM_OVERHEAD_MAX_PCT:.0f}%)",
            )
        )
    mh = metrics.get("multihost")
    if mh is not None:
        bad = list(mh["flips"]) + list(mh["value_mismatches"])
        checks.append(
            dict(
                name="multihost_save_parity",
                passed=not bad,
                detail=(
                    f"diverged across host counts: {bad[:6]}" if bad else
                    f"decisions+bytes identical across {mh['hosts']} host counts"
                ),
            )
        )
    sv = metrics.get("serving")
    if sv is not None:
        # absolute, like the warm/multihost parities: raw evict/restore
        # must be invisible to the token stream, and vacuous passes (no
        # eviction exercised) count as failures
        bad_sv = bool(
            sv["token_mismatches"] or sv["byte_mismatches"] or not sv["evictions"]
        )
        checks.append(
            dict(
                name="serving_page_parity",
                passed=not bad_sv,
                detail=(
                    f"token_mismatches={sv['token_mismatches']} "
                    f"byte_mismatches={sv['byte_mismatches']} "
                    f"evictions={sv['evictions']}"
                    if bad_sv else
                    f"decode bit-identical across {sv['evictions']} "
                    f"evictions; raw page round-trips exact"
                ),
            )
        )
    dev = metrics.get("device_encode")
    if dev is not None:
        # absolute: the device packers must emit byte-identical container
        # streams to the host coders over the same quantized codes — any
        # drift means the unchanged host decoders would misread a
        # device-packed field (declined fields surface here too)
        bad_dev = list(dev["parity_mismatches"])
        checks.append(
            dict(
                name="device_encode_parity",
                passed=not bad_dev,
                detail=(
                    f"device/host stream mismatch: {bad_dev[:6]}" if bad_dev
                    else f"device streams byte-identical on {dev['fields']} "
                    "smoke fields (sz+zfp)"
                ),
            )
        )
    q = metrics.get("quality")
    if q is not None:
        # absolute, two-part: claimed-on-target fields must MEASURE within
        # tolerance (SSIM/correlation floors, KS ceiling — one-sided
        # `metric_gap`), and the solver must keep claiming most fields;
        # a run that solved nothing lossy is vacuous and fails
        bad_q = []
        for m, tol in QUALITY_TOLERANCE.items():
            v = q["violations"].get(m)
            if v is None:
                bad_q.append(f"{m}: not measured")
            elif v > tol:
                bad_q.append(f"{m}: worst gap {v:+.4f} > tol {tol}")
            frac = q["on_target_frac"].get(m, 0.0)
            if frac < QUALITY_ON_TARGET_MIN:
                bad_q.append(
                    f"{m}: claimed on_target {frac:.2f} < "
                    f"{QUALITY_ON_TARGET_MIN}"
                )
        if not q.get("lossy_fields"):
            bad_q.append("vacuous: no lossy fields solved")
        checks.append(
            dict(
                name="quality_target_accuracy",
                passed=not bad_q,
                detail=("; ".join(bad_q) if bad_q else
                        "worst gaps " + ", ".join(
                            f"{m} {q['violations'][m]:+.4f}<=+{t}"
                            for m, t in QUALITY_TOLERANCE.items()
                        )),
            )
        )
        checks.append(
            dict(
                name="quality_solve_overhead",
                passed=q["solve_overhead_ratio"] <= QUALITY_SOLVE_OVERHEAD_MAX,
                detail=f"{q['solve_overhead_ratio']:.2f}x fixed_ratio solve "
                f"(ceiling {QUALITY_SOLVE_OVERHEAD_MAX:.0f}x)",
            )
        )
    base_err = baseline.get("estimation_error_b")
    cur_err = metrics["estimation_error_b"]
    if base_err is None:
        checks.append(dict(name="estimation_error_b", passed=False, detail="no baseline"))
    else:
        ceil = base_err * (1.0 + MAX_REGRESSION) + EST_ABS_SLACK
        checks.append(
            dict(
                name="estimation_error_b",
                passed=cur_err <= ceil,
                detail=f"{cur_err:.3f} b/v vs baseline {base_err:.3f} (ceil {ceil:.3f})",
            )
        )
    return checks


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_10.json", help="report path")
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument(
        "--decisions-only",
        action="store_true",
        help="with --update-baseline: merge only this env's decisions "
        "(keeps committed ratios — for REPRO_SZ_TABLE_BITS cross-keys)",
    )
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()

    key = _env_key()
    print(f"bench gate: environment key {key}", flush=True)
    fields, sels = _smoke_selections()
    metrics: dict = {"decisions": bench_decisions(fields, sels)}
    print(f"  decisions: {len(metrics['decisions'])} fields", flush=True)
    metrics["policyset_parity_mismatches"] = bench_policyset_parity(fields, sels)
    print(
        f"  policyset parity: {len(metrics['policyset_parity_mismatches'])} mismatches",
        flush=True,
    )
    if not (args.update_baseline and args.decisions_only):
        metrics["estimation_error_b"] = bench_estimation_error(fields, sels)
        print(f"  estimation error: {metrics['estimation_error_b']:.3f} b/v", flush=True)
        metrics["ratios"], raw = bench_ratios(args.repeat)
        warm, warm_raw = bench_warm_save()
        raw.update(warm_raw)
        metrics["ratios"]["warm_save_speedup"] = float(warm["warm_save_speedup"])
        metrics["warm_save"] = {
            k: warm[k] for k in ("warm_overhead_pct", "hit_rate", "flips")
        }
        for n, v in metrics["ratios"].items():
            print(f"  {n}: {v:.2f}x", flush=True)
        print(
            f"  warm_save: {warm['warm_overhead_pct']:.2f}% of encode, "
            f"hit rate {warm['hit_rate']:.2f}, flips {warm['flips']}",
            flush=True,
        )
        metrics["multihost"] = bench_multihost()
        print(
            f"  multihost: hosts {metrics['multihost']['hosts']}, "
            f"flips {metrics['multihost']['flips']}, "
            f"mismatches {metrics['multihost']['value_mismatches']}",
            flush=True,
        )
        metrics["serving"] = bench_serving()
        print(
            f"  serving: evictions {metrics['serving']['evictions']}, "
            f"token mismatches {metrics['serving']['token_mismatches']}, "
            f"store ratio {metrics['serving']['compression_store_ratio']:.2f}x, "
            f"tok/s ratio {metrics['serving']['compression_tok_s_ratio']:.2f}x",
            flush=True,
        )
        dev = bench_device_encode(args.repeat)
        raw["device_encode"] = dev["rows"]
        metrics["device_encode"] = {
            "parity_mismatches": dev["parity_mismatches"],
            "speedups": dev["speedups"],
            "fields": dev["fields"],
        }
        metrics["ratios"]["device_encode_speedup"] = float(
            dev["device_encode_speedup"]
        )
        print(
            f"  device_encode: {dev['device_encode_speedup']:.2f}x geomean "
            f"(sz {dev['speedups']['sz']:.2f}x, zfp {dev['speedups']['zfp']:.2f}x), "
            f"parity mismatches {dev['parity_mismatches'] or 'none'}",
            flush=True,
        )
        qsum, q_raw = bench_quality()
        raw.update(q_raw)
        metrics["quality"] = qsum
        print(
            "  quality: worst gaps "
            + ", ".join(f"{m} {v:+.4f}" for m, v in qsum["violations"].items())
            + f", solve overhead {qsum['solve_overhead_ratio']:.2f}x",
            flush=True,
        )

    if args.update_baseline:
        baseline = json.loads(BASELINE.read_text()) if BASELINE.exists() else {}
        baseline.setdefault("decisions", {})[key] = metrics["decisions"]
        if not args.decisions_only:
            baseline["ratios"] = metrics["ratios"]
            baseline["estimation_error_b"] = metrics["estimation_error_b"]
        BASELINE.write_text(json.dumps(baseline, indent=1, sort_keys=True) + "\n")
        print(f"baseline updated: {BASELINE}")
        return 0

    baseline = json.loads(BASELINE.read_text()) if BASELINE.exists() else {}
    checks = gate(metrics, baseline)
    ok = all(c["passed"] for c in checks)
    report = {
        "env_key": key,
        "pass": ok,
        "checks": checks,
        "metrics": metrics,
        "raw_rows": raw,
    }
    Path(args.out).write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    for c in checks:
        print(f"  [{'PASS' if c['passed'] else 'FAIL'}] {c['name']}: {c['detail']}")
    print(("PASS" if ok else "FAIL") + f" — report at {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
