"""Paper Tables 2-5: average + std of relative estimation error for bit-rate
and PSNR, per data-set suite, per sampling rate (1%, 5%, 10%)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import select, sz_compress, sz_stats, zfp_compress, zfp_stats
from .common import SUITES, csv_row


def run(eb_rel: float = 1e-3, rates=(0.01, 0.05, 0.10), suites=("ATM", "Hurricane")):
    rows = [csv_row("suite", "r_sp", "metric", "codec", "avg_rel_err", "std_rel_err")]
    for suite_name in suites:
        fields = SUITES[suite_name]()
        for r_sp in rates:
            errs = {("br", "sz"): [], ("br", "zfp"): [], ("psnr", "sz"): [], ("psnr", "zfp"): []}
            for name, f in fields.items():
                vr = float(f.max() - f.min())
                eb = eb_rel * vr
                sel = select(f, eb_abs=eb, r_sp=r_sp)
                # actual rates from the byte codecs
                a_sz = 8 * len(sz_compress(f, sel.eb_sz)) / f.size
                a_zfp = 8 * len(zfp_compress(f, eb)) / f.size
                errs[("br", "sz")].append((sel.br_sz - a_sz) / a_sz)
                errs[("br", "zfp")].append((sel.br_zfp - a_zfp) / a_zfp)
                # actual PSNR from the stats paths (== codec reconstructions)
                p_sz = float(sz_stats(jnp.asarray(f), sel.eb_sz).psnr)
                p_zfp = float(zfp_stats(jnp.asarray(f), eb).psnr)
                est_p_sz = float(
                    __import__("repro.core.estimator", fromlist=["sz_psnr"]).sz_psnr(sel.eb_sz, vr)
                )
                errs[("psnr", "sz")].append((est_p_sz - p_sz) / p_sz)
                errs[("psnr", "zfp")].append((sel.psnr_target - p_zfp) / p_zfp)
            for (metric, codec), v in errs.items():
                v = np.asarray(v)
                rows.append(
                    csv_row(suite_name, r_sp, metric, codec,
                            f"{np.mean(v):+.4f}", f"{np.std(v):.4f}")
                )
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
