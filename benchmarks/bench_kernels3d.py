"""ISSUE 4 acceptance bench: the 3-D Pallas kernel tier vs the pre-PR
fallback path (DESIGN.md §3.4–§3.5).

encode = fused prequantize + 3-D integer-Lorenzo (SZ Stage I+II); stats =
fused 4x4x4 BOT + truncate + closed-form rate (ZFP Stage I+II). The
fallback is what `kernels/ops.py` dispatched 3-D shapes to before the
kernel tier existed: the jnp `lorenzo_forward(round(x/2eb))` reference
and `core.zfp.zfp_stats` (whose exact coder runs the 31-plane loop).

  PYTHONPATH=src python -m benchmarks.bench_kernels3d [--sizes 256,512]

Default sizes are CPU-friendly (128^3, 256^3 ~ the NYX cube of
`launch.shapes.FIELD_SHAPES`); pass --sizes 512 for the paper-scale cube
on real hardware. The `speedup` column (old encode+stats time over new)
is the ratio the CI bench gate tracks (tools/bench_gate.py).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import csv_row


def _timer(fn, *args, repeat: int = 3):
    """Min-of-repeats wall time (the standard microbench statistic — the
    min is the least load-contaminated sample, which matters because the
    CI bench gate compares these as ratios against a committed baseline)."""
    import jax

    out = fn(*args)
    jax.block_until_ready(out)  # warm-up: compile outside the timed region
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _paths():
    import jax
    import jax.numpy as jnp

    from repro.core.transforms import lorenzo_forward
    from repro.core.zfp import zfp_stats
    from repro.kernels import ops

    old_enc = jax.jit(
        lambda x, eb: lorenzo_forward(jnp.round(x / (2.0 * eb))).astype(jnp.int32)
    )

    def _old_stats(x, eb):
        st = zfp_stats(x, eb)
        return st.recon, st.bitrate

    return {
        "new_encode": lambda x, eb: ops.lorenzo_encode(x, eb),
        "new_stats": lambda x, eb: ops.bot_fused(x, eb),
        "old_encode": old_enc,
        "old_stats": jax.jit(_old_stats),
    }


def run(sizes=None, repeat: int = 3, seed: int = 0):
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.launch.shapes import FIELD_SHAPES

    if sizes is None:
        # the catalog's CPU-scaled NYX cube edge and the Hurricane-like
        # trailing edge (launch.shapes.FIELD_SHAPES) -> 128^3 and 256^3
        sizes = (FIELD_SHAPES["nyx_3d"][0], FIELD_SHAPES["hurricane_3d"][-1])

    p = _paths()
    rows = [
        csv_row(
            "shape", "enc_new_ms", "enc_old_ms", "stats_new_ms", "stats_old_ms",
            "speedup_encode_stats",
        )
    ]
    for n in sizes:
        shape = (n, n, n)
        assert ops.pallas_rank(shape) == 3, "bench field must ride the 3-D tier"
        rng = np.random.default_rng(seed)
        x = jnp.asarray(
            np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)
        )
        eb = jnp.float32(1e-3 * float(jnp.max(x) - jnp.min(x)))
        te_new = _timer(p["new_encode"], x, eb, repeat=repeat)
        ts_new = _timer(p["new_stats"], x, eb, repeat=repeat)
        te_old = _timer(p["old_encode"], x, eb, repeat=repeat)
        # the 31-plane exact coder is 10-50x the kernel path; at bench
        # scale once is plenty, at gate scale keep the min-of-repeats
        ts_old = _timer(p["old_stats"], x, eb, repeat=repeat if n <= 128 else 1)
        speedup = (te_old + ts_old) / (te_new + ts_new)
        rows.append(
            csv_row(
                f"{n}^3",
                f"{te_new * 1e3:.1f}", f"{te_old * 1e3:.1f}",
                f"{ts_new * 1e3:.1f}", f"{ts_old * 1e3:.1f}",
                f"{speedup:.2f}",
            )
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--sizes", default=None,
        help="comma list of cube edges (default: from launch.shapes.FIELD_SHAPES)",
    )
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(",")) if args.sizes else None
    for r in run(sizes=sizes, repeat=args.repeat):
        print(r)


if __name__ == "__main__":
    main()
