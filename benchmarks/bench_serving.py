"""Serving-tier benchmark + parity smoke (DESIGN.md §9).

Two questions about the compression-aware paged KV pool
(`runtime/batcher.py`):

* **Parity** — under page pressure (tiny arena, forced compress-on-evict /
  decompress-on-hit cycles) with `Policy.raw`, does every request decode
  the EXACT token stream of a pressure-free run (huge arena, no
  evictions)? Raw page round-trips are bit-identical by construction, so
  any token mismatch means the pool corrupted a page. This feeds the
  bench gate's absolute `serving_page_parity` check, together with a
  direct byte-level round-trip probe over bf16 page stacks.

* **Compression** — under a saturation workload where every request is
  long-context (resolves to `Policy.fixed_ratio`), how many bytes does
  the evicted-page store hold vs. the same schedule at `Policy.raw`, and
  what does the compression work cost in decode throughput? Reported
  (`store_ratio`, `tok_s_ratio`), not gated — wall times are
  machine-relative.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""

from __future__ import annotations

import time

import numpy as np


def _setup():
    import jax

    from repro.configs import get_config
    from repro.models import build_model, reduced_for_smoke
    from repro.models import nn as rnn

    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    return cfg, model, params


def _workload(cfg, n: int, prompt_len: int, max_new: int, seed: int = 0):
    from repro.runtime.batcher import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, prompt_len).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n)
    ]


def _drive(b, reqs):
    """batcher.run with per-step sampling of the evicted-page store."""
    pending = list(reqs)
    it, peak_store = 0, 0
    t0 = time.perf_counter()
    while (pending or b.preempted or b.live.any()) and it < 10_000:
        while b.preempted and b.try_admit(b.preempted[0]):
            b.preempted.pop(0)
        while pending and b.try_admit(pending[0]):
            pending.pop(0)
        b.step()
        store = sum(
            cp.nbytes for r in b.preempted for cp in r.page_comp.values()
        )
        peak_store = max(peak_store, store)
        it += 1
    return peak_store, time.perf_counter() - t0


def run_parity(n_requests: int = 4, prompt_len: int = 12, max_new: int = 20) -> dict:
    """Tiny-arena vs huge-arena paged serving at Policy.raw -> mismatches."""
    from repro.core.policy import Policy
    from repro.runtime import kvcomp
    from repro.runtime.batcher import ContinuousBatcher

    cfg, model, params = _setup()

    def one(arena_pages):
        b = ContinuousBatcher(
            model, params, slots=2, max_len=48, eos_id=-1,
            page_tokens=8, arena_pages=arena_pages, policies=Policy.raw(),
        )
        reqs = _workload(cfg, n_requests, prompt_len, max_new)
        b.run(reqs)
        return reqs, b

    ref, _ = one(arena_pages=None)  # never evicts
    cur, tiny = one(arena_pages=7)  # max_pages=6, forced evictions
    token_mismatches = sum(
        a.out != c.out or len(c.out) != max_new for a, c in zip(ref, cur)
    )
    # direct byte-level probe: raw page round-trips must be bit-identical
    rng = np.random.default_rng(7)
    byte_mismatches = 0
    for _ in range(4):
        page = rng.standard_normal((2, 8, 64)).astype("bfloat16")
        cp = kvcomp.compress_page(page, Policy.raw())
        back = kvcomp.decompress_page(cp)
        byte_mismatches += int(back.tobytes() != page.tobytes())
    return {
        "token_mismatches": int(token_mismatches),
        "byte_mismatches": int(byte_mismatches),
        "evictions": int(tiny.stats["evictions"]),
        "restores": int(tiny.stats["restores"]),
    }


def run_compression(
    n_requests: int = 6, prompt_len: int = 16, max_new: int = 24
) -> dict:
    """Saturation workload: fixed_ratio long-context policies vs raw at the
    same (tight) arena -> evicted-store byte ratio + decode tok/s ratio."""
    from repro.core.decision_cache import DecisionCache
    from repro.core.policy import Policy, serving_policies
    from repro.runtime.batcher import ContinuousBatcher

    cfg, model, params = _setup()

    def one(policies, decisions=None):
        b = ContinuousBatcher(
            model, params, slots=2, max_len=48, eos_id=-1,
            page_tokens=8, arena_pages=7, policies=policies,
            long_threshold=1, decisions=decisions,
        )
        reqs = _workload(cfg, n_requests, prompt_len, max_new)
        peak_store, wall = _drive(b, reqs)
        assert all(r.done for r in reqs)
        toks = sum(len(r.out) for r in reqs)
        return peak_store, toks / max(wall, 1e-9), b

    # warm the compression path's jit caches (fused kernel + ratio grid at
    # the page-stack shape) so the timed runs compare steady-state decode,
    # not first-call compiles
    from repro.runtime import kvcomp

    nl = cfg.n_layers
    dummy = np.zeros((nl, 8, cfg.n_kv_heads * cfg.dh), np.float32)
    dummy[0, 0, 0] = 1.0
    kvcomp.compress_page(dummy, serving_policies(8.0).resolve("kv/long/0"))

    decisions = DecisionCache()
    raw_store, raw_tok_s, _ = one(Policy.raw())
    comp_store, comp_tok_s, cb = one(serving_policies(8.0), decisions)
    return {
        "raw_peak_store_bytes": int(raw_store),
        "comp_peak_store_bytes": int(comp_store),
        "store_ratio": raw_store / max(comp_store, 1),
        "tok_s_ratio": comp_tok_s / max(raw_tok_s, 1e-9),
        "evictions": int(cb.stats["evictions"]),
        "decision_hits": int(decisions.hits),
    }


def run() -> dict:
    out = run_parity()
    out.update({f"compression_{k}": v for k, v in run_compression().items()})
    return out


if __name__ == "__main__":
    r = run()
    for k, v in r.items():
        print(f"  {k}: {v}")
    ok = not r["token_mismatches"] and not r["byte_mismatches"] and r["evictions"]
    print("PASS" if ok else "FAIL")
    raise SystemExit(0 if ok else 1)
