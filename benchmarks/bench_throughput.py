"""Paper Figs 8-9: storing/loading throughput vs process count.

Real-time codec rates are measured on this machine; the parallel file
system is modeled as a saturating shared-bandwidth resource
(B_eff(p) = B_max * p / (p + p_half), GPFS-like contention curve, per [56]).
Store time per process = compress + write(bytes/B_eff); load = read +
decompress. Throughput = p * field_bytes / time — the paper's setup with
file-per-process POSIX I/O."""

from __future__ import annotations


from repro.core import (
    select_and_compress, decompress, sz_compress, sz_decompress,
    zfp_compress, zfp_decompress,
)
from .common import SUITES, csv_row, timer

B_MAX = 90e9      # aggregate PFS bandwidth (GPFS-class), B/s
P_HALF = 64       # half-saturation process count
PER_PROC = 1.2e9  # single-stream cap, B/s


def _b_eff(p: int) -> float:
    agg = B_MAX * p / (p + P_HALF)
    return min(agg, p * PER_PROC)


def run(eb_rel: float = 1e-4, procs=(1, 16, 64, 256, 1024), suite="Hurricane"):
    fields = dict(list(SUITES[suite]().items())[:4])
    raw = sum(f.nbytes for f in fields.values())
    # measured codec rates (B/s) and sizes
    meas = {}
    for codec in ("baseline", "sz", "zfp", "ours"):
        csize = 0
        t_c = t_d = 1e-12
        for f in fields.values():
            eb = eb_rel * float(f.max() - f.min())
            if codec == "baseline":
                blob, dt = f.tobytes(), 1e-9
                csize += len(blob)
                t_c += dt
                t_d += 1e-9
            elif codec == "sz":
                blob, dt = timer(sz_compress, f, eb)
                csize += len(blob)
                t_c += dt
                _, dt = timer(sz_decompress, blob)
                t_d += dt
            elif codec == "zfp":
                blob, dt = timer(zfp_compress, f, eb)
                csize += len(blob)
                t_c += dt
                _, dt = timer(zfp_decompress, blob)
                t_d += dt
            else:
                cf, dt = timer(select_and_compress, f, eb_abs=eb)
                csize += len(cf.data)
                t_c += dt
                _, dt = timer(decompress, cf)
                t_d += dt
        meas[codec] = dict(
            ratio=raw / csize,
            c_rate=raw / t_c,   # compression throughput, B/s/proc
            d_rate=raw / t_d,
        )
    rows = [csv_row("codec", "procs", "ratio", "store_GBps", "load_GBps")]
    field_bytes = raw / len(fields)
    for codec, m in meas.items():
        for p in procs:
            io_bw = _b_eff(p)
            comp_bytes = field_bytes / m["ratio"]
            t_store = field_bytes / m["c_rate"] + comp_bytes * p / io_bw
            t_load = field_bytes / m["d_rate"] + comp_bytes * p / io_bw
            rows.append(csv_row(
                codec, p, f"{m['ratio']:.2f}",
                f"{p * field_bytes / t_store / 1e9:.2f}",
                f"{p * field_bytes / t_load / 1e9:.2f}",
            ))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
