"""Multi-host save-parity smoke for the CI bench gate (DESIGN.md §6.2).

Saves the same synthetic mixed-policy state cooperatively at each host
count in `processes` — real `jax.distributed` jobs spawned through
`repro.launch.mhrun`, 8 global emulated devices split across them — and
differences the results: Stage I/II decisions, error bounds, segment
geometry, and decompressed bytes must be bit-identical across host
counts (the psum-reconciliation contract the multihost test suite proves
exhaustively; this smoke keeps the invariant wired into the bench gate's
`multihost_save_parity` absolute check, which flips EMPTY across host
counts or fails).

The worker program is `tests/multihost/worker.py` (scenario ``save``) —
one definition of the differential state for the suite and the gate, so
the two can never drift apart.

    PYTHONPATH=src python -m benchmarks.bench_multihost
"""

from __future__ import annotations

import os
import sys
import tempfile
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
WORKER = ROOT / "tests" / "multihost" / "worker.py"


def run(processes: tuple[int, ...] = (1, 2), fields: int = 3, dim: int = 128) -> dict:
    """-> {hosts, flips, value_mismatches, wall_seconds} across `processes`."""
    from repro.launch import mhrun

    env = {
        "PYTHONPATH": os.pathsep.join(
            [str(ROOT / "src"), os.environ.get("PYTHONPATH", "")]
        ).rstrip(os.pathsep)
    }
    payloads: dict[int, list[dict]] = {}
    wall: dict[str, float] = {}
    with tempfile.TemporaryDirectory() as wd:
        for nproc in processes:
            t0 = time.perf_counter()
            results = mhrun.run(
                [sys.executable, str(WORKER)],
                nproc,
                scenario="save",
                args=dict(
                    directory=os.path.join(wd, f"ckpt{nproc}p"),
                    fields=fields, dim=dim,
                ),
                local_devices=8 // nproc,
                timeout_s=600.0,
                workdir=os.path.join(wd, f"mhrun{nproc}p"),
                extra_env=env,
            )
            payloads[nproc] = mhrun.require_success(results)
            wall[f"{nproc}p"] = time.perf_counter() - t0

    base = payloads[processes[0]][0]
    flips: list[str] = []
    mismatches: list[str] = []
    for nproc in processes:
        for p in payloads[nproc]:
            for name, bits in base["summary"]["selection_bits"].items():
                if p["summary"]["selection_bits"].get(name) != bits:
                    flips.append(f"{nproc}p:{name}")
            if p["summary"] != base["summary"]:
                flips.append(f"{nproc}p:<manifest>")
            for name, digest in base["hashes"].items():
                if p["hashes"].get(name) != digest:
                    mismatches.append(f"{nproc}p:{name}")
    return dict(
        hosts=[int(p) for p in processes],
        flips=sorted(set(flips)),
        value_mismatches=sorted(set(mismatches)),
        wall_seconds=wall,
    )


def main() -> int:
    out = run()
    for k, v in out.items():
        print(f"{k}: {v}")
    bad = out["flips"] + out["value_mismatches"]
    print("PASS" if not bad else f"FAIL: {bad[:8]}")
    return 0 if not bad else 1


if __name__ == "__main__":
    sys.exit(main())
