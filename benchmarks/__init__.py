from . import common
