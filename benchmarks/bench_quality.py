"""Quality-metrics targets (DESIGN.md §7.4): achieved-vs-target accuracy
and solve overhead for the SSIM / correlation / KS modes on the
paper-style suites.

For each suite x metric x target, every field is solved (`solve_many` —
the §7.4 estimators invert to an equivalent-PSNR target, so the launch
profile is fixed_psnr's: batched sweeps, ZERO trial compressions), then
actually encoded and decoded; the report compares the MEASURED metric of
the real reconstruction against the target. The contract is one-sided
(`quality.metric_gap`): SSIM and correlation are floors, KS a ceiling —
overshooting quality is never a violation, so the gated number is the
worst signed gap, which must stay within `quality.TOLERANCE[metric]`.

Solve overhead is reported as a ratio against fixed_ratio's solve time
on the same fields (the §7 acceptance envelope): the metric modes add
only per-field numpy statistics (variance + the sorted KS sample) on top
of the shared secant machinery, so the ratio should sit near 1.

  PYTHONPATH=src python -m benchmarks.bench_quality
  PYTHONPATH=src python -m benchmarks.bench_quality --smoke
"""

from __future__ import annotations

import numpy as np

from repro.core import Policy, decompress, encode_with_selection, solve_many
from repro.core import quality as qual

from .common import SUITES, atm_suite, csv_row, hurricane_suite, nyx_suite, timer

#: benchmark targets per metric — one comfortably reachable, one tight
TARGETS = {
    "ssim": (0.92, 0.98),
    "correlation": (0.99, 0.999),
    "ks": (0.05, 0.15),
}

POLICY_OF = {
    "ssim": Policy.fixed_ssim,
    "correlation": Policy.fixed_correlation,
    "ks": Policy.fixed_ks,
}


def _smoke_suites() -> dict:
    """CI-sized versions of the three suites (matches the gate's smoke
    scale; the full sizes are for the standalone report)."""
    return {
        "ATM": lambda: atm_suite(4, size=(96, 192)),
        "Hurricane": lambda: hurricane_suite(3, size=(16, 48, 48)),
        "NYX": lambda: nyx_suite(3, size=(32, 32, 32)),
    }


def _run_metric(fields: dict, metric: str, target: float):
    pol = POLICY_OF[metric](target)
    arrs = list(fields.values())
    solve_many(arrs, pol)  # warm the sweep jit cache before timing
    sols, t_solve = timer(solve_many, arrs, pol)
    gaps, claimed, lossy = [], [], []
    for a, sol in zip(arrs, sols):
        cf = encode_with_selection(a, sol.selection)
        rec = decompress(cf).reshape(a.shape)
        achieved = qual.measured_metric(metric, a, rec)
        gaps.append(qual.metric_gap(metric, achieved, target))
        claimed.append(bool(sol.on_target))
        lossy.append(cf.codec != "raw")
    return sols, np.asarray(gaps), np.asarray(claimed), np.asarray(lossy), t_solve


def run(suites=("ATM", "Hurricane", "NYX"), smoke: bool = False,
        targets: dict | None = None) -> dict:
    """-> {"rows": csv, "violations": {metric: worst signed gap over fields
    the solver CLAIMED on_target}, "on_target_frac": {metric: claimed
    fraction}, "lossy_fields": int, "solve_overhead_ratio": float}.

    The accuracy contract is two-part, mirroring `TargetSolution.on_target`
    semantics: every claimed-on-target field must MEASURE within
    `quality.TOLERANCE[metric]` of the target (the `violations` number),
    and the solver must claim most fields (`on_target_frac`) — a field it
    declines to claim (e.g. an intermittent field whose achievable-PSNR
    staircase has no point near the equivalent target) is an honest,
    reported miss, not a contract violation."""
    targets = dict(TARGETS if targets is None else targets)
    suite_of = _smoke_suites() if smoke else SUITES
    rows = [csv_row("suite", "metric", "target", "n", "achieved_p50",
                    "worst_gap", "claimed_ok", "solve_s", "overhead_vs_ratio")]
    worst: dict[str, float] = {m: -np.inf for m in targets}
    claim_ct: dict[str, list[int]] = {m: [0, 0] for m in targets}
    lossy_total = 0
    overheads = []
    for suite_name in suites:
        fields = suite_of[suite_name]()
        arrs = list(fields.values())
        # fixed_ratio's solve time on the same fields = the §7 envelope
        solve_many(arrs, Policy.fixed_ratio(8.0))
        _, t_ref = timer(solve_many, arrs, Policy.fixed_ratio(8.0))
        for metric, tgts in targets.items():
            for target in tgts:
                sols, gaps, claimed, lossy, t_solve = _run_metric(
                    fields, metric, target
                )
                if claimed.any():
                    worst[metric] = max(worst[metric], float(gaps[claimed].max()))
                claim_ct[metric][0] += int(claimed.sum())
                claim_ct[metric][1] += len(claimed)
                lossy_total += int(lossy.sum())
                overheads.append(t_solve / max(t_ref, 1e-9))
                # invert the signed gap back to the achieved value
                achieved = (target + gaps) if metric == "ks" else (target - gaps)
                rows.append(csv_row(
                    suite_name, metric, f"{target:g}", len(fields),
                    f"{np.median(achieved):.4f}", f"{gaps.max():+.4f}",
                    f"{int(claimed.sum())}/{len(claimed)}",
                    f"{t_solve:.3f}", f"{t_solve / max(t_ref, 1e-9):.2f}x",
                ))
    return {
        "rows": rows,
        "violations": {
            m: (float(worst[m]) if np.isfinite(worst[m]) else 0.0)
            for m in targets
        },
        "on_target_frac": {
            m: (claim_ct[m][0] / claim_ct[m][1] if claim_ct[m][1] else 0.0)
            for m in targets
        },
        "lossy_fields": lossy_total,
        "solve_overhead_ratio": float(
            np.exp(np.mean(np.log(np.maximum(overheads, 1e-9))))
        ),
    }


def main(argv: list[str] | None = None) -> None:
    import sys

    argv = sys.argv[1:] if argv is None else argv
    out = run(smoke="--smoke" in argv)
    for r in out["rows"]:
        print(r)
    print(f"# worst gaps: {out['violations']}")
    print(f"# on-target: {out['on_target_frac']}")
    print(f"# solve overhead vs fixed_ratio: {out['solve_overhead_ratio']:.2f}x")


if __name__ == "__main__":
    main()
