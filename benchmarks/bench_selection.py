"""Paper §6.2 + Figs 6-7: selection accuracy, compression-ratio improvement
at iso-PSNR, and the fixed-eb (Lu et al.) vs fixed-PSNR comparison."""

from __future__ import annotations

import numpy as np

from repro.core import select, sz_compress, zfp_compress
from .common import SUITES, csv_row


def run(eb_rels=(1e-3, 1e-4), suites=("ATM", "Hurricane", "NYX")):
    rows = [csv_row("suite", "eb_rel", "n_fields", "accuracy",
                    "cr_sz_only", "cr_zfp_only", "cr_ours", "cr_optimum",
                    "improve_vs_worst_pct", "degradation_pct", "fixed_eb_picks_sz_pct")]
    for suite_name in suites:
        fields = SUITES[suite_name]()
        for eb_rel in eb_rels:
            n_ok = 0
            bits = {"sz": 0.0, "zfp": 0.0, "ours": 0.0, "opt": 0.0}
            raw_bits = 0.0
            degr = []
            fixed_eb_sz = 0
            for name, f in fields.items():
                vr = float(f.max() - f.min())
                eb = eb_rel * vr
                sel = select(f, eb_abs=eb)
                # iso-PSNR actuals (SZ run at the matched bin size)
                b_sz = 8 * len(sz_compress(f, sel.eb_sz))
                b_zfp = 8 * len(zfp_compress(f, eb))
                best = "sz" if b_sz < b_zfp else "zfp"
                pick = sel.codec if sel.codec in ("sz", "zfp") else best
                n_ok += pick == best
                bits["sz"] += b_sz
                bits["zfp"] += b_zfp
                bits["ours"] += b_sz if pick == "sz" else b_zfp
                bits["opt"] += min(b_sz, b_zfp)
                if pick != best:
                    degr.append(max(b_sz, b_zfp) / min(b_sz, b_zfp) - 1)
                # Lu-et-al-style fixed-eb selection: larger CR at the SAME eb
                b_sz_fixed = 8 * len(sz_compress(f, eb))
                fixed_eb_sz += b_sz_fixed < b_zfp
                raw_bits += f.size * 32
            n = len(fields)
            crs = {k: raw_bits / v for k, v in bits.items()}
            worst = min(crs["sz"], crs["zfp"])
            rows.append(csv_row(
                suite_name, eb_rel, n, f"{n_ok / n:.3f}",
                f"{crs['sz']:.2f}", f"{crs['zfp']:.2f}", f"{crs['ours']:.2f}",
                f"{crs['opt']:.2f}",
                f"{100 * (crs['ours'] / worst - 1):.1f}",
                f"{100 * float(np.mean(degr)) if degr else 0:.2f}",
                f"{100 * fixed_eb_sz / n:.0f}",
            ))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
