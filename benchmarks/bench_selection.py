"""Paper §6.2 + Figs 6-7: selection accuracy, compression-ratio improvement
at iso-PSNR, and the fixed-eb (Lu et al.) vs fixed-PSNR comparison.

`run_many` / `--many`: the batched multi-field engine (`select_many`,
DESIGN.md §1) vs the per-field `select` loop on a many-tensor checkpoint —
one padded block batch + one jitted launch vs one launch (and up to one
compile) per field."""

from __future__ import annotations

import numpy as np

from repro.core import select, select_many, sz_compress, zfp_compress
from .common import SUITES, csv_row, timer


def run(eb_rels=(1e-3, 1e-4), suites=("ATM", "Hurricane", "NYX")):
    rows = [csv_row("suite", "eb_rel", "n_fields", "accuracy",
                    "cr_sz_only", "cr_zfp_only", "cr_ours", "cr_optimum",
                    "improve_vs_worst_pct", "degradation_pct", "fixed_eb_picks_sz_pct")]
    for suite_name in suites:
        fields = SUITES[suite_name]()
        for eb_rel in eb_rels:
            n_ok = 0
            bits = {"sz": 0.0, "zfp": 0.0, "ours": 0.0, "opt": 0.0}
            raw_bits = 0.0
            degr = []
            fixed_eb_sz = 0
            for name, f in fields.items():
                vr = float(f.max() - f.min())
                eb = eb_rel * vr
                sel = select(f, eb_abs=eb)
                # iso-PSNR actuals (SZ run at the matched bin size)
                b_sz = 8 * len(sz_compress(f, sel.eb_sz))
                b_zfp = 8 * len(zfp_compress(f, eb))
                best = "sz" if b_sz < b_zfp else "zfp"
                pick = sel.codec if sel.codec in ("sz", "zfp") else best
                n_ok += pick == best
                bits["sz"] += b_sz
                bits["zfp"] += b_zfp
                bits["ours"] += b_sz if pick == "sz" else b_zfp
                bits["opt"] += min(b_sz, b_zfp)
                if pick != best:
                    degr.append(max(b_sz, b_zfp) / min(b_sz, b_zfp) - 1)
                # Lu-et-al-style fixed-eb selection: larger CR at the SAME eb
                b_sz_fixed = 8 * len(sz_compress(f, eb))
                fixed_eb_sz += b_sz_fixed < b_zfp
                raw_bits += f.size * 32
            n = len(fields)
            crs = {k: raw_bits / v for k, v in bits.items()}
            worst = min(crs["sz"], crs["zfp"])
            rows.append(csv_row(
                suite_name, eb_rel, n, f"{n_ok / n:.3f}",
                f"{crs['sz']:.2f}", f"{crs['zfp']:.2f}", f"{crs['ours']:.2f}",
                f"{crs['opt']:.2f}",
                f"{100 * (crs['ours'] / worst - 1):.1f}",
                f"{100 * float(np.mean(degr)) if degr else 0:.2f}",
                f"{100 * fixed_eb_sz / n:.0f}",
            ))
    return rows


def _checkpoint_fields(n_fields: int, seed: int = 0) -> list[np.ndarray]:
    """A checkpoint-like mix: varied 1/2/3-D shapes and characteristics, so
    the per-field loop pays its worst case (jit cache misses across shapes)
    and the batched engine shows its amortization."""
    rng = np.random.default_rng(seed)
    shapes = [(256, 256), (192, 320), (128, 128), (4096,), (16, 64, 64), (96, 224)]
    out = []
    for i in range(n_fields):
        shape = shapes[i % len(shapes)]
        slope = -4.0 + 3.0 * (i % 7) / 6.0
        grids = np.meshgrid(*[np.linspace(0, 5, s) for s in shape], indexing="ij")
        smooth = np.ones(shape, np.float32)
        for g in grids:
            smooth = smooth * np.sin((1 + i % 5) * g).astype(np.float32)
        f = smooth + 10.0**slope * rng.standard_normal(shape).astype(np.float32)
        out.append(f.astype(np.float32))
    return out


def run_many(n_fields: int = 32, eb_rel: float = 1e-4, repeat: int = 3):
    """Batched `select_many` vs the per-field `select` loop."""
    fields = _checkpoint_fields(n_fields)
    # warm both paths (compile) before timing
    loop_sels = [select(f, eb_rel=eb_rel) for f in fields]
    many_sels = select_many(fields, eb_rel=eb_rel)
    agree = sum(a.codec == b.codec for a, b in zip(loop_sels, many_sels))
    t_loop = min(
        timer(lambda: [select(f, eb_rel=eb_rel) for f in fields])[1]
        for _ in range(repeat)
    )
    t_many = min(
        timer(select_many, fields, eb_rel=eb_rel)[1] for _ in range(repeat)
    )
    rows = [csv_row("n_fields", "t_per_field_s", "t_batched_s", "speedup", "decisions_agree")]
    rows.append(csv_row(
        n_fields, f"{t_loop:.4f}", f"{t_many:.4f}",
        f"{t_loop / max(t_many, 1e-9):.2f}", f"{agree}/{n_fields}",
    ))
    return rows


def main(argv: list[str] | None = None) -> None:
    import sys

    argv = sys.argv[1:] if argv is None else argv
    if "--many" in argv:
        n = 32
        for a in argv:
            if a.startswith("--fields="):
                n = int(a.split("=", 1)[1])
        for r in run_many(n_fields=n):
            print(r)
        return
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
