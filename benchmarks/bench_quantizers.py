"""Paper §5.1.4: rate-distortion comparison of the three vector-quantization
families (linear / log-scale / equal-probability) on the Stage-I residuals.

The paper argues: log-scale reaches higher PSNR per bin count but worse
entropy; equal-probability defeats entropy coding entirely (rate = log2 n);
'the most effective way is to compare their rate-distortion estimations' —
this benchmark does exactly that on each suite."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import quantize as q
from repro.core.transforms import lorenzo_forward
from repro.core.entropy import entropy_bits
from .common import SUITES, csv_row


def _rd_linear(r, vr, n_half):
    mx = np.abs(r).max() + 1e-12
    delta = 2 * mx / (2 * n_half - 1)
    k = np.round(r / delta)
    rec = k * delta
    return _pack(r, rec, k, vr)


def _rd_log(r, vr, n_half):
    codes, b = q.log_quantize(jnp.asarray(r), n_half, float(np.abs(r).max() + 1e-9))
    rec = np.asarray(q.log_dequantize(codes, b))
    return _pack(r, rec, np.asarray(codes), vr)


def _rd_equiprob(r, vr, n_bins):
    edges = np.asarray(q.equiprob_edges(jnp.asarray(r), n_bins))
    codes = np.asarray(q.equiprob_quantize(jnp.asarray(r), jnp.asarray(edges)))
    rec = np.asarray(q.equiprob_dequantize(jnp.asarray(codes), jnp.asarray(edges)))
    return _pack(r, rec, codes, vr)


def _pack(r, rec, codes, vr):
    mse = float(np.mean((r - rec) ** 2))
    psnr = -10 * np.log10(max(mse, 1e-30) / vr**2)
    hist = np.bincount((codes - codes.min()).astype(np.int64).reshape(-1))
    return entropy_bits(hist), psnr


def run(n_half: int = 256, suites=("ATM",)):
    rows = [csv_row("suite", "quantizer", "bits_per_value", "psnr_db", "psnr_per_bit")]
    for suite_name in suites:
        fields = dict(list(SUITES[suite_name]().items())[:6])
        agg = {"linear": [], "log": [], "equiprob": []}
        for f in fields.values():
            vr = float(f.max() - f.min())
            r = np.asarray(lorenzo_forward(jnp.asarray(f))).reshape(-1)
            agg["linear"].append(_rd_linear(r, vr, n_half))
            agg["log"].append(_rd_log(r, vr, n_half))
            agg["equiprob"].append(_rd_equiprob(r, vr, 2 * n_half - 1))
        for name, vals in agg.items():
            br = float(np.mean([v[0] for v in vals]))
            ps = float(np.mean([v[1] for v in vals]))
            rows.append(csv_row(suite_name, name, f"{br:.2f}", f"{ps:.1f}", f"{ps / max(br, 1e-9):.1f}"))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
