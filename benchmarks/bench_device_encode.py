"""Device-resident encode benchmark + parity probe (DESIGN.md §3.7).

Two questions about the in-graph Stage III (`core/device_encode.py`):

* **Parity** — fed the SAME quantized codes, do the device packer and the
  host Stage III emit BYTE-IDENTICAL container streams? This is the
  contract that lets the unchanged host decoders consume device-packed
  fields, and it feeds the bench gate's absolute `device_encode_parity`
  check: the mismatch list must be empty, and a run where the device tier
  declined every field (all-fallback) counts as vacuous and fails.

* **Speedup** — end-to-end encode (field in device memory -> container
  bytes on host) with the device tier vs. the host coder, on 3-D
  NYX-like smoke fields. The host path ships raw f32 values across the
  interconnect and runs the f64 coder loops; the device path ships one
  packed word arena plus small sidecars. Reported as
  `device_encode_speedup`: the geometric mean across every measured
  (field, codec) row — the save-path aggregate over the bench suite —
  gated by the 20% regression rule, with the per-codec geomeans
  alongside in `speedups`. The per-codec picture on the CPU bench host
  is asymmetric by design: SZ's gather-packed Huffman wins at every
  size, while ZFP's chunk emitter pays XLA:CPU's serialized scatter and
  only crosses over at 256^3 (the host coder's plane loops scale
  superlinearly); on an accelerator both tiers also avoid shipping the
  raw field.

    PYTHONPATH=src python -m benchmarks.bench_device_encode     # 128^3/256^3
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, nyx_suite


def _encode_host(x, eb, codec):
    from repro.core import sz_compress, zfp_compress

    return sz_compress(x, eb) if codec == "sz" else zfp_compress(x, eb)


def _encode_device(x, eb, codec):
    from repro.core import device_encode as de

    if codec == "sz":
        return de.sz_encode_device(x, eb)
    return de.zfp_encode_device(x, eb)


def _parity_check(name, x, eb) -> list[str]:
    """Byte-compare device streams against the host Stage III over the
    device's own codes (quantization held fixed, so any diff is the
    packer's fault)."""
    from repro.core import device_encode as de, sz, zfp

    bad = []
    dev_sz = de.sz_encode_device(x, eb)
    if dev_sz is not None:
        d = de.sz_device_residuals(x, eb)
        delta = float(np.float32(2.0) * np.float32(eb))
        host = sz.sz_encode_residuals(d, x.shape, delta, magic=sz.DEVICE_MAGIC)
        if dev_sz != host:
            bad.append(f"{name}:sz")
    else:
        bad.append(f"{name}:sz (declined)")
    dev_zfp = de.zfp_encode_device(x, eb)
    if dev_zfp is not None:
        q, e = de.zfp_device_codes(x, eb)
        padded = tuple(s + (-s) % 4 for s in x.shape)
        if dev_zfp != zfp.zfp_encode_quantized(q, e, x.shape, padded, eb):
            bad.append(f"{name}:zfp")
    else:
        bad.append(f"{name}:zfp (declined)")
    return bad


def _time_encode(x, eb, codec, fn, repeat) -> float:
    import jax

    xd = jax.device_put(np.asarray(x, np.float32))
    fn(xd, eb, codec)  # warm the jit caches / BLAS outside the clock
    t0 = time.perf_counter()
    for _ in range(repeat):
        buf = fn(xd, eb, codec)
        assert buf is not None and len(buf) > 0
    return (time.perf_counter() - t0) / repeat


def _geomean(vals) -> float:
    return float(np.exp(np.mean(np.log(vals)))) if len(vals) else 0.0


def run(size: int = 64, n_fields: int = 2, repeat: int = 3,
        eb_rel: float = 1e-3) -> dict:
    """Gate entry point: parity over the smoke fields + the end-to-end
    speedup aggregate (geomean over (field, codec) rows). Returns
    {speedups, device_encode_speedup, parity_mismatches, fields, rows}."""
    fields = nyx_suite(n_fields, size=(size, size, size))
    mismatches: list[str] = []
    rows = [csv_row("field", "codec", "host_s", "device_s", "speedup",
                    "device_bytes")]
    per_codec: dict[str, list[float]] = {"sz": [], "zfp": []}
    for name, x in fields.items():
        eb = eb_rel * float(x.max() - x.min())
        mismatches += _parity_check(name, x, eb)
        for codec in ("sz", "zfp"):
            th = _time_encode(x, eb, codec, _encode_host, repeat)
            td = _time_encode(x, eb, codec, _encode_device, repeat)
            nb = len(_encode_device(np.asarray(x, np.float32), eb, codec))
            per_codec[codec].append(th / td)
            rows.append(csv_row(name, codec, f"{th:.4f}", f"{td:.4f}",
                                f"{th / td:.2f}", nb))
    speedups = {codec: _geomean(vals) for codec, vals in per_codec.items()}
    return {
        "speedups": speedups,
        "device_encode_speedup": _geomean(
            [r for vals in per_codec.values() for r in vals]
        ),
        "parity_mismatches": mismatches,
        "fields": len(fields),
        "rows": rows,
    }


def main():
    # full measurement at the acceptance sizes (128^3 and 256^3)
    all_ratios: list[float] = []
    mismatches: list[str] = []
    for size, n in ((128, 2), (256, 1)):
        out = run(size=size, n_fields=n, repeat=3)
        print(f"--- {size}^3 ---")
        for r in out["rows"]:
            print(r)
        print(f"per-codec geomean: {out['speedups']}; "
              f"parity mismatches: {out['parity_mismatches'] or 'none'}")
        all_ratios += [float(r.split(",")[4]) for r in out["rows"][1:]]
        mismatches += out["parity_mismatches"]
    print(f"overall save-path speedup (geomean, all rows): "
          f"{_geomean(all_ratios):.2f}x; "
          f"parity mismatches: {mismatches or 'none'}")


if __name__ == "__main__":
    main()
