"""Quality-target controller (DESIGN.md §7): achieved-vs-target accuracy
and controller overhead on the paper-style suites.

For each suite x target, every field is solved (`solve_many`, batched
sweep launches only — no trial compressions) and then actually encoded;
the report compares the achieved PSNR / compression ratio of the real
byte streams against the target, and the controller's solve time against
the time spent encoding (the acceptance bar is solve < 10% of compress).

  PYTHONPATH=src python -m benchmarks.bench_controller
  PYTHONPATH=src python -m benchmarks.bench_controller --psnr=50,70 --ratio=4,8,16
"""

from __future__ import annotations

import numpy as np

from repro.core import Policy, decompress, encode_with_selection, solve_many
from .common import SUITES, csv_row, psnr as _psnr, timer


def _run_mode(fields, mode, target):
    pol = Policy.fixed_psnr(target) if mode == "fixed_psnr" else Policy.fixed_ratio(target)
    arrs = list(fields.values())
    solve_many(arrs, pol)  # warm the sweep jit cache before timing
    sols, t_solve = timer(solve_many, arrs, pol)
    encs, t_encode = timer(
        lambda: [encode_with_selection(a, s.selection) for a, s in zip(arrs, sols)]
    )
    errs, ratios, codecs = [], [], {"sz": 0, "zfp": 0, "raw": 0}
    for a, cf in zip(arrs, encs):
        rec = decompress(cf).reshape(a.shape)
        ratios.append(a.size * 4 / len(cf.data))
        errs.append(_psnr(a, rec))
        codecs[cf.codec] += 1
    return sols, np.asarray(errs), np.asarray(ratios), codecs, t_solve, t_encode


def run(psnr_targets=(50.0, 70.0), ratio_targets=(4.0, 8.0, 16.0), suites=("ATM", "Hurricane", "NYX")):
    rows = [csv_row("suite", "mode", "target", "n", "achieved_p50", "achieved_worst",
                    "miss_p50", "miss_worst", "picks(sz/zfp/raw)",
                    "solve_s", "encode_s", "overhead_pct")]
    for suite_name in suites:
        fields = SUITES[suite_name]()
        for target in psnr_targets:
            sols, psnrs, _, codecs, t_s, t_e = _run_mode(fields, "fixed_psnr", target)
            miss = np.abs(psnrs - target)
            lossy = np.asarray([s.selection.codec != "raw" for s in sols])
            m = miss[lossy] if lossy.any() else miss
            p = psnrs[lossy] if lossy.any() else psnrs
            rows.append(csv_row(
                suite_name, "fixed_psnr", f"{target:g}dB", len(fields),
                f"{np.median(p):.2f}dB", f"{p[np.argmax(m)]:.2f}dB",
                f"{np.median(m):.2f}dB", f"{m.max():.2f}dB",
                f"{codecs['sz']}/{codecs['zfp']}/{codecs['raw']}",
                f"{t_s:.3f}", f"{t_e:.3f}", f"{100 * t_s / max(t_e, 1e-9):.1f}",
            ))
        for target in ratio_targets:
            sols, _, ratios, codecs, t_s, t_e = _run_mode(fields, "fixed_ratio", target)
            on = np.asarray([s.on_target for s in sols])
            r = ratios[on] if on.any() else ratios
            miss = np.abs(r / target - 1.0) * 100
            rows.append(csv_row(
                suite_name, "fixed_ratio", f"{target:g}x", len(fields),
                f"{np.median(r):.2f}x", f"{r[np.argmax(miss)]:.2f}x",
                f"{np.median(miss):.1f}%", f"{miss.max():.1f}%",
                f"{codecs['sz']}/{codecs['zfp']}/{codecs['raw']}",
                f"{t_s:.3f}", f"{t_e:.3f}", f"{100 * t_s / max(t_e, 1e-9):.1f}",
            ))
    return rows


def main(argv: list[str] | None = None) -> None:
    import sys

    argv = sys.argv[1:] if argv is None else argv
    kw = {}
    for a in argv:
        if a.startswith("--psnr="):
            kw["psnr_targets"] = tuple(float(x) for x in a.split("=", 1)[1].split(","))
        elif a.startswith("--ratio="):
            kw["ratio_targets"] = tuple(float(x) for x in a.split("=", 1)[1].split(","))
    for r in run(**kw):
        print(r)


if __name__ == "__main__":
    main()
