"""Shared benchmark utilities: synthetic scientific-field suites that mimic
the paper's three data sets (ATM 2-D climate, Hurricane 3-D, NYX 3-D
cosmology), scaled to CPU-friendly sizes but spectrally diverse (smooth,
banded, turbulent, intermittent fields) so the SZ-vs-ZFP decision is
non-trivial, as in the real data where SZ wins ~73% of ATM fields."""

from __future__ import annotations

import time

import numpy as np


def _spectral_field(shape, slope, seed, nonlin=None):
    """Gaussian random field with power-law spectrum k^slope."""
    rng = np.random.default_rng(seed)
    white = rng.standard_normal(shape)
    f = np.fft.fftn(white)
    grids = np.meshgrid(*[np.fft.fftfreq(s) for s in shape], indexing="ij")
    k = np.sqrt(sum(g**2 for g in grids))
    k[tuple([0] * len(shape))] = 1e-6
    f *= k ** (slope / 2.0)
    x = np.real(np.fft.ifftn(f))
    x = (x - x.mean()) / (x.std() + 1e-12)
    if nonlin == "exp":
        x = np.exp(x)  # log-normal (density-like, NYX baryon_density)
    elif nonlin == "relu":
        x = np.maximum(x, 0)  # intermittent (PRECIP-like)
    return x.astype(np.float32)


def atm_suite(n_fields: int = 20, size=(384, 768)) -> dict[str, np.ndarray]:
    """2-D climate-like fields with varied spectral slopes and noise."""
    rng = np.random.default_rng(7)
    out = {}
    for i in range(n_fields):
        slope = -3.5 + 2.8 * i / max(n_fields - 1, 1)  # smooth .. rough
        nl = ["none", "relu", "none", "exp"][i % 4]
        f = _spectral_field(size, slope, 100 + i, None if nl == "none" else nl)
        noise = 10 ** rng.uniform(-4, -1.5)
        f = f + noise * rng.standard_normal(size).astype(np.float32)
        out[f"ATM_{i:02d}"] = f.astype(np.float32)
    return out


def hurricane_suite(n_fields: int = 13, size=(32, 96, 96)) -> dict[str, np.ndarray]:
    out = {}
    names = ["QICE", "PRECIP", "U", "V", "W", "P", "T", "QVAPOR", "QCLOUD",
             "QRAIN", "QSNOW", "QGRAUP", "CLOUD"]
    for i in range(n_fields):
        slope = -4.0 + 2.0 * i / max(n_fields - 1, 1)
        nl = "relu" if names[i % len(names)].startswith("Q") else None
        out[names[i % len(names)] + f"_{i}"] = _spectral_field(size, slope, 200 + i, nl)
    return out


def nyx_suite(n_fields: int = 6, size=(48, 48, 48)) -> dict[str, np.ndarray]:
    names = ["baryon_density", "dark_matter_density", "temperature",
             "velocity_x", "velocity_y", "velocity_z"]
    out = {}
    for i in range(n_fields):
        nl = "exp" if "density" in names[i] or "temperature" in names[i] else None
        out[names[i]] = _spectral_field(size, -2.8, 300 + i, nl)
    return out


SUITES = {"ATM": atm_suite, "Hurricane": hurricane_suite, "NYX": nyx_suite}


def psnr(a, b) -> float:
    """Value-range PSNR (the paper's metric): 10 log10(VR^2 / MSE)."""
    vr = float(np.max(a) - np.min(a))
    mse = float(np.mean((np.asarray(a, np.float64) - np.asarray(b, np.float64)) ** 2))
    return -10.0 * np.log10(max(mse, 1e-300)) + 20.0 * np.log10(max(vr, 1e-30))


def timer(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
